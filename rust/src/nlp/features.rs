//! Feature extraction: TF-IDF item vectors for the recommender and the
//! MFCC-like frame stream + pretrained acoustic weights for speech.
//!
//! The speech pipeline is a *functional* stand-in for Vosk: synthetic
//! audio features are generated from transcripts with noise, and a
//! deterministic "pretrained" acoustic model (built here, executed via
//! the AOT `acoustic_forward` artifact) maps frames back to character
//! log-probs; the Rust side greedy-decodes with CTC-style collapse. The
//! whole path — flash → features → PJRT inference → decode → WER — is
//! real; only the waveform synthesis is synthetic.

use super::corpus::MovieCatalog;
use super::text::{hash_token, l2_normalize, tokenize};
use crate::util::Rng;

// ---------------------------------------------------------------------
// Recommender features
// ---------------------------------------------------------------------

/// Build L2-normalized TF-IDF feature rows (`n × dim`, row-major) for the
/// catalogue via the hashing trick with IDF weighting.
pub fn movie_features(catalog: &MovieCatalog, dim: usize) -> Vec<f32> {
    let n = catalog.len();
    // Document frequencies (hashed into the same buckets).
    let mut df = vec![0u32; dim];
    let mut docs_tokens: Vec<Vec<(usize, f32)>> = Vec::with_capacity(n);
    for m in &catalog.movies {
        let doc = m.metadata_doc();
        let toks = tokenize(&doc);
        let mut seen = vec![false; dim];
        let mut counts: Vec<(usize, f32)> = Vec::new();
        for t in &toks {
            let h = hash_token(t);
            let idx = (h % dim as u64) as usize;
            let sign = if (h >> 63) == 0 { 1.0 } else { -1.0 };
            counts.push((idx, sign));
            if !seen[idx] {
                seen[idx] = true;
                df[idx] += 1;
            }
        }
        docs_tokens.push(counts);
    }
    let mut out = vec![0.0f32; n * dim];
    for (i, counts) in docs_tokens.iter().enumerate() {
        let row = &mut out[i * dim..(i + 1) * dim];
        for &(idx, sign) in counts {
            let idf = ((n as f32 + 1.0) / (df[idx] as f32 + 1.0)).ln() + 1.0;
            row[idx] += sign * idf;
        }
        l2_normalize(row);
    }
    out
}

// ---------------------------------------------------------------------
// Speech features + pretrained acoustic model
// ---------------------------------------------------------------------

/// Character vocabulary: a–z, space, apostrophe, CTC blank.
pub const VOCAB: usize = 29;
pub const BLANK: usize = 28;
/// Feature dimension per frame (MFCC-like).
pub const FRAME_DIM: usize = 40;

/// Map a transcript character to its vocab index (None = unsupported).
pub fn char_to_idx(c: char) -> Option<usize> {
    match c {
        'a'..='z' => Some(c as usize - 'a' as usize),
        ' ' => Some(26),
        '\'' => Some(27),
        _ => None,
    }
}

pub fn idx_to_char(i: usize) -> char {
    match i {
        0..=25 => (b'a' + i as u8) as char,
        26 => ' ',
        27 => '\'',
        _ => '\u{2205}', // blank — never emitted by the decoder
    }
}

/// Synthesize the MFCC-like frame stream for a transcript: each character
/// emits 2–3 frames of (one-hot + Gaussian noise); a blank frame is
/// inserted between repeated characters (as real CTC alignments have).
/// Returns a row-major `[n_frames × FRAME_DIM]` buffer.
pub fn speech_frames(transcript: &str, rng: &mut Rng, noise: f64) -> Vec<f32> {
    let mut frames: Vec<f32> = Vec::new();
    let mut push_frame = |idx: usize, rng: &mut Rng| {
        let start = frames.len();
        frames.resize(start + FRAME_DIM, 0.0);
        let f = &mut frames[start..];
        for v in f.iter_mut() {
            *v = (rng.gaussian() * noise) as f32;
        }
        f[idx] += 1.0;
    };
    let mut prev: Option<usize> = None;
    for c in transcript.chars() {
        let Some(idx) = char_to_idx(c) else { continue };
        if prev == Some(idx) {
            push_frame(BLANK, rng); // separator for repeated chars
        }
        let reps = rng.range_u64(2, 3);
        for _ in 0..reps {
            push_frame(idx, rng);
        }
        prev = Some(idx);
    }
    frames
}

/// Build the deterministic "pretrained" acoustic model weights matching
/// `acoustic_forward`'s signature: the identity-routing MLP that maps the
/// one-hot feature subspace through both hidden layers to the logits,
/// with sharpening gain. Shapes: w1[F,H] b1[H] w2[H,H] b2[H] w3[H,V] b3[V].
pub fn oracle_acoustic_weights(hidden: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
    let gain = 8.0f32; // sharpens the softmax; noise-robust
    let mut w1 = vec![0.0f32; FRAME_DIM * hidden];
    for c in 0..VOCAB {
        w1[c * hidden + c] = 1.0;
    }
    let b1 = vec![0.0f32; hidden];
    let mut w2 = vec![0.0f32; hidden * hidden];
    for c in 0..VOCAB {
        w2[c * hidden + c] = 1.0;
    }
    let b2 = vec![0.0f32; hidden];
    let mut w3 = vec![0.0f32; hidden * VOCAB];
    for c in 0..VOCAB {
        w3[c * VOCAB + c] = gain;
    }
    let b3 = vec![0.0f32; VOCAB];
    (w1, b1, w2, b2, w3, b3)
}

/// Greedy CTC decode: per-frame argmax, collapse repeats, drop blanks.
/// `logprobs` is row-major `[t × VOCAB]`.
pub fn greedy_ctc_decode(logprobs: &[f32], t: usize) -> String {
    assert_eq!(logprobs.len(), t * VOCAB);
    let mut out = String::new();
    let mut prev = BLANK;
    for f in 0..t {
        let row = &logprobs[f * VOCAB..(f + 1) * VOCAB];
        let mut best = 0usize;
        for (i, &v) in row.iter().enumerate() {
            if v > row[best] {
                best = i;
            }
        }
        if best != prev && best != BLANK {
            out.push(idx_to_char(best));
        }
        prev = best;
    }
    out
}

/// Pure-Rust acoustic forward (oracle for tests and a CPU fallback):
/// relu(relu(x W1 + b1) W2 + b2) W3 + b3 → per-row argmax-compatible
/// logits (softmax omitted — argmax invariant).
pub fn acoustic_forward_rust(
    frames: &[f32],
    t: usize,
    hidden: usize,
    weights: &(Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>),
) -> Vec<f32> {
    let (w1, b1, w2, b2, w3, b3) = weights;
    let mut h1 = vec![0.0f32; t * hidden];
    matmul_bias_relu(frames, w1, b1, t, FRAME_DIM, hidden, &mut h1, true);
    let mut h2 = vec![0.0f32; t * hidden];
    matmul_bias_relu(&h1, w2, b2, t, hidden, hidden, &mut h2, true);
    let mut logits = vec![0.0f32; t * VOCAB];
    matmul_bias_relu(&h2, w3, b3, t, hidden, VOCAB, &mut logits, false);
    logits
}

fn matmul_bias_relu(
    x: &[f32],
    w: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    out: &mut [f32],
    relu: bool,
) {
    for i in 0..m {
        for j in 0..n {
            let mut acc = b[j];
            for p in 0..k {
                acc += x[i * k + p] * w[p * n + j];
            }
            out[i * n + j] = if relu { acc.max(0.0) } else { acc };
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nlp::corpus::SpeechCorpus;
    use crate::nlp::wer;

    #[test]
    fn movie_features_normalized_and_similar_for_shared_metadata() {
        let c = MovieCatalog::generate(1, 500);
        let feats = movie_features(&c, 64);
        assert_eq!(feats.len(), 500 * 64);
        for i in 0..500 {
            let row = &feats[i * 64..(i + 1) * 64];
            let norm: f32 = row.iter().map(|v| v * v).sum::<f32>().sqrt();
            assert!((norm - 1.0).abs() < 1e-4, "row {i} norm {norm}");
        }
        // self-similarity is maximal
        let dot = |a: &[f32], b: &[f32]| -> f32 {
            a.iter().zip(b).map(|(x, y)| x * y).sum()
        };
        let r0 = &feats[0..64];
        let self_sim = dot(r0, r0);
        for i in 1..500 {
            let s = dot(r0, &feats[i * 64..(i + 1) * 64]);
            assert!(s <= self_sim + 1e-5);
        }
    }

    #[test]
    fn char_roundtrip() {
        for c in "abcz' ".chars() {
            let i = char_to_idx(c).unwrap();
            assert_eq!(idx_to_char(i), c);
        }
        assert!(char_to_idx('!').is_none());
    }

    #[test]
    fn frames_then_rust_decode_recovers_transcript() {
        let mut rng = Rng::new(7);
        let text = "the quick brown fox";
        let frames = speech_frames(text, &mut rng, 0.05);
        let t = frames.len() / FRAME_DIM;
        let weights = oracle_acoustic_weights(256);
        let logits = acoustic_forward_rust(&frames, t, 256, &weights);
        let decoded = greedy_ctc_decode(&logits, t);
        assert_eq!(decoded, text);
    }

    #[test]
    fn repeated_chars_survive_collapse() {
        let mut rng = Rng::new(8);
        let text = "hello all";
        let frames = speech_frames(text, &mut rng, 0.02);
        let t = frames.len() / FRAME_DIM;
        let weights = oracle_acoustic_weights(256);
        let logits = acoustic_forward_rust(&frames, t, 256, &weights);
        assert_eq!(greedy_ctc_decode(&logits, t), text, "double-l preserved");
    }

    #[test]
    fn noise_degrades_gracefully() {
        let mut rng = Rng::new(9);
        let corpus = SpeechCorpus::generate(10, 20);
        let weights = oracle_acoustic_weights(256);
        let mut total_wer = 0.0;
        for clip in &corpus.clips {
            let frames = speech_frames(&clip.transcript, &mut rng, 0.15);
            let t = frames.len() / FRAME_DIM;
            let logits = acoustic_forward_rust(&frames, t, 256, &weights);
            total_wer += wer(&clip.transcript, &greedy_ctc_decode(&logits, t));
        }
        let mean = total_wer / 20.0;
        assert!(mean < 0.15, "mean WER {mean} too high at moderate noise");
    }

    #[test]
    fn decode_drops_blanks_and_collapses() {
        // hand-built logprob stream: a a blank a b b
        let seq = [0usize, 0, BLANK, 0, 1, 1];
        let mut lp = vec![-10.0f32; seq.len() * VOCAB];
        for (f, &c) in seq.iter().enumerate() {
            lp[f * VOCAB + c] = 0.0;
        }
        assert_eq!(greedy_ctc_decode(&lp, seq.len()), "aab");
    }
}
