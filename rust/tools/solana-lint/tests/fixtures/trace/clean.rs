// Negative fixture (ISSUE-9): the sanctioned tracer shape — simulated
// time carried as plain f64 seconds from the DES clock, and spans keyed
// in a BTreeMap so every drain is id-ordered.
use std::collections::BTreeMap;

pub struct Span {
    pub t0: f64,
    pub t1: f64,
}

pub fn record(now: f64, open: &mut BTreeMap<u64, Span>, id: u64) {
    open.insert(id, Span { t0: now, t1: now });
}

pub fn export_spans(open: &BTreeMap<u64, Span>) -> Vec<f64> {
    open.values().map(|s| s.t1 - s.t0).collect()
}
