//! Fleet layer: N storage servers processing one sharded corpus.
//!
//! The paper's headline numbers come from "datacenter-grade storage
//! servers comprised of clusters of the Solana" (§IV) — a *rack*, not a
//! single host. This module lifts the single-server scheduler
//! ([`crate::sched::run`], unchanged — it becomes the per-server inner
//! loop) to a fleet of servers:
//!
//! 1. **Sharding** — the corpus is split across servers proportionally
//!    to their storage capacity (drive census; every bay holds the same
//!    drive model, so populated-bay count is the capacity weight), with
//!    cumulative-quota rounding so the total is conserved exactly.
//! 2. **Per-server phase** — each server runs the paper's pull scheduler
//!    over its own shard in virtual time. Servers share nothing (their
//!    own drives, own tunnels, own shared-FS partitions), so the runs
//!    are independent and a 1-server fleet is *bit-identical* to a
//!    direct [`crate::sched::run`] (property-tested).
//! 3. **Aggregation phase** — after the slowest server finishes, every
//!    non-head server ships its result block (per-item outputs + a
//!    64-byte header) to the head server over the top-of-rack
//!    [`RackLink`]; the transfers serialize on the head's downlink.
//!
//! Fleet shapes ([`FleetShape`]) cover the deployments the CSD
//! literature argues about: `all-csd` (every server's ISPs engaged),
//! `all-ssd` (plain enterprise-SSD baseline: same bays, every ISP off),
//! and `mixed` (50/50, the survey's realistic datacenter configuration
//! — arXiv 2112.09691). Experiment Fig 8
//! ([`crate::exp::fig8_scaleout`], `solana fig8`, `cargo bench --bench
//! fleet_scaleout`) sweeps 1→8 servers for all three apps in all three
//! shapes.

use crate::interconnect::RackLink;
use crate::metrics::Metrics;
use crate::power::PowerModel;
use crate::sched::{self, RunReport, SchedConfig};
use crate::workloads::{App, AppModel};

/// Fleet composition: which servers get their ISP engines engaged.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum FleetShape {
    /// Every server is a CSD server (ISPs engaged per the template's
    /// `isp_drives`).
    #[default]
    AllCsd,
    /// Plain enterprise-SSD baseline: same drive census, every ISP
    /// disabled — the fleet-level analogue of
    /// [`SchedConfig::baseline`].
    AllSsd,
    /// 50/50 CSD/SSD servers (even-indexed servers are CSD, so the head
    /// and any 1-server fleet stay CSD); the mixed deployment the CSD
    /// survey flags as the realistic datacenter configuration.
    Mixed,
}

impl FleetShape {
    /// Stable lowercase name used by the CLI, TOML configs and reports.
    pub fn name(&self) -> &'static str {
        match self {
            FleetShape::AllCsd => "all-csd",
            FleetShape::AllSsd => "all-ssd",
            FleetShape::Mixed => "mixed",
        }
    }

    pub fn all() -> [FleetShape; 3] {
        [FleetShape::AllCsd, FleetShape::AllSsd, FleetShape::Mixed]
    }
}

/// One server's resolved place in the fleet: its scheduler config and
/// its capacity weight for corpus sharding.
#[derive(Clone, Debug)]
pub struct ServerSpec {
    pub index: usize,
    pub sched: SchedConfig,
    /// Capacity weight (populated bays; every bay holds the same drive
    /// model, so the drive census is the capacity proxy).
    pub weight: u64,
}

impl ServerSpec {
    /// Whether this server computes in storage (any ISP engaged).
    pub fn is_csd(&self) -> bool {
        self.sched.isp_drives > 0
    }
}

/// Fleet-level configuration: the per-server scheduler template plus
/// the rack topology. Loaded from the `[fleet]` TOML section (see
/// [`crate::config`]) and the `solana fleet` CLI.
#[derive(Clone, Debug)]
pub struct FleetConfig {
    /// Number of storage servers in the fleet.
    pub servers: usize,
    /// Which servers get their ISPs engaged.
    pub shape: FleetShape,
    /// Per-server scheduler template. `isp_drives` applies to CSD
    /// servers; SSD-baseline servers run with every ISP disabled.
    pub sched: SchedConfig,
    /// Top-of-rack link bandwidth into the head server (bytes/s).
    pub rack_bandwidth: f64,
    /// Per-message overhead on the rack link (s).
    pub rack_msg_overhead: f64,
    /// Heterogeneous capacity weights, one per server (`[fleet]
    /// weights = [..]` / `solana fleet --weights`). `None` (default)
    /// weighs every server by its drive census, today's homogeneous
    /// behavior. Must have exactly `servers` positive entries.
    pub weights: Option<Vec<u64>>,
    /// Shard replication factor for serving failover (`[fleet]
    /// replicas` / `solana serve --replicas`, ISSUE-6): with
    /// `replicas >= 1`, each shard's data is also resident on the next
    /// server(s) in ring order, so the front door can fail a
    /// believed-dead server's traffic over to its neighbor. 0 (default)
    /// disables failover routing. Must be < `servers`.
    pub replicas: usize,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            servers: 1,
            shape: FleetShape::AllCsd,
            sched: SchedConfig::default(),
            rack_bandwidth: crate::interconnect::RACK_BANDWIDTH,
            rack_msg_overhead: crate::interconnect::RACK_MSG_OVERHEAD,
            weights: None,
            replicas: 0,
        }
    }
}

impl FleetConfig {
    /// Resolve the per-server specs this fleet shape implies. Capacity
    /// weights come from the explicit `weights` override when present
    /// (heterogeneous fleets), else every server weighs its drive
    /// census. Use [`FleetConfig::validate_weights`] first when the
    /// config came from user input.
    pub fn server_specs(&self) -> Vec<ServerSpec> {
        (0..self.servers)
            .map(|i| {
                let mut sched = self.sched.clone();
                let csd = match self.shape {
                    FleetShape::AllCsd => true,
                    FleetShape::AllSsd => false,
                    FleetShape::Mixed => i % 2 == 0,
                };
                if !csd {
                    sched.isp_drives = 0;
                }
                let weight = match &self.weights {
                    Some(w) => {
                        // The `weights` invariant (one positive entry
                        // per server) is checked by `validate_weights`
                        // on every config-driven path; a library caller
                        // that skips it must not get silently-padded
                        // weights.
                        assert_eq!(
                            w.len(),
                            self.servers,
                            "fleet.weights has {} entries for {} servers (call validate_weights)",
                            w.len(),
                            self.servers
                        );
                        w[i]
                    }
                    None => self.sched.drives as u64,
                };
                ServerSpec { index: i, sched, weight }
            })
            .collect()
    }

    /// Check an explicit weight vector against the fleet: exactly one
    /// positive weight per server (and in particular never empty).
    pub fn validate_weights(&self) -> anyhow::Result<()> {
        if let Some(w) = &self.weights {
            anyhow::ensure!(
                !w.is_empty(),
                "fleet.weights is empty: list one positive weight per server (or omit the key \
                 for homogeneous capacity)"
            );
            anyhow::ensure!(
                w.len() == self.servers,
                "fleet.weights has {} entries for {} servers",
                w.len(),
                self.servers
            );
            anyhow::ensure!(w.iter().all(|&x| x > 0), "fleet.weights must all be positive");
        }
        Ok(())
    }
}

/// Split `items` across weights proportionally, conserving the total
/// exactly: server `i` gets quota `floor(items·W_{0..=i}/W) −
/// floor(items·W_{0..<i}/W)` (cumulative-quota rounding; the product is
/// widened through u128 like the scheduler's pass-0 share).
pub fn shard_by_weight(items: u64, weights: &[u64]) -> Vec<u64> {
    let total: u64 = weights.iter().sum();
    assert!(total > 0, "shard_by_weight needs a positive total weight");
    let mut shards = Vec::with_capacity(weights.len());
    let mut cum: u64 = 0;
    let mut prev: u64 = 0;
    for &w in weights {
        cum += w;
        let hi = (items as u128 * cum as u128 / total as u128) as u64;
        shards.push(hi - prev);
        prev = hi;
    }
    debug_assert_eq!(prev, items);
    shards
}

/// Everything a fleet run produces: the per-server [`RunReport`]s plus
/// the cross-server rollups Fig 8 plots.
#[derive(Clone, Debug)]
pub struct FleetReport {
    pub app: &'static str,
    /// [`FleetShape::name`] of the shape that produced this report.
    pub shape: &'static str,
    pub servers: usize,
    pub total_items: u64,
    /// Slowest server's processing phase plus the head's aggregation
    /// drain (every server's clock starts when its shard is resident,
    /// mirroring the single-server runner's post-ingest clock).
    pub makespan_secs: f64,
    pub items_per_sec: f64,
    pub words_per_sec: f64,
    pub host_items: u64,
    pub csd_items: u64,
    /// Result-aggregation traffic over the top-of-rack link.
    pub rack_bytes: u64,
    pub rack_messages: u64,
    /// Aggregation-phase duration (barrier → last block delivered).
    pub agg_secs: f64,
    /// Sum of per-server energies plus idle power while a server waits
    /// for the barrier + aggregation drain.
    pub energy_j: f64,
    pub energy_per_item_j: f64,
    pub pcie_bytes: u64,
    pub isp_bytes: u64,
    pub tunnel_messages: u64,
    /// One report per server, in server order — for a 1-server all-CSD
    /// fleet this is bit-identical to a direct [`sched::run`]
    /// (property-tested).
    pub per_server: Vec<RunReport>,
}

/// Run one benchmark across the fleet; returns the fleet report.
///
/// Servers are simulated in server order — each is an independent
/// virtual-time run, so the order only affects wall-clock, never
/// results. Fleet-level sweeps (Fig 8) fan whole fleet cells out over
/// [`crate::exp::pool`] instead of parallelizing inside one fleet.
pub fn run_fleet(
    app: App,
    items: u64,
    cfg: &FleetConfig,
    power: &PowerModel,
    metrics: &mut Metrics,
) -> anyhow::Result<FleetReport> {
    anyhow::ensure!(cfg.servers >= 1, "need at least one server in the fleet");
    anyhow::ensure!(
        cfg.sched.drives > 0,
        "need at least one drive bay per server for data"
    );
    anyhow::ensure!(
        cfg.rack_bandwidth > 0.0 && cfg.rack_bandwidth.is_finite(),
        "rack_bandwidth must be positive and finite, got {}",
        cfg.rack_bandwidth
    );
    anyhow::ensure!(
        cfg.rack_msg_overhead >= 0.0 && cfg.rack_msg_overhead.is_finite(),
        "rack_msg_overhead must be non-negative and finite, got {}",
        cfg.rack_msg_overhead
    );
    cfg.validate_weights()?;
    let specs = cfg.server_specs();
    let weights: Vec<u64> = specs.iter().map(|s| s.weight).collect();
    let shards = shard_by_weight(items, &weights);

    // ---- per-server phase -------------------------------------------
    let mut per_server: Vec<RunReport> = Vec::with_capacity(cfg.servers);
    for (spec, &n) in specs.iter().zip(&shards) {
        let model = AppModel::for_app(app, n);
        per_server.push(sched::run(&model, &spec.sched, power, metrics)?);
    }

    // ---- aggregation phase ------------------------------------------
    // Barrier at the slowest server, then every non-head server ships
    // its result block (64-byte header + per-item outputs) to the head;
    // the blocks serialize on the head's downlink.
    let barrier = per_server.iter().map(|r| r.makespan_secs).fold(0.0, f64::max);
    let model = AppModel::for_app(app, items);
    let mut rack = RackLink::new(cfg.rack_bandwidth, cfg.rack_msg_overhead);
    let mut agg_end = barrier;
    for (i, &n) in shards.iter().enumerate() {
        if i == 0 || n == 0 {
            continue; // head results are local; empty shards send nothing
        }
        let bytes = 64 + n * model.output_bytes_per_item;
        agg_end = agg_end.max(rack.send(barrier, bytes));
    }
    let makespan = agg_end.max(1e-9);

    // ---- rollups -----------------------------------------------------
    // Energy: each server's own run, plus chassis+drive idle power for
    // the gap between its finish and the end of aggregation (a server
    // that drained early still burns idle watts until the fleet is
    // done).
    let mut energy = 0.0;
    for (spec, r) in specs.iter().zip(&per_server) {
        let gap = (agg_end - r.makespan_secs).max(0.0);
        energy += r.energy_j + power.instantaneous_w(spec.sched.drives, 0.0, 0) * gap;
    }
    let items_per_sec = items as f64 / makespan;
    let host_items: u64 = per_server.iter().map(|r| r.host_items).sum();
    let csd_items: u64 = per_server.iter().map(|r| r.csd_items).sum();

    metrics.inc("fleet.servers", cfg.servers as f64);
    metrics.inc("fleet.rack_bytes", rack.bytes_moved() as f64);
    metrics.inc("fleet.rack_messages", rack.messages() as f64);
    metrics.inc("fleet.energy_j", energy);

    Ok(FleetReport {
        app: model.app.name(),
        shape: cfg.shape.name(),
        servers: cfg.servers,
        total_items: items,
        makespan_secs: makespan,
        items_per_sec,
        words_per_sec: items_per_sec * model.words_per_item,
        host_items,
        csd_items,
        rack_bytes: rack.bytes_moved(),
        rack_messages: rack.messages(),
        agg_secs: agg_end - barrier,
        energy_j: energy,
        energy_per_item_j: if items > 0 { energy / items as f64 } else { 0.0 },
        pcie_bytes: per_server.iter().map(|r| r.pcie_bytes).sum(),
        isp_bytes: per_server.iter().map(|r| r.isp_bytes).sum(),
        tunnel_messages: per_server.iter().map(|r| r.tunnel_messages).sum(),
        per_server,
    })
}

impl FleetReport {
    /// Fraction of input data processed in storage, fleet-wide.
    pub fn csd_data_fraction(&self) -> f64 {
        if self.total_items == 0 {
            return 0.0;
        }
        self.csd_items as f64 / self.total_items as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop::{check, forall};

    fn fleet(app: App, items: u64, cfg: &FleetConfig) -> FleetReport {
        let mut m = Metrics::new();
        run_fleet(app, items, cfg, &PowerModel::default(), &mut m).unwrap()
    }

    #[test]
    fn shard_by_weight_conserves_and_is_proportional() {
        let shards = shard_by_weight(100, &[1, 1, 1, 1]);
        assert_eq!(shards, vec![25, 25, 25, 25]);
        let shards = shard_by_weight(10, &[3, 1]);
        assert_eq!(shards.iter().sum::<u64>(), 10);
        assert!(shards[0] > shards[1]);
        // indivisible: remainder lands deterministically, total exact
        let shards = shard_by_weight(3275, &[36, 36, 36, 36]);
        assert_eq!(shards.iter().sum::<u64>(), 3275);
        assert_eq!(shards, vec![818, 819, 819, 819]);
        // paper-scale corpora: the quota product needs u128
        let shards = shard_by_weight(12_000_000_000, &[36, 36, 36]);
        assert_eq!(shards.iter().sum::<u64>(), 12_000_000_000);
    }

    #[test]
    fn shapes_resolve_isp_census() {
        let mk = |shape| FleetConfig { servers: 5, shape, ..FleetConfig::default() };
        let csd: Vec<bool> =
            mk(FleetShape::AllCsd).server_specs().iter().map(|s| s.is_csd()).collect();
        assert_eq!(csd, vec![true; 5]);
        let ssd: Vec<bool> =
            mk(FleetShape::AllSsd).server_specs().iter().map(|s| s.is_csd()).collect();
        assert_eq!(ssd, vec![false; 5]);
        let mixed: Vec<bool> =
            mk(FleetShape::Mixed).server_specs().iter().map(|s| s.is_csd()).collect();
        assert_eq!(mixed, vec![true, false, true, false, true]);
        // the SSD servers keep their drive census — only the ISPs go
        for s in mk(FleetShape::AllSsd).server_specs() {
            assert_eq!(s.sched.drives, SchedConfig::default().drives);
            assert_eq!(s.sched.isp_drives, 0);
        }
    }

    #[test]
    fn zero_servers_rejected() {
        let cfg = FleetConfig { servers: 0, ..FleetConfig::default() };
        let mut m = Metrics::new();
        assert!(run_fleet(App::Sentiment, 100, &cfg, &PowerModel::default(), &mut m).is_err());
    }

    #[test]
    fn explicit_weights_feed_server_specs_and_sharding() {
        // The ISSUE-4 satellite: `[fleet] weights = [..]` overrides the
        // drive-census default, and the corpus shards proportionally.
        let cfg = FleetConfig {
            servers: 3,
            weights: Some(vec![3, 1, 2]),
            sched: SchedConfig { csd_batch: 2_000, ..SchedConfig::default() },
            ..FleetConfig::default()
        };
        let specs = cfg.server_specs();
        assert_eq!(specs.iter().map(|s| s.weight).collect::<Vec<_>>(), vec![3, 1, 2]);
        let r = fleet(App::Sentiment, 60_000, &cfg);
        assert_eq!(r.per_server[0].total_items, 30_000);
        assert_eq!(r.per_server[1].total_items, 10_000);
        assert_eq!(r.per_server[2].total_items, 20_000);
        assert_eq!(r.host_items + r.csd_items, 60_000);
        // Default (no weights): drive census everywhere.
        let homog = FleetConfig { servers: 3, ..FleetConfig::default() };
        for s in homog.server_specs() {
            assert_eq!(s.weight, SchedConfig::default().drives as u64);
        }
    }

    #[test]
    fn bad_weight_vectors_rejected() {
        let mut m = Metrics::new();
        let wrong_len = FleetConfig { servers: 2, weights: Some(vec![1]), ..FleetConfig::default() };
        assert!(run_fleet(App::Sentiment, 100, &wrong_len, &PowerModel::default(), &mut m).is_err());
        let zero = FleetConfig { servers: 2, weights: Some(vec![1, 0]), ..FleetConfig::default() };
        assert!(run_fleet(App::Sentiment, 100, &zero, &PowerModel::default(), &mut m).is_err());
    }

    #[test]
    fn property_shard_by_weight_conserves_over_uneven_weights() {
        // The ISSUE-4 satellite: for any positive weight vector and any
        // corpus size, the weighted shards sum to the corpus exactly and
        // each shard is within one quantum of its proportional share.
        forall("weighted sharding conservation", 50, |g| {
            let n = g.usize(1..=12);
            let weights: Vec<u64> = (0..n).map(|_| g.u64(1..=10_000)).collect();
            let items = g.u64(0..=50_000_000);
            let shards = shard_by_weight(items, &weights);
            check(shards.len() == n, format!("len {} != {n}", shards.len()))?;
            check(
                shards.iter().sum::<u64>() == items,
                format!("weights {weights:?} items {items}: sum {} != {items}", shards.iter().sum::<u64>()),
            )?;
            let total: u64 = weights.iter().sum();
            for (i, (&s, &w)) in shards.iter().zip(&weights).enumerate() {
                let exact = items as f64 * w as f64 / total as f64;
                check(
                    (s as f64 - exact).abs() <= 1.0,
                    format!("shard {i} = {s} vs exact {exact:.2} (weights {weights:?}, items {items})"),
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn all_ssd_fleet_moves_no_isp_bytes() {
        let cfg = FleetConfig {
            servers: 2,
            shape: FleetShape::AllSsd,
            sched: SchedConfig { csd_batch: 5_000, ..SchedConfig::default() },
            ..FleetConfig::default()
        };
        let r = fleet(App::Sentiment, 50_000, &cfg);
        assert_eq!(r.csd_items, 0);
        assert_eq!(r.isp_bytes, 0);
        assert_eq!(r.host_items, 50_000);
    }

    #[test]
    fn aggregation_traffic_counts_every_non_head_shard() {
        let servers = 4;
        let items = 40_000u64;
        let cfg = FleetConfig {
            servers,
            sched: SchedConfig { csd_batch: 2_000, ..SchedConfig::default() },
            ..FleetConfig::default()
        };
        let r = fleet(App::Sentiment, items, &cfg);
        assert_eq!(r.rack_messages, (servers - 1) as u64);
        // equal weights, divisible corpus: 3 shards of 10k leave the rack
        let out = AppModel::sentiment(1).output_bytes_per_item;
        assert_eq!(r.rack_bytes, 3 * (64 + 10_000 * out));
        assert!(r.agg_secs > 0.0);
        assert_eq!(r.host_items + r.csd_items, items);
    }

    #[test]
    fn one_server_fleet_rollup_matches_inner_report() {
        let cfg = FleetConfig {
            servers: 1,
            sched: SchedConfig { csd_batch: 6, batch_ratio: 20.0, ..SchedConfig::default() },
            ..FleetConfig::default()
        };
        let r = fleet(App::SpeechToText, 1_310, &cfg);
        assert_eq!(r.per_server.len(), 1);
        let inner = &r.per_server[0];
        assert_eq!(r.makespan_secs.to_bits(), inner.makespan_secs.to_bits());
        assert_eq!(r.items_per_sec.to_bits(), inner.items_per_sec.to_bits());
        assert_eq!(r.energy_j.to_bits(), inner.energy_j.to_bits());
        assert_eq!(r.rack_messages, 0);
        assert_eq!(r.rack_bytes, 0);
    }

    #[test]
    fn property_one_server_all_csd_fleet_is_bit_identical_to_direct_run() {
        // ISSUE-3 satellite: the fleet layer adds *nothing* to a
        // 1-server all-CSD fleet — its per-server RunReport is
        // bit-identical to a direct sched::run with the same SchedConfig,
        // across randomized configs × all three apps.
        forall("1-server fleet ≡ direct run", 10, |g| {
            let drives = g.usize(1..=36);
            let isp_drives = g.usize(0..=drives);
            let items = g.u64(500..=20_000);
            let batch = g.u64(1..=2_000);
            let ratio = g.f64(1.0, 30.0);
            let fair_tail = g.bool();
            let app = *g.rng().choose(&App::all());
            let sched_cfg = SchedConfig {
                csd_batch: batch,
                batch_ratio: ratio,
                drives,
                isp_drives,
                fair_tail,
                ..SchedConfig::default()
            };
            let ctx = format!(
                "{app:?} drives={drives} isp={isp_drives} items={items} batch={batch} ratio={ratio:.2} fair_tail={fair_tail}"
            );
            let model = AppModel::for_app(app, items);
            let mut m1 = Metrics::new();
            let direct = sched::run(&model, &sched_cfg, &PowerModel::default(), &mut m1)
                .map_err(|e| format!("{ctx}: direct run failed: {e}"))?;
            let fcfg = FleetConfig {
                servers: 1,
                shape: FleetShape::AllCsd,
                sched: sched_cfg,
                ..FleetConfig::default()
            };
            let mut m2 = Metrics::new();
            let fleet = run_fleet(app, items, &fcfg, &PowerModel::default(), &mut m2)
                .map_err(|e| format!("{ctx}: fleet run failed: {e}"))?;
            check(fleet.per_server.len() == 1, format!("{ctx}: expected one per-server report"))?;
            fleet.per_server[0]
                .check_bit_identical(&direct)
                .map_err(|e| format!("{ctx}: {e}"))?;
            check(
                fleet.makespan_secs.to_bits() == direct.makespan_secs.to_bits(),
                format!(
                    "{ctx}: fleet makespan {} != direct {}",
                    fleet.makespan_secs, direct.makespan_secs
                ),
            )?;
            check(
                fleet.energy_j.to_bits() == direct.energy_j.to_bits(),
                format!("{ctx}: fleet energy {} != direct {}", fleet.energy_j, direct.energy_j),
            )
        });
    }

    #[test]
    fn scaleout_gate_four_all_csd_servers() {
        // The ISSUE-3 acceptance gate behind `solana fleet --servers 4
        // --shape all-csd` / Fig 8: 1→4 all-CSD servers buys ≥3.5×
        // aggregate items/s while per-item energy stays within 10% of
        // the single-server value. Runs at the Fig 8 operating point
        // ([`crate::exp::scaleout_batch`]) on paper-sized corpora:
        // shards must hold many CSD batches and per-server makespans
        // must dwarf both one batch and the 0.2 s polling grid, or
        // batch/grid quantization (not the fleet layer) dominates.
        for (app, items) in
            [(App::SpeechToText, 13_100), (App::Recommender, 58_000), (App::Sentiment, 2_000_000)]
        {
            let mk = |servers| FleetConfig {
                servers,
                shape: FleetShape::AllCsd,
                sched: SchedConfig {
                    csd_batch: crate::exp::scaleout_batch(app),
                    batch_ratio: crate::exp::batch_ratio(app),
                    ..SchedConfig::default()
                },
                ..FleetConfig::default()
            };
            let one = fleet(app, items, &mk(1));
            let four = fleet(app, items, &mk(4));
            let speedup = four.items_per_sec / one.items_per_sec;
            assert!(
                speedup >= 3.5,
                "{app:?}: 1→4 servers speedup {speedup:.2}x ({:.1} vs {:.1} items/s)",
                four.items_per_sec,
                one.items_per_sec
            );
            assert!(
                speedup <= 4.5,
                "{app:?}: super-linear fleet scaling {speedup:.2}x is a bug"
            );
            let drift = (four.energy_per_item_j - one.energy_per_item_j).abs()
                / one.energy_per_item_j;
            assert!(
                drift <= 0.10,
                "{app:?}: per-item energy drifted {:.1}% (1 server {:.4} J, 4 servers {:.4} J)",
                drift * 100.0,
                one.energy_per_item_j,
                four.energy_per_item_j
            );
            assert_eq!(four.host_items + four.csd_items, items);
        }
    }

    #[test]
    fn mixed_fleet_sits_between_csd_and_ssd() {
        // At equal shard sizes the SSD half of a mixed fleet is the
        // straggler, so mixed throughput lands between the two pure
        // shapes (closer to all-SSD: the barrier waits for the slowest).
        let items = 200_000;
        let mk = |shape| FleetConfig {
            servers: 4,
            shape,
            sched: SchedConfig {
                csd_batch: 500, // scale-out operating point (exp::scaleout_batch)
                batch_ratio: 26.0,
                ..SchedConfig::default()
            },
            ..FleetConfig::default()
        };
        let csd = fleet(App::Sentiment, items, &mk(FleetShape::AllCsd));
        let ssd = fleet(App::Sentiment, items, &mk(FleetShape::AllSsd));
        let mixed = fleet(App::Sentiment, items, &mk(FleetShape::Mixed));
        assert!(
            csd.items_per_sec > mixed.items_per_sec && mixed.items_per_sec >= ssd.items_per_sec,
            "csd {:.0} / mixed {:.0} / ssd {:.0} items/s",
            csd.items_per_sec,
            mixed.items_per_sec,
            ssd.items_per_sec
        );
        assert!(mixed.csd_items > 0, "the CSD half processed in storage");
        assert!(
            mixed.csd_data_fraction() < csd.csd_data_fraction(),
            "half the fleet offloads less than all of it"
        );
    }
}
