// Positive fixture for D4 no-unwrap: unwrap, expect and panic! in
// non-test library code must all fire.
pub fn parse(s: &str) -> u64 {
    let v: u64 = s.parse().unwrap();
    let w: u64 = s.parse().expect("bad number");
    if v != w {
        panic!("mismatch");
    }
    v
}
