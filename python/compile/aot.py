"""AOT bridge: lower each L2 graph to HLO *text* + write the manifest.

Run once at build time (``make artifacts``); the rust runtime then loads
``artifacts/*.hlo.txt`` through ``HloModuleProto::from_text_file`` and
executes them on the PJRT CPU client.  Python never runs at request time.

HLO text — not ``.serialize()`` — is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which the crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly.  (See /opt/xla-example/README.md.)

Usage:  python -m compile.aot --out ../artifacts
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def f32(*shape):
    return spec(tuple(shape), jnp.float32)


def artifact_defs():
    """(name, variant, fn, example_args) for every executable we ship."""
    F = model.SENT_FEATURES
    N, D = model.REC_ITEMS, model.REC_DIM
    T, MF = model.SPEECH_FRAMES, model.SPEECH_FEATURES
    H, V = model.SPEECH_HIDDEN, model.SPEECH_VOCAB

    defs = []
    for bsz in (32, 256):
        defs.append((
            "sentiment_infer", f"b{bsz}", model.sentiment_infer,
            [f32(bsz, F), f32(F, 1), f32(1)],
        ))
    bt = model.SENT_TRAIN_BATCH
    defs.append((
        "sentiment_train_step", f"b{bt}", model.sentiment_train_step,
        [f32(bt, F), f32(bt), f32(F, 1), f32(1), f32()],
    ))
    for q in (1, 32):
        defs.append((
            "recommender_topk", f"q{q}", model.recommender_topk,
            [f32(N, D), f32(N), f32(q, D)],
        ))
    defs.append((
        "acoustic_forward", f"t{T}", model.acoustic_forward,
        [f32(T, MF), f32(MF, H), f32(H), f32(H, H), f32(H), f32(H, V), f32(V)],
    ))
    return defs


def dims_dict():
    return {
        "sent_features": model.SENT_FEATURES,
        "sent_train_batch": model.SENT_TRAIN_BATCH,
        "rec_items": model.REC_ITEMS,
        "rec_dim": model.REC_DIM,
        "rec_topk": model.REC_TOPK,
        "speech_frames": model.SPEECH_FRAMES,
        "speech_features": model.SPEECH_FEATURES,
        "speech_hidden": model.SPEECH_HIDDEN,
        "speech_vocab": model.SPEECH_VOCAB,
    }


def lower_all(out_dir: str, verbose: bool = True):
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"format": 1, "dims": dims_dict(), "artifacts": []}
    for name, variant, fn, args in artifact_defs():
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        fname = f"{name}__{variant}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        out_shapes = [
            {"shape": list(s.shape), "dtype": str(s.dtype)}
            for s in jax.eval_shape(fn, *args)
        ]
        manifest["artifacts"].append({
            "name": name,
            "variant": variant,
            "file": fname,
            "inputs": [
                {"shape": list(a.shape), "dtype": str(a.dtype)} for a in args
            ],
            "outputs": out_shapes,
        })
        if verbose:
            print(f"lowered {name}__{variant}: {len(text)} chars, "
                  f"{len(args)} inputs, {len(out_shapes)} outputs")
    man_path = os.path.join(out_dir, "manifest.json")
    with open(man_path, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    if verbose:
        print(f"wrote {man_path} ({len(manifest['artifacts'])} artifacts)")
    return manifest


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts",
                    help="output directory for *.hlo.txt + manifest.json")
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args()
    lower_all(args.out, verbose=not args.quiet)


if __name__ == "__main__":
    main()
