//! Experiment drivers: one function per paper figure/table, shared by
//! the `cargo bench` targets, the examples, and the `solana` CLI.
//!
//! Experiment index (DESIGN.md §6):
//!
//! | fn | paper artifact |
//! |----|----------------|
//! | [`fig5`] | Fig 5(a/b/c): throughput vs batch size × #CSDs |
//! | [`fig6`] | Fig 6: 1-node sentiment throughput vs batch size |
//! | [`fig7`] | Fig 7: normalized energy/query vs #CSDs |
//! | [`table1`] | Table I: summary of all benchmarks |
//! | [`power_breakdown`] | §IV-C wall-power measurements |
//! | [`ablate_batch_ratio`] | A1: off-optimal batch ratios under-utilize |
//! | [`ablate_datapath`] | A2: shared-FS index dispatch vs tunnel data |
//! | [`ablate_wakeup`] | A3: scheduler polling period sensitivity |
//! | [`ablate_dispatch`] | A4: polling vs event-driven dispatch |
//! | [`fig8_scaleout`] | Fig 8 (ours): fleet scale-out, 1→8 servers × 3 shapes |
//! | [`fig9_latency`] | Fig 9 (ours): serving latency vs offered load × 3 shapes |
//! | [`fig10_autoscale`] | Fig 10 (ours): min servers to meet the p99 SLO vs offered load |
//! | [`fig11_availability`] | Fig 11 (ours): availability under faults × resilience policy |
//! | [`fig12_elastic`] | Fig 12 (ours): elastic fleet — autoscaler + rebalancer vs the best static fleet |
//! | [`fig13_gc`] | Fig 13 (ours): write + GC interference — tail latency and WAF under ingest |
//!
//! Every sweep fans its independent cells out over the deterministic
//! worker pool in [`pool`] (sized by `--threads` / `SOLANA_THREADS` /
//! core count). Each cell owns its `Metrics` and simulated server, and
//! results are collected in input order, so the emitted tables are
//! byte-identical to a sequential run.

pub mod cli;
pub mod pool;

use crate::cluster::fleet::{run_fleet, FleetConfig, FleetShape};
use crate::csd::flash::FlashConfig;
use crate::csd::CsdConfig;
use crate::faults::FaultsConfig;
use crate::metrics::{Metrics, Table};
use crate::power::PowerModel;
use crate::sched::{run, DispatchMode, RunReport, SchedConfig};
use crate::traffic::{
    default_slo_p99, fleet_nominal_rate, serve_fleet, AutoscaleConfig, AutoscalePolicy, LbPolicy,
    ServeReport, TrafficConfig,
};
use crate::workloads::{App, AppModel};

pub use cli::dispatch;

/// Scale factor applied to the paper's dataset sizes (1.0 = full paper
/// scale; benches use smaller factors for quick runs via
/// `SOLANA_BENCH_FAST`).
#[derive(Clone, Copy, Debug)]
pub struct Scale(pub f64);

impl Scale {
    pub fn items(&self, app: App) -> u64 {
        ((AppModel::paper_items(app) as f64 * self.0) as u64).max(1_000)
    }

    pub fn from_env() -> Scale {
        if std::env::var("SOLANA_BENCH_FAST").ok().as_deref() == Some("1") {
            Scale(0.05)
        } else {
            Scale(0.25)
        }
    }
}

/// Default batch-size sweep per app (the paper's Fig 5 x-values; the
/// recommender's are not stated in the paper — we use a range around its
/// operating point, see DESIGN.md).
pub fn batch_sizes(app: App) -> Vec<u64> {
    match app {
        App::SpeechToText => vec![2, 4, 6, 8],
        App::Recommender => vec![64, 128, 256, 512],
        App::Sentiment => vec![10_000, 20_000, 40_000, 80_000],
    }
}

/// Default batch ratio per app (≈ host/CSD speed ratio, §IV-A).
pub fn batch_ratio(app: App) -> f64 {
    AppModel::for_app(app, 1).natural_batch_ratio().round()
}

/// #CSD sweep for Fig 5/7 (0 = host-only baseline).
pub const CSD_COUNTS: [usize; 6] = [0, 4, 9, 18, 27, 36];

fn cfg_for(app: App, batch: u64, isp_drives: usize) -> SchedConfig {
    SchedConfig {
        csd_batch: batch,
        batch_ratio: batch_ratio(app),
        drives: 36,
        isp_drives,
        ..SchedConfig::default()
    }
}

/// One throughput cell of Fig 5.
pub fn run_cell(app: App, items: u64, batch: u64, isp_drives: usize) -> anyhow::Result<RunReport> {
    let model = AppModel::for_app(app, items);
    let mut metrics = Metrics::new();
    run(&model, &cfg_for(app, batch, isp_drives), &PowerModel::default(), &mut metrics)
}

/// Fig 5(a/b/c): throughput vs batch size × engaged CSDs.
/// Rows: one per (batch, csds) with items/s and words/s.
/// Cells run concurrently on the [`pool`]; rows stay in sweep order.
pub fn fig5(app: App, scale: Scale) -> anyhow::Result<Table> {
    let items = scale.items(app);
    let unit = if app == App::SpeechToText { "words/s" } else { "queries/s" };
    let mut t = Table::new(
        &format!("Fig 5 — {} throughput ({} items)", app.name(), items),
        &["batch", "csds", unit, "host items", "csd items", "csd share"],
    );
    let mut cells: Vec<(u64, usize)> = Vec::new();
    for &batch in &batch_sizes(app) {
        for &csds in &CSD_COUNTS {
            cells.push((batch, csds));
        }
    }
    let specs = cells.clone();
    let reports = pool::map_cells(cells, move |(batch, csds)| run_cell(app, items, batch, csds));
    for ((batch, csds), r) in specs.into_iter().zip(reports) {
        let r = r?;
        let rate = if app == App::SpeechToText { r.words_per_sec } else { r.items_per_sec };
        t.row(vec![
            batch.to_string(),
            csds.to_string(),
            format!("{rate:.1}"),
            r.host_items.to_string(),
            r.csd_items.to_string(),
            format!("{:.2}", r.csd_data_fraction()),
        ]);
    }
    Ok(t)
}

/// Fig 6: single-node sentiment throughput vs batch size (log sweep),
/// host and CSD — run end-to-end with one compute node each. Each batch
/// point (a host-only plus a CSD-only run) is one pool cell.
pub fn fig6(scale: Scale) -> anyhow::Result<Table> {
    let mut t = Table::new(
        "Fig 6 — 1-node sentiment throughput vs batch size",
        &["batch", "host q/s", "csd q/s", "host batch latency s", "csd batch latency s"],
    );
    let batches = [10u64, 100, 1_000, 4_000, 10_000, 40_000, 80_000];
    let base_items = scale.items(App::Sentiment);
    let results = pool::map_cells(batches.to_vec(), move |b| {
        let items = (base_items / 8).max(4 * b);
        let model = AppModel::sentiment(items);
        let power = PowerModel::default();
        // host only, one drive holding the data
        let mut m1 = Metrics::new();
        let host = run(
            &model,
            &SchedConfig {
                csd_batch: b,
                batch_ratio: 1.0,
                drives: 1,
                isp_drives: 0,
                ..SchedConfig::default()
            },
            &power,
            &mut m1,
        )?;
        // csd only
        let mut m2 = Metrics::new();
        let csd = run(
            &model,
            &SchedConfig {
                csd_batch: b,
                batch_ratio: 1.0,
                drives: 1,
                isp_drives: 1,
                use_host: false,
                ..SchedConfig::default()
            },
            &power,
            &mut m2,
        )?;
        let hl = m1.histogram("sched.host_batch_latency").map(|h| h.mean()).unwrap_or(0.0);
        let cl = m2.histogram("sched.csd_batch_latency").map(|h| h.mean()).unwrap_or(0.0);
        Ok((host.items_per_sec, csd.items_per_sec, hl, cl))
    });
    for (&b, res) in batches.iter().zip(results) {
        let (host_rate, csd_rate, hl, cl) = res?;
        t.row(vec![
            b.to_string(),
            format!("{host_rate:.1}"),
            format!("{csd_rate:.1}"),
            format!("{hl:.3}"),
            format!("{cl:.3}"),
        ]);
    }
    Ok(t)
}

/// Fig 7: energy per query vs #CSDs, normalized to the host-only setup.
/// All (csds × app) cells run concurrently; normalization against the
/// csds=0 baseline happens after collection, in sweep order.
pub fn fig7(scale: Scale) -> anyhow::Result<Table> {
    let mut t = Table::new(
        "Fig 7 — energy per query, normalized to host-only",
        &["csds", "speech", "recommender", "sentiment"],
    );
    let mut specs: Vec<(usize, App)> = Vec::new();
    for &csds in &CSD_COUNTS {
        for app in App::all() {
            specs.push((csds, app));
        }
    }
    let ordered = specs.clone();
    let reports = pool::map_cells(specs, move |(csds, app)| {
        run_cell(app, scale.items(app), default_batch(app), csds)
    });
    // Re-join results to sweep cells by zipping the same spec vec the
    // pool consumed — a structural mismatch between the two loops fails
    // loudly instead of silently pairing rows with the wrong report.
    let mut it = ordered.into_iter().zip(reports);
    let mut base: Vec<f64> = Vec::new();
    for &csds in &CSD_COUNTS {
        let mut cells = vec![csds.to_string()];
        for (i, app) in App::all().iter().enumerate() {
            // solana-lint: allow(no-unwrap, reason = "sweep-cell pairing invariant: the assert_eq on the next lines pins producer and consumer to the same statically-built spec list")
            let ((spec_csds, spec_app), r) = it.next().expect("one report per sweep cell");
            assert_eq!((spec_csds, spec_app), (csds, *app), "sweep order drifted");
            let r = r?;
            if csds == 0 {
                base.push(r.energy_per_item_j);
                cells.push("1.000".to_string());
            } else {
                cells.push(format!("{:.3}", r.energy_per_item_j / base[i]));
            }
        }
        t.row(cells);
    }
    Ok(t)
}

/// The paper's per-app operating point in Fig 5 (best batch).
pub fn default_batch(app: App) -> u64 {
    match app {
        App::SpeechToText => 6,
        App::Recommender => 256,
        App::Sentiment => 40_000,
    }
}

/// Table I: the summary row block for every app.
pub fn table1(scale: Scale) -> anyhow::Result<Table> {
    let mut t = Table::new(
        "Table I — summary of experimental results",
        &[
            "application",
            "items",
            "max speedup",
            "energy/query host (mJ)",
            "energy/query w/CSD (mJ)",
            "energy saving",
            "data on host",
            "data in CSDs",
        ],
    );
    let mut specs: Vec<(App, usize)> = Vec::new();
    for app in App::all() {
        specs.push((app, 0));
        specs.push((app, 36));
    }
    let ordered = specs.clone();
    let reports = pool::map_cells(specs, move |(app, csds)| {
        run_cell(app, scale.items(app), default_batch(app), csds)
    });
    let mut it = ordered.into_iter().zip(reports);
    for app in App::all() {
        let items = scale.items(app);
        // solana-lint: allow(no-unwrap, reason = "sweep-cell pairing invariant: the assert_eq on the next lines pins producer and consumer to the same statically-built spec list")
        let (base_spec, base) = it.next().expect("baseline cell");
        // solana-lint: allow(no-unwrap, reason = "sweep-cell pairing invariant: the assert_eq on the next lines pins producer and consumer to the same statically-built spec list")
        let (isp_spec, isp) = it.next().expect("isp cell");
        assert_eq!(base_spec, (app, 0), "sweep order drifted");
        assert_eq!(isp_spec, (app, 36), "sweep order drifted");
        let base = base?;
        let isp = isp?;
        let speedup = isp.items_per_sec / base.items_per_sec;
        // the paper reports energy per word for speech
        let divisor = AppModel::for_app(app, items).words_per_item;
        let e_host = base.energy_per_item_j / divisor * 1e3;
        let e_isp = isp.energy_per_item_j / divisor * 1e3;
        t.row(vec![
            app.name().to_string(),
            items.to_string(),
            format!("{speedup:.1}x"),
            format!("{e_host:.0}"),
            format!("{e_isp:.0}"),
            format!("{:.0}%", (1.0 - e_isp / e_host) * 100.0),
            format!("{:.0}%", (1.0 - isp.csd_data_fraction()) * 100.0),
            format!("{:.0}%", isp.csd_data_fraction() * 100.0),
        ]);
    }
    Ok(t)
}

/// §IV-C: wall power in the four measured states.
pub fn power_breakdown() -> Table {
    let p = PowerModel::default();
    let mut t = Table::new(
        "Power breakdown (paper §IV-C)",
        &["state", "model W", "paper W"],
    );
    t.row(vec!["idle, no drives".into(), format!("{:.1}", p.instantaneous_w(0, 0.0, 0)), "167".into()]);
    t.row(vec!["idle, 36 CSDs".into(), format!("{:.1}", p.instantaneous_w(36, 0.0, 0)), "405".into()]);
    t.row(vec!["running, ISP off".into(), format!("{:.1}", p.instantaneous_w(36, 1.0, 0)), "482".into()]);
    t.row(vec!["running, 36 ISPs".into(), format!("{:.1}", p.instantaneous_w(36, 1.0, 36)), "492".into()]);
    t
}

/// A1: batch-ratio sweep at fixed batch size — off-optimal ratios
/// under-utilize one side (§IV-A).
pub fn ablate_batch_ratio(app: App, scale: Scale) -> anyhow::Result<Table> {
    let items = scale.items(app);
    let natural = batch_ratio(app);
    let mut t = Table::new(
        &format!("A1 — batch-ratio sweep ({}; natural ≈ {natural})", app.name()),
        &["ratio", "items/s", "host util", "mean csd idle gap s"],
    );
    let results = pool::map_cells(vec![0.25, 0.5, 1.0, 2.0, 4.0], move |mult| {
        let ratio = (natural * mult).max(1.0);
        let model = AppModel::for_app(app, items);
        let mut m = Metrics::new();
        let cfg = SchedConfig {
            // batch small enough that the run is many batches long per
            // node (a single-tail-batch run would mask the ratio)
            csd_batch: (default_batch(app) / 8).max(1),
            batch_ratio: ratio,
            drives: 36,
            isp_drives: 36,
            // the paper's plain scheduler — our fair-share tail fix
            // hides exactly the under-utilization this ablation shows
            fair_tail: false,
            ..SchedConfig::default()
        };
        let r = run(&model, &cfg, &PowerModel::default(), &mut m)?;
        Ok((ratio, r))
    });
    for res in results {
        let (ratio, r) = res?;
        let host_util = r.host_busy_secs / r.makespan_secs;
        let idle_gap = (r.makespan_secs * 36.0 - r.isp_busy_secs) / 36.0 / r.csd_batches.max(1) as f64;
        t.row(vec![
            format!("{ratio:.0}"),
            format!("{:.1}", r.items_per_sec),
            format!("{host_util:.2}"),
            format!("{idle_gap:.3}"),
        ]);
    }
    Ok(t)
}

/// A2: what if the scheduler shipped *data* over the TCP/IP tunnel
/// instead of indexes into the shared FS? (Why OCFS2 matters, §IV-A.)
///
/// Run on an IO-bound scan workload: the paper's NLP apps are
/// A53-compute-bound, so their data path barely shows; a grep-like scan
/// is where "GBps of PCIe/DMA vs MBps of TCP/IP" decides everything.
/// The `app` argument selects the *paper* workload shown alongside for
/// contrast.
pub fn ablate_datapath(app: App, scale: Scale) -> anyhow::Result<Table> {
    let items = (scale.items(App::Sentiment) / 100).max(5_000);
    let base = AppModel::scan(items);
    let mut t = Table::new(
        &format!("A2 — dispatch datapath (IO-bound scan; contrast app: {})", app.name()),
        &["dispatch", "items/s", "speedup vs host-only"],
    );
    let cfg = SchedConfig {
        csd_batch: 256,
        batch_ratio: 8.0,
        ..SchedConfig::default()
    };
    // tunnel-data dispatch: every CSD item's bytes cross the ~120 MB/s
    // tunnel (serialized per drive) before the scan can run
    let mut tunneled = base.clone();
    let tun = crate::interconnect::TcpTunnel::default();
    tunneled.csd_item_secs += tun.unloaded_secs(base.bytes_per_item) * crate::workloads::ISP_CORES;
    let specs: Vec<(&'static str, AppModel, SchedConfig)> = vec![
        ("host-only", base.clone(), SchedConfig { isp_drives: 0, ..cfg.clone() }),
        // index-only dispatch (the paper's design): ISPs read via local DMA
        ("shared-fs indexes", base, cfg.clone()),
        ("tunnel data", tunneled, cfg),
    ];
    let results = pool::map_cells(specs, |(name, model, cfg)| {
        let mut m = Metrics::new();
        let r = run(&model, &cfg, &PowerModel::default(), &mut m)?;
        Ok((name, r))
    });
    let mut rows = Vec::with_capacity(results.len());
    for res in results {
        rows.push(res?);
    }
    let host_rate = rows[0].1.items_per_sec;
    for (name, r) in &rows {
        t.row(vec![
            name.to_string(),
            format!("{:.1}", r.items_per_sec),
            format!("{:.2}x", r.items_per_sec / host_rate),
        ]);
    }
    Ok(t)
}

/// A3: scheduler wakeup period sensitivity (paper fixes 0.2 s), run in
/// both wake modes. Throughput and tunnel traffic are identical by the
/// coalescing invariant (the test suite asserts bit-identity); the two
/// event columns show what the fast path actually saves at each period.
pub fn ablate_wakeup(app: App, scale: Scale) -> anyhow::Result<Table> {
    let items = scale.items(app);
    let mut t = Table::new(
        &format!("A3 — scheduler wakeup period ({})", app.name()),
        &["wakeup s", "items/s", "tunnel msgs", "events coalesced", "events naive"],
    );
    let results = pool::map_cells(vec![0.02, 0.1, 0.2, 0.5, 1.0, 2.0], move |wakeup| {
        let model = AppModel::for_app(app, items);
        let mk = |coalesce: bool| SchedConfig {
            wakeup_secs: wakeup,
            coalesce_wakes: coalesce,
            ..cfg_for(app, default_batch(app), 36)
        };
        let mut m = Metrics::new();
        let coal = run(&model, &mk(true), &PowerModel::default(), &mut m)?;
        let naive = run(&model, &mk(false), &PowerModel::default(), &mut m)?;
        Ok((wakeup, coal, naive))
    });
    for res in results {
        let (wakeup, coal, naive) = res?;
        t.row(vec![
            format!("{wakeup}"),
            format!("{:.1}", coal.items_per_sec),
            coal.tunnel_messages.to_string(),
            coal.events_executed.to_string(),
            naive.events_executed.to_string(),
        ]);
    }
    Ok(t)
}

/// A4: polling vs event-driven dispatch (`DispatchMode`, the ISSUE-2
/// tentpole) across the app's batch-size sweep at 36 engaged CSDs.
///
/// Polling taxes every batch a mean half-period idle gap — the node's
/// ack waits for the next wake-grid point before new work is handed out
/// — so the relative makespan gap is largest at small batches, where
/// that gap dominates the per-batch service time. Event-driven dispatch
/// hands out each batch at or before the grid point polling would have
/// used, so its makespan is ≤ polling's on every row (asserted by the
/// test suite).
pub fn ablate_dispatch(app: App, scale: Scale) -> anyhow::Result<Table> {
    let items = scale.items(app);
    let wakeup = SchedConfig::default().wakeup_secs;
    let mut t = Table::new(
        &format!("A4 — dispatch mode ({}; polling wakeup {wakeup} s)", app.name()),
        &[
            "batch",
            "poll items/s",
            "event items/s",
            "speedup",
            "poll makespan s",
            "event makespan s",
            "poll batch lat s",
            "event batch lat s",
        ],
    );
    let results = pool::map_cells(batch_sizes(app), move |batch| {
        let model = AppModel::for_app(app, items);
        let mk = |dispatch: DispatchMode| SchedConfig { dispatch, ..cfg_for(app, batch, 36) };
        let mut m = Metrics::new();
        let poll = run(&model, &mk(DispatchMode::Polling), &PowerModel::default(), &mut m)?;
        let event = run(&model, &mk(DispatchMode::EventDriven), &PowerModel::default(), &mut m)?;
        Ok((batch, poll, event))
    });
    for res in results {
        let (batch, poll, event) = res?;
        t.row(vec![
            batch.to_string(),
            format!("{:.1}", poll.items_per_sec),
            format!("{:.1}", event.items_per_sec),
            format!("{:.2}x", event.items_per_sec / poll.items_per_sec),
            format!("{:.2}", poll.makespan_secs),
            format!("{:.2}", event.makespan_secs),
            format!("{:.3}", poll.mean_batch_latency),
            format!("{:.3}", event.mean_batch_latency),
        ]);
    }
    Ok(t)
}

/// Server-count sweep for Fig 8 (fleet scale-out).
pub const SERVER_COUNTS: [usize; 4] = [1, 2, 4, 8];

/// Per-app CSD batch size for the scale-out sweep (Fig 8).
///
/// Deliberately much smaller than [`default_batch`]: sharding a corpus
/// over 8 servers divides every drive's shard by 8, and at the Fig 5
/// operating batches a shard can be *smaller than one CSD batch* (a
/// sentiment drive holds ~7 k items of an 8-server 0.25-scale shard vs
/// a 40 k-item batch). Batch granularity — one indivisible
/// `overhead + n·t_item/cores` chunk per drive — would then dominate
/// the makespan and masquerade as poor fleet scaling. The scale-out
/// operating point keeps many batches per drive at every fleet size, so
/// Fig 8 measures the topology (sharding + barrier + rack aggregation),
/// not batch quantization. This is a real scheduling consequence of
/// scale-out, not a benchmarking trick: a fleet scheduler must shrink
/// batches as shards shrink.
pub fn scaleout_batch(app: App) -> u64 {
    match app {
        App::SpeechToText => 2,
        App::Recommender => 16,
        App::Sentiment => 500,
    }
}

/// Fig 8 (ours): fleet-level scale-out — aggregate throughput, per-item
/// energy and rack aggregation traffic for 1→8 storage servers, for all
/// three apps in all three fleet shapes (`all-csd`, the plain-SSD
/// `all-ssd` baseline, `mixed` 50/50). Every fleet cell runs its own
/// servers sequentially in virtual time; the (app × shape × servers)
/// cells fan out over the [`pool`]. Speedup is normalized to the
/// 1-server fleet of the same (app, shape). Batches come from
/// [`scaleout_batch`] (see there for why the Fig 5 operating batches
/// are wrong for sharded corpora).
pub fn fig8_scaleout(scale: Scale) -> anyhow::Result<Table> {
    let mut t = Table::new(
        "Fig 8 — fleet scale-out: 1→8 storage servers",
        &[
            "app",
            "shape",
            "servers",
            "items/s",
            "speedup",
            "energy/item J",
            "rack KiB",
            "makespan s",
        ],
    );
    let mut specs: Vec<(App, FleetShape, usize)> = Vec::new();
    for app in App::all() {
        for shape in FleetShape::all() {
            for &servers in &SERVER_COUNTS {
                specs.push((app, shape, servers));
            }
        }
    }
    let ordered = specs.clone();
    let reports = pool::map_cells(specs, move |(app, shape, servers)| {
        let cfg = FleetConfig {
            servers,
            shape,
            sched: SchedConfig {
                csd_batch: scaleout_batch(app),
                batch_ratio: batch_ratio(app),
                ..SchedConfig::default()
            },
            ..FleetConfig::default()
        };
        let mut m = Metrics::new();
        run_fleet(app, scale.items(app), &cfg, &PowerModel::default(), &mut m)
    });
    let mut it = ordered.into_iter().zip(reports);
    for app in App::all() {
        for shape in FleetShape::all() {
            let mut base_rate = 0.0f64;
            for &servers in &SERVER_COUNTS {
                let ((spec_app, spec_shape, spec_servers), r) =
                    // solana-lint: allow(no-unwrap, reason = "sweep-cell pairing invariant: the assert_eq on the next lines pins producer and consumer to the same statically-built spec list")
                    it.next().expect("one report per sweep cell");
                assert_eq!(
                    (spec_app, spec_shape, spec_servers),
                    (app, shape, servers),
                    "sweep order drifted"
                );
                let r = r?;
                if servers == SERVER_COUNTS[0] {
                    base_rate = r.items_per_sec;
                }
                t.row(vec![
                    app.name().to_string(),
                    shape.name().to_string(),
                    servers.to_string(),
                    format!("{:.1}", r.items_per_sec),
                    format!("{:.2}x", r.items_per_sec / base_rate),
                    format!("{:.4}", r.energy_per_item_j),
                    format!("{:.1}", r.rack_bytes as f64 / 1024.0),
                    format!("{:.2}", r.makespan_secs),
                ]);
            }
        }
    }
    Ok(t)
}

/// Offered-load sweep for Fig 9, as fractions of the fleet's nominal
/// service capacity ([`crate::traffic::nominal_rate`]): two points below
/// the knee, one near it, one past it (open-loop overload).
pub const FIG9_LOADS: [f64; 4] = [0.3, 0.6, 0.9, 1.2];

/// Fleet size for the Fig 9 serving cells (2 servers: the smallest
/// fleet where the balancer, the rack response path, and the mixed
/// shape are all non-trivial).
pub const FIG9_SERVERS: usize = 2;

/// Requests per Fig 9 cell: a quarter of the scaled corpus, floored so
/// the tail percentiles have resolution even at golden scale.
pub fn fig9_requests(app: App, scale: Scale) -> u64 {
    (scale.items(app) / 4).max(2_000)
}

/// One Fig 9 serving cell: its sweep coordinates, the (shape-independent)
/// p99 SLO it is judged against, and the full serving report.
#[derive(Clone, Debug)]
pub struct Fig9Cell {
    pub app: App,
    pub shape: FleetShape,
    /// Offered load as a fraction of the fleet's nominal capacity.
    pub load: f64,
    pub slo_p99_s: f64,
    pub report: ServeReport,
}

impl Fig9Cell {
    /// Delegates to [`ServeReport::meets_slo`] (`slo_p99_s` mirrors the
    /// report's), inheriting its served-nothing guard: an all-shed cell
    /// must never read as sustainable off its empty percentile set.
    pub fn meets_slo(&self) -> bool {
        self.report.meets_slo()
    }
}

/// Fig 9 sched template: the scale-out batch point (latency-friendly
/// small batches) with event-driven dispatch — the serving frontend's
/// latency-optimal mode; ablation A4 and the traffic tests quantify the
/// polling alternative.
fn fig9_sched(app: App) -> SchedConfig {
    SchedConfig {
        csd_batch: scaleout_batch(app),
        batch_ratio: batch_ratio(app),
        dispatch: DispatchMode::EventDriven,
        ..SchedConfig::default()
    }
}

/// Raw Fig 9 sweep: every (app × shape × load) serving cell, in sweep
/// order, fanned out over the [`pool`]. The acceptance gates (latency
/// monotone in load; all-CSD max-sustainable ≥ 1.5× all-SSD under the
/// SLO) test against this, not the rounded table strings.
pub fn fig9_cells(scale: Scale) -> anyhow::Result<Vec<Fig9Cell>> {
    let mut specs: Vec<(App, FleetShape, f64)> = Vec::new();
    for app in App::all() {
        for shape in FleetShape::all() {
            for &load in &FIG9_LOADS {
                specs.push((app, shape, load));
            }
        }
    }
    let results = pool::map_cells(specs, move |(app, shape, load)| {
        let fcfg = FleetConfig {
            servers: FIG9_SERVERS,
            shape,
            sched: fig9_sched(app),
            ..FleetConfig::default()
        };
        let tcfg = TrafficConfig {
            load,
            requests: fig9_requests(app, scale),
            ..TrafficConfig::default()
        };
        let mut m = Metrics::new();
        let report = serve_fleet(app, &fcfg, &tcfg, &PowerModel::default(), &mut m)?;
        // The report carries the resolved per-app SLO (ISSUE-5 moved
        // resolution into the serving layer).
        let slo_p99_s = report.slo_p99_s;
        Ok(Fig9Cell { app, shape, load, slo_p99_s, report })
    });
    results.into_iter().collect()
}

/// Max sustainable throughput for one (app, shape) block: the offered
/// rate of the highest load whose p99 meets the SLO (0 when none does).
pub fn max_sustainable_rps(cells: &[&Fig9Cell]) -> f64 {
    cells
        .iter()
        .filter(|c| c.meets_slo())
        .map(|c| c.report.offered_rps)
        .fold(0.0, f64::max)
}

/// Fig 9 (ours): serving latency vs offered load — open-loop Poisson
/// traffic over a 2-server fleet in all three shapes, per-request
/// latency percentiles, and each block's max sustainable throughput
/// under the p99 SLO (the `sustained` row). This is the tail-latency
/// dimension the CSD serving literature (ZCSD; Lukken & Trivedi's
/// survey) evaluates by, applied to the paper's hardware model.
pub fn fig9_latency(scale: Scale) -> anyhow::Result<Table> {
    let mut t = Table::new(
        "Fig 9 — serving latency vs offered load (2 servers, event-driven, jsq)",
        &[
            "app",
            "shape",
            "load",
            "offered rps",
            "achieved rps",
            "p50 s",
            "p95 s",
            "p99 s",
            "p99.9 s",
            "csd share",
            "slo s",
            "slo ok",
        ],
    );
    let cells = fig9_cells(scale)?;
    let mut it = cells.iter();
    for app in App::all() {
        for shape in FleetShape::all() {
            let mut block: Vec<&Fig9Cell> = Vec::with_capacity(FIG9_LOADS.len());
            for &load in &FIG9_LOADS {
                // solana-lint: allow(no-unwrap, reason = "sweep-cell pairing invariant: the assert_eq on the next lines pins producer and consumer to the same statically-built spec list")
                let c = it.next().expect("one cell per sweep point");
                assert_eq!(
                    (c.app, c.shape, c.load),
                    (app, shape, load),
                    "sweep order drifted"
                );
                let r = &c.report;
                t.row(vec![
                    app.name().to_string(),
                    shape.name().to_string(),
                    format!("{load:.1}"),
                    format!("{:.1}", r.offered_rps),
                    format!("{:.1}", r.achieved_rps),
                    format!("{:.4}", r.latency.p50),
                    format!("{:.4}", r.latency.p95),
                    format!("{:.4}", r.latency.p99),
                    format!("{:.4}", r.latency.p999),
                    format!("{:.2}", r.csd_share()),
                    format!("{:.4}", c.slo_p99_s),
                    if c.meets_slo() { "yes".to_string() } else { "no".to_string() },
                ]);
                block.push(c);
            }
            // Block summary: the max sustainable throughput under the
            // SLO, in the `offered rps` column (it is an offered rate).
            let sustained = max_sustainable_rps(&block);
            t.row(vec![
                app.name().to_string(),
                shape.name().to_string(),
                "sust".to_string(),
                format!("{sustained:.1}"),
                "-".to_string(),
                "-".to_string(),
                "-".to_string(),
                "-".to_string(),
                "-".to_string(),
                "-".to_string(),
                format!("{:.4}", block[0].slo_p99_s),
                "-".to_string(),
            ]);
        }
    }
    Ok(t)
}

/// Fleet sizes the Fig 10 autoscaling search may use (1..=8 servers,
/// searched in ascending order with early exit at the first fit).
pub const FIG10_MAX_SERVERS: usize = 8;

/// Offered-load sweep for Fig 10, in units of **one all-SSD server's
/// nominal service rate** (the host-only rate — the natural "how many
/// plain storage servers is this load worth?" yardstick). The sweep
/// spans well below one SSD server to well past two, so the min-server
/// curves for the three shapes separate.
pub const FIG10_LOADS: [f64; 4] = [0.6, 1.2, 1.8, 2.4];

/// Requests per Fig 10 serving cell: enough that the **arrival window
/// spans ≥ 6 p99-SLOs**. A sustained overload needs `slo/(r−1)` seconds
/// (overload ratio `r = offered/capacity`) to fill the admission bound
/// and blow the SLO; a window shorter than the SLO makes every fleet
/// size look compliant, no matter how overloaded (acute for sentiment,
/// whose ~10⁴ rps rates make a fixed request count a sub-second window
/// against a multi-second SLO). Six SLOs resolve any `r ≳ 1.17`;
/// verdicts for marginal overloads inside that band truncate toward
/// "meets", which biases all shapes equally and never flips the
/// CSD-vs-SSD ordering the gate pins (the shapes' per-server capacities
/// sit ≥ 2.3× apart). A floor keeps tail resolution at tiny scales, and
/// the scale-linked term sharpens the tail at larger `--scale` like
/// every other figure.
pub fn fig10_requests(app: App, scale: Scale, offered_rps: f64, slo_p99_s: f64) -> u64 {
    let window = (offered_rps * 6.0 * slo_p99_s).ceil() as u64;
    window.max(scale.items(app) / 8).max(1_200)
}

/// SLO-compliance criterion for one Fig 10 operating point: the
/// accepted-request p99 meets the SLO **and** goodput is at least 99%
/// of offered (≤ 1% shed). Both halves matter: admission alone could
/// keep p99 bounded at any fleet size by shedding the overload, so a
/// "meets the SLO" verdict must also require that almost nothing was
/// thrown away.
pub fn fig10_meets(report: &ServeReport) -> bool {
    report.meets_slo() && report.shed * 100 <= report.requests
}

/// One Fig 10 sweep point: its coordinates, the autoscaling verdict,
/// and the serving report at the chosen operating point.
#[derive(Clone, Debug)]
pub struct Fig10Cell {
    pub app: App,
    pub shape: FleetShape,
    /// Offered load in all-SSD-server units (see [`FIG10_LOADS`]).
    pub load_units: f64,
    /// Offered rate, requests/s.
    pub offered_rps: f64,
    pub slo_p99_s: f64,
    /// Minimum servers meeting [`fig10_meets`]; `None` when even
    /// [`FIG10_MAX_SERVERS`] fails.
    pub servers: Option<usize>,
    /// Report at the chosen operating point (the min-server fleet), or
    /// at [`FIG10_MAX_SERVERS`] when nothing fit.
    pub report: ServeReport,
}

/// Raw Fig 10 sweep: every (app × shape × load) autoscaling search, in
/// sweep order, fanned out over the [`pool`] (the per-cell search over
/// fleet sizes runs sequentially inside its cell so it can stop at the
/// first fit). Serving runs use the control plane as deployed:
/// admission on, least-work balancing, the Fig 9 serving template.
pub fn fig10_cells(scale: Scale) -> anyhow::Result<Vec<Fig10Cell>> {
    let mut specs: Vec<(App, FleetShape, f64)> = Vec::new();
    for app in App::all() {
        for shape in FleetShape::all() {
            for &load in &FIG10_LOADS {
                specs.push((app, shape, load));
            }
        }
    }
    let results = pool::map_cells(specs, move |(app, shape, load)| {
        let model = AppModel::for_app(app, 1);
        // One all-SSD server's nominal rate: the load unit.
        let offered = load * model.host_rate();
        let sched = fig9_sched(app);
        let slo = default_slo_p99(&model, sched.csd_batch);
        let requests = fig10_requests(app, scale, offered, slo);
        let mut chosen: Option<(usize, ServeReport)> = None;
        let mut fallback: Option<ServeReport> = None;
        for servers in 1..=FIG10_MAX_SERVERS {
            let fcfg = FleetConfig {
                servers,
                shape,
                sched: sched.clone(),
                ..FleetConfig::default()
            };
            let tcfg = TrafficConfig {
                rate_rps: Some(offered),
                requests,
                admission: true,
                policy: LbPolicy::LeastWork,
                ..TrafficConfig::default()
            };
            let mut m = Metrics::new();
            let report = serve_fleet(app, &fcfg, &tcfg, &PowerModel::default(), &mut m)?;
            if fig10_meets(&report) {
                chosen = Some((servers, report));
                break;
            }
            fallback = Some(report);
        }
        let (servers, report) = match chosen {
            Some((n, r)) => (Some(n), r),
            // solana-lint: allow(no-unwrap, reason = "SERVER_CANDIDATES is a non-empty constant, so the search loop always records a fallback before reaching here")
            None => (None, fallback.expect("at least one fleet size attempted")),
        };
        Ok(Fig10Cell {
            app,
            shape,
            load_units: load,
            offered_rps: offered,
            slo_p99_s: report.slo_p99_s,
            servers,
            report,
        })
    });
    results.into_iter().collect()
}

/// Fig 10 (ours): the autoscaling study — minimum servers each fleet
/// shape needs to meet the p99 SLO as offered load grows, with goodput,
/// shed fraction and per-request energy at the chosen operating point.
/// This is the capacity-planning view of the paper's claim: if an
/// all-CSD fleet meets the same SLO at the same load with fewer
/// servers than the all-SSD baseline, in-storage processing buys
/// datacenter capacity, not just single-box speedups. The acceptance
/// gate pins exactly that, for every app.
pub fn fig10_autoscale(scale: Scale) -> anyhow::Result<Table> {
    Ok(fig10_table_from(&fig10_cells(scale)?))
}

/// Render the Fig 10 table from precomputed cells — split from
/// [`fig10_autoscale`] so callers that already hold the cells (the gate
/// test) don't pay for a second full sweep.
pub fn fig10_table_from(cells: &[Fig10Cell]) -> Table {
    let mut t = Table::new(
        "Fig 10 — autoscaling: min servers meeting the p99 SLO vs offered load \
         (admission on, least-work)",
        &[
            "app",
            "shape",
            "load xssd",
            "offered rps",
            "servers",
            "p99 s",
            "slo s",
            "goodput rps",
            "shed %",
            "energy/req J",
        ],
    );
    let mut it = cells.iter();
    for app in App::all() {
        for shape in FleetShape::all() {
            for &load in &FIG10_LOADS {
                // solana-lint: allow(no-unwrap, reason = "sweep-cell pairing invariant: the assert_eq on the next lines pins producer and consumer to the same statically-built spec list")
                let c = it.next().expect("one cell per sweep point");
                assert_eq!(
                    (c.app, c.shape, c.load_units),
                    (app, shape, load),
                    "sweep order drifted"
                );
                let r = &c.report;
                t.row(vec![
                    app.name().to_string(),
                    shape.name().to_string(),
                    format!("{load:.1}"),
                    format!("{:.1}", c.offered_rps),
                    c.servers.map(|n| n.to_string()).unwrap_or_else(|| "-".to_string()),
                    format!("{:.4}", r.latency.p99),
                    format!("{:.4}", c.slo_p99_s),
                    format!("{:.1}", r.achieved_rps),
                    format!("{:.2}", r.shed_fraction() * 100.0),
                    format!("{:.4}", r.energy_per_req_j),
                ]);
            }
        }
    }
    t
}

/// Fleet size for the Fig 11 availability cells. Four servers is the
/// smallest fleet where one crash removes a quarter of capacity — large
/// enough that the survivors can absorb a failover at [`FIG11_LOAD`],
/// small enough that an unhandled crash is catastrophic for the gate.
pub const FIG11_SERVERS: usize = 4;

/// Offered load for every Fig 11 cell, as a fraction of nominal fleet
/// capacity. 0.6 leaves the three surviving servers at ~0.8 effective
/// load after a crash, so availability under failover measures the
/// resilience machinery, not raw capacity headroom.
pub const FIG11_LOAD: f64 = 0.6;

/// The app Fig 11 studies. Speech-to-text sits between sentiment's
/// firehose and the recommender's trickle: rates high enough to resolve
/// the 99.9th percentile at golden scale, per-request SLOs long enough
/// that one deadline-aware retry (timeout at half the SLO) can still
/// land inside the SLO.
pub const FIG11_APP: App = App::SpeechToText;

/// Fleet shapes Fig 11 sweeps: the paper's all-CSD build against the
/// plain-SSD baseline. (Mixed adds nothing to the availability story —
/// faults are injected per drive/server/link, not per medium.)
pub const FIG11_SHAPES: [FleetShape; 2] = [FleetShape::AllCsd, FleetShape::AllSsd];

/// Fault scenarios swept by Fig 11, from a perfectly healthy fleet to a
/// permanent single-server crash.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultScenario {
    /// No fault plan at all (`faults: None`): the clean baseline that
    /// every resilience policy must leave bit-identical
    /// (`tests/chaos.rs` pins the stronger quiet-plan property).
    Healthy,
    /// Light drive-level trouble: 2% lost acks + 2% transient stalls.
    DriveLight,
    /// Heavy drive-level trouble: 10% lost acks + 10% transient stalls.
    DriveHeavy,
    /// Server 0 crashes permanently a quarter of the way into the
    /// arrival window — the single-failure case the acceptance gate
    /// pins.
    ServerCrash,
}

impl FaultScenario {
    pub fn all() -> [FaultScenario; 4] {
        [
            FaultScenario::Healthy,
            FaultScenario::DriveLight,
            FaultScenario::DriveHeavy,
            FaultScenario::ServerCrash,
        ]
    }

    pub fn name(&self) -> &'static str {
        match self {
            FaultScenario::Healthy => "healthy",
            FaultScenario::DriveLight => "drive-2%",
            FaultScenario::DriveHeavy => "drive-10%",
            FaultScenario::ServerCrash => "crash",
        }
    }

    /// The fault plan for this scenario. Stalls park a drive for ~a
    /// third of the SLO: long enough to hurt the tail, short enough
    /// that a stalled ack usually still beats the retry timeout — the
    /// regime where hedging (not just retrying) earns its keep.
    pub fn faults(&self, slo_p99_s: f64) -> Option<FaultsConfig> {
        let drive = |rate: f64| FaultsConfig {
            ack_loss: rate,
            stall: rate,
            stall_s: 0.3 * slo_p99_s,
            ..FaultsConfig::default()
        };
        match self {
            FaultScenario::Healthy => None,
            FaultScenario::DriveLight => Some(drive(0.02)),
            FaultScenario::DriveHeavy => Some(drive(0.10)),
            FaultScenario::ServerCrash => Some(FaultsConfig {
                server_crash_at: Some(0.25),
                crash_server: 0,
                ..FaultsConfig::default()
            }),
        }
    }
}

/// Front-door resilience policies swept by Fig 11, in increasing order
/// of machinery. Each maps onto the `[traffic]`/`[fleet]` knobs the
/// CLI exposes (`--retries`, `--hedge`, `--replicas`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ResiliencePolicy {
    /// Fire-and-forget: no timeouts, no retries, no replicas. What the
    /// serving plane did before the failure plane existed.
    Off,
    /// Per-request timeout with up to 3 capped-exponential-backoff
    /// retries.
    Retry,
    /// Retries plus one hedged duplicate at 75% of the timeout
    /// (first response wins).
    RetryHedge,
    /// Retries + hedging + one shard replica, so a dead server's
    /// requests have somewhere to fail over to.
    RetryHedgeReplica,
}

impl ResiliencePolicy {
    pub fn all() -> [ResiliencePolicy; 4] {
        [
            ResiliencePolicy::Off,
            ResiliencePolicy::Retry,
            ResiliencePolicy::RetryHedge,
            ResiliencePolicy::RetryHedgeReplica,
        ]
    }

    pub fn name(&self) -> &'static str {
        match self {
            ResiliencePolicy::Off => "off",
            ResiliencePolicy::Retry => "retry",
            ResiliencePolicy::RetryHedge => "retry+hedge",
            ResiliencePolicy::RetryHedgeReplica => "retry+hedge+replica",
        }
    }

    pub fn retries(&self) -> u32 {
        match self {
            ResiliencePolicy::Off => 0,
            _ => 3,
        }
    }

    pub fn hedge(&self) -> bool {
        matches!(self, ResiliencePolicy::RetryHedge | ResiliencePolicy::RetryHedgeReplica)
    }

    pub fn replicas(&self) -> usize {
        match self {
            ResiliencePolicy::RetryHedgeReplica => 1,
            _ => 0,
        }
    }
}

/// One Fig 11 availability cell: its sweep coordinates and the full
/// serving report (availability, goodput, tail latencies, fault/retry
/// counters).
#[derive(Clone, Debug)]
pub struct Fig11Cell {
    pub scenario: FaultScenario,
    pub policy: ResiliencePolicy,
    pub shape: FleetShape,
    pub slo_p99_s: f64,
    pub report: ServeReport,
}

/// Raw Fig 11 sweep: every (scenario × policy × shape) availability
/// cell on a 4-server round-robin fleet at 0.6 load, in sweep order,
/// fanned out over the [`pool`]. Round-robin (not least-work) is
/// deliberate: it keeps routing to a crashed server until the dead-peer
/// belief trips, so the sweep isolates what the *resilience* machinery
/// recovers rather than letting queue-depth routing hide the failure.
/// The retry timeout is pinned to half the p99 SLO — deadline-aware in
/// the sense that a timed-out first attempt plus one retry can still
/// complete inside the SLO.
pub fn fig11_cells(scale: Scale) -> anyhow::Result<Vec<Fig11Cell>> {
    let mut specs: Vec<(FaultScenario, ResiliencePolicy, FleetShape)> = Vec::new();
    for scenario in FaultScenario::all() {
        for policy in ResiliencePolicy::all() {
            for shape in FIG11_SHAPES {
                specs.push((scenario, policy, shape));
            }
        }
    }
    let results = pool::map_cells(specs, move |(scenario, policy, shape)| {
        let app = FIG11_APP;
        let sched = fig9_sched(app);
        let slo = default_slo_p99(&AppModel::for_app(app, 1), sched.csd_batch);
        let fcfg = FleetConfig {
            servers: FIG11_SERVERS,
            shape,
            sched,
            replicas: policy.replicas(),
            ..FleetConfig::default()
        };
        let tcfg = TrafficConfig {
            load: FIG11_LOAD,
            requests: fig9_requests(app, scale),
            policy: LbPolicy::RoundRobin,
            retries: policy.retries(),
            hedge: policy.hedge(),
            retry_timeout_s: Some(0.5 * slo),
            faults: scenario.faults(slo),
            ..TrafficConfig::default()
        };
        let mut m = Metrics::new();
        let report = serve_fleet(app, &fcfg, &tcfg, &PowerModel::default(), &mut m)?;
        let slo_p99_s = report.slo_p99_s;
        Ok(Fig11Cell { scenario, policy, shape, slo_p99_s, report })
    });
    results.into_iter().collect()
}

/// Fig 11 (ours): the availability study — what fraction of offered
/// requests complete within the p99 SLO as deterministic faults (lost
/// acks, drive stalls, a permanent server crash) meet increasingly
/// capable front-door resilience (timeouts+retries, hedging, shard
/// failover), for the all-CSD build and the all-SSD baseline. The
/// acceptance gate pins the headline: with retry+hedge+replica, a
/// 4-server fleet rides out a single-server crash at 0.6 load with
/// ≥ 99% availability, while the fire-and-forget baseline provably
/// cannot.
pub fn fig11_availability(scale: Scale) -> anyhow::Result<Table> {
    Ok(fig11_table_from(&fig11_cells(scale)?))
}

/// Render the Fig 11 table from precomputed cells — split from
/// [`fig11_availability`] so callers that already hold the cells (the
/// gate test) don't pay for a second full sweep.
pub fn fig11_table_from(cells: &[Fig11Cell]) -> Table {
    let mut t = Table::new(
        "Fig 11 — availability under faults: scenario × resilience policy \
         (4 servers, round-robin, load 0.6)",
        &[
            "scenario",
            "policy",
            "shape",
            "avail %",
            "goodput rps",
            "p99 s",
            "p99.9 s",
            "slo s",
            "failed",
            "retried",
            "hedged",
            "energy/req J",
        ],
    );
    let mut it = cells.iter();
    for scenario in FaultScenario::all() {
        for policy in ResiliencePolicy::all() {
            for shape in FIG11_SHAPES {
                // solana-lint: allow(no-unwrap, reason = "sweep-cell pairing invariant: the assert_eq on the next lines pins producer and consumer to the same statically-built spec list")
                let c = it.next().expect("one cell per sweep point");
                assert_eq!(
                    (c.scenario, c.policy, c.shape),
                    (scenario, policy, shape),
                    "sweep order drifted"
                );
                let r = &c.report;
                t.row(vec![
                    scenario.name().to_string(),
                    policy.name().to_string(),
                    shape.name().to_string(),
                    format!("{:.2}", r.availability * 100.0),
                    format!("{:.1}", r.achieved_rps),
                    format!("{:.4}", r.latency.p99),
                    format!("{:.4}", r.latency.p999),
                    format!("{:.4}", c.slo_p99_s),
                    r.failed.to_string(),
                    r.retried.to_string(),
                    r.hedged.to_string(),
                    format!("{:.4}", r.energy_per_req_j),
                ]);
            }
        }
    }
    t
}

/// Fleet size for the Fig 13 write-interference cells.
pub const FIG13_SERVERS: usize = 2;

/// Offered query load for every Fig 13 cell, as a fraction of the
/// shape's nominal capacity — below the knee, so tail inflation is
/// attributable to flash-level interference, not queueing collapse.
pub const FIG13_LOAD: f64 = 0.6;

/// The app Fig 13 studies. Sentiment has the smallest items (140 B) and
/// the highest request rates, so its tail percentiles resolve at golden
/// scale and its serving corpus fits a deliberately small flash
/// geometry ([`fig13_flash`]) where GC is reachable in a single run.
pub const FIG13_APP: App = App::Sentiment;

/// Drive bays per Fig 13 server — small, so the per-die write pressure
/// from one ingest stream is concentrated enough to cycle GC.
pub const FIG13_DRIVES: usize = 4;

/// CSD batch size for the Fig 13 serving cells. Much smaller than even
/// the scale-out point: at serving-scale batches the flash service time
/// is a visible share of per-request latency, which is exactly the
/// share GC steals. Big batches would hide the interference behind
/// compute.
pub const FIG13_BATCH: u64 = 50;

/// Fleet shapes Fig 13 sweeps: the paper's all-CSD build against the
/// plain-SSD baseline. (Mixed adds nothing: GC is injected per drive,
/// and the two pure shapes bound its per-request impact.)
pub const FIG13_SHAPES: [FleetShape; 2] = [FleetShape::AllCsd, FleetShape::AllSsd];

/// Ingest intensities swept by Fig 13, as fractions of the server's
/// aggregate flash *program* bandwidth (pages/s over all dies). Rates
/// are anchored to the device write path — not the query rate — so the
/// all-CSD and all-SSD shapes face the *same absolute* write + GC
/// pressure and differ only in how their query path absorbs it. 0 is
/// the exact read-only serving path (no RNG drawn, bit-identical to
/// pre-ingest builds).
pub const FIG13_INGEST_UTILS: [f64; 3] = [0.0, 0.2, 0.5];

/// Flash-management modes swept by Fig 13, mapping onto the `[flash]`
/// TOML section.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GcMode {
    /// Plain FTL: garbage collection runs on the write path only, when
    /// a die's free pool falls below the low-water mark — every
    /// relocation and erase lands in front of foreground traffic.
    Foreground,
    /// Plus opportunistic relocation on idle dies ahead of the
    /// low-water mark (`[flash] background_gc`).
    Background,
    /// Zoned namespaces (`[flash] zns`, after ZCSD, arXiv 2112.00142):
    /// append-only zones, host-visible resets, no device GC and WAF
    /// pinned at 1.0 by construction.
    Zns,
}

impl GcMode {
    pub fn all() -> [GcMode; 3] {
        [GcMode::Foreground, GcMode::Background, GcMode::Zns]
    }

    pub fn name(&self) -> &'static str {
        match self {
            GcMode::Foreground => "fg-gc",
            GcMode::Background => "bg-gc",
            GcMode::Zns => "zns",
        }
    }

    /// The Fig 13 flash geometry with this mode's flags applied.
    pub fn flash(&self) -> FlashConfig {
        let mut f = fig13_flash();
        match self {
            GcMode::Foreground => {}
            GcMode::Background => f.background_gc = true,
            GcMode::Zns => f.zns = true,
        }
        f
    }
}

/// Fig 13 flash geometry: 2 channels × 2 dies × 5 blocks × 8 pages ×
/// 4 KiB = 160 pages (640 KiB) per drive. Sized against the serving
/// corpus, which is fixed by the batch template (2 × host-batch ×
/// 140 B ≈ 89 pages per drive): ~56% utilization, ~2.8 of 5 blocks
/// valid per die after the fill, free pools right at the GC low-water
/// mark. A handful of update writes per die starts the reclaim cycle;
/// the default 12-TB geometry would need billions. Timings (tR, tPROG,
/// tBERS, channel bandwidth) stay at the datasheet defaults — only the
/// geometry shrinks.
pub fn fig13_flash() -> FlashConfig {
    FlashConfig {
        channels: 2,
        dies_per_channel: 2,
        blocks_per_die: 5,
        pages_per_block: 8,
        page_bytes: 4096,
        ..FlashConfig::default()
    }
}

/// Resolve an ingest utilization ([`FIG13_INGEST_UTILS`]) to an
/// absolute per-server write rate (item-sized writes/s): `util ×
/// dies-per-server / tPROG`, the rate at which the server's dies would
/// be `util`-busy programming pages before any GC tax.
pub fn fig13_ingest_rate(util: f64) -> f64 {
    let flash = fig13_flash();
    let dies = (FIG13_DRIVES * flash.dies()) as f64;
    util * dies / flash.program_secs
}

/// Requests per Fig 13 serving cell: an eighth of the scaled corpus,
/// floored so the 99.9th percentile keeps ≥ 4 samples even at smoke
/// scales.
pub fn fig13_requests(scale: Scale) -> u64 {
    (scale.items(FIG13_APP) / 8).max(4_000)
}

/// Per-server scheduler template for one Fig 13 cell. Built per
/// (shape, mode) — not once — because the two shapes need different
/// compute paths (`all-csd` serves purely in storage; `all-ssd` is the
/// host-compute baseline, and the fleet layer zeroes its ISPs) and each
/// GC mode needs its own `[flash]` flags.
fn fig13_sched(shape: FleetShape, mode: GcMode) -> SchedConfig {
    SchedConfig {
        csd_batch: FIG13_BATCH,
        batch_ratio: batch_ratio(FIG13_APP),
        drives: FIG13_DRIVES,
        isp_drives: FIG13_DRIVES,
        use_host: shape == FleetShape::AllSsd,
        dispatch: DispatchMode::EventDriven,
        csd: CsdConfig { flash: mode.flash(), ..CsdConfig::default() },
        ..SchedConfig::default()
    }
}

/// One Fig 13 cell: its sweep coordinates, the resolved absolute ingest
/// rate, and the full serving report (tail latencies, WAF, GC counters,
/// admission accounting).
#[derive(Clone, Debug)]
pub struct Fig13Cell {
    pub shape: FleetShape,
    pub mode: GcMode,
    /// Ingest intensity as a fraction of flash program bandwidth
    /// ([`FIG13_INGEST_UTILS`]).
    pub ingest_util: f64,
    /// Resolved per-server ingest rate, writes/s.
    pub ingest_rate_rps: f64,
    pub report: ServeReport,
}

/// Raw Fig 13 sweep: every (shape × GC mode × ingest intensity) serving
/// cell, in sweep order, fanned out over the [`pool`]. Admission is on
/// and the balancer is least-work — the control plane as deployed — so
/// the sweep also exercises exact admission accounting under GC stalls.
/// The acceptance gates test against these raw cells, not the rounded
/// table strings.
pub fn fig13_cells(scale: Scale) -> anyhow::Result<Vec<Fig13Cell>> {
    let mut specs: Vec<(FleetShape, GcMode, f64)> = Vec::new();
    for shape in FIG13_SHAPES {
        for mode in GcMode::all() {
            for &util in &FIG13_INGEST_UTILS {
                specs.push((shape, mode, util));
            }
        }
    }
    let results = pool::map_cells(specs, move |(shape, mode, util)| {
        let fcfg = FleetConfig {
            servers: FIG13_SERVERS,
            shape,
            sched: fig13_sched(shape, mode),
            ..FleetConfig::default()
        };
        let model = AppModel::for_app(FIG13_APP, 1);
        // Each shape serves at the same *relative* query load; the
        // ingest rate is absolute (write-path-anchored), so the flash
        // sees identical write pressure under both shapes.
        let offered = FIG13_LOAD * fleet_nominal_rate(&model, &fcfg.server_specs());
        let ingest_rate_rps = fig13_ingest_rate(util);
        let tcfg = TrafficConfig {
            rate_rps: Some(offered),
            requests: fig13_requests(scale),
            admission: true,
            policy: LbPolicy::LeastWork,
            ingest_rate: ingest_rate_rps,
            ..TrafficConfig::default()
        };
        let mut m = Metrics::new();
        let report = serve_fleet(FIG13_APP, &fcfg, &tcfg, &PowerModel::default(), &mut m)?;
        Ok(Fig13Cell { shape, mode, ingest_util: util, ingest_rate_rps, report })
    });
    results.into_iter().collect()
}

/// Fig 13 (ours): the write + GC interference study — query tail
/// latency (p50/p99/p99.9), write amplification and GC activity as a
/// background ingest/update stream runs the full device write path
/// during serving, for {all-CSD, all-SSD} × {foreground GC, background
/// GC, ZNS}. This is the flash-realism dimension the CSD literature
/// (ZCSD; MQSim's GC studies) evaluates by: a drive that computes where
/// it stores still garbage-collects where it stores, and the acceptance
/// gate pins that the all-SSD baseline's tail inflates measurably more
/// than the all-CSD build's under identical write pressure.
pub fn fig13_gc(scale: Scale) -> anyhow::Result<Table> {
    Ok(fig13_table_from(&fig13_cells(scale)?))
}

/// Render the Fig 13 table from precomputed cells — split from
/// [`fig13_gc`] so callers that already hold the cells (the gate test)
/// don't pay for a second full sweep.
pub fn fig13_table_from(cells: &[Fig13Cell]) -> Table {
    let mut t = Table::new(
        "Fig 13 — write + GC interference: tail latency and WAF under ingest \
         (2 servers, admission on, least-work)",
        &[
            "shape",
            "gc",
            "ingest util",
            "offered rps",
            "ingest writes",
            "p50 s",
            "p99 s",
            "p99.9 s",
            "waf",
            "gc runs",
            "wear",
            "shed %",
        ],
    );
    let mut it = cells.iter();
    for shape in FIG13_SHAPES {
        for mode in GcMode::all() {
            for &util in &FIG13_INGEST_UTILS {
                // solana-lint: allow(no-unwrap, reason = "sweep-cell pairing invariant: the assert_eq on the next lines pins producer and consumer to the same statically-built spec list")
                let c = it.next().expect("one cell per sweep point");
                assert_eq!(
                    (c.shape, c.mode, c.ingest_util),
                    (shape, mode, util),
                    "sweep order drifted"
                );
                let r = &c.report;
                t.row(vec![
                    shape.name().to_string(),
                    mode.name().to_string(),
                    format!("{util:.1}"),
                    format!("{:.1}", r.offered_rps),
                    r.ingest_writes.to_string(),
                    format!("{:.4}", r.latency.p50),
                    format!("{:.4}", r.latency.p99),
                    format!("{:.4}", r.latency.p999),
                    format!("{:.3}", r.waf),
                    r.gc_runs.to_string(),
                    r.wear_spread.to_string(),
                    format!("{:.2}", r.shed_fraction() * 100.0),
                ]);
            }
        }
    }
    t
}

// ---------------------------------------------------------------------
// Fig 12 (ours): the elastic-fleet study (ISSUE-10)
// ---------------------------------------------------------------------

/// The app Fig 12 studies. Speech-to-text's multi-second SLO gives the
/// autoscaler a realistic reaction budget: an eval interval that is a
/// small fraction of the SLO still spans many requests, so the observed
/// window statistics the policies act on are meaningful.
pub const FIG12_APP: App = App::SpeechToText;

/// Fleet ceiling for Fig 12 — both the autoscaler's `max_servers` and
/// the static search bound, matching [`FIG10_MAX_SERVERS`] so the
/// elastic and static provisioners pick from the same hardware pool.
pub const FIG12_MAX_SERVERS: usize = 8;

/// Load scenarios Fig 12 sweeps, as piecewise-constant rate profiles.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Fig12Scenario {
    /// Diurnal-style staircase: long quiet morning, then successive
    /// steps up to a 3.1× peak that one server cannot hope to carry.
    Ramp,
    /// Flash crowd: steady half-load with a short 3.2× spike in the
    /// middle — the case where static provisioning must pay for the
    /// spike all day.
    FlashCrowd,
}

impl Fig12Scenario {
    pub fn all() -> [Fig12Scenario; 2] {
        [Fig12Scenario::Ramp, Fig12Scenario::FlashCrowd]
    }

    pub fn name(&self) -> &'static str {
        match self {
            Fig12Scenario::Ramp => "ramp",
            Fig12Scenario::FlashCrowd => "flash-crowd",
        }
    }

    /// The profile as (fraction of the arrival window, rate multiplier
    /// in single-CSD-server units) segments; fractions sum to 1 and the
    /// last segment extends until the request budget is spent.
    pub fn segments(&self) -> &'static [(f64, f64)] {
        match self {
            Fig12Scenario::Ramp => &[(0.4, 0.3), (0.2, 1.0), (0.1, 1.8), (0.3, 3.1)],
            Fig12Scenario::FlashCrowd => &[(0.45, 0.5), (0.1, 3.2), (0.45, 0.5)],
        }
    }
}

/// Provisioning modes Fig 12 compares: the two autoscaler policies (the
/// ablation) against the fig10-style best static fleet.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Fig12Mode {
    Reactive,
    Predictive,
    Static,
}

impl Fig12Mode {
    pub fn all() -> [Fig12Mode; 3] {
        [Fig12Mode::Reactive, Fig12Mode::Predictive, Fig12Mode::Static]
    }

    pub fn name(&self) -> &'static str {
        match self {
            Fig12Mode::Reactive => "reactive",
            Fig12Mode::Predictive => "predictive",
            Fig12Mode::Static => "static",
        }
    }
}

/// Fleet template for one Fig 12 serving run: all-CSD (the paper's
/// build — fig10 already showed it needs the fewest servers at any
/// load, so it is the shape whose provisioning is worth optimizing
/// further) on the Fig 9 serving scheduler.
pub fn fig12_fleet(servers: usize) -> FleetConfig {
    FleetConfig {
        servers,
        shape: FleetShape::AllCsd,
        sched: fig9_sched(FIG12_APP),
        ..FleetConfig::default()
    }
}

/// One CSD server's nominal service rate — the unit the scenario
/// multipliers are expressed in.
pub fn fig12_base_rps() -> f64 {
    let model = AppModel::for_app(FIG12_APP, 1);
    fleet_nominal_rate(&model, &fig12_fleet(1).server_specs())
}

/// Arrival-window length (s) for one Fig 12 run: a multiple of the p99
/// SLO so the time series spans many autoscaler reaction times, growing
/// with `--scale` like every other figure's resolution knob.
pub fn fig12_window_s(scale: Scale) -> f64 {
    let model = AppModel::for_app(FIG12_APP, 1);
    let slo = default_slo_p99(&model, fig9_sched(FIG12_APP).csd_batch);
    slo * (12.0 + 20.0 * scale.0.min(1.0))
}

/// Request budget for one Fig 12 scenario: the scenario's mean offered
/// rate times the arrival window, floored for tail resolution. Sizing
/// by the *mean* (not the peak) keeps the segment fractions honest —
/// the budget runs out right as the profile's window ends.
pub fn fig12_requests(scale: Scale, scenario: Fig12Scenario) -> u64 {
    let mean: f64 = scenario.segments().iter().map(|&(frac, mult)| frac * mult).sum();
    let window = fig12_window_s(scale) * fig12_base_rps() * mean;
    (window.ceil() as u64).max(1_000)
}

/// SLO-compliance criterion for one Fig 12 run: accepted-request p99
/// meets the SLO and ≤ 5% shed. Looser than [`fig10_meets`]'s 1% on
/// purpose: a flash crowd above the *whole pool's* capacity makes some
/// shedding unavoidable for every provisioner, and the interesting
/// question is who meets the tail SLO at bounded goodput loss for the
/// fewest server-seconds.
pub fn fig12_meets(report: &ServeReport) -> bool {
    report.meets_slo() && report.shed * 20 <= report.requests
}

/// Traffic plan for one Fig 12 run: the scenario's rate profile over
/// the scaled window, admission on, least-work balancing, a mild Zipf
/// shard skew (so the rebalancer has real hot spots to chase), and the
/// mode's autoscaler — or none for the static baseline, which keeps the
/// static cells on the bit-identical pre-elastic path.
fn fig12_tcfg(scale: Scale, scenario: Fig12Scenario, mode: Fig12Mode) -> TrafficConfig {
    let window = fig12_window_s(scale);
    let segments: Vec<(f64, f64)> =
        scenario.segments().iter().map(|&(frac, mult)| (frac * window, mult)).collect();
    let autoscale = match mode {
        Fig12Mode::Static => None,
        Fig12Mode::Reactive | Fig12Mode::Predictive => Some(AutoscaleConfig {
            policy: if mode == Fig12Mode::Reactive {
                AutoscalePolicy::Reactive
            } else {
                AutoscalePolicy::Predictive
            },
            min_servers: 1,
            max_servers: FIG12_MAX_SERVERS,
            // ~8 evals per segment even in the short flash-crowd spike.
            check_interval_s: window / 96.0,
            estimator_window_s: window / 12.0,
            ..AutoscaleConfig::default()
        }),
    };
    TrafficConfig {
        rate_rps: Some(fig12_base_rps()),
        rate_segments: Some(segments),
        requests: fig12_requests(scale, scenario),
        admission: true,
        policy: LbPolicy::LeastWork,
        skew: 0.6,
        autoscale,
        ..TrafficConfig::default()
    }
}

/// One Fig 12 cell: its sweep coordinates, the static search verdict
/// (elastic modes: `None`), and the full serving report — including the
/// fleet time series for the elastic modes.
#[derive(Clone, Debug)]
pub struct Fig12Cell {
    pub scenario: Fig12Scenario,
    pub mode: Fig12Mode,
    /// [`Fig12Mode::Static`]: minimum fixed fleet meeting
    /// [`fig12_meets`], or `None` when even [`FIG12_MAX_SERVERS`]
    /// fails. Elastic modes: `None` (the fleet size is a time series).
    pub servers: Option<usize>,
    pub report: ServeReport,
}

/// Raw Fig 12 sweep: every (scenario × mode) cell, in sweep order,
/// fanned out over the [`pool`]. Elastic cells start from one server
/// and let the autoscaler grow the fleet; static cells run the
/// fig10-style sequential min-server search against the *same* traffic
/// profile (stopping at the first fit). The acceptance gate tests
/// against these raw cells, not the rounded table strings.
pub fn fig12_cells(scale: Scale) -> anyhow::Result<Vec<Fig12Cell>> {
    let mut specs: Vec<(Fig12Scenario, Fig12Mode)> = Vec::new();
    for scenario in Fig12Scenario::all() {
        for mode in Fig12Mode::all() {
            specs.push((scenario, mode));
        }
    }
    let results = pool::map_cells(specs, move |(scenario, mode)| {
        let tcfg = fig12_tcfg(scale, scenario, mode);
        match mode {
            Fig12Mode::Reactive | Fig12Mode::Predictive => {
                let mut m = Metrics::new();
                let report =
                    serve_fleet(FIG12_APP, &fig12_fleet(1), &tcfg, &PowerModel::default(), &mut m)?;
                Ok(Fig12Cell { scenario, mode, servers: None, report })
            }
            Fig12Mode::Static => {
                let mut chosen: Option<(usize, ServeReport)> = None;
                let mut fallback: Option<ServeReport> = None;
                for servers in 1..=FIG12_MAX_SERVERS {
                    let mut m = Metrics::new();
                    let report = serve_fleet(
                        FIG12_APP,
                        &fig12_fleet(servers),
                        &tcfg,
                        &PowerModel::default(),
                        &mut m,
                    )?;
                    if fig12_meets(&report) {
                        chosen = Some((servers, report));
                        break;
                    }
                    fallback = Some(report);
                }
                let (servers, report) = match chosen {
                    Some((n, r)) => (Some(n), r),
                    // solana-lint: allow(no-unwrap, reason = "the 1..=FIG12_MAX_SERVERS search loop always records a fallback before reaching here")
                    None => (None, fallback.expect("at least one fleet size attempted")),
                };
                Ok(Fig12Cell { scenario, mode, servers, report })
            }
        }
    });
    results.into_iter().collect()
}

/// Fig 12 (ours): the elastic-fleet study — an autoscaler (reactive vs
/// predictive, the ablation) plus a mid-run shard rebalancer serving a
/// load ramp and a flash crowd, against the best *static* fleet chosen
/// fig10-style for the same traffic. Each elastic cell emits its fleet
/// time series (size, p99, shed, energy per observation window); the
/// acceptance gate pins the paper-extension claim: the elastic fleet
/// meets the same p99 SLO on both scenarios while paying strictly
/// fewer server-seconds than the best static fleet, even though every
/// shard migration it performs ships real bytes over the rack link.
pub fn fig12_elastic(scale: Scale) -> anyhow::Result<Table> {
    Ok(fig12_table_from(&fig12_cells(scale)?))
}

/// Render the Fig 12 table from precomputed cells — split from
/// [`fig12_elastic`] so callers that already hold the cells (the gate
/// test) don't pay for a second full sweep. Each cell contributes one
/// `run` summary row; elastic cells follow it with sampled `t+` time
/// series rows (at most 8 per cell, evenly strided).
pub fn fig12_table_from(cells: &[Fig12Cell]) -> Table {
    let mut t = Table::new(
        "Fig 12 — elastic fleet: autoscaler + shard rebalancer vs best static fleet \
         (speech, all-CSD, admission on, least-work)",
        &[
            "scenario",
            "mode",
            "row",
            "t s",
            "servers",
            "p99 s",
            "shed %",
            "served",
            "server-s",
            "energy J",
            "migr",
        ],
    );
    let mut it = cells.iter();
    for scenario in Fig12Scenario::all() {
        for mode in Fig12Mode::all() {
            // solana-lint: allow(no-unwrap, reason = "sweep-cell pairing invariant: the assert_eq on the next lines pins producer and consumer to the same statically-built spec list")
            let c = it.next().expect("one cell per sweep point");
            assert_eq!((c.scenario, c.mode), (scenario, mode), "sweep order drifted");
            let r = &c.report;
            let servers = match c.mode {
                Fig12Mode::Static => {
                    c.servers.map(|n| n.to_string()).unwrap_or_else(|| "-".to_string())
                }
                _ => format!("peak {}", r.peak_servers),
            };
            t.row(vec![
                scenario.name().to_string(),
                mode.name().to_string(),
                "run".to_string(),
                format!("{:.1}", r.duration_secs),
                servers,
                format!("{:.4}", r.latency.p99),
                format!("{:.2}", r.shed_fraction() * 100.0),
                r.served.to_string(),
                format!("{:.1}", r.server_seconds),
                format!("{:.1}", r.energy_j),
                r.migrations.to_string(),
            ]);
            let stride = r.timeline.len().div_ceil(8).max(1);
            for sample in r.timeline.iter().step_by(stride) {
                let window_shed = if sample.arrived > 0 {
                    sample.shed as f64 * 100.0 / sample.arrived as f64
                } else {
                    0.0
                };
                t.row(vec![
                    scenario.name().to_string(),
                    mode.name().to_string(),
                    "t+".to_string(),
                    format!("{:.1}", sample.t),
                    format!("{}+{}", sample.active, sample.draining),
                    format!("{:.4}", sample.p99_s),
                    format!("{window_shed:.2}"),
                    sample.served.to_string(),
                    "-".to_string(),
                    format!("{:.1}", sample.energy_j),
                    "-".to_string(),
                ]);
            }
        }
    }
    t
}

/// Write a table to `target/bench-results/<name>.{txt,csv}` and print it.
pub fn emit(table: &Table, name: &str) -> anyhow::Result<()> {
    print!("{}", table.render());
    let dir = std::path::Path::new("target/bench-results");
    std::fs::create_dir_all(dir)?;
    std::fs::write(dir.join(format!("{name}.txt")), table.render())?;
    std::fs::write(dir.join(format!("{name}.csv")), table.to_csv())?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_ratios_are_natural() {
        assert!((batch_ratio(App::Sentiment) - 26.0).abs() < 1.0);
        assert!((batch_ratio(App::SpeechToText) - 19.0).abs() < 1.5);
    }

    #[test]
    fn fig5_speech_small_scale_shape() {
        // tiny scale: monotone in #CSDs at fixed batch
        let items = 2_620;
        let r0 = run_cell(App::SpeechToText, items, 6, 0).unwrap();
        let r18 = run_cell(App::SpeechToText, items, 6, 18).unwrap();
        let r36 = run_cell(App::SpeechToText, items, 6, 36).unwrap();
        assert!(r18.words_per_sec > r0.words_per_sec);
        assert!(r36.words_per_sec > r18.words_per_sec);
    }

    #[test]
    fn parallel_sweep_output_is_byte_identical_to_sequential() {
        // Same cells, same order, same strings — thread count must only
        // change wall-clock. (Other tests may race pool::set_threads;
        // that's fine, any pool size must produce these exact bytes.)
        let scale = Scale(0.005);
        pool::set_threads(1);
        let seq = fig5(App::Sentiment, scale).unwrap().render();
        pool::set_threads(4);
        let par = fig5(App::Sentiment, scale).unwrap().render();
        pool::set_threads(0);
        assert_eq!(seq, par);
    }

    #[test]
    fn ablate_wakeup_reports_event_savings() {
        let t = ablate_wakeup(App::Sentiment, Scale(0.005)).unwrap();
        assert_eq!(t.headers.len(), 5);
        for row in &t.rows {
            let coalesced: u64 = row[3].parse().unwrap();
            let naive: u64 = row[4].parse().unwrap();
            assert!(coalesced <= naive, "coalesced {coalesced} > naive {naive}");
        }
    }

    #[test]
    fn ablate_dispatch_event_driven_never_slower() {
        // The A4 acceptance gate: event-driven makespan ≤ polling
        // makespan at every operating point of the sweep (checked on the
        // raw reports, not the rounded table strings).
        let scale = Scale(0.005);
        for app in [App::SpeechToText, App::Sentiment] {
            let items = scale.items(app);
            for &batch in &batch_sizes(app) {
                let model = AppModel::for_app(app, items);
                let mk = |dispatch: DispatchMode| SchedConfig { dispatch, ..cfg_for(app, batch, 36) };
                let mut m = Metrics::new();
                let poll =
                    run(&model, &mk(DispatchMode::Polling), &PowerModel::default(), &mut m).unwrap();
                let event =
                    run(&model, &mk(DispatchMode::EventDriven), &PowerModel::default(), &mut m)
                        .unwrap();
                assert!(
                    event.makespan_secs <= poll.makespan_secs + 1e-9,
                    "{app:?} batch {batch}: event-driven {} > polling {}",
                    event.makespan_secs,
                    poll.makespan_secs
                );
                assert_eq!(event.host_items + event.csd_items, model.items);
            }
        }
    }

    #[test]
    fn ablate_dispatch_table_shape_and_small_batch_gap() {
        let t = ablate_dispatch(App::SpeechToText, Scale(0.005)).unwrap();
        assert_eq!(t.headers.len(), 8);
        assert_eq!(t.rows.len(), batch_sizes(App::SpeechToText).len());
        let speedups: Vec<f64> = t
            .rows
            .iter()
            .map(|r| r[3].trim_end_matches('x').parse().unwrap())
            .collect();
        for s in &speedups {
            assert!(*s >= 0.99, "event-driven slower than polling: {speedups:?}");
        }
        // The polling tax is largest where the half-period idle gap
        // dominates the per-batch service time: the smallest batch.
        assert!(
            speedups.first().unwrap() + 0.05 >= *speedups.last().unwrap(),
            "expected the largest gap at the smallest batch: {speedups:?}"
        );
    }

    #[test]
    fn fig8_scaleout_shape_and_normalization() {
        let t = fig8_scaleout(Scale(0.005)).unwrap();
        assert_eq!(t.headers.len(), 8);
        assert_eq!(t.rows.len(), 3 * 3 * SERVER_COUNTS.len(), "apps × shapes × server counts");
        // every (app, shape) block starts at its own 1-server baseline
        for block in t.rows.chunks(SERVER_COUNTS.len()) {
            assert_eq!(block[0][2], "1");
            assert_eq!(block[0][4], "1.00x");
            // 1-server fleets never touch the rack
            assert_eq!(block[0][6], "0.0");
        }
        // even at tiny scale, 8 all-CSD sentiment servers strictly beat 1
        // (the ≥3.5× 1→4 acceptance gate runs at realistic corpus sizes
        // in cluster::fleet::tests — tiny scales are granularity-bound)
        let sent_csd = t
            .rows
            .iter()
            .find(|r| r[0] == "sentiment" && r[1] == "all-csd" && r[2] == "8")
            .expect("sentiment all-csd 8-server row");
        let speedup: f64 = sent_csd[4].trim_end_matches('x').parse().unwrap();
        assert!(speedup > 1.0, "8-server sentiment speedup {speedup}");
        assert_ne!(sent_csd[6], "0.0", "an 8-server fleet aggregates over the rack");
    }

    #[test]
    fn fig9_gate_latency_monotone_and_csd_sustains_more() {
        // The ISSUE-4 acceptance gate, checked on raw reports (not the
        // rounded table strings):
        //  1. p50 and p99 are monotonically non-decreasing in offered
        //     load for every shape × app (2% tolerance absorbs batch
        //     quantization at cell boundaries; the curves must not dip);
        //  2. under the fixed per-app p99 SLO, the all-CSD fleet's max
        //     sustainable throughput beats all-SSD by ≥ 1.5× for at
        //     least one app.
        let cells = fig9_cells(Scale(0.01)).unwrap();
        let block = |app: App, shape: FleetShape| -> Vec<&Fig9Cell> {
            cells.iter().filter(|c| c.app == app && c.shape == shape).collect()
        };
        for app in App::all() {
            for shape in FleetShape::all() {
                let b = block(app, shape);
                assert_eq!(b.len(), FIG9_LOADS.len());
                for w in b.windows(2) {
                    let (lo, hi) = (&w[0].report.latency, &w[1].report.latency);
                    assert!(
                        hi.p50 >= lo.p50 * 0.98,
                        "{app:?}/{shape:?}: p50 dips with load: {} -> {}",
                        lo.p50,
                        hi.p50
                    );
                    assert!(
                        hi.p99 >= lo.p99 * 0.98,
                        "{app:?}/{shape:?}: p99 dips with load: {} -> {}",
                        lo.p99,
                        hi.p99
                    );
                }
            }
        }
        let mut any_app_clears_bar = false;
        for app in App::all() {
            let csd = max_sustainable_rps(&block(app, FleetShape::AllCsd));
            let ssd = max_sustainable_rps(&block(app, FleetShape::AllSsd));
            if csd > 0.0 && csd >= 1.5 * ssd {
                any_app_clears_bar = true;
            }
        }
        assert!(
            any_app_clears_bar,
            "no app shows >= 1.5x all-CSD max sustainable throughput over all-SSD"
        );
    }

    #[test]
    fn fig9_table_shape() {
        let t = fig9_latency(Scale(0.005)).unwrap();
        assert_eq!(t.headers.len(), 12);
        // per (app, shape) block: one row per load + the sustained row
        assert_eq!(t.rows.len(), 3 * 3 * (FIG9_LOADS.len() + 1));
        for block in t.rows.chunks(FIG9_LOADS.len() + 1) {
            let sust = block.last().unwrap();
            assert_eq!(sust[2], "sust");
            // the sustained row's latency columns stay empty — the SLO
            // lives in its own column, the sustained rate under offered
            assert_eq!(sust[7], "-", "no fake p99 in the sustained row");
            assert_ne!(sust[10], "-", "sustained row carries the SLO");
            for row in &block[..FIG9_LOADS.len()] {
                assert!(row[11] == "yes" || row[11] == "no", "slo column: {row:?}");
                assert_eq!(row[10], sust[10], "one SLO per (app, shape) block");
            }
        }
    }

    #[test]
    fn fig10_gate_csd_meets_slo_with_strictly_fewer_servers() {
        // The ISSUE-5 acceptance gate, on raw cells (not the rounded
        // table strings). For every app:
        //  1. exact admission accounting at every operating point;
        //  2. at the max offered load where the all-CSD fleet meets the
        //     p99 SLO at all, it does so with strictly fewer servers
        //     than the all-SSD baseline needs (a baseline that cannot
        //     meet the SLO within FIG10_MAX_SERVERS counts as needing
        //     more than any CSD answer).
        // The table-shape checks ride on the same cells (one sweep —
        // fig10's SLO-spanning windows make it the costliest figure).
        let cells = fig10_cells(Scale(0.01)).unwrap();
        for c in &cells {
            assert_eq!(
                c.report.served + c.report.shed,
                c.report.requests,
                "{:?}/{:?}/load {}: offered == accepted + shed",
                c.app,
                c.shape,
                c.load_units
            );
            if let Some(n) = c.servers {
                assert!((1..=FIG10_MAX_SERVERS).contains(&n));
                assert!(fig10_meets(&c.report), "chosen point must meet its own criterion");
            }
        }
        fn get(cells: &[Fig10Cell], app: App, shape: FleetShape, load: f64) -> &Fig10Cell {
            cells
                .iter()
                .find(|c| c.app == app && c.shape == shape && c.load_units == load)
                .expect("cell present")
        }
        for app in App::all() {
            let best = FIG10_LOADS
                .iter()
                .rev()
                .find(|&&l| get(&cells, app, FleetShape::AllCsd, l).servers.is_some())
                .copied()
                .unwrap_or_else(|| panic!("{app:?}: all-CSD never meets the SLO"));
            let csd = get(&cells, app, FleetShape::AllCsd, best).servers.unwrap();
            match get(&cells, app, FleetShape::AllSsd, best).servers {
                Some(ssd) => assert!(
                    csd < ssd,
                    "{app:?} @ load {best}: all-CSD needs {csd} servers, all-SSD only {ssd}"
                ),
                // SSD can't meet the SLO at all within the server
                // budget: trivially more than the CSD answer.
                None => {}
            }
        }
        // ---- table shape, from the same cells ------------------------
        let t = fig10_table_from(&cells);
        assert_eq!(t.headers.len(), 10);
        assert_eq!(t.rows.len(), 3 * 3 * FIG10_LOADS.len(), "apps × shapes × loads");
        for row in &t.rows {
            // servers is a count in 1..=8 or the "-" none marker
            if row[4] != "-" {
                let n: usize = row[4].parse().unwrap();
                assert!((1..=FIG10_MAX_SERVERS).contains(&n), "{row:?}");
            }
            let shed: f64 = row[8].parse().unwrap();
            assert!((0.0..=100.0).contains(&shed), "{row:?}");
        }
    }

    #[test]
    fn fig11_gate_failover_rides_out_a_server_crash() {
        // The ISSUE-6 acceptance gate, on raw cells (not the rounded
        // table strings):
        //  1. exact request conservation at every cell, faults or not:
        //     served + failed + shed == requests;
        //  2. under the single-server crash at 0.6 load, the full
        //     resilience stack (retry+hedge+replica) keeps the all-CSD
        //     fleet at >= 99% availability;
        //  3. the fire-and-forget baseline provably cannot: round-robin
        //     keeps feeding the dead server, so its availability lands
        //     well under 99%.
        // The table-shape checks ride on the same cells (one sweep).
        let cells = fig11_cells(Scale(0.01)).unwrap();
        for c in &cells {
            let r = &c.report;
            assert_eq!(
                r.served + r.failed + r.shed,
                r.requests,
                "{:?}/{:?}/{:?}: conservation",
                c.scenario,
                c.policy,
                c.shape
            );
            assert!(
                (0.0..=1.0).contains(&r.availability),
                "availability out of range: {}",
                r.availability
            );
            if c.policy == ResiliencePolicy::Off {
                assert_eq!(r.retried, 0, "no retries without a retry budget");
                assert_eq!(r.hedged, 0, "no hedges without hedging");
            }
            if c.scenario == FaultScenario::Healthy {
                assert_eq!(r.failed, 0, "{:?}/{:?}: failures on a healthy fleet", c.policy, c.shape);
            }
        }
        let get = |scenario: FaultScenario, policy: ResiliencePolicy, shape: FleetShape| {
            cells
                .iter()
                .find(|c| c.scenario == scenario && c.policy == policy && c.shape == shape)
                .expect("cell present")
        };
        let off = get(FaultScenario::ServerCrash, ResiliencePolicy::Off, FleetShape::AllCsd);
        let full =
            get(FaultScenario::ServerCrash, ResiliencePolicy::RetryHedgeReplica, FleetShape::AllCsd);
        assert!(
            off.report.availability < 0.99,
            "fire-and-forget should not survive a crash: availability {}",
            off.report.availability
        );
        assert!(
            off.report.failed > 0,
            "a crashed server must strand fire-and-forget requests"
        );
        assert!(
            full.report.availability >= 0.99,
            "retry+hedge+replica must ride out the crash: availability {}",
            full.report.availability
        );
        assert!(
            full.report.retried > 0,
            "riding out a crash requires actual retries"
        );
        // ---- table shape, from the same cells ------------------------
        let t = fig11_table_from(&cells);
        assert_eq!(t.headers.len(), 12);
        assert_eq!(t.rows.len(), 4 * 4 * 2, "scenarios × policies × shapes");
        for row in &t.rows {
            let avail: f64 = row[3].parse().unwrap();
            assert!((0.0..=100.0).contains(&avail), "{row:?}");
        }
    }

    #[test]
    fn fig13_gate_gc_interference_and_conservation() {
        // The ISSUE-8 acceptance gate, on raw cells (not the rounded
        // table strings):
        //  1. exact admission accounting at every operating point, GC
        //     stalls or not: offered == accepted + shed;
        //  2. the read-only cells are exactly GC-free (no writes, no GC
        //     runs, WAF pinned at 1.0) — ingest off is the pre-ISSUE-8
        //     serving path;
        //  3. ZNS never runs device GC and never amplifies writes;
        //  4. under the heaviest ingest with foreground-only GC, the
        //     all-SSD baseline's p99.9 inflates measurably over its
        //     read-only tail, and the all-CSD build's relative
        //     inflation is strictly smaller — compute-in-storage keeps
        //     more of its latency budget out of GC's way.
        // The table-shape checks ride on the same cells (one sweep).
        let cells = fig13_cells(Scale(0.01)).unwrap();
        let top = FIG13_INGEST_UTILS[FIG13_INGEST_UTILS.len() - 1];
        for c in &cells {
            let r = &c.report;
            let ctx = format!("{:?}/{:?}/util {}", c.shape, c.mode, c.ingest_util);
            assert_eq!(
                r.served + r.shed,
                r.requests,
                "{ctx}: offered == accepted + shed under GC stalls"
            );
            assert_eq!(r.failed, 0, "{ctx}: no faults in fig13");
            if c.ingest_util == 0.0 {
                assert_eq!(r.ingest_writes, 0, "{ctx}: no stream armed");
                assert_eq!(r.gc_runs, 0, "{ctx}: no writes, no GC");
                assert_eq!(r.waf, 1.0, "{ctx}: read-only serving never amplifies");
            } else {
                assert!(r.ingest_writes > 0, "{ctx}: armed stream wrote nothing");
                assert!(r.waf >= 1.0, "{ctx}: WAF below 1: {}", r.waf);
            }
            match c.mode {
                GcMode::Zns => {
                    assert_eq!(r.waf, 1.0, "{ctx}: zns never relocates");
                    assert_eq!(r.gc_runs, 0, "{ctx}: zns has no device GC");
                }
                _ => {
                    if c.ingest_util == top {
                        assert!(
                            r.gc_runs > 0,
                            "{ctx}: heavy ingest must cycle GC on this geometry"
                        );
                    }
                }
            }
        }
        let get = |shape: FleetShape, mode: GcMode, util: f64| -> &Fig13Cell {
            cells
                .iter()
                .find(|c| c.shape == shape && c.mode == mode && c.ingest_util == util)
                .expect("cell present")
        };
        let p999 = |c: &Fig13Cell| c.report.latency.p999;
        let ssd_base = p999(get(FleetShape::AllSsd, GcMode::Foreground, 0.0));
        let ssd_hot = p999(get(FleetShape::AllSsd, GcMode::Foreground, top));
        let csd_base = p999(get(FleetShape::AllCsd, GcMode::Foreground, 0.0));
        let csd_hot = p999(get(FleetShape::AllCsd, GcMode::Foreground, top));
        assert!(ssd_base > 0.0 && csd_base > 0.0, "tails must be resolved");
        let ssd_inflation = ssd_hot / ssd_base;
        let csd_inflation = csd_hot / csd_base;
        assert!(
            ssd_inflation >= 1.02,
            "GC must visibly inflate the all-SSD p99.9: {ssd_inflation:.4}x \
             ({ssd_base:.4}s -> {ssd_hot:.4}s)"
        );
        assert!(
            csd_inflation < ssd_inflation,
            "all-CSD must be measurably less GC-sensitive: csd {csd_inflation:.4}x \
             vs ssd {ssd_inflation:.4}x"
        );
        // ---- table shape, from the same cells ------------------------
        let t = fig13_table_from(&cells);
        assert_eq!(t.headers.len(), 12);
        assert_eq!(
            t.rows.len(),
            FIG13_SHAPES.len() * GcMode::all().len() * FIG13_INGEST_UTILS.len(),
            "shapes × gc modes × ingest intensities"
        );
        for row in &t.rows {
            let waf: f64 = row[8].parse().unwrap();
            assert!(waf >= 1.0, "{row:?}");
            let shed: f64 = row[11].parse().unwrap();
            assert!((0.0..=100.0).contains(&shed), "{row:?}");
        }
    }

    #[test]
    fn fig12_gate_elastic_beats_best_static_fleet() {
        // The ISSUE-10 acceptance gate, on raw cells (not the rounded
        // table strings): on both load scenarios the predictive elastic
        // fleet meets the p99 SLO at bounded shed AND pays strictly
        // fewer server-seconds than the best static fleet chosen
        // fig10-style for the same traffic — while every migration it
        // performed shipped real bytes over the rack link. The
        // table-shape checks ride on the same cells (one sweep).
        let cells = fig12_cells(Scale(0.01)).unwrap();
        assert_eq!(cells.len(), Fig12Scenario::all().len() * Fig12Mode::all().len());
        for c in &cells {
            let r = &c.report;
            let ctx = format!("{}/{}", c.scenario.name(), c.mode.name());
            assert_eq!(
                r.served + r.failed + r.shed,
                r.requests,
                "{ctx}: conservation through joins, drains and migrations"
            );
            match c.mode {
                Fig12Mode::Static => {
                    assert!(r.timeline.is_empty(), "{ctx}: static cells emit no time series");
                    assert_eq!(r.migrations, 0, "{ctx}");
                    assert_eq!(r.joins + r.drains, 0, "{ctx}");
                    assert!(
                        c.servers.is_some(),
                        "{ctx}: some fixed fleet <= {FIG12_MAX_SERVERS} must carry the profile"
                    );
                }
                _ => {
                    assert!(!r.timeline.is_empty(), "{ctx}: elastic cells emit the time series");
                    assert!(r.joins >= 1, "{ctx}: both profiles overload one server");
                    assert!(r.peak_servers > 1, "{ctx}: peak {}", r.peak_servers);
                    assert!(
                        r.server_seconds > 0.0 && r.server_seconds.is_finite(),
                        "{ctx}: server-seconds {}",
                        r.server_seconds
                    );
                }
            }
        }
        let get = |scenario: Fig12Scenario, mode: Fig12Mode| -> &Fig12Cell {
            cells
                .iter()
                .find(|c| c.scenario == scenario && c.mode == mode)
                .expect("cell present")
        };
        for scenario in Fig12Scenario::all() {
            let elastic = get(scenario, Fig12Mode::Predictive);
            let static_ = get(scenario, Fig12Mode::Static);
            assert!(
                fig12_meets(&elastic.report),
                "{}: predictive elastic must meet the SLO (p99 {:.4}s vs slo {:.4}s, \
                 shed {} of {})",
                scenario.name(),
                elastic.report.latency.p99,
                elastic.report.slo_p99_s,
                elastic.report.shed,
                elastic.report.requests
            );
            assert!(
                elastic.report.server_seconds < static_.report.server_seconds,
                "{}: elastic must pay strictly fewer server-seconds: {:.1} vs static {:.1} \
                 ({} servers)",
                scenario.name(),
                elastic.report.server_seconds,
                static_.report.server_seconds,
                static_.servers.map(|n| n.to_string()).unwrap_or_else(|| "-".into())
            );
        }
        // ---- table shape, from the same cells ------------------------
        let t = fig12_table_from(&cells);
        assert_eq!(t.headers.len(), 11);
        // One summary row per cell plus up to 8 time-series rows per
        // elastic cell; every row's shed column is a valid percentage.
        assert!(t.rows.len() >= cells.len(), "at least one row per cell");
        let summaries = t.rows.iter().filter(|r| r[2] == "run").count();
        assert_eq!(summaries, cells.len(), "exactly one summary row per cell");
        for row in &t.rows {
            let shed: f64 = row[6].parse().unwrap();
            assert!((0.0..=100.0).contains(&shed), "{row:?}");
        }
    }

    #[test]
    fn power_breakdown_matches_paper() {
        let t = power_breakdown();
        let rendered = t.render();
        assert!(rendered.contains("167.0"));
        assert!(rendered.contains("404.6"));
        assert!(rendered.contains("481.6"));
        assert!(rendered.contains("491.7"));
    }

    #[test]
    fn table1_quarter_scale_speedups() {
        let t = table1(Scale(0.25)).unwrap();
        assert_eq!(t.rows.len(), 3);
        // speedups all > 1.5x at quarter scale
        for row in &t.rows {
            let sp: f64 = row[2].trim_end_matches('x').parse().unwrap();
            assert!(sp > 1.5, "{row:?}");
        }
    }
}
