"""Tiled GEMM / similarity-scores Pallas kernels.

Hardware adaptation (DESIGN.md §8): the paper's ISP inner loop streams a
large table (embedding matrix / feature matrix) from flash-backed DRAM
through the A53's caches and NEON registers.  The TPU-shaped equivalent
streams HBM tiles through VMEM into the MXU:

* the *grid* walks (rows/BLOCK_N, cols/BLOCK_O, k/BLOCK_K) tiles;
* ``BlockSpec`` index maps express which (BLOCK, BLOCK) tile of each
  operand is resident in VMEM for a given grid step — this is the
  flash->DRAM->compute schedule the paper implements with the CBDD;
* an f32 VMEM scratch accumulator carries partial sums across the K
  loop (the innermost grid dimension), exactly like the NEON register
  tile carries the row accumulator.

Kernels are executed with ``interpret=True``: the CPU PJRT plugin cannot
run Mosaic custom-calls, and correctness (vs ``ref.py``) plus *structural*
efficiency (VMEM footprint, MXU-shaped tiles — reported by
``vmem_footprint``) are what we validate on this testbed.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


# Default tile shapes: MXU-friendly (128x128 systolic array) while small
# enough that  x_tile + w_tile + acc  stay well under ~16 MiB VMEM.
BLOCK_M = 128
BLOCK_O = 128
BLOCK_K = 512


def _matmul_kernel(x_ref, w_ref, o_ref, acc_ref, *, n_k: int):
    """One (i, j, k) grid step: acc += x_tile @ w_tile."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...].astype(jnp.float32),
        w_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _pad_to(x, multiple, axis):
    size = x.shape[axis]
    rem = size % multiple
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, multiple - rem)
    return jnp.pad(x, pad)


@functools.partial(jax.jit, static_argnames=("block_m", "block_o", "block_k"))
def matmul(x, w, block_m=BLOCK_M, block_o=BLOCK_O, block_k=BLOCK_K):
    """Tiled ``x @ w`` with f32 accumulation.

    Shapes: x[M, K] @ w[K, O] -> [M, O] (f32).  Inputs may be f32 or
    bf16; accumulation is always f32 (MXU-style).  Arbitrary shapes are
    padded up to the tile grid and cropped back.
    """
    m, k = x.shape
    k2, o = w.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    bm, bo, bk = min(block_m, m), min(block_o, o), min(block_k, k)
    xp = _pad_to(_pad_to(x, bm, 0), bk, 1)
    wp = _pad_to(_pad_to(w, bk, 0), bo, 1)
    mp, kp = xp.shape
    _, op = wp.shape
    n_k = kp // bk
    grid = (mp // bm, op // bo, n_k)
    out = pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bo), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bo), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, op), jnp.float32),
        scratch_shapes=[pltpu_vmem((bm, bo), jnp.float32)],
        interpret=True,
    )(xp, wp)
    return out[:m, :o]


def pltpu_vmem(shape, dtype):
    """VMEM scratch allocation.

    Under ``interpret=True`` any scratch shape works; on a real TPU this
    maps to ``pltpu.VMEM``.  Isolated here so the TPU path is a one-line
    change.
    """
    try:  # pragma: no cover - only on TPU-enabled jaxlibs
        from jax.experimental.pallas import tpu as pltpu

        return pltpu.VMEM(shape, dtype)
    except Exception:  # pragma: no cover
        return jax.ShapeDtypeStruct(shape, dtype)


def similarity(m, q, block_n=BLOCK_M, block_k=BLOCK_K):
    """Similarity scores ``M[N, D] @ q[D] -> [N]``.

    The recommender hot path: one row of scores per catalogue item.
    Implemented on the tiled GEMM with a width-1 output tile kept in
    VMEM; the matrix streams through once (arithmetic intensity ~1 FLOP/
    byte, bandwidth-bound on any hardware, which is exactly why the paper
    runs it next to the flash).
    """
    scores = matmul(m, q[:, None], block_m=block_n, block_o=1, block_k=block_k)
    return scores[:, 0]


def vmem_footprint(block_m=BLOCK_M, block_o=BLOCK_O, block_k=BLOCK_K,
                   in_dtype_bytes=4):
    """Static VMEM bytes resident per grid step (x tile + w tile + acc).

    Used by DESIGN.md §Perf and the L1 structural benchmarks: the target
    is footprint <= ~4 MiB so double-buffering fits in 16 MiB VMEM.
    """
    x_tile = block_m * block_k * in_dtype_bytes
    w_tile = block_k * block_o * in_dtype_bytes
    acc = block_m * block_o * 4
    return x_tile + w_tile + acc


def mxu_utilization_estimate(m, k, o, block_m=BLOCK_M, block_o=BLOCK_O):
    """Fraction of MXU lanes a (block_m x block_o) tile keeps busy,
    discounted by edge padding waste. Analytic estimate for DESIGN.md
    (interpret mode gives no hardware counters)."""
    mxu = 128
    lane_fill = min(block_m, mxu) / mxu * min(block_o, mxu) / mxu
    def waste(size, block):
        import math
        padded = math.ceil(size / block) * block
        return size / padded
    return lane_fill * waste(m, block_m) * waste(o, block_o)
