//! `cargo bench --bench ablate_datapath` — regenerates A2: shared-FS index dispatch vs tunnel data dispatch
//!
//! Scale with `SOLANA_BENCH_FAST=1` (5%) or default 25% of the paper's
//! dataset sizes; the *shape* (who wins, by what factor, where the
//! crossovers fall) is scale-invariant. See EXPERIMENTS.md.

use solana_isp::bench_support::Bencher;
use solana_isp::exp::{self, Scale};
#[allow(unused_imports)]
use solana_isp::workloads::App;

fn main() -> anyhow::Result<()> {
    let scale = Scale::from_env();
    let table = exp::ablate_datapath(App::SpeechToText, scale)?;
    exp::emit(&table, "ablate_datapath")?;
    // Wall-time of regenerating the artifact (simulator throughput):
    let mut b = Bencher::new(0, if std::env::var("SOLANA_BENCH_FAST").is_ok() { 1 } else { 2 });
    b.bench("ablate_datapath", || {
        let t = exp::ablate_datapath(App::SpeechToText, scale).expect("rerun");
        t.rows.len() as u64
    });
    print!("{}", b.report());
    b.write_json("ablate_datapath")?;
    Ok(())
}
