//! `cargo bench --bench power_breakdown` — §IV-C wall-power states:
//! idle 167 W, +36 CSDs 405 W (6.6 W/drive), running 482 W storage-only
//! vs 492 W with all ISP engines (0.28 W per engine).

use solana_isp::exp;
use solana_isp::power::PowerModel;

fn main() -> anyhow::Result<()> {
    exp::emit(&exp::power_breakdown(), "power")?;

    // Energy-per-query checks straight from the model (Table I column).
    let p = PowerModel::default();
    println!("\nderived energy/query at the paper's measured rates:");
    for (app, base_rate, isp_rate, paper_host_mj, paper_isp_mj) in [
        ("speech (per word)", 96.0, 296.0, 5021.0, 1662.0),
        ("recommender", 579.0, 1506.0, 832.0, 327.0),
        ("sentiment", 9496.0, 20994.0, 51.0, 23.0),
    ] {
        let host = p.instantaneous_w(36, 1.0, 0) / base_rate * 1e3;
        let isp = p.instantaneous_w(36, 1.0, 36) / isp_rate * 1e3;
        println!(
            "  {app:<18} host {host:7.0} mJ (paper {paper_host_mj:5.0})   \
             w/CSD {isp:6.0} mJ (paper {paper_isp_mj:4.0})"
        );
    }
    Ok(())
}
