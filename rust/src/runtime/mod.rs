//! PJRT runtime: load the AOT artifacts and execute them from Rust.
//!
//! This is the request-path compute engine. `make artifacts` (Python,
//! build-time only) lowers the L2 JAX graphs to HLO text; this module
//! loads each `artifacts/<name>__<variant>.hlo.txt` through
//! `HloModuleProto::from_text_file`, compiles it once on the PJRT CPU
//! client, and executes it with concrete inputs. One compiled executable
//! per model variant, cached in the [`Engine`].
//!
//! Big, reused operands (the recommender's item matrix, model weights)
//! are uploaded once as device buffers ([`Engine::upload`]) and passed to
//! [`Engine::run_b`] so the hot loop never re-marshals them.

pub mod tensor;

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::codec::json::Json;
pub use tensor::Tensor;

/// Shape+dtype of one executable input/output, from the manifest.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(j: &Json) -> Result<TensorSpec> {
        let shape = j
            .get("shape")
            .and_then(|s| s.as_arr())
            .ok_or_else(|| anyhow!("spec missing shape"))?
            .iter()
            .map(|v| v.as_u64().map(|x| x as usize))
            .collect::<Option<Vec<_>>>()
            .ok_or_else(|| anyhow!("bad shape"))?;
        let dtype = j
            .get("dtype")
            .and_then(|d| d.as_str())
            .ok_or_else(|| anyhow!("spec missing dtype"))?
            .to_string();
        Ok(TensorSpec { shape, dtype })
    }
}

/// One artifact entry from the manifest.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub variant: String,
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

impl ArtifactSpec {
    pub fn key(&self) -> String {
        format!("{}__{}", self.name, self.variant)
    }
}

/// Parsed `artifacts/manifest.json`.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dims: BTreeMap<String, u64>,
    pub artifacts: Vec<ArtifactSpec>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        Manifest::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text).map_err(|e| anyhow!("manifest: {e}"))?;
        let format = j.get("format").and_then(|f| f.as_u64()).unwrap_or(0);
        if format != 1 {
            bail!("unsupported manifest format {format}");
        }
        let mut dims = BTreeMap::new();
        if let Some(d) = j.get("dims").and_then(|d| d.as_obj()) {
            for (k, v) in d {
                dims.insert(
                    k.clone(),
                    v.as_u64().ok_or_else(|| anyhow!("dim {k} not integer"))?,
                );
            }
        }
        let mut artifacts = Vec::new();
        for a in j
            .get("artifacts")
            .and_then(|a| a.as_arr())
            .ok_or_else(|| anyhow!("manifest missing artifacts"))?
        {
            let get_str = |k: &str| -> Result<String> {
                Ok(a.get(k)
                    .and_then(|v| v.as_str())
                    .ok_or_else(|| anyhow!("artifact missing {k}"))?
                    .to_string())
            };
            let specs = |k: &str| -> Result<Vec<TensorSpec>> {
                a.get(k)
                    .and_then(|v| v.as_arr())
                    .ok_or_else(|| anyhow!("artifact missing {k}"))?
                    .iter()
                    .map(TensorSpec::from_json)
                    .collect()
            };
            artifacts.push(ArtifactSpec {
                name: get_str("name")?,
                variant: get_str("variant")?,
                file: get_str("file")?,
                inputs: specs("inputs")?,
                outputs: specs("outputs")?,
            });
        }
        Ok(Manifest { dims, artifacts })
    }

    pub fn dim(&self, key: &str) -> Result<u64> {
        self.dims
            .get(key)
            .copied()
            .ok_or_else(|| anyhow!("manifest has no dim '{key}'"))
    }

    pub fn find(&self, name: &str, variant: &str) -> Option<&ArtifactSpec> {
        self.artifacts
            .iter()
            .find(|a| a.name == name && a.variant == variant)
    }
}

/// The engine: PJRT CPU client + lazily compiled executables.
pub struct Engine {
    client: xla::PjRtClient,
    dir: PathBuf,
    pub manifest: Manifest,
    // BTreeMap for determinism hygiene (lint rule D1): the cache is
    // keyed-lookup-only today, but nothing downstream should ever be
    // able to observe hasher-dependent order if that changes.
    executables: BTreeMap<String, xla::PjRtLoadedExecutable>,
    executions: u64,
}

impl Engine {
    /// Load the manifest and create the PJRT client. Executables compile
    /// on first use.
    pub fn load(dir: impl AsRef<Path>) -> Result<Engine> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Engine { client, dir, manifest, executables: BTreeMap::new(), executions: 0 })
    }

    /// Engine for tests/examples: looks for artifacts relative to the
    /// crate root; returns `None` (with a note) when not built.
    pub fn load_default() -> Option<Engine> {
        let dir = default_artifacts_dir();
        match Engine::load(&dir) {
            Ok(e) => Some(e),
            Err(err) => {
                eprintln!("[runtime] artifacts unavailable ({err:#}); run `make artifacts`");
                None
            }
        }
    }

    pub fn executions(&self) -> u64 {
        self.executions
    }

    /// Compile (or fetch the cached) executable for `name__variant`.
    pub fn executable(&mut self, name: &str, variant: &str) -> Result<&xla::PjRtLoadedExecutable> {
        let spec = self
            .manifest
            .find(name, variant)
            .ok_or_else(|| anyhow!("no artifact {name}__{variant} in manifest"))?
            .clone();
        let key = spec.key();
        if !self.executables.contains_key(&key) {
            let path = self.dir.join(&spec.file);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow!("parsing {path:?}: {e:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {key}: {e:?}"))?;
            self.executables.insert(key.clone(), exe);
        }
        Ok(&self.executables[&key])
    }

    /// Upload a tensor to the device once (for reused operands).
    pub fn upload(&self, t: &Tensor) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(&t.data, &t.shape, None)
            .map_err(|e| anyhow!("upload: {e:?}"))
    }

    /// Execute with host tensors; returns output tensors (tuple
    /// flattened).
    ///
    /// Inputs are uploaded with `buffer_from_host_buffer` (one copy,
    /// host→device) rather than through an intermediate `Literal`
    /// (§Perf: the Literal path copies twice and cost ~35% of small-batch
    /// inference latency).
    pub fn run(&mut self, name: &str, variant: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        self.validate_inputs(name, variant, inputs)?;
        let bufs: Vec<xla::PjRtBuffer> = inputs
            .iter()
            .map(|t| {
                // XLA represents scalars as rank-0; shape [] works as-is.
                self.client
                    .buffer_from_host_buffer(&t.data, &t.shape, None)
                    .map_err(|e| anyhow!("upload input: {e:?}"))
            })
            .collect::<Result<_>>()?;
        let exe = self.executable(name, variant)?;
        let out = exe
            .execute_b(&bufs.iter().collect::<Vec<_>>())
            .map_err(|e| anyhow!("executing {name}__{variant}: {e:?}"))?;
        self.executions += 1;
        Self::collect_outputs(out)
    }

    /// Execute with pre-uploaded device buffers (hot path).
    pub fn run_b(
        &mut self,
        name: &str,
        variant: &str,
        inputs: &[&xla::PjRtBuffer],
    ) -> Result<Vec<Tensor>> {
        let exe = self.executable(name, variant)?;
        let out = exe
            .execute_b(inputs)
            .map_err(|e| anyhow!("executing {name}__{variant}: {e:?}"))?;
        self.executions += 1;
        Self::collect_outputs(out)
    }

    fn collect_outputs(out: Vec<Vec<xla::PjRtBuffer>>) -> Result<Vec<Tensor>> {
        let buf = &out[0][0];
        let lit = buf
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result: {e:?}"))?;
        // aot.py lowers with return_tuple=True: the root is always a tuple.
        let parts = lit.to_tuple().map_err(|e| anyhow!("untuple: {e:?}"))?;
        parts.into_iter().map(|l| Tensor::from_literal(&l)).collect()
    }

    fn validate_inputs(&self, name: &str, variant: &str, inputs: &[Tensor]) -> Result<()> {
        let spec = self
            .manifest
            .find(name, variant)
            .ok_or_else(|| anyhow!("no artifact {name}__{variant}"))?;
        if spec.inputs.len() != inputs.len() {
            bail!(
                "{name}__{variant}: expected {} inputs, got {}",
                spec.inputs.len(),
                inputs.len()
            );
        }
        for (i, (s, t)) in spec.inputs.iter().zip(inputs).enumerate() {
            if s.shape != t.shape {
                bail!(
                    "{name}__{variant} input {i}: shape {:?} != manifest {:?}",
                    t.shape,
                    s.shape
                );
            }
        }
        Ok(())
    }
}

/// `artifacts/` relative to the workspace root (works from tests, benches
/// and examples).
pub fn default_artifacts_dir() -> PathBuf {
    let mut dir = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    dir.push("artifacts");
    dir
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE_MANIFEST: &str = r#"{
      "format": 1,
      "dims": {"rec_topk": 10, "sent_features": 4096},
      "artifacts": [
        {"name": "m", "variant": "b8", "file": "m__b8.hlo.txt",
         "inputs": [{"shape": [8, 16], "dtype": "float32"}],
         "outputs": [{"shape": [8], "dtype": "float32"}]}
      ]
    }"#;

    #[test]
    fn manifest_parses() {
        let m = Manifest::parse(SAMPLE_MANIFEST).unwrap();
        assert_eq!(m.dim("rec_topk").unwrap(), 10);
        let a = m.find("m", "b8").unwrap();
        assert_eq!(a.file, "m__b8.hlo.txt");
        assert_eq!(a.inputs[0].shape, vec![8, 16]);
        assert_eq!(a.inputs[0].elements(), 128);
        assert!(m.find("m", "b9").is_none());
    }

    #[test]
    fn manifest_rejects_bad_format() {
        assert!(Manifest::parse(r#"{"format": 2, "artifacts": []}"#).is_err());
        assert!(Manifest::parse(r#"{"format": 1}"#).is_err());
        assert!(Manifest::parse("not json").is_err());
    }

    #[test]
    fn missing_dim_is_error() {
        let m = Manifest::parse(SAMPLE_MANIFEST).unwrap();
        assert!(m.dim("nope").is_err());
    }
}
