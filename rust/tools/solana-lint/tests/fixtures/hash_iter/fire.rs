// Positive fixture for D1 hash-iter: both iteration forms must fire.
use std::collections::HashMap;

pub fn report_counts() -> Vec<u32> {
    let mut counts: HashMap<u64, u32> = HashMap::new();
    counts.insert(1, 2);
    let mut out = Vec::new();
    for v in counts.values() {
        out.push(*v);
    }
    for (k, v) in &counts {
        out.push((*k % 7) as u32 + *v);
    }
    out
}
