//! Movie recommender demo: build the 58k-title MovieLens-like catalogue,
//! upload the TF-IDF matrix to the device once, and answer top-10
//! queries through the Pallas-kernel-backed `recommender_topk`
//! executable — then report the simulated cluster throughput (Fig 5(b)).
//!
//! ```bash
//! make artifacts && cargo run --release --example recommender
//! ```

use solana_isp::metrics::Metrics;
use solana_isp::nlp::corpus::MovieCatalog;
use solana_isp::power::PowerModel;
use solana_isp::runtime::Engine;
use solana_isp::sched::{run, SchedConfig};
use solana_isp::workloads::{AppModel, RecommenderApp};

fn main() -> anyhow::Result<()> {
    let Some(mut eng) = Engine::load_default() else {
        anyhow::bail!("run `make artifacts` first");
    };

    println!("building the 58,000-title catalogue + TF-IDF features…");
    let catalog = MovieCatalog::generate(7, 58_000);
    let t0 = std::time::Instant::now();
    let app = RecommenderApp::build(&mut eng, catalog)?;
    println!("built + uploaded in {:.2}s wall", t0.elapsed().as_secs_f64());

    // Answer a few real queries.
    let queries: Vec<u32> = app.catalog.shuffled_query_ids(99)[..8].to_vec();
    let t1 = std::time::Instant::now();
    let recs = app.recommend(&mut eng, &queries)?;
    let per_q = t1.elapsed().as_secs_f64() / queries.len() as f64;
    println!("served {} queries ({:.1} ms/query wall)\n", queries.len(), per_q * 1e3);
    for (q, rlist) in queries.iter().zip(&recs).take(3) {
        let movie = &app.catalog.movies[*q as usize];
        println!("query: \"{}\" [{}]", movie.title, movie.genres.join(", "));
        for r in rlist.iter().take(3) {
            let m = &app.catalog.movies[r.movie_id as usize];
            println!(
                "   {:.3}  \"{}\" [{}]",
                r.score,
                m.title,
                m.genres.join(", ")
            );
        }
    }

    // Cluster simulation: Fig 5(b) headline.
    println!("\nsimulating 58,000 queries on the 36-CSD server…");
    let model = AppModel::recommender(58_000);
    let power = PowerModel::default();
    let mut m = Metrics::new();
    let cfg = SchedConfig { csd_batch: 256, batch_ratio: 22.0, ..SchedConfig::default() };
    let base = run(&model, &SchedConfig { isp_drives: 0, ..cfg.clone() }, &power, &mut m)?;
    let isp = run(&model, &cfg, &power, &mut m)?;
    println!(
        "host-only : {:.0} queries/s   (paper:  579 q/s)",
        base.items_per_sec
    );
    println!(
        "36 CSDs   : {:.0} queries/s   (paper: 1506 q/s) — speedup {:.2}x (paper 2.6x)",
        isp.items_per_sec,
        isp.items_per_sec / base.items_per_sec
    );
    Ok(())
}
