//! Quickstart: simulate a small Solana storage server (host + 8 CSDs)
//! running the sentiment benchmark, and print the paper's headline
//! metrics — throughput vs the storage-only baseline, data-transfer
//! reduction, and energy per query.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use solana_isp::metrics::{Metrics, Table};
use solana_isp::power::PowerModel;
use solana_isp::sched::{run, SchedConfig};
use solana_isp::util::{human_bytes, human_secs};
use solana_isp::workloads::AppModel;

fn main() -> anyhow::Result<()> {
    let items = 1_500_000; // tweets
    let model = AppModel::sentiment(items);
    let power = PowerModel::default();

    println!("Solana ISP quickstart — {} tweets, host + 8 CSDs\n", items);

    let cfg = SchedConfig {
        drives: 8,
        isp_drives: 8,
        csd_batch: 5_000,
        batch_ratio: 26.0,
        ..SchedConfig::default()
    };

    // Baseline: same server, ISP engines disabled (CSDs = plain SSDs).
    let mut m0 = Metrics::new();
    let base = run(&model, &SchedConfig { isp_drives: 0, ..cfg.clone() }, &power, &mut m0)?;

    // In-storage processing on.
    let mut m1 = Metrics::new();
    let isp = run(&model, &cfg, &power, &mut m1)?;

    let mut t = Table::new(
        "host-only vs in-storage processing",
        &["metric", "baseline", "with ISP", "delta"],
    );
    t.row(vec![
        "throughput (q/s)".into(),
        format!("{:.0}", base.items_per_sec),
        format!("{:.0}", isp.items_per_sec),
        format!("{:.2}x", isp.items_per_sec / base.items_per_sec),
    ]);
    t.row(vec![
        "makespan".into(),
        human_secs(base.makespan_secs),
        human_secs(isp.makespan_secs),
        format!("{:.0}%", (1.0 - isp.makespan_secs / base.makespan_secs) * 100.0),
    ]);
    t.row(vec![
        "PCIe traffic".into(),
        human_bytes(base.pcie_bytes),
        human_bytes(isp.pcie_bytes),
        format!("-{:.0}%", (1.0 - isp.pcie_bytes as f64 / base.pcie_bytes as f64) * 100.0),
    ]);
    t.row(vec![
        "energy/query (mJ)".into(),
        format!("{:.1}", base.energy_per_item_j * 1e3),
        format!("{:.1}", isp.energy_per_item_j * 1e3),
        format!("-{:.0}%", (1.0 - isp.energy_per_item_j / base.energy_per_item_j) * 100.0),
    ]);
    t.row(vec![
        "items in storage".into(),
        "0%".into(),
        format!("{:.0}%", isp.csd_data_fraction() * 100.0),
        "".into(),
    ]);
    print!("\n{}", t.render());
    println!(
        "\n{} tunnel messages carried only indexes and acks — the dataset \
         stayed on flash for {:.0}% of queries.",
        isp.tunnel_messages,
        isp.csd_data_fraction() * 100.0
    );
    Ok(())
}
