//! NLP substrates: tokenization, feature hashing, synthetic corpora, and
//! decode/score utilities shared by the three benchmark apps.
//!
//! The paper's datasets (LJSpeech audio, MovieLens metadata, Sentiment140
//! tweets) are not redistributable inside this environment, so
//! [`corpus`] generates deterministic synthetic equivalents with the same
//! statistical shape (sizes, length distributions, label balance, skew) —
//! see DESIGN.md §2 for the substitution argument. Everything is seeded:
//! two runs produce byte-identical corpora.

pub mod corpus;
pub mod edit;
pub mod features;
pub mod text;

pub use corpus::{MovieCatalog, SpeechCorpus, TweetCorpus};
pub use edit::{levenshtein, wer};
pub use text::{hash_token, tokenize, HashingVectorizer};
