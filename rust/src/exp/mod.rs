//! Experiment drivers: one function per paper figure/table, shared by
//! the `cargo bench` targets, the examples, and the `solana` CLI.
//!
//! Experiment index (DESIGN.md §6):
//!
//! | fn | paper artifact |
//! |----|----------------|
//! | [`fig5`] | Fig 5(a/b/c): throughput vs batch size × #CSDs |
//! | [`fig6`] | Fig 6: 1-node sentiment throughput vs batch size |
//! | [`fig7`] | Fig 7: normalized energy/query vs #CSDs |
//! | [`table1`] | Table I: summary of all benchmarks |
//! | [`power_breakdown`] | §IV-C wall-power measurements |
//! | [`ablate_batch_ratio`] | A1: off-optimal batch ratios under-utilize |
//! | [`ablate_datapath`] | A2: shared-FS index dispatch vs tunnel data |
//! | [`ablate_wakeup`] | A3: scheduler polling period sensitivity |

pub mod cli;

use crate::metrics::{Metrics, Table};
use crate::power::PowerModel;
use crate::sched::{run, RunReport, SchedConfig};
use crate::workloads::{App, AppModel};

pub use cli::dispatch;

/// Scale factor applied to the paper's dataset sizes (1.0 = full paper
/// scale; benches use smaller factors for quick runs via
/// `SOLANA_BENCH_FAST`).
#[derive(Clone, Copy, Debug)]
pub struct Scale(pub f64);

impl Scale {
    pub fn items(&self, app: App) -> u64 {
        ((AppModel::paper_items(app) as f64 * self.0) as u64).max(1_000)
    }

    pub fn from_env() -> Scale {
        if std::env::var("SOLANA_BENCH_FAST").ok().as_deref() == Some("1") {
            Scale(0.05)
        } else {
            Scale(0.25)
        }
    }
}

/// Default batch-size sweep per app (the paper's Fig 5 x-values; the
/// recommender's are not stated in the paper — we use a range around its
/// operating point, see DESIGN.md).
pub fn batch_sizes(app: App) -> Vec<u64> {
    match app {
        App::SpeechToText => vec![2, 4, 6, 8],
        App::Recommender => vec![64, 128, 256, 512],
        App::Sentiment => vec![10_000, 20_000, 40_000, 80_000],
    }
}

/// Default batch ratio per app (≈ host/CSD speed ratio, §IV-A).
pub fn batch_ratio(app: App) -> f64 {
    AppModel::for_app(app, 1).natural_batch_ratio().round()
}

/// #CSD sweep for Fig 5/7 (0 = host-only baseline).
pub const CSD_COUNTS: [usize; 6] = [0, 4, 9, 18, 27, 36];

fn cfg_for(app: App, batch: u64, isp_drives: usize) -> SchedConfig {
    SchedConfig {
        csd_batch: batch,
        batch_ratio: batch_ratio(app),
        drives: 36,
        isp_drives,
        ..SchedConfig::default()
    }
}

/// One throughput cell of Fig 5.
pub fn run_cell(app: App, items: u64, batch: u64, isp_drives: usize) -> anyhow::Result<RunReport> {
    let model = AppModel::for_app(app, items);
    let mut metrics = Metrics::new();
    run(&model, &cfg_for(app, batch, isp_drives), &PowerModel::default(), &mut metrics)
}

/// Fig 5(a/b/c): throughput vs batch size × engaged CSDs.
/// Rows: one per (batch, csds) with items/s and words/s.
pub fn fig5(app: App, scale: Scale) -> anyhow::Result<Table> {
    let items = scale.items(app);
    let unit = if app == App::SpeechToText { "words/s" } else { "queries/s" };
    let mut t = Table::new(
        &format!("Fig 5 — {} throughput ({} items)", app.name(), items),
        &["batch", "csds", unit, "host items", "csd items", "csd share"],
    );
    for &batch in &batch_sizes(app) {
        for &csds in &CSD_COUNTS {
            let r = run_cell(app, items, batch, csds)?;
            let rate = if app == App::SpeechToText { r.words_per_sec } else { r.items_per_sec };
            t.row(vec![
                batch.to_string(),
                csds.to_string(),
                format!("{rate:.1}"),
                r.host_items.to_string(),
                r.csd_items.to_string(),
                format!("{:.2}", r.csd_data_fraction()),
            ]);
        }
    }
    Ok(t)
}

/// Fig 6: single-node sentiment throughput vs batch size (log sweep),
/// host and CSD — run end-to-end with one compute node each.
pub fn fig6(scale: Scale) -> anyhow::Result<Table> {
    let mut t = Table::new(
        "Fig 6 — 1-node sentiment throughput vs batch size",
        &["batch", "host q/s", "csd q/s", "host batch latency s", "csd batch latency s"],
    );
    let batches = [10u64, 100, 1_000, 4_000, 10_000, 40_000, 80_000];
    for &b in &batches {
        let items = (scale.items(App::Sentiment) / 8).max(4 * b);
        let model = AppModel::sentiment(items);
        let power = PowerModel::default();
        // host only, one drive holding the data
        let mut m1 = Metrics::new();
        let host = run(
            &model,
            &SchedConfig {
                csd_batch: b,
                batch_ratio: 1.0,
                drives: 1,
                isp_drives: 0,
                ..SchedConfig::default()
            },
            &power,
            &mut m1,
        )?;
        // csd only
        let mut m2 = Metrics::new();
        let csd = run(
            &model,
            &SchedConfig {
                csd_batch: b,
                batch_ratio: 1.0,
                drives: 1,
                isp_drives: 1,
                use_host: false,
                ..SchedConfig::default()
            },
            &power,
            &mut m2,
        )?;
        let hl = m1.histogram("sched.host_batch_latency").map(|h| h.mean()).unwrap_or(0.0);
        let cl = m2.histogram("sched.csd_batch_latency").map(|h| h.mean()).unwrap_or(0.0);
        t.row(vec![
            b.to_string(),
            format!("{:.1}", host.items_per_sec),
            format!("{:.1}", csd.items_per_sec),
            format!("{hl:.3}"),
            format!("{cl:.3}"),
        ]);
    }
    Ok(t)
}

/// Fig 7: energy per query vs #CSDs, normalized to the host-only setup.
pub fn fig7(scale: Scale) -> anyhow::Result<Table> {
    let mut t = Table::new(
        "Fig 7 — energy per query, normalized to host-only",
        &["csds", "speech", "recommender", "sentiment"],
    );
    let mut base: Vec<f64> = Vec::new();
    for &csds in &CSD_COUNTS {
        let mut cells = vec![csds.to_string()];
        for (i, app) in App::all().iter().enumerate() {
            let batch = default_batch(*app);
            let r = run_cell(*app, scale.items(*app), batch, csds)?;
            if csds == 0 {
                base.push(r.energy_per_item_j);
                cells.push("1.000".to_string());
            } else {
                cells.push(format!("{:.3}", r.energy_per_item_j / base[i]));
            }
        }
        t.row(cells);
    }
    Ok(t)
}

/// The paper's per-app operating point in Fig 5 (best batch).
pub fn default_batch(app: App) -> u64 {
    match app {
        App::SpeechToText => 6,
        App::Recommender => 256,
        App::Sentiment => 40_000,
    }
}

/// Table I: the summary row block for every app.
pub fn table1(scale: Scale) -> anyhow::Result<Table> {
    let mut t = Table::new(
        "Table I — summary of experimental results",
        &[
            "application",
            "items",
            "max speedup",
            "energy/query host (mJ)",
            "energy/query w/CSD (mJ)",
            "energy saving",
            "data on host",
            "data in CSDs",
        ],
    );
    for app in App::all() {
        let items = scale.items(app);
        let batch = default_batch(app);
        let base = run_cell(app, items, batch, 0)?;
        let isp = run_cell(app, items, batch, 36)?;
        let speedup = isp.items_per_sec / base.items_per_sec;
        // the paper reports energy per word for speech
        let divisor = AppModel::for_app(app, items).words_per_item;
        let e_host = base.energy_per_item_j / divisor * 1e3;
        let e_isp = isp.energy_per_item_j / divisor * 1e3;
        t.row(vec![
            app.name().to_string(),
            items.to_string(),
            format!("{speedup:.1}x"),
            format!("{e_host:.0}"),
            format!("{e_isp:.0}"),
            format!("{:.0}%", (1.0 - e_isp / e_host) * 100.0),
            format!("{:.0}%", (1.0 - isp.csd_data_fraction()) * 100.0),
            format!("{:.0}%", isp.csd_data_fraction() * 100.0),
        ]);
    }
    Ok(t)
}

/// §IV-C: wall power in the four measured states.
pub fn power_breakdown() -> Table {
    let p = PowerModel::default();
    let mut t = Table::new(
        "Power breakdown (paper §IV-C)",
        &["state", "model W", "paper W"],
    );
    t.row(vec!["idle, no drives".into(), format!("{:.1}", p.instantaneous_w(0, 0.0, 0)), "167".into()]);
    t.row(vec!["idle, 36 CSDs".into(), format!("{:.1}", p.instantaneous_w(36, 0.0, 0)), "405".into()]);
    t.row(vec!["running, ISP off".into(), format!("{:.1}", p.instantaneous_w(36, 1.0, 0)), "482".into()]);
    t.row(vec!["running, 36 ISPs".into(), format!("{:.1}", p.instantaneous_w(36, 1.0, 36)), "492".into()]);
    t
}

/// A1: batch-ratio sweep at fixed batch size — off-optimal ratios
/// under-utilize one side (§IV-A).
pub fn ablate_batch_ratio(app: App, scale: Scale) -> anyhow::Result<Table> {
    let items = scale.items(app);
    let natural = batch_ratio(app);
    let mut t = Table::new(
        &format!("A1 — batch-ratio sweep ({}; natural ≈ {natural})", app.name()),
        &["ratio", "items/s", "host util", "mean csd idle gap s"],
    );
    for mult in [0.25, 0.5, 1.0, 2.0, 4.0] {
        let ratio = (natural * mult).max(1.0);
        let model = AppModel::for_app(app, items);
        let mut m = Metrics::new();
        let cfg = SchedConfig {
            // batch small enough that the run is many batches long per
            // node (a single-tail-batch run would mask the ratio)
            csd_batch: (default_batch(app) / 8).max(1),
            batch_ratio: ratio,
            drives: 36,
            isp_drives: 36,
            // the paper's plain scheduler — our fair-share tail fix
            // hides exactly the under-utilization this ablation shows
            fair_tail: false,
            ..SchedConfig::default()
        };
        let r = run(&model, &cfg, &PowerModel::default(), &mut m)?;
        let host_util = r.host_busy_secs / r.makespan_secs;
        let idle_gap = (r.makespan_secs * 36.0 - r.isp_busy_secs) / 36.0 / r.csd_batches.max(1) as f64;
        t.row(vec![
            format!("{ratio:.0}"),
            format!("{:.1}", r.items_per_sec),
            format!("{host_util:.2}"),
            format!("{idle_gap:.3}"),
        ]);
    }
    Ok(t)
}

/// A2: what if the scheduler shipped *data* over the TCP/IP tunnel
/// instead of indexes into the shared FS? (Why OCFS2 matters, §IV-A.)
///
/// Run on an IO-bound scan workload: the paper's NLP apps are
/// A53-compute-bound, so their data path barely shows; a grep-like scan
/// is where "GBps of PCIe/DMA vs MBps of TCP/IP" decides everything.
/// The `app` argument selects the *paper* workload shown alongside for
/// contrast.
pub fn ablate_datapath(app: App, scale: Scale) -> anyhow::Result<Table> {
    let items = (scale.items(App::Sentiment) / 100).max(5_000);
    let base = AppModel::scan(items);
    let mut t = Table::new(
        &format!("A2 — dispatch datapath (IO-bound scan; contrast app: {})", app.name()),
        &["dispatch", "items/s", "speedup vs host-only"],
    );
    let power = PowerModel::default();
    let mut m = Metrics::new();
    let cfg = SchedConfig {
        csd_batch: 256,
        batch_ratio: 8.0,
        ..SchedConfig::default()
    };
    let host_only = run(&base, &SchedConfig { isp_drives: 0, ..cfg.clone() }, &power, &mut m)?;
    // index-only dispatch (the paper's design): ISPs read via local DMA
    let shared_fs = run(&base, &cfg, &power, &mut m)?;
    // tunnel-data dispatch: every CSD item's bytes cross the ~120 MB/s
    // tunnel (serialized per drive) before the scan can run
    let mut tunneled = base.clone();
    let tun = crate::interconnect::TcpTunnel::default();
    tunneled.csd_item_secs += tun.unloaded_secs(base.bytes_per_item) * crate::workloads::ISP_CORES;
    let tunnel_run = run(&tunneled, &cfg, &power, &mut m)?;
    for (name, r) in [
        ("host-only", &host_only),
        ("shared-fs indexes", &shared_fs),
        ("tunnel data", &tunnel_run),
    ] {
        t.row(vec![
            name.to_string(),
            format!("{:.1}", r.items_per_sec),
            format!("{:.2}x", r.items_per_sec / host_only.items_per_sec),
        ]);
    }
    Ok(t)
}

/// A3: scheduler wakeup period sensitivity (paper fixes 0.2 s).
pub fn ablate_wakeup(app: App, scale: Scale) -> anyhow::Result<Table> {
    let items = scale.items(app);
    let model = AppModel::for_app(app, items);
    let mut t = Table::new(
        &format!("A3 — scheduler wakeup period ({})", app.name()),
        &["wakeup s", "items/s", "tunnel msgs"],
    );
    for wakeup in [0.02, 0.1, 0.2, 0.5, 1.0, 2.0] {
        let mut m = Metrics::new();
        let cfg = SchedConfig {
            wakeup_secs: wakeup,
            ..cfg_for(app, default_batch(app), 36)
        };
        let r = run(&model, &cfg, &PowerModel::default(), &mut m)?;
        t.row(vec![
            format!("{wakeup}"),
            format!("{:.1}", r.items_per_sec),
            r.tunnel_messages.to_string(),
        ]);
    }
    Ok(t)
}

/// Write a table to `target/bench-results/<name>.{txt,csv}` and print it.
pub fn emit(table: &Table, name: &str) -> anyhow::Result<()> {
    print!("{}", table.render());
    let dir = std::path::Path::new("target/bench-results");
    std::fs::create_dir_all(dir)?;
    std::fs::write(dir.join(format!("{name}.txt")), table.render())?;
    std::fs::write(dir.join(format!("{name}.csv")), table.to_csv())?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_ratios_are_natural() {
        assert!((batch_ratio(App::Sentiment) - 26.0).abs() < 1.0);
        assert!((batch_ratio(App::SpeechToText) - 19.0).abs() < 1.5);
    }

    #[test]
    fn fig5_speech_small_scale_shape() {
        // tiny scale: monotone in #CSDs at fixed batch
        let items = 2_620;
        let r0 = run_cell(App::SpeechToText, items, 6, 0).unwrap();
        let r18 = run_cell(App::SpeechToText, items, 6, 18).unwrap();
        let r36 = run_cell(App::SpeechToText, items, 6, 36).unwrap();
        assert!(r18.words_per_sec > r0.words_per_sec);
        assert!(r36.words_per_sec > r18.words_per_sec);
    }

    #[test]
    fn power_breakdown_matches_paper() {
        let t = power_breakdown();
        let rendered = t.render();
        assert!(rendered.contains("167.0"));
        assert!(rendered.contains("404.6"));
        assert!(rendered.contains("481.6"));
        assert!(rendered.contains("491.7"));
    }

    #[test]
    fn table1_quarter_scale_speedups() {
        let t = table1(Scale(0.25)).unwrap();
        assert_eq!(t.rows.len(), 3);
        // speedups all > 1.5x at quarter scale
        for row in &t.rows {
            let sp: f64 = row[2].trim_end_matches('x').parse().unwrap();
            assert!(sp > 1.5, "{row:?}");
        }
    }
}
