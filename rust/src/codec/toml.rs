//! TOML-subset parser for experiment configuration files.
//!
//! Supports the subset our configs use: `[table]` / `[table.sub]`
//! headers, `key = value` with string / integer / float / bool / array
//! values, `#` comments, and bare or quoted keys. No date-times, no
//! multi-line strings, no inline tables, no arrays-of-tables — config
//! files in `configs/` stay inside this subset by construction.

use std::collections::BTreeMap;

/// A parsed TOML value.
#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            TomlValue::Int(i) => Some(*i),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[TomlValue]> {
        match self {
            TomlValue::Arr(a) => Some(a),
            _ => None,
        }
    }
}

/// A flat table: dotted-path key → value. `[server]` + `drives = 36`
/// becomes `"server.drives" → Int(36)`.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TomlTable {
    entries: BTreeMap<String, TomlValue>,
}

/// Parse error with line number.
#[derive(Debug)]
pub struct TomlError {
    pub line: usize,
    pub msg: String,
}

impl std::fmt::Display for TomlError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "toml parse error on line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for TomlError {}

impl TomlTable {
    pub fn parse(text: &str) -> Result<TomlTable, TomlError> {
        let mut entries = BTreeMap::new();
        let mut prefix = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            let err = |msg: &str| TomlError { line: lineno + 1, msg: msg.to_string() };
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| err("unterminated table header"))?
                    .trim();
                if name.is_empty() {
                    return Err(err("empty table name"));
                }
                prefix = name.to_string();
            } else {
                let eq = line.find('=').ok_or_else(|| err("expected key = value"))?;
                let key = unquote_key(line[..eq].trim()).map_err(|m| err(m))?;
                let val = parse_value(line[eq + 1..].trim()).map_err(|m| err(m))?;
                let full = if prefix.is_empty() {
                    key
                } else {
                    format!("{prefix}.{key}")
                };
                if entries.insert(full.clone(), val).is_some() {
                    return Err(err(&format!("duplicate key '{full}'")));
                }
            }
        }
        Ok(TomlTable { entries })
    }

    pub fn get(&self, path: &str) -> Option<&TomlValue> {
        self.entries.get(path)
    }

    pub fn str(&self, path: &str) -> Option<&str> {
        self.get(path)?.as_str()
    }
    pub fn i64(&self, path: &str) -> Option<i64> {
        self.get(path)?.as_i64()
    }
    pub fn u64(&self, path: &str) -> Option<u64> {
        self.i64(path).filter(|v| *v >= 0).map(|v| v as u64)
    }
    pub fn f64(&self, path: &str) -> Option<f64> {
        self.get(path)?.as_f64()
    }
    pub fn bool(&self, path: &str) -> Option<bool> {
        self.get(path)?.as_bool()
    }

    /// All keys under a dotted prefix (for iterating `[workload.*]`).
    pub fn keys_under<'a>(&'a self, prefix: &'a str) -> impl Iterator<Item = &'a str> + 'a {
        let pat = format!("{prefix}.");
        self.entries.keys().filter_map(move |k| {
            k.strip_prefix(&pat).map(|_| k.as_str())
        })
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a basic string.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn unquote_key(k: &str) -> Result<String, &'static str> {
    if k.is_empty() {
        return Err("empty key");
    }
    if let Some(inner) = k.strip_prefix('"').and_then(|s| s.strip_suffix('"')) {
        return Ok(inner.to_string());
    }
    if k.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '-' || c == '.') {
        Ok(k.to_string())
    } else {
        Err("invalid bare key")
    }
}

fn parse_value(v: &str) -> Result<TomlValue, &'static str> {
    if v.is_empty() {
        return Err("empty value");
    }
    if let Some(inner) = v.strip_prefix('"') {
        let inner = inner.strip_suffix('"').ok_or("unterminated string")?;
        // Handle the escapes our configs may use.
        let mut s = String::new();
        let mut chars = inner.chars();
        while let Some(c) = chars.next() {
            if c == '\\' {
                match chars.next() {
                    Some('n') => s.push('\n'),
                    Some('t') => s.push('\t'),
                    Some('"') => s.push('"'),
                    Some('\\') => s.push('\\'),
                    _ => return Err("bad escape in string"),
                }
            } else {
                s.push(c);
            }
        }
        return Ok(TomlValue::Str(s));
    }
    if v == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if v == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(inner) = v.strip_prefix('[') {
        let inner = inner.strip_suffix(']').ok_or("unterminated array")?.trim();
        if inner.is_empty() {
            return Ok(TomlValue::Arr(vec![]));
        }
        let mut items = Vec::new();
        // Arrays of scalars only — split on commas outside strings.
        let mut depth_str = false;
        let mut start = 0usize;
        let bytes = inner.as_bytes();
        for i in 0..bytes.len() {
            match bytes[i] {
                b'"' => depth_str = !depth_str,
                b',' if !depth_str => {
                    items.push(parse_value(inner[start..i].trim())?);
                    start = i + 1;
                }
                _ => {}
            }
        }
        items.push(parse_value(inner[start..].trim())?);
        return Ok(TomlValue::Arr(items));
    }
    let clean = v.replace('_', "");
    if clean.chars().all(|c| c.is_ascii_digit() || c == '-' || c == '+') {
        if let Ok(i) = clean.parse::<i64>() {
            return Ok(TomlValue::Int(i));
        }
    }
    if let Ok(f) = clean.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    Err("unrecognized value")
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# Solana experiment config
seed = 42
name = "fig5a"        # trailing comment

[server]
drives = 36
host_threads = 16
idle_power_w = 167.0
enable_isp = true

[sched]
batch_sizes = [2, 4, 6, 8]
batch_ratio = 20
wakeup_s = 0.2
apps = ["speech", "sentiment"]
"#;

    #[test]
    fn parse_sample() {
        let t = TomlTable::parse(SAMPLE).unwrap();
        assert_eq!(t.i64("seed"), Some(42));
        assert_eq!(t.str("name"), Some("fig5a"));
        assert_eq!(t.u64("server.drives"), Some(36));
        assert_eq!(t.f64("server.idle_power_w"), Some(167.0));
        assert_eq!(t.bool("server.enable_isp"), Some(true));
        let arr = t.get("sched.batch_sizes").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 4);
        assert_eq!(arr[2].as_i64(), Some(6));
        let apps = t.get("sched.apps").unwrap().as_arr().unwrap();
        assert_eq!(apps[1].as_str(), Some("sentiment"));
    }

    #[test]
    fn int_promotes_to_f64() {
        let t = TomlTable::parse("x = 3").unwrap();
        assert_eq!(t.f64("x"), Some(3.0));
    }

    #[test]
    fn rejects_duplicates_and_garbage() {
        assert!(TomlTable::parse("a = 1\na = 2").is_err());
        assert!(TomlTable::parse("a =").is_err());
        assert!(TomlTable::parse("[unterminated").is_err());
        assert!(TomlTable::parse("novalue").is_err());
    }

    #[test]
    fn string_escapes() {
        let t = TomlTable::parse(r#"s = "a\nb\"c""#).unwrap();
        assert_eq!(t.str("s"), Some("a\nb\"c"));
    }

    #[test]
    fn hash_inside_string_not_comment() {
        let t = TomlTable::parse(r##"s = "a#b" # real comment"##).unwrap();
        assert_eq!(t.str("s"), Some("a#b"));
    }

    #[test]
    fn underscored_integers() {
        let t = TomlTable::parse("n = 1_600_000").unwrap();
        assert_eq!(t.i64("n"), Some(1_600_000));
    }

    #[test]
    fn empty_array() {
        let t = TomlTable::parse("a = []").unwrap();
        assert_eq!(t.get("a").unwrap().as_arr().unwrap().len(), 0);
    }
}
