//! Analytic FIFO resources: k-server queues and shared bandwidth pipes.
//!
//! With non-preemptive FIFO service and service times known at submit
//! time, queueing outcomes can be computed directly instead of simulated
//! event-by-event:
//!
//! * [`Servers`] — k parallel servers (CPU cores, flash channels, NVMe
//!   queue pairs). A job entering at `now` with service time `s` starts at
//!   `max(now, earliest_free)` and completes `s` later.
//! * [`Pipe`] — a serialized link (PCIe lane group, intra-chip bus,
//!   TCP/IP tunnel). A transfer occupies the link for `latency +
//!   bytes/bandwidth`; concurrent transfers queue behind its busy-until
//!   horizon.
//!
//! Both track utilization (busy seconds) so the power model can integrate
//! active vs idle energy.

use std::collections::BinaryHeap;
use std::cmp::Reverse;

use super::SimTime;

/// Total order wrapper for f64 times inside heaps (no NaNs by invariant).
#[derive(Clone, Copy, PartialEq, Debug)]
struct T(f64);
impl Eq for T {}
impl PartialOrd for T {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for T {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // solana-lint: allow(no-unwrap, reason = "completion times are sums of finite non-negative service times; the NaN policy is pinned by the release-profile stats tests")
        self.0.partial_cmp(&other.0).expect("NaN time")
    }
}

/// k-server analytic FIFO queue.
///
/// Capacity 1 (flash dies, serialized links) skips the heap entirely —
/// a single `free_at` scalar (§Perf: the per-page device loop dominates
/// full-sweep simulation time).
#[derive(Debug, Clone)]
pub struct Servers {
    free_at: BinaryHeap<Reverse<T>>,
    /// Fast path for capacity == 1.
    single_free: SimTime,
    capacity: usize,
    busy_secs: f64,
    jobs: u64,
    last_completion: SimTime,
}

impl Servers {
    pub fn new(capacity: usize) -> Servers {
        assert!(capacity > 0);
        let mut free_at = BinaryHeap::new();
        if capacity > 1 {
            free_at.reserve(capacity);
            for _ in 0..capacity {
                free_at.push(Reverse(T(0.0)));
            }
        }
        Servers {
            free_at,
            single_free: 0.0,
            capacity,
            busy_secs: 0.0,
            jobs: 0,
            last_completion: 0.0,
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Submit a job at `now` with the given service time; returns its
    /// completion time.
    #[inline]
    pub fn acquire(&mut self, now: SimTime, service: SimTime) -> SimTime {
        debug_assert!(service >= 0.0);
        let done = if self.capacity == 1 {
            let start = if now > self.single_free { now } else { self.single_free };
            let done = start + service;
            self.single_free = done;
            done
        } else {
            // solana-lint: allow(no-unwrap, reason = "free_at holds exactly `capacity` entries on this branch and capacity > 1 here")
            let Reverse(T(free)) = self.free_at.pop().expect("capacity>0");
            let start = now.max(free);
            let done = start + service;
            self.free_at.push(Reverse(T(done)));
            done
        };
        self.busy_secs += service;
        self.jobs += 1;
        if done > self.last_completion {
            self.last_completion = done;
        }
        done
    }

    /// Earliest time a new job submitted at `now` would start.
    pub fn next_start(&self, now: SimTime) -> SimTime {
        if self.capacity == 1 {
            return now.max(self.single_free);
        }
        // solana-lint: allow(no-unwrap, reason = "free_at holds exactly `capacity` entries on this branch and capacity > 1 here")
        let Reverse(T(free)) = *self.free_at.peek().expect("capacity>0");
        now.max(free)
    }

    /// Time when all queued work drains.
    pub fn drain_time(&self) -> SimTime {
        self.last_completion
    }

    /// Total service seconds delivered (for utilization = busy/(cap×T)).
    pub fn busy_secs(&self) -> f64 {
        self.busy_secs
    }

    pub fn jobs(&self) -> u64 {
        self.jobs
    }

    /// Utilization over `[0, horizon]`.
    pub fn utilization(&self, horizon: SimTime) -> f64 {
        if horizon <= 0.0 {
            return 0.0;
        }
        (self.busy_secs / (self.capacity as f64 * horizon)).min(1.0)
    }
}

/// A serialized bandwidth resource.
#[derive(Debug, Clone)]
pub struct Pipe {
    /// Bytes per second.
    pub bandwidth: f64,
    /// Fixed per-transfer latency in seconds (protocol + DMA setup).
    pub latency: SimTime,
    busy_until: SimTime,
    bytes_moved: u64,
    transfers: u64,
    busy_secs: f64,
}

/// Outcome of a [`Pipe::transfer`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Transfer {
    /// When the transfer began moving bytes (after queueing).
    pub start: SimTime,
    /// When the last byte arrived.
    pub end: SimTime,
}

impl Pipe {
    pub fn new(bandwidth: f64, latency: SimTime) -> Pipe {
        assert!(bandwidth > 0.0);
        assert!(latency >= 0.0);
        Pipe { bandwidth, latency, busy_until: 0.0, bytes_moved: 0, transfers: 0, busy_secs: 0.0 }
    }

    /// Enqueue a transfer of `bytes` at `now`; returns its start/end.
    pub fn transfer(&mut self, now: SimTime, bytes: u64) -> Transfer {
        let start = now.max(self.busy_until);
        let xfer = self.latency + bytes as f64 / self.bandwidth;
        let end = start + xfer;
        self.busy_until = end;
        self.bytes_moved += bytes;
        self.transfers += 1;
        self.busy_secs += xfer;
        Transfer { start, end }
    }

    /// Pure cost of a transfer ignoring queueing (for estimates).
    pub fn unloaded_secs(&self, bytes: u64) -> SimTime {
        self.latency + bytes as f64 / self.bandwidth
    }

    pub fn bytes_moved(&self) -> u64 {
        self.bytes_moved
    }

    pub fn transfers(&self) -> u64 {
        self.transfers
    }

    pub fn busy_secs(&self) -> f64 {
        self.busy_secs
    }

    pub fn busy_until(&self) -> SimTime {
        self.busy_until
    }

    pub fn utilization(&self, horizon: SimTime) -> f64 {
        if horizon <= 0.0 {
            return 0.0;
        }
        (self.busy_secs / horizon).min(1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop::{check, forall};

    #[test]
    fn single_server_serializes() {
        let mut s = Servers::new(1);
        assert_eq!(s.acquire(0.0, 1.0), 1.0);
        assert_eq!(s.acquire(0.0, 1.0), 2.0);
        assert_eq!(s.acquire(5.0, 1.0), 6.0); // idle gap honoured
        assert_eq!(s.jobs(), 3);
        assert!((s.busy_secs() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn multi_server_parallelism() {
        let mut s = Servers::new(4);
        let dones: Vec<f64> = (0..8).map(|_| s.acquire(0.0, 2.0)).collect();
        // first 4 finish at 2.0, next 4 at 4.0
        assert_eq!(&dones[..4], &[2.0; 4]);
        assert_eq!(&dones[4..], &[4.0; 4]);
        assert_eq!(s.drain_time(), 4.0);
        assert!((s.utilization(4.0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn pipe_queues_and_accounts() {
        let mut p = Pipe::new(1000.0, 0.5); // 1 KB/s, 0.5 s setup
        let t1 = p.transfer(0.0, 1000); // 0.5 + 1.0 = ends 1.5
        assert_eq!(t1, Transfer { start: 0.0, end: 1.5 });
        let t2 = p.transfer(0.0, 500); // queued behind t1
        assert_eq!(t2.start, 1.5);
        assert!((t2.end - 2.5).abs() < 1e-12);
        assert_eq!(p.bytes_moved(), 1500);
        assert_eq!(p.transfers(), 2);
    }

    #[test]
    fn property_servers_conserve_work() {
        forall("servers conserve work", 150, |g| {
            let cap = g.usize(1..=8);
            let mut s = Servers::new(cap);
            let services = g.vec_f64(0.0, 5.0, 1, 64);
            let mut arrivals: Vec<f64> = g.vec_f64(0.0, 10.0, services.len(), services.len());
            arrivals.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let total: f64 = services.iter().sum();
            let mut max_done: f64 = 0.0;
            for (i, &svc) in services.iter().enumerate() {
                let done = s.acquire(arrivals[i], svc);
                check(done >= arrivals[i] + svc - 1e-12, "done before arrival+service")?;
                max_done = max_done.max(done);
            }
            // busy time is conserved exactly
            check((s.busy_secs() - total).abs() < 1e-9, "busy != sum(service)")?;
            // makespan is at least total/cap and at most arrival span + total
            let lb = total / cap as f64;
            check(max_done + 1e-9 >= lb, format!("makespan {max_done} < {lb}"))?;
            let ub = arrivals.last().unwrap() + total;
            check(max_done <= ub + 1e-9, "makespan exceeds serial bound")?;
            Ok(())
        });
    }

    #[test]
    fn property_pipe_fifo_no_overlap() {
        forall("pipe transfers never overlap", 150, |g| {
            let mut p = Pipe::new(g.f64(1.0, 1e9), g.f64(0.0, 0.01));
            let mut arrivals = g.vec_f64(0.0, 10.0, 1, 64);
            arrivals.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let mut prev_end = 0.0f64;
            for &a in &arrivals {
                let tr = p.transfer(a, g.u64(0..=1_000_000));
                check(tr.start + 1e-12 >= prev_end, "overlapping transfers")?;
                check(tr.end >= tr.start, "end before start")?;
                prev_end = tr.end;
            }
            Ok(())
        });
    }
}
