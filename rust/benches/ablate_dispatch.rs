//! `cargo bench --bench ablate_dispatch` — regenerates A4: polling vs
//! event-driven dispatch across the batch-size sweep (ISSUE-2 tentpole).
//!
//! Scale with `SOLANA_BENCH_FAST=1` (5%) or default 25% of the paper's
//! dataset sizes; the *shape* (event-driven never slower, gap largest at
//! small batches) is scale-invariant. See the `sched` module docs.

use solana_isp::bench_support::Bencher;
use solana_isp::exp::{self, Scale};
#[allow(unused_imports)]
use solana_isp::workloads::App;

fn main() -> anyhow::Result<()> {
    let scale = Scale::from_env();
    let table = exp::ablate_dispatch(App::SpeechToText, scale)?;
    exp::emit(&table, "ablate_dispatch")?;
    // Wall-time of regenerating the artifact (simulator throughput):
    let mut b = Bencher::new(0, if std::env::var("SOLANA_BENCH_FAST").is_ok() { 1 } else { 2 });
    b.bench("ablate_dispatch", || {
        let t = exp::ablate_dispatch(App::SpeechToText, scale).expect("rerun");
        t.rows.len() as u64
    });
    print!("{}", b.report());
    b.write_json("ablate_dispatch")?;
    Ok(())
}
