//! Live execution mode: the scheduler protocol running on *real* OS
//! threads with *real* PJRT inference — no virtual time anywhere.
//!
//! This is the composition proof for the three-layer architecture: the
//! rust coordinator (rank 0) trains the sentiment model through the AOT
//! `sentiment_train_step` executable, broadcasts the weights to worker
//! ranks (stand-ins for ISP engines, each owning its own PJRT client
//! exactly like each CSD owns its own runtime), then drives the paper's
//! pull/ack protocol: index-only batch dispatch, 0.2 s polling loop,
//! batch-ratio-sized host batches processed on the coordinator itself.
//! Python never runs — everything on the request path is this binary.
//!
//! Like the simulated scheduler, live mode supports both
//! [`DispatchMode`]s: `Polling` (default) drains at most one worker
//! message per wake period, while `EventDriven` drains every queued
//! RESULT and re-arms each worker the moment its result is observed —
//! worker turnaround is no longer bounded by the `recv_timeout` grid.
//!
//! The protocol engine ([`run_live_with`]) is generic over a
//! [`LiveClassifier`], so the full pull/ack loop — threads, loopback
//! [`Communicator`]s, both dispatch modes — can be driven end-to-end
//! without PJRT artifacts: the loopback integration test
//! (`tests/live_loopback.rs`) substitutes a deterministic oracle model
//! and asserts item conservation and cross-mode agreement, while
//! [`run_live`] wires in the real AOT sentiment model.

use std::sync::Arc;
use std::time::{Duration, Instant};

use super::DispatchMode;
use crate::cluster::mpi::{self, tag, Communicator};
use crate::nlp::corpus::{Tweet, TweetCorpus};
use crate::runtime::{Engine, Tensor};
use crate::workloads::SentimentApp;

/// Live-mode configuration.
#[derive(Clone, Debug)]
pub struct LiveConfig {
    /// Worker threads (simulated ISP engines).
    pub workers: usize,
    /// Items per worker batch.
    pub batch: usize,
    /// Host batch = ratio × batch (processed on the coordinator).
    pub ratio: usize,
    /// Total tweets to serve.
    pub items: usize,
    /// Scheduler polling period (paper: 0.2 s). In event-driven mode
    /// this only bounds the blocking wait for straggler results.
    pub wakeup: Duration,
    /// Training set size.
    pub train_items: usize,
    /// Polling grid (the paper) vs re-arm-on-RESULT (see [`DispatchMode`]).
    pub dispatch: DispatchMode,
    pub seed: u64,
    /// Stuck-worker watchdog: consecutive empty wake periods the
    /// coordinator tolerates once every batch is handed out (nothing
    /// left to serve locally, so only worker RESULTs can make progress)
    /// before declaring a worker stuck and bailing. Measured in
    /// `wakeup` periods; the 600 × 0.2 s default ≈ 2 minutes.
    pub worker_deadline: usize,
}

impl Default for LiveConfig {
    fn default() -> Self {
        LiveConfig {
            workers: 2,
            batch: 64,
            ratio: 4,
            items: 4_096,
            wakeup: Duration::from_millis(200),
            train_items: 2_048,
            dispatch: DispatchMode::Polling,
            seed: 11,
            worker_deadline: 600,
        }
    }
}

/// Outcome of a live run.
#[derive(Clone, Debug)]
pub struct LiveReport {
    pub items: usize,
    pub wall_secs: f64,
    pub items_per_sec: f64,
    pub host_items: usize,
    pub worker_items: Vec<usize>,
    pub accuracy: f64,
    pub messages: u64,
    /// Sorted serving indices that were processed (exactly once each).
    /// Equals `0..items` on success — the loopback integration test
    /// asserts both dispatch modes produce the identical set.
    pub processed_indices: Vec<u32>,
}

/// A sentiment classifier driving the live protocol. The production
/// implementation wraps the AOT-compiled model + its PJRT [`Engine`]
/// (one per node, like each CSD's ISP runs its own binary); the loopback
/// integration test substitutes a deterministic oracle so the protocol
/// itself is testable without artifacts.
pub trait LiveClassifier: Send {
    /// Classify each text as positive (`true`) or negative (`false`).
    fn classify(&mut self, texts: &[&str]) -> anyhow::Result<Vec<bool>>;
}

/// Builds one [`LiveClassifier`] per worker rank from the broadcast
/// weight vector (`w ++ b`, f32 LE). Called on the worker's own thread,
/// mirroring how each ISP engine loads its own runtime.
pub type WorkerFactory =
    Arc<dyn Fn(usize, &[f32]) -> anyhow::Result<Box<dyn LiveClassifier>> + Send + Sync>;

/// The production classifier: AOT sentiment model through PJRT.
struct PjrtClassifier {
    app: SentimentApp,
    eng: Engine,
}

impl LiveClassifier for PjrtClassifier {
    fn classify(&mut self, texts: &[&str]) -> anyhow::Result<Vec<bool>> {
        let probs = self.app.predict(&mut self.eng, texts)?;
        Ok(probs.iter().map(|p| *p > 0.5).collect())
    }
}

/// Worker rank body: receive weights, build this rank's classifier via
/// the factory, then serve index batches until shutdown. The spawn
/// wrapper in [`run_live_with`] reports any `Err` back to rank 0 as a
/// `tag::ERROR` message so the coordinator fails fast instead of
/// waiting forever for a RESULT that will never come.
fn worker_main(
    comm: &mut Communicator,
    corpus: &Arc<Vec<Tweet>>,
    factory: &WorkerFactory,
) -> anyhow::Result<usize> {
    // weights arrive first
    let weights = loop {
        let p = comm.recv().map_err(|e| anyhow::anyhow!("{e}"))?;
        match p.tag {
            tag::WEIGHTS => break mpi::decode_f32s(&p.payload).map_err(|e| anyhow::anyhow!("{e}"))?,
            tag::SHUTDOWN => return Ok(0),
            _ => continue,
        }
    };
    let mut model = factory(comm.rank(), &weights)?;
    let mut served = 0usize;
    // initial ack announces readiness (the pull in "pull-based")
    comm.send(0, tag::RESULT, Vec::new()).map_err(|e| anyhow::anyhow!("{e}"))?;
    loop {
        let p = comm.recv().map_err(|e| anyhow::anyhow!("{e}"))?;
        match p.tag {
            tag::BATCH => {
                let idxs = mpi::decode_u32s(&p.payload).map_err(|e| anyhow::anyhow!("{e}"))?;
                let texts: Vec<&str> =
                    idxs.iter().map(|&i| corpus[i as usize].text.as_str()).collect();
                let preds = model.classify(&texts)?;
                served += idxs.len();
                // result = one byte per item (the label) + ack semantics
                let labels: Vec<u8> = preds.iter().map(|&b| u8::from(b)).collect();
                let mut payload = mpi::encode_u32s(&idxs);
                payload.extend_from_slice(&labels);
                comm.send(0, tag::RESULT, payload).map_err(|e| anyhow::anyhow!("{e}"))?;
            }
            tag::SHUTDOWN => return Ok(served),
            _ => {}
        }
    }
}

/// Apply one worker RESULT packet to the serving state: protocol
/// validation, exactly-once bookkeeping, accuracy tally. Returns the
/// worker index (`src - 1`).
///
/// Validation added by ISSUE-2's satellites: the source rank must be a
/// worker rank (a rank-0 packet used to underflow `src - 1`), and the
/// payload must be a whole number of 5-byte `(u32 index, u8 label)`
/// pairs (a misaligned payload used to silently drop trailing bytes and
/// could misalign index/label pairing).
fn absorb_result(
    p: &mpi::Packet,
    workers: usize,
    serve: &[Tweet],
    done: &mut [bool],
    completed: &mut usize,
    worker_items: &mut [usize],
    correct: &mut usize,
) -> anyhow::Result<usize> {
    anyhow::ensure!(
        (1..=workers).contains(&p.src),
        "RESULT from rank {} outside the worker range 1..={workers}",
        p.src
    );
    let worker = p.src - 1;
    if !p.payload.is_empty() {
        anyhow::ensure!(
            p.payload.len() % 5 == 0,
            "malformed RESULT payload from rank {}: {} bytes is not a whole \
             number of 5-byte (u32 index, u8 label) pairs",
            p.src,
            p.payload.len()
        );
        let n_idx = p.payload.len() / 5; // 4B index + 1B label
        let (idx_bytes, labels) = p.payload.split_at(4 * n_idx);
        let idxs = mpi::decode_u32s(idx_bytes).map_err(|e| anyhow::anyhow!("{e}"))?;
        // Validate the whole packet before tallying anything, so a
        // rejected packet leaves the serving state untouched. `done` is
        // marked during validation (which also catches duplicates
        // *within* the packet) and rolled back if a later pair fails.
        let mut marked = 0usize;
        let mut violation: Option<String> = None;
        for &idx in &idxs {
            let idx = idx as usize;
            if idx >= serve.len() {
                violation = Some(format!(
                    "RESULT index {idx} out of range ({} serving items)",
                    serve.len()
                ));
                break;
            }
            if done[idx] {
                violation = Some(format!("item {idx} served twice"));
                break;
            }
            done[idx] = true;
            marked += 1;
        }
        if let Some(msg) = violation {
            for &idx in &idxs[..marked] {
                done[idx as usize] = false;
            }
            anyhow::bail!("{msg}");
        }
        for (i, &idx) in idxs.iter().enumerate() {
            let idx = idx as usize;
            *completed += 1;
            worker_items[worker] += 1;
            if (labels[i] == 1) == serve[idx].positive {
                *correct += 1;
            }
        }
    }
    Ok(worker)
}

/// Re-arm `dst` with the next index batch, if any items are left to
/// hand out.
fn send_next_batch(
    c0: &mut Communicator,
    next: &mut usize,
    cfg: &LiveConfig,
    dst: usize,
) -> anyhow::Result<()> {
    if *next < cfg.items {
        let hi = (*next + cfg.batch).min(cfg.items);
        let idxs: Vec<u32> = (*next..hi).map(|i| i as u32).collect();
        *next = hi;
        c0.send(dst, tag::BATCH, mpi::encode_u32s(&idxs))
            .map_err(|e| anyhow::anyhow!("{e}"))?;
    }
    Ok(())
}

/// Handle one coordinator receive outcome, shared by every receive site
/// in both dispatch modes: absorb + re-arm on RESULT, ignore other
/// tags, map a timeout/empty queue to "no packet", surface transport
/// errors. Returns whether a packet was processed.
#[allow(clippy::too_many_arguments)]
fn pump_coordinator(
    res: Result<mpi::Packet, mpi::MpiError>,
    c0: &mut Communicator,
    next: &mut usize,
    cfg: &LiveConfig,
    serve: &[Tweet],
    done: &mut [bool],
    completed: &mut usize,
    worker_items: &mut [usize],
    correct: &mut usize,
) -> anyhow::Result<bool> {
    match res {
        Ok(p) if p.tag == tag::RESULT => {
            absorb_result(&p, cfg.workers, serve, done, completed, worker_items, correct)?;
            send_next_batch(c0, next, cfg, p.src)?;
            Ok(true)
        }
        Ok(p) if p.tag == tag::ERROR => anyhow::bail!(
            "worker rank {} failed: {}",
            p.src,
            String::from_utf8_lossy(&p.payload)
        ),
        Ok(_) => Ok(true),
        Err(mpi::MpiError::Timeout) => Ok(false),
        Err(e) => anyhow::bail!("coordinator recv: {e}"),
    }
}

/// Run the live cluster with the real AOT sentiment model; requires
/// `make artifacts`. Trains on the coordinator, then hands the protocol
/// to [`run_live_with`].
pub fn run_live(cfg: &LiveConfig) -> anyhow::Result<LiveReport> {
    // Also checked by run_live_with, but fail fast here — before engine
    // load, corpus generation and training.
    anyhow::ensure!(cfg.workers >= 1, "need at least one worker");
    let mut eng = Engine::load(crate::runtime::default_artifacts_dir())?;
    let features = eng.manifest.dim("sent_features")? as usize;

    // Corpus: train split + serving split (deterministic).
    let mut gen = TweetCorpus::new(cfg.seed);
    let train = gen.take(cfg.train_items);
    let serve: Arc<Vec<Tweet>> = Arc::new(gen.take(cfg.items));

    // Train on the coordinator through the AOT SGD step.
    let (app, _losses) = SentimentApp::train(&mut eng, &train, 3, cfg.seed)?;

    // Broadcast payload: w ++ b as f32 LE.
    let mut weights = app.w.data.clone();
    weights.extend_from_slice(&app.b.data);

    let host: Box<dyn LiveClassifier> = Box::new(PjrtClassifier { app, eng });
    let factory: WorkerFactory = Arc::new(move |_rank, w: &[f32]| {
        // Each worker owns its Engine, exactly like each CSD's ISP.
        let eng = Engine::load(crate::runtime::default_artifacts_dir())?;
        let (w_raw, b_raw) = w.split_at(features);
        let app = SentimentApp::from_weights(
            features,
            Tensor::new(vec![features, 1], w_raw.to_vec()),
            Tensor::new(vec![1], b_raw.to_vec()),
        );
        Ok(Box::new(PjrtClassifier { app, eng }) as Box<dyn LiveClassifier>)
    });
    run_live_with(cfg, serve, weights, host, factory)
}

/// Run the live protocol — threads, weight broadcast, pull/ack dispatch
/// in either [`DispatchMode`] — with pluggable classifiers. `serve` is
/// the serving corpus, `weights` the broadcast payload handed to the
/// [`WorkerFactory`] on each worker rank, `host` the coordinator's own
/// classifier.
pub fn run_live_with(
    cfg: &LiveConfig,
    serve: Arc<Vec<Tweet>>,
    weights: Vec<f32>,
    mut host: Box<dyn LiveClassifier>,
    factory: WorkerFactory,
) -> anyhow::Result<LiveReport> {
    anyhow::ensure!(cfg.workers >= 1, "need at least one worker");
    anyhow::ensure!(
        cfg.batch >= 1,
        "batch must be >= 1 (a zero batch ping-pongs empty BATCH/RESULT messages forever)"
    );
    anyhow::ensure!(
        cfg.worker_deadline >= 1,
        "worker_deadline must be >= 1 wake period (0 would trip the watchdog on the first \
         straggler wait)"
    );
    anyhow::ensure!(serve.len() == cfg.items, "serving corpus size != cfg.items");

    // Spawn workers. A worker that errors reports back over the tunnel
    // (tag::ERROR) before exiting, so the coordinator loop below can
    // bail instead of polling forever for the missing RESULT.
    let mut comms = mpi::group(cfg.workers + 1);
    let mut handles = Vec::new();
    for mut comm in comms.drain(1..) {
        let corpus = Arc::clone(&serve);
        let factory = Arc::clone(&factory);
        // solana-lint: allow(join-reduce, reason = "live-mode workers return integer item counts over the tunnel; no cross-thread float accumulation happens at this join")
        handles.push(std::thread::spawn(move || {
            // Catch panics too: an unreported worker death would leave
            // the coordinator polling forever (rank 0 can never see a
            // channel disconnect — every rank holds a clone of its
            // sender).
            let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                worker_main(&mut comm, &corpus, &factory)
            }))
            .unwrap_or_else(|p| {
                let msg = p
                    .downcast_ref::<&str>()
                    .map(|s| s.to_string())
                    .or_else(|| p.downcast_ref::<String>().cloned())
                    .unwrap_or_else(|| "unknown panic".to_string());
                Err(anyhow::anyhow!("worker panicked: {msg}"))
            });
            if let Err(ref e) = res {
                let _ = comm.send(0, tag::ERROR, e.to_string().into_bytes());
            }
            res
        }));
    }
    // solana-lint: allow(no-unwrap, reason = "mpi::group(workers + 1) returned exactly workers + 1 comms and drain(1..) left rank 0")
    let mut c0 = comms.pop().unwrap();

    c0.bcast(tag::WEIGHTS, &mpi::encode_f32s(&weights))
        .map_err(|e| anyhow::anyhow!("{e}"))?;

    // Pull/ack dispatch loop.
    let event_driven = cfg.dispatch == DispatchMode::EventDriven;
    // solana-lint: allow(wall-clock, reason = "live mode runs on real threads against the host clock; this is the sanctioned non-simulated path")
    let t0 = Instant::now();
    let mut next = 0usize;
    let mut done = vec![false; cfg.items];
    let mut completed = 0usize;
    let mut host_items = 0usize;
    let mut worker_items = vec![0usize; cfg.workers];
    let mut correct = 0usize;
    // The dispatch loop proper, wrapped so an error (host classify
    // failure, worker ERROR report, protocol violation) still falls
    // through to the shutdown/join sequence below instead of leaving
    // worker threads parked on a dead channel.
    let mut protocol = || -> anyhow::Result<()> {
    // Stuck-worker watchdog state: consecutive empty wake periods seen
    // while every remaining item is outstanding at a worker. Any
    // progress (a processed packet, or batches left for the host to
    // serve itself) resets it.
    let mut idle_wakes = 0usize;
    while completed < cfg.items {
        if event_driven {
            // Event-driven dispatch: drain every RESULT already queued
            // and re-arm each worker the moment its result is seen — no
            // wake grid bounds worker turnaround.
            loop {
                let res = c0.try_recv();
                if !pump_coordinator(
                    res, &mut c0, &mut next, cfg, &serve, &mut done, &mut completed,
                    &mut worker_items, &mut correct,
                )? {
                    break;
                }
            }
            if completed >= cfg.items {
                break;
            }
            if next >= cfg.items {
                // Nothing left to hand out or process locally: block for
                // the next straggler RESULT instead of spinning.
                let res = c0.recv_timeout(cfg.wakeup);
                let got = pump_coordinator(
                    res, &mut c0, &mut next, cfg, &serve, &mut done, &mut completed,
                    &mut worker_items, &mut correct,
                )?;
                idle_wakes = if got { 0 } else { idle_wakes + 1 };
            }
        } else {
            // The paper's polling loop: drain worker messages for up to
            // one wakeup period (at most one message per wake).
            let res = c0.recv_timeout(cfg.wakeup);
            let got = pump_coordinator(
                res, &mut c0, &mut next, cfg, &serve, &mut done, &mut completed,
                &mut worker_items, &mut correct,
            )?;
            idle_wakes = if got || next < cfg.items { 0 } else { idle_wakes + 1 };
        }
        anyhow::ensure!(
            idle_wakes < cfg.worker_deadline,
            "watchdog: no worker RESULT for {} consecutive wake periods with {} of {} \
             items outstanding — a worker looks stuck",
            idle_wakes,
            cfg.items - completed,
            cfg.items
        );
        // Host processes its own (ratio-sized) batch between polls.
        if next < cfg.items {
            let hi = (next + cfg.batch * cfg.ratio).min(cfg.items);
            let idxs: Vec<usize> = (next..hi).collect();
            next = hi;
            let texts: Vec<&str> = idxs.iter().map(|&i| serve[i].text.as_str()).collect();
            let preds = host.classify(&texts)?;
            for (k, &idx) in idxs.iter().enumerate() {
                anyhow::ensure!(!done[idx], "item {idx} served twice");
                done[idx] = true;
                completed += 1;
                host_items += 1;
                if preds[k] == serve[idx].positive {
                    correct += 1;
                }
            }
        }
    }
    Ok(())
    };
    let protocol_result = protocol();
    let wall = t0.elapsed().as_secs_f64();
    // Best-effort per-rank shutdown (a bcast would abort at the first
    // already-exited worker's closed channel, stranding the rest), then
    // join everyone: live workers exit on SHUTDOWN, failed workers have
    // already returned their Err.
    for dst in 1..=cfg.workers {
        let _ = c0.send(dst, tag::SHUTDOWN, Vec::new());
    }
    let worker_results: Vec<anyhow::Result<usize>> = handles
        .into_iter()
        // solana-lint: allow(no-unwrap, reason = "worker bodies catch_unwind their own panics into Err results; a panicking join here means the catch itself is broken")
        .map(|h| h.join().expect("worker panicked"))
        .collect();
    // The coordinator's own error wins (it names the failing rank when a
    // worker reported in); otherwise surface the first worker error.
    protocol_result?;
    for r in worker_results {
        r?;
    }
    let (sent, received) = c0.stats();
    let processed_indices: Vec<u32> = done
        .iter()
        .enumerate()
        .filter(|(_, &d)| d)
        .map(|(i, _)| i as u32)
        .collect();
    Ok(LiveReport {
        items: cfg.items,
        wall_secs: wall,
        items_per_sec: cfg.items as f64 / wall,
        host_items,
        worker_items,
        accuracy: correct as f64 / cfg.items as f64,
        messages: sent + received,
        processed_indices,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tally(n: usize, workers: usize) -> (Vec<Tweet>, Vec<bool>, usize, Vec<usize>, usize) {
        let serve = TweetCorpus::new(1).take(n);
        (serve, vec![false; n], 0, vec![0; workers], 0)
    }

    #[test]
    fn absorb_result_tallies_well_formed_payloads() {
        let (serve, mut done, mut completed, mut worker_items, mut correct) = tally(8, 2);
        let mut payload = mpi::encode_u32s(&[1, 3]);
        payload.extend_from_slice(&[u8::from(serve[1].positive), u8::from(serve[3].positive)]);
        let p = mpi::Packet { src: 2, tag: tag::RESULT, payload };
        let w = absorb_result(&p, 2, &serve, &mut done, &mut completed, &mut worker_items, &mut correct)
            .unwrap();
        assert_eq!(w, 1);
        assert_eq!(completed, 2);
        assert_eq!(worker_items, vec![0, 2]);
        assert_eq!(correct, 2);
        assert!(done[1] && done[3]);
    }

    #[test]
    fn absorb_result_rejects_misaligned_payloads() {
        // ISSUE-2 regression: `len / 5` silently dropped trailing bytes
        // of a misaligned payload; now it is a protocol error.
        let (serve, mut done, mut completed, mut worker_items, mut correct) = tally(4, 2);
        for bad_len in [1usize, 4, 7, 9] {
            let p = mpi::Packet { src: 1, tag: tag::RESULT, payload: vec![0u8; bad_len] };
            let err = absorb_result(
                &p, 2, &serve, &mut done, &mut completed, &mut worker_items, &mut correct,
            )
            .unwrap_err();
            assert!(err.to_string().contains("5-byte"), "len {bad_len}: {err}");
        }
        assert_eq!(completed, 0, "malformed payloads must not tally anything");
    }

    #[test]
    fn absorb_result_rejects_out_of_range_ranks() {
        // ISSUE-2 regression: a rank-0 packet underflowed `src - 1`
        // (panic); now any non-worker rank is a protocol error.
        let (serve, mut done, mut completed, mut worker_items, mut correct) = tally(4, 2);
        for bad_src in [0usize, 3, 99] {
            let p = mpi::Packet { src: bad_src, tag: tag::RESULT, payload: Vec::new() };
            let err = absorb_result(
                &p, 2, &serve, &mut done, &mut completed, &mut worker_items, &mut correct,
            )
            .unwrap_err();
            assert!(err.to_string().contains("worker range"), "src {bad_src}: {err}");
        }
    }

    #[test]
    fn absorb_result_rejects_bad_indexes_and_duplicates() {
        let (serve, mut done, mut completed, mut worker_items, mut correct) = tally(4, 1);
        // index out of range
        let mut payload = mpi::encode_u32s(&[9]);
        payload.push(1);
        let p = mpi::Packet { src: 1, tag: tag::RESULT, payload };
        let err = absorb_result(
            &p, 1, &serve, &mut done, &mut completed, &mut worker_items, &mut correct,
        )
        .unwrap_err();
        assert!(err.to_string().contains("out of range"), "{err}");
        // duplicate serve
        done[2] = true;
        let mut payload = mpi::encode_u32s(&[2]);
        payload.push(0);
        let p = mpi::Packet { src: 1, tag: tag::RESULT, payload };
        let err = absorb_result(
            &p, 1, &serve, &mut done, &mut completed, &mut worker_items, &mut correct,
        )
        .unwrap_err();
        assert!(err.to_string().contains("served twice"), "{err}");
        // rejection is atomic: a packet whose *second* pair is invalid
        // must not tally (or keep marks for) its valid first pair
        let mut payload = mpi::encode_u32s(&[0, 9]);
        payload.extend_from_slice(&[1, 1]);
        let p = mpi::Packet { src: 1, tag: tag::RESULT, payload };
        assert!(absorb_result(
            &p, 1, &serve, &mut done, &mut completed, &mut worker_items, &mut correct,
        )
        .is_err());
        assert!(!done[0], "rolled back the valid pair of a rejected packet");
        assert_eq!(completed, 0);
        assert_eq!(worker_items, vec![0]);
    }

    #[test]
    fn live_cluster_serves_everything_exactly_once() {
        if Engine::load_default().is_none() {
            return; // artifacts not built
        }
        let cfg = LiveConfig {
            workers: 2,
            batch: 32,
            ratio: 4,
            items: 1_024,
            train_items: 1_024,
            wakeup: Duration::from_millis(50),
            dispatch: DispatchMode::Polling,
            seed: 3,
            worker_deadline: 600,
        };
        let r = run_live(&cfg).unwrap();
        assert_eq!(r.items, 1_024);
        let worker_total: usize = r.worker_items.iter().sum();
        assert_eq!(r.host_items + worker_total, 1_024);
        assert!(r.accuracy > 0.85, "accuracy {}", r.accuracy);
        assert!(r.items_per_sec > 0.0);
        assert!(
            worker_total > 0,
            "workers served some batches: {:?}",
            r.worker_items
        );
    }

    #[test]
    fn live_cluster_event_driven_serves_everything_exactly_once() {
        if Engine::load_default().is_none() {
            return; // artifacts not built
        }
        let cfg = LiveConfig {
            workers: 2,
            batch: 32,
            ratio: 4,
            items: 1_024,
            train_items: 1_024,
            wakeup: Duration::from_millis(50),
            dispatch: DispatchMode::EventDriven,
            seed: 3,
            worker_deadline: 600,
        };
        let r = run_live(&cfg).unwrap();
        let worker_total: usize = r.worker_items.iter().sum();
        assert_eq!(r.host_items + worker_total, 1_024);
        assert!(r.accuracy > 0.85, "accuracy {}", r.accuracy);
        // No `worker_total > 0` assert here, deliberately: the
        // event-driven coordinator never waits out a poll period, so on
        // a fast host it can legitimately serve every item before the
        // workers finish loading their engines — exactly-once serving
        // and accuracy are the protocol guarantees, worker share is not.
    }
}
