//! Workflow-lint guard (ISSUE-10 satellite): the CI pipeline is part of
//! the contract, so drift between the CLI's experiment registry and the
//! workflow file is a test failure, not a code-review hope.
//!
//! For every `figN` command registered in `src/exp/cli.rs`, this guard
//! asserts:
//!
//! 1. **a smoke cell** — `.github/workflows/ci.yml` invokes
//!    `solana -- figN --scale` somewhere (the fan-out smoke matrix),
//!    unless the command is on the documented exemption list below;
//! 2. **a golden registration** — `tests/golden_tables.rs` calls
//!    `exp::figN…`, so the table is pinned by the cell-by-cell net.
//!
//! fig12 (the elastic-fleet study) is the first experiment added with
//! this guard in place; every later figN lands with both hooks or fails
//! `cargo test` on the spot. The guard also checks its own exemption
//! list for staleness (an exempted name must still be a registered
//! command) and that the workflow's structural pieces it depends on —
//! the smoke matrix with `fail-fast: false` and the concurrency group —
//! are still present.

use std::fs;
use std::path::PathBuf;

fn repo_file(rel: &str) -> String {
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join(rel);
    fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("read {}: {e}", path.display()))
}

/// Every `Command::new("figN", …)` registration in the CLI, in order.
fn registered_fig_commands(cli_src: &str) -> Vec<String> {
    let mut figs = Vec::new();
    for line in cli_src.lines() {
        let Some(rest) = line.trim_start().strip_prefix("Command::new(\"fig") else {
            continue;
        };
        let Some(end) = rest.find('"') else { continue };
        figs.push(format!("fig{}", &rest[..end]));
    }
    figs
}

/// figN commands with no direct `figN --scale` smoke cell, each with the
/// reason the exemption is sound. Additions here need a reason of the
/// same strength.
const SMOKE_EXEMPT: &[(&str, &str)] = &[
    ("fig5", "batch-mode table; pinned per-app by the fig5a/b/c goldens and cargo test"),
    ("fig6", "batch-mode table; pinned by its golden and cargo test"),
    ("fig7", "batch-mode table; pinned by its golden and cargo test"),
    ("fig8", "smoked through the `fleet --servers 4` CLI cell (same sweep, one point)"),
    ("fig9", "smoked through the `serve --scale 0.01` CLI cell (same serving path)"),
];

#[test]
fn every_fig_experiment_has_a_smoke_cell_and_a_golden() {
    let cli = repo_file("src/exp/cli.rs");
    let workflow = repo_file("../.github/workflows/ci.yml");
    let goldens = repo_file("tests/golden_tables.rs");

    let figs = registered_fig_commands(&cli);
    assert!(
        figs.len() >= 9,
        "fig-command extraction broke: found only {figs:?} in src/exp/cli.rs"
    );

    for (name, _reason) in SMOKE_EXEMPT {
        assert!(
            figs.iter().any(|f| f == name),
            "stale smoke exemption: {name} is no longer a registered CLI command"
        );
    }

    let mut missing = Vec::new();
    for fig in &figs {
        let exempt = SMOKE_EXEMPT.iter().any(|(n, _)| n == fig);
        // The smoke matrix invokes every experiment through the real
        // binary; a bare substring match would let fig1 piggyback on
        // fig10, so the scale flag is part of the needle.
        let smoke_needle = format!("-- {fig} --scale");
        if !exempt && !workflow.contains(&smoke_needle) {
            missing.push(format!(
                "{fig}: no smoke cell — add `solana -- {fig} --scale 0.01` to the \
                 smoke matrix in .github/workflows/ci.yml (or add a justified \
                 exemption to tests/workflow_lint.rs)"
            ));
        }
        // Golden registration: `exp::figN(` or `exp::figN_suffix(` — the
        // char after the name disambiguates fig1 vs fig10.
        let hit = goldens.match_indices(&format!("exp::{fig}")).any(|(i, m)| {
            matches!(goldens.as_bytes().get(i + m.len()), Some(b'(' | b'_'))
        });
        if !hit {
            missing.push(format!(
                "{fig}: not registered in tests/golden_tables.rs — every experiment \
                 table must be pinned by the golden net"
            ));
        }
    }
    assert!(missing.is_empty(), "workflow drift:\n  {}", missing.join("\n  "));
}

#[test]
fn workflow_structure_the_guard_depends_on_is_intact() {
    let workflow = repo_file("../.github/workflows/ci.yml");
    for (needle, why) in [
        ("concurrency:", "per-ref concurrency group with cancel-in-progress"),
        ("cancel-in-progress: true", "superseded runs must cancel, not queue"),
        ("fail-fast: false", "one smoke failure must not hide the cells behind it"),
        ("needs: build-lint-test", "smoke fans out only after the build+test gate"),
        ("actions/cache@", "smoke cells rely on the warm cargo/target cache"),
        ("if: always()", "artifacts upload even when a cell fails"),
        ("timeout-minutes:", "every job needs a wall-clock bound"),
    ] {
        assert!(
            workflow.contains(needle),
            "ci.yml lost `{needle}` ({why}) — the smoke-matrix contract this \
             guard checks no longer holds"
        );
    }
    // The two ISSUE-10 consumers this guard was introduced for:
    assert!(
        workflow.contains("-- fig12 --scale"),
        "ci.yml must smoke the fig12 elastic-fleet experiment"
    );
    assert!(
        workflow.contains("--autoscale predictive"),
        "ci.yml must smoke the serve --autoscale CLI surface"
    );
    assert!(
        workflow.contains("--bench serve_elastic"),
        "ci.yml must smoke the serve_elastic bench"
    );
}
