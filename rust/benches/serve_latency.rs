//! `cargo bench --bench serve_latency` — regenerates Fig 9: per-request
//! serving latency vs offered load across fleet shapes (the ISSUE-4
//! tentpole). See `traffic` for the serving frontend and `exp` for the
//! sweep definition.
//!
//! Scale with `SOLANA_BENCH_FAST=1` (5%) or default 25% of the paper's
//! dataset sizes; the *shape* (flat latency below the knee, blowup past
//! it, all-CSD sustaining ~2.5× the all-SSD rate under the SLO) is
//! scale-invariant — only the tail resolution improves with more
//! requests.

use solana_isp::bench_support::Bencher;
use solana_isp::exp::{self, Scale};

fn main() -> anyhow::Result<()> {
    let scale = Scale::from_env();
    let table = exp::fig9_latency(scale)?;
    exp::emit(&table, "fig9")?;
    // Wall-time of regenerating the artifact (simulator throughput):
    let mut b = Bencher::new(0, if std::env::var("SOLANA_BENCH_FAST").is_ok() { 1 } else { 2 });
    b.bench("fig9_serve_latency", || {
        let t = exp::fig9_latency(scale).expect("rerun");
        t.rows.len() as u64
    });
    print!("{}", b.report());
    b.write_json("serve_latency")?;
    Ok(())
}
