//! Elastic-fleet configuration (the ISSUE-10 tentpole): the `[autoscale]`
//! TOML section and the `solana serve --autoscale` flags both resolve
//! into [`AutoscaleConfig`], carried as
//! [`super::TrafficConfig::autoscale`].
//!
//! The paper's scale-out story is statically provisioned — fig10
//! searches for the minimum *fixed* fleet per offered load. Production
//! load moves (diurnal ramps, flash crowds), so this layer makes
//! membership time-varying inside one serving run:
//!
//! * an **autoscaler** adds servers when the observed p99 (or shedding)
//!   blows the SLO and drains them when the fleet runs cold, under one
//!   of two [`AutoscalePolicy`] flavors — reactive
//!   (threshold + hysteresis on the last observation window) or
//!   predictive (a windowed arrival-rate estimator sizes the fleet for
//!   the load it *expects*);
//! * a **shard rebalancer** migrates hot shards between servers, where
//!   the migration ships the shard's bytes over the rack link and the
//!   shard is unavailable on the source from handoff until the transfer
//!   drains at the destination — the simulator prices the cure as well
//!   as the disease;
//! * **draining** servers take no new work but finish every in-flight
//!   request before leaving, so elasticity never loses a request
//!   (conservation through joins/drains is property-tested in
//!   `tests/chaos.rs`).
//!
//! `autoscale: None` (the default) contributes nothing to the serving
//! event race and mutates no state — the bit-identical static path.
//! The whole elastic layer draws **no RNG**: every decision is a pure
//! function of observed simulation state, so elastic runs reproduce
//! bit-for-bit from the seed like everything else.

use crate::cluster::fleet::FleetConfig;

/// When the autoscaler decides to resize (the ablation axis of fig12).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum AutoscalePolicy {
    /// Threshold + hysteresis on the last observation window: scale up
    /// one server when the window's p99 blew the SLO (or anything was
    /// shed), scale down one when the window ran comfortably cold.
    Reactive,
    /// Windowed arrival-rate estimator: blend the observed window rate
    /// into an EWMA over `estimator_window_s` and size the fleet for
    /// `rate / (per_server_rate × target_util)` directly — multiple
    /// joins in one step when a flash crowd hits.
    #[default]
    Predictive,
}

impl AutoscalePolicy {
    /// Stable lowercase name used by the CLI, TOML configs and reports.
    pub fn name(&self) -> &'static str {
        match self {
            AutoscalePolicy::Reactive => "reactive",
            AutoscalePolicy::Predictive => "predictive",
        }
    }

    pub fn all() -> [AutoscalePolicy; 2] {
        [AutoscalePolicy::Reactive, AutoscalePolicy::Predictive]
    }
}

/// Parse an autoscale policy name from config/CLI.
pub fn parse_autoscale_policy(name: &str) -> anyhow::Result<AutoscalePolicy> {
    match name {
        "reactive" | "threshold" => Ok(AutoscalePolicy::Reactive),
        "predictive" | "estimator" => Ok(AutoscalePolicy::Predictive),
        other => anyhow::bail!(
            "unknown autoscale policy '{other}' (expected reactive|predictive)"
        ),
    }
}

/// Elastic-fleet knobs for one serving run. Defaults are the fig12
/// operating point; every field is validated by
/// [`AutoscaleConfig::validate`] before serving starts.
#[derive(Clone, Debug, PartialEq)]
pub struct AutoscaleConfig {
    /// Resize-decision policy (the fig12 ablation axis).
    pub policy: AutoscalePolicy,
    /// Fleet-size floor: the autoscaler never drains below this.
    pub min_servers: usize,
    /// Fleet-size ceiling: shards and engines are provisioned for this
    /// many servers up front; joins activate them.
    pub max_servers: usize,
    /// Seconds between autoscaler evaluations (the observation window).
    pub check_interval_s: f64,
    /// Scale-down hysteresis in (0,1): a server drains only when the
    /// window's p99 stayed under `(1 − hysteresis) × SLO` — the dead
    /// band that keeps reactive scaling from oscillating.
    pub hysteresis: f64,
    /// Predictive estimator memory (s): the EWMA over observed arrival
    /// rates spans roughly this window.
    pub estimator_window_s: f64,
    /// Target per-server utilization in (0,1]: the predictive policy
    /// sizes the fleet so each active server runs at this fraction of
    /// its nominal rate, and the reactive policy refuses to drain while
    /// the shrunken fleet would exceed it.
    pub target_util: f64,
    /// Arm the mid-run shard rebalancer (migrates hot shards off the
    /// most-routed server when its window share exceeds the threshold).
    pub rebalance: bool,
    /// Rebalance trigger in (0,1]: the hottest server's share of
    /// window-routed requests that starts a migration. 1.0 never fires.
    pub rebalance_threshold: f64,
    /// Routable shards the corpus is split into. More shards = finer
    /// migration granularity but smaller (cheaper) transfers.
    pub shards: usize,
}

impl Default for AutoscaleConfig {
    fn default() -> Self {
        AutoscaleConfig {
            policy: AutoscalePolicy::Predictive,
            min_servers: 1,
            max_servers: 8,
            check_interval_s: 1.0,
            hysteresis: 0.25,
            estimator_window_s: 10.0,
            target_util: 0.8,
            rebalance: true,
            rebalance_threshold: 0.55,
            shards: 32,
        }
    }
}

impl AutoscaleConfig {
    /// Validate every knob against the fleet it will drive — the
    /// ISSUE-10 satellite. Called at TOML parse (against the `[fleet]`
    /// section) and again by `serve_fleet` (against the final fleet),
    /// so CLI-layered overrides cannot sneak past it.
    pub fn validate(&self, fcfg: &FleetConfig) -> anyhow::Result<()> {
        anyhow::ensure!(self.min_servers >= 1, "autoscale.min_servers must be >= 1");
        anyhow::ensure!(
            self.min_servers <= self.max_servers,
            "autoscale.min_servers ({}) exceeds autoscale.max_servers ({})",
            self.min_servers,
            self.max_servers
        );
        anyhow::ensure!(
            self.check_interval_s > 0.0 && self.check_interval_s.is_finite(),
            "autoscale.check_interval_s must be positive and finite"
        );
        anyhow::ensure!(
            self.hysteresis > 0.0 && self.hysteresis < 1.0,
            "autoscale.hysteresis must be in (0,1): got {}",
            self.hysteresis
        );
        anyhow::ensure!(
            self.estimator_window_s > 0.0 && self.estimator_window_s.is_finite(),
            "autoscale.estimator_window_s must be positive and finite"
        );
        anyhow::ensure!(
            self.target_util > 0.0 && self.target_util <= 1.0,
            "autoscale.target_util must be in (0,1]: got {}",
            self.target_util
        );
        anyhow::ensure!(
            self.rebalance_threshold > 0.0 && self.rebalance_threshold <= 1.0,
            "autoscale.rebalance_threshold must be in (0,1]: got {}",
            self.rebalance_threshold
        );
        anyhow::ensure!(
            self.shards >= self.max_servers,
            "autoscale.shards ({}) must be >= autoscale.max_servers ({}): every active \
             server needs at least one shard to serve",
            self.shards,
            self.max_servers
        );
        // Failover replicas must survive the smallest fleet the
        // autoscaler may shrink to (and so trivially fit the largest).
        anyhow::ensure!(
            fcfg.replicas == 0 || fcfg.replicas < self.min_servers,
            "fleet.replicas ({}) must be < autoscale.min_servers ({}): a drained fleet \
             must still hold every replica (max_servers is {})",
            fcfg.replicas,
            self.min_servers,
            self.max_servers
        );
        // Explicit per-server weights describe a fixed membership; a
        // time-varying fleet has no stable server list to weight.
        anyhow::ensure!(
            fcfg.weights.is_none(),
            "fleet.weights is incompatible with autoscaling: explicit per-server weights \
             assume fixed membership"
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fleet() -> FleetConfig {
        FleetConfig::default()
    }

    #[test]
    fn default_config_validates() {
        AutoscaleConfig::default().validate(&fleet()).unwrap();
    }

    #[test]
    fn rejects_min_over_max() {
        let a = AutoscaleConfig { min_servers: 5, max_servers: 4, ..AutoscaleConfig::default() };
        let e = a.validate(&fleet()).unwrap_err().to_string();
        assert!(e.contains("min_servers"), "unhelpful error: {e}");
    }

    #[test]
    fn rejects_zero_min() {
        let a = AutoscaleConfig { min_servers: 0, ..AutoscaleConfig::default() };
        assert!(a.validate(&fleet()).is_err());
    }

    #[test]
    fn rejects_bad_check_interval() {
        for bad in [0.0, -1.0, f64::NAN, f64::INFINITY] {
            let a = AutoscaleConfig { check_interval_s: bad, ..AutoscaleConfig::default() };
            assert!(a.validate(&fleet()).is_err(), "accepted interval {bad}");
        }
    }

    #[test]
    fn rejects_bad_hysteresis() {
        for bad in [0.0, -0.5, 1.0, 1.5, f64::NAN] {
            let a = AutoscaleConfig { hysteresis: bad, ..AutoscaleConfig::default() };
            assert!(a.validate(&fleet()).is_err(), "accepted hysteresis {bad}");
        }
    }

    #[test]
    fn rejects_bad_estimator_window() {
        for bad in [0.0, -2.0, f64::NAN, f64::INFINITY] {
            let a = AutoscaleConfig { estimator_window_s: bad, ..AutoscaleConfig::default() };
            assert!(a.validate(&fleet()).is_err(), "accepted window {bad}");
        }
    }

    #[test]
    fn rejects_bad_target_util() {
        for bad in [0.0, -0.1, 1.01, f64::NAN] {
            let a = AutoscaleConfig { target_util: bad, ..AutoscaleConfig::default() };
            assert!(a.validate(&fleet()).is_err(), "accepted target_util {bad}");
        }
        let ok = AutoscaleConfig { target_util: 1.0, ..AutoscaleConfig::default() };
        ok.validate(&fleet()).unwrap();
    }

    #[test]
    fn rejects_bad_rebalance_threshold() {
        for bad in [0.0, -0.3, 1.5, f64::NAN] {
            let a = AutoscaleConfig { rebalance_threshold: bad, ..AutoscaleConfig::default() };
            assert!(a.validate(&fleet()).is_err(), "accepted threshold {bad}");
        }
        let ok = AutoscaleConfig { rebalance_threshold: 1.0, ..AutoscaleConfig::default() };
        ok.validate(&fleet()).unwrap();
    }

    #[test]
    fn rejects_fewer_shards_than_max_servers() {
        let a = AutoscaleConfig { shards: 4, max_servers: 8, ..AutoscaleConfig::default() };
        let e = a.validate(&fleet()).unwrap_err().to_string();
        assert!(e.contains("shards"), "unhelpful error: {e}");
    }

    #[test]
    fn rejects_replicas_that_outgrow_the_floor() {
        // replicas must fit the smallest fleet (and so the largest too —
        // the ISSUE-10 "replicas > max servers" rejection falls out).
        let f = FleetConfig { replicas: 2, ..FleetConfig::default() };
        let a = AutoscaleConfig { min_servers: 2, max_servers: 8, ..AutoscaleConfig::default() };
        let e = a.validate(&f).unwrap_err().to_string();
        assert!(e.contains("replicas"), "unhelpful error: {e}");
        let ok = AutoscaleConfig { min_servers: 3, ..a };
        ok.validate(&f).unwrap();
    }

    #[test]
    fn rejects_explicit_weights() {
        let f = FleetConfig { weights: Some(vec![36, 12]), ..FleetConfig::default() };
        let e = AutoscaleConfig::default().validate(&f).unwrap_err().to_string();
        assert!(e.contains("weights"), "unhelpful error: {e}");
    }

    #[test]
    fn policy_names_round_trip() {
        for p in AutoscalePolicy::all() {
            assert_eq!(parse_autoscale_policy(p.name()).unwrap(), p);
        }
        assert!(parse_autoscale_policy("psychic").is_err());
    }
}
