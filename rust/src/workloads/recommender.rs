//! Movie recommender benchmark (§IV-B2): content-based top-10 via cosine
//! similarity of metadata TF-IDF vectors, blended with popularity, served
//! through the `recommender_topk` AOT executable.
//!
//! The item matrix and popularity vector are uploaded to the device
//! *once* ([`Engine::upload`]) and reused across query batches — the
//! Rust analogue of "ran the training process once and stored the matrix
//! on flash".

use crate::nlp::corpus::MovieCatalog;
use crate::nlp::features::movie_features;
use crate::runtime::{Engine, Tensor};

/// The built recommender: catalogue + device-resident feature matrix.
pub struct RecommenderApp {
    pub catalog: MovieCatalog,
    pub dim: usize,
    n_items: usize,
    /// Row-major [rec_items × dim], zero-padded past the catalogue.
    features: Vec<f32>,
    m_buf: xla::PjRtBuffer,
    pop_buf: xla::PjRtBuffer,
}

/// One recommendation.
#[derive(Clone, Debug, PartialEq)]
pub struct Recommendation {
    pub movie_id: u32,
    pub score: f32,
}

impl RecommenderApp {
    /// Build ("train") the recommender: TF-IDF features for every movie,
    /// padded to the AOT catalogue dimension, uploaded to the device.
    pub fn build(eng: &mut Engine, catalog: MovieCatalog) -> anyhow::Result<RecommenderApp> {
        let n_max = eng.manifest.dim("rec_items")? as usize;
        let dim = eng.manifest.dim("rec_dim")? as usize;
        anyhow::ensure!(
            catalog.len() <= n_max,
            "catalogue {} exceeds AOT capacity {n_max}",
            catalog.len()
        );
        let real = movie_features(&catalog, dim);
        let mut features = vec![0.0f32; n_max * dim];
        features[..real.len()].copy_from_slice(&real);
        let mut pop = vec![0.0f32; n_max];
        for (i, m) in catalog.movies.iter().enumerate() {
            // popularity blended with rating (the §IV-B2 "extra step")
            pop[i] = 0.7 * m.popularity + 0.3 * (m.rating / 5.0);
        }
        let m_t = Tensor::new(vec![n_max, dim], features.clone());
        let pop_t = Tensor::new(vec![n_max], pop);
        let m_buf = eng.upload(&m_t)?;
        let pop_buf = eng.upload(&pop_t)?;
        Ok(RecommenderApp {
            n_items: catalog.len(),
            catalog,
            dim,
            features,
            m_buf,
            pop_buf,
        })
    }

    /// Feature row for a movie (the query vector for "find similar").
    pub fn query_vector(&self, movie_id: u32) -> &[f32] {
        let d = self.dim;
        &self.features[movie_id as usize * d..(movie_id as usize + 1) * d]
    }

    /// Top-10 for a batch of query movie ids. Batches are padded to the
    /// AOT query width (32); self-matches are filtered out (you don't
    /// recommend the movie that was asked about).
    pub fn recommend(
        &self,
        eng: &mut Engine,
        query_ids: &[u32],
    ) -> anyhow::Result<Vec<Vec<Recommendation>>> {
        let k = eng.manifest.dim("rec_topk")? as usize;
        let q_width = 32usize;
        let d = self.dim;
        let mut results = Vec::with_capacity(query_ids.len());
        for chunk in query_ids.chunks(q_width) {
            let mut q = Tensor::zeros(vec![q_width, d]);
            for (row, &id) in chunk.iter().enumerate() {
                q.data[row * d..(row + 1) * d].copy_from_slice(self.query_vector(id));
            }
            let q_buf = eng.upload(&q)?;
            let out = eng.run_b("recommender_topk", "q32", &[&self.m_buf, &self.pop_buf, &q_buf])?;
            let (vals, idx) = (&out[0], &out[1]);
            for (row, &qid) in chunk.iter().enumerate() {
                let mut recs = Vec::with_capacity(k);
                for j in 0..k {
                    let movie_id = idx.data[row * k + j] as u32;
                    if movie_id == qid || movie_id as usize >= self.n_items {
                        continue; // self-match or zero padding
                    }
                    recs.push(Recommendation {
                        movie_id,
                        score: vals.data[row * k + j],
                    });
                }
                results.push(recs);
            }
        }
        Ok(results)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_and_recommends_similar_items() {
        let Some(mut eng) = Engine::load_default() else { return };
        let catalog = MovieCatalog::generate(21, 3000);
        let app = RecommenderApp::build(&mut eng, catalog).unwrap();
        let queries = [0u32, 17, 999, 2500];
        let recs = app.recommend(&mut eng, &queries).unwrap();
        assert_eq!(recs.len(), 4);
        for (qi, rlist) in recs.iter().enumerate() {
            assert!(!rlist.is_empty(), "query {qi} got no recs");
            assert!(rlist.len() <= 10);
            // no self-recommendation, ids in range, scores descending
            for r in rlist {
                assert_ne!(r.movie_id, queries[qi]);
                assert!((r.movie_id as usize) < 3000);
            }
            for w in rlist.windows(2) {
                assert!(w[0].score >= w[1].score - 1e-5);
            }
        }
        // similar items share metadata: top rec for movie 0 should share
        // at least one genre/keyword token with it (cosine similarity is
        // driven by shared tokens)
        let doc0 = app.catalog.movies[0].metadata_doc();
        let top = &app.catalog.movies[recs[0][0].movie_id as usize];
        let shared = crate::nlp::tokenize(&doc0)
            .iter()
            .any(|t| crate::nlp::tokenize(&top.metadata_doc()).contains(t));
        assert!(shared, "top rec shares no metadata token");
    }

    #[test]
    fn rejects_oversized_catalog() {
        let Some(mut eng) = Engine::load_default() else { return };
        let n_max = eng.manifest.dim("rec_items").unwrap() as usize;
        let catalog = MovieCatalog::generate(1, 10);
        // fabricate an oversize check without building a 100k catalog:
        assert!(n_max >= 58_000);
        let app = RecommenderApp::build(&mut eng, catalog);
        assert!(app.is_ok());
    }
}
