//! Single-server serving engine: an arrival-fed frontend over the
//! *batch* scheduler's dispatch state machine.
//!
//! The engine owns a [`SchedState`] whose shards start **empty**:
//! arriving requests are routed round-robin to the drive holding their
//! data (`id % drives`), incrementing that drive's `shard_remaining`,
//! and the engine then invokes the exact same
//! [`SchedState::dispatch_host`] / [`SchedState::dispatch_csds`] bodies
//! the batch runner uses — flash reads, DLM locks, tunnel messages and
//! batch overheads are all modeled by the code that produced every
//! batch-mode figure, never re-implemented here.
//!
//! What the frontend adds on top:
//!
//! * **batch formation (size-or-timeout)** — dispatch is gated until
//!   either `min_batch` requests are queued or the oldest has waited
//!   `batch_timeout_s`. `min_batch = 1` (the default) dispatches
//!   immediately: latency-optimal, at the cost of per-batch overhead
//!   amortization — the knob Fig 9's batching ablation turns.
//! * **dispatch timing** — both [`DispatchMode`]s are honored.
//!   `Polling` quantizes dispatch to the paper's wake grid (arrivals
//!   wait for the next grid point — the dispatch-latency tax the CSD
//!   survey calls out); `EventDriven` dispatches on every arrival and
//!   ack, subject only to the formation gate.
//! * **per-request latency** — the engine remembers which queued
//!   requests each dispatched batch consumed (FIFO per drive, so the
//!   diff of `shard_remaining` around a dispatch call identifies them)
//!   and emits a [`Completion`] per request when the batch's ack pops.
//!
//! The engine's corpus is resident before serving starts: each drive is
//! ingested with a circular window of the dataset sized to cover the
//! largest possible single-dispatch read, and read offsets wrap so a
//! serving run of any length reads only resident bytes.

use std::collections::VecDeque;

use crate::cluster::StorageServer;
use crate::csd::CsdConfig;
use crate::metrics::Metrics;
use crate::sched::{DispatchMode, Ev, SchedConfig, SchedState, SHARD};
use crate::sim::EventQueue;
use crate::workloads::AppModel;

/// One served request: issue id, frontend arrival instant, and the
/// instant its batch's result reached the frontend (all on the engine's
/// absolute clock).
#[derive(Clone, Copy, Debug)]
pub(crate) struct Completion {
    pub id: u64,
    pub arrival: f64,
    pub done: f64,
}

/// A queued request awaiting dispatch.
#[derive(Clone, Copy, Debug)]
struct Queued {
    id: u64,
    arrival: f64,
}

/// Batch-formation policy: release queued work to the scheduler when
/// either `min_batch` requests are waiting or the oldest has waited
/// `timeout_s`.
#[derive(Clone, Copy, Debug)]
pub struct FormationPolicy {
    pub min_batch: u64,
    pub timeout_s: f64,
}

impl Default for FormationPolicy {
    fn default() -> Self {
        // Dispatch immediately: latency-optimal serving. Raising
        // `min_batch` trades first-request wait for per-batch overhead
        // amortization (bounded by `timeout_s`).
        FormationPolicy { min_batch: 1, timeout_s: 0.05 }
    }
}

pub(crate) struct ServeEngine<'a> {
    st: SchedState<'a>,
    q: EventQueue<Ev>,
    metrics: Metrics,
    formation: FormationPolicy,
    event_driven: bool,
    /// Serving clock origin (corpus resident).
    t0: f64,
    /// Per-drive FIFO of queued requests (arrival order). A dispatch
    /// consumes from the front — the scheduler takes the oldest items of
    /// each shard.
    pending: Vec<VecDeque<Queued>>,
    queued: u64,
    /// Requests inside the in-flight host batch (at most one exists).
    host_inflight: Vec<Queued>,
    /// Requests inside each drive's in-flight CSD batch.
    csd_inflight: Vec<Vec<Queued>>,
    /// Next wake-grid point (polling mode; consumed only while work is
    /// queued, walked forward over idle stretches).
    next_wake: f64,
    /// Pending formation-timeout flush (event-driven mode only).
    flush_at: Option<f64>,
    /// Scratch: shard occupancy before a dispatch call, for the diff.
    prev_remaining: Vec<u64>,
    /// Round-robin data-placement cursor.
    route_next: usize,
    /// Bytes of resident corpus per drive; read offsets wrap below it.
    corpus_bytes: u64,
    /// Largest single-dispatch read; offsets wrap once they pass
    /// `corpus_bytes - max_read_bytes`.
    max_read_bytes: u64,
    completions: Vec<Completion>,
}

impl<'a> ServeEngine<'a> {
    pub(crate) fn new(
        model: &'a AppModel,
        cfg: &'a SchedConfig,
        formation: FormationPolicy,
    ) -> anyhow::Result<ServeEngine<'a>> {
        anyhow::ensure!(cfg.drives > 0, "need at least one drive for data");
        anyhow::ensure!(cfg.isp_drives <= cfg.drives, "isp_drives exceeds drives");
        anyhow::ensure!(cfg.use_host || cfg.use_isp(), "no compute nodes enabled");
        anyhow::ensure!(
            cfg.wakeup_secs > 0.0 && cfg.wakeup_secs.is_finite(),
            "wakeup_secs must be positive and finite, got {}",
            cfg.wakeup_secs
        );
        anyhow::ensure!(formation.min_batch >= 1, "min_batch must be >= 1");
        anyhow::ensure!(
            formation.timeout_s >= 0.0 && formation.timeout_s.is_finite(),
            "batch timeout must be non-negative and finite, got {}",
            formation.timeout_s
        );
        let mut server = StorageServer::new(cfg.drives, CsdConfig::default());

        // Resident corpus: a circular per-drive window twice the largest
        // single-dispatch read, so offsets always have room before the
        // wrap point.
        let max_read_bytes =
            (cfg.host_batch().max(cfg.csd_batch) * model.bytes_per_item).max(1);
        let corpus_bytes = 2 * max_read_bytes;
        let mut t0 = 0.0f64;
        for d in 0..cfg.drives {
            t0 = t0.max(server.ingest(0.0, d, SHARD, corpus_bytes)?);
        }

        let mut metrics = Metrics::new();
        let st = SchedState::new(model, cfg, server, vec![0; cfg.drives], t0, &mut metrics);
        Ok(ServeEngine {
            event_driven: cfg.dispatch == DispatchMode::EventDriven,
            q: EventQueue::new(),
            metrics,
            formation,
            t0,
            pending: (0..cfg.drives).map(|_| VecDeque::new()).collect(),
            queued: 0,
            host_inflight: Vec::new(),
            csd_inflight: vec![Vec::new(); cfg.drives],
            next_wake: t0,
            flush_at: None,
            prev_remaining: vec![0; cfg.drives],
            route_next: 0,
            corpus_bytes,
            max_read_bytes,
            completions: Vec::new(),
            st,
        })
    }

    /// Serving clock origin: the instant the resident corpus is in
    /// place. Drivers offset generator timelines by this.
    pub(crate) fn t0(&self) -> f64 {
        self.t0
    }

    pub(crate) fn state(&self) -> &SchedState<'a> {
        &self.st
    }

    /// The engine's private metrics registry (batch-latency histograms
    /// recorded by the shared dispatch bodies) — merged into the
    /// caller's registry when the run ends.
    pub(crate) fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// Completed requests since the last call (order: completion order).
    pub(crate) fn take_completions(&mut self) -> Vec<Completion> {
        std::mem::take(&mut self.completions)
    }

    /// Earliest instant at which this engine has internal work to do.
    pub(crate) fn next_time(&self) -> Option<f64> {
        let mut t = f64::INFINITY;
        if let Some(tq) = self.q.peek_time() {
            t = t.min(tq);
        }
        if !self.event_driven && self.queued > 0 {
            t = t.min(self.next_wake);
        }
        if let Some(tf) = self.flush_at {
            t = t.min(tf);
        }
        t.is_finite().then_some(t)
    }

    /// Accept one request at absolute time `now` (must be ≥ every
    /// previously processed instant — the driver advances global time
    /// monotonically).
    pub(crate) fn offer(&mut self, now: f64, id: u64) -> anyhow::Result<()> {
        // With the host disabled only ISP drives can serve, so requests
        // are placed only on them (a request on a host-less non-ISP
        // drive could never be dispatched).
        let routable = if self.st.cfg.use_host {
            self.st.cfg.drives
        } else {
            self.st.cfg.isp_drives
        };
        let d = self.route_next % routable;
        self.route_next += 1;
        self.pending[d].push_back(Queued { id, arrival: now });
        self.st.shard_remaining[d] += 1;
        self.st.total_remaining += 1;
        self.queued += 1;
        // A drained drive was retired from the idle index (batch-mode
        // shards never refill); a request landing on it re-opens it.
        if d < self.st.cfg.isp_drives && self.csd_inflight[d].is_empty() {
            self.st.idle_isp.insert(d);
        }
        if self.event_driven {
            self.try_dispatch(now, false)?;
        } else {
            // Polling: the request waits for the wake grid. Walk the
            // grid cursor past any idle stretch so the next consumed
            // wake is the first grid point at or after this arrival.
            while self.next_wake < now {
                self.next_wake += self.st.cfg.wakeup_secs;
            }
        }
        Ok(())
    }

    /// Process exactly one internal event (the one at
    /// [`ServeEngine::next_time`]). Sched-queue events win ties — acks
    /// mutate node state before any same-instant dispatch runs, matching
    /// the batch runner's calendar order.
    pub(crate) fn step(&mut self) -> anyhow::Result<()> {
        let tq = self.q.peek_time().unwrap_or(f64::INFINITY);
        let tw = if !self.event_driven && self.queued > 0 {
            self.next_wake
        } else {
            f64::INFINITY
        };
        let tf = self.flush_at.unwrap_or(f64::INFINITY);
        if tq <= tw && tq <= tf {
            let (now, ev) = self.q.pop().expect("peeked event");
            match ev {
                Ev::HostDone { items, dispatched } => {
                    self.st.host_done(now, items, dispatched, &mut self.metrics);
                    debug_assert_eq!(self.host_inflight.len() as u64, items);
                    for r in std::mem::take(&mut self.host_inflight) {
                        self.completions.push(Completion { id: r.id, arrival: r.arrival, done: now });
                    }
                    if self.event_driven {
                        self.try_dispatch(now, false)?;
                    }
                }
                Ev::CsdAck { drive, items, dispatched } => {
                    self.st.csd_ack(now, drive, items, dispatched, &mut self.metrics);
                    debug_assert_eq!(self.csd_inflight[drive].len() as u64, items);
                    for r in std::mem::take(&mut self.csd_inflight[drive]) {
                        self.completions.push(Completion { id: r.id, arrival: r.arrival, done: now });
                    }
                    if self.event_driven {
                        self.try_dispatch(now, false)?;
                    }
                }
                // Serving always dispatches CSDs with `coalesce = false`
                // and never schedules wakes on the sched queue.
                Ev::CsdAckBatch { .. } | Ev::Wake => {
                    unreachable!("batch-mode-only event in serving engine")
                }
            }
        } else if tw <= tf {
            // Wake-grid point (polling): the grid is both the dispatch
            // clock and the formation timeout check.
            let now = self.next_wake;
            self.next_wake += self.st.cfg.wakeup_secs;
            self.try_dispatch(now, false)?;
        } else {
            // Formation timeout (event-driven): the oldest queued
            // request has waited long enough — force the batch out.
            let now = self.flush_at.take().expect("flush deadline");
            self.try_dispatch(now, true)?;
        }
        Ok(())
    }

    /// Oldest queued arrival across all drives (None when empty).
    fn oldest_arrival(&self) -> Option<f64> {
        self.pending
            .iter()
            .filter_map(|dq| dq.front().map(|r| r.arrival))
            .min_by(f64::total_cmp)
    }

    /// The size-or-timeout gate: release queued work when enough has
    /// accumulated or the head of the queue has waited out the timeout.
    fn gate_open(&self, now: f64) -> bool {
        if self.queued == 0 {
            return false;
        }
        if self.queued >= self.formation.min_batch {
            return true;
        }
        match self.oldest_arrival() {
            // Written as `now >= t + timeout` — the exact float
            // expression the flush deadline is computed with — so a
            // flush firing at its own deadline always finds the gate
            // open (no same-instant re-arm loop).
            Some(t) => now >= t + self.formation.timeout_s,
            None => false,
        }
    }

    /// Run the shared dispatch bodies (host first, then CSDs — the batch
    /// runner's wake order), map consumed shard items back to queued
    /// requests, and re-arm the formation flush if work stays queued.
    fn try_dispatch(&mut self, now: f64, force: bool) -> anyhow::Result<()> {
        if force || self.gate_open(now) {
            self.prev_remaining.copy_from_slice(&self.st.shard_remaining);
            self.st.dispatch_host(now, &mut self.q)?;
            self.collect_taken(true);
            self.wrap_offsets();

            self.prev_remaining.copy_from_slice(&self.st.shard_remaining);
            self.st.dispatch_csds(now, &mut self.q, false)?;
            self.collect_taken(false);
            self.wrap_offsets();
        }
        // Re-arm the formation timeout: in event-driven mode a closed
        // gate with queued work must still fire on its own.
        self.flush_at = if self.event_driven && self.queued > 0 && !self.gate_open(now) {
            self.oldest_arrival().map(|t| t + self.formation.timeout_s)
        } else {
            None
        };
        Ok(())
    }

    /// Diff shard occupancy around a dispatch call and move the consumed
    /// requests (FIFO per drive) into the matching in-flight set.
    fn collect_taken(&mut self, host: bool) {
        for d in 0..self.st.cfg.drives {
            let taken = self.prev_remaining[d] - self.st.shard_remaining[d];
            for _ in 0..taken {
                let r = self.pending[d].pop_front().expect("dispatch consumed a queued request");
                if host {
                    self.host_inflight.push(r);
                } else {
                    self.csd_inflight[d].push(r);
                }
            }
            self.queued -= taken;
        }
    }

    /// Wrap read cursors so the next dispatch's largest possible read
    /// stays inside the resident corpus window (circular re-read of
    /// resident data — serving reads the same stored dataset forever).
    fn wrap_offsets(&mut self) {
        for off in &mut self.st.shard_offset {
            if *off + self.max_read_bytes > self.corpus_bytes {
                *off = 0;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::App;

    fn engine_cfg(dispatch: DispatchMode) -> SchedConfig {
        SchedConfig {
            csd_batch: 500,
            batch_ratio: 26.0,
            drives: 4,
            isp_drives: 4,
            dispatch,
            ..SchedConfig::default()
        }
    }

    /// Drive an engine by hand: `n` requests at fixed spacing; every
    /// request must complete exactly once, in both dispatch modes.
    #[test]
    fn engine_serves_every_request_exactly_once() {
        for dispatch in [DispatchMode::Polling, DispatchMode::EventDriven] {
            let model = AppModel::for_app(App::Sentiment, 1_000);
            let cfg = engine_cfg(dispatch);
            let mut e = ServeEngine::new(&model, &cfg, FormationPolicy::default()).unwrap();
            let t0 = e.t0();
            let n: u64 = 1_000;
            let mut next_arrival = 0u64;
            let mut done = std::collections::BTreeSet::new();
            loop {
                let ta = (next_arrival < n).then(|| t0 + next_arrival as f64 * 1e-4);
                match (ta, e.next_time()) {
                    (Some(a), Some(t)) if a <= t => {
                        e.offer(a, next_arrival).unwrap();
                        next_arrival += 1;
                    }
                    (Some(a), None) => {
                        e.offer(a, next_arrival).unwrap();
                        next_arrival += 1;
                    }
                    (_, Some(_)) => e.step().unwrap(),
                    (None, None) => break,
                }
                for c in e.take_completions() {
                    assert!(c.done >= c.arrival, "{dispatch:?}: time travel");
                    assert!(done.insert(c.id), "{dispatch:?}: duplicate completion {}", c.id);
                }
            }
            assert_eq!(done.len() as u64, n, "{dispatch:?}: every request served once");
            assert_eq!(e.state().host_items + e.state().csd_items, n);
        }
    }

    #[test]
    fn host_less_engine_places_requests_only_on_isp_drives() {
        // Regression: with use_host = false and isp_drives < drives,
        // round-robin placement over *all* drives would park requests on
        // drives nothing can dispatch (polling would wake forever,
        // event-driven would lose requests). Placement is restricted to
        // the drives that can actually serve.
        let model = AppModel::for_app(App::Sentiment, 200);
        let cfg = SchedConfig {
            csd_batch: 50,
            drives: 4,
            isp_drives: 2,
            use_host: false,
            dispatch: DispatchMode::EventDriven,
            ..SchedConfig::default()
        };
        let mut e = ServeEngine::new(&model, &cfg, FormationPolicy::default()).unwrap();
        let t0 = e.t0();
        for i in 0..200u64 {
            e.offer(t0 + i as f64 * 1e-3, i).unwrap();
            while let Some(t) = e.next_time() {
                if t > t0 + (i + 1) as f64 * 1e-3 {
                    break;
                }
                e.step().unwrap();
            }
        }
        let mut served = e.take_completions().len();
        while e.next_time().is_some() {
            e.step().unwrap();
            served += e.take_completions().len();
        }
        assert_eq!(served, 200, "every request lands on a dispatchable drive");
        assert_eq!(e.state().csd_items, 200);
        assert_eq!(e.state().host_items, 0);
    }

    #[test]
    fn formation_gate_holds_small_batches_until_timeout() {
        let model = AppModel::for_app(App::Sentiment, 100);
        let cfg = engine_cfg(DispatchMode::EventDriven);
        let formation = FormationPolicy { min_batch: 50, timeout_s: 0.5 };
        let mut e = ServeEngine::new(&model, &cfg, formation).unwrap();
        let t0 = e.t0();
        e.offer(t0, 0).unwrap();
        // Below min_batch: nothing dispatched, a flush is armed instead.
        assert!(e.host_inflight.is_empty() && e.queued == 1);
        let flush = e.next_time().expect("flush deadline pending");
        assert!((flush - (t0 + 0.5)).abs() < 1e-12, "flush at arrival + timeout");
        // The flush forces the lone request out; it completes.
        let mut served = 0;
        while e.next_time().is_some() {
            e.step().unwrap();
            served += e.take_completions().len();
        }
        assert_eq!(served, 1);
    }

    #[test]
    fn polling_engine_quantizes_dispatch_to_the_grid() {
        let model = AppModel::for_app(App::Sentiment, 100);
        let cfg = engine_cfg(DispatchMode::Polling);
        let mut e = ServeEngine::new(&model, &cfg, FormationPolicy::default()).unwrap();
        let t0 = e.t0();
        // Arrive just after a grid point: the request waits ~one period.
        e.offer(t0 + 0.01, 0).unwrap();
        let wake = e.next_time().unwrap();
        assert!(wake >= t0 + cfg.wakeup_secs - 1e-12, "dispatch waits for the grid: {wake}");
        let mut comps = Vec::new();
        while e.next_time().is_some() {
            e.step().unwrap();
            comps.extend(e.take_completions());
        }
        assert_eq!(comps.len(), 1);
        // Latency includes the grid wait the event-driven engine avoids.
        assert!(comps[0].done - comps[0].arrival >= cfg.wakeup_secs - 0.01 - 1e-12);
    }
}
