//! `cargo bench --bench fleet_scaleout` — regenerates Fig 8: fleet
//! scale-out, 1→8 storage servers × three fleet shapes × three apps
//! (the ISSUE-3 tentpole). See `cluster::fleet` for the topology model.
//!
//! Scale with `SOLANA_BENCH_FAST=1` (5%) or default 25% of the paper's
//! dataset sizes; the *shape* (near-linear all-CSD scaling, SSD-half
//! stragglers capping the mixed fleet) is scale-invariant above the
//! polling-grid floor.

use solana_isp::bench_support::Bencher;
use solana_isp::exp::{self, Scale};

fn main() -> anyhow::Result<()> {
    let scale = Scale::from_env();
    let table = exp::fig8_scaleout(scale)?;
    exp::emit(&table, "fig8")?;
    // Wall-time of regenerating the artifact (simulator throughput):
    let mut b = Bencher::new(0, if std::env::var("SOLANA_BENCH_FAST").is_ok() { 1 } else { 2 });
    b.bench("fig8_scaleout", || {
        let t = exp::fig8_scaleout(scale).expect("rerun");
        t.rows.len() as u64
    });
    print!("{}", b.report());
    b.write_json("fleet_scaleout")?;
    Ok(())
}
