// Positive fixture for the bad-marker meta-rule: a marker without a
// reason is itself a (unsuppressable) finding, and the underlying
// no-unwrap finding still fires.
pub fn f(v: &[u64]) -> u64 {
    // solana-lint: allow(no-unwrap)
    *v.first().unwrap()
}
