"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes and dtypes; assert_allclose against ref.  This is
the CORE correctness signal for the compute layer — everything the rust
runtime executes flows through these kernels.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import matmul, ref, similarity
from compile.kernels.matmul import (
    mxu_utilization_estimate,
    vmem_footprint,
)

settings.register_profile("kernels", deadline=None, max_examples=25)
settings.load_profile("kernels")


def rand(rng, *shape, dtype=np.float32):
    return rng.standard_normal(shape).astype(dtype)


dims = st.integers(min_value=1, max_value=300)


@given(m=dims, k=dims, o=st.integers(1, 64))
def test_matmul_matches_ref_shapes(m, k, o):
    rng = np.random.default_rng(m * 1000 + k * 10 + o)
    x, w = rand(rng, m, k), rand(rng, k, o)
    out = matmul(jnp.asarray(x), jnp.asarray(w))
    np.testing.assert_allclose(out, ref.matmul(x, w), rtol=1e-4, atol=1e-4)
    assert out.shape == (m, o)
    assert out.dtype == jnp.float32


@given(
    m=st.integers(1, 128),
    k=st.integers(1, 128),
    dtype=st.sampled_from([np.float32, jnp.bfloat16]),
)
def test_matmul_dtypes_accumulate_f32(m, k, dtype):
    rng = np.random.default_rng(7)
    x = rng.standard_normal((m, k)).astype(dtype)
    w = rng.standard_normal((k, 8)).astype(dtype)
    out = matmul(jnp.asarray(x), jnp.asarray(w))
    assert out.dtype == jnp.float32
    expect = ref.matmul(jnp.asarray(x), jnp.asarray(w))
    tol = 1e-4 if dtype == np.float32 else 5e-2
    np.testing.assert_allclose(out, expect, rtol=tol, atol=tol)


@given(
    bm=st.sampled_from([8, 32, 128]),
    bk=st.sampled_from([16, 128, 512]),
)
def test_matmul_block_shape_invariance(bm, bk):
    """Tiling must never change the numbers (beyond fp reassociation)."""
    rng = np.random.default_rng(3)
    x, w = rand(rng, 100, 200), rand(rng, 200, 30)
    base = ref.matmul(x, w)
    out = matmul(jnp.asarray(x), jnp.asarray(w), block_m=bm, block_o=16, block_k=bk)
    np.testing.assert_allclose(out, base, rtol=1e-4, atol=1e-4)


@given(n=st.integers(1, 500), d=st.integers(1, 128))
def test_similarity_matches_ref(n, d):
    rng = np.random.default_rng(n * 7 + d)
    m, q = rand(rng, n, d), rand(rng, d)
    out = similarity(jnp.asarray(m), jnp.asarray(q))
    np.testing.assert_allclose(out, ref.similarity(m, q), rtol=1e-4, atol=1e-4)
    assert out.shape == (n,)


def test_matmul_exact_on_integers():
    """f32 matmul on small integers is exact — catches tile-boundary
    double-count/omission bugs precisely."""
    rng = np.random.default_rng(0)
    x = rng.integers(-3, 4, size=(257, 513)).astype(np.float32)
    w = rng.integers(-3, 4, size=(513, 129)).astype(np.float32)
    out = np.asarray(matmul(jnp.asarray(x), jnp.asarray(w)))
    np.testing.assert_array_equal(out, x @ w)


def test_zero_and_identity():
    x = np.zeros((64, 64), np.float32)
    assert float(np.abs(np.asarray(matmul(jnp.asarray(x), jnp.asarray(x)))).max()) == 0.0
    eye = np.eye(64, dtype=np.float32)
    rng = np.random.default_rng(1)
    w = rand(rng, 64, 64)
    np.testing.assert_allclose(matmul(jnp.asarray(eye), jnp.asarray(w)), w, rtol=1e-6)


def test_cosine_scores_self_similarity():
    rng = np.random.default_rng(2)
    m = rand(rng, 50, 16)
    s = ref.cosine_scores(jnp.asarray(m), jnp.asarray(m[17]))
    assert int(np.argmax(np.asarray(s))) == 17
    assert np.asarray(s)[17] == pytest.approx(1.0, abs=1e-5)


# ---- structural (L1 perf) checks: VMEM footprint + MXU estimates -------

def test_default_blocks_fit_vmem_budget():
    # double-buffered default tiles must fit 16 MiB VMEM
    assert 2 * vmem_footprint() <= 16 * 1024 * 1024


def test_mxu_estimate_full_tiles():
    assert mxu_utilization_estimate(1280, 4096, 1280) == pytest.approx(1.0)
    # tiny matrices waste lanes
    assert mxu_utilization_estimate(8, 64, 8) < 0.02


def test_footprint_scales_with_blocks():
    small = vmem_footprint(block_m=32, block_o=32, block_k=128)
    big = vmem_footprint(block_m=256, block_o=256, block_k=512)
    assert small < big
