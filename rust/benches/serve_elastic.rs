//! `cargo bench --bench serve_elastic` — regenerates Fig 12: the
//! elastic-fleet study (reactive and predictive autoscaling plus the
//! mid-run shard rebalancer serving a load ramp and a flash crowd,
//! against the best static fleet chosen fig10-style for the same
//! traffic; the ISSUE-10 tentpole). Serving runs use the control plane
//! as deployed — admission on, least-work balancing — and every shard
//! migration ships real bytes over the rack link. See
//! `traffic::elastic` for the autoscaler/rebalancer and
//! `exp::fig12_elastic` for the sweep definition.
//!
//! Scale with `SOLANA_BENCH_FAST=1` (5%) or default 25% of the paper's
//! dataset sizes; the *shape* (the elastic fleet meeting the p99 SLO
//! with strictly fewer server-seconds than the best static fleet) is
//! scale-invariant.

use solana_isp::bench_support::Bencher;
use solana_isp::exp::{self, Scale};

fn main() -> anyhow::Result<()> {
    let scale = Scale::from_env();
    let table = exp::fig12_elastic(scale)?;
    exp::emit(&table, "fig12")?;
    // Wall-time of regenerating the artifact (simulator throughput):
    let mut b = Bencher::new(0, if std::env::var("SOLANA_BENCH_FAST").is_ok() { 1 } else { 2 });
    b.bench("fig12_serve_elastic", || {
        let t = exp::fig12_elastic(scale).expect("rerun");
        t.rows.len() as u64
    });
    print!("{}", b.report());
    b.write_json("serve_elastic")?;
    Ok(())
}
