"""L1: Pallas kernels for the NLP compute hot-spots.

The kernels here are the in-storage analogue of the paper's NEON-tiled
inner loops, re-thought for a TPU-shaped memory hierarchy: BlockSpec
expresses the HBM->VMEM streaming schedule, and an f32 VMEM scratch
accumulator plays the role of the A53's register tile.  All kernels are
lowered with ``interpret=True`` so the resulting HLO runs on any PJRT
backend (the rust runtime uses the CPU client); see DESIGN.md
§Hardware-Adaptation.
"""

from .matmul import matmul, similarity  # noqa: F401
from . import ref  # noqa: F401
