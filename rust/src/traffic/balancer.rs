//! Fleet serving: a front-door load balancer over
//! [`crate::cluster::fleet`] servers.
//!
//! One global virtual-time loop interleaves the arrival stream with
//! every server's [`ServeEngine`](super::engine::ServeEngine) — unlike
//! the batch fleet (independent per-server runs joined by a barrier),
//! serving requires a *joint* simulation because the balancer's
//! decisions depend on live cross-server state (queue depths for JSQ)
//! and responses contend on one shared rack downlink.
//!
//! Balancer policies:
//!
//! * **round-robin** — oblivious rotation; the baseline every LB paper
//!   starts from. Suffers on heterogeneous fleets (an SSD server gets
//!   the same share as a CSD server 2–3× its capacity).
//! * **weighted-by-capacity** — smooth weighted round-robin over each
//!   server's nominal service rate; the right *open-loop* split for
//!   heterogeneous fleets.
//! * **join-shortest-queue** — route to the server with the fewest
//!   outstanding requests; adapts to bursts and heterogeneity without
//!   knowing capacities.
//! * **least-work** — route to the server with the least outstanding
//!   *estimated service time*: queued requests divided by the server's
//!   nominal rate (the per-shape service estimate). On a heterogeneous
//!   fleet a queued request is not a unit of work — an SSD server's
//!   request costs ~2–3× a CSD server's — and counting requests (JSQ)
//!   systematically overloads the slow shape. Worse, under admission
//!   control a shedding server's queue *freezes* at its (lower)
//!   admission bound, so JSQ pins on it and throws away headroom the
//!   fast servers still have; least-work keeps routing by time and
//!   fills every server to its own bound (the ISSUE-5 gate test).
//!
//! Responses from non-head servers ship over the top-of-rack
//! [`RackLink`] (one message per completed batch, FIFO at the head's
//! downlink), so a request's end-to-end latency includes the rack hop
//! its placement implies.
//!
//! With admission control on (`[traffic] admission = true`), a request
//! the target server sheds is answered immediately with a rejection:
//! it contributes to `shed` (goodput loss), never to the latency
//! percentiles, and a closed-loop client that receives a rejection
//! re-arms just like one that got a real response.

use std::cmp::Reverse;
use std::collections::{BTreeMap, BinaryHeap};

use crate::cluster::fleet::{FleetConfig, ServerSpec};
use crate::faults::{FaultPlan, LinkOutcome};
use crate::interconnect::RackLink;
use crate::metrics::Metrics;
use crate::power::PowerModel;
use crate::trace::{EngineProfile, Outcome as TraceOutcome, SpanKind, Tracer};
use crate::workloads::{App, AppModel};

use super::elastic::{AutoscaleConfig, AutoscalePolicy};
use super::engine::{EnginePolicy, Offer, ServeEngine};
use super::{
    default_slo_p99, fleet_nominal_rate, FleetSample, LatencyStats, ServeReport,
    ServerServeStats, TrafficConfig,
};

/// Front-door load-balancer policy.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum LbPolicy {
    /// Oblivious rotation across servers.
    RoundRobin,
    /// Smooth weighted round-robin by nominal capacity.
    WeightedCapacity,
    /// Fewest outstanding requests wins (ties: lowest index).
    #[default]
    JoinShortestQueue,
    /// Least outstanding estimated service *time* wins (queued requests
    /// ÷ nominal rate; ties: lowest index) — the latency-aware policy.
    LeastWork,
}

impl LbPolicy {
    /// Stable lowercase name used by the CLI, TOML configs and reports.
    pub fn name(&self) -> &'static str {
        match self {
            LbPolicy::RoundRobin => "rr",
            LbPolicy::WeightedCapacity => "weighted",
            LbPolicy::JoinShortestQueue => "jsq",
            LbPolicy::LeastWork => "least-work",
        }
    }

    pub fn all() -> [LbPolicy; 4] {
        [
            LbPolicy::RoundRobin,
            LbPolicy::WeightedCapacity,
            LbPolicy::JoinShortestQueue,
            LbPolicy::LeastWork,
        ]
    }
}

/// Deterministic balancer state.
struct Balancer {
    policy: LbPolicy,
    rr_next: usize,
    assigned: Vec<u64>,
    outstanding: Vec<u64>,
    weights: Vec<f64>,
    /// Per-server nominal service rates (items/s) — the per-shape
    /// service estimate `least-work` divides outstanding counts by.
    rates: Vec<f64>,
    /// Dead-server *belief* (ISSUE-6): set after consecutive missed
    /// acks, cleared by any delivered response. All-false on a healthy
    /// run, in which every policy below takes its exact pre-chaos path.
    dead: Vec<bool>,
}

impl Balancer {
    fn new(policy: LbPolicy, weights: Vec<f64>, rates: Vec<f64>) -> Balancer {
        let n = weights.len();
        debug_assert_eq!(rates.len(), n);
        Balancer {
            policy,
            rr_next: 0,
            assigned: vec![0; n],
            outstanding: vec![0; n],
            weights,
            rates,
            dead: vec![false; n],
        }
    }

    fn pick(&mut self) -> usize {
        let n = self.weights.len();
        let any_dead = self.dead.iter().any(|&d| d);
        let s = match self.policy {
            LbPolicy::RoundRobin => {
                let mut s = self.rr_next % n;
                self.rr_next += 1;
                if any_dead {
                    // Skip believed-dead servers, advancing the
                    // rotation; all-dead falls back to the raw slot.
                    let mut hops = 0;
                    while self.dead[s] && hops < n {
                        s = self.rr_next % n;
                        self.rr_next += 1;
                        hops += 1;
                    }
                }
                s
            }
            // Smooth WRR: send the next request where the realized
            // share lags the capacity share most. A believed-dead
            // server's weight is masked to 0 (never picked while an
            // alternative exists — same convention as the engine's
            // crashed-drive fallback).
            LbPolicy::WeightedCapacity => {
                if any_dead {
                    let w: Vec<f64> = self
                        .weights
                        .iter()
                        .zip(&self.dead)
                        .map(|(&w, &d)| if d { 0.0 } else { w })
                        .collect();
                    super::smooth_pick(&self.assigned, &w)
                } else {
                    super::smooth_pick(&self.assigned, &self.weights)
                }
            }
            LbPolicy::JoinShortestQueue => {
                let mut best = usize::MAX;
                for i in 0..n {
                    if any_dead && self.dead[i] {
                        continue;
                    }
                    if best == usize::MAX || self.outstanding[i] < self.outstanding[best] {
                        best = i;
                    }
                }
                if best == usize::MAX {
                    0
                } else {
                    best
                }
            }
            // Outstanding *seconds* of backlog, not request count: the
            // same queue length is 2–3× more work on an SSD server
            // than on a CSD server.
            LbPolicy::LeastWork => {
                if any_dead {
                    let r: Vec<f64> = self
                        .rates
                        .iter()
                        .zip(&self.dead)
                        .map(|(&r, &d)| if d { 0.0 } else { r })
                        .collect();
                    super::smooth_pick(&self.outstanding, &r)
                } else {
                    super::smooth_pick(&self.outstanding, &self.rates)
                }
            }
        };
        self.assigned[s] += 1;
        self.outstanding[s] += 1;
        s
    }
}

// ---- the failure plane (ISSUE-6) ------------------------------------

/// Consecutive missed acks (fired timeouts) against one server before
/// the front door believes it dead and fails its shards over.
const MISSED_ACKS_DEAD: u32 = 3;
/// Hedge delay as a fraction of the first-attempt timeout: late enough
/// to be rare on a healthy tail, early enough to rescue a straggler
/// before its deadline.
const HEDGE_FRACTION: f64 = 0.75;
/// Deadline-aware automatic timeout: this × (completion estimate +
/// wake/formation floor). Generous enough that it never fires on a
/// healthy fleet at sane loads.
const AUTO_TIMEOUT_MARGIN: f64 = 4.0;

/// Capped exponential backoff multiplier for attempt `k` (1-based).
fn backoff(attempt: u32) -> f64 {
    match attempt {
        0 | 1 => 1.0,
        2 => 2.0,
        3 => 4.0,
        _ => 8.0,
    }
}

/// First believed-live server scanning from `home`'s neighbor — the
/// replica chain a shard fails over along. All-dead returns `home`.
fn failover_target(home: usize, dead: &[bool]) -> usize {
    let n = dead.len();
    for k in 1..n {
        let c = (home + k) % n;
        if !dead[c] {
            return c;
        }
    }
    home
}

/// Front-door bookkeeping for one request's whole lifetime (across
/// retries and hedges). Stored per request id; aggregation is always
/// order-free, so the map's iteration order can never leak into the
/// report.
struct Track {
    arrival: f64,
    /// The server the balancer originally picked (shard home).
    home: usize,
    /// Submissions so far (first offer = 1); retries increment.
    attempts: u32,
    /// Timeout base frozen at first submission.
    base: f64,
    hedged: bool,
    /// Resolved: completed (first response) or declared failed. Late
    /// responses for a done request are duplicate-suppressed.
    done: bool,
}

const KIND_HEDGE: u8 = 0;
const KIND_TIMEOUT: u8 = 1;
const KIND_SUBMIT: u8 = 2;

/// A front-door timer-wheel entry: hedge fire, retry timeout, or a
/// delayed (rack-redirected) submission.
#[derive(Clone, Copy, Debug)]
struct Deadline {
    t: f64,
    id: u64,
    kind: u8,
    tgt: usize,
}

impl PartialEq for Deadline {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == std::cmp::Ordering::Equal
    }
}
impl Eq for Deadline {}
impl PartialOrd for Deadline {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Deadline {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // Total, deterministic order: time, then id, then kind — the
        // wheel's pop order is part of the bit-identity contract.
        self.t
            .total_cmp(&other.t)
            .then(self.id.cmp(&other.id))
            .then(self.kind.cmp(&other.kind))
            .then(self.tgt.cmp(&other.tgt))
    }
}

// ---- the elastic plane (ISSUE-10) -----------------------------------

/// One server's membership in a time-varying fleet.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Membership {
    /// Provisioned but not part of the fleet (takes nothing).
    Off,
    /// Taking new work.
    Active,
    /// Finishing in-flight work, taking nothing new; leaves the fleet
    /// (→ Off) once its engine and front-door books are empty.
    Draining,
}

/// Runtime state of the autoscaler + shard rebalancer. Exists only when
/// `[autoscale]` is configured; `None` contributes a single +INF to the
/// event race and mutates nothing — the bit-identical static path. The
/// whole layer draws **no RNG**: every decision is a pure function of
/// observed simulation state.
struct Elastic {
    cfg: AutoscaleConfig,
    /// Copy of `cfg.check_interval_s` (the observation-window length).
    interval: f64,
    state: Vec<Membership>,
    /// Shard → serving server. Always an Active server: joins, drains
    /// and rebalances rehome shards before membership changes bite.
    shard_home: Vec<usize>,
    /// Per shard: the instant its last migration drains at the
    /// destination. A request for a shard arriving before this waits at
    /// the front door (the migrating shard is unavailable on the source
    /// once handoff starts) and submits at the destination then.
    shard_ready_at: Vec<f64>,
    /// Request → shard routing state: smooth weighted rotation over the
    /// Zipf-like shard popularity implied by `[traffic] skew`
    /// (`w_s ∝ 1/(s+1)^skew`; skew 0 = uniform).
    shard_counts: Vec<u64>,
    shard_weights: Vec<f64>,
    /// Migration cost: one shard's resident bytes, shipped over the
    /// rack link per move (corpus bytes / shards, floored at a header).
    shard_bytes: u64,
    /// Next autoscaler evaluation instant (+INF once arrivals end).
    next_eval: f64,
    /// Evaluations run so far (the first seeds the EWMA directly).
    evals: u64,
    /// Windowed arrival-rate estimator (predictive policy).
    ewma_rps: f64,
    /// Mean nominal per-server service rate — the fleet-sizing unit.
    per_server_rate: f64,
    /// Per server: activation instant of the current residency.
    active_since: Vec<f64>,
    /// Per server: accumulated active+draining seconds over closed
    /// residencies — the `server_seconds` report source.
    closed_secs: Vec<f64>,
    joins: u64,
    drains: u64,
    migrations: u64,
    migrated_bytes: u64,
    peak_servers: usize,
    timeline: Vec<FleetSample>,
    // Current-window accumulators, reset at every evaluation.
    win_arrived: u64,
    win_served: u64,
    win_shed: u64,
    win_lat: Vec<f64>,
    win_routed: Vec<u64>,
    win_shard: Vec<u64>,
}

impl Elastic {
    fn new(
        cfg: AutoscaleConfig,
        t0: f64,
        active0: usize,
        rates: &[f64],
        skew: f64,
        corpus_bytes: u64,
    ) -> Elastic {
        let n = rates.len();
        let shards = cfg.shards;
        let mut state = vec![Membership::Off; n];
        let mut active_since = vec![0.0; n];
        for (s, a) in state.iter_mut().zip(active_since.iter_mut()).take(active0) {
            *s = Membership::Active;
            *a = t0;
        }
        let shard_weights: Vec<f64> =
            (0..shards).map(|s| 1.0 / ((s + 1) as f64).powf(skew)).collect();
        let per_server_rate = rates.iter().sum::<f64>() / n as f64;
        Elastic {
            interval: cfg.check_interval_s,
            state,
            shard_home: (0..shards).map(|s| s % active0).collect(),
            shard_ready_at: vec![0.0; shards],
            shard_counts: vec![0; shards],
            shard_weights,
            shard_bytes: (corpus_bytes / shards as u64).max(64),
            next_eval: t0 + cfg.check_interval_s,
            evals: 0,
            ewma_rps: 0.0,
            per_server_rate,
            active_since,
            closed_secs: vec![0.0; n],
            joins: 0,
            drains: 0,
            migrations: 0,
            migrated_bytes: 0,
            peak_servers: active0,
            timeline: Vec::new(),
            win_arrived: 0,
            win_served: 0,
            win_shed: 0,
            win_lat: Vec::new(),
            win_routed: vec![0; n],
            win_shard: vec![0; shards],
            cfg,
        }
    }

    fn is_active(&self, i: usize) -> bool {
        self.state[i] == Membership::Active
    }

    fn active_count(&self) -> usize {
        self.state.iter().filter(|s| **s == Membership::Active).count()
    }

    /// Failover mask for the resilience plane under elastic membership:
    /// a server is unroutable when believed dead OR not Active. The
    /// replica ring scans over this instead of the raw dead belief.
    fn masked(&self, dead: &[bool]) -> Vec<bool> {
        dead.iter()
            .zip(&self.state)
            .map(|(&d, s)| d || *s != Membership::Active)
            .collect()
    }

    /// Route one arrival: shard by popularity rotation, server by the
    /// shard's home (failing over the replica ring when the home is
    /// believed dead). Returns the target server and, when the shard is
    /// mid-migration, the instant the transfer drains (the request then
    /// waits at the front door and submits at the destination).
    fn route(&mut self, now: f64, balancer: &mut Balancer, replicas: usize) -> (usize, Option<f64>) {
        let shard = super::smooth_pick(&self.shard_counts, &self.shard_weights);
        self.shard_counts[shard] += 1;
        let mut s = self.shard_home[shard];
        if balancer.dead[s] && replicas > 0 {
            s = failover_target(s, &self.masked(&balancer.dead));
        }
        balancer.assigned[s] += 1;
        balancer.outstanding[s] += 1;
        self.win_arrived += 1;
        self.win_routed[s] += 1;
        self.win_shard[shard] += 1;
        let ready = self.shard_ready_at[shard];
        (s, (now < ready).then_some(ready))
    }

    /// Move one shard to `dest`, paying the rack link for its bytes.
    /// The shard serves from the destination once the transfer drains;
    /// requests arriving before that wait at the front door.
    fn migrate(&mut self, shard: usize, dest: usize, now: f64, rack: &mut RackLink) {
        let done = rack.send(now, self.shard_bytes);
        self.shard_home[shard] = dest;
        self.shard_ready_at[shard] = done;
        self.migrations += 1;
        self.migrated_bytes += self.shard_bytes;
    }

    /// Activate the lowest-index Off server and rehome an even share of
    /// shards onto it (each move pays the rack). Returns false when no
    /// server is available to join.
    fn join(&mut self, now: f64, rack: &mut RackLink) -> bool {
        if self.active_count() >= self.cfg.max_servers {
            return false;
        }
        let Some(nw) = self.state.iter().position(|s| *s == Membership::Off) else {
            return false;
        };
        self.state[nw] = Membership::Active;
        self.active_since[nw] = now;
        self.joins += 1;
        let n_active = self.active_count();
        let take = self.shard_home.len() / n_active;
        for _ in 0..take {
            // Donor: the Active server (≠ newcomer) homing the most
            // shards, ties to the lowest index; move its lowest shard.
            let mut homed = vec![0u64; self.state.len()];
            for &h in &self.shard_home {
                homed[h] += 1;
            }
            let mut donor = usize::MAX;
            for i in 0..self.state.len() {
                if i == nw || !self.is_active(i) {
                    continue;
                }
                if donor == usize::MAX || homed[i] > homed[donor] {
                    donor = i;
                }
            }
            if donor == usize::MAX || homed[donor] == 0 {
                break;
            }
            let Some(shard) = self.shard_home.iter().position(|&h| h == donor) else {
                break;
            };
            self.migrate(shard, nw, now, rack);
        }
        true
    }

    /// Start draining the highest-index Active server: it takes nothing
    /// new, every shard it homes migrates to the least-loaded remaining
    /// Active server, and in-flight requests finish where they are
    /// (their drain start is pinned as a trace mark). Never shrinks the
    /// Active set below the configured floor.
    fn drain(
        &mut self,
        now: f64,
        balancer: &Balancer,
        rack: &mut RackLink,
        tracer: &mut Tracer,
        tracker: &BTreeMap<u64, Track>,
    ) {
        let actives: Vec<usize> =
            (0..self.state.len()).filter(|&i| self.is_active(i)).collect();
        if actives.len() <= self.cfg.min_servers || actives.len() <= 1 {
            return;
        }
        let Some(&victim) = actives.last() else {
            return;
        };
        self.state[victim] = Membership::Draining;
        self.drains += 1;
        for shard in 0..self.shard_home.len() {
            if self.shard_home[shard] != victim {
                continue;
            }
            // Least-work destination: argmin outstanding service time
            // over the remaining Active servers, ties to lowest index.
            let mut dest = usize::MAX;
            let mut best = f64::INFINITY;
            for i in 0..self.state.len() {
                if !self.is_active(i) {
                    continue;
                }
                let wl = balancer.outstanding[i] as f64 / balancer.rates[i].max(1e-12);
                if wl < best {
                    best = wl;
                    dest = i;
                }
            }
            if dest == usize::MAX {
                break;
            }
            self.migrate(shard, dest, now, rack);
        }
        // Pin the drain start on every request still in flight there
        // (BTreeMap iteration: request-id order, deterministic).
        for (id, t) in tracker.iter() {
            if !t.done && t.home == victim {
                tracer.mark(*id, SpanKind::Drain, now);
            }
        }
    }

    /// One autoscaler evaluation at `now`: close the observation
    /// window, decide joins/drains per policy, complete finished
    /// drains, maybe rebalance one hot shard, and sample the timeline.
    #[allow(clippy::too_many_arguments)]
    fn eval(
        &mut self,
        now: f64,
        t0: f64,
        balancer: &mut Balancer,
        engines: &[ServeEngine],
        rack: &mut RackLink,
        tracer: &mut Tracer,
        tracker: &BTreeMap<u64, Track>,
        specs: &[ServerSpec],
        power: &PowerModel,
        slo: f64,
        arrivals_done: bool,
    ) {
        let p99 = LatencyStats::of(&self.win_lat).p99;
        let obs = self.win_arrived as f64 / self.interval;
        // Windowed arrival-rate estimator: EWMA whose memory spans
        // roughly `estimator_window_s`; the first window seeds it.
        let alpha = (self.interval / self.cfg.estimator_window_s).min(1.0);
        self.ewma_rps =
            if self.evals == 0 { obs } else { alpha * obs + (1.0 - alpha) * self.ewma_rps };
        self.evals += 1;
        let est = obs.max(self.ewma_rps);
        let active = self.active_count();
        // What one active server is sized to carry.
        let cap = self.per_server_rate * self.cfg.target_util;

        let mut want_join = 0usize;
        let mut want_drain = false;
        match self.cfg.policy {
            AutoscalePolicy::Reactive => {
                // Threshold + hysteresis on the last window only.
                let blown = self.win_shed > 0 || (!self.win_lat.is_empty() && p99 > slo);
                if blown {
                    want_join = 1;
                } else if self.win_shed == 0
                    && !self.win_lat.is_empty()
                    && p99 < (1.0 - self.cfg.hysteresis) * slo
                    && active > 1
                    && obs < cap * (active - 1) as f64
                {
                    // The utilization guard: only drain when the
                    // shrunken fleet would still run under target —
                    // p99 hysteresis alone oscillates on ramps.
                    want_drain = true;
                }
            }
            AutoscalePolicy::Predictive => {
                // Size the fleet for the estimated rate directly; a
                // flash crowd can join several servers in one step.
                let target = ((est / cap).ceil() as usize)
                    .clamp(self.cfg.min_servers, self.cfg.max_servers);
                if target > active {
                    want_join = target - active;
                } else if target < active {
                    want_drain = true;
                }
            }
        }
        for _ in 0..want_join {
            if !self.join(now, rack) {
                break;
            }
        }
        if want_drain {
            self.drain(now, balancer, rack, tracer, tracker);
        }
        // Drain completion: a draining server leaves once its engine
        // and the front-door books are both empty — zero lost in-flight
        // work, by construction.
        for i in 0..self.state.len() {
            if self.state[i] == Membership::Draining
                && engines[i].idle()
                && balancer.outstanding[i] == 0
            {
                self.state[i] = Membership::Off;
                self.closed_secs[i] += (now - self.active_since[i]).max(0.0);
            }
        }
        // Rebalance: when one Active server took more than the
        // threshold share of this window's routed requests, move its
        // hottest shard to the coldest Active server (one per window —
        // the rack prices every move, so the cure stays incremental).
        if self.cfg.rebalance {
            let actives: Vec<usize> =
                (0..self.state.len()).filter(|&i| self.is_active(i)).collect();
            let total: u64 = self.win_routed.iter().sum();
            if total > 0 && actives.len() >= 2 {
                let mut hot = actives[0];
                for &i in &actives {
                    if self.win_routed[i] > self.win_routed[hot] {
                        hot = i;
                    }
                }
                if self.win_routed[hot] as f64 > self.cfg.rebalance_threshold * total as f64 {
                    let mut shard = usize::MAX;
                    for s in 0..self.shard_home.len() {
                        if self.shard_home[s] == hot
                            && (shard == usize::MAX || self.win_shard[s] > self.win_shard[shard])
                        {
                            shard = s;
                        }
                    }
                    if shard != usize::MAX {
                        let mut cold = usize::MAX;
                        for &i in &actives {
                            if i != hot
                                && (cold == usize::MAX
                                    || self.win_routed[i] < self.win_routed[cold])
                            {
                                cold = i;
                            }
                        }
                        if cold != usize::MAX {
                            self.migrate(shard, cold, now, rack);
                        }
                    }
                }
            }
        }
        // Timeline sample + window reset.
        let active = self.active_count();
        let draining =
            self.state.iter().filter(|s| **s == Membership::Draining).count();
        self.peak_servers = self.peak_servers.max(active + draining);
        let mut energy = 0.0;
        for (i, spec) in specs.iter().enumerate() {
            if self.state[i] != Membership::Off {
                // Window energy estimate: a resident server pays its
                // host busy envelope for the window (ISP draw is folded
                // into the end-of-run exact accounting).
                energy += power.energy(self.interval, spec.sched.drives, self.interval, 0.0).energy_j;
            }
        }
        self.timeline.push(FleetSample {
            t: now - t0,
            active,
            draining,
            p99_s: p99,
            arrived: self.win_arrived,
            served: self.win_served,
            shed: self.win_shed,
            energy_j: energy,
        });
        self.win_arrived = 0;
        self.win_served = 0;
        self.win_shed = 0;
        self.win_lat.clear();
        for x in self.win_routed.iter_mut() {
            *x = 0;
        }
        for x in self.win_shard.iter_mut() {
            *x = 0;
        }
        // Once every request has arrived the fleet only drains; no more
        // resize decisions are needed and the run must be able to end.
        self.next_eval = if arrivals_done { f64::INFINITY } else { now + self.interval };
    }

    /// Close every open residency at the end of the run: draining (and
    /// still-active) servers are paid for until the last response.
    fn finish(&mut self, last_done: f64) {
        for i in 0..self.state.len() {
            if self.state[i] != Membership::Off {
                self.closed_secs[i] += (last_done - self.active_since[i]).max(0.0);
                self.state[i] = Membership::Off;
            }
        }
    }
}

/// Serve one app across the fleet; returns the rollup report.
///
/// The run is a single joint DES over all servers: global events
/// (arrivals, per-server acks/wakes/flushes, rack deliveries) execute in
/// nondecreasing virtual time, so cross-server interactions (JSQ
/// routing, rack FIFO) are causally consistent and the whole run is a
/// pure function of (config, seed).
pub fn serve_fleet(
    app: App,
    fcfg: &FleetConfig,
    tcfg: &TrafficConfig,
    power: &PowerModel,
    metrics: &mut Metrics,
) -> anyhow::Result<ServeReport> {
    serve_fleet_traced(app, fcfg, tcfg, power, metrics, &mut Tracer::Off)
}

/// [`serve_fleet`] with a span tracer (ISSUE-9). The master `tracer`
/// records front-door events (admission, shed, rack delivery, retries,
/// hedges, failover) and each engine gets a child tracer for the
/// dispatch-path phases; children fold back into the master before the
/// function returns. Passing [`Tracer::Off`] (what [`serve_fleet`]
/// does) runs the exact untraced path — the traced-off bit-identity
/// property pinned by `tests/trace_conservation.rs`.
pub fn serve_fleet_traced(
    app: App,
    fcfg: &FleetConfig,
    tcfg: &TrafficConfig,
    power: &PowerModel,
    metrics: &mut Metrics,
    tracer: &mut Tracer,
) -> anyhow::Result<ServeReport> {
    anyhow::ensure!(fcfg.servers >= 1, "need at least one server in the fleet");
    fcfg.validate_weights()?;
    anyhow::ensure!(tcfg.requests >= 1, "need at least one request to serve");
    anyhow::ensure!(tcfg.min_batch >= 1, "traffic.min_batch must be >= 1");
    anyhow::ensure!(
        tcfg.batch_timeout_s >= 0.0 && tcfg.batch_timeout_s.is_finite(),
        "traffic.batch_timeout_s must be non-negative and finite"
    );
    anyhow::ensure!(
        tcfg.load > 0.0 && tcfg.load.is_finite(),
        "traffic.load must be positive and finite, got {}",
        tcfg.load
    );
    if let Some(r) = tcfg.rate_rps {
        anyhow::ensure!(r > 0.0 && r.is_finite(), "traffic.rate_rps must be positive, got {r}");
        anyhow::ensure!(
            tcfg.process != super::ArrivalProcess::ClosedLoop,
            "rate_rps does not apply to the closed-loop process: its offered rate is \
             clients/think_s ({} clients / {} s); drop --rate or use an open-loop process",
            tcfg.clients,
            tcfg.think_s
        );
    }
    anyhow::ensure!(tcfg.clients >= 1, "traffic.clients must be >= 1");
    anyhow::ensure!(
        tcfg.think_s > 0.0 && tcfg.think_s.is_finite(),
        "traffic.think_s must be positive"
    );
    anyhow::ensure!(
        tcfg.burstiness >= 1.0 && tcfg.burstiness.is_finite(),
        "traffic.burstiness must be >= 1 (peak/mean ratio)"
    );
    anyhow::ensure!(
        tcfg.burst_on_s > 0.0 && tcfg.burst_on_s.is_finite(),
        "traffic.burst_on_s must be positive"
    );
    // Elastic membership (ISSUE-10): the autoscale knobs are validated
    // against the fleet here too, so CLI-layered overrides cannot sneak
    // past the TOML-parse check. With autoscale on, the replica bound is
    // the (stricter) elastic one: replicas < min_servers.
    if let Some(ac) = &tcfg.autoscale {
        ac.validate(fcfg)?;
    }
    if let Some(segs) = &tcfg.rate_segments {
        anyhow::ensure!(
            tcfg.process == super::ArrivalProcess::Poisson,
            "traffic.rate_segments applies only to the poisson arrival process"
        );
        anyhow::ensure!(!segs.is_empty(), "traffic.rate_segments must not be empty");
        for &(d, m) in segs {
            anyhow::ensure!(
                d > 0.0 && d.is_finite(),
                "rate_segments durations must be positive and finite, got {d}"
            );
            anyhow::ensure!(
                m > 0.0 && m.is_finite(),
                "rate_segments multipliers must be positive and finite, got {m}"
            );
        }
    }
    anyhow::ensure!(
        tcfg.autoscale.is_some() || fcfg.replicas == 0 || fcfg.replicas < fcfg.servers,
        "fleet.replicas ({}) needs a distinct neighbor per shard: must be < servers ({})",
        fcfg.replicas,
        fcfg.servers
    );
    if let Some(to) = tcfg.retry_timeout_s {
        anyhow::ensure!(
            to > 0.0 && to.is_finite(),
            "traffic.retry_timeout_s must be positive and finite, got {to}"
        );
    }
    anyhow::ensure!(
        tcfg.ingest_rate >= 0.0 && tcfg.ingest_rate.is_finite(),
        "traffic.ingest_rate must be non-negative and finite, got {}",
        tcfg.ingest_rate
    );
    // The provisioned server count: everything the run may ever use.
    // Elastic runs provision (and build engines for) `max_servers` up
    // front; joins activate them. Static runs use the fleet as given.
    let n_total = tcfg.autoscale.as_ref().map(|a| a.max_servers).unwrap_or(fcfg.servers);
    if let Some(fc) = &tcfg.faults {
        fc.validate(n_total)?;
    }

    let specs = match &tcfg.autoscale {
        None => fcfg.server_specs(),
        Some(a) => FleetConfig { servers: a.max_servers, ..fcfg.clone() }.server_specs(),
    };
    // Initially active servers: the configured fleet size, clamped into
    // the autoscaler's band (static runs: exactly the configured size).
    let active0 = tcfg
        .autoscale
        .as_ref()
        .map(|a| fcfg.servers.clamp(a.min_servers, a.max_servers))
        .unwrap_or(fcfg.servers);
    let model = AppModel::for_app(app, tcfg.requests);
    // Offered load is expressed against the *initial* fleet's capacity
    // (the full fleet when static): fig12's ramps then mean "multiples
    // of what the starting fleet can nominally carry".
    let nominal = fleet_nominal_rate(&model, &specs[..active0]);
    let offered = tcfg.offered_rps(nominal);
    anyhow::ensure!(
        offered > 0.0 && offered.is_finite(),
        "offered rate must be positive (load {} × nominal {nominal})",
        tcfg.load
    );

    // The SLO every run is judged against; with admission on it is also
    // the per-request deadline budget the gate sheds by.
    let slo = tcfg.slo_p99_s.unwrap_or_else(|| default_slo_p99(&model, fcfg.sched.csd_batch));
    anyhow::ensure!(
        slo > 0.0 && slo.is_finite(),
        "traffic.slo_p99_s must be positive and finite, got {slo}"
    );
    let epolicy = EnginePolicy {
        formation: tcfg.formation(),
        skew: tcfg.skew,
        admission_budget_s: tcfg.admission.then_some(slo),
    };

    // ---- build the per-server engines -------------------------------
    // (ServeEngine::new also validates the serving parameters a direct
    // library caller could get wrong: min_batch vs dispatch capacity,
    // skew, the admission budget.)
    let mut engines: Vec<ServeEngine> = specs
        .iter()
        .map(|s| ServeEngine::new(&model, &s.sched, epolicy))
        .collect::<anyhow::Result<_>>()?;
    // Global serving clock starts when the slowest corpus is resident.
    let t0 = engines.iter().map(|e| e.t0()).fold(0.0, f64::max);

    // Per-server nominal rates: the least-work policy's service
    // estimate, and the default capacity weights.
    let rates: Vec<f64> = specs.iter().map(|s| super::nominal_rate(&model, &s.sched)).collect();
    // Balancer capacity weights: the explicit `[fleet] weights` /
    // `--weights` override when present (heterogeneous fleets), else
    // each server's nominal service rate.
    let weights: Vec<f64> = match &fcfg.weights {
        Some(w) => w.iter().map(|&x| x as f64).collect(),
        None => rates.clone(),
    };
    let mut balancer = Balancer::new(tcfg.policy, weights, rates);
    let mut gen = tcfg.arrivals(offered);
    let mut rack = RackLink::new(fcfg.rack_bandwidth, fcfg.rack_msg_overhead);

    let mut latencies: Vec<f64> = Vec::with_capacity(tcfg.requests as usize);
    let mut served_per: Vec<u64> = vec![0; specs.len()];
    let mut shed_per: Vec<u64> = vec![0; specs.len()];
    let mut first_arrival = f64::INFINITY;
    let mut last_done = t0;

    // ---- the failure plane (ISSUE-6) --------------------------------
    // `resilient` arms the front-door timer wheel (timeouts, hedges);
    // `tracking` maintains per-request lifetime state. Both off is the
    // exact pre-chaos fast path; a *quiet* fault plan draws nothing
    // from its RNG streams, so quiet-plan runs are bit-identical to
    // fault-free runs (the `tests/chaos.rs` property).
    let resilient = tcfg.resilient();
    let tracking = resilient || tcfg.faults.is_some() || tcfg.autoscale.is_some();
    // Expected arrival window: the crash schedule's time base.
    let window = tcfg.requests as f64 / offered;
    let drives_per_server: Vec<usize> = specs.iter().map(|s| s.sched.drives).collect();
    let mut plan = tcfg
        .faults
        .as_ref()
        .map(|fc| FaultPlan::new(fc, &drives_per_server, t0, window));
    if let Some(p) = plan.as_mut() {
        for (e, d) in engines.iter_mut().zip(p.drive.drain(..)) {
            e.set_faults(d);
        }
    }
    // Background ingest/update stream (ISSUE-8): per-server seeded
    // Poisson update writes through the drives' FTLs, firing over the
    // expected arrival window. Rate 0 (the default) arms nothing and
    // draws no RNG — bit-identical to the pre-ISSUE-8 run.
    if tcfg.ingest_rate > 0.0 {
        let mut root = crate::util::Rng::new(tcfg.seed).fork("ingest");
        for (i, e) in engines.iter_mut().enumerate() {
            e.set_ingest(tcfg.ingest_rate, t0 + window, root.fork(&format!("server-{i}")));
        }
    }
    // Span tracing (ISSUE-9): each engine gets a child tracer tagged
    // with its server index; children fold back into the master when
    // the run ends. Off children keep engines on the exact untraced
    // path.
    if tracer.is_on() {
        for (i, e) in engines.iter_mut().enumerate() {
            e.set_tracer(tracer.child(i as u32));
        }
    }
    // Queue-depth / inflight time-series keys (sampled per completion
    // batch while tracing).
    let qd_keys: Vec<String> =
        (0..specs.len()).map(|i| format!("serve.s{i}.queue_depth")).collect();
    let if_keys: Vec<String> = (0..specs.len()).map(|i| format!("serve.s{i}.inflight")).collect();
    // Per-server latency floor a healthy request can legitimately spend
    // before service starts (wake grid + batch formation): part of the
    // deadline-aware automatic timeout base.
    let floors: Vec<f64> =
        specs.iter().map(|s| s.sched.wakeup_secs + tcfg.batch_timeout_s).collect();
    // BTreeMap, not HashMap: the end-of-run sweep iterates this map,
    // and a failed-request *set* must resolve in request-id order so
    // no hasher state can ever reach the report (lint rule D1).
    let mut tracker: BTreeMap<u64, Track> = BTreeMap::new();
    let mut wheel: BinaryHeap<Reverse<Deadline>> = BinaryHeap::new();
    let mut missed_acks: Vec<u32> = vec![0; specs.len()];
    // Elastic runtime (ISSUE-10): None is the exact static path — it
    // contributes one +INF to the event race and mutates nothing. The
    // shard corpus is requests × per-item bytes, split across shards.
    let mut el: Option<Elastic> = tcfg.autoscale.as_ref().map(|a| {
        Elastic::new(
            a.clone(),
            t0,
            active0,
            &balancer.rates,
            tcfg.skew,
            tcfg.requests.saturating_mul(model.bytes_per_item),
        )
    });
    let mut failed = 0u64;
    let mut retried = 0u64;
    let mut hedged = 0u64;
    let mut duplicate_suppressed = 0u64;
    let mut completed_in_slo = 0u64;
    // Attempt-level (not request-level) accounting, for the engine
    // conservation checks below.
    let mut extra_shed = 0u64;
    let mut engine_emitted = 0u64;
    let mut crash_suppressed = 0u64;
    let mut link_dropped = 0u64;
    let mut arrived = 0u64;

    // ---- the joint event loop ---------------------------------------
    // Four event sources in nondecreasing virtual time: arrivals, the
    // per-server engines, the front-door timer wheel, and the elastic
    // autoscaler's evaluation clock. Arrivals win global ties so
    // same-instant dispatch sees the queued request; engine events beat
    // same-instant deadlines so a response that lands exactly at its
    // timeout counts as delivered; the autoscaler evaluates last at any
    // tie (it only *observes* the instant). With the wheel empty and no
    // autoscaler (any static non-resilient run) the selection reduces
    // exactly to the pre-chaos two-way race. The break condition
    // deliberately ignores the eval clock: evaluations alone cannot
    // extend a run that has no work left.
    loop {
        let ta = gen.peek().map(|t| t0 + t);
        let te = engines
            .iter()
            .enumerate()
            .filter_map(|(i, e)| e.next_time().map(|t| (t, i)))
            .min_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        let a = ta.unwrap_or(f64::INFINITY);
        let e = te.map(|(t, _)| t).unwrap_or(f64::INFINITY);
        let w = wheel.peek().map(|d| d.0.t).unwrap_or(f64::INFINITY);
        let c = el.as_ref().map(|el| el.next_eval).unwrap_or(f64::INFINITY);
        if a.is_infinite() && e.is_infinite() && w.is_infinite() {
            break;
        }
        if a <= e && a <= w && a <= c {
            let Some(req) = gen.pop() else {
                anyhow::bail!("arrival stream drained between peek and pop");
            };
            arrived += 1;
            let (s, defer_until) = match el.as_mut() {
                Some(el) => el.route(a, &mut balancer, fcfg.replicas),
                None => (balancer.pick(), None),
            };
            first_arrival = first_arrival.min(a);
            // Timeout base frozen at first submission: explicit when
            // configured, else deadline-aware — a margin over the
            // target's own completion estimate plus its wake floor, so
            // it never fires on a healthy fleet.
            let base = if resilient {
                tcfg.retry_timeout_s.unwrap_or_else(|| {
                    AUTO_TIMEOUT_MARGIN * (engines[s].estimated_completion_s() + floors[s])
                })
            } else {
                0.0
            };
            let down_now = plan.as_ref().map_or(false, |p| p.down(s, a));
            if down_now {
                // The dead server swallows the request whole: no ack,
                // no rejection. Only the timer wheel (or the end-of-run
                // sweep, without resilience) can resolve it now.
                tracer.begin_on(req.id, a, s as u32);
                tracker.insert(
                    req.id,
                    Track { arrival: a, home: s, attempts: 1, base, hedged: false, done: false },
                );
                if resilient {
                    wheel.push(Reverse(Deadline {
                        t: a + base,
                        id: req.id,
                        kind: KIND_TIMEOUT,
                        tgt: s,
                    }));
                    if tcfg.hedge {
                        wheel.push(Reverse(Deadline {
                            t: a + HEDGE_FRACTION * base,
                            id: req.id,
                            kind: KIND_HEDGE,
                            tgt: s,
                        }));
                    }
                }
            } else if let Some(ready) = defer_until {
                // The request's home shard is mid-migration (ISSUE-10):
                // it is unavailable on the source once handoff starts,
                // so the request waits at the front door and submits at
                // the destination when the transfer drains — the
                // migration span covers the wait.
                tracer.begin_on(req.id, a, s as u32);
                tracer.mark(req.id, SpanKind::Migration, ready);
                tracker.insert(
                    req.id,
                    Track { arrival: a, home: s, attempts: 1, base, hedged: false, done: false },
                );
                // The front-door books carry it again once it lands.
                balancer.outstanding[s] -= 1;
                wheel.push(Reverse(Deadline {
                    t: ready,
                    id: req.id,
                    kind: KIND_SUBMIT,
                    tgt: s,
                }));
                if resilient {
                    wheel.push(Reverse(Deadline {
                        t: a + base,
                        id: req.id,
                        kind: KIND_TIMEOUT,
                        tgt: s,
                    }));
                    if tcfg.hedge {
                        wheel.push(Reverse(Deadline {
                            t: a + HEDGE_FRACTION * base,
                            id: req.id,
                            kind: KIND_HEDGE,
                            tgt: s,
                        }));
                    }
                }
            } else if engines[s].offer(a, req.id)? == Offer::Shed {
                // Rejected at the door: an immediate response that
                // never enters the percentiles. The rejection still
                // re-arms a closed-loop client, and it closes the
                // serving window like any other response.
                shed_per[s] += 1;
                balancer.outstanding[s] -= 1;
                if let Some(el) = el.as_mut() {
                    el.win_shed += 1;
                }
                // A shed request is a zero-width traced timeline: begun
                // and closed at the door in the same instant.
                tracer.begin_on(req.id, a, s as u32);
                tracer.finish(req.id, a, TraceOutcome::Shed);
                gen.on_complete(a - t0);
                last_done = last_done.max(a);
            } else if tracking {
                tracer.begin_on(req.id, a, s as u32);
                tracker.insert(
                    req.id,
                    Track { arrival: a, home: s, attempts: 1, base, hedged: false, done: false },
                );
                if resilient {
                    wheel.push(Reverse(Deadline {
                        t: a + base,
                        id: req.id,
                        kind: KIND_TIMEOUT,
                        tgt: s,
                    }));
                    if tcfg.hedge {
                        wheel.push(Reverse(Deadline {
                            t: a + HEDGE_FRACTION * base,
                            id: req.id,
                            kind: KIND_HEDGE,
                            tgt: s,
                        }));
                    }
                }
            } else {
                // Accepted on a fault-free, non-resilient run: no
                // tracker entry needed, but the traced timeline still
                // opens at the front door.
                tracer.begin_on(req.id, a, s as u32);
            }
        } else if e <= w && e <= c {
            let Some((_, i)) = te else {
                anyhow::bail!("engine event vanished between peek and step");
            };
            engines[i].step()?;
            let comps = engines[i].take_completions();
            if comps.is_empty() {
                continue;
            }
            engine_emitted += comps.len() as u64;
            if tracer.is_on() {
                // Queue-depth / inflight time series, sampled once per
                // completion batch on the server that produced it.
                metrics.sample(&qd_keys[i], comps[0].done, engines[i].queued() as f64);
                metrics.sample(&if_keys[i], comps[0].done, engines[i].inflight() as f64);
            }
            // One ack event → one batch → one response block over
            // the rack for non-head servers (64 B header + per-item
            // outputs), serialized FIFO on the head's downlink.
            let batch_done = comps[0].done;
            // A crashed server produces no responses: everything it
            // completes during downtime is suppressed, and the front
            // door recovers via timeouts, not mercy.
            if plan.as_ref().map_or(false, |p| p.down(i, batch_done)) {
                crash_suppressed += comps.len() as u64;
                continue;
            }
            let mut dup_copies = false;
            let delivered = if i == 0 {
                batch_done
            } else {
                let bytes = 64 + comps.len() as u64 * model.output_bytes_per_item;
                match plan.as_mut().map_or(LinkOutcome::Deliver, |p| p.link.outcome()) {
                    LinkOutcome::Drop => {
                        // The message transits (bandwidth is spent)
                        // and dies at the head's downlink.
                        let _ = rack.send(batch_done, bytes);
                        link_dropped += comps.len() as u64;
                        continue;
                    }
                    LinkOutcome::Duplicate => {
                        let d = rack.send(batch_done, bytes);
                        // The spurious copy pays the rack again and
                        // arrives strictly later, so every completion
                        // it carries is a duplicate by construction.
                        let _second = rack.send(batch_done, bytes);
                        dup_copies = true;
                        d
                    }
                    LinkOutcome::Deliver => rack.send(batch_done, bytes),
                }
            };
            for c in &comps {
                debug_assert_eq!(c.done.to_bits(), batch_done.to_bits());
                if tracking {
                    let tr = tracker
                        .get_mut(&c.id)
                        .ok_or_else(|| anyhow::anyhow!("completion for untracked request {}", c.id))?;
                    if tr.done {
                        // First response won already (hedge/retry
                        // race, or a post-failure straggler).
                        duplicate_suppressed += 1;
                        continue;
                    }
                    tr.done = true;
                    let lat = delivered - tr.arrival;
                    latencies.push(lat);
                    if let Some(el) = el.as_mut() {
                        el.win_lat.push(lat);
                        el.win_served += 1;
                    }
                    if lat <= slo {
                        completed_in_slo += 1;
                    }
                    if i != 0 {
                        // Non-head response: the rack hop it just paid.
                        tracer.mark(c.id, SpanKind::RackLink, delivered);
                    }
                    tracer.finish(c.id, delivered, TraceOutcome::Served);
                    gen.on_complete(delivered - t0);
                    served_per[i] += 1;
                } else {
                    let lat = delivered - c.arrival;
                    latencies.push(lat);
                    if lat <= slo {
                        completed_in_slo += 1;
                    }
                    if i != 0 {
                        tracer.mark(c.id, SpanKind::RackLink, delivered);
                    }
                    tracer.finish(c.id, delivered, TraceOutcome::Served);
                    gen.on_complete(delivered - t0);
                    served_per[i] += 1;
                }
            }
            if dup_copies {
                duplicate_suppressed += comps.len() as u64;
            }
            balancer.outstanding[i] = balancer.outstanding[i].saturating_sub(comps.len() as u64);
            if tracking {
                // A delivered response is a liveness proof: reset the
                // missed-ack belief (post-rejoin resurrection).
                missed_acks[i] = 0;
                balancer.dead[i] = false;
            }
            last_done = last_done.max(delivered);
        } else if w <= c {
            let Some(Reverse(dl)) = wheel.pop() else {
                anyhow::bail!("timer wheel drained between peek and pop");
            };
            let now = dl.t;
            let tr = tracker
                .get_mut(&dl.id)
                .ok_or_else(|| anyhow::anyhow!("deadline for untracked request {}", dl.id))?;
            if tr.done {
                // Stale deadline for a resolved request: ignored with
                // zero side effects — the property that keeps healthy
                // resilient runs identical to non-resilient ones.
                continue;
            }
            match dl.kind {
                KIND_HEDGE => {
                    if tr.hedged {
                        continue;
                    }
                    tr.hedged = true;
                    hedged += 1;
                    tracer.mark_attempt(dl.id, SpanKind::Hedge, now, tr.attempts);
                    let h = if fcfg.replicas > 0 {
                        // Under elastic membership the replica ring
                        // skips draining/off servers too.
                        match el.as_ref() {
                            Some(el) => failover_target(tr.home, &el.masked(&balancer.dead)),
                            None => failover_target(tr.home, &balancer.dead),
                        }
                    } else {
                        tr.home
                    };
                    let home = tr.home;
                    if h == home {
                        // Same-server hedge: a fresh copy through the
                        // front door (rescues a faulted ack).
                        if !plan.as_ref().map_or(false, |p| p.down(h, now)) {
                            match engines[h].offer(now, dl.id)? {
                                Offer::Accepted => balancer.outstanding[h] += 1,
                                Offer::Shed => extra_shed += 1,
                            }
                        }
                    } else {
                        // Cross-server hedge: the redirect rides (and
                        // pays) the rack, landing as a delayed submit.
                        let at = rack.send(now, 64 + model.bytes_per_item);
                        tracer.mark(dl.id, SpanKind::FailoverRedirect, at);
                        wheel.push(Reverse(Deadline {
                            t: at,
                            id: dl.id,
                            kind: KIND_SUBMIT,
                            tgt: h,
                        }));
                    }
                }
                KIND_TIMEOUT => {
                    // The attempt aimed at dl.tgt missed its deadline:
                    // one missed ack against that server, and the
                    // straggler is written off the queue-depth books.
                    missed_acks[dl.tgt] += 1;
                    if missed_acks[dl.tgt] >= MISSED_ACKS_DEAD {
                        balancer.dead[dl.tgt] = true;
                    }
                    balancer.outstanding[dl.tgt] =
                        balancer.outstanding[dl.tgt].saturating_sub(1);
                    if tr.attempts > tcfg.retries {
                        // Retry budget exhausted: the front door
                        // answers the client with a failure. That IS a
                        // response — it re-arms a closed-loop client
                        // and extends the serving window.
                        tr.done = true;
                        failed += 1;
                        tracer.finish(dl.id, now, TraceOutcome::Failed);
                        gen.on_complete(now - t0);
                        last_done = last_done.max(now);
                    } else {
                        tr.attempts += 1;
                        retried += 1;
                        // The timed-out attempt's wasted time, tagged
                        // with the attempt number it opened.
                        tracer.mark_attempt(dl.id, SpanKind::Retry, now, tr.attempts);
                        let home_gone = balancer.dead[tr.home]
                            || el.as_ref().map_or(false, |el| !el.is_active(tr.home));
                        let nt = if home_gone && fcfg.replicas > 0 {
                            match el.as_ref() {
                                Some(el) => {
                                    failover_target(tr.home, &el.masked(&balancer.dead))
                                }
                                None => failover_target(tr.home, &balancer.dead),
                            }
                        } else {
                            tr.home
                        };
                        wheel.push(Reverse(Deadline {
                            t: now + tr.base * backoff(tr.attempts),
                            id: dl.id,
                            kind: KIND_TIMEOUT,
                            tgt: nt,
                        }));
                        if nt == tr.home {
                            if !plan.as_ref().map_or(false, |p| p.down(nt, now)) {
                                match engines[nt].offer(now, dl.id)? {
                                    Offer::Accepted => balancer.outstanding[nt] += 1,
                                    Offer::Shed => extra_shed += 1,
                                }
                            }
                        } else {
                            let at = rack.send(now, 64 + model.bytes_per_item);
                            tracer.mark(dl.id, SpanKind::FailoverRedirect, at);
                            wheel.push(Reverse(Deadline {
                                t: at,
                                id: dl.id,
                                kind: KIND_SUBMIT,
                                tgt: nt,
                            }));
                        }
                    }
                }
                _ => {
                    // KIND_SUBMIT: a redirected copy lands at its
                    // failover target (a migration-deferred request
                    // lands at the shard's new home the same way). A
                    // dead target swallows it (the armed timeout
                    // recovers); a shed just dies — the timeout covers
                    // that path too, and without resilience the
                    // end-of-run sweep declares it failed.
                    if !plan.as_ref().map_or(false, |p| p.down(dl.tgt, now)) {
                        match engines[dl.tgt].offer(now, dl.id)? {
                            Offer::Accepted => balancer.outstanding[dl.tgt] += 1,
                            Offer::Shed => extra_shed += 1,
                        }
                    }
                }
            }
        } else {
            // Elastic evaluation (ISSUE-10): close the observation
            // window and let the autoscaler/rebalancer act. Loses every
            // tie above — it only observes the instant.
            let Some(el) = el.as_mut() else {
                anyhow::bail!("elastic evaluation fired without an autoscale config");
            };
            el.eval(
                c,
                t0,
                &mut balancer,
                &engines,
                &mut rack,
                tracer,
                &tracker,
                &specs,
                power,
                slo,
                arrived >= tcfg.requests,
            );
        }
    }

    // ---- conservation -----------------------------------------------
    // Exact accounting at two levels. Requests: every offered request
    // was served (completed once), declared failed, or shed at the
    // door. Attempts: every engine-accepted attempt either emitted a
    // completion or was destroyed by a fault, and every emitted
    // completion was delivered once, duplicate-suppressed, or eaten by
    // a crash/link fault. On a fault-free run every fault term is zero
    // and the checks collapse to the strict pre-chaos invariants.
    let served: u64 = served_per.iter().sum();
    let shed: u64 = shed_per.iter().sum();
    if tracking {
        // Requests with no event left to resolve them (swallowed by a
        // dead server or destroyed with no retry budget) are failures.
        // Counting is order-free, so the map's iteration order cannot
        // leak into the report.
        for (id, t) in tracker.iter().filter(|(_, t)| !t.done) {
            // Traced: a swallowed request closes as a zero-width failed
            // timeline (no response ever reached the front door).
            tracer.finish(*id, t.arrival, TraceOutcome::Failed);
        }
        failed += tracker.values().filter(|t| !t.done).count() as u64;
    }
    anyhow::ensure!(
        served + failed + shed == arrived,
        "serving lost requests: served {served} + failed {failed} + shed {shed} != arrived {arrived}"
    );
    // Open-loop generators always emit every request; a closed loop
    // falls short only when a fault swallowed a request with no
    // resilience armed — the stuck client's request never re-entered
    // circulation. That shortfall is itself a failure to serve.
    // (A migration-deferred request that is then shed with no retry
    // budget also resolves only at the sweep, so an elastic closed loop
    // can legitimately fall short too.)
    anyhow::ensure!(
        arrived == tcfg.requests || tcfg.faults.is_some() || tcfg.autoscale.is_some(),
        "arrival stream ended early without faults: {arrived} of {} requests",
        tcfg.requests
    );
    failed += tcfg.requests - arrived;
    let engine_shed: u64 = engines.iter().map(|e| e.shed()).sum();
    anyhow::ensure!(
        engine_shed == shed + extra_shed,
        "engine admission counters disagree with the front door: \
         {engine_shed} vs {shed} first-offer + {extra_shed} retry/hedge"
    );
    let engine_accepted: u64 = engines.iter().map(|e| e.accepted()).sum();
    let engine_lost: u64 = engines.iter().map(|e| e.lost()).sum();
    anyhow::ensure!(
        engine_accepted == engine_emitted + engine_lost,
        "attempt accounting leak: accepted {engine_accepted} != \
         emitted {engine_emitted} + fault-lost {engine_lost}"
    );
    anyhow::ensure!(
        engine_emitted == served + duplicate_suppressed + crash_suppressed + link_dropped,
        "response accounting leak: emitted {engine_emitted} != served {served} + \
         dup {duplicate_suppressed} + crash-suppressed {crash_suppressed} + \
         link-dropped {link_dropped}"
    );
    let items: u64 = engines.iter().map(|e| e.state().host_items + e.state().csd_items).sum();
    anyhow::ensure!(
        items == engine_accepted,
        "scheduler item split ({items}) disagrees with accepted attempts ({engine_accepted})"
    );

    // Engine self-profiling rollup (always on) and child-trace merge
    // (engine index order — deterministic and part of the trace
    // contract).
    let mut profile = EngineProfile::default();
    for e in engines.iter_mut() {
        profile.absorb(e.profile());
        if tracer.is_on() {
            tracer.merge(e.take_tracer());
        }
    }

    // ---- rollups -----------------------------------------------------
    // Serving window per the report contract: first arrival → last
    // response (requests ≥ 1 is ensured above, so an arrival exists).
    let duration = (last_done - first_arrival.min(last_done)).max(1e-9);
    // Close every open elastic residency: draining/active servers are
    // paid for until the last response.
    if let Some(el) = el.as_mut() {
        el.finish(last_done);
    }
    let mut energy = 0.0;
    for (i, (spec, e)) in specs.iter().zip(&engines).enumerate() {
        let st = e.state();
        // Elastic fleets pay idle power only for a server's resident
        // (active + draining) seconds; static fleets pay the whole
        // serving window on every server — the fig12 cost asymmetry.
        let dur_i = el.as_ref().map(|el| el.closed_secs[i]).unwrap_or(duration);
        // host_busy_secs is single-resource time (≤ duration up to the
        // window clamp); isp_busy_secs is deliberately unclamped — it
        // aggregates across all of the server's drives, so it
        // legitimately exceeds the window on ISP-heavy runs.
        energy += power
            .energy(dur_i, spec.sched.drives, st.host_busy_secs.min(dur_i), st.isp_busy_secs)
            .energy_j;
        metrics.merge(e.metrics());
    }
    let per_server: Vec<ServerServeStats> = specs
        .iter()
        .zip(&engines)
        .zip(served_per.iter().zip(&shed_per))
        .map(|((spec, e), (&served, &shed))| {
            let st = e.state();
            ServerServeStats {
                index: spec.index,
                is_csd: spec.is_csd(),
                served,
                shed,
                host_items: st.host_items,
                csd_items: st.csd_items,
                host_busy_secs: st.host_busy_secs,
                isp_busy_secs: st.isp_busy_secs,
            }
        })
        .collect();

    // Flash-management rollup (ISSUE-8): summed FTL counters and the
    // worst per-drive wear spread across every server's drives.
    let mut ftl = crate::csd::ftl::FtlStats::default();
    let mut wear_spread = 0u32;
    let mut ingest_writes = 0u64;
    for e in &engines {
        let (s, w) = e.ftl_rollup();
        ftl.absorb(&s);
        wear_spread = wear_spread.max(w);
        ingest_writes += e.ingest_writes();
    }

    // Elastic rollup (ISSUE-10). Static runs get the exact static
    // values: every server resident for the whole window, no joins,
    // drains, migrations, or timeline.
    let server_seconds = match &el {
        Some(el) => el.closed_secs.iter().sum(),
        None => fcfg.servers as f64 * duration,
    };
    let (peak_servers, migrations, migrated_bytes, joins, drains, timeline) = match el {
        Some(el) => {
            (el.peak_servers, el.migrations, el.migrated_bytes, el.joins, el.drains, el.timeline)
        }
        None => (fcfg.servers, 0, 0, 0, 0, Vec::new()),
    };

    let latency = LatencyStats::of(&latencies);
    metrics.inc("serve.requests", served as f64);
    metrics.inc("serve.shed", shed as f64);
    metrics.inc("serve.failed", failed as f64);
    metrics.inc("serve.retried", retried as f64);
    metrics.inc("serve.rack_bytes", rack.bytes_moved() as f64);
    metrics.set_gauge("serve.p99_latency_s", latency.p99);

    Ok(ServeReport {
        app: model.app.name(),
        shape: fcfg.shape.name(),
        dispatch: fcfg.sched.dispatch.name(),
        process: tcfg.process.name(),
        policy: tcfg.policy.name(),
        servers: fcfg.servers,
        requests: tcfg.requests,
        served,
        shed,
        failed,
        retried,
        hedged,
        duplicate_suppressed,
        completed_in_slo,
        availability: completed_in_slo as f64 / tcfg.requests as f64,
        admission: tcfg.admission,
        slo_p99_s: slo,
        offered_rps: offered,
        achieved_rps: served as f64 / duration,
        duration_secs: duration,
        latency,
        host_items: engines.iter().map(|e| e.state().host_items).sum(),
        csd_items: engines.iter().map(|e| e.state().csd_items).sum(),
        host_batches: engines.iter().map(|e| e.state().host_batches).sum(),
        csd_batches: engines.iter().map(|e| e.state().csd_batches).sum(),
        rack_bytes: rack.bytes_moved(),
        rack_messages: rack.messages(),
        energy_j: energy,
        energy_per_req_j: if served > 0 { energy / served as f64 } else { 0.0 },
        ingest_writes,
        waf: ftl.waf(),
        gc_runs: ftl.gc_runs,
        wear_spread,
        engine_events: profile.events,
        host_done_events: profile.host_done_events,
        csd_ack_events: profile.csd_ack_events,
        wake_events: profile.wake_events,
        flush_events: profile.flush_events,
        ingest_events: profile.ingest_events,
        max_queue_depth: profile.max_queue_depth,
        mean_queue_depth: profile.mean_queue_depth(),
        max_inflight: profile.max_inflight,
        per_server,
        server_seconds,
        peak_servers,
        migrations,
        migrated_bytes,
        joins,
        drains,
        timeline,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::fleet::FleetShape;
    use crate::sched::{DispatchMode, SchedConfig};
    use crate::traffic::ArrivalProcess;

    fn fleet_cfg(servers: usize, shape: FleetShape) -> FleetConfig {
        FleetConfig {
            servers,
            shape,
            sched: SchedConfig {
                csd_batch: 500,
                batch_ratio: 26.0,
                drives: 8,
                isp_drives: 8,
                dispatch: DispatchMode::EventDriven,
                ..SchedConfig::default()
            },
            ..FleetConfig::default()
        }
    }

    fn run(servers: usize, shape: FleetShape, policy: LbPolicy, load: f64) -> ServeReport {
        let tcfg = TrafficConfig {
            load,
            requests: 4_000,
            policy,
            ..TrafficConfig::default()
        };
        let mut m = Metrics::new();
        serve_fleet(App::Sentiment, &fleet_cfg(servers, shape), &tcfg, &PowerModel::default(), &mut m)
            .unwrap()
    }

    #[test]
    fn fleet_serving_conserves_across_policies_and_shapes() {
        for policy in LbPolicy::all() {
            for shape in FleetShape::all() {
                let r = run(3, shape, policy, 0.6);
                assert_eq!(r.served, 4_000, "{policy:?}/{shape:?}");
                assert_eq!(r.host_items + r.csd_items, 4_000);
                assert_eq!(r.per_server.iter().map(|s| s.served).sum::<u64>(), 4_000);
            }
        }
    }

    #[test]
    fn non_head_responses_pay_the_rack() {
        let multi = run(3, FleetShape::AllCsd, LbPolicy::RoundRobin, 0.5);
        assert!(multi.rack_messages > 0, "servers 1..n respond over the rack");
        assert!(multi.rack_bytes > 64 * multi.rack_messages);
        let single = run(1, FleetShape::AllCsd, LbPolicy::RoundRobin, 0.5);
        assert_eq!(single.rack_messages, 0, "a 1-server fleet never touches the rack");
        assert_eq!(single.rack_bytes, 0);
    }

    #[test]
    fn round_robin_spreads_requests_evenly() {
        let r = run(4, FleetShape::AllCsd, LbPolicy::RoundRobin, 0.5);
        for s in &r.per_server {
            assert_eq!(s.served, 1_000, "server {}", s.index);
        }
    }

    #[test]
    fn weighted_capacity_tracks_heterogeneous_fleets() {
        // Mixed fleet: CSD servers (even indices) have ~1.3× the nominal
        // capacity of SSD servers here, so weighted routing must give
        // them a proportionally larger share; the realized split tracks
        // the weight split within 2%.
        let r = run(4, FleetShape::Mixed, LbPolicy::WeightedCapacity, 0.5);
        let model = AppModel::for_app(App::Sentiment, 1);
        let csd_w = model.host_rate() + 8.0 * model.csd_rate();
        let ssd_w = model.host_rate();
        let want_csd_share = 2.0 * csd_w / (2.0 * csd_w + 2.0 * ssd_w);
        let got: u64 = r.per_server.iter().filter(|s| s.is_csd).map(|s| s.served).sum();
        let got_share = got as f64 / r.served as f64;
        assert!(
            (got_share - want_csd_share).abs() < 0.02,
            "csd share {got_share:.3}, capacity share {want_csd_share:.3}"
        );
    }

    #[test]
    fn explicit_weights_skew_the_weighted_balancer() {
        // Regression: `--weights` used to be validated and then ignored
        // by serving. With weights [3, 1] the weighted policy must
        // realize a 75/25 split regardless of the servers' (equal)
        // nominal rates.
        let fcfg = FleetConfig {
            weights: Some(vec![3, 1]),
            ..fleet_cfg(2, FleetShape::AllCsd)
        };
        let tcfg = TrafficConfig {
            load: 0.5,
            requests: 4_000,
            policy: LbPolicy::WeightedCapacity,
            ..TrafficConfig::default()
        };
        let mut m = Metrics::new();
        let r = serve_fleet(App::Sentiment, &fcfg, &tcfg, &PowerModel::default(), &mut m).unwrap();
        assert_eq!(r.per_server[0].served, 3_000);
        assert_eq!(r.per_server[1].served, 1_000);
    }

    #[test]
    fn jsq_beats_round_robin_tail_on_a_mixed_fleet_under_load() {
        // The scenario JSQ exists for: a mixed fleet where the CSD
        // server's in-storage engines give it real extra capacity. An
        // oblivious 50/50 rotation pushes the SSD server past its
        // capacity (its backlog grows for the whole run) while JSQ
        // steers the excess to the CSD server, so the rr tail must blow
        // past the jsq tail. The run is long enough (30 k requests at
        // ~fleet-nominal load) for the rr backlog to accumulate.
        let mk = |policy| TrafficConfig { load: 1.0, requests: 30_000, policy, ..TrafficConfig::default() };
        let mut m = Metrics::new();
        let fleet = fleet_cfg(2, FleetShape::Mixed);
        let rr = serve_fleet(App::Sentiment, &fleet, &mk(LbPolicy::RoundRobin), &PowerModel::default(), &mut m)
            .unwrap();
        let jsq = serve_fleet(
            App::Sentiment,
            &fleet,
            &mk(LbPolicy::JoinShortestQueue),
            &PowerModel::default(),
            &mut m,
        )
        .unwrap();
        assert_eq!(rr.served, jsq.served);
        assert!(
            jsq.latency.p99 < rr.latency.p99,
            "jsq p99 {} should beat rr p99 {} on a skewed fleet",
            jsq.latency.p99,
            rr.latency.p99
        );
    }

    /// A speech serving fleet: the app whose per-request service times
    /// (hundreds of ms) make admission bounds small enough to exercise
    /// with a few thousand requests. csd_batch = 2 is the speech
    /// scale-out operating point, so the default SLO (4× the CSD batch
    /// service time ≈ 26.8 s) is realistic.
    fn speech_fleet(servers: usize, shape: FleetShape) -> FleetConfig {
        FleetConfig {
            servers,
            shape,
            sched: SchedConfig {
                csd_batch: 2,
                batch_ratio: 19.0,
                drives: 8,
                isp_drives: 8,
                dispatch: DispatchMode::EventDriven,
                ..SchedConfig::default()
            },
            ..FleetConfig::default()
        }
    }

    #[test]
    fn least_work_beats_jsq_goodput_on_skewed_mixed_fleet_under_overload() {
        // The ISSUE-5 gate. Mixed fleet, hot-shard skew, sustained
        // bursty overload, admission on. JSQ counts requests, so once
        // the slow SSD server's queue freezes at its (lower) admission
        // bound, JSQ pins on it as the "shortest" queue and sheds
        // requests the CSD server still had deadline headroom for;
        // least-work routes on estimated backlog *time*, fills every
        // server to its own bound, and therefore accepts strictly more.
        let mk = |policy| TrafficConfig {
            process: ArrivalProcess::Bursty,
            load: 1.3,
            requests: 6_000,
            admission: true,
            skew: 1.0,
            policy,
            ..TrafficConfig::default()
        };
        let fleet = speech_fleet(2, FleetShape::Mixed);
        let mut m = Metrics::new();
        let jsq = serve_fleet(
            App::SpeechToText,
            &fleet,
            &mk(LbPolicy::JoinShortestQueue),
            &PowerModel::default(),
            &mut m,
        )
        .unwrap();
        let lw = serve_fleet(
            App::SpeechToText,
            &fleet,
            &mk(LbPolicy::LeastWork),
            &PowerModel::default(),
            &mut m,
        )
        .unwrap();
        for r in [&jsq, &lw] {
            assert_eq!(r.served + r.shed, 6_000, "{}: exact admission accounting", r.policy);
            assert!(r.shed > 0, "{}: sustained overload must shed", r.policy);
        }
        assert!(
            lw.served > jsq.served,
            "least-work goodput {} (shed {}) should beat jsq {} (shed {})",
            lw.served,
            lw.shed,
            jsq.served,
            jsq.shed
        );
    }

    #[test]
    fn admission_bounds_the_tail_the_open_loop_otherwise_blows() {
        // Same overloaded open-loop run ± admission: without it the
        // queue (and every percentile) grows with the run; with it the
        // accepted requests' p99 stays near the deadline budget and the
        // loss shows up as shed count instead.
        let mk = |admission| TrafficConfig {
            load: 1.4,
            requests: 5_000,
            admission,
            ..TrafficConfig::default()
        };
        let fleet = speech_fleet(2, FleetShape::AllCsd);
        let mut m = Metrics::new();
        let open =
            serve_fleet(App::SpeechToText, &fleet, &mk(false), &PowerModel::default(), &mut m)
                .unwrap();
        let gated =
            serve_fleet(App::SpeechToText, &fleet, &mk(true), &PowerModel::default(), &mut m)
                .unwrap();
        assert_eq!(open.shed, 0, "admission off never sheds");
        assert_eq!(open.served, 5_000);
        assert!(gated.shed > 0, "overload under admission shows up as shed");
        assert_eq!(gated.served + gated.shed, 5_000);
        assert!(
            gated.latency.p99 < open.latency.p99,
            "admission p99 {} should be far below the open-loop blowup {}",
            gated.latency.p99,
            open.latency.p99
        );
        assert!(
            gated.latency.p99 <= 2.0 * gated.slo_p99_s,
            "accepted p99 {} should sit near the deadline budget {}",
            gated.latency.p99,
            gated.slo_p99_s
        );
    }

    /// ISSUE-8: fleet serving with the ingest stream on — updates fire
    /// on every server, request conservation is untouched, the FTL
    /// counters reach the report, and the whole run is bit-identical
    /// across repeats (the comparator now covers waf/gc_runs/
    /// wear_spread/ingest_writes too).
    #[test]
    fn ingest_stream_conserves_and_is_bit_identical() {
        let mk = || TrafficConfig {
            load: 0.6,
            requests: 2_000,
            ingest_rate: 500.0,
            ..TrafficConfig::default()
        };
        let fleet = fleet_cfg(2, FleetShape::AllCsd);
        let mut m = Metrics::new();
        let a = serve_fleet(App::Sentiment, &fleet, &mk(), &PowerModel::default(), &mut m).unwrap();
        let b = serve_fleet(App::Sentiment, &fleet, &mk(), &PowerModel::default(), &mut m).unwrap();
        a.check_bit_identical(&b).unwrap();
        assert_eq!(a.served, 2_000, "updates never eat requests");
        assert!(a.ingest_writes > 0, "the stream must fire during the window");
        assert!(a.waf >= 1.0, "flash writes can only amplify");
        let quiet =
            serve_fleet(App::Sentiment, &fleet, &TrafficConfig { ingest_rate: 0.0, ..mk() },
                &PowerModel::default(), &mut m)
            .unwrap();
        assert_eq!(quiet.ingest_writes, 0, "rate 0 arms nothing");
    }

    #[test]
    fn closed_loop_fleet_conserves() {
        let tcfg = TrafficConfig {
            process: ArrivalProcess::ClosedLoop,
            clients: 32,
            think_s: 0.05,
            requests: 2_000,
            ..TrafficConfig::default()
        };
        let mut m = Metrics::new();
        let r = serve_fleet(
            App::Sentiment,
            &fleet_cfg(2, FleetShape::AllCsd),
            &tcfg,
            &PowerModel::default(),
            &mut m,
        )
        .unwrap();
        assert_eq!(r.served, 2_000);
    }

    #[test]
    fn rejects_nonsense() {
        let mut m = Metrics::new();
        let tcfg = TrafficConfig::default();
        let bad = FleetConfig { servers: 0, ..fleet_cfg(1, FleetShape::AllCsd) };
        assert!(serve_fleet(App::Sentiment, &bad, &tcfg, &PowerModel::default(), &mut m).is_err());
        let zero_req = TrafficConfig { requests: 0, ..TrafficConfig::default() };
        let ok = fleet_cfg(1, FleetShape::AllCsd);
        assert!(
            serve_fleet(App::Sentiment, &ok, &zero_req, &PowerModel::default(), &mut m).is_err()
        );
        // rate_rps is meaningless for a closed loop: rejected, not
        // silently ignored.
        let closed_rate = TrafficConfig {
            process: ArrivalProcess::ClosedLoop,
            rate_rps: Some(100.0),
            ..TrafficConfig::default()
        };
        assert!(
            serve_fleet(App::Sentiment, &ok, &closed_rate, &PowerModel::default(), &mut m).is_err()
        );
        // ISSUE-5 satellite: degenerate serving parameters fail loudly.
        let neg_skew = TrafficConfig { skew: -1.0, ..TrafficConfig::default() };
        assert!(
            serve_fleet(App::Sentiment, &ok, &neg_skew, &PowerModel::default(), &mut m).is_err()
        );
        // min_batch beyond one server's single-dispatch drain capacity
        // (host 500×26 + 8×500 = 17_000 for this fleet template).
        let big_min = TrafficConfig { min_batch: 17_001, ..TrafficConfig::default() };
        assert!(
            serve_fleet(App::Sentiment, &ok, &big_min, &PowerModel::default(), &mut m).is_err()
        );
        let bad_slo = TrafficConfig { slo_p99_s: Some(0.0), ..TrafficConfig::default() };
        assert!(
            serve_fleet(App::Sentiment, &ok, &bad_slo, &PowerModel::default(), &mut m).is_err()
        );
        // empty weight vectors are rejected with a clear error
        let empty_w = FleetConfig { weights: Some(vec![]), ..fleet_cfg(1, FleetShape::AllCsd) };
        let err = serve_fleet(
            App::Sentiment,
            &empty_w,
            &TrafficConfig::default(),
            &PowerModel::default(),
            &mut m,
        )
        .unwrap_err();
        assert!(err.to_string().contains("empty"), "unhelpful error: {err}");
    }

    // ---- ISSUE-6: chaos / resilience --------------------------------

    use crate::faults::FaultsConfig;

    /// A single-server crash at 25% of the arrival window.
    fn crash_faults() -> FaultsConfig {
        FaultsConfig { server_crash_at: Some(0.25), crash_server: 0, ..FaultsConfig::default() }
    }

    #[test]
    fn server_crash_without_resilience_loses_requests() {
        // No retries, no hedging, no replicas: everything routed to the
        // crashed server after its crash instant (and everything it had
        // in flight) is simply lost — conservation must still hold, as
        // `failed`, never as a hang or a leak.
        let tcfg = TrafficConfig {
            load: 0.6,
            requests: 4_000,
            policy: LbPolicy::RoundRobin,
            faults: Some(crash_faults()),
            ..TrafficConfig::default()
        };
        let mut m = Metrics::new();
        let r = serve_fleet(
            App::Sentiment,
            &fleet_cfg(4, FleetShape::AllCsd),
            &tcfg,
            &PowerModel::default(),
            &mut m,
        )
        .unwrap();
        assert_eq!(r.served + r.failed + r.shed, 4_000, "conservation under crash");
        assert!(r.failed > 0, "a dead server with no resilience must lose requests");
        assert!(
            r.availability < 0.99,
            "no-resilience availability {} should be visibly degraded",
            r.availability
        );
        assert_eq!(r.retried, 0);
        assert_eq!(r.hedged, 0);
    }

    #[test]
    fn retry_failover_recovers_a_crashed_server() {
        // The full resilience stack: deadline-aware retries, hedging,
        // and one replica per shard. The front door detects the dead
        // server by missed acks, fails its shards over to the neighbor,
        // and steers new arrivals away — availability recovers past the
        // fig11 gate's 99% bar.
        let fcfg = FleetConfig { replicas: 1, ..fleet_cfg(4, FleetShape::AllCsd) };
        let tcfg = TrafficConfig {
            load: 0.6,
            requests: 4_000,
            policy: LbPolicy::RoundRobin,
            retries: 3,
            hedge: true,
            faults: Some(crash_faults()),
            ..TrafficConfig::default()
        };
        let mut m = Metrics::new();
        let r = serve_fleet(App::Sentiment, &fcfg, &tcfg, &PowerModel::default(), &mut m).unwrap();
        assert_eq!(r.served + r.failed + r.shed, 4_000);
        assert!(r.retried > 0, "recovery must go through retries");
        assert!(
            r.availability >= 0.99,
            "resilient availability {} (served {}, failed {}) should clear 99%",
            r.availability,
            r.served,
            r.failed
        );
        assert!(r.per_server[0].served < r.per_server[1].served, "traffic left the dead server");
    }

    #[test]
    fn ack_loss_is_absorbed_by_retries() {
        // Lossy drive acks on a single server: every lost batch times
        // out at the front door and the retry budget replays it — no
        // request may be lost, and the loss shows up in `retried`.
        let tcfg = TrafficConfig {
            load: 0.5,
            requests: 2_000,
            retries: 5,
            faults: Some(FaultsConfig { ack_loss: 0.05, ..FaultsConfig::default() }),
            ..TrafficConfig::default()
        };
        let mut m = Metrics::new();
        let r = serve_fleet(
            App::Sentiment,
            &fleet_cfg(1, FleetShape::AllCsd),
            &tcfg,
            &PowerModel::default(),
            &mut m,
        )
        .unwrap();
        assert_eq!(r.served, 2_000, "retries must recover every lost ack (failed {})", r.failed);
        assert_eq!(r.failed, 0);
        assert!(r.retried > 0, "a 5% ack-loss run must actually retry");
    }

    #[test]
    fn duplicated_rack_messages_are_suppressed() {
        // Heavy link duplication: every response still counts exactly
        // once (first copy wins), the spurious copies are tallied, and
        // both copies pay rack bandwidth.
        let mk = |dup| TrafficConfig {
            load: 0.5,
            requests: 2_000,
            policy: LbPolicy::RoundRobin,
            faults: Some(FaultsConfig { link_dup: dup, ..FaultsConfig::default() }),
            ..TrafficConfig::default()
        };
        let mut m = Metrics::new();
        let fleet = fleet_cfg(2, FleetShape::AllCsd);
        let clean =
            serve_fleet(App::Sentiment, &fleet, &mk(0.0), &PowerModel::default(), &mut m).unwrap();
        let dup =
            serve_fleet(App::Sentiment, &fleet, &mk(0.5), &PowerModel::default(), &mut m).unwrap();
        for r in [&clean, &dup] {
            assert_eq!(r.served, 2_000);
            assert_eq!(r.failed, 0);
        }
        assert_eq!(clean.duplicate_suppressed, 0);
        assert!(dup.duplicate_suppressed > 0, "duplicates must be counted, not double-served");
        assert!(dup.rack_bytes > clean.rack_bytes, "the spurious copy pays the rack");
    }

    #[test]
    fn drive_stalls_delay_but_never_lose() {
        // Transient drive stalls: acks arrive late, nothing is lost,
        // no resilience machinery required.
        let tcfg = TrafficConfig {
            load: 0.5,
            requests: 2_000,
            faults: Some(FaultsConfig { stall: 0.2, stall_s: 0.05, ..FaultsConfig::default() }),
            ..TrafficConfig::default()
        };
        let mut m = Metrics::new();
        let fleet = fleet_cfg(1, FleetShape::AllCsd);
        let r =
            serve_fleet(App::Sentiment, &fleet, &tcfg, &PowerModel::default(), &mut m).unwrap();
        assert_eq!(r.served, 2_000);
        assert_eq!(r.failed, 0);
        let clean = serve_fleet(
            App::Sentiment,
            &fleet,
            &TrafficConfig { faults: None, ..tcfg },
            &PowerModel::default(),
            &mut m,
        )
        .unwrap();
        assert!(
            r.latency.p99 > clean.latency.p99,
            "stalls must show up in the tail: {} vs {}",
            r.latency.p99,
            clean.latency.p99
        );
    }

    #[test]
    fn faulted_runs_are_deterministic() {
        // Same (config, fault seed) twice → bit-identical reports, even
        // under heavy mixed faults.
        let fcfg = FleetConfig { replicas: 1, ..fleet_cfg(3, FleetShape::AllCsd) };
        let tcfg = TrafficConfig {
            load: 0.6,
            requests: 2_000,
            retries: 2,
            hedge: true,
            faults: Some(FaultsConfig {
                ack_loss: 0.05,
                stall: 0.05,
                stall_s: 0.02,
                link_drop: 0.02,
                link_dup: 0.02,
                server_crash_at: Some(0.5),
                rejoin_s: Some(2.0),
                ..FaultsConfig::default()
            }),
            ..TrafficConfig::default()
        };
        let mut m = Metrics::new();
        let a = serve_fleet(App::Sentiment, &fcfg, &tcfg, &PowerModel::default(), &mut m).unwrap();
        let b = serve_fleet(App::Sentiment, &fcfg, &tcfg, &PowerModel::default(), &mut m).unwrap();
        a.check_bit_identical(&b).unwrap();
        assert_eq!(a.served + a.failed + a.shed, 2_000);
    }

    #[test]
    fn rejects_nonsense_resilience_params() {
        let mut m = Metrics::new();
        let ok = fleet_cfg(2, FleetShape::AllCsd);
        // replicas must leave a distinct neighbor
        let bad_rep = FleetConfig { replicas: 2, ..fleet_cfg(2, FleetShape::AllCsd) };
        assert!(serve_fleet(
            App::Sentiment,
            &bad_rep,
            &TrafficConfig::default(),
            &PowerModel::default(),
            &mut m
        )
        .is_err());
        // retry timeout must be positive and finite
        let bad_to =
            TrafficConfig { retry_timeout_s: Some(0.0), retries: 1, ..TrafficConfig::default() };
        assert!(
            serve_fleet(App::Sentiment, &ok, &bad_to, &PowerModel::default(), &mut m).is_err()
        );
        // fault plans are validated against the fleet
        let bad_faults = TrafficConfig {
            faults: Some(FaultsConfig {
                server_crash_at: Some(0.5),
                crash_server: 7,
                ..FaultsConfig::default()
            }),
            ..TrafficConfig::default()
        };
        assert!(
            serve_fleet(App::Sentiment, &ok, &bad_faults, &PowerModel::default(), &mut m).is_err()
        );
    }

    // ---- ISSUE-10: elastic fleet ------------------------------------

    use crate::traffic::elastic::{AutoscaleConfig, AutoscalePolicy};

    /// One CSD server's nominal rate under the test fleet template —
    /// the unit the elastic tests express durations and rates in, so
    /// they stay valid if the app model's constants move.
    fn base_rate() -> f64 {
        let model = AppModel::for_app(App::Sentiment, 1);
        crate::traffic::nominal_rate(&model, &fleet_cfg(1, FleetShape::AllCsd).sched)
    }

    /// Ramp + decay traffic over an elastic 1→4 fleet: low load, a
    /// 2.5× flash, then low again — the autoscaler must join on the
    /// flash and drain back down on the decay.
    fn elastic_tcfg(policy: AutoscalePolicy) -> TrafficConfig {
        let base = base_rate();
        TrafficConfig {
            rate_rps: Some(base),
            rate_segments: Some(vec![
                (500.0 / base, 0.4),
                (600.0 / base, 2.5),
                (2_000.0 / base, 0.4),
            ]),
            requests: 2_500,
            policy: LbPolicy::LeastWork,
            autoscale: Some(AutoscaleConfig {
                policy,
                min_servers: 1,
                max_servers: 4,
                check_interval_s: 200.0 / base,
                estimator_window_s: 600.0 / base,
                target_util: 0.75,
                ..AutoscaleConfig::default()
            }),
            ..TrafficConfig::default()
        }
    }

    #[test]
    fn autoscaler_joins_on_a_flash_and_drains_on_the_decay() {
        let tcfg = elastic_tcfg(AutoscalePolicy::Predictive);
        let mut m = Metrics::new();
        let r = serve_fleet(
            App::Sentiment,
            &fleet_cfg(1, FleetShape::AllCsd),
            &tcfg,
            &PowerModel::default(),
            &mut m,
        )
        .unwrap();
        assert_eq!(r.served + r.failed + r.shed, 2_500, "conservation through joins/drains");
        assert!(r.joins >= 1, "the flash must grow the fleet (joins {})", r.joins);
        assert!(r.drains >= 1, "the decay must shrink it (drains {})", r.drains);
        assert!(r.peak_servers > 1 && r.peak_servers <= 4, "peak {}", r.peak_servers);
        assert!(r.migrations > 0, "joins/drains rehome shards");
        assert!(r.migrated_bytes > 0);
        assert!(!r.timeline.is_empty(), "elastic runs emit the fleet time series");
        // The elastic fleet pays for strictly less than keeping the
        // peak fleet resident the whole run.
        assert!(
            r.server_seconds < r.peak_servers as f64 * r.duration_secs,
            "server-seconds {} vs peak-static {}",
            r.server_seconds,
            r.peak_servers as f64 * r.duration_secs
        );
        assert!(r.server_seconds > 0.0);
    }

    #[test]
    fn reactive_policy_also_scales_and_both_are_deterministic() {
        for policy in AutoscalePolicy::all() {
            let tcfg = elastic_tcfg(policy);
            let fleet = fleet_cfg(1, FleetShape::AllCsd);
            let mut m = Metrics::new();
            let a =
                serve_fleet(App::Sentiment, &fleet, &tcfg, &PowerModel::default(), &mut m).unwrap();
            let b =
                serve_fleet(App::Sentiment, &fleet, &tcfg, &PowerModel::default(), &mut m).unwrap();
            a.check_bit_identical(&b)
                .unwrap_or_else(|e| panic!("{}: elastic rerun diverged: {e}", policy.name()));
            assert_eq!(a.served + a.failed + a.shed, 2_500, "{}", policy.name());
            assert!(a.joins >= 1, "{}: joins {}", policy.name(), a.joins);
        }
    }

    #[test]
    fn rebalancer_migrates_hot_shards_off_a_skewed_server() {
        // Fixed-size fleet (min == max: the autoscaler cannot resize),
        // heavy shard skew: the rebalancer alone must fire, and every
        // migration pays the rack link.
        let base = base_rate();
        let tcfg = TrafficConfig {
            rate_rps: Some(base),
            requests: 3_000,
            skew: 1.5,
            autoscale: Some(AutoscaleConfig {
                min_servers: 2,
                max_servers: 2,
                check_interval_s: 200.0 / base,
                estimator_window_s: 600.0 / base,
                shards: 8,
                rebalance_threshold: 0.6,
                ..AutoscaleConfig::default()
            }),
            ..TrafficConfig::default()
        };
        let fleet = fleet_cfg(2, FleetShape::AllCsd);
        let mut m = Metrics::new();
        let r = serve_fleet(App::Sentiment, &fleet, &tcfg, &PowerModel::default(), &mut m).unwrap();
        assert_eq!(r.served + r.failed + r.shed, 3_000);
        assert_eq!(r.joins, 0, "min == max: membership never changes");
        assert_eq!(r.drains, 0);
        assert!(r.migrations > 0, "a 0.69 routed share must trip the 0.6 threshold");
        assert!(r.migrated_bytes > 0, "migrations ship shard bytes");
        let off = TrafficConfig {
            autoscale: tcfg.autoscale.clone().map(|a| AutoscaleConfig { rebalance: false, ..a }),
            ..tcfg.clone()
        };
        let quiet =
            serve_fleet(App::Sentiment, &fleet, &off, &PowerModel::default(), &mut m).unwrap();
        assert_eq!(quiet.migrations, 0, "rebalance off never migrates");
        assert!(
            r.rack_bytes > quiet.rack_bytes,
            "migration traffic must show up on the rack: {} vs {}",
            r.rack_bytes,
            quiet.rack_bytes
        );
    }

    #[test]
    fn elastic_rejects_nonsense() {
        let mut m = Metrics::new();
        let fleet = fleet_cfg(2, FleetShape::AllCsd);
        // autoscale knobs are validated at the serve entry point too
        let bad = TrafficConfig {
            autoscale: Some(AutoscaleConfig {
                min_servers: 5,
                max_servers: 2,
                ..AutoscaleConfig::default()
            }),
            ..TrafficConfig::default()
        };
        assert!(serve_fleet(App::Sentiment, &fleet, &bad, &PowerModel::default(), &mut m).is_err());
        // explicit weights are incompatible with elastic membership
        let weighted = FleetConfig { weights: Some(vec![2, 1]), ..fleet_cfg(2, FleetShape::AllCsd) };
        let auto = TrafficConfig {
            autoscale: Some(AutoscaleConfig::default()),
            ..TrafficConfig::default()
        };
        assert!(
            serve_fleet(App::Sentiment, &weighted, &auto, &PowerModel::default(), &mut m).is_err()
        );
        // rate segments must be positive, finite, and Poisson-only
        for segs in [
            vec![],
            vec![(0.0, 1.0)],
            vec![(1.0, -2.0)],
            vec![(f64::INFINITY, 1.0)],
            vec![(1.0, f64::NAN)],
        ] {
            let t = TrafficConfig { rate_segments: Some(segs), ..TrafficConfig::default() };
            assert!(
                serve_fleet(App::Sentiment, &fleet, &t, &PowerModel::default(), &mut m).is_err()
            );
        }
        let bursty_segs = TrafficConfig {
            process: ArrivalProcess::Bursty,
            rate_segments: Some(vec![(1.0, 1.0)]),
            ..TrafficConfig::default()
        };
        assert!(
            serve_fleet(App::Sentiment, &fleet, &bursty_segs, &PowerModel::default(), &mut m)
                .is_err()
        );
    }
}
