//! Live execution mode: the scheduler protocol running on *real* OS
//! threads with *real* PJRT inference — no virtual time anywhere.
//!
//! This is the composition proof for the three-layer architecture: the
//! rust coordinator (rank 0) trains the sentiment model through the AOT
//! `sentiment_train_step` executable, broadcasts the weights to worker
//! ranks (stand-ins for ISP engines, each owning its own PJRT client
//! exactly like each CSD owns its own runtime), then drives the paper's
//! pull/ack protocol: index-only batch dispatch, 0.2 s polling loop,
//! batch-ratio-sized host batches processed on the coordinator itself.
//! Python never runs — everything on the request path is this binary.

use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::cluster::mpi::{self, tag, Communicator};
use crate::nlp::corpus::{Tweet, TweetCorpus};
use crate::runtime::{Engine, Tensor};
use crate::workloads::SentimentApp;

/// Live-mode configuration.
#[derive(Clone, Debug)]
pub struct LiveConfig {
    /// Worker threads (simulated ISP engines).
    pub workers: usize,
    /// Items per worker batch.
    pub batch: usize,
    /// Host batch = ratio × batch (processed on the coordinator).
    pub ratio: usize,
    /// Total tweets to serve.
    pub items: usize,
    /// Scheduler polling period (paper: 0.2 s).
    pub wakeup: Duration,
    /// Training set size.
    pub train_items: usize,
    pub seed: u64,
}

impl Default for LiveConfig {
    fn default() -> Self {
        LiveConfig {
            workers: 2,
            batch: 64,
            ratio: 4,
            items: 4_096,
            wakeup: Duration::from_millis(200),
            train_items: 2_048,
            seed: 11,
        }
    }
}

/// Outcome of a live run.
#[derive(Clone, Debug)]
pub struct LiveReport {
    pub items: usize,
    pub wall_secs: f64,
    pub items_per_sec: f64,
    pub host_items: usize,
    pub worker_items: Vec<usize>,
    pub accuracy: f64,
    pub messages: u64,
}

/// Worker rank body: receive weights, then serve index batches until
/// shutdown. Each worker builds its own [`Engine`] — one runtime per
/// (simulated) device, like each CSD's ISP runs its own binary.
fn worker_main(
    mut comm: Communicator,
    corpus: Arc<Vec<Tweet>>,
    features: usize,
) -> anyhow::Result<usize> {
    let mut eng = Engine::load(crate::runtime::default_artifacts_dir())?;
    // weights arrive first
    let weights = loop {
        let p = comm.recv().map_err(|e| anyhow::anyhow!("{e}"))?;
        match p.tag {
            tag::WEIGHTS => break mpi::decode_f32s(&p.payload).map_err(|e| anyhow::anyhow!("{e}"))?,
            tag::SHUTDOWN => return Ok(0),
            _ => continue,
        }
    };
    let (w_raw, b_raw) = weights.split_at(features);
    let app = SentimentApp::from_weights(
        features,
        Tensor::new(vec![features, 1], w_raw.to_vec()),
        Tensor::new(vec![1], b_raw.to_vec()),
    );
    let mut served = 0usize;
    // initial ack announces readiness (the pull in "pull-based")
    comm.send(0, tag::RESULT, Vec::new()).map_err(|e| anyhow::anyhow!("{e}"))?;
    loop {
        let p = comm.recv().map_err(|e| anyhow::anyhow!("{e}"))?;
        match p.tag {
            tag::BATCH => {
                let idxs = mpi::decode_u32s(&p.payload).map_err(|e| anyhow::anyhow!("{e}"))?;
                let texts: Vec<&str> =
                    idxs.iter().map(|&i| corpus[i as usize].text.as_str()).collect();
                let probs = app.predict(&mut eng, &texts)?;
                served += idxs.len();
                // result = one byte per item (the label) + ack semantics
                let labels: Vec<u8> = probs.iter().map(|p| u8::from(*p > 0.5)).collect();
                let mut payload = mpi::encode_u32s(&idxs);
                payload.extend_from_slice(&labels);
                comm.send(0, tag::RESULT, payload).map_err(|e| anyhow::anyhow!("{e}"))?;
            }
            tag::SHUTDOWN => return Ok(served),
            _ => {}
        }
    }
}

/// Run the live cluster; requires `make artifacts`.
pub fn run_live(cfg: &LiveConfig) -> anyhow::Result<LiveReport> {
    anyhow::ensure!(cfg.workers >= 1, "need at least one worker");
    let mut eng = Engine::load(crate::runtime::default_artifacts_dir())?;
    let features = eng.manifest.dim("sent_features")? as usize;

    // Corpus: train split + serving split (deterministic).
    let mut gen = TweetCorpus::new(cfg.seed);
    let train = gen.take(cfg.train_items);
    let serve: Arc<Vec<Tweet>> = Arc::new(gen.take(cfg.items));

    // Train on the coordinator through the AOT SGD step.
    let (app, _losses) = SentimentApp::train(&mut eng, &train, 3, cfg.seed)?;

    // Spawn workers.
    let mut comms = mpi::group(cfg.workers + 1);
    let mut handles = Vec::new();
    for comm in comms.drain(1..) {
        let corpus = Arc::clone(&serve);
        handles.push(std::thread::spawn(move || worker_main(comm, corpus, features)));
    }
    let mut c0 = comms.pop().unwrap();

    // Broadcast weights (w ++ b as f32 LE).
    let mut weights = app.w.data.clone();
    weights.extend_from_slice(&app.b.data);
    c0.bcast(tag::WEIGHTS, &mpi::encode_f32s(&weights))
        .map_err(|e| anyhow::anyhow!("{e}"))?;

    // Pull/ack dispatch loop.
    let t0 = Instant::now();
    let mut next = 0usize;
    let mut done = vec![false; cfg.items];
    let mut completed = 0usize;
    let mut host_items = 0usize;
    let mut worker_items = vec![0usize; cfg.workers];
    let mut correct = 0usize;
    while completed < cfg.items {
        // Drain worker messages for up to one wakeup period.
        match c0.recv_timeout(cfg.wakeup) {
            Ok(p) if p.tag == tag::RESULT => {
                let worker = p.src - 1;
                if !p.payload.is_empty() {
                    let n_idx = p.payload.len() / 5; // 4B index + 1B label
                    let (idx_bytes, labels) = p.payload.split_at(4 * n_idx);
                    let idxs = mpi::decode_u32s(idx_bytes).map_err(|e| anyhow::anyhow!("{e}"))?;
                    for (i, &idx) in idxs.iter().enumerate() {
                        let idx = idx as usize;
                        anyhow::ensure!(!done[idx], "item {idx} served twice");
                        done[idx] = true;
                        completed += 1;
                        worker_items[worker] += 1;
                        if (labels[i] == 1) == serve[idx].positive {
                            correct += 1;
                        }
                    }
                }
                // Re-arm this worker with the next batch.
                if next < cfg.items {
                    let hi = (next + cfg.batch).min(cfg.items);
                    let idxs: Vec<u32> = (next..hi).map(|i| i as u32).collect();
                    next = hi;
                    c0.send(p.src, tag::BATCH, mpi::encode_u32s(&idxs))
                        .map_err(|e| anyhow::anyhow!("{e}"))?;
                }
            }
            Ok(_) => {}
            Err(mpi::MpiError::Timeout) => {}
            Err(e) => anyhow::bail!("coordinator recv: {e}"),
        }
        // Host processes its own (ratio-sized) batch between polls.
        if next < cfg.items {
            let hi = (next + cfg.batch * cfg.ratio).min(cfg.items);
            let idxs: Vec<usize> = (next..hi).collect();
            next = hi;
            let texts: Vec<&str> = idxs.iter().map(|&i| serve[i].text.as_str()).collect();
            let probs = app.predict(&mut eng, &texts)?;
            for (k, &idx) in idxs.iter().enumerate() {
                anyhow::ensure!(!done[idx], "item {idx} served twice");
                done[idx] = true;
                completed += 1;
                host_items += 1;
                if (probs[k] > 0.5) == serve[idx].positive {
                    correct += 1;
                }
            }
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    c0.bcast(tag::SHUTDOWN, &[]).map_err(|e| anyhow::anyhow!("{e}"))?;
    for h in handles {
        h.join().expect("worker panicked")?;
    }
    let (sent, received) = c0.stats();
    Ok(LiveReport {
        items: cfg.items,
        wall_secs: wall,
        items_per_sec: cfg.items as f64 / wall,
        host_items,
        worker_items,
        accuracy: correct as f64 / cfg.items as f64,
        messages: sent + received,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn live_cluster_serves_everything_exactly_once() {
        if Engine::load_default().is_none() {
            return; // artifacts not built
        }
        let cfg = LiveConfig {
            workers: 2,
            batch: 32,
            ratio: 4,
            items: 1_024,
            train_items: 1_024,
            wakeup: Duration::from_millis(50),
            seed: 3,
        };
        let r = run_live(&cfg).unwrap();
        assert_eq!(r.items, 1_024);
        let worker_total: usize = r.worker_items.iter().sum();
        assert_eq!(r.host_items + worker_total, 1_024);
        assert!(r.accuracy > 0.85, "accuracy {}", r.accuracy);
        assert!(r.items_per_sec > 0.0);
        assert!(
            worker_total > 0,
            "workers served some batches: {:?}",
            r.worker_items
        );
    }
}
