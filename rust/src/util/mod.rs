//! Small self-contained utilities: deterministic PRNG, statistics,
//! byte-size formatting. No external dependencies — the offline build
//! environment has no `rand`, so [`rng::Rng`] (xoshiro256++) is the
//! crate-wide randomness source. Everything here is deterministic given a
//! seed, which the simulator relies on for reproducible experiments.

pub mod rng;
pub mod stats;

pub use rng::Rng;
pub use stats::Summary;

/// Fast hasher for integer-keyed hot-path maps (FTL page tables): a
/// splitmix64 finalizer instead of SipHash. Keys are u64 page numbers /
/// small structs — DoS resistance is irrelevant, lookup latency is not
/// (§Perf: the per-page device loop is the simulator's hottest path).
#[derive(Clone, Copy, Debug, Default)]
pub struct FastHasher(u64);

impl std::hash::Hasher for FastHasher {
    #[inline]
    fn finish(&self) -> u64 {
        let mut z = self.0;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 = (self.0 ^ b as u64).wrapping_mul(0x100000001b3);
        }
    }

    #[inline]
    fn write_u64(&mut self, v: u64) {
        self.0 = self.0.rotate_left(31) ^ v.wrapping_mul(0x9E3779B97F4A7C15);
    }

    #[inline]
    fn write_u32(&mut self, v: u32) {
        self.write_u64(v as u64);
    }

    #[inline]
    fn write_u16(&mut self, v: u16) {
        self.write_u64(v as u64);
    }
}

/// `HashMap` with the fast integer hasher.
pub type FastMap<K, V> =
    std::collections::HashMap<K, V, std::hash::BuildHasherDefault<FastHasher>>;

/// Deterministic-iteration escape hatch for hash maps (lint rule D1
/// `hash-iter`): collect the entries and sort by key before anything
/// order-sensitive can observe them. Generic over the hasher, so it
/// serves both std `HashMap` and [`FastMap`]. Keyed lookup on a hash
/// map stays free; *iteration* goes through here (or a `BTreeMap`).
pub fn sorted_pairs<'a, K: Ord, V, S: std::hash::BuildHasher>(
    m: &'a std::collections::HashMap<K, V, S>,
) -> Vec<(&'a K, &'a V)> {
    // solana-lint: allow(hash-iter, reason = "the one sanctioned hash-map iteration: entries are sorted by key before any order-sensitive code can observe them")
    let mut v: Vec<(&'a K, &'a V)> = m.iter().collect();
    v.sort_by(|a, b| a.0.cmp(b.0));
    v
}

/// Format a byte count as a human-readable string (binary units).
pub fn human_bytes(bytes: u64) -> String {
    const UNITS: [&str; 6] = ["B", "KiB", "MiB", "GiB", "TiB", "PiB"];
    if bytes < 1024 {
        return format!("{bytes} B");
    }
    let mut v = bytes as f64;
    let mut unit = 0;
    while v >= 1024.0 && unit < UNITS.len() - 1 {
        v /= 1024.0;
        unit += 1;
    }
    format!("{v:.2} {}", UNITS[unit])
}

/// Format a duration in seconds with an adaptive unit.
pub fn human_secs(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1} ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2} µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2} ms", secs * 1e3)
    } else if secs < 120.0 {
        format!("{secs:.2} s")
    } else {
        format!("{:.1} min", secs / 60.0)
    }
}

/// Integer ceiling division.
pub fn div_ceil(a: u64, b: u64) -> u64 {
    debug_assert!(b > 0);
    (a + b - 1) / b
}

/// `ceil(a * b / d)` with the intermediate product widened to u128, for
/// proportional shares of item counts whose product overflows u64 (the
/// scheduler's pass-0 host share at paper-scale corpora: both factors
/// can exceed 2^32). The result must fit u64 — guaranteed whenever
/// `min(a, b) <= d`, which holds for any proportional share.
pub fn mul_div_ceil(a: u64, b: u64, d: u64) -> u64 {
    debug_assert!(d > 0);
    let p = a as u128 * b as u128;
    ((p + (d as u128 - 1)) / d as u128) as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn human_bytes_units() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(2048), "2.00 KiB");
        assert_eq!(human_bytes(12 * 1024 * 1024 * 1024 * 1024), "12.00 TiB");
    }

    #[test]
    fn human_secs_units() {
        assert_eq!(human_secs(0.5e-9 * 2.0), "1.0 ns");
        assert!(human_secs(0.002).ends_with("ms"));
        assert!(human_secs(3.0).ends_with("s"));
        assert!(human_secs(600.0).ends_with("min"));
    }

    #[test]
    fn sorted_pairs_is_key_ordered_for_any_hasher() {
        let mut std_map = std::collections::HashMap::new();
        let mut fast_map: FastMap<u64, &str> = FastMap::default();
        for (k, v) in [(9u64, "i"), (2, "b"), (7, "g"), (4, "d")] {
            std_map.insert(k, v);
            fast_map.insert(k, v);
        }
        let keys: Vec<u64> = sorted_pairs(&std_map).iter().map(|(k, _)| **k).collect();
        assert_eq!(keys, [2, 4, 7, 9]);
        let fast_keys: Vec<u64> = sorted_pairs(&fast_map).iter().map(|(k, _)| **k).collect();
        assert_eq!(fast_keys, keys);
    }

    #[test]
    fn div_ceil_basics() {
        assert_eq!(div_ceil(10, 3), 4);
        assert_eq!(div_ceil(9, 3), 3);
        assert_eq!(div_ceil(1, 4096), 1);
        assert_eq!(div_ceil(0, 7), 0);
    }

    #[test]
    fn mul_div_ceil_matches_div_ceil_in_range() {
        for (a, b, d) in [(10u64, 3, 7), (9, 9, 3), (0, 5, 2), (1, 1, 4096)] {
            assert_eq!(mul_div_ceil(a, b, d), div_ceil(a * b, d), "{a}*{b}/{d}");
        }
    }

    #[test]
    fn mul_div_ceil_survives_u64_overflowing_products() {
        // take * avail ≈ 4e19 > u64::MAX ≈ 1.84e19 (the scheduler's
        // paper-scale share); exact value checked against u128 math.
        let (take, avail, rem) = (10_000_000_000u64, 4_000_000_000u64, 12_000_000_000u64);
        let expect = ((take as u128 * avail as u128 + rem as u128 - 1) / rem as u128) as u64;
        assert_eq!(mul_div_ceil(take, avail, rem), expect);
        assert_eq!(mul_div_ceil(u64::MAX, u64::MAX, u64::MAX), u64::MAX);
    }
}
