//! Speech-to-text end to end: synthesize an LJ-like corpus, run the real
//! AOT acoustic model through PJRT (the same executable an ISP engine
//! runs), greedy-CTC decode, and report WER — then simulate the full
//! 36-CSD cluster run for the Fig 5(a) headline.
//!
//! ```bash
//! make artifacts && cargo run --release --example speech_to_text
//! ```

use solana_isp::metrics::Metrics;
use solana_isp::nlp::corpus::SpeechCorpus;
use solana_isp::power::PowerModel;
use solana_isp::runtime::Engine;
use solana_isp::sched::{run, SchedConfig};
use solana_isp::workloads::{AppModel, SpeechApp};

fn main() -> anyhow::Result<()> {
    let Some(mut eng) = Engine::load_default() else {
        anyhow::bail!("run `make artifacts` first");
    };

    // --- real compute: transcribe a sample through PJRT ---------------
    let sample_clips = 40;
    let corpus = SpeechCorpus::generate(2024, sample_clips);
    println!(
        "corpus: {} clips, {} words, {:.1} min of audio",
        corpus.clips.len(),
        corpus.total_words(),
        corpus.total_audio_secs() / 60.0
    );
    let app = SpeechApp::new(&eng, corpus)?;
    let ids: Vec<u32> = (0..sample_clips as u32).collect();
    let t0 = std::time::Instant::now();
    let (mean_wer, trs) = app.transcribe_set(&mut eng, &ids, 7)?;
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "transcribed {} clips in {:.2}s wall ({} PJRT executions)",
        trs.len(),
        wall,
        eng.executions()
    );
    println!("mean WER: {:.3}", mean_wer);
    for tr in trs.iter().take(3) {
        let reference = &app.corpus.clips[tr.clip_id as usize].transcript;
        println!("  ref: {reference}");
        println!("  hyp: {} (wer {:.2})", tr.text, tr.wer);
    }
    anyhow::ensure!(mean_wer < 0.12, "acoustic model degraded: WER {mean_wer}");

    // --- cluster simulation: the paper's Fig 5(a) headline ------------
    println!("\nsimulating the full 13,100-clip run on the 36-CSD server…");
    let model = AppModel::speech(13_100);
    let power = PowerModel::default();
    let mut m = Metrics::new();
    let base = run(&model, &SchedConfig::baseline(36), &power, &mut m)?;
    let isp = run(
        &model,
        &SchedConfig { csd_batch: 6, batch_ratio: 20.0, ..SchedConfig::default() },
        &power,
        &mut m,
    )?;
    println!(
        "host-only : {:.1} words/s   (paper:  96 w/s)",
        base.words_per_sec
    );
    println!(
        "36 CSDs   : {:.1} words/s   (paper: 296 w/s) — speedup {:.2}x (paper 3.1x)",
        isp.words_per_sec,
        isp.words_per_sec / base.words_per_sec
    );
    println!(
        "data kept in storage: {:.0}% (paper: 68%)",
        isp.csd_data_fraction() * 100.0
    );
    Ok(())
}
