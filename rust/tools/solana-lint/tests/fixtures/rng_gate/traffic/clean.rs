// Negative fixture for D3 rng-gate: draws dominated by `> 0`-style
// guards (if-block, while-block, or inside the condition itself).
impl Gen {
    pub fn maybe(&mut self) -> bool {
        if self.rate > 0.0 {
            return self.rng.chance(self.rate);
        }
        false
    }

    pub fn gap_if_live(&mut self) -> f64 {
        while self.budget > 0 {
            return self.rng.exponential(self.rate);
        }
        0.0
    }

    pub fn guarded_in_condition(&mut self) -> bool {
        if self.rate > 0.0 && self.rng.chance(self.rate) {
            return true;
        }
        false
    }
}
