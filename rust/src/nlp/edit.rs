//! Edit distance and word-error-rate — the speech benchmark's output
//! quality metric ("output accuracy: same", Table I: ISP and host runs
//! must produce identical transcripts; WER measures both against the
//! reference).

/// Levenshtein distance over arbitrary comparable tokens.
pub fn levenshtein<T: PartialEq>(a: &[T], b: &[T]) -> usize {
    if a.is_empty() {
        return b.len();
    }
    if b.is_empty() {
        return a.len();
    }
    // Single-row DP.
    let mut row: Vec<usize> = (0..=b.len()).collect();
    for (i, ai) in a.iter().enumerate() {
        let mut prev = row[0];
        row[0] = i + 1;
        for (j, bj) in b.iter().enumerate() {
            let cost = if ai == bj { 0 } else { 1 };
            let val = (prev + cost).min(row[j] + 1).min(row[j + 1] + 1);
            prev = row[j + 1];
            row[j + 1] = val;
        }
    }
    row[b.len()]
}

/// Word error rate: edit distance over word tokens ÷ reference length.
pub fn wer(reference: &str, hypothesis: &str) -> f64 {
    let r = super::tokenize(reference);
    let h = super::tokenize(hypothesis);
    if r.is_empty() {
        return if h.is_empty() { 0.0 } else { 1.0 };
    }
    levenshtein(&r, &h) as f64 / r.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop::{check, forall};

    #[test]
    fn distance_basics() {
        assert_eq!(levenshtein(b"kitten", b"sitting"), 3);
        assert_eq!(levenshtein(b"abc", b"abc"), 0);
        assert_eq!(levenshtein(b"", b"abc"), 3);
        assert_eq!(levenshtein(b"abc", b""), 3);
    }

    #[test]
    fn wer_basics() {
        assert_eq!(wer("the cat sat", "the cat sat"), 0.0);
        assert!((wer("the cat sat", "the cat") - 1.0 / 3.0).abs() < 1e-12);
        assert_eq!(wer("", ""), 0.0);
        assert_eq!(wer("", "something"), 1.0);
    }

    #[test]
    fn property_metric_axioms() {
        forall("levenshtein is a metric", 120, |g| {
            let a = g.vec_u64(0..=5, 0, 16);
            let b = g.vec_u64(0..=5, 0, 16);
            let c = g.vec_u64(0..=5, 0, 16);
            let dab = levenshtein(&a, &b);
            let dba = levenshtein(&b, &a);
            check(dab == dba, "symmetry")?;
            check(
                (dab == 0) == (a == b),
                "identity of indiscernibles",
            )?;
            let dac = levenshtein(&a, &c);
            let dcb = levenshtein(&c, &b);
            check(dab <= dac + dcb, "triangle inequality")?;
            check(
                dab <= a.len().max(b.len()),
                "bounded by longer length",
            )
        });
    }
}
