// Negative fixture for D4 no-unwrap: test code may unwrap freely —
// both `#[cfg(test)]` modules and bare `#[test]` functions.
pub fn helper() -> u64 {
    7
}

#[cfg(test)]
mod tests {
    #[test]
    fn unwrap_is_fine_in_tests() {
        let v: u64 = "7".parse().unwrap();
        assert_eq!(v, 7);
    }
}

#[test]
fn probe() {
    let v: u64 = "7".parse().unwrap();
    assert_eq!(v, helper());
}
