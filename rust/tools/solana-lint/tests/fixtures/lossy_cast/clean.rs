// Negative fixture for D5 lossy-cast: widening a counter is fine, and
// narrowing a non-counter identifier is out of scope.
pub fn widen(items: u32) -> u64 {
    items as u64
}

pub fn index(idx: u64) -> u32 {
    idx as u32
}
