// Positive fixture for the bad-marker meta-rule: the comment below
// mentions the tool by name but does not parse as a marker.
// solana-lint: allow no-unwrap -- missing parens
pub fn f() {}
