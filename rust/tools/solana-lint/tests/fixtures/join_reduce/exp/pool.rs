// Negative fixture for D6 join-reduce: `exp/pool.rs` is the sanctioned
// home of thread spawning (the deterministic reduction itself).
use std::thread;

pub fn pooled() {
    thread::scope(|_s| {});
}
