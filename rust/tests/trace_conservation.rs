//! Observability-plane integration tests (ISSUE-9): the determinism and
//! conservation contracts of the span tracer, checked end-to-end
//! through `serve_fleet_traced`.
//!
//! Four properties:
//! 1. **Tracing off is free** — `Tracer::Off` (and the plain
//!    `serve_fleet` wrapper) must produce reports bit-identical to each
//!    other AND to a fully-traced run: the tracer observes simulated
//!    time, it never spends any.
//! 2. **Phases partition the timeline** — for every traced request,
//!    `sum(phase durations) == end_to_end` to the bit, including
//!    requests that were retried, hedged, failed over, shed, GC-stalled
//!    or killed by a server crash.
//! 3. **Exports round-trip** — the Chrome trace re-parses through
//!    `codec::json` and passes the schema check (monotone timestamps,
//!    matched B/E pairs); the JSONL export re-imports bit-exactly.
//! 4. **GC lives in the tail** — a fig13-style ingest-heavy cell
//!    attributes a larger `gc_stall` share to the p99.9 band than to
//!    the population, and a read-only run attributes none at all.

use solana_isp::cluster::fleet::{FleetConfig, FleetShape};
use solana_isp::csd::CsdConfig;
use solana_isp::exp::{self, Scale};
use solana_isp::faults::FaultsConfig;
use solana_isp::metrics::Metrics;
use solana_isp::power::PowerModel;
use solana_isp::prop::forall;
use solana_isp::sched::{DispatchMode, SchedConfig};
use solana_isp::trace::{self, Outcome, Tracer};
use solana_isp::traffic::{
    fleet_nominal_rate, serve_fleet, serve_fleet_traced, LbPolicy, ServeReport, TrafficConfig,
};
use solana_isp::workloads::{App, AppModel};

const APPS: [App; 3] = [App::SpeechToText, App::Recommender, App::Sentiment];
const SHAPES: [FleetShape; 3] = [FleetShape::AllCsd, FleetShape::AllSsd, FleetShape::Mixed];

fn serve_plain(app: App, fcfg: &FleetConfig, tcfg: &TrafficConfig) -> ServeReport {
    let mut m = Metrics::new();
    serve_fleet(app, fcfg, tcfg, &PowerModel::default(), &mut m).expect("serve_fleet")
}

fn serve_traced(
    app: App,
    fcfg: &FleetConfig,
    tcfg: &TrafficConfig,
    tracer: &mut Tracer,
) -> ServeReport {
    let mut m = Metrics::new();
    serve_fleet_traced(app, fcfg, tcfg, &PowerModel::default(), &mut m, tracer)
        .expect("serve_fleet_traced")
}

/// The heavy mixed fault plan from the chaos suite: drive, server, and
/// link faults all live at once.
fn chaos_faults() -> FaultsConfig {
    FaultsConfig {
        ack_loss: 0.05,
        stall: 0.05,
        stall_s: 0.02,
        link_drop: 0.02,
        link_dup: 0.02,
        server_crash_at: Some(0.5),
        rejoin_s: Some(2.0),
        ..FaultsConfig::default()
    }
}

#[test]
fn tracer_off_is_bit_identical_to_untraced_and_tracing_costs_nothing() {
    // Randomized configs: app × shape × dispatch mode × fault plan ×
    // resilience knobs. Three runs per case — untraced, Tracer::Off,
    // full tracing — must agree on every report field bit-for-bit:
    // tracing may never perturb the simulation it observes.
    forall("tracing is free", 8, |g| {
        let app = APPS[g.usize(0..=2)];
        let servers = g.usize(1..=3);
        let shape = SHAPES[g.usize(0..=2)];
        let dispatch =
            if g.bool() { DispatchMode::EventDriven } else { DispatchMode::Polling };
        let faulted = g.bool();
        let replicas = if servers > 1 && faulted { 1 } else { 0 };
        let fcfg = FleetConfig {
            servers,
            shape,
            replicas,
            sched: SchedConfig { dispatch, ..SchedConfig::default() },
            ..FleetConfig::default()
        };
        let tcfg = TrafficConfig {
            load: g.f64(0.3, 0.9),
            requests: 400,
            retries: if faulted { 2 } else { 0 },
            hedge: faulted,
            faults: if faulted { Some(chaos_faults()) } else { None },
            ..TrafficConfig::default()
        };
        let plain = serve_plain(app, &fcfg, &tcfg);
        let mut off = Tracer::Off;
        let off_report = serve_traced(app, &fcfg, &tcfg, &mut off);
        plain.check_bit_identical(&off_report)?;
        let (reqs, _) = off.take_requests();
        if !reqs.is_empty() {
            return Err("Tracer::Off recorded request timelines".to_string());
        }
        let mut on = Tracer::in_memory(1);
        let on_report = serve_traced(app, &fcfg, &tcfg, &mut on);
        plain.check_bit_identical(&on_report)
    });
}

#[test]
fn phase_sums_equal_end_to_end_under_heavy_chaos() {
    // Retries, hedges, failovers, crash-swallowed attempts, shed
    // requests: whatever happens to a request, its phase decomposition
    // must sum to its end-to-end latency exactly, and every terminal
    // outcome must agree with the report's accounting.
    let fcfg = FleetConfig {
        servers: 3,
        shape: FleetShape::AllCsd,
        replicas: 1,
        ..FleetConfig::default()
    };
    let tcfg = TrafficConfig {
        load: 0.7,
        requests: 2_000,
        retries: 2,
        hedge: true,
        faults: Some(chaos_faults()),
        ..TrafficConfig::default()
    };
    let mut tracer = Tracer::in_memory(1);
    let r = serve_traced(App::Sentiment, &fcfg, &tcfg, &mut tracer);
    let (reqs, dropped) = tracer.take_requests();
    assert_eq!(dropped, 0, "the unbounded sink never evicts");
    assert!(!reqs.is_empty());
    trace::verify_conservation(&reqs).expect("phase conservation");
    for req in &reqs {
        let sum = req.phase_sum();
        assert_eq!(
            sum.to_bits(),
            req.end_to_end().to_bits(),
            "request {}: phases sum to {sum}, end-to-end {}",
            req.id,
            req.end_to_end()
        );
    }
    let served = reqs.iter().filter(|q| q.outcome == Outcome::Served).count() as u64;
    let shed = reqs.iter().filter(|q| q.outcome == Outcome::Shed).count() as u64;
    assert_eq!(served, r.served, "served traces must match the report");
    assert_eq!(shed, r.shed, "shed traces must match the report");
    assert!(r.failed > 0 || r.retried > 0, "the chaos plan was supposed to bite");
    // The tail-attribution decomposition is exact over these traces.
    let bands = trace::attribution(&reqs);
    assert!(bands.iter().any(|b| b.band == "p99.9"));
    for b in &bands {
        let share: f64 = b.phases.iter().map(|(_, _, s)| s).sum();
        assert!((share - 1.0).abs() < 1e-9, "band {} shares sum to {share}", b.band);
    }
}

#[test]
fn sampling_and_ring_eviction_stay_deterministic() {
    let fcfg = FleetConfig { servers: 2, shape: FleetShape::Mixed, ..FleetConfig::default() };
    let tcfg = TrafficConfig { load: 0.6, requests: 1_000, ..TrafficConfig::default() };
    // Sampling is by request id, not by RNG stream: only ids ≡ 0 mod 4.
    let mut sampled = Tracer::in_memory(4);
    serve_traced(App::Sentiment, &fcfg, &tcfg, &mut sampled);
    let (reqs, _) = sampled.take_requests();
    assert!(!reqs.is_empty());
    assert!(reqs.iter().all(|q| q.id % 4 == 0), "sampling must be by id");
    trace::verify_conservation(&reqs).expect("sampled traces conserve too");
    // A bounded ring keeps at most `cap` timelines and reports what it
    // evicted; twice the run, bit-identical traces.
    let run_ring = || {
        let mut t = Tracer::ring(64, 1);
        serve_traced(App::Sentiment, &fcfg, &tcfg, &mut t);
        t.take_requests()
    };
    let (a, dropped_a) = run_ring();
    let (b, dropped_b) = run_ring();
    assert!(a.len() <= 64);
    assert_eq!(dropped_a, dropped_b);
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.id, y.id);
        assert_eq!(x.arrival.to_bits(), y.arrival.to_bits());
        assert_eq!(x.done.to_bits(), y.done.to_bits());
        assert_eq!(x.phases.len(), y.phases.len());
    }
}

#[test]
fn exports_round_trip_through_codec_json() {
    let fcfg = FleetConfig {
        servers: 3,
        shape: FleetShape::Mixed,
        replicas: 1,
        ..FleetConfig::default()
    };
    let tcfg = TrafficConfig {
        load: 0.7,
        requests: 1_200,
        retries: 2,
        hedge: true,
        faults: Some(chaos_faults()),
        ..TrafficConfig::default()
    };
    let mut tracer = Tracer::in_memory(1);
    serve_traced(App::Sentiment, &fcfg, &tcfg, &mut tracer);
    let (reqs, _) = tracer.take_requests();
    assert!(!reqs.is_empty());
    // Chrome: emit → pretty-print → re-parse → schema check (monotone
    // timestamps, matched B/E pairs, metadata first).
    let chrome = trace::chrome_trace(&reqs);
    let reparsed = solana_isp::codec::json::Json::parse(&chrome.to_pretty())
        .expect("chrome trace must be valid JSON");
    trace::check_chrome(&reparsed).expect("chrome schema check");
    // JSONL: emit → re-import → bit-exact equality, field by field.
    let jsonl = trace::to_jsonl(&reqs);
    let back = trace::parse_jsonl(&jsonl).expect("jsonl re-import");
    assert_eq!(back.len(), reqs.len());
    for (orig, got) in reqs.iter().zip(&back) {
        assert_eq!(orig.id, got.id);
        assert_eq!(orig.server, got.server);
        assert_eq!(orig.outcome, got.outcome);
        assert_eq!(orig.arrival.to_bits(), got.arrival.to_bits());
        assert_eq!(orig.done.to_bits(), got.done.to_bits());
        assert_eq!(orig.phases.len(), got.phases.len(), "request {}", orig.id);
        for (p, q) in orig.phases.iter().zip(&got.phases) {
            assert_eq!(p.kind, q.kind);
            assert_eq!(p.attempt, q.attempt);
            assert_eq!(p.drive, q.drive);
            assert_eq!(p.t0.to_bits(), q.t0.to_bits());
            assert_eq!(p.t1.to_bits(), q.t1.to_bits());
            assert_eq!(p.dur.to_bits(), q.dur.to_bits());
        }
    }
    trace::verify_conservation(&back).expect("conservation survives the round trip");
}

/// The fig13 serving cell (all-CSD, foreground GC, small flash
/// geometry) rebuilt from the experiment's published constants.
fn fig13_cell_cfgs(ingest_util: f64) -> (FleetConfig, TrafficConfig) {
    let shape = FleetShape::AllCsd;
    let sched = SchedConfig {
        csd_batch: exp::FIG13_BATCH,
        batch_ratio: exp::batch_ratio(exp::FIG13_APP),
        drives: exp::FIG13_DRIVES,
        isp_drives: exp::FIG13_DRIVES,
        use_host: false,
        dispatch: DispatchMode::EventDriven,
        csd: CsdConfig { flash: exp::fig13_flash(), ..CsdConfig::default() },
        ..SchedConfig::default()
    };
    let fcfg =
        FleetConfig { servers: exp::FIG13_SERVERS, shape, sched, ..FleetConfig::default() };
    let model = AppModel::for_app(exp::FIG13_APP, 1);
    let offered = exp::FIG13_LOAD * fleet_nominal_rate(&model, &fcfg.server_specs());
    let tcfg = TrafficConfig {
        rate_rps: Some(offered),
        requests: exp::fig13_requests(Scale(0.005)),
        admission: true,
        policy: LbPolicy::LeastWork,
        ingest_rate: exp::fig13_ingest_rate(ingest_util),
        ..TrafficConfig::default()
    };
    (fcfg, tcfg)
}

#[test]
fn gc_stall_concentrates_in_the_p999_band_fig13_style() {
    // The tentpole's "where does the p99 live" answer for fig13: under
    // an ingest stream that cycles foreground GC, the p99.9 band's
    // gc_stall share must exceed the whole population's — GC lives in
    // the tail — while a read-only run of the same cell attributes no
    // gc_stall anywhere.
    let (fcfg, tcfg) = fig13_cell_cfgs(0.5);
    let mut tracer = Tracer::in_memory(1);
    let r = serve_traced(exp::FIG13_APP, &fcfg, &tcfg, &mut tracer);
    assert!(r.gc_runs > 0, "the fig13 geometry must cycle GC under ingest");
    let (reqs, _) = tracer.take_requests();
    trace::verify_conservation(&reqs).expect("conservation under GC stalls");
    let bands = trace::attribution(&reqs);
    let all = bands.iter().find(|b| b.band == "all").expect("all band");
    let p999 = bands.iter().find(|b| b.band == "p99.9").expect("p99.9 band");
    assert!(
        p999.share_of("gc_stall") > 0.0,
        "the p99.9 band must carry a gc_stall component: {:?}",
        p999.phases
    );
    assert!(
        p999.share_of("gc_stall") > all.share_of("gc_stall"),
        "gc_stall must concentrate in the tail: p99.9 {} <= all {}",
        p999.share_of("gc_stall"),
        all.share_of("gc_stall")
    );
    // Read-only control: same cell, no ingest → no GC, no gc_stall.
    let (fcfg0, tcfg0) = fig13_cell_cfgs(0.0);
    let mut t0 = Tracer::in_memory(1);
    let r0 = serve_traced(exp::FIG13_APP, &fcfg0, &tcfg0, &mut t0);
    assert_eq!(r0.gc_runs, 0, "read-only serving must not GC");
    let (reqs0, _) = t0.take_requests();
    trace::verify_conservation(&reqs0).expect("read-only conservation");
    for b in trace::attribution(&reqs0) {
        assert_eq!(
            b.share_of("gc_stall"),
            0.0,
            "band {}: gc_stall attributed on a read-only run",
            b.band
        );
    }
}
