//! Hand-rolled CLI argument parser (the offline build has no `clap`).
//!
//! Model: `solana <subcommand> [--flag] [--key value] [positional...]`.
//! Subcommands register the options they accept; unknown options are hard
//! errors with a usage dump, matching what users expect from clap-style
//! binaries.

use std::collections::BTreeMap;

/// Declared option.
#[derive(Clone, Debug)]
pub struct OptSpec {
    pub name: &'static str,
    pub takes_value: bool,
    pub default: Option<&'static str>,
    pub help: &'static str,
}

/// Parsed arguments for one subcommand invocation.
#[derive(Clone, Debug, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: BTreeMap<String, bool>,
    pub positional: Vec<String>,
}

impl Args {
    pub fn str(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn u64(&self, name: &str) -> anyhow::Result<Option<u64>> {
        match self.values.get(name) {
            None => Ok(None),
            Some(v) => Ok(Some(v.parse().map_err(|_| {
                anyhow::anyhow!("option --{name} expects an integer, got '{v}'")
            })?)),
        }
    }

    pub fn f64(&self, name: &str) -> anyhow::Result<Option<f64>> {
        match self.values.get(name) {
            None => Ok(None),
            Some(v) => Ok(Some(v.parse().map_err(|_| {
                anyhow::anyhow!("option --{name} expects a number, got '{v}'")
            })?)),
        }
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.get(name).copied().unwrap_or(false)
    }

    /// Comma-separated list option → Vec<u64>.
    pub fn u64_list(&self, name: &str) -> anyhow::Result<Option<Vec<u64>>> {
        match self.values.get(name) {
            None => Ok(None),
            Some(v) => v
                .split(',')
                .map(|p| {
                    p.trim().parse::<u64>().map_err(|_| {
                        anyhow::anyhow!("option --{name}: bad integer '{p}'")
                    })
                })
                .collect::<anyhow::Result<Vec<_>>>()
                .map(Some),
        }
    }
}

/// A subcommand definition.
pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    pub opts: Vec<OptSpec>,
}

impl Command {
    pub fn new(name: &'static str, about: &'static str) -> Command {
        Command { name, about, opts: Vec::new() }
    }

    pub fn opt(mut self, name: &'static str, default: Option<&'static str>, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, takes_value: true, default, help });
        self
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, takes_value: false, default: None, help });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("usage: solana {} [options]\n  {}\n\noptions:\n", self.name, self.about);
        for o in &self.opts {
            let arg = if o.takes_value { format!("--{} <v>", o.name) } else { format!("--{}", o.name) };
            let def = o.default.map(|d| format!(" (default: {d})")).unwrap_or_default();
            s.push_str(&format!("  {arg:<24} {}{def}\n", o.help));
        }
        s
    }

    /// Parse raw arguments (after the subcommand token).
    pub fn parse(&self, raw: &[String]) -> anyhow::Result<Args> {
        let mut args = Args::default();
        for o in &self.opts {
            if let Some(d) = o.default {
                args.values.insert(o.name.to_string(), d.to_string());
            }
        }
        let mut i = 0;
        while i < raw.len() {
            let tok = &raw[i];
            if let Some(name) = tok.strip_prefix("--") {
                // Accept --key=value as well as --key value.
                let (name, inline) = match name.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (name, None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.name == name)
                    .ok_or_else(|| anyhow::anyhow!("unknown option --{name}\n{}", self.usage()))?;
                if spec.takes_value {
                    let v = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            raw.get(i)
                                .cloned()
                                .ok_or_else(|| anyhow::anyhow!("option --{name} needs a value"))?
                        }
                    };
                    args.values.insert(name.to_string(), v);
                } else {
                    if inline.is_some() {
                        anyhow::bail!("flag --{name} does not take a value");
                    }
                    args.flags.insert(name.to_string(), true);
                }
            } else {
                args.positional.push(tok.clone());
            }
            i += 1;
        }
        Ok(args)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cmd() -> Command {
        Command::new("bench", "run a benchmark")
            .opt("csds", Some("36"), "number of CSDs")
            .opt("batch", None, "batch size")
            .opt("sizes", None, "comma list")
            .flag("verbose", "chatty output")
    }

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_apply() {
        let a = cmd().parse(&sv(&[])).unwrap();
        assert_eq!(a.u64("csds").unwrap(), Some(36));
        assert_eq!(a.str("batch"), None);
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn parse_values_flags_positional() {
        let a = cmd()
            .parse(&sv(&["--csds", "8", "--verbose", "pos1", "--batch=40000"]))
            .unwrap();
        assert_eq!(a.u64("csds").unwrap(), Some(8));
        assert_eq!(a.u64("batch").unwrap(), Some(40000));
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["pos1".to_string()]);
    }

    #[test]
    fn u64_list_parses() {
        let a = cmd().parse(&sv(&["--sizes", "2,4, 6,8"])).unwrap();
        assert_eq!(a.u64_list("sizes").unwrap(), Some(vec![2, 4, 6, 8]));
    }

    #[test]
    fn unknown_option_errors() {
        assert!(cmd().parse(&sv(&["--nope"])).is_err());
    }

    #[test]
    fn missing_value_errors() {
        assert!(cmd().parse(&sv(&["--batch"])).is_err());
        assert!(cmd().parse(&sv(&["--verbose=1"])).is_err());
    }

    #[test]
    fn bad_integer_errors() {
        let a = cmd().parse(&sv(&["--csds", "many"])).unwrap();
        assert!(a.u64("csds").is_err());
    }

    #[test]
    fn usage_mentions_options() {
        let u = cmd().usage();
        assert!(u.contains("--csds"));
        assert!(u.contains("default: 36"));
    }
}
