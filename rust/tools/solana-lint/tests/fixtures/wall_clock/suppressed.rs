// Negative fixture for D2 wall-clock: a marker with a reason on the
// preceding line suppresses the finding.
use std::time::Instant;

pub fn bench_clock() -> Instant {
    // solana-lint: allow(wall-clock, reason = "fixture: sanctioned real-time site")
    Instant::now()
}
