//! In-storage processing engine: quad-core ARM Cortex-A53 + NEON.
//!
//! §III-A2. The ISP runs full Linux and executes unmodified application
//! binaries; computationally it is a small out-of-order-free in-order
//! quad core. We model it as a 4-server queue whose service times are
//! derived from the *host-relative slowdown* calibrated from the paper's
//! single-node measurements (e.g. speech-to-text: host 102 words/s vs
//! CSD 5.3 words/s ⇒ ≈19.2× per-item slowdown for that app; sentiment
//! 9496 vs 364 ⇒ ≈26×). NEON SIMD benefit is folded into the calibrated
//! rate, exactly as it was in the measured prototype.

use crate::sim::{Servers, SimTime};

/// ISP compute configuration.
#[derive(Clone, Debug)]
pub struct IspConfig {
    /// Number of A53 cores (paper: 4).
    pub cores: usize,
    /// Clock in Hz (A53-class, used for reporting only — service times
    /// come from calibrated per-app rates).
    pub clock_hz: f64,
    /// Multiplier applied to all service times (1.0 = calibrated A53;
    /// ablations can scale the engine up/down).
    pub speed_factor: f64,
}

impl Default for IspConfig {
    fn default() -> Self {
        IspConfig { cores: 4, clock_hz: 1.4e9, speed_factor: 1.0 }
    }
}

/// The engine: a k-core run queue.
pub struct IspEngine {
    pub cfg: IspConfig,
    cores: Servers,
    jobs: u64,
}

impl IspEngine {
    pub fn new(cfg: IspConfig) -> IspEngine {
        IspEngine { cores: Servers::new(cfg.cores), jobs: 0, cfg }
    }

    /// Run a job of `work_secs` single-core-equivalent seconds; returns
    /// completion time. Jobs are not internally parallelized (the
    /// paper's scheduler hands whole batches to the node; within a node
    /// the app pins one batch per worker process).
    pub fn run(&mut self, now: SimTime, work_secs: f64) -> SimTime {
        debug_assert!(work_secs >= 0.0);
        self.jobs += 1;
        self.cores.acquire(now, work_secs / self.cfg.speed_factor)
    }

    /// Earliest time a new job would start executing.
    pub fn next_start(&self, now: SimTime) -> SimTime {
        self.cores.next_start(now)
    }

    pub fn drain_time(&self) -> SimTime {
        self.cores.drain_time()
    }

    pub fn busy_secs(&self) -> f64 {
        self.cores.busy_secs()
    }

    pub fn jobs(&self) -> u64 {
        self.jobs
    }

    pub fn utilization(&self, horizon: SimTime) -> f64 {
        self.cores.utilization(horizon)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn four_jobs_run_in_parallel() {
        let mut isp = IspEngine::new(IspConfig::default());
        let dones: Vec<f64> = (0..4).map(|_| isp.run(0.0, 2.0)).collect();
        assert!(dones.iter().all(|&d| (d - 2.0).abs() < 1e-12));
        // fifth job queues
        assert!((isp.run(0.0, 2.0) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn speed_factor_scales_service() {
        let mut fast = IspEngine::new(IspConfig { speed_factor: 2.0, ..Default::default() });
        assert!((fast.run(0.0, 2.0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn utilization_accounting() {
        let mut isp = IspEngine::new(IspConfig::default());
        for _ in 0..8 {
            isp.run(0.0, 1.0);
        }
        let horizon = isp.drain_time();
        assert!((horizon - 2.0).abs() < 1e-12);
        assert!((isp.utilization(horizon) - 1.0).abs() < 1e-12);
        assert_eq!(isp.jobs(), 8);
    }
}
