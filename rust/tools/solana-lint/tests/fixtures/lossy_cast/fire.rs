// Positive fixture for D5 lossy-cast: narrowing casts on item/byte
// counters (including `.len()` results) must fire.
pub fn pack(items: u64, bytes: u64) -> (u32, u32) {
    let a = items as u32;
    let b = bytes as u32;
    (a, b)
}

pub fn frame_len(payload: &[u8]) -> u32 {
    payload.len() as u32
}
