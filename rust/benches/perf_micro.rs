//! `cargo bench --bench perf_micro` — L3 hot-path microbenchmarks for
//! the performance pass (EXPERIMENTS.md §Perf): DES event throughput,
//! analytic resource ops, FTL write/GC path, FCU read path, full
//! scheduler runs per second, and (artifacts permitting) PJRT execution
//! latency.

use solana_isp::bench_support::Bencher;
use solana_isp::cluster::fleet::FleetConfig;
use solana_isp::csd::{CsdConfig, Fcu, IoRequester};
use solana_isp::exp::{self, pool, Scale};
use solana_isp::metrics::{Histogram, Metrics};
use solana_isp::power::PowerModel;
use solana_isp::runtime::{Engine, Tensor};
use solana_isp::sched::{run, DispatchMode, SchedConfig};
use solana_isp::sim::{EventQueue, Pipe, Servers};
use solana_isp::trace::Tracer;
use solana_isp::traffic::{serve_fleet, serve_fleet_traced, TrafficConfig};
use solana_isp::workloads::{App, AppModel};

fn main() -> anyhow::Result<()> {
    let mut b = Bencher::from_env();

    // DES core: schedule+pop churn.
    b.bench("sim.event_queue 100k schedule+pop", || {
        let mut q: EventQueue<u32> = EventQueue::new();
        let mut acc = 0u64;
        for round in 0..10u32 {
            for i in 0..10_000u32 {
                q.schedule((i % 97) as f64 * 1e-4, i ^ round);
            }
            while let Some((_, e)) = q.pop() {
                acc = acc.wrapping_add(e as u64);
            }
        }
        std::hint::black_box(acc);
        100_000
    });

    // Analytic resources.
    b.bench("sim.servers 100k acquire", || {
        let mut s = Servers::new(16);
        let mut now = 0.0;
        for i in 0..100_000u64 {
            now = s.acquire(now * 0.999, 1e-5 * ((i % 13) as f64 + 1.0)).min(1e6);
        }
        std::hint::black_box(now);
        100_000
    });
    b.bench("sim.pipe 100k transfers", || {
        let mut p = Pipe::new(3.2e9, 1e-6);
        let mut t = 0.0;
        for i in 0..100_000u64 {
            t = p.transfer(t * 0.999, 4096 + (i % 7) * 512).end.min(1e6);
        }
        std::hint::black_box(t);
        100_000
    });

    // FTL + flash write path (tiny geometry forces GC). Three flash
    // management modes over the same overwrite churn (ISSUE-8): the
    // foreground collector stalls writes at the low-water mark, the
    // background collector relocates ahead of it on idle dies, and ZNS
    // sidesteps device GC entirely (WAF pinned at 1.0).
    for (label, bg, zns) in [
        ("ftl.write_page 20k (foreground GC)", false, false),
        ("ftl.write_page 20k (background GC)", true, false),
        ("ftl.write_page 20k (zns)", false, true),
    ] {
        b.bench(label, move || {
            let mut cfg = CsdConfig::tiny();
            cfg.flash.background_gc = bg;
            cfg.flash.zns = zns;
            let mut fcu = Fcu::new(&cfg);
            let mut now = 0.0;
            for i in 0..20_000u64 {
                now = fcu.write(now, (i % 200) * 4096, 4096, IoRequester::Host);
            }
            let stats = fcu.ftl_stats();
            assert!(stats.waf() >= 1.0);
            std::hint::black_box((now, stats.waf()));
            20_000
        });
    }

    // FCU read path on the full-size drive.
    b.bench("fcu.read 2k x 64KiB", || {
        let cfg = CsdConfig::default();
        let mut fcu = Fcu::new(&cfg);
        let now = fcu.write(0.0, 0, 2_000 * 65_536, IoRequester::Host);
        let mut t = now;
        for i in 0..2_000u64 {
            t = t.max(fcu.read(now, i * 65_536, 65_536, IoRequester::Isp));
        }
        std::hint::black_box(t);
        2_000
    });

    // Whole-scheduler run (the Fig-5 inner loop).
    b.bench("sched.run sentiment 500k items 36 drives", || {
        let model = AppModel::sentiment(500_000);
        let cfg = SchedConfig {
            csd_batch: 20_000,
            batch_ratio: 26.0,
            ..SchedConfig::default()
        };
        let mut m = Metrics::new();
        let r = run(&model, &cfg, &PowerModel::default(), &mut m).unwrap();
        std::hint::black_box(r.items_per_sec);
        500_000
    });
    b.bench("sched.run speech 13k items 36 drives", || {
        let model = AppModel::speech(13_100);
        let cfg = SchedConfig { csd_batch: 6, batch_ratio: 20.0, ..SchedConfig::default() };
        let mut m = Metrics::new();
        let r = run(&model, &cfg, &PowerModel::default(), &mut m).unwrap();
        std::hint::black_box(r.items_per_sec);
        13_100
    });

    // Wake coalescing (ISSUE-1 tentpole): identical simulated results,
    // far fewer DES events. Report the event counts once, then time both
    // modes on the paper's Fig 5(a) speech operating point.
    {
        let speech_cfg = |coalesce: bool| SchedConfig {
            csd_batch: 6,
            batch_ratio: 20.0,
            coalesce_wakes: coalesce,
            ..SchedConfig::default()
        };
        let model = AppModel::speech(13_100);
        let mut m = Metrics::new();
        let naive = run(&model, &speech_cfg(false), &PowerModel::default(), &mut m).unwrap();
        let coal = run(&model, &speech_cfg(true), &PowerModel::default(), &mut m).unwrap();
        assert_eq!(naive.makespan_secs.to_bits(), coal.makespan_secs.to_bits());
        println!(
            "sched.run speech events_executed: naive={} ({} wakes) coalesced={} ({} wakes) => {:.1}x fewer events",
            naive.events_executed,
            naive.wake_events,
            coal.events_executed,
            coal.wake_events,
            naive.events_executed as f64 / coal.events_executed.max(1) as f64,
        );
        b.bench("sched.run speech 13k naive wakes", || {
            let mut m = Metrics::new();
            let r = run(&model, &speech_cfg(false), &PowerModel::default(), &mut m).unwrap();
            std::hint::black_box(r.items_per_sec);
            13_100
        });
        b.bench("sched.run speech 13k coalesced wakes", || {
            let mut m = Metrics::new();
            let r = run(&model, &speech_cfg(true), &PowerModel::default(), &mut m).unwrap();
            std::hint::black_box(r.items_per_sec);
            13_100
        });
    }

    // Dispatch modes (ISSUE-2 tentpole): event-driven dispatch re-arms a
    // node the moment its ack pops, removing the polling grid's mean
    // half-period idle gap per batch. Report the simulated makespans
    // once, then time both modes at the Fig 5(a) speech point.
    {
        let speech_cfg = |dispatch: DispatchMode| SchedConfig {
            csd_batch: 6,
            batch_ratio: 20.0,
            dispatch,
            ..SchedConfig::default()
        };
        let model = AppModel::speech(13_100);
        let mut m = Metrics::new();
        let poll = run(&model, &speech_cfg(DispatchMode::Polling), &PowerModel::default(), &mut m)
            .unwrap();
        let event =
            run(&model, &speech_cfg(DispatchMode::EventDriven), &PowerModel::default(), &mut m)
                .unwrap();
        assert!(event.makespan_secs <= poll.makespan_secs + 1e-9);
        println!(
            "sched.run speech simulated makespan: polling={:.2}s event-driven={:.2}s => {:.3}x ({} vs {} events)",
            poll.makespan_secs,
            event.makespan_secs,
            poll.makespan_secs / event.makespan_secs,
            poll.events_executed,
            event.events_executed,
        );
        b.bench("sched.run speech 13k event-driven", || {
            let mut m = Metrics::new();
            let r = run(&model, &speech_cfg(DispatchMode::EventDriven), &PowerModel::default(), &mut m)
                .unwrap();
            std::hint::black_box(r.items_per_sec);
            13_100
        });
    }

    // Parallel sweep runner: the same Fig 5 sweep on one worker vs the
    // full pool (outputs are byte-identical; only wall-clock moves).
    {
        let scale = Scale(0.02);
        let threads = pool::pool_size();
        pool::set_threads(1);
        b.bench("exp.fig5 speech sweep 1 thread", || {
            let t = exp::fig5(App::SpeechToText, scale).expect("fig5 sequential");
            t.rows.len() as u64
        });
        pool::set_threads(threads);
        b.bench("exp.fig5 speech sweep pooled", || {
            let t = exp::fig5(App::SpeechToText, scale).expect("fig5 parallel");
            t.rows.len() as u64
        });
        pool::set_threads(0);
        println!("exp.fig5 pooled sweep used {threads} worker threads");
    }

    // Histogram tail reporting (ISSUE-9 satellite): the old report path
    // called `percentile()` per quantile — one clone + sort each — where
    // `summary()` sorts once for all of them. Values are pinned
    // bit-identical before timing either path.
    {
        let mut h = Histogram::with_capacity(100_000);
        for i in 0..100_000u64 {
            h.record((i.wrapping_mul(2_654_435_761) % 1_000_003) as f64 * 1e-6);
        }
        let s = h.summary().expect("non-empty histogram");
        for (pct, via_summary) in
            [(50.0, s.p50), (90.0, s.p90), (95.0, s.p95), (99.0, s.p99), (99.9, s.p999)]
        {
            assert_eq!(h.percentile(pct).to_bits(), via_summary.to_bits());
        }
        b.bench("metrics.histogram 100k tail via percentile() x5", || {
            let acc = h.percentile(50.0)
                + h.percentile(90.0)
                + h.percentile(95.0)
                + h.percentile(99.0)
                + h.percentile(99.9);
            std::hint::black_box(acc);
            5
        });
        b.bench("metrics.histogram 100k tail via summary()", || {
            let s = h.summary().expect("non-empty histogram");
            std::hint::black_box(s.p50 + s.p90 + s.p95 + s.p99 + s.p999);
            5
        });
    }

    // Tracing overhead (ISSUE-9 tentpole): a traced-off serve must cost
    // nothing — `Tracer::Off` makes every record call a no-op — and even
    // a fully-traced run may only spend host time, never simulated time.
    // The bit-identity assertions are the contract; the timings bound the
    // host-side cost of each mode.
    {
        let fcfg = FleetConfig { servers: 2, ..FleetConfig::default() };
        let tcfg = TrafficConfig { requests: 1500, ..TrafficConfig::default() };
        let serve_with = |tracer: &mut Tracer| {
            let mut m = Metrics::new();
            serve_fleet_traced(App::Sentiment, &fcfg, &tcfg, &PowerModel::default(), &mut m, tracer)
                .expect("serve_fleet_traced")
        };
        let mut m = Metrics::new();
        let plain = serve_fleet(App::Sentiment, &fcfg, &tcfg, &PowerModel::default(), &mut m)
            .expect("serve_fleet");
        let off = serve_with(&mut Tracer::Off);
        let mut on = Tracer::in_memory(1);
        let traced = serve_with(&mut on);
        plain.check_bit_identical(&off).expect("Tracer::Off must be bit-identical to untraced");
        plain.check_bit_identical(&traced).expect("tracing on must not perturb simulated time");
        b.bench("traffic.serve_fleet 1.5k requests untraced", || {
            let r = serve_with(&mut Tracer::Off);
            std::hint::black_box(r.served);
            1_500
        });
        b.bench("traffic.serve_fleet 1.5k requests traced (sample=1)", || {
            let mut t = Tracer::in_memory(1);
            let r = serve_with(&mut t);
            std::hint::black_box((r.served, t.take_requests().0.len()));
            1_500
        });
    }

    // PJRT hot path (skipped when artifacts are absent).
    if let Some(mut eng) = Engine::load_default() {
        let f = eng.manifest.dim("sent_features")? as usize;
        let x = Tensor::zeros(vec![32, f]);
        let w = Tensor::zeros(vec![f, 1]);
        let bias = Tensor::zeros(vec![1]);
        // warm the executable cache
        eng.run("sentiment_infer", "b32", &[x.clone(), w.clone(), bias.clone()])?;
        b.bench("runtime.sentiment_infer b32", || {
            eng.run("sentiment_infer", "b32", &[x.clone(), w.clone(), bias.clone()])
                .unwrap();
            32
        });
    }

    print!("{}", b.report());
    b.write_json("perf_micro")?;
    // Opt-in committable trajectory point (BENCH_NNNN.json): CI sets the
    // env var and uploads bench-trajectory/ as an artifact.
    if std::env::var("SOLANA_BENCH_TRAJECTORY").ok().as_deref() == Some("1") {
        let p = b.write_trajectory("perf_micro")?;
        println!("bench trajectory point written to {}", p.display());
    }
    Ok(())
}
