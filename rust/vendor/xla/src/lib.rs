//! Offline API stub of the XLA/PJRT Rust bindings.
//!
//! The real bindings need the `xla_extension` C++ distribution, which the
//! offline build environment does not ship. This stub is type-compatible
//! with the surface `solana_isp::runtime` uses; every entry point that
//! would touch PJRT returns [`Error`] instead. `PjRtClient::cpu()`
//! failing is the load-bearing part: `runtime::Engine::load`/
//! `load_default` then report the runtime as unavailable and all
//! runtime-dependent tests, benches, and examples skip — the same
//! graceful path taken when `artifacts/` has not been built.
//!
//! Swap this path dependency for the real `xla` crate to run the actual
//! PJRT CPU engine; no caller changes are needed.

use std::path::Path;

/// Error type; callers format it with `{:?}`.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what}: XLA/PJRT is unavailable in this offline build (stub crate)"
    )))
}

/// Element types the runtime distinguishes; mirrors the real crate's
/// names so `match shape.ty()` arms stay source-compatible.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S8,
    S16,
    S32,
    S64,
    U8,
    U16,
    U32,
    U64,
    F16,
    Bf16,
    F32,
    F64,
}

/// Array shape: dimensions plus element type.
#[derive(Clone, Debug)]
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    pub fn new(dims: Vec<i64>, ty: ElementType) -> ArrayShape {
        ArrayShape { dims, ty }
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

/// Marker for element types transferable to/from device buffers.
pub trait ArrayElement: Copy + 'static {}
impl ArrayElement for f32 {}
impl ArrayElement for f64 {}
impl ArrayElement for i32 {}
impl ArrayElement for i64 {}
impl ArrayElement for u8 {}

/// Host-side literal (constant tensor value).
#[derive(Clone, Debug, Default)]
pub struct Literal;

impl Literal {
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        unavailable("Literal::reshape")
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        unavailable("Literal::array_shape")
    }

    pub fn to_vec<T: ArrayElement>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple")
    }
}

/// Parsed HLO module.
#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(path: impl AsRef<Path>) -> Result<HloModuleProto> {
        unavailable(&format!("HloModuleProto::from_text_file({:?})", path.as_ref()))
    }
}

/// An XLA computation ready for compilation.
#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device-resident buffer.
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Compiled, loaded executable.
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _inputs: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute_b")
    }
}

/// PJRT client handle.
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    /// Always fails in the stub — see the module docs.
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }

    pub fn buffer_from_host_buffer<T: ArrayElement>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        unavailable("PjRtClient::buffer_from_host_buffer")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(format!("{err:?}").contains("offline"), "{err:?}");
    }

    #[test]
    fn shape_accessors() {
        let s = ArrayShape::new(vec![2, 3], ElementType::F32);
        assert_eq!(s.dims(), &[2, 3]);
        assert_eq!(s.ty(), ElementType::F32);
    }
}
