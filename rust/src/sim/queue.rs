//! Event calendar: time-ordered heap with deterministic tie-breaking.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use super::SimTime;

struct Entry<E> {
    time: SimTime,
    seq: u64,
    event: E,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl<E> Eq for Entry<E> {}

impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap; invert for earliest-first. Ties break
        // on sequence number so insertion order is replayed exactly.
        other
            .time
            .partial_cmp(&self.time)
            // solana-lint: allow(no-unwrap, reason = "schedule_at rejects NaN timestamps at the door (release-profile clamp test), so ordering is total here")
            .expect("NaN event time")
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Deterministic event calendar.
///
/// `pop` advances [`EventQueue::now`] to the popped event's timestamp;
/// scheduling in the past (or NaN) panics in debug builds — a past event
/// is always a model bug.
pub struct EventQueue<E> {
    heap: BinaryHeap<Entry<E>>,
    seq: u64,
    now: SimTime,
    popped: u64,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        EventQueue { heap: BinaryHeap::new(), seq: 0, now: 0.0, popped: 0 }
    }

    /// Current simulated time (timestamp of the last popped event).
    #[inline]
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Number of events executed so far.
    pub fn events_executed(&self) -> u64 {
        self.popped
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Schedule `event` at absolute time `t ≥ now`.
    ///
    /// Scheduling into the past (or at NaN) is always a model bug, and
    /// the two build profiles handle it deliberately differently:
    ///
    /// * **debug**: panic at the call site (`debug_assert`), so tests
    ///   and development runs catch the bug where it happens;
    /// * **release**: the timestamp is **clamped to `now`** (and NaN
    ///   likewise becomes `now` — `f64::max` returns the other operand
    ///   for a NaN argument, so no NaN ever reaches the heap
    ///   comparator). A long optimized sweep thus degrades to a
    ///   causally-sane schedule — the event fires immediately — instead
    ///   of silently reordering history; `pop` never yields a time
    ///   before `now` in either profile.
    ///
    /// Both behaviours are covered by profile-gated tests below.
    pub fn schedule_at(&mut self, t: SimTime, event: E) {
        debug_assert!(!t.is_nan(), "NaN event time");
        debug_assert!(
            t >= self.now - super::TIME_EPS,
            "scheduling into the past: t={t} now={}",
            self.now
        );
        self.seq += 1;
        self.heap.push(Entry { time: t.max(self.now), seq: self.seq, event });
    }

    /// Schedule `event` after a non-negative delay.
    pub fn schedule(&mut self, delay: SimTime, event: E) {
        debug_assert!(delay >= 0.0, "negative delay {delay}");
        self.schedule_at(self.now + delay, event);
    }

    /// Pop the earliest event, advancing the clock to its timestamp.
    pub fn pop(&mut self) -> Option<(SimTime, E)> {
        let e = self.heap.pop()?;
        debug_assert!(e.time >= self.now - super::TIME_EPS);
        self.now = e.time;
        self.popped += 1;
        Some((e.time, e.event))
    }

    /// Peek the next event time without popping.
    ///
    /// This is what wake coalescing in [`crate::sched`] builds on: when a
    /// scheduler wake finds nothing dispatchable, the earliest pending
    /// ack bounds how far ahead the next wake can safely jump on the
    /// wake grid without changing any simulated result.
    pub fn peek_time(&self) -> Option<SimTime> {
        self.heap.peek().map(|e| e.time)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop::{check, forall};

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule_at(3.0, "c");
        q.schedule_at(1.0, "a");
        q.schedule_at(2.0, "b");
        assert_eq!(q.pop().unwrap(), (1.0, "a"));
        assert_eq!(q.pop().unwrap(), (2.0, "b"));
        assert_eq!(q.pop().unwrap(), (3.0, "c"));
        assert!(q.pop().is_none());
        assert_eq!(q.events_executed(), 3);
    }

    #[test]
    fn ties_break_in_insertion_order() {
        let mut q = EventQueue::new();
        for i in 0..100 {
            q.schedule_at(5.0, i);
        }
        for i in 0..100 {
            assert_eq!(q.pop().unwrap().1, i);
        }
    }

    #[test]
    fn clock_advances_monotonically() {
        let mut q = EventQueue::new();
        q.schedule(0.5, ());
        q.schedule(0.25, ());
        let (t1, _) = q.pop().unwrap();
        assert_eq!(t1, 0.25);
        assert_eq!(q.now(), 0.25);
        q.schedule(0.1, ()); // relative to new now
        let (t2, _) = q.pop().unwrap();
        assert!((t2 - 0.35).abs() < 1e-12);
    }

    // The past-timestamp contract diverges by profile on purpose (see
    // `schedule_at`): debug panics, release clamps to `now`. Each test
    // is gated to the profile whose behaviour it pins down — previously
    // the panic test alone would fail under `cargo test --release`.
    #[test]
    #[cfg(debug_assertions)]
    #[should_panic]
    fn scheduling_past_panics_in_debug() {
        let mut q = EventQueue::new();
        q.schedule_at(10.0, ());
        q.pop();
        q.schedule_at(5.0, ());
    }

    #[test]
    #[cfg(not(debug_assertions))]
    fn scheduling_past_clamps_to_now_in_release() {
        let mut q = EventQueue::new();
        q.schedule_at(10.0, "future");
        q.pop();
        q.schedule_at(5.0, "past");
        let (t, e) = q.pop().unwrap();
        assert_eq!(e, "past");
        assert_eq!(t, 10.0, "past timestamp clamps to now, never rewinds the clock");
        assert_eq!(q.now(), 10.0);
    }

    #[test]
    #[cfg(not(debug_assertions))]
    fn scheduling_nan_clamps_to_now_in_release() {
        let mut q = EventQueue::new();
        q.schedule_at(3.0, "a");
        q.pop();
        q.schedule_at(f64::NAN, "nan");
        let (t, e) = q.pop().unwrap();
        assert_eq!(e, "nan");
        assert_eq!(t, 3.0, "NaN timestamp becomes now instead of poisoning the heap");
    }

    #[test]
    fn property_pop_order_is_sorted_and_stable() {
        forall("event queue ordering", 100, |g| {
            let times = g.vec_f64(0.0, 100.0, 0, 200);
            let mut q = EventQueue::new();
            for (i, &t) in times.iter().enumerate() {
                q.schedule_at(t, i);
            }
            let mut last_t = f64::NEG_INFINITY;
            let mut last_seq_at_t: Option<usize> = None;
            while let Some((t, idx)) = q.pop() {
                check(t >= last_t, format!("time went backwards {t} < {last_t}"))?;
                if (t - last_t).abs() < 1e-15 {
                    if let Some(prev) = last_seq_at_t {
                        check(idx > prev, "tie not in insertion order")?;
                    }
                }
                if t > last_t {
                    last_seq_at_t = None;
                }
                last_t = t;
                if times[idx] == t {
                    last_seq_at_t = Some(idx);
                }
                check((times[idx] - t).abs() < 1e-12, "event time preserved")?;
            }
            Ok(())
        });
    }
}
