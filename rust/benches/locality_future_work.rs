//! `cargo bench --bench locality_future_work` — the paper's §V future
//! work: data-aware (category-routed) scheduling vs the oblivious
//! baseline, on the recommender workload.

use solana_isp::bench_support::Bencher;
use solana_isp::metrics::{Metrics, Table};
use solana_isp::power::PowerModel;
use solana_isp::sched::locality::{run_with_policy, LocalityConfig, Policy};
use solana_isp::sched::SchedConfig;
use solana_isp::workloads::AppModel;

fn main() -> anyhow::Result<()> {
    let items = if std::env::var("SOLANA_BENCH_FAST").is_ok() { 10_000 } else { 58_000 };
    let base = AppModel::recommender(items);
    let power = PowerModel::default();
    let cfg = LocalityConfig::default();
    let mut table = Table::new(
        "future work — data-aware vs oblivious routing (recommender)",
        &["policy", "csds", "queries/s", "gain"],
    );
    let mut bencher = Bencher::new(0, 1);
    for drives in [9usize, 18, 36] {
        let sched = SchedConfig {
            drives,
            isp_drives: drives,
            csd_batch: 256,
            batch_ratio: 22.0,
            ..SchedConfig::default()
        };
        let mut m = Metrics::new();
        let obl = run_with_policy(&base, &sched, Policy::Oblivious, &cfg, &power, &mut m)?;
        let aware = run_with_policy(&base, &sched, Policy::DataAware, &cfg, &power, &mut m)?;
        table.row(vec![
            "oblivious".into(),
            drives.to_string(),
            format!("{:.0}", obl.items_per_sec),
            "1.00x".into(),
        ]);
        table.row(vec![
            "data-aware".into(),
            drives.to_string(),
            format!("{:.0}", aware.items_per_sec),
            format!("{:.2}x", aware.items_per_sec / obl.items_per_sec),
        ]);
    }
    print!("{}", table.render());
    std::fs::create_dir_all("target/bench-results")?;
    std::fs::write("target/bench-results/locality.txt", table.render())?;
    bencher.bench("locality_pair_36", || {
        let sched = SchedConfig {
            drives: 36,
            isp_drives: 36,
            csd_batch: 256,
            batch_ratio: 22.0,
            ..SchedConfig::default()
        };
        let mut m = Metrics::new();
        run_with_policy(&base, &sched, Policy::DataAware, &cfg, &power, &mut m).unwrap();
        items
    });
    print!("{}", bencher.report());
    Ok(())
}
