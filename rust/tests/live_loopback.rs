//! Live-mode loopback integration test (ISSUE-3 satellite): drive
//! `sched::live` end-to-end — real OS threads, real in-process loopback
//! `Communicator`s, the full WEIGHTS/BATCH/RESULT/SHUTDOWN protocol — in
//! both dispatch modes, without PJRT artifacts. A deterministic oracle
//! classifier stands in for the AOT model, so the assertions are about
//! the *protocol*: every item served exactly once, and both modes agree
//! on the processed index set.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Duration;

use solana_isp::nlp::corpus::{Tweet, TweetCorpus};
use solana_isp::sched::live::{run_live_with, LiveClassifier, LiveConfig, LiveReport, WorkerFactory};
use solana_isp::sched::DispatchMode;

const ITEMS: usize = 1_024;
const SEED: u64 = 9;

/// Deterministic stand-in for the AOT model: classifies by looking the
/// text up in the ground-truth label map, so accuracy doubles as a
/// payload-integrity check (a misrouted index/label pair shows up as a
/// wrong answer).
struct OracleClassifier {
    labels: Arc<HashMap<String, bool>>,
}

impl LiveClassifier for OracleClassifier {
    fn classify(&mut self, texts: &[&str]) -> anyhow::Result<Vec<bool>> {
        texts
            .iter()
            .map(|t| {
                self.labels
                    .get(*t)
                    .copied()
                    .ok_or_else(|| anyhow::anyhow!("classifier saw a text outside the corpus"))
            })
            .collect()
    }
}

fn run_mode(dispatch: DispatchMode) -> LiveReport {
    let serve: Arc<Vec<Tweet>> = Arc::new(TweetCorpus::new(SEED).take(ITEMS));
    let labels: Arc<HashMap<String, bool>> =
        Arc::new(serve.iter().map(|t| (t.text.clone(), t.positive)).collect());
    let cfg = LiveConfig {
        workers: 3,
        batch: 16,
        ratio: 4,
        items: ITEMS,
        wakeup: Duration::from_millis(20),
        train_items: 0, // unused: run_live_with takes the corpus directly
        dispatch,
        seed: SEED,
        worker_deadline: 600,
    };
    let host = Box::new(OracleClassifier { labels: Arc::clone(&labels) });
    let factory: WorkerFactory = Arc::new(move |_rank, _weights: &[f32]| {
        Ok(Box::new(OracleClassifier { labels: Arc::clone(&labels) }) as Box<dyn LiveClassifier>)
    });
    run_live_with(&cfg, serve, vec![0.0; 8], host, factory).expect("live protocol run")
}

fn check_conservation(mode: &str, r: &LiveReport) {
    assert_eq!(r.items, ITEMS, "{mode}: item count");
    let worker_total: usize = r.worker_items.iter().sum();
    assert_eq!(
        r.host_items + worker_total,
        ITEMS,
        "{mode}: host {} + workers {worker_total} must cover every item exactly once",
        r.host_items
    );
    // Not redundant with the counter check above: processed_indices is
    // derived from the done[] array, host/worker_items from separate
    // counters — a bug that tallies without marking (or vice versa)
    // trips exactly one of the two. The *set contents* are asserted
    // once, cross-mode, in the test body, so that comparison stays
    // load-bearing.
    assert_eq!(
        r.processed_indices.len(),
        ITEMS,
        "{mode}: done[] marks must match the {ITEMS}-item corpus"
    );
    // The oracle is exact on corpus texts, so anything below 100%
    // means the protocol misrouted an index/label pair. (Duplicate
    // random tweet texts could in principle collide in the label map;
    // with same-text collisions the labels still agree or the corpus
    // seed would need changing — keep a hair of slack.)
    assert!(r.accuracy > 0.99, "{mode}: accuracy {} (payload misrouting?)", r.accuracy);
    assert!(r.messages > 0, "{mode}: tunnel carried protocol traffic");
    assert!(r.wall_secs > 0.0 && r.items_per_sec > 0.0, "{mode}: sane wall-clock report");
}

/// A classifier that answers instantly on the coordinator but parks
/// (bounded) on worker ranks, so the batches those workers hold never
/// come back within the watchdog budget.
struct StuckClassifier {
    labels: Arc<HashMap<String, bool>>,
    stall: Option<Duration>,
}

impl LiveClassifier for StuckClassifier {
    fn classify(&mut self, texts: &[&str]) -> anyhow::Result<Vec<bool>> {
        if let Some(d) = self.stall {
            // Bounded, so the test always terminates: the watchdog must
            // fire long before this sleep returns.
            std::thread::sleep(d);
        }
        texts
            .iter()
            .map(|t| {
                self.labels
                    .get(*t)
                    .copied()
                    .ok_or_else(|| anyhow::anyhow!("classifier saw a text outside the corpus"))
            })
            .collect()
    }
}

#[test]
fn watchdog_bails_on_a_stuck_worker() {
    // ISSUE-6 satellite: a worker that accepts a batch and never
    // answers must trip the coordinator's watchdog (10 × 20 ms here),
    // not hang the run. The stall is bounded at 4 s so the shutdown
    // join below always completes.
    let serve: Arc<Vec<Tweet>> = Arc::new(TweetCorpus::new(SEED).take(ITEMS));
    let labels: Arc<HashMap<String, bool>> =
        Arc::new(serve.iter().map(|t| (t.text.clone(), t.positive)).collect());
    let cfg = LiveConfig {
        workers: 3,
        batch: 16,
        ratio: 4,
        items: ITEMS,
        wakeup: Duration::from_millis(20),
        train_items: 0,
        dispatch: DispatchMode::Polling,
        seed: SEED,
        worker_deadline: 10,
    };
    let host = Box::new(StuckClassifier { labels: Arc::clone(&labels), stall: None });
    let factory: WorkerFactory = Arc::new(move |_rank, _weights: &[f32]| {
        Ok(Box::new(StuckClassifier {
            labels: Arc::clone(&labels),
            stall: Some(Duration::from_secs(4)),
        }) as Box<dyn LiveClassifier>)
    });
    let err = run_live_with(&cfg, serve, vec![0.0; 8], host, factory)
        .expect_err("a stuck worker must not hang the coordinator");
    assert!(err.to_string().contains("watchdog"), "unexpected error: {err}");
}

#[test]
fn watchdog_zero_deadline_is_rejected() {
    let serve: Arc<Vec<Tweet>> = Arc::new(TweetCorpus::new(SEED).take(16));
    let labels: Arc<HashMap<String, bool>> =
        Arc::new(serve.iter().map(|t| (t.text.clone(), t.positive)).collect());
    let cfg = LiveConfig {
        workers: 1,
        batch: 16,
        ratio: 1,
        items: 16,
        wakeup: Duration::from_millis(20),
        train_items: 0,
        dispatch: DispatchMode::Polling,
        seed: SEED,
        worker_deadline: 0,
    };
    let host = Box::new(OracleClassifier { labels: Arc::clone(&labels) });
    let factory: WorkerFactory = Arc::new(move |_rank, _weights: &[f32]| {
        Ok(Box::new(OracleClassifier { labels: Arc::clone(&labels) }) as Box<dyn LiveClassifier>)
    });
    let err = run_live_with(&cfg, serve, vec![0.0; 8], host, factory)
        .expect_err("worker_deadline = 0 must be rejected");
    assert!(err.to_string().contains("worker_deadline"), "unexpected error: {err}");
}

#[test]
fn live_loopback_both_modes_conserve_and_agree() {
    // One protocol run per dispatch mode: each must conserve (every
    // index exactly once, oracle accuracy = payload routing intact),
    // and the two modes — which hand out batches on different clocks —
    // must agree on the processed index set.
    let poll = run_mode(DispatchMode::Polling);
    check_conservation("polling", &poll);
    let event = run_mode(DispatchMode::EventDriven);
    check_conservation("event-driven", &event);
    assert_eq!(
        poll.processed_indices,
        (0..ITEMS as u32).collect::<Vec<u32>>(),
        "polling covers every serving index exactly once"
    );
    assert_eq!(
        poll.processed_indices, event.processed_indices,
        "dispatch modes disagree on the processed index set"
    );
}
