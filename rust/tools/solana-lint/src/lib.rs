//! solana-lint: the determinism & invariant static-analysis gate for
//! the solana-isp workspace (ISSUE-7).
//!
//! Every headline claim of this reproduction — bit-identity of reports,
//! `offered == accepted + shed` conservation, quiet-fault-plan ≡
//! no-plan — depends on conventions this tool mechanizes:
//!
//! * D1 `hash-iter`   — no HashMap/HashSet iteration (order reaches reports)
//! * D2 `wall-clock`  — no `Instant::now`/`SystemTime::now` in simulator paths
//! * D3 `rng-gate`    — RNG draws in faults/ and traffic/ gated on `rate > 0.0`
//! * D4 `no-unwrap`   — no `unwrap()`/`expect()`/`panic!` in library code
//! * D5 `lossy-cast`  — no lossy `as` narrowing on item/byte counters
//! * D6 `join-reduce` — threads only via the deterministic `exp::pool`
//!
//! Suppress a finding with a mandatory-reason marker on the line above
//! (or the same line):
//!
//! ```text
//! // solana-lint: allow(no-unwrap, reason = "mutex poisoning is unrecoverable here")
//! // solana-lint: allow-file(rng-gate, reason = "an arrivals stream is never quiet")
//! ```
//!
//! The scanner is a hand-rolled lexer + token-pattern rules: no syn, no
//! regex, no dependencies — consistent with the vendored-offline build.

pub mod lexer;
pub mod rules;

pub use rules::{Finding, RULES};

use std::fs;
use std::io;
use std::path::{Path, PathBuf};

/// Result of scanning one file or tree.
#[derive(Debug, Default)]
pub struct Report {
    pub findings: Vec<Finding>,
    pub suppressed: usize,
}

/// Scan one source string as if it were the file `rel` (path-scoped
/// rules key off `rel`'s components).
pub fn scan_source(rel: &str, src: &str) -> Report {
    let (toks, comments) = lexer::lex(src);
    let regions = rules::test_regions(&toks);
    let markers = rules::parse_markers(&comments);

    let mut raw = Vec::new();
    rules::rule_hash_iter(rel, &toks, &mut raw);
    rules::rule_wall_clock(rel, &toks, &mut raw);
    rules::rule_rng_gate(rel, &toks, &mut raw);
    rules::rule_no_unwrap(rel, &toks, &regions, &mut raw);
    rules::rule_lossy_cast(rel, &toks, &regions, &mut raw);
    rules::rule_join_reduce(rel, &toks, &regions, &mut raw);

    let mut report = Report::default();
    for mut f in raw {
        if markers.allows(f.rule, f.line) {
            report.suppressed += 1;
            continue;
        }
        f.file = rel.to_string();
        report.findings.push(f);
    }
    for (line, msg) in markers.bad {
        report.findings.push(Finding {
            rule: "bad-marker",
            file: rel.to_string(),
            line,
            col: 1,
            msg,
        });
    }
    report
}

/// Scan one file on disk, reporting it under the path `rel`.
pub fn scan_file(path: &Path, rel: &str) -> io::Result<Report> {
    let src = fs::read_to_string(path)?;
    Ok(scan_source(rel, &src))
}

/// Scan every `.rs` file under `root` (recursively, in sorted order);
/// findings carry paths relative to `root`.
pub fn scan_tree(root: &Path) -> io::Result<Report> {
    let mut files = Vec::new();
    walk(root, &mut files)?;
    let mut report = Report::default();
    for p in files {
        let rel = p
            .strip_prefix(root)
            .unwrap_or(&p)
            .to_string_lossy()
            .replace('\\', "/");
        let r = scan_file(&p, &rel)?;
        report.findings.extend(r.findings);
        report.suppressed += r.suppressed;
    }
    report
        .findings
        .sort_by(|a, b| (&a.file, a.line, a.col).cmp(&(&b.file, b.line, b.col)));
    Ok(report)
}

fn walk(dir: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    let mut entries: Vec<_> = fs::read_dir(dir)?.collect::<Result<_, _>>()?;
    entries.sort_by_key(|e| e.file_name());
    for e in entries {
        let p = e.path();
        if p.is_dir() {
            walk(&p, out)?;
        } else if p.extension().is_some_and(|x| x == "rs") {
            out.push(p);
        }
    }
    Ok(())
}

/// Minimal JSON string escaping (the output schema needs nothing more).
pub fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Render a report as the machine-readable JSON document emitted by
/// `solana-lint --json`.
pub fn to_json(report: &Report) -> String {
    let mut out = String::from("{\n  \"findings\": [");
    for (i, f) in report.findings.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!(
            "\n    {{\"rule\": \"{}\", \"file\": \"{}\", \"line\": {}, \"col\": {}, \
             \"message\": \"{}\"}}",
            json_escape(f.rule),
            json_escape(&f.file),
            f.line,
            f.col,
            json_escape(&f.msg)
        ));
    }
    if !report.findings.is_empty() {
        out.push_str("\n  ");
    }
    out.push_str(&format!(
        "],\n  \"suppressed\": {},\n  \"total\": {}\n}}\n",
        report.suppressed,
        report.findings.len()
    ));
    out
}
