//! Minimal but complete JSON implementation (RFC 8259 subset: no
//! surrogate-pair escapes beyond \uXXXX pass-through).
//!
//! Used for: reading `artifacts/manifest.json` produced by the Python AOT
//! step, and writing machine-readable experiment results consumed by the
//! report tooling.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use `BTreeMap` so output is deterministic.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset.
#[derive(Debug)]
pub struct JsonError {
    pub offset: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---------- constructors / accessors ----------

    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn set(&mut self, key: &str, value: Json) -> &mut Self {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), value);
        } else {
            // solana-lint: allow(no-unwrap, reason = "builder misuse on the serializer side is a programmer error at the call site, not a parse-path input error")
            panic!("Json::set on non-object");
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as u64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Path accessor: `j.at(&["model", "variants", "0"])`.
    pub fn at(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for p in path {
            cur = match cur {
                Json::Obj(m) => m.get(*p)?,
                Json::Arr(a) => a.get(p.parse::<usize>().ok()?)?,
                _ => return None,
            };
        }
        Some(cur)
    }

    // ---------- parsing ----------

    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ---------- writing ----------

    /// Compact single-line encoding.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty-printed with 2-space indent.
    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s.push('\n');
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_str(out, s),
            Json::Arr(a) => {
                if a.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push(']');
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_str(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string())
    }
}

impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Json {
        Json::Num(n)
    }
}
impl From<u64> for Json {
    fn from(n: u64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Json {
        Json::Num(n as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl From<Vec<Json>> for Json {
    fn from(a: Vec<Json>) -> Json {
        Json::Arr(a)
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, n: f64) {
    if n.is_finite() {
        if n.fract() == 0.0 && n.abs() < 1e15 {
            out.push_str(&format!("{}", n as i64));
        } else {
            out.push_str(&format!("{n}"));
        }
    } else {
        // JSON has no Inf/NaN; encode as null like most writers.
        out.push_str("null");
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { offset: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn eat(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(b'-' | b'0'..=b'9') => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.skip_ws();
            a.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            if self.pos + 5 > self.bytes.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 code point.
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().ok_or_else(|| self.err("invalid utf-8"))?;
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("bad number"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x\ny"}], "c": null}"#).unwrap();
        assert_eq!(j.at(&["a", "2", "b"]).unwrap().as_str(), Some("x\ny"));
        assert_eq!(j.get("c"), Some(&Json::Null));
    }

    #[test]
    fn parse_unicode_escape() {
        let j = Json::parse(r#""éA""#).unwrap();
        assert_eq!(j.as_str(), Some("éA"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"model":"sentiment","variants":[{"batch":64,"path":"a.hlo.txt"}],"ok":true,"pi":3.25}"#;
        let j = Json::parse(src).unwrap();
        let compact = j.to_string();
        assert_eq!(Json::parse(&compact).unwrap(), j);
        let pretty = j.to_pretty();
        assert_eq!(Json::parse(&pretty).unwrap(), j);
    }

    #[test]
    fn string_escaping_roundtrip() {
        let j = Json::Str("quote\" slash\\ tab\t nl\n ctl\u{1}".into());
        let enc = j.to_string();
        assert_eq!(Json::parse(&enc).unwrap(), j);
    }

    #[test]
    fn builder_api() {
        let mut j = Json::obj();
        j.set("name", "solana".into())
            .set("drives", 36u64.into())
            .set("ratio", 26.0.into());
        assert_eq!(j.get("drives").unwrap().as_u64(), Some(36));
        assert_eq!(j.get("name").unwrap().as_str(), Some("solana"));
    }

    #[test]
    fn integers_written_without_fraction() {
        assert_eq!(Json::Num(36.0).to_string(), "36");
        assert_eq!(Json::Num(3.5).to_string(), "3.5");
    }
}
