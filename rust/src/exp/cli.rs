//! CLI dispatch for the `solana` binary.
//!
//! ```text
//! solana run   --app sentiment --drives 36 --isp-drives 36 --batch 40000
//! solana run   --app speech --dispatch event   # off-grid dispatch (A4)
//! solana fleet --servers 4 --shape all-csd     # multi-server scale-out
//! solana fleet --servers 2 --weights 36,12     # heterogeneous capacity
//! solana serve --app sentiment --load 0.7      # online serving, tail latency
//! solana serve --process closed --clients 64   # closed-loop traffic
//! solana serve --admission on --policy least-work --skew 1.0   # control plane
//! solana serve --faults server-crash@0.3,crash-server=0 \
//!              --retries 3 --hedge --replicas 1          # chaos + resilience
//! solana serve --ingest-rate 2000                        # writes + GC under serving
//! solana serve --trace out.jsonl --trace-sample 8        # span tracing (ISSUE-9)
//! solana trace-report --input out.jsonl                  # tail-latency attribution
//! solana fig5  --app speech [--scale 0.25] [--threads 8]
//! solana fig6 | fig7 | fig8 | fig9 | fig10 | fig11 | fig12 | fig13 | table1 | power
//! solana ablate --which ratio|datapath|wakeup|dispatch --app sentiment
//! solana version | help
//! ```
//!
//! Sweep commands accept `--threads N` to size the parallel cell runner
//! (overrides `SOLANA_THREADS`; default: all cores). Results are
//! byte-identical at any thread count.

use crate::cli::{Args, Command};
use crate::cluster::fleet::{run_fleet, FleetReport};
use crate::config::{parse_app, parse_dispatch, parse_shape, ExperimentConfig};
use crate::exp::{self, Scale};
use crate::metrics::Metrics;
use crate::sched;
use crate::trace::{self, TraceFormat};
use crate::traffic::{parse_policy, parse_process, serve_fleet_traced, ServeReport};
use crate::workloads::{App, AppModel};

fn commands() -> Vec<Command> {
    vec![
        Command::new("run", "run one benchmark under the scheduler")
            .opt("app", None, "speech|recommender|sentiment (default: config app or sentiment)")
            .opt("config", None, "TOML config file (configs/*.toml)")
            .opt("drives", None, "populated drive bays (default 36)")
            .opt("isp-drives", None, "drives with ISP engaged (default = drives)")
            .opt("batch", None, "CSD batch size (items)")
            .opt("ratio", None, "host/CSD batch ratio")
            .opt("dispatch", None, "polling|event — when batches are handed out (default polling, the paper's 0.2 s grid; event = re-arm on ack, A4)")
            .opt("scale", None, "dataset scale vs paper (0..1], default 0.25")
            .flag("baseline", "disable all ISP engines (storage-only)")
            .flag("json", "emit the report as JSON"),
        Command::new("fleet", "run one benchmark across N storage servers (sharded corpus)")
            .opt("app", None, "speech|recommender|sentiment (default: config app or sentiment)")
            .opt("config", None, "TOML config file ([fleet] + [sched] sections)")
            .opt("servers", None, "storage servers in the fleet (default: config [fleet] or 1)")
            .opt("shape", None, "all-csd|all-ssd|mixed — which servers engage ISPs (default: config [fleet] or all-csd)")
            .opt("drives", None, "drive bays per server (default 36)")
            .opt("isp-drives", None, "ISP-engaged drives per CSD server (default = drives)")
            .opt("batch", None, "CSD batch size (items)")
            .opt("ratio", None, "host/CSD batch ratio")
            .opt("dispatch", None, "polling|event — per-server dispatch mode")
            .opt("weights", None, "comma-separated capacity weights, one per server (heterogeneous fleets)")
            .opt("scale", None, "dataset scale vs paper (0..1], default 0.25")
            .flag("json", "emit the fleet report as JSON"),
        Command::new("serve", "serve online traffic and report tail latency")
            .opt("app", None, "speech|recommender|sentiment (default: config app or sentiment)")
            .opt("config", None, "TOML config file ([traffic] + [fleet] + [sched] sections)")
            .opt("servers", None, "storage servers behind the balancer (default: config [fleet] or 1)")
            .opt("shape", None, "all-csd|all-ssd|mixed — which servers engage ISPs")
            .opt("weights", None, "comma-separated capacity weights, one per server")
            .opt("drives", None, "drive bays per server (default 36)")
            .opt("isp-drives", None, "ISP-engaged drives per CSD server (default = drives)")
            .opt("batch", None, "CSD batch size (default: per-app scale-out point)")
            .opt("ratio", None, "host/CSD batch ratio")
            .opt("dispatch", None, "polling|event — when batches are handed out")
            .opt("process", None, "poisson|bursty|closed — arrival process (default poisson)")
            .opt("load", None, "offered load as a fraction of nominal capacity (default 0.5)")
            .opt("rate", None, "absolute offered rate, requests/s (overrides --load; open-loop processes only)")
            .opt("requests", None, "total requests (default: scaled corpus / 4)")
            .opt("min-batch", None, "batch formation: dispatch at this many queued requests (default 1)")
            .opt("clients", None, "closed loop: concurrent clients (default 64)")
            .opt("policy", None, "rr|weighted|jsq|least-work — front-door balancer (default jsq)")
            .opt("admission", None, "on|off — SLO-aware admission control: shed requests whose estimated wait blows the p99 deadline budget (default off)")
            .opt("skew", None, "hot-shard placement skew exponent (Zipf-like per-drive weighting; 0 = uniform, default 0)")
            .opt("slo", None, "p99 SLO in seconds (default: per-app, 4x the CSD batch service time)")
            .opt("retries", None, "per-request retry budget after a timeout (default 0 = fire-and-forget)")
            .opt("retry-timeout", None, "per-request timeout in seconds before a retry (default: 4x the estimated completion time)")
            .opt("replicas", None, "shard replicas per server for crash failover (default 0; must be < servers)")
            .opt("faults", None, "fault plan: comma-separated name@rate / key=value clauses, e.g. 'ack-loss@0.05,stall@0.1,stall-s=0.2' or 'server-crash@0.3,crash-server=0'")
            .opt("fault-seed", None, "fault-plan RNG seed (independent of the traffic stream; requires --faults)")
            .opt("ingest-rate", None, "background ingest/update writes per second per server — runs the full FTL/GC write path during serving (default 0 = read-only)")
            .opt("autoscale", None, "reactive|predictive — arm the mid-run autoscaler (elastic fleet; --servers is the initial size)")
            .opt("autoscale-min", None, "autoscaler fleet floor (default 1; requires --autoscale)")
            .opt("autoscale-max", None, "autoscaler fleet ceiling (default 8; requires --autoscale)")
            .opt("autoscale-interval", None, "seconds between autoscaler evaluations (default 1)")
            .opt("autoscale-hysteresis", None, "scale-down dead band in (0,1): drain only when the window p99 stays under (1-h) x SLO (default 0.25)")
            .opt("autoscale-window", None, "predictive arrival-rate estimator window, seconds (default 10)")
            .opt("autoscale-util", None, "target per-server utilization in (0,1] (default 0.8)")
            .opt("autoscale-rebalance", None, "on|off — migrate hot shards between servers mid-run (default on)")
            .opt("autoscale-rebalance-threshold", None, "hottest server's share of window-routed requests that triggers a migration, in (0,1] (default 0.55)")
            .opt("autoscale-shards", None, "routable shards the corpus splits into (default 32; must be >= the ceiling)")
            .flag("hedge", "hedge slow requests: duplicate at 75% of the timeout, first response wins")
            .opt("trace", None, "arm the span tracer and write the request trace to this path (see also the [trace] config section)")
            .opt("trace-format", None, "jsonl|chrome — trace export format (default jsonl; chrome loads in Perfetto)")
            .opt("trace-sample", None, "trace every Nth request by id (default 1 = every request)")
            .opt("scale", None, "dataset scale vs paper (0..1], default 0.25")
            .flag("baseline", "disable all ISP engines (storage-only)")
            .flag("json", "emit the serving report as JSON"),
        Command::new("fig5", "regenerate Fig 5 (throughput sweep)")
            .opt("app", Some("speech"), "speech|recommender|sentiment")
            .opt("scale", None, "dataset scale (default 0.25)")
            .opt("threads", None, "sweep worker threads (default: SOLANA_THREADS or all cores)"),
        Command::new("fig6", "regenerate Fig 6 (1-node batch sweep)")
            .opt("scale", None, "dataset scale")
            .opt("threads", None, "sweep worker threads"),
        Command::new("fig7", "regenerate Fig 7 (energy per query)")
            .opt("scale", None, "dataset scale")
            .opt("threads", None, "sweep worker threads"),
        Command::new("fig8", "regenerate Fig 8 (fleet scale-out sweep, 1→8 servers)")
            .opt("scale", None, "dataset scale")
            .opt("threads", None, "sweep worker threads"),
        Command::new("fig9", "regenerate Fig 9 (serving latency vs offered load)")
            .opt("scale", None, "dataset scale")
            .opt("threads", None, "sweep worker threads"),
        Command::new("fig10", "regenerate Fig 10 (autoscaling: min servers vs offered load)")
            .opt("scale", None, "dataset scale")
            .opt("threads", None, "sweep worker threads"),
        Command::new("fig11", "regenerate Fig 11 (availability under faults × resilience policy)")
            .opt("scale", None, "dataset scale")
            .opt("threads", None, "sweep worker threads"),
        Command::new("fig12", "regenerate Fig 12 (elastic fleet: autoscaler + shard rebalancer vs best static fleet)")
            .opt("scale", None, "dataset scale")
            .opt("threads", None, "sweep worker threads"),
        Command::new("fig13", "regenerate Fig 13 (write + GC interference: tail latency and WAF under ingest)")
            .opt("scale", None, "dataset scale")
            .opt("threads", None, "sweep worker threads"),
        Command::new("table1", "regenerate Table I (summary)")
            .opt("scale", None, "dataset scale")
            .opt("threads", None, "sweep worker threads"),
        Command::new("power", "print the power breakdown (§IV-C)"),
        Command::new("ablate", "run an ablation study")
            .opt("which", Some("ratio"), "ratio|datapath|wakeup|dispatch")
            .opt("app", Some("sentiment"), "benchmark app")
            .opt("scale", None, "dataset scale")
            .opt("threads", None, "sweep worker threads"),
        Command::new("trace-report", "read a request trace and print the tail-latency attribution")
            .opt("input", None, "trace file produced by `solana serve --trace` (required)")
            .opt("format", Some("jsonl"), "jsonl|chrome — chrome validates the event stream instead of reporting")
            .flag("csv", "emit the attribution table as CSV"),
        Command::new("version", "print the version"),
        Command::new("help", "show this help"),
    ]
}

/// Resolve the config-file / per-app-default / CLI-flag precedence
/// shared by `run` and `fleet` (flags beat the file, the file beats the
/// per-app defaults — including `--scale`, where `cli_scale` is the
/// already-validated flag/env value used only when the flag was given).
/// `default_batch_for` supplies the command's batch operating point:
/// the Fig 5 best batch for `run`, the scale-out point for `fleet` (see
/// [`exp::scaleout_batch`]).
fn resolve_sched_args(
    args: &Args,
    default_batch_for: fn(App) -> u64,
    cli_scale: Scale,
) -> anyhow::Result<(App, ExperimentConfig, Scale)> {
    let mut cfg = match args.str("config") {
        Some(path) => ExperimentConfig::from_file(path)?,
        None => ExperimentConfig::default(),
    };
    // No CLI default for --app: a hard default would shadow the config
    // file's `app` key (flag > file > sentiment).
    let app = match args.str("app") {
        Some(a) => parse_app(a)?,
        None => cfg.app.unwrap_or(App::Sentiment),
    };
    if let Some(d) = args.u64("drives")? {
        cfg.sched.drives = d as usize;
        cfg.sched.isp_drives = cfg.sched.isp_drives.min(d as usize);
    }
    if let Some(d) = args.u64("isp-drives")? {
        cfg.sched.isp_drives = d as usize;
    }
    if args.flag("baseline") {
        cfg.sched.isp_drives = 0;
    }
    if let Some(b) = args.u64("batch")? {
        cfg.sched.csd_batch = b;
    } else if !cfg.batch_explicit {
        cfg.sched.csd_batch = default_batch_for(app);
    }
    if let Some(r) = args.f64("ratio")? {
        cfg.sched.batch_ratio = r;
    } else if !cfg.ratio_explicit {
        cfg.sched.batch_ratio = exp::batch_ratio(app);
    }
    if let Some(d) = args.str("dispatch") {
        cfg.sched.dispatch = parse_dispatch(d)?;
    }
    let scale = match args.f64("scale")? {
        Some(_) => cli_scale,
        None => Scale(cfg.scale),
    };
    Ok((app, cfg, scale))
}

/// Dispatch CLI arguments; returns the process exit code.
pub fn dispatch(argv: &[String]) -> anyhow::Result<i32> {
    let name = argv.first().map(|s| s.as_str()).unwrap_or("help");
    let rest: Vec<String> = argv.iter().skip(1).cloned().collect();
    let cmds = commands();
    let Some(cmd) = cmds.iter().find(|c| c.name == name) else {
        eprintln!("unknown command '{name}'");
        print_help(&cmds);
        return Ok(2);
    };
    let args = cmd.parse(&rest)?;
    let scale = match args.f64("scale")? {
        Some(s) => {
            anyhow::ensure!(s > 0.0 && s <= 1.0, "--scale must be in (0,1]");
            Scale(s)
        }
        None => Scale::from_env(),
    };
    if let Some(n) = args.u64("threads")? {
        anyhow::ensure!(n >= 1, "--threads must be >= 1");
        exp::pool::set_threads(n as usize);
    }
    match name {
        "version" => println!("solana-isp {}", crate::VERSION),
        "help" => print_help(&cmds),
        "run" => {
            let (app, cfg, scale) = resolve_sched_args(&args, exp::default_batch, scale)?;
            let items = scale.items(app);
            let model = AppModel::for_app(app, items);
            let mut metrics = Metrics::new();
            let r = sched::run(&model, &cfg.sched, &cfg.power, &mut metrics)?;
            if args.flag("json") {
                println!("{}", report_json(&r).to_pretty());
            } else {
                print_report(&r);
            }
        }
        "fleet" => {
            let (app, cfg, scale) = resolve_sched_args(&args, exp::scaleout_batch, scale)?;
            let mut fcfg = cfg.fleet.clone();
            // CLI sched overrides feed the per-server template too.
            fcfg.sched = cfg.sched.clone();
            if let Some(n) = args.u64("servers")? {
                anyhow::ensure!(n >= 1, "--servers must be >= 1");
                fcfg.servers = n as usize;
            }
            if let Some(s) = args.str("shape") {
                fcfg.shape = parse_shape(s)?;
            }
            if let Some(w) = args.u64_list("weights")? {
                fcfg.weights = Some(w);
            }
            fcfg.validate_weights()?;
            let items = scale.items(app);
            let mut metrics = Metrics::new();
            let r = run_fleet(app, items, &fcfg, &cfg.power, &mut metrics)?;
            if args.flag("json") {
                println!("{}", fleet_json(&r).to_pretty());
            } else {
                print_fleet_report(&r);
            }
        }
        "serve" => {
            let (app, cfg, scale) = resolve_sched_args(&args, exp::scaleout_batch, scale)?;
            let mut fcfg = cfg.fleet.clone();
            fcfg.sched = cfg.sched.clone();
            // Serving defaults to 1 server unless the config/flags say
            // otherwise (the balancer degenerates, the rack is unused).
            if let Some(n) = args.u64("servers")? {
                anyhow::ensure!(n >= 1, "--servers must be >= 1");
                fcfg.servers = n as usize;
            }
            if let Some(s) = args.str("shape") {
                fcfg.shape = parse_shape(s)?;
            }
            if let Some(w) = args.u64_list("weights")? {
                fcfg.weights = Some(w);
            }
            fcfg.validate_weights()?;
            let mut tcfg = cfg.traffic.clone();
            if let Some(p) = args.str("process") {
                tcfg.process = parse_process(p)?;
            }
            if let Some(l) = args.f64("load")? {
                anyhow::ensure!(l > 0.0 && l.is_finite(), "--load must be positive");
                tcfg.load = l;
            }
            if let Some(r) = args.f64("rate")? {
                anyhow::ensure!(r > 0.0 && r.is_finite(), "--rate must be positive");
                tcfg.rate_rps = Some(r);
            }
            if let Some(n) = args.u64("requests")? {
                anyhow::ensure!(n >= 1, "--requests must be >= 1");
                tcfg.requests = n;
            } else if !cfg.requests_explicit {
                tcfg.requests = exp::fig9_requests(app, scale);
            }
            if let Some(n) = args.u64("min-batch")? {
                anyhow::ensure!(n >= 1, "--min-batch must be >= 1");
                tcfg.min_batch = n;
            }
            if let Some(n) = args.u64("clients")? {
                anyhow::ensure!(n >= 1, "--clients must be >= 1");
                tcfg.clients = n as usize;
            }
            if let Some(p) = args.str("policy") {
                tcfg.policy = parse_policy(p)?;
            }
            if let Some(a) = args.str("admission") {
                tcfg.admission = crate::traffic::parse_on_off(a)
                    .map_err(|e| anyhow::anyhow!("--admission: {e}"))?;
            }
            if let Some(s) = args.f64("skew")? {
                anyhow::ensure!(
                    s >= 0.0 && s.is_finite(),
                    "--skew must be non-negative and finite"
                );
                tcfg.skew = s;
            }
            if let Some(s) = args.f64("slo")? {
                anyhow::ensure!(s > 0.0 && s.is_finite(), "--slo must be positive");
                tcfg.slo_p99_s = Some(s);
            }
            if let Some(n) = args.u64("retries")? {
                tcfg.retries = n as u32;
            }
            if let Some(s) = args.f64("retry-timeout")? {
                anyhow::ensure!(s > 0.0 && s.is_finite(), "--retry-timeout must be positive");
                tcfg.retry_timeout_s = Some(s);
            }
            if args.flag("hedge") {
                tcfg.hedge = true;
            }
            if let Some(r) = args.f64("ingest-rate")? {
                anyhow::ensure!(
                    r >= 0.0 && r.is_finite(),
                    "--ingest-rate must be non-negative and finite"
                );
                tcfg.ingest_rate = r;
            }
            if let Some(n) = args.u64("replicas")? {
                // Range (replicas < servers) is validated by serve_fleet,
                // which sees the final server count.
                fcfg.replicas = n as usize;
            }
            // Elastic fleet (ISSUE-10): --autoscale arms the autoscaler
            // (layering over an [autoscale] config section if present);
            // the sub-flags tune it. A sub-flag without the autoscaler
            // armed is rejected, not silently ignored. Knob ranges are
            // validated by serve_fleet against the final fleet.
            if let Some(p) = args.str("autoscale") {
                let mut ac = tcfg.autoscale.take().unwrap_or_default();
                ac.policy = crate::traffic::parse_autoscale_policy(p)
                    .map_err(|e| anyhow::anyhow!("--autoscale: {e}"))?;
                tcfg.autoscale = Some(ac);
            }
            match tcfg.autoscale.as_mut() {
                Some(ac) => {
                    if let Some(n) = args.u64("autoscale-min")? {
                        ac.min_servers = n as usize;
                    }
                    if let Some(n) = args.u64("autoscale-max")? {
                        ac.max_servers = n as usize;
                    }
                    if let Some(s) = args.f64("autoscale-interval")? {
                        ac.check_interval_s = s;
                    }
                    if let Some(h) = args.f64("autoscale-hysteresis")? {
                        ac.hysteresis = h;
                    }
                    if let Some(w) = args.f64("autoscale-window")? {
                        ac.estimator_window_s = w;
                    }
                    if let Some(u) = args.f64("autoscale-util")? {
                        ac.target_util = u;
                    }
                    if let Some(v) = args.str("autoscale-rebalance") {
                        ac.rebalance = crate::traffic::parse_on_off(v)
                            .map_err(|e| anyhow::anyhow!("--autoscale-rebalance: {e}"))?;
                    }
                    if let Some(t) = args.f64("autoscale-rebalance-threshold")? {
                        ac.rebalance_threshold = t;
                    }
                    if let Some(n) = args.u64("autoscale-shards")? {
                        ac.shards = n as usize;
                    }
                }
                None => {
                    for key in [
                        "autoscale-min",
                        "autoscale-max",
                        "autoscale-interval",
                        "autoscale-hysteresis",
                        "autoscale-window",
                        "autoscale-util",
                        "autoscale-rebalance",
                        "autoscale-rebalance-threshold",
                        "autoscale-shards",
                    ] {
                        anyhow::ensure!(
                            args.str(key).is_none(),
                            "--{key} requires --autoscale or an [autoscale] config section"
                        );
                    }
                }
            }
            if let Some(spec) = args.str("faults") {
                let seed = match args.u64("fault-seed")? {
                    Some(s) => s,
                    None => crate::faults::FaultsConfig::default().seed,
                };
                // Rates/targets are validated by serve_fleet against the
                // final fleet; parse only checks the clause grammar.
                tcfg.faults = Some(crate::faults::FaultsConfig::parse(spec, seed)?);
            } else if let Some(seed) = args.u64("fault-seed")? {
                match tcfg.faults.as_mut() {
                    Some(fc) => fc.seed = seed,
                    None => anyhow::bail!(
                        "--fault-seed requires --faults or a [faults] config section"
                    ),
                }
            }
            // An explicit --load is meaningless for a closed loop
            // (offered rate = clients/think): rejected, not silently
            // ignored — mirroring serve_fleet's --rate guard.
            anyhow::ensure!(
                !(tcfg.process == crate::traffic::ArrivalProcess::ClosedLoop
                    && args.f64("load")?.is_some()),
                "--load does not apply to the closed-loop process: its offered rate is \
                 clients/think_s; drop --load or use an open-loop process"
            );
            // Span tracing (ISSUE-9): flags layer over the [trace]
            // config section; --trace both arms the tracer and names
            // the export file.
            let mut trcfg = cfg.trace.clone();
            if let Some(p) = args.str("trace") {
                trcfg.enabled = true;
                trcfg.out = Some(p.to_string());
            }
            if let Some(f) = args.str("trace-format") {
                trcfg.format = TraceFormat::parse(f).ok_or_else(|| {
                    anyhow::anyhow!("--trace-format: expected jsonl|chrome, got '{f}'")
                })?;
            }
            if let Some(n) = args.u64("trace-sample")? {
                trcfg.sample_every = n;
            }
            trcfg.validate().map_err(|e| anyhow::anyhow!("{e}"))?;
            let mut metrics = Metrics::new();
            let mut tracer = trcfg.tracer();
            // The report carries the resolved p99 SLO (the `--slo` /
            // `[traffic] slo_p99_s` override or the per-app default).
            let r = serve_fleet_traced(app, &fcfg, &tcfg, &cfg.power, &mut metrics, &mut tracer)?;
            let traced = if tracer.is_on() {
                let (reqs, dropped) = tracer.take_requests();
                trace::verify_conservation(&reqs)
                    .map_err(|e| anyhow::anyhow!("trace conservation: {e}"))?;
                if let Some(path) = &trcfg.out {
                    let text = match trcfg.format {
                        TraceFormat::Chrome => trace::chrome_trace(&reqs).to_pretty(),
                        TraceFormat::Jsonl => trace::to_jsonl(&reqs),
                    };
                    std::fs::write(path, text)?;
                }
                Some((reqs, dropped))
            } else {
                None
            };
            if args.flag("json") {
                println!("{}", serve_json(&r).to_pretty());
            } else {
                print_serve_report(&r);
                if let Some((reqs, dropped)) = &traced {
                    print!("{}", trace::attribution_table(&trace::attribution(reqs)).render());
                    println!("traced requests     {:>14} ({dropped} evicted)", reqs.len());
                }
            }
        }
        "trace-report" => {
            let path = args
                .str("input")
                .ok_or_else(|| anyhow::anyhow!("--input <trace file> is required"))?;
            let text = std::fs::read_to_string(path)?;
            match args.str("format").unwrap_or("jsonl") {
                "chrome" => {
                    let j = crate::codec::json::Json::parse(&text)
                        .map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
                    trace::check_chrome(&j).map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
                    println!("{path}: chrome trace ok");
                }
                "jsonl" => {
                    let reqs = trace::parse_jsonl(&text)
                        .map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
                    trace::verify_conservation(&reqs)
                        .map_err(|e| anyhow::anyhow!("{path}: conservation: {e}"))?;
                    let table = trace::attribution_table(&trace::attribution(&reqs));
                    if args.flag("csv") {
                        print!("{}", table.to_csv());
                    } else {
                        print!("{}", table.render());
                        println!("traced requests: {}", reqs.len());
                    }
                }
                other => anyhow::bail!("--format: expected jsonl|chrome, got '{other}'"),
            }
        }
        "fig5" => {
            let app = parse_app(args.str("app").unwrap_or("speech"))?;
            let suffix = match app {
                App::SpeechToText => "a",
                App::Recommender => "b",
                App::Sentiment => "c",
            };
            exp::emit(&exp::fig5(app, scale)?, &format!("fig5{suffix}"))?;
        }
        "fig6" => exp::emit(&exp::fig6(scale)?, "fig6")?,
        "fig7" => exp::emit(&exp::fig7(scale)?, "fig7")?,
        "fig8" => exp::emit(&exp::fig8_scaleout(scale)?, "fig8")?,
        "fig9" => exp::emit(&exp::fig9_latency(scale)?, "fig9")?,
        "fig10" => exp::emit(&exp::fig10_autoscale(scale)?, "fig10")?,
        "fig11" => exp::emit(&exp::fig11_availability(scale)?, "fig11")?,
        "fig12" => exp::emit(&exp::fig12_elastic(scale)?, "fig12")?,
        "fig13" => exp::emit(&exp::fig13_gc(scale)?, "fig13")?,
        "table1" => exp::emit(&exp::table1(scale)?, "table1")?,
        "power" => exp::emit(&exp::power_breakdown(), "power")?,
        "ablate" => {
            let app = parse_app(args.str("app").unwrap_or("sentiment"))?;
            match args.str("which").unwrap_or("ratio") {
                "ratio" => exp::emit(&exp::ablate_batch_ratio(app, scale)?, "ablate_ratio")?,
                "datapath" => exp::emit(&exp::ablate_datapath(app, scale)?, "ablate_datapath")?,
                "wakeup" => exp::emit(&exp::ablate_wakeup(app, scale)?, "ablate_wakeup")?,
                "dispatch" => exp::emit(&exp::ablate_dispatch(app, scale)?, "ablate_dispatch")?,
                other => anyhow::bail!("unknown ablation '{other}'"),
            }
        }
        _ => unreachable!(),
    }
    Ok(0)
}

fn print_help(cmds: &[Command]) {
    println!("solana-isp {} — Solana CSD reproduction\n", crate::VERSION);
    println!("commands:");
    for c in cmds {
        println!("  {:<10} {}", c.name, c.about);
    }
    println!("\nrun `solana <command> --help-like-nonsense` to see its options error message.");
}

fn print_report(r: &sched::RunReport) {
    println!("== {} run ==", r.app);
    println!("dispatch            {:>14}", r.dispatch);
    println!("items               {:>14}", r.total_items);
    println!("makespan            {:>14}", crate::util::human_secs(r.makespan_secs));
    println!("throughput          {:>11.1} items/s", r.items_per_sec);
    if r.words_per_sec != r.items_per_sec {
        println!("                    {:>11.1} words/s", r.words_per_sec);
    }
    println!("host/csd items      {:>7} / {}", r.host_items, r.csd_items);
    println!("csd data share      {:>13.1}%", r.csd_data_fraction() * 100.0);
    println!("pcie bytes          {:>14}", crate::util::human_bytes(r.pcie_bytes));
    println!("in-storage bytes    {:>14}", crate::util::human_bytes(r.isp_bytes));
    println!("tunnel messages     {:>14}", r.tunnel_messages);
    println!("energy              {:>11.1} J ({:.1} W avg)", r.energy_j, r.avg_power_w);
    println!("energy/item         {:>11.4} J", r.energy_per_item_j);
    println!("mean batch latency  {:>11.2} s", r.mean_batch_latency);
    println!("flash waf           {:>14.3}", r.waf);
    println!("gc runs / wear      {:>7} / {}", r.gc_runs, r.wear_spread);
    println!("des events          {:>14} ({} wakes)", r.events_executed, r.wake_events);
}

fn print_fleet_report(r: &FleetReport) {
    println!("== {} fleet run ==", r.app);
    println!("shape               {:>14}", r.shape);
    println!("servers             {:>14}", r.servers);
    println!("items               {:>14}", r.total_items);
    println!("makespan            {:>14}", crate::util::human_secs(r.makespan_secs));
    println!("agg phase           {:>14}", crate::util::human_secs(r.agg_secs));
    println!("throughput          {:>11.1} items/s", r.items_per_sec);
    if r.words_per_sec != r.items_per_sec {
        println!("                    {:>11.1} words/s", r.words_per_sec);
    }
    println!("host/csd items      {:>7} / {}", r.host_items, r.csd_items);
    println!("csd data share      {:>13.1}%", r.csd_data_fraction() * 100.0);
    println!("pcie bytes          {:>14}", crate::util::human_bytes(r.pcie_bytes));
    println!("in-storage bytes    {:>14}", crate::util::human_bytes(r.isp_bytes));
    println!("rack bytes          {:>14}", crate::util::human_bytes(r.rack_bytes));
    println!("rack messages       {:>14}", r.rack_messages);
    println!("tunnel messages     {:>14}", r.tunnel_messages);
    println!("energy              {:>11.1} J", r.energy_j);
    println!("energy/item         {:>11.4} J", r.energy_per_item_j);
    for (i, s) in r.per_server.iter().enumerate() {
        println!(
            "  server {i:<2} {:>9} items  {:>9.1} items/s  makespan {:>10}",
            s.total_items,
            s.items_per_sec,
            crate::util::human_secs(s.makespan_secs)
        );
    }
}

fn print_serve_report(r: &ServeReport) {
    println!("== {} serving run ==", r.app);
    println!("shape               {:>14}", r.shape);
    println!("servers             {:>14}", r.servers);
    println!("policy              {:>14}", r.policy);
    println!("process             {:>14}", r.process);
    println!("dispatch            {:>14}", r.dispatch);
    println!("admission           {:>14}", if r.admission { "on" } else { "off" });
    println!("requests            {:>14}", r.requests);
    println!("served / shed       {:>7} / {}", r.served, r.shed);
    if r.shed > 0 {
        println!("goodput loss        {:>13.1}%", r.shed_fraction() * 100.0);
    }
    if r.failed > 0 || r.retried > 0 || r.hedged > 0 {
        println!("failed              {:>14}", r.failed);
        println!("retried / hedged    {:>7} / {}", r.retried, r.hedged);
        println!("dup suppressed      {:>14}", r.duplicate_suppressed);
    }
    println!("availability        {:>13.2}%", r.availability * 100.0);
    println!("offered             {:>11.1} req/s", r.offered_rps);
    println!("goodput             {:>11.1} req/s", r.achieved_rps);
    println!("duration            {:>14}", crate::util::human_secs(r.duration_secs));
    println!("latency mean        {:>14}", crate::util::human_secs(r.latency.mean));
    println!("        p50         {:>14}", crate::util::human_secs(r.latency.p50));
    println!("        p95         {:>14}", crate::util::human_secs(r.latency.p95));
    println!("        p99         {:>14}", crate::util::human_secs(r.latency.p99));
    println!("        p99.9       {:>14}", crate::util::human_secs(r.latency.p999));
    println!("        max         {:>14}", crate::util::human_secs(r.latency.max));
    println!("host/csd items      {:>7} / {}", r.host_items, r.csd_items);
    println!("csd share           {:>13.1}%", r.csd_share() * 100.0);
    println!("host/csd batches    {:>7} / {}", r.host_batches, r.csd_batches);
    println!("rack bytes          {:>14}", crate::util::human_bytes(r.rack_bytes));
    println!("rack messages       {:>14}", r.rack_messages);
    if r.ingest_writes > 0 {
        println!("ingest writes       {:>14}", r.ingest_writes);
        println!("flash waf           {:>14.3}", r.waf);
        println!("gc runs / wear      {:>7} / {}", r.gc_runs, r.wear_spread);
    }
    println!("energy              {:>11.1} J ({:.4} J/req)", r.energy_j, r.energy_per_req_j);
    println!("des events          {:>14} ({} wakes)", r.engine_events, r.wake_events);
    println!(
        "queue depth         {:>10.2} avg / {} max  ({} inflight max)",
        r.mean_queue_depth, r.max_queue_depth, r.max_inflight
    );
    println!(
        "p99 SLO             {:>14}  [{}]",
        crate::util::human_secs(r.slo_p99_s),
        if r.meets_slo() { "met" } else { "violated" }
    );
    if !r.timeline.is_empty() {
        println!("fleet peak          {:>14}", r.peak_servers);
        println!("joins / drains      {:>7} / {}", r.joins, r.drains);
        println!(
            "migrations          {:>14} ({})",
            r.migrations,
            crate::util::human_bytes(r.migrated_bytes)
        );
        println!("server-seconds      {:>13.1}s", r.server_seconds);
    }
    for s in &r.per_server {
        println!(
            "  server {:<2} {:>5} {:>9} served  {:>7} shed  host {:>9}  csd {:>9}",
            s.index,
            if s.is_csd { "csd" } else { "ssd" },
            s.served,
            s.shed,
            s.host_items,
            s.csd_items
        );
    }
}

fn serve_json(r: &ServeReport) -> crate::codec::json::Json {
    use crate::codec::json::Json;
    let mut j = Json::obj();
    j.set("app", r.app.into())
        .set("shape", r.shape.into())
        .set("dispatch", r.dispatch.into())
        .set("process", r.process.into())
        .set("policy", r.policy.into())
        .set("servers", (r.servers as u64).into())
        .set("requests", r.requests.into())
        .set("served", r.served.into())
        .set("shed", r.shed.into())
        .set("shed_fraction", r.shed_fraction().into())
        .set("failed", r.failed.into())
        .set("retried", r.retried.into())
        .set("hedged", r.hedged.into())
        .set("duplicate_suppressed", r.duplicate_suppressed.into())
        .set("completed_in_slo", r.completed_in_slo.into())
        .set("availability", r.availability.into())
        .set("admission", r.admission.into())
        .set("slo_p99_s", r.slo_p99_s.into())
        .set("meets_slo", r.meets_slo().into())
        .set("offered_rps", r.offered_rps.into())
        .set("achieved_rps", r.achieved_rps.into())
        .set("duration_secs", r.duration_secs.into())
        .set("latency_mean_s", r.latency.mean.into())
        .set("latency_p50_s", r.latency.p50.into())
        .set("latency_p95_s", r.latency.p95.into())
        .set("latency_p99_s", r.latency.p99.into())
        .set("latency_p999_s", r.latency.p999.into())
        .set("latency_max_s", r.latency.max.into())
        .set("host_items", r.host_items.into())
        .set("csd_items", r.csd_items.into())
        .set("host_batches", r.host_batches.into())
        .set("csd_batches", r.csd_batches.into())
        .set("rack_bytes", r.rack_bytes.into())
        .set("rack_messages", r.rack_messages.into())
        .set("energy_j", r.energy_j.into())
        .set("energy_per_req_j", r.energy_per_req_j.into())
        .set("ingest_writes", r.ingest_writes.into())
        .set("waf", r.waf.into())
        .set("gc_runs", r.gc_runs.into())
        .set("wear_spread", (r.wear_spread as u64).into())
        .set("engine_events", r.engine_events.into())
        .set("host_done_events", r.host_done_events.into())
        .set("csd_ack_events", r.csd_ack_events.into())
        .set("wake_events", r.wake_events.into())
        .set("flush_events", r.flush_events.into())
        .set("ingest_events", r.ingest_events.into())
        .set("max_queue_depth", r.max_queue_depth.into())
        .set("mean_queue_depth", r.mean_queue_depth.into())
        .set("max_inflight", r.max_inflight.into())
        .set("server_seconds", r.server_seconds.into())
        .set("peak_servers", (r.peak_servers as u64).into())
        .set("migrations", r.migrations.into())
        .set("migrated_bytes", r.migrated_bytes.into())
        .set("joins", r.joins.into())
        .set("drains", r.drains.into());
    let timeline: Vec<Json> = r
        .timeline
        .iter()
        .map(|s| {
            let mut o = Json::obj();
            o.set("t_s", s.t.into())
                .set("active", (s.active as u64).into())
                .set("draining", (s.draining as u64).into())
                .set("p99_s", s.p99_s.into())
                .set("arrived", s.arrived.into())
                .set("served", s.served.into())
                .set("shed", s.shed.into())
                .set("energy_j", s.energy_j.into());
            o
        })
        .collect();
    j.set("timeline", timeline.into());
    let servers: Vec<Json> = r
        .per_server
        .iter()
        .map(|s| {
            let mut o = Json::obj();
            o.set("index", (s.index as u64).into())
                .set("is_csd", s.is_csd.into())
                .set("served", s.served.into())
                .set("shed", s.shed.into())
                .set("host_items", s.host_items.into())
                .set("csd_items", s.csd_items.into());
            o
        })
        .collect();
    j.set("per_server", servers.into());
    j
}

fn fleet_json(r: &FleetReport) -> crate::codec::json::Json {
    use crate::codec::json::Json;
    let mut j = Json::obj();
    j.set("app", r.app.into())
        .set("shape", r.shape.into())
        .set("servers", (r.servers as u64).into())
        .set("total_items", r.total_items.into())
        .set("makespan_secs", r.makespan_secs.into())
        .set("agg_secs", r.agg_secs.into())
        .set("items_per_sec", r.items_per_sec.into())
        .set("words_per_sec", r.words_per_sec.into())
        .set("host_items", r.host_items.into())
        .set("csd_items", r.csd_items.into())
        .set("pcie_bytes", r.pcie_bytes.into())
        .set("isp_bytes", r.isp_bytes.into())
        .set("rack_bytes", r.rack_bytes.into())
        .set("rack_messages", r.rack_messages.into())
        .set("tunnel_messages", r.tunnel_messages.into())
        .set("energy_j", r.energy_j.into())
        .set("energy_per_item_j", r.energy_per_item_j.into());
    let servers: Vec<Json> = r
        .per_server
        .iter()
        .map(|s| {
            let mut o = Json::obj();
            o.set("items", s.total_items.into())
                .set("items_per_sec", s.items_per_sec.into())
                .set("makespan_secs", s.makespan_secs.into())
                .set("host_items", s.host_items.into())
                .set("csd_items", s.csd_items.into())
                .set("energy_j", s.energy_j.into());
            o
        })
        .collect();
    j.set("per_server", servers.into());
    j
}

fn report_json(r: &sched::RunReport) -> crate::codec::json::Json {
    use crate::codec::json::Json;
    let mut j = Json::obj();
    j.set("app", r.app.into())
        .set("dispatch", r.dispatch.into())
        .set("total_items", r.total_items.into())
        .set("makespan_secs", r.makespan_secs.into())
        .set("items_per_sec", r.items_per_sec.into())
        .set("words_per_sec", r.words_per_sec.into())
        .set("host_items", r.host_items.into())
        .set("csd_items", r.csd_items.into())
        .set("pcie_bytes", r.pcie_bytes.into())
        .set("isp_bytes", r.isp_bytes.into())
        .set("tunnel_messages", r.tunnel_messages.into())
        .set("energy_j", r.energy_j.into())
        .set("avg_power_w", r.avg_power_w.into())
        .set("energy_per_item_j", r.energy_per_item_j.into())
        .set("mean_batch_latency_s", r.mean_batch_latency.into())
        .set("waf", r.waf.into())
        .set("gc_runs", r.gc_runs.into())
        .set("wear_spread", (r.wear_spread as u64).into())
        .set("events_executed", r.events_executed.into())
        .set("wake_events", r.wake_events.into())
        .set("host_ack_events", r.host_ack_events.into())
        .set("csd_ack_events", r.csd_ack_events.into());
    j
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn version_and_help() {
        assert_eq!(dispatch(&sv(&["version"])).unwrap(), 0);
        assert_eq!(dispatch(&sv(&["help"])).unwrap(), 0);
        assert_eq!(dispatch(&sv(&["nonsense"])).unwrap(), 2);
    }

    #[test]
    fn run_small_benchmark() {
        let code = dispatch(&sv(&[
            "run", "--app", "sentiment", "--scale", "0.01", "--batch", "5000", "--json",
        ]))
        .unwrap();
        assert_eq!(code, 0);
    }

    #[test]
    fn run_event_driven_benchmark() {
        let code = dispatch(&sv(&[
            "run", "--app", "sentiment", "--scale", "0.01", "--batch", "5000",
            "--dispatch", "event", "--json",
        ]))
        .unwrap();
        assert_eq!(code, 0);
    }

    #[test]
    fn config_file_app_applies_when_flag_absent() {
        // Regression: --app used to carry a hard CLI default, which
        // always shadowed the config file's `app` key.
        let path = std::env::temp_dir()
            .join(format!("solana_cli_app_precedence_{}.toml", std::process::id()));
        std::fs::write(&path, "app = \"speech\"\n").unwrap();
        let cmd = commands().into_iter().find(|c| c.name == "run").unwrap();
        let args = cmd.parse(&sv(&["--config", path.to_str().unwrap()])).unwrap();
        let (app, _, _) = resolve_sched_args(&args, exp::default_batch, Scale(0.5)).unwrap();
        assert_eq!(app, App::SpeechToText, "config app applies without a flag");
        let args = cmd
            .parse(&sv(&["--config", path.to_str().unwrap(), "--app", "sentiment"]))
            .unwrap();
        let (app, _, scale) = resolve_sched_args(&args, exp::default_batch, Scale(0.5)).unwrap();
        assert_eq!(app, App::Sentiment, "an explicit flag still beats the file");
        assert_eq!(scale.0, 0.25, "no --scale flag: the config default applies, not cli_scale");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn fleet_run_all_shapes() {
        // the acceptance invocation (tiny scale) plus the other shapes
        for shape in ["all-csd", "all-ssd", "mixed"] {
            let code = dispatch(&sv(&[
                "fleet", "--servers", "4", "--shape", shape, "--app", "sentiment",
                "--scale", "0.01", "--json",
            ]))
            .unwrap();
            assert_eq!(code, 0, "shape {shape}");
        }
    }

    #[test]
    fn fleet_rejects_nonsense() {
        assert!(dispatch(&sv(&["fleet", "--servers", "0", "--scale", "0.01"])).is_err());
        assert!(dispatch(&sv(&["fleet", "--shape", "pyramid", "--scale", "0.01"])).is_err());
    }

    #[test]
    fn fig8_smoke() {
        assert_eq!(dispatch(&sv(&["fig8", "--scale", "0.005"])).unwrap(), 0);
    }

    #[test]
    fn fig9_smoke() {
        assert_eq!(dispatch(&sv(&["fig9", "--scale", "0.005"])).unwrap(), 0);
    }

    #[test]
    fn serve_smoke_all_processes() {
        // the CI smoke invocation (`solana serve --scale 0.01`) plus the
        // other arrival processes and both report formats
        assert_eq!(dispatch(&sv(&["serve", "--scale", "0.01"])).unwrap(), 0);
        for process in ["poisson", "bursty", "closed"] {
            let code = dispatch(&sv(&[
                "serve", "--app", "sentiment", "--scale", "0.01", "--requests", "1000",
                "--process", process, "--json",
            ]))
            .unwrap();
            assert_eq!(code, 0, "process {process}");
        }
    }

    #[test]
    fn serve_fleet_with_policies_and_weights() {
        for policy in ["rr", "weighted", "jsq"] {
            let code = dispatch(&sv(&[
                "serve", "--servers", "2", "--shape", "mixed", "--policy", policy,
                "--scale", "0.01", "--requests", "1000", "--json",
            ]))
            .unwrap();
            assert_eq!(code, 0, "policy {policy}");
        }
        let code = dispatch(&sv(&[
            "serve", "--servers", "2", "--weights", "36,12", "--scale", "0.01",
            "--requests", "500",
        ]))
        .unwrap();
        assert_eq!(code, 0);
    }

    #[test]
    fn serve_rejects_nonsense() {
        assert!(dispatch(&sv(&["serve", "--process", "psychic", "--scale", "0.01"])).is_err());
        assert!(dispatch(&sv(&["serve", "--policy", "chaos", "--scale", "0.01"])).is_err());
        assert!(dispatch(&sv(&["serve", "--load", "0", "--scale", "0.01"])).is_err());
        assert!(dispatch(&sv(&["serve", "--min-batch", "0", "--scale", "0.01"])).is_err());
        assert!(dispatch(&sv(&[
            "serve", "--servers", "2", "--weights", "36", "--scale", "0.01"
        ]))
        .is_err());
        // ISSUE-5 satellite: control-plane parameters are validated too.
        assert!(dispatch(&sv(&["serve", "--admission", "maybe", "--scale", "0.01"])).is_err());
        assert!(dispatch(&sv(&["serve", "--skew", "-1", "--scale", "0.01"])).is_err());
        assert!(dispatch(&sv(&["serve", "--slo", "0", "--scale", "0.01"])).is_err());
        // min_batch beyond one server's single-dispatch drain capacity
        assert!(dispatch(&sv(&[
            "serve", "--min-batch", "99999999", "--scale", "0.01", "--requests", "500"
        ]))
        .is_err());
    }

    #[test]
    fn serve_control_plane_smoke() {
        // Admission + least-work + skew through the real CLI (the CI
        // smoke invocation), overloaded enough that shedding is live.
        let code = dispatch(&sv(&[
            "serve", "--app", "speech", "--servers", "2", "--shape", "mixed",
            "--policy", "least-work", "--admission", "on", "--skew", "1.0",
            "--load", "1.3", "--requests", "1500", "--scale", "0.01", "--json",
        ]))
        .unwrap();
        assert_eq!(code, 0);
        // and an explicit SLO override with admission off
        let code = dispatch(&sv(&[
            "serve", "--app", "speech", "--slo", "10", "--load", "0.4",
            "--requests", "500", "--scale", "0.01",
        ]))
        .unwrap();
        assert_eq!(code, 0);
    }

    #[test]
    fn fig10_smoke() {
        assert_eq!(dispatch(&sv(&["fig10", "--scale", "0.005"])).unwrap(), 0);
    }

    #[test]
    fn fig11_smoke() {
        assert_eq!(dispatch(&sv(&["fig11", "--scale", "0.005"])).unwrap(), 0);
    }

    #[test]
    fn fig12_smoke() {
        // the CI smoke invocation: `solana fig12 --scale 0.01` (the test
        // runs one notch smaller to stay quick)
        assert_eq!(dispatch(&sv(&["fig12", "--scale", "0.005"])).unwrap(), 0);
    }

    #[test]
    fn fig13_smoke() {
        // the CI smoke invocation: `solana fig13 --scale 0.01` (the test
        // runs one notch smaller to stay quick)
        assert_eq!(dispatch(&sv(&["fig13", "--scale", "0.005"])).unwrap(), 0);
    }

    #[test]
    fn serve_ingest_smoke() {
        // The ISSUE-8 serve path: a background ingest/update stream
        // through the real CLI, both report formats.
        let code = dispatch(&sv(&[
            "serve", "--app", "sentiment", "--servers", "2", "--ingest-rate", "2000",
            "--load", "0.5", "--requests", "800", "--scale", "0.01", "--json",
        ]))
        .unwrap();
        assert_eq!(code, 0);
        let code = dispatch(&sv(&[
            "serve", "--ingest-rate", "500", "--requests", "500", "--scale", "0.01",
        ]))
        .unwrap();
        assert_eq!(code, 0);
        // rejected: negative or non-finite rates
        assert!(dispatch(&sv(&["serve", "--ingest-rate", "-5", "--scale", "0.01"])).is_err());
        assert!(dispatch(&sv(&["serve", "--ingest-rate", "nan", "--scale", "0.01"])).is_err());
    }

    #[test]
    fn serve_chaos_smoke() {
        // The CI chaos smoke invocation: crash one server out of four
        // and ride it out with the full resilience stack.
        let code = dispatch(&sv(&[
            "serve", "--app", "speech", "--servers", "4", "--policy", "rr",
            "--faults", "server-crash@0.3,crash-server=0", "--fault-seed", "11",
            "--retries", "3", "--hedge", "--replicas", "1",
            "--load", "0.6", "--requests", "1200", "--scale", "0.01", "--json",
        ]))
        .unwrap();
        assert_eq!(code, 0);
        // Drive-level chaos with a modest retry budget, human report.
        let code = dispatch(&sv(&[
            "serve", "--faults", "ack-loss@0.05,stall@0.1,stall-s=0.05",
            "--retries", "2", "--requests", "800", "--scale", "0.01",
        ]))
        .unwrap();
        assert_eq!(code, 0);
    }

    #[test]
    fn serve_rejects_bad_fault_and_resilience_specs() {
        // unknown clause name: rejected at parse time
        assert!(dispatch(&sv(&["serve", "--faults", "gremlins@0.5", "--scale", "0.01"])).is_err());
        // rate outside [0,1]: rejected by serve_fleet's validation
        assert!(dispatch(&sv(&["serve", "--faults", "ack-loss@1.5", "--scale", "0.01"])).is_err());
        // crash target outside the fleet
        assert!(dispatch(&sv(&[
            "serve", "--servers", "2", "--faults", "server-crash@0.5,crash-server=7",
            "--scale", "0.01"
        ]))
        .is_err());
        // resilience knobs are validated too
        assert!(dispatch(&sv(&["serve", "--retry-timeout", "0", "--scale", "0.01"])).is_err());
        // replicas must be < servers (1 replica on a 1-server fleet)
        assert!(dispatch(&sv(&["serve", "--replicas", "1", "--scale", "0.01"])).is_err());
        // --fault-seed without a fault plan is meaningless
        assert!(dispatch(&sv(&["serve", "--fault-seed", "3", "--scale", "0.01"])).is_err());
    }

    #[test]
    fn serve_elastic_smoke() {
        // The CI elastic smoke invocation: an autoscaled serve through
        // the real CLI, both policies and both report formats.
        let code = dispatch(&sv(&[
            "serve", "--app", "speech", "--servers", "1", "--autoscale", "predictive",
            "--autoscale-max", "4", "--load", "0.9", "--requests", "2000",
            "--scale", "0.01", "--json",
        ]))
        .unwrap();
        assert_eq!(code, 0);
        let code = dispatch(&sv(&[
            "serve", "--app", "speech", "--autoscale", "reactive",
            "--autoscale-max", "2", "--autoscale-rebalance", "off",
            "--load", "0.5", "--requests", "800", "--scale", "0.01",
        ]))
        .unwrap();
        assert_eq!(code, 0);
    }

    #[test]
    fn serve_rejects_bad_autoscale_specs() {
        // unknown policy name: rejected at parse time
        assert!(dispatch(&sv(&["serve", "--autoscale", "psychic", "--scale", "0.01"])).is_err());
        // a sub-flag without the autoscaler armed is an error, not a no-op
        assert!(dispatch(&sv(&["serve", "--autoscale-max", "4", "--scale", "0.01"])).is_err());
        // knob ranges, one rejection each (validated by serve_fleet)
        let bad = [
            vec!["--autoscale-min", "0"],
            vec!["--autoscale-min", "5", "--autoscale-max", "2"],
            vec!["--autoscale-interval", "0"],
            vec!["--autoscale-hysteresis", "1.5"],
            vec!["--autoscale-hysteresis", "nan"],
            vec!["--autoscale-window", "0"],
            vec!["--autoscale-util", "0"],
            vec!["--autoscale-util", "1.5"],
            vec!["--autoscale-rebalance", "maybe"],
            vec!["--autoscale-rebalance-threshold", "0"],
            vec!["--autoscale-shards", "2"],
        ];
        for extra in bad {
            let mut argv =
                sv(&["serve", "--autoscale", "predictive", "--scale", "0.01"]);
            argv.extend(sv(&extra));
            assert!(dispatch(&argv).is_err(), "accepted {extra:?}");
        }
        // failover replicas must fit the smallest fleet the autoscaler
        // may shrink to
        assert!(dispatch(&sv(&[
            "serve", "--servers", "2", "--replicas", "1", "--autoscale", "predictive",
            "--scale", "0.01"
        ]))
        .is_err());
        // explicit per-server weights assume fixed membership
        assert!(dispatch(&sv(&[
            "serve", "--servers", "2", "--weights", "36,12", "--autoscale", "predictive",
            "--scale", "0.01"
        ]))
        .is_err());
    }

    #[test]
    fn serve_trace_then_report_round_trip() {
        // The ISSUE-9 CI smoke path: traced serve → JSONL export →
        // trace-report reads it back and prints the attribution table.
        let dir = std::env::temp_dir();
        let jsonl = dir.join(format!("solana_cli_trace_{}.jsonl", std::process::id()));
        let code = dispatch(&sv(&[
            "serve", "--app", "sentiment", "--scale", "0.01", "--requests", "600",
            "--trace", jsonl.to_str().unwrap(),
        ]))
        .unwrap();
        assert_eq!(code, 0);
        assert!(jsonl.exists(), "serve --trace must write the export");
        assert_eq!(
            dispatch(&sv(&["trace-report", "--input", jsonl.to_str().unwrap()])).unwrap(),
            0
        );
        assert_eq!(
            dispatch(&sv(&["trace-report", "--input", jsonl.to_str().unwrap(), "--csv"])).unwrap(),
            0
        );
        let _ = std::fs::remove_file(&jsonl);
        // Chrome export validates through the same round trip.
        let chrome = dir.join(format!("solana_cli_trace_{}.json", std::process::id()));
        let code = dispatch(&sv(&[
            "serve", "--app", "sentiment", "--scale", "0.01", "--requests", "600",
            "--trace", chrome.to_str().unwrap(), "--trace-format", "chrome",
            "--trace-sample", "4", "--json",
        ]))
        .unwrap();
        assert_eq!(code, 0);
        assert_eq!(
            dispatch(&sv(&[
                "trace-report", "--input", chrome.to_str().unwrap(), "--format", "chrome",
            ]))
            .unwrap(),
            0
        );
        let _ = std::fs::remove_file(&chrome);
    }

    #[test]
    fn trace_flags_rejected_when_nonsense() {
        assert!(dispatch(&sv(&[
            "serve", "--scale", "0.01", "--trace", "/tmp/x", "--trace-format", "svg",
        ]))
        .is_err());
        assert!(dispatch(&sv(&[
            "serve", "--scale", "0.01", "--trace", "/tmp/x", "--trace-sample", "0",
        ]))
        .is_err());
        assert!(dispatch(&sv(&["trace-report"])).is_err(), "--input is required");
        assert!(
            dispatch(&sv(&["trace-report", "--input", "/nonexistent/trace.jsonl"])).is_err()
        );
    }

    #[test]
    fn fleet_weights_override() {
        let code = dispatch(&sv(&[
            "fleet", "--servers", "2", "--weights", "36,12", "--app", "sentiment",
            "--scale", "0.01", "--json",
        ]))
        .unwrap();
        assert_eq!(code, 0);
        assert!(dispatch(&sv(&[
            "fleet", "--servers", "2", "--weights", "1,2,3", "--scale", "0.01"
        ]))
        .is_err());
    }

    #[test]
    fn ablate_dispatch_smoke() {
        // the CI smoke invocation: `solana ablate --which dispatch --scale 0.005`
        assert_eq!(
            dispatch(&sv(&["ablate", "--which", "dispatch", "--scale", "0.005"])).unwrap(),
            0
        );
    }

    #[test]
    fn bad_dispatch_mode_rejected() {
        assert!(dispatch(&sv(&[
            "run", "--scale", "0.01", "--dispatch", "sometimes"
        ]))
        .is_err());
    }

    #[test]
    fn power_command() {
        assert_eq!(dispatch(&sv(&["power"])).unwrap(), 0);
    }

    #[test]
    fn bad_scale_rejected() {
        assert!(dispatch(&sv(&["run", "--scale", "3.0"])).is_err());
    }
}
