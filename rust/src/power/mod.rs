//! Power and energy model, calibrated to the paper's HPM-100A wall-plug
//! measurements (§IV-C):
//!
//! * server idle, no drives: **167 W**
//! * +36 CSDs idle: **405 W** ⇒ 6.6 W per drive
//! * benchmark running, ISP disabled (storage-only baseline): **482 W**
//!   ⇒ host compute adds ~77 W at full load
//! * benchmark running, all 36 ISP engines on: **492 W** ⇒ **0.28 W per
//!   ISP engine** — the number that makes in-storage processing a net
//!   energy win despite the A53's lower speed.
//!
//! Energy integrates component power over *busy time* from the
//! simulation: `E = P_idle·T + P_drive·n·T + P_host·host_busy +
//! P_isp·isp_busy`.

/// Component power constants (Watts).
#[derive(Clone, Copy, Debug)]
pub struct PowerModel {
    /// Chassis + idle host CPU + fans (no drives).
    pub server_idle_w: f64,
    /// One populated E1.S Solana drive (storage function).
    pub csd_idle_w: f64,
    /// Incremental host-CPU power at full benchmark load.
    pub host_active_w: f64,
    /// Incremental power of one busy ISP engine.
    pub isp_active_w: f64,
}

impl Default for PowerModel {
    fn default() -> Self {
        PowerModel {
            server_idle_w: 167.0,
            csd_idle_w: 6.6,
            host_active_w: 77.0,
            isp_active_w: 0.28,
        }
    }
}

/// Energy accounting for one benchmark run.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct EnergyReport {
    pub makespan_secs: f64,
    pub energy_j: f64,
    pub avg_power_w: f64,
    /// Peak (all-components-busy) power during the run.
    pub peak_power_w: f64,
}

impl PowerModel {
    /// Instantaneous wall power with `drives` populated, the host at
    /// `host_load` (0..1) and `busy_isps` ISP engines active.
    pub fn instantaneous_w(&self, drives: usize, host_load: f64, busy_isps: usize) -> f64 {
        debug_assert!((0.0..=1.0 + 1e-9).contains(&host_load));
        self.server_idle_w
            + self.csd_idle_w * drives as f64
            + self.host_active_w * host_load
            + self.isp_active_w * busy_isps as f64
    }

    /// Integrate energy for a run: `host_busy_secs` is host *node* busy
    /// time (0..makespan), `isp_busy_secs` is summed across engines
    /// (0..drives×makespan).
    pub fn energy(
        &self,
        makespan_secs: f64,
        drives: usize,
        host_busy_secs: f64,
        isp_busy_secs: f64,
    ) -> EnergyReport {
        debug_assert!(host_busy_secs <= makespan_secs + 1e-6);
        let energy_j = self.server_idle_w * makespan_secs
            + self.csd_idle_w * drives as f64 * makespan_secs
            + self.host_active_w * host_busy_secs
            + self.isp_active_w * isp_busy_secs;
        let avg = if makespan_secs > 0.0 { energy_j / makespan_secs } else { 0.0 };
        EnergyReport {
            makespan_secs,
            energy_j,
            avg_power_w: avg,
            peak_power_w: self.instantaneous_w(drives, 1.0, drives),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const P: PowerModel = PowerModel {
        server_idle_w: 167.0,
        csd_idle_w: 6.6,
        host_active_w: 77.0,
        isp_active_w: 0.28,
    };

    #[test]
    fn reproduces_paper_idle_numbers() {
        // "the server consumes 167 W without storage drives, or 405 W
        // with 36 CSDs"
        assert_eq!(P.instantaneous_w(0, 0.0, 0), 167.0);
        let populated = P.instantaneous_w(36, 0.0, 0);
        assert!((populated - 405.0).abs() < 1.0, "{populated}");
    }

    #[test]
    fn reproduces_paper_running_numbers() {
        // "up to 482 W without enabling ISP ... 492 W with all 36 ISP
        // engines running"
        let storage_only = P.instantaneous_w(36, 1.0, 0);
        assert!((storage_only - 482.0).abs() < 1.0, "{storage_only}");
        let with_isp = P.instantaneous_w(36, 1.0, 36);
        assert!((with_isp - 492.0).abs() < 2.0, "{with_isp}");
    }

    #[test]
    fn table1_energy_per_query_host_vs_csd() {
        // Host-only speech: 96 w/s at ~482 W ⇒ ~5.0 J/word.
        // With ISP: 296 w/s at ~492 W ⇒ ~1.66 J/word (67% saving).
        let host_run = P.energy(1.0, 36, 1.0, 0.0);
        let per_word_host = host_run.energy_j / 96.0;
        assert!((per_word_host - 5.021).abs() < 0.05, "{per_word_host}");
        let isp_run = P.energy(1.0, 36, 1.0, 36.0);
        let per_word_isp = isp_run.energy_j / 296.0;
        assert!((per_word_isp - 1.662).abs() < 0.05, "{per_word_isp}");
        let saving = 1.0 - per_word_isp / per_word_host;
        assert!((saving - 0.67).abs() < 0.02, "saving {saving}");
    }

    #[test]
    fn energy_scales_with_makespan_and_busy_time() {
        let a = P.energy(10.0, 4, 5.0, 8.0);
        let b = P.energy(20.0, 4, 5.0, 8.0);
        assert!(b.energy_j > a.energy_j);
        assert!(b.avg_power_w < a.avg_power_w, "longer idle tail lowers avg");
        let c = P.energy(10.0, 4, 10.0, 40.0);
        assert_eq!(c.avg_power_w, P.instantaneous_w(4, 1.0, 4));
    }
}
