//! Speech-to-text benchmark (§IV-B1): transcribe LJ-like clips through
//! the AOT acoustic model + greedy CTC decode, and score WER against the
//! reference transcripts.
//!
//! Pipeline per clip: synth MFCC-like frames (the flash-resident "audio")
//! → chunk to the AOT frame window → `acoustic_forward` on PJRT → concat
//! log-probs → greedy CTC collapse → WER.

use crate::nlp::corpus::{Clip, SpeechCorpus};
use crate::nlp::features::{
    greedy_ctc_decode, oracle_acoustic_weights, speech_frames, BLANK, FRAME_DIM, VOCAB,
};
use crate::nlp::wer;
use crate::runtime::{Engine, Tensor};
use crate::util::Rng;

/// The speech app: corpus + pretrained acoustic weights (device-side
/// tensors prepared once).
pub struct SpeechApp {
    pub corpus: SpeechCorpus,
    weights: Vec<Tensor>,
    frames_per_chunk: usize,
    /// Feature-synthesis noise (σ of the Gaussian added to the one-hot).
    pub noise: f64,
}

/// Result of transcribing one clip.
#[derive(Clone, Debug)]
pub struct Transcription {
    pub clip_id: u32,
    pub text: String,
    pub wer: f64,
    pub frames: usize,
    pub chunks: usize,
}

impl SpeechApp {
    pub fn new(eng: &Engine, corpus: SpeechCorpus) -> anyhow::Result<SpeechApp> {
        let t = eng.manifest.dim("speech_frames")? as usize;
        let f = eng.manifest.dim("speech_features")? as usize;
        let h = eng.manifest.dim("speech_hidden")? as usize;
        let v = eng.manifest.dim("speech_vocab")? as usize;
        anyhow::ensure!(f == FRAME_DIM && v == VOCAB, "manifest dims drifted");
        let (w1, b1, w2, b2, w3, b3) = oracle_acoustic_weights(h);
        let weights = vec![
            Tensor::new(vec![f, h], w1),
            Tensor::new(vec![h], b1),
            Tensor::new(vec![h, h], w2),
            Tensor::new(vec![h], b2),
            Tensor::new(vec![h, v], w3),
            Tensor::new(vec![v], b3),
        ];
        Ok(SpeechApp { corpus, weights, frames_per_chunk: t, noise: 0.08 })
    }

    /// Transcribe one clip through the PJRT acoustic model.
    pub fn transcribe(
        &self,
        eng: &mut Engine,
        clip: &Clip,
        rng: &mut Rng,
    ) -> anyhow::Result<Transcription> {
        let t = self.frames_per_chunk;
        let mut frames = speech_frames(&clip.transcript, rng, self.noise);
        let n_frames = frames.len() / FRAME_DIM;
        // Pad to a whole number of chunks with blank frames.
        let chunks = n_frames.div_ceil(t).max(1);
        frames.resize(chunks * t * FRAME_DIM, 0.0);
        for pad in n_frames..chunks * t {
            frames[pad * FRAME_DIM + BLANK] = 1.0;
        }
        let variant = format!("t{t}");
        let mut logprobs: Vec<f32> = Vec::with_capacity(chunks * t * VOCAB);
        for c in 0..chunks {
            let chunk =
                Tensor::new(vec![t, FRAME_DIM], frames[c * t * FRAME_DIM..(c + 1) * t * FRAME_DIM].to_vec());
            let mut inputs = Vec::with_capacity(7);
            inputs.push(chunk);
            inputs.extend(self.weights.iter().cloned());
            let out = eng.run("acoustic_forward", &variant, &inputs)?;
            logprobs.extend_from_slice(&out[0].data);
        }
        let text = greedy_ctc_decode(&logprobs, chunks * t);
        let wer = wer(&clip.transcript, &text);
        Ok(Transcription { clip_id: clip.id, text, wer, frames: n_frames, chunks })
    }

    /// Transcribe a set of clips; returns (mean WER, transcriptions).
    pub fn transcribe_set(
        &self,
        eng: &mut Engine,
        clip_ids: &[u32],
        seed: u64,
    ) -> anyhow::Result<(f64, Vec<Transcription>)> {
        let mut rng = Rng::new(seed);
        let mut out = Vec::with_capacity(clip_ids.len());
        let mut total = 0.0;
        for &id in clip_ids {
            let tr = self.transcribe(eng, &self.corpus.clips[id as usize], &mut rng)?;
            total += tr.wer;
            out.push(tr);
        }
        Ok((total / clip_ids.len().max(1) as f64, out))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transcribes_with_low_wer() {
        let Some(mut eng) = Engine::load_default() else { return };
        let corpus = SpeechCorpus::generate(31, 8);
        let app = SpeechApp::new(&eng, corpus).unwrap();
        let ids: Vec<u32> = (0..8).collect();
        let (mean_wer, trs) = app.transcribe_set(&mut eng, &ids, 77).unwrap();
        assert!(mean_wer < 0.10, "mean WER {mean_wer}");
        for tr in &trs {
            assert!(!tr.text.is_empty());
            assert!(tr.chunks >= 1);
            assert_eq!(tr.frames.div_ceil(100).max(1), tr.chunks);
        }
    }

    #[test]
    fn pjrt_and_rust_decodes_agree() {
        // "output accuracy: same" — the ISP path (PJRT) and a pure-Rust
        // forward must produce identical transcripts.
        let Some(mut eng) = Engine::load_default() else { return };
        let corpus = SpeechCorpus::generate(32, 3);
        let app = SpeechApp::new(&eng, corpus).unwrap();
        for clip in &app.corpus.clips {
            let mut rng_a = Rng::new(5);
            let tr = app.transcribe(&mut eng, clip, &mut rng_a).unwrap();
            // rust oracle on the same frames
            let mut rng_b = Rng::new(5);
            let frames = speech_frames(&clip.transcript, &mut rng_b, app.noise);
            let t = frames.len() / FRAME_DIM;
            let weights = oracle_acoustic_weights(256);
            let logits =
                crate::nlp::features::acoustic_forward_rust(&frames, t, 256, &weights);
            let rust_text = greedy_ctc_decode(&logits, t);
            assert_eq!(tr.text, rust_text, "clip {}", clip.id);
        }
    }
}
