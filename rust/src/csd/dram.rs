//! Shared 6-GB DRAM between the flash controller and the ISP engine.
//!
//! §III-A: "both sharing a 6-GB DRAM through a high-speed intra-chip data
//! bus". Two ports (host-DMA side and ISP side) arbitrate for the same
//! underlying bandwidth; we model each port as a pipe at half the device
//! bandwidth, which matches the round-robin arbiter of the prototype
//! under sustained dual-master load, plus a byte-accurate allocator used
//! by the TCP/IP tunnel's shared buffers (§III-C3).

use crate::sim::Pipe;

/// Allocation handle returned by [`SharedDram::alloc`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DramRegion {
    pub offset: u64,
    pub bytes: u64,
}

/// The shared DRAM: capacity accounting + two arbitrated ports.
pub struct SharedDram {
    pub capacity: u64,
    allocated: u64,
    regions: Vec<DramRegion>,
    /// Port used by the FCU/host-DMA master.
    pub host_port: Pipe,
    /// Port used by the ISP master (CBDD buffers, tunnel buffers).
    pub isp_port: Pipe,
}

impl SharedDram {
    pub fn new(capacity: u64, total_bw: f64) -> SharedDram {
        SharedDram {
            capacity,
            allocated: 0,
            regions: Vec::new(),
            // Round-robin arbiter: each master sees half the sustained
            // bandwidth when both are active.
            host_port: Pipe::new(total_bw / 2.0, 0.5e-6),
            isp_port: Pipe::new(total_bw / 2.0, 0.5e-6),
        }
    }

    /// Allocate a buffer (bump allocator — buffers here live for the
    /// whole run: tunnel rings, CBDD scatter-gather regions).
    pub fn alloc(&mut self, bytes: u64) -> Option<DramRegion> {
        if self.allocated + bytes > self.capacity {
            return None;
        }
        let r = DramRegion { offset: self.allocated, bytes };
        self.allocated += bytes;
        self.regions.push(r);
        Some(r)
    }

    pub fn allocated(&self) -> u64 {
        self.allocated
    }

    pub fn free_bytes(&self) -> u64 {
        self.capacity - self.allocated
    }

    pub fn regions(&self) -> &[DramRegion] {
        &self.regions
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_until_full() {
        let mut d = SharedDram::new(1024, 1e9);
        let a = d.alloc(512).unwrap();
        let b = d.alloc(512).unwrap();
        assert_eq!(a.offset, 0);
        assert_eq!(b.offset, 512);
        assert!(d.alloc(1).is_none());
        assert_eq!(d.free_bytes(), 0);
    }

    #[test]
    fn ports_are_independent_queues() {
        let mut d = SharedDram::new(6 << 30, 12.8e9);
        let h = d.host_port.transfer(0.0, 1 << 20);
        let i = d.isp_port.transfer(0.0, 1 << 20);
        // both start immediately — separate arbiter slots
        assert_eq!(h.start, 0.0);
        assert_eq!(i.start, 0.0);
        // each sees half bandwidth
        let expect = 0.5e-6 + (1u64 << 20) as f64 / 6.4e9;
        assert!((h.end - expect).abs() < 1e-9);
        assert!((i.end - expect).abs() < 1e-9);
    }

    #[test]
    fn regions_tracked() {
        let mut d = SharedDram::new(4096, 1e9);
        d.alloc(100);
        d.alloc(200);
        assert_eq!(d.regions().len(), 2);
        assert_eq!(d.allocated(), 300);
    }
}
