// Negative fixture for D1 hash-iter: keyed lookup on a hash map is
// fine, and BTreeMap iteration is the sanctioned replacement.
use std::collections::{BTreeMap, HashMap};

pub fn lookup(m: &HashMap<u64, u32>, key: u64) -> Option<u32> {
    m.get(&key).copied()
}

pub fn ordered() -> Vec<u32> {
    let mut sorted: BTreeMap<u64, u32> = BTreeMap::new();
    sorted.insert(1, 2);
    sorted.values().copied().collect()
}
