//! # solana-isp
//!
//! Full-system reproduction of *"In-storage Processing of I/O Intensive
//! Applications on Computational Storage Drives"* (HeydariGorji et al.,
//! 2021) — the **Solana** computational storage drive (CSD) and the
//! MPI-style pull scheduler that distributes NLP workloads over a storage
//! server's host CPU and up to 36 CSDs.
//!
//! The physical testbed (a 12-TB E1.S CSD ASIC with an embedded quad-core
//! ARM Cortex-A53 ISP engine, mounted 36-up in an AIC FB128-LX server) is
//! reproduced as a deterministic discrete-event full-system simulator,
//! calibrated to the paper's measured single-node rates and power numbers.
//! The NLP compute itself is *real*: JAX/Pallas models are AOT-lowered to
//! HLO at build time and executed from Rust through the PJRT CPU client
//! (see [`runtime`]) — Python never runs on the request path.
//!
//! Layer map:
//! * **L3** — this crate: simulator, device models, shared FS, scheduler,
//!   power/energy accounting, workloads, experiment drivers.
//! * **L2** — `python/compile/model.py`: JAX graphs for the three NLP
//!   benchmarks (sentiment LR train+infer, recommender cosine top-k,
//!   speech acoustic model).
//! * **L1** — `python/compile/kernels/`: Pallas tiled similarity/GEMM
//!   kernels (interpret mode), verified against a pure-jnp oracle.
//!
//! See `DESIGN.md` for the module inventory and the experiment index.

pub mod bench_support;
pub mod cli;
pub mod cluster;
pub mod codec;
pub mod config;
pub mod csd;
pub mod exp;
pub mod faults;
pub mod fs;
pub mod interconnect;
pub mod metrics;
pub mod nlp;
pub mod power;
pub mod prop;
pub mod runtime;
pub mod sched;
pub mod sim;
pub mod trace;
pub mod traffic;
pub mod util;
pub mod workloads;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;

/// Version string reported by the CLI.
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
