//! Summary statistics over sample sets — used by the metrics layer, the
//! bench harness, and the experiment drivers to report mean / percentile
//! rows the way the paper's figures do.

/// Aggregate summary of a set of f64 samples.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub count: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
}

impl Summary {
    /// Compute a summary; returns `None` for an empty sample set.
    pub fn of(samples: &[f64]) -> Option<Summary> {
        if samples.is_empty() {
            return None;
        }
        let mut sorted: Vec<f64> = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).expect("NaN sample"));
        let count = sorted.len();
        let mean = sorted.iter().sum::<f64>() / count as f64;
        let var = sorted.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / count as f64;
        Some(Summary {
            count,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[count - 1],
            p50: percentile_sorted(&sorted, 50.0),
            p90: percentile_sorted(&sorted, 90.0),
            p99: percentile_sorted(&sorted, 99.0),
        })
    }
}

/// Percentile over a pre-sorted slice using linear interpolation
/// (the "exclusive" definition, matching numpy's default closely enough
/// for reporting).
pub fn percentile_sorted(sorted: &[f64], pct: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=100.0).contains(&pct));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = pct / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = rank - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

/// Online mean/variance accumulator (Welford) for streaming metrics.
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    pub fn new() -> Self {
        Welford { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_known_set() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]).unwrap();
        assert_eq!(s.count, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.p50 - 3.0).abs() < 1e-12);
    }

    #[test]
    fn summary_empty_is_none() {
        assert!(Summary::of(&[]).is_none());
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [10.0, 20.0, 30.0, 40.0];
        assert!((percentile_sorted(&xs, 0.0) - 10.0).abs() < 1e-12);
        assert!((percentile_sorted(&xs, 100.0) - 40.0).abs() < 1e-12);
        assert!((percentile_sorted(&xs, 50.0) - 25.0).abs() < 1e-12);
    }

    #[test]
    fn welford_matches_batch() {
        let xs: Vec<f64> = (0..1000).map(|i| (i as f64).sin() * 10.0).collect();
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        let s = Summary::of(&xs).unwrap();
        assert!((w.mean() - s.mean).abs() < 1e-9);
        assert!((w.std() - s.std).abs() < 1e-9);
        assert_eq!(w.min(), s.min);
        assert_eq!(w.max(), s.max);
        assert_eq!(w.count(), 1000);
    }
}
