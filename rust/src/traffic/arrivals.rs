//! Deterministic arrival-process generators for the serving layer.
//!
//! Three processes cover the traffic shapes the serving literature
//! sweeps:
//!
//! * **Open-loop Poisson** — memoryless inter-arrival gaps at a fixed
//!   offered rate. Open-loop means arrivals do *not* slow down when the
//!   system congests, so queueing delay compounds past the knee — the
//!   honest way to measure saturation (coordinated omission is
//!   impossible by construction).
//! * **Open-loop bursty** — an on/off MMPP-style modulated Poisson
//!   process: exponentially-distributed ON windows arriving at
//!   `burstiness ×` the mean rate, separated by silent OFF windows sized
//!   so the long-run average stays the offered rate. Same mean load as
//!   Poisson, much harsher tail.
//! * **Closed-loop** — N clients, each issuing one request, waiting for
//!   its response, thinking for an exponential pause, then issuing the
//!   next. Closed loops self-throttle at saturation (offered load tracks
//!   completions), so they probe *capacity* rather than tail blowup.
//!
//! All three are seeded through [`crate::util::Rng`]; the same seed
//! yields the same request timeline bit-for-bit, which the serving
//! determinism property test pins.
//!
//! Exponential sampling uses `-ln(u)/rate` on a fixed uniform stream, so
//! two Poisson generators with the same seed and different rates emit
//! *time-scaled copies* of the same sequence — load sweeps (Fig 9)
//! compare the same traffic at different compression, not different
//! traffic.
//!
//! An arrivals stream is never "quiet": every constructor asserts
//! `rate > 0.0` (and the bursty/closed-loop shape parameters positive)
//! before any draw, so the per-draw gating the D3 rule wants is
//! enforced once at construction instead of at all nine draw sites.
// solana-lint: allow-file(rng-gate, reason = "constructors assert rate > 0.0; an arrivals generator exists only to draw, so there is no quiet-plan state to protect")

use std::collections::BinaryHeap;

use crate::util::Rng;

/// Which arrival process generates the request timeline.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub enum ArrivalProcess {
    /// Open-loop Poisson at the offered rate.
    #[default]
    Poisson,
    /// Open-loop on/off bursty (MMPP-style): ON windows at
    /// `burstiness ×` the offered rate, OFF windows of silence, same
    /// long-run mean rate.
    Bursty,
    /// Closed-loop: `clients` concurrent clients with exponential think
    /// time between response and next request.
    ClosedLoop,
}

impl ArrivalProcess {
    /// Stable lowercase name used by the CLI, TOML configs and reports.
    pub fn name(&self) -> &'static str {
        match self {
            ArrivalProcess::Poisson => "poisson",
            ArrivalProcess::Bursty => "bursty",
            ArrivalProcess::ClosedLoop => "closed",
        }
    }

    pub fn all() -> [ArrivalProcess; 3] {
        [ArrivalProcess::Poisson, ArrivalProcess::Bursty, ArrivalProcess::ClosedLoop]
    }
}

/// One timestamped request. `id` is the global issue order (0-based) —
/// the serving layer uses it for round-robin data placement, so a
/// request's home drive is a pure function of its issue index.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Request {
    pub id: u64,
    /// Arrival time at the frontend (seconds, serving clock).
    pub arrival: f64,
}

/// Min-heap entry for pending arrivals (closed-loop re-arms arrive out
/// of issue order). Ordered by time, ties broken by insertion sequence
/// so the pop order is total and deterministic.
#[derive(Clone, Copy, Debug)]
struct Pending {
    time: f64,
    seq: u64,
}

impl PartialEq for Pending {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl Eq for Pending {}
impl PartialOrd for Pending {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Pending {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // BinaryHeap is a max-heap: reverse for earliest-first.
        other
            .time
            .total_cmp(&self.time)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// One piecewise-constant rate segment of a ramped stream: arrivals
/// accrue at `rate` req/s until absolute time `end`.
#[derive(Clone, Copy, Debug)]
struct Segment {
    end: f64,
    rate: f64,
}

/// A bounded stream of timestamped requests over one of the
/// [`ArrivalProcess`] shapes. Open-loop processes are self-driving;
/// the closed loop needs [`Arrivals::on_complete`] fed back to re-arm
/// clients.
#[derive(Clone, Debug)]
pub struct Arrivals {
    process: ArrivalProcess,
    rng: Rng,
    /// Total requests this stream will emit.
    limit: u64,
    issued: u64,
    /// Open-loop Poisson/bursty cursor: next arrival instant.
    next_open: f64,
    /// Bursty state: end of the current ON window and the window pair
    /// durations (`on_secs` at `peak_rate`, then `off_secs` silent).
    on_until: f64,
    peak_rate: f64,
    mean_on_secs: f64,
    mean_off_secs: f64,
    rate: f64,
    /// Closed-loop state.
    think_secs: f64,
    pending: BinaryHeap<Pending>,
    /// Ramped-Poisson state (ISSUE-10): piecewise-constant rate
    /// schedule. Empty for every other stream — the exact fixed-rate
    /// Poisson draw sequence is untouched.
    segments: Vec<Segment>,
    seg_idx: usize,
}

impl Arrivals {
    /// Open-loop Poisson at `rate` requests/s, `limit` requests total.
    pub fn poisson(rate: f64, limit: u64, seed: u64) -> Arrivals {
        assert!(rate > 0.0 && rate.is_finite(), "arrival rate must be positive");
        let mut rng = Rng::new(seed).fork("traffic.poisson");
        let first = rng.exponential(rate);
        Arrivals {
            process: ArrivalProcess::Poisson,
            rng,
            limit,
            issued: 0,
            next_open: first,
            on_until: f64::INFINITY,
            peak_rate: rate,
            mean_on_secs: 0.0,
            mean_off_secs: 0.0,
            rate,
            think_secs: 0.0,
            pending: BinaryHeap::new(),
            segments: Vec::new(),
            seg_idx: 0,
        }
    }

    /// Open-loop Poisson with a piecewise-constant offered rate
    /// (ISSUE-10 elastic fleet): `segments` is a list of
    /// `(duration_s, rate_rps)` pairs walked in order; the last
    /// segment's rate extends forever, so the stream can always emit
    /// all `limit` requests. Each arrival consumes exactly one
    /// unit-mean exponential draw, spread across segment boundaries by
    /// inversion — within any one segment the stream is exactly Poisson
    /// at that segment's rate, and the draw count per request is
    /// independent of how many boundaries the gap crosses.
    pub fn ramped(segments: &[(f64, f64)], limit: u64, seed: u64) -> Arrivals {
        assert!(!segments.is_empty(), "ramped stream needs at least one segment");
        for &(dur, rate) in segments {
            assert!(dur > 0.0 && dur.is_finite(), "segment duration must be positive");
            assert!(rate > 0.0 && rate.is_finite(), "segment rate must be positive");
        }
        let mut end = 0.0;
        let segs: Vec<Segment> = segments
            .iter()
            .map(|&(dur, rate)| {
                end += dur;
                Segment { end, rate }
            })
            .collect();
        let mut a = Arrivals {
            process: ArrivalProcess::Poisson,
            rng: Rng::new(seed).fork("traffic.ramped"),
            limit,
            issued: 0,
            next_open: 0.0,
            on_until: f64::INFINITY,
            peak_rate: 0.0,
            mean_on_secs: 0.0,
            mean_off_secs: 0.0,
            rate: segments[0].1,
            think_secs: 0.0,
            pending: BinaryHeap::new(),
            segments: segs,
            seg_idx: 0,
        };
        let first = a.rng.exponential(1.0);
        a.advance_ramped(first);
        a
    }

    /// Open-loop bursty process with long-run mean `rate`: ON windows
    /// (mean `mean_on_secs`) arrive at `burstiness × rate`, separated by
    /// OFF windows sized so the duty cycle is `1/burstiness`.
    pub fn bursty(rate: f64, burstiness: f64, mean_on_secs: f64, limit: u64, seed: u64) -> Arrivals {
        assert!(rate > 0.0 && rate.is_finite(), "arrival rate must be positive");
        assert!(burstiness >= 1.0, "burstiness must be >= 1 (peak/mean ratio)");
        assert!(mean_on_secs > 0.0, "mean ON window must be positive");
        let mut rng = Rng::new(seed).fork("traffic.bursty");
        let peak_rate = rate * burstiness;
        let mean_off_secs = mean_on_secs * (burstiness - 1.0);
        let on_until = rng.exponential(1.0 / mean_on_secs);
        let mut a = Arrivals {
            process: ArrivalProcess::Bursty,
            rng,
            limit,
            issued: 0,
            next_open: 0.0,
            on_until,
            peak_rate,
            mean_on_secs,
            mean_off_secs,
            rate,
            think_secs: 0.0,
            pending: BinaryHeap::new(),
            segments: Vec::new(),
            seg_idx: 0,
        };
        let first = a.rng.exponential(peak_rate);
        a.advance_bursty(first);
        a
    }

    /// Closed loop: `clients` clients, exponential think with mean
    /// `think_secs` between response and next request, `limit` requests
    /// total. Clients stagger their first requests over one mean think
    /// time so the opening instant is not a synchronized stampede.
    pub fn closed_loop(clients: usize, think_secs: f64, limit: u64, seed: u64) -> Arrivals {
        assert!(clients > 0, "closed loop needs at least one client");
        assert!(think_secs > 0.0 && think_secs.is_finite(), "think time must be positive");
        let mut rng = Rng::new(seed).fork("traffic.closed");
        let mut pending = BinaryHeap::new();
        for c in 0..clients.min(limit as usize) {
            let t = rng.range_f64(0.0, think_secs);
            pending.push(Pending { time: t, seq: c as u64 });
        }
        Arrivals {
            process: ArrivalProcess::ClosedLoop,
            rng,
            limit,
            issued: 0,
            next_open: 0.0,
            on_until: f64::INFINITY,
            peak_rate: 0.0,
            mean_on_secs: 0.0,
            mean_off_secs: 0.0,
            rate: 0.0,
            think_secs,
            pending,
            segments: Vec::new(),
            seg_idx: 0,
        }
    }

    pub fn process(&self) -> ArrivalProcess {
        self.process
    }

    /// Total requests this stream will emit.
    pub fn limit(&self) -> u64 {
        self.limit
    }

    /// Requests emitted so far.
    pub fn issued(&self) -> u64 {
        self.issued
    }

    /// Time of the next arrival, if any remain.
    pub fn peek(&self) -> Option<f64> {
        if self.issued >= self.limit {
            return None;
        }
        match self.process {
            ArrivalProcess::Poisson | ArrivalProcess::Bursty => Some(self.next_open),
            ArrivalProcess::ClosedLoop => self.pending.peek().map(|p| p.time),
        }
    }

    /// Emit the next request and advance the process.
    pub fn pop(&mut self) -> Option<Request> {
        let arrival = self.peek()?;
        let id = self.issued;
        self.issued += 1;
        match self.process {
            ArrivalProcess::Poisson => {
                if self.segments.is_empty() {
                    self.next_open += self.rng.exponential(self.rate);
                } else {
                    let gap = self.rng.exponential(1.0);
                    self.advance_ramped(gap);
                }
            }
            ArrivalProcess::Bursty => {
                let gap = self.rng.exponential(self.peak_rate);
                self.advance_bursty(gap);
            }
            ArrivalProcess::ClosedLoop => {
                self.pending.pop();
            }
        }
        Some(Request { id, arrival })
    }

    /// Spend `gap` seconds of ON-time from the current cursor, hopping
    /// over OFF windows: arrivals only accrue while the source is ON.
    /// Leaves `next_open` at the resulting arrival instant (inside an ON
    /// window) — the invariant `peek` relies on.
    fn advance_bursty(&mut self, gap: f64) {
        while self.next_open + gap > self.on_until {
            let spent_here = self.on_until - self.next_open;
            let off = self.rng.exponential(1.0 / self.mean_off_secs);
            let next_on_start = self.on_until + off;
            self.next_open = next_on_start - spent_here;
            self.on_until = next_on_start + self.rng.exponential(1.0 / self.mean_on_secs);
        }
        self.next_open += gap;
    }

    /// Spend `units` of unit-rate exponential mass from the cursor,
    /// walking the rate schedule: a segment at rate `r` converts mass
    /// to time as `dt = units / r`, and a segment spanning `s` seconds
    /// absorbs `r × s` units. Leaves `next_open` at the resulting
    /// arrival instant; the final segment extends forever.
    fn advance_ramped(&mut self, mut units: f64) {
        loop {
            let Segment { end, rate } = self.segments[self.seg_idx];
            let dt = units / rate;
            if self.seg_idx + 1 >= self.segments.len() || self.next_open + dt <= end {
                self.next_open += dt;
                return;
            }
            units -= (end - self.next_open) * rate;
            self.next_open = end;
            self.seg_idx += 1;
        }
    }

    /// Feed a completion back (closed loop re-arms that client after a
    /// think pause; a no-op for open-loop processes).
    pub fn on_complete(&mut self, done: f64) {
        if self.process != ArrivalProcess::ClosedLoop {
            return;
        }
        // Re-arm only while unissued requests remain beyond the ones
        // already waiting in the heap.
        if self.issued + self.pending.len() as u64 >= self.limit {
            return;
        }
        let think = self.rng.exponential(1.0 / self.think_secs);
        let seq = self.issued + self.pending.len() as u64;
        self.pending.push(Pending { time: done + think, seq });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn drain_open(mut a: Arrivals) -> Vec<f64> {
        let mut ts = Vec::new();
        while let Some(r) = a.pop() {
            ts.push(r.arrival);
        }
        ts
    }

    #[test]
    fn poisson_mean_rate_and_order() {
        let ts = drain_open(Arrivals::poisson(100.0, 10_000, 42));
        assert_eq!(ts.len(), 10_000);
        assert!(ts.windows(2).all(|w| w[0] <= w[1]), "arrivals sorted");
        let measured = ts.len() as f64 / ts.last().unwrap();
        assert!((measured / 100.0 - 1.0).abs() < 0.05, "rate {measured}");
    }

    #[test]
    fn poisson_same_seed_is_bit_identical() {
        let a = drain_open(Arrivals::poisson(50.0, 1_000, 7));
        let b = drain_open(Arrivals::poisson(50.0, 1_000, 7));
        assert!(a.iter().zip(&b).all(|(x, y)| x.to_bits() == y.to_bits()));
        let c = drain_open(Arrivals::poisson(50.0, 1_000, 8));
        assert_ne!(a, c, "different seed, different timeline");
    }

    #[test]
    fn poisson_rate_scales_the_same_timeline() {
        // Same uniform stream → doubling the rate exactly halves every
        // arrival instant. Load sweeps compare compressed copies of the
        // same traffic.
        let slow = drain_open(Arrivals::poisson(50.0, 500, 3));
        let fast = drain_open(Arrivals::poisson(100.0, 500, 3));
        for (s, f) in slow.iter().zip(&fast) {
            assert!((s / f - 2.0).abs() < 1e-9, "{s} vs {f}");
        }
    }

    #[test]
    fn bursty_same_mean_rate_heavier_tail() {
        let rate = 200.0;
        let n = 20_000;
        let poisson = drain_open(Arrivals::poisson(rate, n, 11));
        let bursty = drain_open(Arrivals::bursty(rate, 4.0, 0.5, n, 11));
        let p_span = poisson.last().unwrap();
        let b_span = bursty.last().unwrap();
        // Long-run mean near the offered rate for both. The bursty
        // bound is looser: the span is dominated by ~50 exponential
        // OFF-windows, so its relative spread is ~10% even at n = 20k.
        assert!((n as f64 / p_span / rate - 1.0).abs() < 0.15);
        assert!((n as f64 / b_span / rate - 1.0).abs() < 0.30, "bursty mean rate off: {}", n as f64 / b_span / rate);
        // Burstiness: the max ON-window instantaneous rate (arrivals in
        // any 100 ms window) is much higher for the bursty process.
        let peak = |ts: &[f64]| {
            let mut best = 0usize;
            let mut lo = 0usize;
            for hi in 0..ts.len() {
                while ts[hi] - ts[lo] > 0.1 {
                    lo += 1;
                }
                best = best.max(hi - lo + 1);
            }
            best
        };
        assert!(
            peak(&bursty) as f64 > 1.8 * peak(&poisson) as f64,
            "bursty peak {} !>> poisson peak {}",
            peak(&bursty),
            peak(&poisson)
        );
    }

    #[test]
    fn closed_loop_throttles_on_completions() {
        let mut a = Arrivals::closed_loop(4, 1.0, 100, 5);
        // Only the 4 initial requests exist until completions arrive.
        let mut got = Vec::new();
        for _ in 0..4 {
            got.push(a.pop().unwrap());
        }
        assert_eq!(a.peek(), None, "no 5th request before a completion");
        a.on_complete(10.0);
        let next = a.pop().unwrap();
        assert!(next.arrival > 10.0, "re-arm happens after the response + think");
        assert_eq!(next.id, 4);
    }

    #[test]
    fn closed_loop_respects_limit() {
        let mut a = Arrivals::closed_loop(8, 0.5, 10, 9);
        let mut n = 0;
        while let Some(r) = a.pop() {
            n += 1;
            a.on_complete(r.arrival + 0.1);
        }
        assert_eq!(n, 10);
        a.on_complete(99.0);
        assert_eq!(a.peek(), None, "limit reached: completions stop re-arming");
    }

    #[test]
    fn ramped_tracks_segment_rates() {
        // A 3-segment schedule: quiet → surge → quiet. Arrivals inside
        // each window must track that window's rate, not the mean.
        let ts = drain_open(Arrivals::ramped(
            &[(10.0, 50.0), (10.0, 500.0), (10.0, 50.0)],
            6_000,
            17,
        ));
        assert!(ts.windows(2).all(|w| w[0] <= w[1]), "arrivals sorted");
        let in_window = |lo: f64, hi: f64| ts.iter().filter(|&&t| t >= lo && t < hi).count() as f64;
        let quiet = in_window(0.0, 10.0) / 10.0;
        let surge = in_window(10.0, 20.0) / 10.0;
        assert!((quiet / 50.0 - 1.0).abs() < 0.25, "quiet-window rate {quiet}");
        assert!((surge / 500.0 - 1.0).abs() < 0.1, "surge-window rate {surge}");
    }

    #[test]
    fn ramped_last_segment_extends_forever() {
        // More requests than the schedule's windows hold: the tail must
        // keep arriving at the final segment's rate, never stall.
        let ts = drain_open(Arrivals::ramped(&[(1.0, 10.0), (1.0, 100.0)], 2_000, 3));
        assert_eq!(ts.len(), 2_000);
        let span_past = ts.last().unwrap() - 2.0;
        let rate_past = ts.iter().filter(|&&t| t >= 2.0).count() as f64 / span_past;
        assert!((rate_past / 100.0 - 1.0).abs() < 0.1, "tail rate {rate_past}");
    }

    #[test]
    fn ramped_same_seed_is_bit_identical() {
        let segs = [(5.0, 40.0), (5.0, 160.0)];
        let a = drain_open(Arrivals::ramped(&segs, 800, 7));
        let b = drain_open(Arrivals::ramped(&segs, 800, 7));
        assert!(a.iter().zip(&b).all(|(x, y)| x.to_bits() == y.to_bits()));
        let c = drain_open(Arrivals::ramped(&segs, 800, 8));
        assert_ne!(a, c, "different seed, different timeline");
    }

    #[test]
    fn ramped_single_segment_is_poisson_shaped() {
        // One segment == a fixed-rate Poisson process (its own RNG fork,
        // so not bit-identical to Arrivals::poisson — but the measured
        // rate must match).
        let ts = drain_open(Arrivals::ramped(&[(1.0, 100.0)], 10_000, 42));
        let measured = ts.len() as f64 / ts.last().unwrap();
        assert!((measured / 100.0 - 1.0).abs() < 0.05, "rate {measured}");
    }

    #[test]
    fn ids_are_issue_ordered() {
        let mut a = Arrivals::poisson(10.0, 50, 1);
        for want in 0..50 {
            assert_eq!(a.pop().unwrap().id, want);
        }
        assert!(a.pop().is_none());
    }
}
