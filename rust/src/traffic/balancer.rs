//! Fleet serving: a front-door load balancer over
//! [`crate::cluster::fleet`] servers.
//!
//! One global virtual-time loop interleaves the arrival stream with
//! every server's [`ServeEngine`](super::engine::ServeEngine) — unlike
//! the batch fleet (independent per-server runs joined by a barrier),
//! serving requires a *joint* simulation because the balancer's
//! decisions depend on live cross-server state (queue depths for JSQ)
//! and responses contend on one shared rack downlink.
//!
//! Balancer policies:
//!
//! * **round-robin** — oblivious rotation; the baseline every LB paper
//!   starts from. Suffers on heterogeneous fleets (an SSD server gets
//!   the same share as a CSD server 2–3× its capacity).
//! * **weighted-by-capacity** — smooth weighted round-robin over each
//!   server's nominal service rate; the right *open-loop* split for
//!   heterogeneous fleets.
//! * **join-shortest-queue** — route to the server with the fewest
//!   outstanding requests; adapts to bursts and heterogeneity without
//!   knowing capacities.
//! * **least-work** — route to the server with the least outstanding
//!   *estimated service time*: queued requests divided by the server's
//!   nominal rate (the per-shape service estimate). On a heterogeneous
//!   fleet a queued request is not a unit of work — an SSD server's
//!   request costs ~2–3× a CSD server's — and counting requests (JSQ)
//!   systematically overloads the slow shape. Worse, under admission
//!   control a shedding server's queue *freezes* at its (lower)
//!   admission bound, so JSQ pins on it and throws away headroom the
//!   fast servers still have; least-work keeps routing by time and
//!   fills every server to its own bound (the ISSUE-5 gate test).
//!
//! Responses from non-head servers ship over the top-of-rack
//! [`RackLink`] (one message per completed batch, FIFO at the head's
//! downlink), so a request's end-to-end latency includes the rack hop
//! its placement implies.
//!
//! With admission control on (`[traffic] admission = true`), a request
//! the target server sheds is answered immediately with a rejection:
//! it contributes to `shed` (goodput loss), never to the latency
//! percentiles, and a closed-loop client that receives a rejection
//! re-arms just like one that got a real response.

use std::cmp::Reverse;
use std::collections::{BTreeMap, BinaryHeap};

use crate::cluster::fleet::FleetConfig;
use crate::faults::{FaultPlan, LinkOutcome};
use crate::interconnect::RackLink;
use crate::metrics::Metrics;
use crate::power::PowerModel;
use crate::trace::{EngineProfile, Outcome as TraceOutcome, SpanKind, Tracer};
use crate::workloads::{App, AppModel};

use super::engine::{EnginePolicy, Offer, ServeEngine};
use super::{
    default_slo_p99, fleet_nominal_rate, LatencyStats, ServeReport, ServerServeStats,
    TrafficConfig,
};

/// Front-door load-balancer policy.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum LbPolicy {
    /// Oblivious rotation across servers.
    RoundRobin,
    /// Smooth weighted round-robin by nominal capacity.
    WeightedCapacity,
    /// Fewest outstanding requests wins (ties: lowest index).
    #[default]
    JoinShortestQueue,
    /// Least outstanding estimated service *time* wins (queued requests
    /// ÷ nominal rate; ties: lowest index) — the latency-aware policy.
    LeastWork,
}

impl LbPolicy {
    /// Stable lowercase name used by the CLI, TOML configs and reports.
    pub fn name(&self) -> &'static str {
        match self {
            LbPolicy::RoundRobin => "rr",
            LbPolicy::WeightedCapacity => "weighted",
            LbPolicy::JoinShortestQueue => "jsq",
            LbPolicy::LeastWork => "least-work",
        }
    }

    pub fn all() -> [LbPolicy; 4] {
        [
            LbPolicy::RoundRobin,
            LbPolicy::WeightedCapacity,
            LbPolicy::JoinShortestQueue,
            LbPolicy::LeastWork,
        ]
    }
}

/// Deterministic balancer state.
struct Balancer {
    policy: LbPolicy,
    rr_next: usize,
    assigned: Vec<u64>,
    outstanding: Vec<u64>,
    weights: Vec<f64>,
    /// Per-server nominal service rates (items/s) — the per-shape
    /// service estimate `least-work` divides outstanding counts by.
    rates: Vec<f64>,
    /// Dead-server *belief* (ISSUE-6): set after consecutive missed
    /// acks, cleared by any delivered response. All-false on a healthy
    /// run, in which every policy below takes its exact pre-chaos path.
    dead: Vec<bool>,
}

impl Balancer {
    fn new(policy: LbPolicy, weights: Vec<f64>, rates: Vec<f64>) -> Balancer {
        let n = weights.len();
        debug_assert_eq!(rates.len(), n);
        Balancer {
            policy,
            rr_next: 0,
            assigned: vec![0; n],
            outstanding: vec![0; n],
            weights,
            rates,
            dead: vec![false; n],
        }
    }

    fn pick(&mut self) -> usize {
        let n = self.weights.len();
        let any_dead = self.dead.iter().any(|&d| d);
        let s = match self.policy {
            LbPolicy::RoundRobin => {
                let mut s = self.rr_next % n;
                self.rr_next += 1;
                if any_dead {
                    // Skip believed-dead servers, advancing the
                    // rotation; all-dead falls back to the raw slot.
                    let mut hops = 0;
                    while self.dead[s] && hops < n {
                        s = self.rr_next % n;
                        self.rr_next += 1;
                        hops += 1;
                    }
                }
                s
            }
            // Smooth WRR: send the next request where the realized
            // share lags the capacity share most. A believed-dead
            // server's weight is masked to 0 (never picked while an
            // alternative exists — same convention as the engine's
            // crashed-drive fallback).
            LbPolicy::WeightedCapacity => {
                if any_dead {
                    let w: Vec<f64> = self
                        .weights
                        .iter()
                        .zip(&self.dead)
                        .map(|(&w, &d)| if d { 0.0 } else { w })
                        .collect();
                    super::smooth_pick(&self.assigned, &w)
                } else {
                    super::smooth_pick(&self.assigned, &self.weights)
                }
            }
            LbPolicy::JoinShortestQueue => {
                let mut best = usize::MAX;
                for i in 0..n {
                    if any_dead && self.dead[i] {
                        continue;
                    }
                    if best == usize::MAX || self.outstanding[i] < self.outstanding[best] {
                        best = i;
                    }
                }
                if best == usize::MAX {
                    0
                } else {
                    best
                }
            }
            // Outstanding *seconds* of backlog, not request count: the
            // same queue length is 2–3× more work on an SSD server
            // than on a CSD server.
            LbPolicy::LeastWork => {
                if any_dead {
                    let r: Vec<f64> = self
                        .rates
                        .iter()
                        .zip(&self.dead)
                        .map(|(&r, &d)| if d { 0.0 } else { r })
                        .collect();
                    super::smooth_pick(&self.outstanding, &r)
                } else {
                    super::smooth_pick(&self.outstanding, &self.rates)
                }
            }
        };
        self.assigned[s] += 1;
        self.outstanding[s] += 1;
        s
    }
}

// ---- the failure plane (ISSUE-6) ------------------------------------

/// Consecutive missed acks (fired timeouts) against one server before
/// the front door believes it dead and fails its shards over.
const MISSED_ACKS_DEAD: u32 = 3;
/// Hedge delay as a fraction of the first-attempt timeout: late enough
/// to be rare on a healthy tail, early enough to rescue a straggler
/// before its deadline.
const HEDGE_FRACTION: f64 = 0.75;
/// Deadline-aware automatic timeout: this × (completion estimate +
/// wake/formation floor). Generous enough that it never fires on a
/// healthy fleet at sane loads.
const AUTO_TIMEOUT_MARGIN: f64 = 4.0;

/// Capped exponential backoff multiplier for attempt `k` (1-based).
fn backoff(attempt: u32) -> f64 {
    match attempt {
        0 | 1 => 1.0,
        2 => 2.0,
        3 => 4.0,
        _ => 8.0,
    }
}

/// First believed-live server scanning from `home`'s neighbor — the
/// replica chain a shard fails over along. All-dead returns `home`.
fn failover_target(home: usize, dead: &[bool]) -> usize {
    let n = dead.len();
    for k in 1..n {
        let c = (home + k) % n;
        if !dead[c] {
            return c;
        }
    }
    home
}

/// Front-door bookkeeping for one request's whole lifetime (across
/// retries and hedges). Stored per request id; aggregation is always
/// order-free, so the map's iteration order can never leak into the
/// report.
struct Track {
    arrival: f64,
    /// The server the balancer originally picked (shard home).
    home: usize,
    /// Submissions so far (first offer = 1); retries increment.
    attempts: u32,
    /// Timeout base frozen at first submission.
    base: f64,
    hedged: bool,
    /// Resolved: completed (first response) or declared failed. Late
    /// responses for a done request are duplicate-suppressed.
    done: bool,
}

const KIND_HEDGE: u8 = 0;
const KIND_TIMEOUT: u8 = 1;
const KIND_SUBMIT: u8 = 2;

/// A front-door timer-wheel entry: hedge fire, retry timeout, or a
/// delayed (rack-redirected) submission.
#[derive(Clone, Copy, Debug)]
struct Deadline {
    t: f64,
    id: u64,
    kind: u8,
    tgt: usize,
}

impl PartialEq for Deadline {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == std::cmp::Ordering::Equal
    }
}
impl Eq for Deadline {}
impl PartialOrd for Deadline {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Deadline {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // Total, deterministic order: time, then id, then kind — the
        // wheel's pop order is part of the bit-identity contract.
        self.t
            .total_cmp(&other.t)
            .then(self.id.cmp(&other.id))
            .then(self.kind.cmp(&other.kind))
            .then(self.tgt.cmp(&other.tgt))
    }
}

/// Serve one app across the fleet; returns the rollup report.
///
/// The run is a single joint DES over all servers: global events
/// (arrivals, per-server acks/wakes/flushes, rack deliveries) execute in
/// nondecreasing virtual time, so cross-server interactions (JSQ
/// routing, rack FIFO) are causally consistent and the whole run is a
/// pure function of (config, seed).
pub fn serve_fleet(
    app: App,
    fcfg: &FleetConfig,
    tcfg: &TrafficConfig,
    power: &PowerModel,
    metrics: &mut Metrics,
) -> anyhow::Result<ServeReport> {
    serve_fleet_traced(app, fcfg, tcfg, power, metrics, &mut Tracer::Off)
}

/// [`serve_fleet`] with a span tracer (ISSUE-9). The master `tracer`
/// records front-door events (admission, shed, rack delivery, retries,
/// hedges, failover) and each engine gets a child tracer for the
/// dispatch-path phases; children fold back into the master before the
/// function returns. Passing [`Tracer::Off`] (what [`serve_fleet`]
/// does) runs the exact untraced path — the traced-off bit-identity
/// property pinned by `tests/trace_conservation.rs`.
pub fn serve_fleet_traced(
    app: App,
    fcfg: &FleetConfig,
    tcfg: &TrafficConfig,
    power: &PowerModel,
    metrics: &mut Metrics,
    tracer: &mut Tracer,
) -> anyhow::Result<ServeReport> {
    anyhow::ensure!(fcfg.servers >= 1, "need at least one server in the fleet");
    fcfg.validate_weights()?;
    anyhow::ensure!(tcfg.requests >= 1, "need at least one request to serve");
    anyhow::ensure!(tcfg.min_batch >= 1, "traffic.min_batch must be >= 1");
    anyhow::ensure!(
        tcfg.batch_timeout_s >= 0.0 && tcfg.batch_timeout_s.is_finite(),
        "traffic.batch_timeout_s must be non-negative and finite"
    );
    anyhow::ensure!(
        tcfg.load > 0.0 && tcfg.load.is_finite(),
        "traffic.load must be positive and finite, got {}",
        tcfg.load
    );
    if let Some(r) = tcfg.rate_rps {
        anyhow::ensure!(r > 0.0 && r.is_finite(), "traffic.rate_rps must be positive, got {r}");
        anyhow::ensure!(
            tcfg.process != super::ArrivalProcess::ClosedLoop,
            "rate_rps does not apply to the closed-loop process: its offered rate is \
             clients/think_s ({} clients / {} s); drop --rate or use an open-loop process",
            tcfg.clients,
            tcfg.think_s
        );
    }
    anyhow::ensure!(tcfg.clients >= 1, "traffic.clients must be >= 1");
    anyhow::ensure!(
        tcfg.think_s > 0.0 && tcfg.think_s.is_finite(),
        "traffic.think_s must be positive"
    );
    anyhow::ensure!(
        tcfg.burstiness >= 1.0 && tcfg.burstiness.is_finite(),
        "traffic.burstiness must be >= 1 (peak/mean ratio)"
    );
    anyhow::ensure!(
        tcfg.burst_on_s > 0.0 && tcfg.burst_on_s.is_finite(),
        "traffic.burst_on_s must be positive"
    );
    anyhow::ensure!(
        fcfg.replicas == 0 || fcfg.replicas < fcfg.servers,
        "fleet.replicas ({}) needs a distinct neighbor per shard: must be < servers ({})",
        fcfg.replicas,
        fcfg.servers
    );
    if let Some(to) = tcfg.retry_timeout_s {
        anyhow::ensure!(
            to > 0.0 && to.is_finite(),
            "traffic.retry_timeout_s must be positive and finite, got {to}"
        );
    }
    anyhow::ensure!(
        tcfg.ingest_rate >= 0.0 && tcfg.ingest_rate.is_finite(),
        "traffic.ingest_rate must be non-negative and finite, got {}",
        tcfg.ingest_rate
    );
    if let Some(fc) = &tcfg.faults {
        fc.validate(fcfg.servers)?;
    }

    let specs = fcfg.server_specs();
    let model = AppModel::for_app(app, tcfg.requests);
    let nominal = fleet_nominal_rate(&model, &specs);
    let offered = tcfg.offered_rps(nominal);
    anyhow::ensure!(
        offered > 0.0 && offered.is_finite(),
        "offered rate must be positive (load {} × nominal {nominal})",
        tcfg.load
    );

    // The SLO every run is judged against; with admission on it is also
    // the per-request deadline budget the gate sheds by.
    let slo = tcfg.slo_p99_s.unwrap_or_else(|| default_slo_p99(&model, fcfg.sched.csd_batch));
    anyhow::ensure!(
        slo > 0.0 && slo.is_finite(),
        "traffic.slo_p99_s must be positive and finite, got {slo}"
    );
    let epolicy = EnginePolicy {
        formation: tcfg.formation(),
        skew: tcfg.skew,
        admission_budget_s: tcfg.admission.then_some(slo),
    };

    // ---- build the per-server engines -------------------------------
    // (ServeEngine::new also validates the serving parameters a direct
    // library caller could get wrong: min_batch vs dispatch capacity,
    // skew, the admission budget.)
    let mut engines: Vec<ServeEngine> = specs
        .iter()
        .map(|s| ServeEngine::new(&model, &s.sched, epolicy))
        .collect::<anyhow::Result<_>>()?;
    // Global serving clock starts when the slowest corpus is resident.
    let t0 = engines.iter().map(|e| e.t0()).fold(0.0, f64::max);

    // Per-server nominal rates: the least-work policy's service
    // estimate, and the default capacity weights.
    let rates: Vec<f64> = specs.iter().map(|s| super::nominal_rate(&model, &s.sched)).collect();
    // Balancer capacity weights: the explicit `[fleet] weights` /
    // `--weights` override when present (heterogeneous fleets), else
    // each server's nominal service rate.
    let weights: Vec<f64> = match &fcfg.weights {
        Some(w) => w.iter().map(|&x| x as f64).collect(),
        None => rates.clone(),
    };
    let mut balancer = Balancer::new(tcfg.policy, weights, rates);
    let mut gen = tcfg.arrivals(offered);
    let mut rack = RackLink::new(fcfg.rack_bandwidth, fcfg.rack_msg_overhead);

    let mut latencies: Vec<f64> = Vec::with_capacity(tcfg.requests as usize);
    let mut served_per: Vec<u64> = vec![0; fcfg.servers];
    let mut shed_per: Vec<u64> = vec![0; fcfg.servers];
    let mut first_arrival = f64::INFINITY;
    let mut last_done = t0;

    // ---- the failure plane (ISSUE-6) --------------------------------
    // `resilient` arms the front-door timer wheel (timeouts, hedges);
    // `tracking` maintains per-request lifetime state. Both off is the
    // exact pre-chaos fast path; a *quiet* fault plan draws nothing
    // from its RNG streams, so quiet-plan runs are bit-identical to
    // fault-free runs (the `tests/chaos.rs` property).
    let resilient = tcfg.resilient();
    let tracking = resilient || tcfg.faults.is_some();
    // Expected arrival window: the crash schedule's time base.
    let window = tcfg.requests as f64 / offered;
    let drives_per_server: Vec<usize> = specs.iter().map(|s| s.sched.drives).collect();
    let mut plan = tcfg
        .faults
        .as_ref()
        .map(|fc| FaultPlan::new(fc, &drives_per_server, t0, window));
    if let Some(p) = plan.as_mut() {
        for (e, d) in engines.iter_mut().zip(p.drive.drain(..)) {
            e.set_faults(d);
        }
    }
    // Background ingest/update stream (ISSUE-8): per-server seeded
    // Poisson update writes through the drives' FTLs, firing over the
    // expected arrival window. Rate 0 (the default) arms nothing and
    // draws no RNG — bit-identical to the pre-ISSUE-8 run.
    if tcfg.ingest_rate > 0.0 {
        let mut root = crate::util::Rng::new(tcfg.seed).fork("ingest");
        for (i, e) in engines.iter_mut().enumerate() {
            e.set_ingest(tcfg.ingest_rate, t0 + window, root.fork(&format!("server-{i}")));
        }
    }
    // Span tracing (ISSUE-9): each engine gets a child tracer tagged
    // with its server index; children fold back into the master when
    // the run ends. Off children keep engines on the exact untraced
    // path.
    if tracer.is_on() {
        for (i, e) in engines.iter_mut().enumerate() {
            e.set_tracer(tracer.child(i as u32));
        }
    }
    // Queue-depth / inflight time-series keys (sampled per completion
    // batch while tracing).
    let qd_keys: Vec<String> =
        (0..fcfg.servers).map(|i| format!("serve.s{i}.queue_depth")).collect();
    let if_keys: Vec<String> = (0..fcfg.servers).map(|i| format!("serve.s{i}.inflight")).collect();
    // Per-server latency floor a healthy request can legitimately spend
    // before service starts (wake grid + batch formation): part of the
    // deadline-aware automatic timeout base.
    let floors: Vec<f64> =
        specs.iter().map(|s| s.sched.wakeup_secs + tcfg.batch_timeout_s).collect();
    // BTreeMap, not HashMap: the end-of-run sweep iterates this map,
    // and a failed-request *set* must resolve in request-id order so
    // no hasher state can ever reach the report (lint rule D1).
    let mut tracker: BTreeMap<u64, Track> = BTreeMap::new();
    let mut wheel: BinaryHeap<Reverse<Deadline>> = BinaryHeap::new();
    let mut missed_acks: Vec<u32> = vec![0; fcfg.servers];
    let mut failed = 0u64;
    let mut retried = 0u64;
    let mut hedged = 0u64;
    let mut duplicate_suppressed = 0u64;
    let mut completed_in_slo = 0u64;
    // Attempt-level (not request-level) accounting, for the engine
    // conservation checks below.
    let mut extra_shed = 0u64;
    let mut engine_emitted = 0u64;
    let mut crash_suppressed = 0u64;
    let mut link_dropped = 0u64;
    let mut arrived = 0u64;

    // ---- the joint event loop ---------------------------------------
    // Three event sources in nondecreasing virtual time: arrivals, the
    // per-server engines, and the front-door timer wheel. Arrivals win
    // global ties so same-instant dispatch sees the queued request;
    // engine events beat same-instant deadlines so a response that
    // lands exactly at its timeout counts as delivered. With the wheel
    // empty (any non-resilient run) the selection reduces exactly to
    // the pre-chaos two-way race.
    loop {
        let ta = gen.peek().map(|t| t0 + t);
        let te = engines
            .iter()
            .enumerate()
            .filter_map(|(i, e)| e.next_time().map(|t| (t, i)))
            .min_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        let a = ta.unwrap_or(f64::INFINITY);
        let e = te.map(|(t, _)| t).unwrap_or(f64::INFINITY);
        let w = wheel.peek().map(|d| d.0.t).unwrap_or(f64::INFINITY);
        if a.is_infinite() && e.is_infinite() && w.is_infinite() {
            break;
        }
        if a <= e && a <= w {
            let Some(req) = gen.pop() else {
                anyhow::bail!("arrival stream drained between peek and pop");
            };
            arrived += 1;
            let s = balancer.pick();
            first_arrival = first_arrival.min(a);
            // Timeout base frozen at first submission: explicit when
            // configured, else deadline-aware — a margin over the
            // target's own completion estimate plus its wake floor, so
            // it never fires on a healthy fleet.
            let base = if resilient {
                tcfg.retry_timeout_s.unwrap_or_else(|| {
                    AUTO_TIMEOUT_MARGIN * (engines[s].estimated_completion_s() + floors[s])
                })
            } else {
                0.0
            };
            let down_now = plan.as_ref().map_or(false, |p| p.down(s, a));
            if down_now {
                // The dead server swallows the request whole: no ack,
                // no rejection. Only the timer wheel (or the end-of-run
                // sweep, without resilience) can resolve it now.
                tracer.begin_on(req.id, a, s as u32);
                tracker.insert(
                    req.id,
                    Track { arrival: a, home: s, attempts: 1, base, hedged: false, done: false },
                );
                if resilient {
                    wheel.push(Reverse(Deadline {
                        t: a + base,
                        id: req.id,
                        kind: KIND_TIMEOUT,
                        tgt: s,
                    }));
                    if tcfg.hedge {
                        wheel.push(Reverse(Deadline {
                            t: a + HEDGE_FRACTION * base,
                            id: req.id,
                            kind: KIND_HEDGE,
                            tgt: s,
                        }));
                    }
                }
            } else if engines[s].offer(a, req.id)? == Offer::Shed {
                // Rejected at the door: an immediate response that
                // never enters the percentiles. The rejection still
                // re-arms a closed-loop client, and it closes the
                // serving window like any other response.
                shed_per[s] += 1;
                balancer.outstanding[s] -= 1;
                // A shed request is a zero-width traced timeline: begun
                // and closed at the door in the same instant.
                tracer.begin_on(req.id, a, s as u32);
                tracer.finish(req.id, a, TraceOutcome::Shed);
                gen.on_complete(a - t0);
                last_done = last_done.max(a);
            } else if tracking {
                tracer.begin_on(req.id, a, s as u32);
                tracker.insert(
                    req.id,
                    Track { arrival: a, home: s, attempts: 1, base, hedged: false, done: false },
                );
                if resilient {
                    wheel.push(Reverse(Deadline {
                        t: a + base,
                        id: req.id,
                        kind: KIND_TIMEOUT,
                        tgt: s,
                    }));
                    if tcfg.hedge {
                        wheel.push(Reverse(Deadline {
                            t: a + HEDGE_FRACTION * base,
                            id: req.id,
                            kind: KIND_HEDGE,
                            tgt: s,
                        }));
                    }
                }
            } else {
                // Accepted on a fault-free, non-resilient run: no
                // tracker entry needed, but the traced timeline still
                // opens at the front door.
                tracer.begin_on(req.id, a, s as u32);
            }
        } else if e <= w {
            let Some((_, i)) = te else {
                anyhow::bail!("engine event vanished between peek and step");
            };
            engines[i].step()?;
            let comps = engines[i].take_completions();
            if comps.is_empty() {
                continue;
            }
            engine_emitted += comps.len() as u64;
            if tracer.is_on() {
                // Queue-depth / inflight time series, sampled once per
                // completion batch on the server that produced it.
                metrics.sample(&qd_keys[i], comps[0].done, engines[i].queued() as f64);
                metrics.sample(&if_keys[i], comps[0].done, engines[i].inflight() as f64);
            }
            // One ack event → one batch → one response block over
            // the rack for non-head servers (64 B header + per-item
            // outputs), serialized FIFO on the head's downlink.
            let batch_done = comps[0].done;
            // A crashed server produces no responses: everything it
            // completes during downtime is suppressed, and the front
            // door recovers via timeouts, not mercy.
            if plan.as_ref().map_or(false, |p| p.down(i, batch_done)) {
                crash_suppressed += comps.len() as u64;
                continue;
            }
            let mut dup_copies = false;
            let delivered = if i == 0 {
                batch_done
            } else {
                let bytes = 64 + comps.len() as u64 * model.output_bytes_per_item;
                match plan.as_mut().map_or(LinkOutcome::Deliver, |p| p.link.outcome()) {
                    LinkOutcome::Drop => {
                        // The message transits (bandwidth is spent)
                        // and dies at the head's downlink.
                        let _ = rack.send(batch_done, bytes);
                        link_dropped += comps.len() as u64;
                        continue;
                    }
                    LinkOutcome::Duplicate => {
                        let d = rack.send(batch_done, bytes);
                        // The spurious copy pays the rack again and
                        // arrives strictly later, so every completion
                        // it carries is a duplicate by construction.
                        let _second = rack.send(batch_done, bytes);
                        dup_copies = true;
                        d
                    }
                    LinkOutcome::Deliver => rack.send(batch_done, bytes),
                }
            };
            for c in &comps {
                debug_assert_eq!(c.done.to_bits(), batch_done.to_bits());
                if tracking {
                    let tr = tracker
                        .get_mut(&c.id)
                        .ok_or_else(|| anyhow::anyhow!("completion for untracked request {}", c.id))?;
                    if tr.done {
                        // First response won already (hedge/retry
                        // race, or a post-failure straggler).
                        duplicate_suppressed += 1;
                        continue;
                    }
                    tr.done = true;
                    let lat = delivered - tr.arrival;
                    latencies.push(lat);
                    if lat <= slo {
                        completed_in_slo += 1;
                    }
                    if i != 0 {
                        // Non-head response: the rack hop it just paid.
                        tracer.mark(c.id, SpanKind::RackLink, delivered);
                    }
                    tracer.finish(c.id, delivered, TraceOutcome::Served);
                    gen.on_complete(delivered - t0);
                    served_per[i] += 1;
                } else {
                    let lat = delivered - c.arrival;
                    latencies.push(lat);
                    if lat <= slo {
                        completed_in_slo += 1;
                    }
                    if i != 0 {
                        tracer.mark(c.id, SpanKind::RackLink, delivered);
                    }
                    tracer.finish(c.id, delivered, TraceOutcome::Served);
                    gen.on_complete(delivered - t0);
                    served_per[i] += 1;
                }
            }
            if dup_copies {
                duplicate_suppressed += comps.len() as u64;
            }
            balancer.outstanding[i] = balancer.outstanding[i].saturating_sub(comps.len() as u64);
            if tracking {
                // A delivered response is a liveness proof: reset the
                // missed-ack belief (post-rejoin resurrection).
                missed_acks[i] = 0;
                balancer.dead[i] = false;
            }
            last_done = last_done.max(delivered);
        } else {
            let Some(Reverse(dl)) = wheel.pop() else {
                anyhow::bail!("timer wheel drained between peek and pop");
            };
            let now = dl.t;
            let tr = tracker
                .get_mut(&dl.id)
                .ok_or_else(|| anyhow::anyhow!("deadline for untracked request {}", dl.id))?;
            if tr.done {
                // Stale deadline for a resolved request: ignored with
                // zero side effects — the property that keeps healthy
                // resilient runs identical to non-resilient ones.
                continue;
            }
            match dl.kind {
                KIND_HEDGE => {
                    if tr.hedged {
                        continue;
                    }
                    tr.hedged = true;
                    hedged += 1;
                    tracer.mark_attempt(dl.id, SpanKind::Hedge, now, tr.attempts);
                    let h = if fcfg.replicas > 0 {
                        failover_target(tr.home, &balancer.dead)
                    } else {
                        tr.home
                    };
                    let home = tr.home;
                    if h == home {
                        // Same-server hedge: a fresh copy through the
                        // front door (rescues a faulted ack).
                        if !plan.as_ref().map_or(false, |p| p.down(h, now)) {
                            match engines[h].offer(now, dl.id)? {
                                Offer::Accepted => balancer.outstanding[h] += 1,
                                Offer::Shed => extra_shed += 1,
                            }
                        }
                    } else {
                        // Cross-server hedge: the redirect rides (and
                        // pays) the rack, landing as a delayed submit.
                        let at = rack.send(now, 64 + model.bytes_per_item);
                        tracer.mark(dl.id, SpanKind::FailoverRedirect, at);
                        wheel.push(Reverse(Deadline {
                            t: at,
                            id: dl.id,
                            kind: KIND_SUBMIT,
                            tgt: h,
                        }));
                    }
                }
                KIND_TIMEOUT => {
                    // The attempt aimed at dl.tgt missed its deadline:
                    // one missed ack against that server, and the
                    // straggler is written off the queue-depth books.
                    missed_acks[dl.tgt] += 1;
                    if missed_acks[dl.tgt] >= MISSED_ACKS_DEAD {
                        balancer.dead[dl.tgt] = true;
                    }
                    balancer.outstanding[dl.tgt] =
                        balancer.outstanding[dl.tgt].saturating_sub(1);
                    if tr.attempts > tcfg.retries {
                        // Retry budget exhausted: the front door
                        // answers the client with a failure. That IS a
                        // response — it re-arms a closed-loop client
                        // and extends the serving window.
                        tr.done = true;
                        failed += 1;
                        tracer.finish(dl.id, now, TraceOutcome::Failed);
                        gen.on_complete(now - t0);
                        last_done = last_done.max(now);
                    } else {
                        tr.attempts += 1;
                        retried += 1;
                        // The timed-out attempt's wasted time, tagged
                        // with the attempt number it opened.
                        tracer.mark_attempt(dl.id, SpanKind::Retry, now, tr.attempts);
                        let nt = if balancer.dead[tr.home] && fcfg.replicas > 0 {
                            failover_target(tr.home, &balancer.dead)
                        } else {
                            tr.home
                        };
                        wheel.push(Reverse(Deadline {
                            t: now + tr.base * backoff(tr.attempts),
                            id: dl.id,
                            kind: KIND_TIMEOUT,
                            tgt: nt,
                        }));
                        if nt == tr.home {
                            if !plan.as_ref().map_or(false, |p| p.down(nt, now)) {
                                match engines[nt].offer(now, dl.id)? {
                                    Offer::Accepted => balancer.outstanding[nt] += 1,
                                    Offer::Shed => extra_shed += 1,
                                }
                            }
                        } else {
                            let at = rack.send(now, 64 + model.bytes_per_item);
                            tracer.mark(dl.id, SpanKind::FailoverRedirect, at);
                            wheel.push(Reverse(Deadline {
                                t: at,
                                id: dl.id,
                                kind: KIND_SUBMIT,
                                tgt: nt,
                            }));
                        }
                    }
                }
                _ => {
                    // KIND_SUBMIT: a redirected copy lands at its
                    // failover target. A dead target swallows it (the
                    // armed timeout recovers); a shed just dies — the
                    // timeout covers that path too.
                    if !plan.as_ref().map_or(false, |p| p.down(dl.tgt, now)) {
                        match engines[dl.tgt].offer(now, dl.id)? {
                            Offer::Accepted => balancer.outstanding[dl.tgt] += 1,
                            Offer::Shed => extra_shed += 1,
                        }
                    }
                }
            }
        }
    }

    // ---- conservation -----------------------------------------------
    // Exact accounting at two levels. Requests: every offered request
    // was served (completed once), declared failed, or shed at the
    // door. Attempts: every engine-accepted attempt either emitted a
    // completion or was destroyed by a fault, and every emitted
    // completion was delivered once, duplicate-suppressed, or eaten by
    // a crash/link fault. On a fault-free run every fault term is zero
    // and the checks collapse to the strict pre-chaos invariants.
    let served: u64 = served_per.iter().sum();
    let shed: u64 = shed_per.iter().sum();
    if tracking {
        // Requests with no event left to resolve them (swallowed by a
        // dead server or destroyed with no retry budget) are failures.
        // Counting is order-free, so the map's iteration order cannot
        // leak into the report.
        for (id, t) in tracker.iter().filter(|(_, t)| !t.done) {
            // Traced: a swallowed request closes as a zero-width failed
            // timeline (no response ever reached the front door).
            tracer.finish(*id, t.arrival, TraceOutcome::Failed);
        }
        failed += tracker.values().filter(|t| !t.done).count() as u64;
    }
    anyhow::ensure!(
        served + failed + shed == arrived,
        "serving lost requests: served {served} + failed {failed} + shed {shed} != arrived {arrived}"
    );
    // Open-loop generators always emit every request; a closed loop
    // falls short only when a fault swallowed a request with no
    // resilience armed — the stuck client's request never re-entered
    // circulation. That shortfall is itself a failure to serve.
    anyhow::ensure!(
        arrived == tcfg.requests || tcfg.faults.is_some(),
        "arrival stream ended early without faults: {arrived} of {} requests",
        tcfg.requests
    );
    failed += tcfg.requests - arrived;
    let engine_shed: u64 = engines.iter().map(|e| e.shed()).sum();
    anyhow::ensure!(
        engine_shed == shed + extra_shed,
        "engine admission counters disagree with the front door: \
         {engine_shed} vs {shed} first-offer + {extra_shed} retry/hedge"
    );
    let engine_accepted: u64 = engines.iter().map(|e| e.accepted()).sum();
    let engine_lost: u64 = engines.iter().map(|e| e.lost()).sum();
    anyhow::ensure!(
        engine_accepted == engine_emitted + engine_lost,
        "attempt accounting leak: accepted {engine_accepted} != \
         emitted {engine_emitted} + fault-lost {engine_lost}"
    );
    anyhow::ensure!(
        engine_emitted == served + duplicate_suppressed + crash_suppressed + link_dropped,
        "response accounting leak: emitted {engine_emitted} != served {served} + \
         dup {duplicate_suppressed} + crash-suppressed {crash_suppressed} + \
         link-dropped {link_dropped}"
    );
    let items: u64 = engines.iter().map(|e| e.state().host_items + e.state().csd_items).sum();
    anyhow::ensure!(
        items == engine_accepted,
        "scheduler item split ({items}) disagrees with accepted attempts ({engine_accepted})"
    );

    // Engine self-profiling rollup (always on) and child-trace merge
    // (engine index order — deterministic and part of the trace
    // contract).
    let mut profile = EngineProfile::default();
    for e in engines.iter_mut() {
        profile.absorb(e.profile());
        if tracer.is_on() {
            tracer.merge(e.take_tracer());
        }
    }

    // ---- rollups -----------------------------------------------------
    // Serving window per the report contract: first arrival → last
    // response (requests ≥ 1 is ensured above, so an arrival exists).
    let duration = (last_done - first_arrival.min(last_done)).max(1e-9);
    let mut energy = 0.0;
    for (spec, e) in specs.iter().zip(&engines) {
        let st = e.state();
        // host_busy_secs is single-resource time (≤ duration up to the
        // window clamp); isp_busy_secs is deliberately unclamped — it
        // aggregates across all of the server's drives, so it
        // legitimately exceeds the window on ISP-heavy runs.
        energy += power
            .energy(duration, spec.sched.drives, st.host_busy_secs.min(duration), st.isp_busy_secs)
            .energy_j;
        metrics.merge(e.metrics());
    }
    let per_server: Vec<ServerServeStats> = specs
        .iter()
        .zip(&engines)
        .zip(served_per.iter().zip(&shed_per))
        .map(|((spec, e), (&served, &shed))| {
            let st = e.state();
            ServerServeStats {
                index: spec.index,
                is_csd: spec.is_csd(),
                served,
                shed,
                host_items: st.host_items,
                csd_items: st.csd_items,
                host_busy_secs: st.host_busy_secs,
                isp_busy_secs: st.isp_busy_secs,
            }
        })
        .collect();

    // Flash-management rollup (ISSUE-8): summed FTL counters and the
    // worst per-drive wear spread across every server's drives.
    let mut ftl = crate::csd::ftl::FtlStats::default();
    let mut wear_spread = 0u32;
    let mut ingest_writes = 0u64;
    for e in &engines {
        let (s, w) = e.ftl_rollup();
        ftl.absorb(&s);
        wear_spread = wear_spread.max(w);
        ingest_writes += e.ingest_writes();
    }

    let latency = LatencyStats::of(&latencies);
    metrics.inc("serve.requests", served as f64);
    metrics.inc("serve.shed", shed as f64);
    metrics.inc("serve.failed", failed as f64);
    metrics.inc("serve.retried", retried as f64);
    metrics.inc("serve.rack_bytes", rack.bytes_moved() as f64);
    metrics.set_gauge("serve.p99_latency_s", latency.p99);

    Ok(ServeReport {
        app: model.app.name(),
        shape: fcfg.shape.name(),
        dispatch: fcfg.sched.dispatch.name(),
        process: tcfg.process.name(),
        policy: tcfg.policy.name(),
        servers: fcfg.servers,
        requests: tcfg.requests,
        served,
        shed,
        failed,
        retried,
        hedged,
        duplicate_suppressed,
        completed_in_slo,
        availability: completed_in_slo as f64 / tcfg.requests as f64,
        admission: tcfg.admission,
        slo_p99_s: slo,
        offered_rps: offered,
        achieved_rps: served as f64 / duration,
        duration_secs: duration,
        latency,
        host_items: engines.iter().map(|e| e.state().host_items).sum(),
        csd_items: engines.iter().map(|e| e.state().csd_items).sum(),
        host_batches: engines.iter().map(|e| e.state().host_batches).sum(),
        csd_batches: engines.iter().map(|e| e.state().csd_batches).sum(),
        rack_bytes: rack.bytes_moved(),
        rack_messages: rack.messages(),
        energy_j: energy,
        energy_per_req_j: if served > 0 { energy / served as f64 } else { 0.0 },
        ingest_writes,
        waf: ftl.waf(),
        gc_runs: ftl.gc_runs,
        wear_spread,
        engine_events: profile.events,
        host_done_events: profile.host_done_events,
        csd_ack_events: profile.csd_ack_events,
        wake_events: profile.wake_events,
        flush_events: profile.flush_events,
        ingest_events: profile.ingest_events,
        max_queue_depth: profile.max_queue_depth,
        mean_queue_depth: profile.mean_queue_depth(),
        max_inflight: profile.max_inflight,
        per_server,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::fleet::FleetShape;
    use crate::sched::{DispatchMode, SchedConfig};
    use crate::traffic::ArrivalProcess;

    fn fleet_cfg(servers: usize, shape: FleetShape) -> FleetConfig {
        FleetConfig {
            servers,
            shape,
            sched: SchedConfig {
                csd_batch: 500,
                batch_ratio: 26.0,
                drives: 8,
                isp_drives: 8,
                dispatch: DispatchMode::EventDriven,
                ..SchedConfig::default()
            },
            ..FleetConfig::default()
        }
    }

    fn run(servers: usize, shape: FleetShape, policy: LbPolicy, load: f64) -> ServeReport {
        let tcfg = TrafficConfig {
            load,
            requests: 4_000,
            policy,
            ..TrafficConfig::default()
        };
        let mut m = Metrics::new();
        serve_fleet(App::Sentiment, &fleet_cfg(servers, shape), &tcfg, &PowerModel::default(), &mut m)
            .unwrap()
    }

    #[test]
    fn fleet_serving_conserves_across_policies_and_shapes() {
        for policy in LbPolicy::all() {
            for shape in FleetShape::all() {
                let r = run(3, shape, policy, 0.6);
                assert_eq!(r.served, 4_000, "{policy:?}/{shape:?}");
                assert_eq!(r.host_items + r.csd_items, 4_000);
                assert_eq!(r.per_server.iter().map(|s| s.served).sum::<u64>(), 4_000);
            }
        }
    }

    #[test]
    fn non_head_responses_pay_the_rack() {
        let multi = run(3, FleetShape::AllCsd, LbPolicy::RoundRobin, 0.5);
        assert!(multi.rack_messages > 0, "servers 1..n respond over the rack");
        assert!(multi.rack_bytes > 64 * multi.rack_messages);
        let single = run(1, FleetShape::AllCsd, LbPolicy::RoundRobin, 0.5);
        assert_eq!(single.rack_messages, 0, "a 1-server fleet never touches the rack");
        assert_eq!(single.rack_bytes, 0);
    }

    #[test]
    fn round_robin_spreads_requests_evenly() {
        let r = run(4, FleetShape::AllCsd, LbPolicy::RoundRobin, 0.5);
        for s in &r.per_server {
            assert_eq!(s.served, 1_000, "server {}", s.index);
        }
    }

    #[test]
    fn weighted_capacity_tracks_heterogeneous_fleets() {
        // Mixed fleet: CSD servers (even indices) have ~1.3× the nominal
        // capacity of SSD servers here, so weighted routing must give
        // them a proportionally larger share; the realized split tracks
        // the weight split within 2%.
        let r = run(4, FleetShape::Mixed, LbPolicy::WeightedCapacity, 0.5);
        let model = AppModel::for_app(App::Sentiment, 1);
        let csd_w = model.host_rate() + 8.0 * model.csd_rate();
        let ssd_w = model.host_rate();
        let want_csd_share = 2.0 * csd_w / (2.0 * csd_w + 2.0 * ssd_w);
        let got: u64 = r.per_server.iter().filter(|s| s.is_csd).map(|s| s.served).sum();
        let got_share = got as f64 / r.served as f64;
        assert!(
            (got_share - want_csd_share).abs() < 0.02,
            "csd share {got_share:.3}, capacity share {want_csd_share:.3}"
        );
    }

    #[test]
    fn explicit_weights_skew_the_weighted_balancer() {
        // Regression: `--weights` used to be validated and then ignored
        // by serving. With weights [3, 1] the weighted policy must
        // realize a 75/25 split regardless of the servers' (equal)
        // nominal rates.
        let fcfg = FleetConfig {
            weights: Some(vec![3, 1]),
            ..fleet_cfg(2, FleetShape::AllCsd)
        };
        let tcfg = TrafficConfig {
            load: 0.5,
            requests: 4_000,
            policy: LbPolicy::WeightedCapacity,
            ..TrafficConfig::default()
        };
        let mut m = Metrics::new();
        let r = serve_fleet(App::Sentiment, &fcfg, &tcfg, &PowerModel::default(), &mut m).unwrap();
        assert_eq!(r.per_server[0].served, 3_000);
        assert_eq!(r.per_server[1].served, 1_000);
    }

    #[test]
    fn jsq_beats_round_robin_tail_on_a_mixed_fleet_under_load() {
        // The scenario JSQ exists for: a mixed fleet where the CSD
        // server's in-storage engines give it real extra capacity. An
        // oblivious 50/50 rotation pushes the SSD server past its
        // capacity (its backlog grows for the whole run) while JSQ
        // steers the excess to the CSD server, so the rr tail must blow
        // past the jsq tail. The run is long enough (30 k requests at
        // ~fleet-nominal load) for the rr backlog to accumulate.
        let mk = |policy| TrafficConfig { load: 1.0, requests: 30_000, policy, ..TrafficConfig::default() };
        let mut m = Metrics::new();
        let fleet = fleet_cfg(2, FleetShape::Mixed);
        let rr = serve_fleet(App::Sentiment, &fleet, &mk(LbPolicy::RoundRobin), &PowerModel::default(), &mut m)
            .unwrap();
        let jsq = serve_fleet(
            App::Sentiment,
            &fleet,
            &mk(LbPolicy::JoinShortestQueue),
            &PowerModel::default(),
            &mut m,
        )
        .unwrap();
        assert_eq!(rr.served, jsq.served);
        assert!(
            jsq.latency.p99 < rr.latency.p99,
            "jsq p99 {} should beat rr p99 {} on a skewed fleet",
            jsq.latency.p99,
            rr.latency.p99
        );
    }

    /// A speech serving fleet: the app whose per-request service times
    /// (hundreds of ms) make admission bounds small enough to exercise
    /// with a few thousand requests. csd_batch = 2 is the speech
    /// scale-out operating point, so the default SLO (4× the CSD batch
    /// service time ≈ 26.8 s) is realistic.
    fn speech_fleet(servers: usize, shape: FleetShape) -> FleetConfig {
        FleetConfig {
            servers,
            shape,
            sched: SchedConfig {
                csd_batch: 2,
                batch_ratio: 19.0,
                drives: 8,
                isp_drives: 8,
                dispatch: DispatchMode::EventDriven,
                ..SchedConfig::default()
            },
            ..FleetConfig::default()
        }
    }

    #[test]
    fn least_work_beats_jsq_goodput_on_skewed_mixed_fleet_under_overload() {
        // The ISSUE-5 gate. Mixed fleet, hot-shard skew, sustained
        // bursty overload, admission on. JSQ counts requests, so once
        // the slow SSD server's queue freezes at its (lower) admission
        // bound, JSQ pins on it as the "shortest" queue and sheds
        // requests the CSD server still had deadline headroom for;
        // least-work routes on estimated backlog *time*, fills every
        // server to its own bound, and therefore accepts strictly more.
        let mk = |policy| TrafficConfig {
            process: ArrivalProcess::Bursty,
            load: 1.3,
            requests: 6_000,
            admission: true,
            skew: 1.0,
            policy,
            ..TrafficConfig::default()
        };
        let fleet = speech_fleet(2, FleetShape::Mixed);
        let mut m = Metrics::new();
        let jsq = serve_fleet(
            App::SpeechToText,
            &fleet,
            &mk(LbPolicy::JoinShortestQueue),
            &PowerModel::default(),
            &mut m,
        )
        .unwrap();
        let lw = serve_fleet(
            App::SpeechToText,
            &fleet,
            &mk(LbPolicy::LeastWork),
            &PowerModel::default(),
            &mut m,
        )
        .unwrap();
        for r in [&jsq, &lw] {
            assert_eq!(r.served + r.shed, 6_000, "{}: exact admission accounting", r.policy);
            assert!(r.shed > 0, "{}: sustained overload must shed", r.policy);
        }
        assert!(
            lw.served > jsq.served,
            "least-work goodput {} (shed {}) should beat jsq {} (shed {})",
            lw.served,
            lw.shed,
            jsq.served,
            jsq.shed
        );
    }

    #[test]
    fn admission_bounds_the_tail_the_open_loop_otherwise_blows() {
        // Same overloaded open-loop run ± admission: without it the
        // queue (and every percentile) grows with the run; with it the
        // accepted requests' p99 stays near the deadline budget and the
        // loss shows up as shed count instead.
        let mk = |admission| TrafficConfig {
            load: 1.4,
            requests: 5_000,
            admission,
            ..TrafficConfig::default()
        };
        let fleet = speech_fleet(2, FleetShape::AllCsd);
        let mut m = Metrics::new();
        let open =
            serve_fleet(App::SpeechToText, &fleet, &mk(false), &PowerModel::default(), &mut m)
                .unwrap();
        let gated =
            serve_fleet(App::SpeechToText, &fleet, &mk(true), &PowerModel::default(), &mut m)
                .unwrap();
        assert_eq!(open.shed, 0, "admission off never sheds");
        assert_eq!(open.served, 5_000);
        assert!(gated.shed > 0, "overload under admission shows up as shed");
        assert_eq!(gated.served + gated.shed, 5_000);
        assert!(
            gated.latency.p99 < open.latency.p99,
            "admission p99 {} should be far below the open-loop blowup {}",
            gated.latency.p99,
            open.latency.p99
        );
        assert!(
            gated.latency.p99 <= 2.0 * gated.slo_p99_s,
            "accepted p99 {} should sit near the deadline budget {}",
            gated.latency.p99,
            gated.slo_p99_s
        );
    }

    /// ISSUE-8: fleet serving with the ingest stream on — updates fire
    /// on every server, request conservation is untouched, the FTL
    /// counters reach the report, and the whole run is bit-identical
    /// across repeats (the comparator now covers waf/gc_runs/
    /// wear_spread/ingest_writes too).
    #[test]
    fn ingest_stream_conserves_and_is_bit_identical() {
        let mk = || TrafficConfig {
            load: 0.6,
            requests: 2_000,
            ingest_rate: 500.0,
            ..TrafficConfig::default()
        };
        let fleet = fleet_cfg(2, FleetShape::AllCsd);
        let mut m = Metrics::new();
        let a = serve_fleet(App::Sentiment, &fleet, &mk(), &PowerModel::default(), &mut m).unwrap();
        let b = serve_fleet(App::Sentiment, &fleet, &mk(), &PowerModel::default(), &mut m).unwrap();
        a.check_bit_identical(&b).unwrap();
        assert_eq!(a.served, 2_000, "updates never eat requests");
        assert!(a.ingest_writes > 0, "the stream must fire during the window");
        assert!(a.waf >= 1.0, "flash writes can only amplify");
        let quiet =
            serve_fleet(App::Sentiment, &fleet, &TrafficConfig { ingest_rate: 0.0, ..mk() },
                &PowerModel::default(), &mut m)
            .unwrap();
        assert_eq!(quiet.ingest_writes, 0, "rate 0 arms nothing");
    }

    #[test]
    fn closed_loop_fleet_conserves() {
        let tcfg = TrafficConfig {
            process: ArrivalProcess::ClosedLoop,
            clients: 32,
            think_s: 0.05,
            requests: 2_000,
            ..TrafficConfig::default()
        };
        let mut m = Metrics::new();
        let r = serve_fleet(
            App::Sentiment,
            &fleet_cfg(2, FleetShape::AllCsd),
            &tcfg,
            &PowerModel::default(),
            &mut m,
        )
        .unwrap();
        assert_eq!(r.served, 2_000);
    }

    #[test]
    fn rejects_nonsense() {
        let mut m = Metrics::new();
        let tcfg = TrafficConfig::default();
        let bad = FleetConfig { servers: 0, ..fleet_cfg(1, FleetShape::AllCsd) };
        assert!(serve_fleet(App::Sentiment, &bad, &tcfg, &PowerModel::default(), &mut m).is_err());
        let zero_req = TrafficConfig { requests: 0, ..TrafficConfig::default() };
        let ok = fleet_cfg(1, FleetShape::AllCsd);
        assert!(
            serve_fleet(App::Sentiment, &ok, &zero_req, &PowerModel::default(), &mut m).is_err()
        );
        // rate_rps is meaningless for a closed loop: rejected, not
        // silently ignored.
        let closed_rate = TrafficConfig {
            process: ArrivalProcess::ClosedLoop,
            rate_rps: Some(100.0),
            ..TrafficConfig::default()
        };
        assert!(
            serve_fleet(App::Sentiment, &ok, &closed_rate, &PowerModel::default(), &mut m).is_err()
        );
        // ISSUE-5 satellite: degenerate serving parameters fail loudly.
        let neg_skew = TrafficConfig { skew: -1.0, ..TrafficConfig::default() };
        assert!(
            serve_fleet(App::Sentiment, &ok, &neg_skew, &PowerModel::default(), &mut m).is_err()
        );
        // min_batch beyond one server's single-dispatch drain capacity
        // (host 500×26 + 8×500 = 17_000 for this fleet template).
        let big_min = TrafficConfig { min_batch: 17_001, ..TrafficConfig::default() };
        assert!(
            serve_fleet(App::Sentiment, &ok, &big_min, &PowerModel::default(), &mut m).is_err()
        );
        let bad_slo = TrafficConfig { slo_p99_s: Some(0.0), ..TrafficConfig::default() };
        assert!(
            serve_fleet(App::Sentiment, &ok, &bad_slo, &PowerModel::default(), &mut m).is_err()
        );
        // empty weight vectors are rejected with a clear error
        let empty_w = FleetConfig { weights: Some(vec![]), ..fleet_cfg(1, FleetShape::AllCsd) };
        let err = serve_fleet(
            App::Sentiment,
            &empty_w,
            &TrafficConfig::default(),
            &PowerModel::default(),
            &mut m,
        )
        .unwrap_err();
        assert!(err.to_string().contains("empty"), "unhelpful error: {err}");
    }

    // ---- ISSUE-6: chaos / resilience --------------------------------

    use crate::faults::FaultsConfig;

    /// A single-server crash at 25% of the arrival window.
    fn crash_faults() -> FaultsConfig {
        FaultsConfig { server_crash_at: Some(0.25), crash_server: 0, ..FaultsConfig::default() }
    }

    #[test]
    fn server_crash_without_resilience_loses_requests() {
        // No retries, no hedging, no replicas: everything routed to the
        // crashed server after its crash instant (and everything it had
        // in flight) is simply lost — conservation must still hold, as
        // `failed`, never as a hang or a leak.
        let tcfg = TrafficConfig {
            load: 0.6,
            requests: 4_000,
            policy: LbPolicy::RoundRobin,
            faults: Some(crash_faults()),
            ..TrafficConfig::default()
        };
        let mut m = Metrics::new();
        let r = serve_fleet(
            App::Sentiment,
            &fleet_cfg(4, FleetShape::AllCsd),
            &tcfg,
            &PowerModel::default(),
            &mut m,
        )
        .unwrap();
        assert_eq!(r.served + r.failed + r.shed, 4_000, "conservation under crash");
        assert!(r.failed > 0, "a dead server with no resilience must lose requests");
        assert!(
            r.availability < 0.99,
            "no-resilience availability {} should be visibly degraded",
            r.availability
        );
        assert_eq!(r.retried, 0);
        assert_eq!(r.hedged, 0);
    }

    #[test]
    fn retry_failover_recovers_a_crashed_server() {
        // The full resilience stack: deadline-aware retries, hedging,
        // and one replica per shard. The front door detects the dead
        // server by missed acks, fails its shards over to the neighbor,
        // and steers new arrivals away — availability recovers past the
        // fig11 gate's 99% bar.
        let fcfg = FleetConfig { replicas: 1, ..fleet_cfg(4, FleetShape::AllCsd) };
        let tcfg = TrafficConfig {
            load: 0.6,
            requests: 4_000,
            policy: LbPolicy::RoundRobin,
            retries: 3,
            hedge: true,
            faults: Some(crash_faults()),
            ..TrafficConfig::default()
        };
        let mut m = Metrics::new();
        let r = serve_fleet(App::Sentiment, &fcfg, &tcfg, &PowerModel::default(), &mut m).unwrap();
        assert_eq!(r.served + r.failed + r.shed, 4_000);
        assert!(r.retried > 0, "recovery must go through retries");
        assert!(
            r.availability >= 0.99,
            "resilient availability {} (served {}, failed {}) should clear 99%",
            r.availability,
            r.served,
            r.failed
        );
        assert!(r.per_server[0].served < r.per_server[1].served, "traffic left the dead server");
    }

    #[test]
    fn ack_loss_is_absorbed_by_retries() {
        // Lossy drive acks on a single server: every lost batch times
        // out at the front door and the retry budget replays it — no
        // request may be lost, and the loss shows up in `retried`.
        let tcfg = TrafficConfig {
            load: 0.5,
            requests: 2_000,
            retries: 5,
            faults: Some(FaultsConfig { ack_loss: 0.05, ..FaultsConfig::default() }),
            ..TrafficConfig::default()
        };
        let mut m = Metrics::new();
        let r = serve_fleet(
            App::Sentiment,
            &fleet_cfg(1, FleetShape::AllCsd),
            &tcfg,
            &PowerModel::default(),
            &mut m,
        )
        .unwrap();
        assert_eq!(r.served, 2_000, "retries must recover every lost ack (failed {})", r.failed);
        assert_eq!(r.failed, 0);
        assert!(r.retried > 0, "a 5% ack-loss run must actually retry");
    }

    #[test]
    fn duplicated_rack_messages_are_suppressed() {
        // Heavy link duplication: every response still counts exactly
        // once (first copy wins), the spurious copies are tallied, and
        // both copies pay rack bandwidth.
        let mk = |dup| TrafficConfig {
            load: 0.5,
            requests: 2_000,
            policy: LbPolicy::RoundRobin,
            faults: Some(FaultsConfig { link_dup: dup, ..FaultsConfig::default() }),
            ..TrafficConfig::default()
        };
        let mut m = Metrics::new();
        let fleet = fleet_cfg(2, FleetShape::AllCsd);
        let clean =
            serve_fleet(App::Sentiment, &fleet, &mk(0.0), &PowerModel::default(), &mut m).unwrap();
        let dup =
            serve_fleet(App::Sentiment, &fleet, &mk(0.5), &PowerModel::default(), &mut m).unwrap();
        for r in [&clean, &dup] {
            assert_eq!(r.served, 2_000);
            assert_eq!(r.failed, 0);
        }
        assert_eq!(clean.duplicate_suppressed, 0);
        assert!(dup.duplicate_suppressed > 0, "duplicates must be counted, not double-served");
        assert!(dup.rack_bytes > clean.rack_bytes, "the spurious copy pays the rack");
    }

    #[test]
    fn drive_stalls_delay_but_never_lose() {
        // Transient drive stalls: acks arrive late, nothing is lost,
        // no resilience machinery required.
        let tcfg = TrafficConfig {
            load: 0.5,
            requests: 2_000,
            faults: Some(FaultsConfig { stall: 0.2, stall_s: 0.05, ..FaultsConfig::default() }),
            ..TrafficConfig::default()
        };
        let mut m = Metrics::new();
        let fleet = fleet_cfg(1, FleetShape::AllCsd);
        let r =
            serve_fleet(App::Sentiment, &fleet, &tcfg, &PowerModel::default(), &mut m).unwrap();
        assert_eq!(r.served, 2_000);
        assert_eq!(r.failed, 0);
        let clean = serve_fleet(
            App::Sentiment,
            &fleet,
            &TrafficConfig { faults: None, ..tcfg },
            &PowerModel::default(),
            &mut m,
        )
        .unwrap();
        assert!(
            r.latency.p99 > clean.latency.p99,
            "stalls must show up in the tail: {} vs {}",
            r.latency.p99,
            clean.latency.p99
        );
    }

    #[test]
    fn faulted_runs_are_deterministic() {
        // Same (config, fault seed) twice → bit-identical reports, even
        // under heavy mixed faults.
        let fcfg = FleetConfig { replicas: 1, ..fleet_cfg(3, FleetShape::AllCsd) };
        let tcfg = TrafficConfig {
            load: 0.6,
            requests: 2_000,
            retries: 2,
            hedge: true,
            faults: Some(FaultsConfig {
                ack_loss: 0.05,
                stall: 0.05,
                stall_s: 0.02,
                link_drop: 0.02,
                link_dup: 0.02,
                server_crash_at: Some(0.5),
                rejoin_s: Some(2.0),
                ..FaultsConfig::default()
            }),
            ..TrafficConfig::default()
        };
        let mut m = Metrics::new();
        let a = serve_fleet(App::Sentiment, &fcfg, &tcfg, &PowerModel::default(), &mut m).unwrap();
        let b = serve_fleet(App::Sentiment, &fcfg, &tcfg, &PowerModel::default(), &mut m).unwrap();
        a.check_bit_identical(&b).unwrap();
        assert_eq!(a.served + a.failed + a.shed, 2_000);
    }

    #[test]
    fn rejects_nonsense_resilience_params() {
        let mut m = Metrics::new();
        let ok = fleet_cfg(2, FleetShape::AllCsd);
        // replicas must leave a distinct neighbor
        let bad_rep = FleetConfig { replicas: 2, ..fleet_cfg(2, FleetShape::AllCsd) };
        assert!(serve_fleet(
            App::Sentiment,
            &bad_rep,
            &TrafficConfig::default(),
            &PowerModel::default(),
            &mut m
        )
        .is_err());
        // retry timeout must be positive and finite
        let bad_to =
            TrafficConfig { retry_timeout_s: Some(0.0), retries: 1, ..TrafficConfig::default() };
        assert!(
            serve_fleet(App::Sentiment, &ok, &bad_to, &PowerModel::default(), &mut m).is_err()
        );
        // fault plans are validated against the fleet
        let bad_faults = TrafficConfig {
            faults: Some(FaultsConfig {
                server_crash_at: Some(0.5),
                crash_server: 7,
                ..FaultsConfig::default()
            }),
            ..TrafficConfig::default()
        };
        assert!(
            serve_fleet(App::Sentiment, &ok, &bad_faults, &PowerModel::default(), &mut m).is_err()
        );
    }
}
