//! Data-aware locality scheduling — the paper's stated future work
//! (§V: "develop a data-aware distributed system that can benefit not
//! only from temporal locality but also from spatial locality of data,
//! by classifying queries into categorical groups and redirecting them
//! to associated nodes").
//!
//! Model: items belong to `categories` groups; each drive stores the
//! data for `categories / drives` groups. A node that processes an item
//! whose category lives on its own drive reads it over the fast local
//! path; a *miss* must pull the bytes from the owning drive through the
//! host over the TCP/IP tunnel — the slow path the paper's asymmetry
//! numbers quantify.
//!
//! * `Oblivious` — the baseline §IV-A scheduler: batches are handed to
//!   whoever acks first, so a CSD's hit rate is only `1/drives`.
//! * `DataAware` — queries are classified and routed to the node owning
//!   their category: hit rate ≈ `coverage` (classifier accuracy).

use crate::interconnect::TcpTunnel;
use crate::metrics::Metrics;
use crate::power::PowerModel;
use crate::workloads::AppModel;

use super::{run, RunReport, SchedConfig};

/// Routing policy under evaluation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Policy {
    /// First-come first-served batches (the paper's current scheduler).
    Oblivious,
    /// Category-routed batches (the future-work proposal).
    DataAware,
}

/// Locality experiment parameters.
#[derive(Clone, Copy, Debug)]
pub struct LocalityConfig {
    /// Number of query categories (e.g. topic clusters).
    pub categories: usize,
    /// Fraction of items the classifier routes correctly (DataAware).
    pub coverage: f64,
    /// Bytes of per-category working set (embedding shard, category
    /// model partition) a node must page in when it switches category.
    /// This — not the item payload — is what temporal/spatial locality
    /// saves: a hit reuses the resident working set, a miss streams a
    /// fresh one through the tunnel.
    pub category_state_bytes: u64,
}

impl Default for LocalityConfig {
    fn default() -> Self {
        LocalityConfig {
            categories: 256,
            coverage: 0.95,
            category_state_bytes: 16 << 20,
        }
    }
}

/// Per-item cost of a category miss: request plus the category working
/// set streamed over the tunnel (unloaded estimate).
fn miss_fetch_secs(cfg: &LocalityConfig) -> f64 {
    let tun = TcpTunnel::default();
    tun.unloaded_secs(64) + tun.unloaded_secs(cfg.category_state_bytes)
}

/// Hit rate for a policy on a cluster of `drives`.
pub fn hit_rate(policy: Policy, cfg: &LocalityConfig, drives: usize) -> f64 {
    match policy {
        Policy::Oblivious => 1.0 / drives.max(1) as f64,
        Policy::DataAware => cfg.coverage,
    }
}

/// Expected number of *distinct* categories in a batch of `batch` items
/// drawn uniformly from `categories` groups (occupancy formula). Each
/// distinct non-resident category costs one working-set fetch.
pub fn expected_distinct(categories: usize, batch: u64) -> f64 {
    let c = categories as f64;
    c * (1.0 - (1.0 - 1.0 / c).powf(batch as f64))
}

/// Derive the effective workload model under a routing policy: each
/// batch pays one working-set fetch per distinct non-resident category,
/// amortized over the batch. Oblivious batches mix ~min(categories,
/// batch) categories; data-aware batches are category-pure, so fetches
/// all but vanish. The host path is identical under both policies.
pub fn effective_model(
    base: &AppModel,
    policy: Policy,
    cfg: &LocalityConfig,
    drives: usize,
    csd_batch: u64,
) -> AppModel {
    let miss = 1.0 - hit_rate(policy, cfg, drives);
    let distinct = match policy {
        // random mix of categories per batch
        Policy::Oblivious => expected_distinct(cfg.categories, csd_batch),
        // routed: a batch is (almost) one category
        Policy::DataAware => 1.0,
    };
    let fetch_per_item = miss * miss_fetch_secs(cfg) * distinct / csd_batch.max(1) as f64;
    let mut m = base.clone();
    // csd_item_secs is per-core service; node-level extra time F per item
    // is equivalent to item_secs + F × cores.
    m.csd_item_secs += fetch_per_item * crate::workloads::ISP_CORES;
    m
}

/// Run the same benchmark under a policy; returns the report.
pub fn run_with_policy(
    base: &AppModel,
    sched: &SchedConfig,
    policy: Policy,
    cfg: &LocalityConfig,
    power: &PowerModel,
    metrics: &mut Metrics,
) -> anyhow::Result<RunReport> {
    let model = effective_model(base, policy, cfg, sched.drives, sched.csd_batch);
    run(&model, sched, power, metrics)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::AppModel;

    #[test]
    fn occupancy_formula() {
        // batch of 1 → exactly 1 distinct; huge batch → ~all categories
        assert!((expected_distinct(256, 1) - 1.0).abs() < 1e-9);
        assert!(expected_distinct(256, 100_000) > 255.9);
        let d = expected_distinct(256, 128);
        assert!((90.0..110.0).contains(&d), "distinct {d}");
    }

    #[test]
    fn hit_rates() {
        let cfg = LocalityConfig::default();
        assert!((hit_rate(Policy::Oblivious, &cfg, 36) - 1.0 / 36.0).abs() < 1e-12);
        assert_eq!(hit_rate(Policy::DataAware, &cfg, 36), 0.95);
    }

    #[test]
    fn misses_inflate_csd_cost() {
        let base = AppModel::recommender(1000);
        let cfg = LocalityConfig::default();
        let obl = effective_model(&base, Policy::Oblivious, &cfg, 36, 128);
        let aware = effective_model(&base, Policy::DataAware, &cfg, 36, 128);
        assert!(obl.csd_item_secs > aware.csd_item_secs);
        assert!(aware.csd_item_secs < base.csd_item_secs * 1.1);
        // oblivious pays a meaningful premium (>20%)
        assert!(obl.csd_item_secs > base.csd_item_secs * 1.2);
    }

    #[test]
    fn data_aware_beats_oblivious_end_to_end() {
        let base = AppModel::recommender(20_000);
        let sched = SchedConfig {
            drives: 16,
            isp_drives: 16,
            csd_batch: 128,
            batch_ratio: 22.0,
            ..Default::default()
        };
        let cfg = LocalityConfig::default();
        let p = PowerModel::default();
        let mut m = Metrics::new();
        let obl =
            run_with_policy(&base, &sched, Policy::Oblivious, &cfg, &p, &mut m).unwrap();
        let aware =
            run_with_policy(&base, &sched, Policy::DataAware, &cfg, &p, &mut m).unwrap();
        assert!(
            aware.items_per_sec > obl.items_per_sec,
            "data-aware {} !> oblivious {}",
            aware.items_per_sec,
            obl.items_per_sec
        );
    }
}
