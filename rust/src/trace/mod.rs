//! Deterministic request tracing + tail-latency attribution.
//!
//! Every serving figure in this repo asserts *tail* behaviour (fig9
//! load, fig11 faults, fig13 GC) — this module explains it. A
//! [`Tracer`] threads through the whole serving path
//! (`traffic::balancer` front door → `traffic::engine` →
//! `sched` dispatch → `csd`/`cluster` device models) and records, per
//! sampled request, a **span timeline** in *simulated* time: where the
//! request waited (`admission`, `formation_wait`, `dispatch_wait`),
//! where it executed (`host_io`/`flash_read`/`isp_compute`/…), and
//! what interfered (`gc_stall`, `ecc`, `stall`, `rack_link`,
//! `retry[n]`, `hedge`, `failover_redirect`).
//!
//! Design contract (property-pinned in `tests/trace_conservation.rs`):
//!
//! - **Zero overhead when off.** [`Tracer::Off`] (the default) makes
//!   every record call a no-op and draws no RNG; traced-off runs are
//!   bit-identical to pre-trace behaviour, and traced-ON runs produce
//!   bit-identical *reports* too (tracing is read-only).
//! - **No wall clocks.** All timestamps are simulated seconds
//!   (solana-lint's `wall-clock` rule covers this module).
//! - **Deterministic sampling.** A request is traced iff
//!   `id % sample_every == 0` — seeded by the request id, not the RNG
//!   stream, so sampling never perturbs the simulation and the traced
//!   subset is reproducible.
//! - **Conservation.** For every finalized request,
//!   `sum(phase durations) == end_to_end latency` **to the bit**
//!   (left-fold order). The terminal phase absorbs IEEE-754 residue:
//!   its `dur` may differ from `t1 - t0` by an ulp.
//!
//! Timelines are recorded as *marks*: each mark ends a phase of the
//! given kind that began at the previous mark (the first phase begins
//! at arrival). Finalization stable-sorts marks by time, clamps them
//! monotonically into `[arrival, done]`, and converts consecutive
//! diffs into [`Phase`]s. A request with no marks (e.g. shed at the
//! door) collapses to a single `admission` phase.
//!
//! Exporters: Chrome trace-event JSON ([`chrome_trace`], loadable in
//! Perfetto / `chrome://tracing`, one process per server, one thread
//! track per drive) and JSONL ([`to_jsonl`], one span per line,
//! re-importable via [`parse_jsonl`] for `solana trace-report`).

use std::collections::BTreeMap;

use crate::codec::json::Json;
use crate::metrics::Table;
use crate::util::stats::percentile_sorted;

// ---------------------------------------------------------------------------
// Span taxonomy
// ---------------------------------------------------------------------------

/// Phase kinds a request timeline decomposes into. Ordered roughly by
/// pipeline position; the `Ord` impl only matters for stable grouping.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum SpanKind {
    /// Front-door admission + per-shard queueing before batch formation.
    Admission,
    /// Waiting for the batch to fill to `min_batch` (formation gate).
    FormationWait,
    /// Formation satisfied → actual dispatch (the polling-grid tax; ~0
    /// under `DispatchMode::EventDriven`).
    DispatchWait,
    /// PCIe/DMA tunnel transfer to or from a CSD.
    Tunnel,
    /// Blocked behind garbage collection on the target drive.
    GcStall,
    /// NAND flash array read on the drive.
    FlashRead,
    /// ECC decode on the drive's FCU.
    Ecc,
    /// Host-path SSD read (baseline data movement over PCIe).
    HostIo,
    /// Host CPU compute on host-path batches.
    HostCompute,
    /// In-storage (ISP) compute on the drive.
    IspCompute,
    /// Top-of-rack link hop between servers.
    RackLink,
    /// A timed-out attempt; the phase covers the wasted attempt time.
    Retry,
    /// A hedged (duplicate) request was launched at this point.
    Hedge,
    /// The attempt was redirected to a replica on another server.
    FailoverRedirect,
    /// Injected drive stall (fault plan).
    Stall,
    /// The request's home shard was mid-migration at arrival; the phase
    /// covers the wait for the transfer to drain at the destination
    /// (ISSUE-10 elastic fleet).
    Migration,
    /// The request was in flight on a server that started draining out
    /// of the fleet; the mark pins the drain start (ISSUE-10).
    Drain,
}

/// All kinds, for exhaustive reporting/tests.
pub const SPAN_KINDS: [SpanKind; 17] = [
    SpanKind::Admission,
    SpanKind::FormationWait,
    SpanKind::DispatchWait,
    SpanKind::Tunnel,
    SpanKind::GcStall,
    SpanKind::FlashRead,
    SpanKind::Ecc,
    SpanKind::HostIo,
    SpanKind::HostCompute,
    SpanKind::IspCompute,
    SpanKind::RackLink,
    SpanKind::Retry,
    SpanKind::Hedge,
    SpanKind::FailoverRedirect,
    SpanKind::Stall,
    SpanKind::Migration,
    SpanKind::Drain,
];

impl SpanKind {
    pub fn base_name(self) -> &'static str {
        match self {
            SpanKind::Admission => "admission",
            SpanKind::FormationWait => "formation_wait",
            SpanKind::DispatchWait => "dispatch_wait",
            SpanKind::Tunnel => "tunnel",
            SpanKind::GcStall => "gc_stall",
            SpanKind::FlashRead => "flash_read",
            SpanKind::Ecc => "ecc",
            SpanKind::HostIo => "host_io",
            SpanKind::HostCompute => "host_compute",
            SpanKind::IspCompute => "isp_compute",
            SpanKind::RackLink => "rack_link",
            SpanKind::Retry => "retry",
            SpanKind::Hedge => "hedge",
            SpanKind::FailoverRedirect => "failover_redirect",
            SpanKind::Stall => "stall",
            SpanKind::Migration => "migration",
            SpanKind::Drain => "drain",
        }
    }

    /// Report label; `retry` carries the attempt number (`retry[2]`).
    pub fn label(self, attempt: u32) -> String {
        match self {
            SpanKind::Retry => format!("retry[{attempt}]"),
            _ => self.base_name().to_string(),
        }
    }

    /// Inverse of [`SpanKind::label`] modulo the attempt number (which
    /// the JSONL span record carries separately).
    pub fn parse(name: &str) -> Option<SpanKind> {
        if name.starts_with("retry[") && name.ends_with(']') {
            return Some(SpanKind::Retry);
        }
        SPAN_KINDS.iter().copied().find(|k| k.base_name() == name)
    }
}

/// Terminal state of a traced request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Outcome {
    /// First response delivered back to the front door.
    Served,
    /// Rejected by admission control (zero-width timeline).
    Shed,
    /// All retry attempts exhausted (or still in flight at end of run).
    Failed,
}

impl Outcome {
    pub fn name(self) -> &'static str {
        match self {
            Outcome::Served => "served",
            Outcome::Shed => "shed",
            Outcome::Failed => "failed",
        }
    }

    pub fn parse(s: &str) -> Option<Outcome> {
        match s {
            "served" => Some(Outcome::Served),
            "shed" => Some(Outcome::Shed),
            "failed" => Some(Outcome::Failed),
            _ => None,
        }
    }
}

// ---------------------------------------------------------------------------
// Tracer
// ---------------------------------------------------------------------------

/// A recorded mark: ends a phase of `kind` that started at the
/// previous mark (or at arrival).
#[derive(Clone, Copy, Debug)]
struct Mark {
    kind: SpanKind,
    t: f64,
    /// Drive index, or -1 for host/front-door phases.
    drive: i64,
    attempt: u32,
}

#[derive(Clone, Debug)]
struct ReqBuf {
    arrival: f64,
    server: u32,
    marks: Vec<Mark>,
    done: Option<(f64, Outcome)>,
}

/// Backing store for an armed tracer. Keyed by request id in a
/// `BTreeMap` so finalization order is deterministic.
#[derive(Clone, Debug)]
pub struct TraceBuf {
    sample_every: u64,
    /// 0 = unbounded; > 0 = bounded ring evicting the smallest id.
    cap: usize,
    server: u32,
    by_req: BTreeMap<u64, ReqBuf>,
    dropped: u64,
}

/// Span tracer sink. `Off` (the default) is a guaranteed no-op: every
/// record method returns immediately, so traced-off runs take the
/// exact pre-trace code path.
#[derive(Clone, Debug, Default)]
pub enum Tracer {
    #[default]
    Off,
    On(Box<TraceBuf>),
}

impl Tracer {
    /// Unbounded in-memory sink keeping every `id % sample_every == 0`
    /// request.
    pub fn in_memory(sample_every: u64) -> Tracer {
        Tracer::ring(0, sample_every)
    }

    /// Bounded ring sink: at most `cap` request timelines are retained
    /// (`cap == 0` means unbounded); on overflow the smallest id is
    /// evicted and counted in [`Tracer::dropped`].
    pub fn ring(cap: usize, sample_every: u64) -> Tracer {
        Tracer::On(Box::new(TraceBuf {
            sample_every: sample_every.max(1),
            cap,
            server: 0,
            by_req: BTreeMap::new(),
            dropped: 0,
        }))
    }

    pub fn is_on(&self) -> bool {
        matches!(self, Tracer::On(_))
    }

    /// A per-engine child tracer with the same sampling/capacity
    /// configuration, tagged with `server`, and an empty buffer. A
    /// child of `Off` is `Off`.
    pub fn child(&self, server: u32) -> Tracer {
        match self {
            Tracer::Off => Tracer::Off,
            Tracer::On(b) => Tracer::On(Box::new(TraceBuf {
                sample_every: b.sample_every,
                cap: b.cap,
                server,
                by_req: BTreeMap::new(),
                dropped: 0,
            })),
        }
    }

    /// Deterministic sampling predicate: trace iff the tracer is armed
    /// and `id % sample_every == 0`. Keyed by request id — never by an
    /// RNG draw — so sampling cannot perturb the simulation.
    #[inline]
    pub fn wants(&self, id: u64) -> bool {
        match self {
            Tracer::Off => false,
            Tracer::On(b) => id % b.sample_every == 0,
        }
    }

    /// Open a timeline for `id` at simulated time `t`, tagged with this
    /// tracer's own server index. Idempotent (keeps the earliest
    /// arrival).
    pub fn begin(&mut self, id: u64, t: f64) {
        let server = match self {
            Tracer::Off => return,
            Tracer::On(b) => b.server,
        };
        self.begin_on(id, t, server);
    }

    /// Open a timeline for `id` with an explicit owning server (used by
    /// the front-door master tracer).
    pub fn begin_on(&mut self, id: u64, t: f64, server: u32) {
        let Tracer::On(b) = self else { return };
        if id % b.sample_every != 0 {
            return;
        }
        if let Some(r) = b.by_req.get_mut(&id) {
            if t < r.arrival {
                r.arrival = t;
            }
            return;
        }
        if b.cap > 0 && b.by_req.len() >= b.cap {
            b.by_req.pop_first();
            b.dropped += 1;
        }
        b.by_req
            .insert(id, ReqBuf { arrival: t, server, marks: Vec::new(), done: None });
    }

    /// End a host/front-door phase of `kind` at time `t`.
    #[inline]
    pub fn mark(&mut self, id: u64, kind: SpanKind, t: f64) {
        self.push_mark(id, kind, t, -1, 0);
    }

    /// End a device phase of `kind` at time `t` on `drive`.
    #[inline]
    pub fn mark_drive(&mut self, id: u64, kind: SpanKind, t: f64, drive: usize) {
        self.push_mark(id, kind, t, drive as i64, 0);
    }

    /// End a phase carrying an attempt number (`retry[n]`, `hedge`).
    #[inline]
    pub fn mark_attempt(&mut self, id: u64, kind: SpanKind, t: f64, attempt: u32) {
        self.push_mark(id, kind, t, -1, attempt);
    }

    fn push_mark(&mut self, id: u64, kind: SpanKind, t: f64, drive: i64, attempt: u32) {
        let Tracer::On(b) = self else { return };
        if let Some(r) = b.by_req.get_mut(&id) {
            r.marks.push(Mark { kind, t, drive, attempt });
        }
    }

    /// Close the timeline at `t` with `outcome`. First close wins
    /// (duplicate deliveries are suppressed upstream, but be safe).
    pub fn finish(&mut self, id: u64, t: f64, outcome: Outcome) {
        let Tracer::On(b) = self else { return };
        if let Some(r) = b.by_req.get_mut(&id) {
            if r.done.is_none() {
                r.done = Some((t, outcome));
            }
        }
    }

    /// Fold a per-engine child tracer into this (master) one: marks
    /// append, arrivals keep the minimum, the first close wins.
    pub fn merge(&mut self, child: Tracer) {
        let Tracer::On(b) = self else { return };
        let Tracer::On(c) = child else { return };
        b.dropped += c.dropped;
        for (id, cr) in c.by_req {
            match b.by_req.get_mut(&id) {
                Some(r) => {
                    if cr.arrival < r.arrival {
                        r.arrival = cr.arrival;
                    }
                    r.marks.extend(cr.marks);
                    if r.done.is_none() {
                        r.done = cr.done;
                    }
                }
                None => {
                    if b.cap > 0 && b.by_req.len() >= b.cap {
                        b.by_req.pop_first();
                        b.dropped += 1;
                    }
                    b.by_req.insert(id, cr);
                }
            }
        }
    }

    /// Timelines evicted by the ring bound so far.
    pub fn dropped(&self) -> u64 {
        match self {
            Tracer::Off => 0,
            Tracer::On(b) => b.dropped,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            Tracer::Off => 0,
            Tracer::On(b) => b.by_req.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drain and finalize every closed timeline (ascending request id).
    /// Returns the traces plus the count of unfinished (never-closed)
    /// timelines that were discarded.
    pub fn take_requests(&mut self) -> (Vec<RequestTrace>, u64) {
        let Tracer::On(b) = self else { return (Vec::new(), 0) };
        let by_req = std::mem::take(&mut b.by_req);
        let mut out = Vec::new();
        let mut unfinished = 0u64;
        for (id, r) in by_req {
            match finalize(id, r) {
                Some(tr) => out.push(tr),
                None => unfinished += 1,
            }
        }
        (out, unfinished)
    }
}

// ---------------------------------------------------------------------------
// Finalized timelines
// ---------------------------------------------------------------------------

/// One contiguous phase of a finalized request timeline.
#[derive(Clone, Debug)]
pub struct Phase {
    pub kind: SpanKind,
    pub attempt: u32,
    /// Drive index, or -1 for host/front-door phases.
    pub drive: i64,
    pub t0: f64,
    pub t1: f64,
    /// Duration in seconds. The terminal phase of each request absorbs
    /// IEEE-754 residue so that the left-fold of `dur` equals
    /// `end_to_end()` bit-for-bit; it may therefore differ from
    /// `t1 - t0` by an ulp (and can even be ≤ 0 by an ulp).
    pub dur: f64,
}

/// A finalized per-request span timeline: contiguous phases covering
/// `[arrival, done]` exactly.
#[derive(Clone, Debug)]
pub struct RequestTrace {
    pub id: u64,
    pub server: u32,
    pub arrival: f64,
    pub done: f64,
    pub outcome: Outcome,
    pub phases: Vec<Phase>,
}

impl RequestTrace {
    pub fn end_to_end(&self) -> f64 {
        self.done - self.arrival
    }

    /// Left-fold sum of phase durations, in phase order — the order the
    /// conservation invariant is defined over.
    pub fn phase_sum(&self) -> f64 {
        let mut s = 0.0;
        for p in &self.phases {
            s += p.dur;
        }
        s
    }
}

fn finalize(id: u64, r: ReqBuf) -> Option<RequestTrace> {
    let (done, outcome) = r.done?;
    let arrival = r.arrival;
    let done = done.max(arrival);
    let e2e = done - arrival;
    let mut marks = r.marks;
    // Stable by simulated time: ties keep insertion order, which is the
    // order the pipeline emitted them.
    marks.sort_by(|a, b| a.t.total_cmp(&b.t));
    let mut prev = arrival;
    for m in &mut marks {
        m.t = m.t.max(prev).min(done);
        prev = m.t;
    }
    let mut phases: Vec<Phase> = Vec::new();
    if marks.is_empty() {
        // No pipeline marks (e.g. shed at the door): one admission
        // phase covers the whole (possibly zero-width) timeline.
        phases.push(Phase {
            kind: SpanKind::Admission,
            attempt: 0,
            drive: -1,
            t0: arrival,
            t1: done,
            dur: e2e,
        });
    } else {
        let mut t0 = arrival;
        for m in &marks {
            phases.push(Phase {
                kind: m.kind,
                attempt: m.attempt,
                drive: m.drive,
                t0,
                t1: m.t,
                dur: m.t - t0,
            });
            t0 = m.t;
        }
        // The terminal phase stretches to `done` and takes the exact
        // remainder; `fl(S + fl(E-S)) == E` is NOT an IEEE identity, so
        // a (bounded, normally 0-iteration) fixup nudges the last dur
        // until the left-fold reproduces e2e bit-for-bit.
        let n = phases.len();
        let mut sum_prev = 0.0;
        for p in &phases[..n - 1] {
            sum_prev += p.dur;
        }
        phases[n - 1].t1 = done;
        phases[n - 1].dur = e2e - sum_prev;
        for _ in 0..8 {
            let mut tot = 0.0;
            for p in &phases {
                tot += p.dur;
            }
            if tot.to_bits() == e2e.to_bits() {
                break;
            }
            phases[n - 1].dur += e2e - tot;
        }
    }
    Some(RequestTrace { id, server: r.server, arrival, done, outcome, phases })
}

/// Check the conservation invariant over finalized traces: every
/// request's phase durations left-fold to its end-to-end latency
/// bit-for-bit, and every request has at least one phase.
pub fn verify_conservation(reqs: &[RequestTrace]) -> Result<(), String> {
    for r in reqs {
        if r.phases.is_empty() {
            return Err(format!("request {}: no phases", r.id));
        }
        let sum = r.phase_sum();
        let e2e = r.end_to_end();
        if sum.to_bits() != e2e.to_bits() {
            return Err(format!(
                "request {}: phase sum {sum:?} != end-to-end {e2e:?} (bitwise)",
                r.id
            ));
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Tail-latency attribution
// ---------------------------------------------------------------------------

/// Phase decomposition of the requests at or above a latency
/// percentile ("where does the p99 live").
#[derive(Clone, Debug)]
pub struct BandAttribution {
    pub band: String,
    /// Latency threshold defining band membership (seconds).
    pub threshold_s: f64,
    /// Number of member requests.
    pub requests: usize,
    /// `(phase label, total seconds across members, share of band
    /// end-to-end)`, sorted by descending total (label breaks ties).
    pub phases: Vec<(String, f64, f64)>,
}

impl BandAttribution {
    /// The phase this band spends the most time in.
    pub fn dominant(&self) -> Option<&(String, f64, f64)> {
        self.phases.first()
    }

    /// Share of band end-to-end attributed to `label` (0.0 if absent).
    pub fn share_of(&self, label: &str) -> f64 {
        self.phases
            .iter()
            .find(|(l, _, _)| l == label)
            .map(|(_, _, s)| *s)
            .unwrap_or(0.0)
    }
}

/// Decompose request latency into phase components for the standard
/// percentile bands (`all`, `p50`, `p99`, `p99.9`). A band holds every
/// request whose end-to-end latency is ≥ that percentile of the whole
/// population.
pub fn attribution(reqs: &[RequestTrace]) -> Vec<BandAttribution> {
    let mut sorted: Vec<f64> = reqs.iter().map(|r| r.end_to_end()).collect();
    sorted.sort_by(f64::total_cmp);
    let mut out = Vec::new();
    for (band, pct) in [("all", 0.0), ("p50", 50.0), ("p99", 99.0), ("p99.9", 99.9)] {
        let Some(p) = percentile_sorted(&sorted, pct) else { continue };
        let threshold = if pct == 0.0 { f64::NEG_INFINITY } else { p };
        let mut totals: BTreeMap<String, f64> = BTreeMap::new();
        let mut members = 0usize;
        let mut e2e_total = 0.0;
        for r in reqs {
            if r.end_to_end() < threshold {
                continue;
            }
            members += 1;
            e2e_total += r.end_to_end();
            for ph in &r.phases {
                *totals.entry(ph.kind.label(ph.attempt)).or_insert(0.0) += ph.dur;
            }
        }
        if members == 0 {
            continue;
        }
        let mut phases: Vec<(String, f64, f64)> = totals
            .into_iter()
            .map(|(k, v)| {
                let share = if e2e_total > 0.0 { v / e2e_total } else { 0.0 };
                (k, v, share)
            })
            .collect();
        phases.sort_by(|a, b| b.1.total_cmp(&a.1).then_with(|| a.0.cmp(&b.0)));
        out.push(BandAttribution {
            band: band.to_string(),
            threshold_s: if pct == 0.0 { sorted.first().copied().unwrap_or(0.0) } else { p },
            requests: members,
            phases,
        });
    }
    out
}

/// Render attribution bands as a fixed-width report table.
pub fn attribution_table(bands: &[BandAttribution]) -> Table {
    let mut t = Table::new(
        "Tail-latency attribution (phase share of band end-to-end)",
        &["band", "threshold_s", "requests", "phase", "mean_s", "share_%"],
    );
    for b in bands {
        let nreq = b.requests;
        for (i, (label, tot, share)) in b.phases.iter().enumerate() {
            let (band, thr, reqs) = if i == 0 {
                (b.band.clone(), format!("{:.6}", b.threshold_s), nreq.to_string())
            } else {
                (String::new(), String::new(), String::new())
            };
            let mean = if nreq > 0 { tot / nreq as f64 } else { 0.0 };
            t.row(vec![
                band,
                thr,
                reqs,
                label.clone(),
                format!("{mean:.6}"),
                format!("{:.2}", share * 100.0),
            ]);
        }
    }
    t
}

// ---------------------------------------------------------------------------
// Chrome trace-event exporter
// ---------------------------------------------------------------------------

/// Thread-track assignment inside a server's process: 0 = front door,
/// 1 = host path, 2+d = drive d.
fn track_of(p: &Phase) -> u32 {
    let device = matches!(
        p.kind,
        SpanKind::HostIo
            | SpanKind::HostCompute
            | SpanKind::GcStall
            | SpanKind::Ecc
            | SpanKind::FlashRead
            | SpanKind::IspCompute
            | SpanKind::Tunnel
    );
    if !device {
        0
    } else if p.drive >= 0 {
        2 + p.drive as u32
    } else {
        1
    }
}

fn chrome_event(name: &str, ph: &str, ts: f64, pid: u32, tid: u32, id: u64) -> Json {
    let mut e = Json::obj();
    e.set("name", name.into())
        .set("cat", "span".into())
        .set("ph", ph.into())
        .set("ts", ts.into())
        .set("pid", (pid as u64).into())
        .set("tid", (tid as u64).into());
    let mut args = Json::obj();
    args.set("req", id.into());
    e.set("args", args);
    e
}

fn chrome_meta(name: &str, value: &str, pid: u32, tid: u32) -> Json {
    let mut e = Json::obj();
    e.set("name", name.into())
        .set("ph", "M".into())
        .set("ts", 0.0.into())
        .set("pid", (pid as u64).into())
        .set("tid", (tid as u64).into());
    let mut args = Json::obj();
    args.set("name", value.into());
    e.set("args", args);
    e
}

/// Export finalized traces as Chrome trace-event JSON
/// (`{"traceEvents": [...]}`), loadable in Perfetto or
/// `chrome://tracing`. One process per server; thread 0 is the front
/// door (`ph:"X"` complete events, one per phase, overlapping requests
/// allowed), thread 1 the host path, thread 2+d drive d (`ph:"B"/"E"`
/// pairs with proper nesting). Timestamps are simulated microseconds,
/// globally non-decreasing across the event array.
pub fn chrome_trace(reqs: &[RequestTrace]) -> Json {
    // Group phases per (server pid, thread track).
    type Span = (f64, f64, usize, String, u64);
    let mut tracks: BTreeMap<(u32, u32), Vec<Span>> = BTreeMap::new();
    let mut seq = 0usize;
    for r in reqs {
        for p in &r.phases {
            let tid = track_of(p);
            tracks
                .entry((r.server, tid))
                .or_default()
                .push((p.t0, p.t1, seq, p.kind.label(p.attempt), r.id));
            seq += 1;
        }
    }
    let mut meta: Vec<Json> = Vec::new();
    let mut last_pid: Option<u32> = None;
    for &(pid, tid) in tracks.keys() {
        if last_pid != Some(pid) {
            meta.push(chrome_meta("process_name", &format!("server {pid}"), pid, 0));
            last_pid = Some(pid);
        }
        let tname = match tid {
            0 => "frontdoor".to_string(),
            1 => "host".to_string(),
            d => format!("drive {}", d - 2),
        };
        meta.push(chrome_meta("thread_name", &tname, pid, tid));
    }
    let mut events: Vec<(f64, Json)> = Vec::new();
    for ((pid, tid), mut spans) in tracks {
        if tid == 0 {
            // Front door: complete events; requests overlap freely.
            for (t0, t1, _seq, name, id) in spans {
                let mut e = chrome_event(&name, "X", t0 * 1e6, pid, tid, id);
                e.set("dur", ((t1 - t0).max(0.0) * 1e6).into());
                events.push((t0 * 1e6, e));
            }
            continue;
        }
        // Device tracks: laminar B/E nesting via a lazy-close stack.
        // Sort containers first (t0 asc, t1 desc), then emit B events,
        // closing every open span that ends at or before the new start.
        spans.sort_by(|a, b| {
            a.0.total_cmp(&b.0).then(b.1.total_cmp(&a.1)).then(a.2.cmp(&b.2))
        });
        let mut stack: Vec<(String, f64, u64)> = Vec::new();
        for (t0, mut t1, _seq, name, id) in spans {
            while let Some(top) = stack.last() {
                if top.1 <= t0 {
                    let (n, te, tid_req) = (top.0.clone(), top.1, top.2);
                    stack.pop();
                    events.push((te * 1e6, chrome_event(&n, "E", te * 1e6, pid, tid, tid_req)));
                } else {
                    break;
                }
            }
            if let Some(top) = stack.last() {
                // Defensive: keep nesting laminar even if a child
                // outlives its container by an ulp.
                t1 = t1.min(top.1);
            }
            let t1 = t1.max(t0);
            events.push((t0 * 1e6, chrome_event(&name, "B", t0 * 1e6, pid, tid, id)));
            stack.push((name, t1, id));
        }
        while let Some((n, te, id)) = stack.pop() {
            events.push((te * 1e6, chrome_event(&n, "E", te * 1e6, pid, tid, id)));
        }
    }
    // Stable sort keeps per-track emission order among equal
    // timestamps, so B/E discipline survives the global ordering.
    events.sort_by(|a, b| a.0.total_cmp(&b.0));
    let mut arr: Vec<Json> = meta;
    arr.extend(events.into_iter().map(|(_, e)| e));
    let mut root = Json::obj();
    root.set("traceEvents", arr.into());
    root.set("displayTimeUnit", "ms".into());
    root
}

/// Schema sanity for an exported Chrome trace: non-decreasing `ts`
/// over non-metadata events in array order, and per-(pid, tid) `B`/`E`
/// stack discipline with matching names, all stacks empty at the end.
pub fn check_chrome(j: &Json) -> Result<(), String> {
    let evs = j
        .get("traceEvents")
        .and_then(|e| e.as_arr())
        .ok_or_else(|| "missing traceEvents array".to_string())?;
    let mut last_ts = f64::NEG_INFINITY;
    let mut stacks: BTreeMap<(u64, u64), Vec<String>> = BTreeMap::new();
    for (i, e) in evs.iter().enumerate() {
        let ph = e
            .get("ph")
            .and_then(|p| p.as_str())
            .ok_or_else(|| format!("event {i}: missing ph"))?;
        if ph == "M" {
            continue;
        }
        let ts = e
            .get("ts")
            .and_then(|t| t.as_f64())
            .ok_or_else(|| format!("event {i}: missing ts"))?;
        if ts < last_ts {
            return Err(format!("event {i}: ts {ts} < previous {last_ts} (non-monotone)"));
        }
        last_ts = ts;
        let pid = e
            .get("pid")
            .and_then(|p| p.as_u64())
            .ok_or_else(|| format!("event {i}: missing pid"))?;
        let tid = e
            .get("tid")
            .and_then(|t| t.as_u64())
            .ok_or_else(|| format!("event {i}: missing tid"))?;
        let name = e.get("name").and_then(|n| n.as_str()).unwrap_or("").to_string();
        match ph {
            "B" => stacks.entry((pid, tid)).or_default().push(name),
            "E" => {
                let st = stacks.entry((pid, tid)).or_default();
                match st.pop() {
                    Some(top) if top == name => {}
                    Some(top) => {
                        return Err(format!(
                            "event {i}: E `{name}` does not match open B `{top}` on ({pid},{tid})"
                        ))
                    }
                    None => {
                        return Err(format!("event {i}: E `{name}` with empty stack on ({pid},{tid})"))
                    }
                }
            }
            "X" => {}
            other => return Err(format!("event {i}: unexpected ph `{other}`")),
        }
    }
    for ((pid, tid), st) in &stacks {
        if !st.is_empty() {
            return Err(format!("track ({pid},{tid}): {} unclosed B events", st.len()));
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// JSONL exporter / importer
// ---------------------------------------------------------------------------

/// Export finalized traces as JSONL: one `request` record plus one
/// `span` record per phase, per line. Numbers round-trip bit-exactly
/// (shortest-round-trip float formatting on both ends), so the
/// conservation invariant survives export → import.
pub fn to_jsonl(reqs: &[RequestTrace]) -> String {
    let mut out = String::new();
    for r in reqs {
        let mut o = Json::obj();
        o.set("type", "request".into())
            .set("id", r.id.into())
            .set("server", (r.server as u64).into())
            .set("arrival", r.arrival.into())
            .set("done", r.done.into())
            .set("e2e", r.end_to_end().into())
            .set("outcome", r.outcome.name().into());
        out.push_str(&o.to_string());
        out.push('\n');
        for p in &r.phases {
            let mut s = Json::obj();
            s.set("type", "span".into())
                .set("id", r.id.into())
                .set("name", p.kind.label(p.attempt).into())
                .set("t0", p.t0.into())
                .set("t1", p.t1.into())
                .set("dur", p.dur.into())
                .set("server", (r.server as u64).into())
                .set("drive", (p.drive as f64).into())
                .set("attempt", (p.attempt as u64).into());
            out.push_str(&s.to_string());
            out.push('\n');
        }
    }
    out
}

fn req_f64(j: &Json, key: &str, lineno: usize) -> Result<f64, String> {
    j.get(key)
        .and_then(|v| v.as_f64())
        .ok_or_else(|| format!("line {lineno}: missing number `{key}`"))
}

fn req_u64(j: &Json, key: &str, lineno: usize) -> Result<u64, String> {
    j.get(key)
        .and_then(|v| v.as_u64())
        .ok_or_else(|| format!("line {lineno}: missing integer `{key}`"))
}

/// Import a JSONL trace produced by [`to_jsonl`]. Spans re-attach to
/// their request in file order; returns traces in ascending id order.
pub fn parse_jsonl(text: &str) -> Result<Vec<RequestTrace>, String> {
    let mut reqs: BTreeMap<u64, RequestTrace> = BTreeMap::new();
    let mut spans: BTreeMap<u64, Vec<Phase>> = BTreeMap::new();
    for (i, raw) in text.lines().enumerate() {
        let lineno = i + 1;
        let line = raw.trim();
        if line.is_empty() {
            continue;
        }
        let j = Json::parse(line).map_err(|e| format!("line {lineno}: {e}"))?;
        let ty = j
            .get("type")
            .and_then(|t| t.as_str())
            .ok_or_else(|| format!("line {lineno}: missing `type`"))?;
        match ty {
            "request" => {
                let id = req_u64(&j, "id", lineno)?;
                let outcome_s = j
                    .get("outcome")
                    .and_then(|o| o.as_str())
                    .ok_or_else(|| format!("line {lineno}: missing `outcome`"))?;
                let outcome = Outcome::parse(outcome_s)
                    .ok_or_else(|| format!("line {lineno}: unknown outcome `{outcome_s}`"))?;
                let server_u = req_u64(&j, "server", lineno)?;
                reqs.insert(
                    id,
                    RequestTrace {
                        id,
                        server: u32::try_from(server_u)
                            .map_err(|_| format!("line {lineno}: server out of range"))?,
                        arrival: req_f64(&j, "arrival", lineno)?,
                        done: req_f64(&j, "done", lineno)?,
                        outcome,
                        phases: Vec::new(),
                    },
                );
            }
            "span" => {
                let id = req_u64(&j, "id", lineno)?;
                let name = j
                    .get("name")
                    .and_then(|n| n.as_str())
                    .ok_or_else(|| format!("line {lineno}: missing `name`"))?;
                let kind = SpanKind::parse(name)
                    .ok_or_else(|| format!("line {lineno}: unknown span kind `{name}`"))?;
                let attempt_u = req_u64(&j, "attempt", lineno)?;
                let drive = req_f64(&j, "drive", lineno)? as i64;
                spans.entry(id).or_default().push(Phase {
                    kind,
                    attempt: u32::try_from(attempt_u)
                        .map_err(|_| format!("line {lineno}: attempt out of range"))?,
                    drive,
                    t0: req_f64(&j, "t0", lineno)?,
                    t1: req_f64(&j, "t1", lineno)?,
                    dur: req_f64(&j, "dur", lineno)?,
                });
            }
            other => return Err(format!("line {lineno}: unknown record type `{other}`")),
        }
    }
    let mut out = Vec::new();
    for (id, mut r) in reqs {
        if let Some(ph) = spans.remove(&id) {
            r.phases = ph;
        }
        out.push(r);
    }
    if let Some((id, _)) = spans.iter().next() {
        return Err(format!("span records for id {id} have no request record"));
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// Configuration
// ---------------------------------------------------------------------------

/// Trace export format.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum TraceFormat {
    /// Chrome trace-event JSON (Perfetto / chrome://tracing).
    Chrome,
    /// One span per line; `solana trace-report` input.
    #[default]
    Jsonl,
}

impl TraceFormat {
    pub fn parse(s: &str) -> Option<TraceFormat> {
        match s {
            "chrome" => Some(TraceFormat::Chrome),
            "jsonl" => Some(TraceFormat::Jsonl),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            TraceFormat::Chrome => "chrome",
            TraceFormat::Jsonl => "jsonl",
        }
    }
}

/// `[trace]` configuration: sink shape, deterministic sampling rate,
/// and export format/path. Disabled (i.e. [`Tracer::Off`]) by default.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceConfig {
    pub enabled: bool,
    /// 0 = unbounded in-memory sink; > 0 = bounded ring of this many
    /// request timelines (smallest ids evicted first).
    pub ring_cap: usize,
    /// Trace every Nth request (`id % N == 0`); 1 = every request.
    pub sample_every: u64,
    pub format: TraceFormat,
    /// Export path; `None` keeps the trace in memory (report only).
    pub out: Option<String>,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig {
            enabled: false,
            ring_cap: 0,
            sample_every: 1,
            format: TraceFormat::Jsonl,
            out: None,
        }
    }
}

impl TraceConfig {
    pub fn validate(&self) -> Result<(), String> {
        if self.sample_every == 0 {
            return Err("[trace] sample must be >= 1 (1 = trace every request)".to_string());
        }
        Ok(())
    }

    /// Build the tracer this config describes ([`Tracer::Off`] when
    /// disabled).
    pub fn tracer(&self) -> Tracer {
        if !self.enabled {
            Tracer::Off
        } else if self.ring_cap > 0 {
            Tracer::ring(self.ring_cap, self.sample_every)
        } else {
            Tracer::in_memory(self.sample_every)
        }
    }
}

// ---------------------------------------------------------------------------
// Engine self-profiling
// ---------------------------------------------------------------------------

/// Always-on per-engine execution counters (cheap integer increments;
/// identical traced-on and traced-off since they never feed back into
/// the simulation). Surfaced in `ServeReport` / `--json`; excluded
/// from `check_bit_identical` like the scheduler's event counters.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct EngineProfile {
    /// Total engine events executed.
    pub events: u64,
    pub host_done_events: u64,
    pub csd_ack_events: u64,
    pub wake_events: u64,
    pub flush_events: u64,
    pub ingest_events: u64,
    /// Sum of queue depth observed at each event (mean = sum/events).
    pub queue_depth_sum: u64,
    pub max_queue_depth: u64,
    pub max_inflight: u64,
}

impl EngineProfile {
    /// Fold another engine's profile into this aggregate (sums add,
    /// maxima take the max).
    pub fn absorb(&mut self, other: &EngineProfile) {
        self.events += other.events;
        self.host_done_events += other.host_done_events;
        self.csd_ack_events += other.csd_ack_events;
        self.wake_events += other.wake_events;
        self.flush_events += other.flush_events;
        self.ingest_events += other.ingest_events;
        self.queue_depth_sum += other.queue_depth_sum;
        self.max_queue_depth = self.max_queue_depth.max(other.max_queue_depth);
        self.max_inflight = self.max_inflight.max(other.max_inflight);
    }

    pub fn mean_queue_depth(&self) -> f64 {
        if self.events == 0 {
            0.0
        } else {
            self.queue_depth_sum as f64 / self.events as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn traced_one(marks: &[(SpanKind, f64)], arrival: f64, done: f64) -> RequestTrace {
        let mut t = Tracer::in_memory(1);
        t.begin_on(7, arrival, 0);
        for &(k, at) in marks {
            t.mark(7, k, at);
        }
        t.finish(7, done, Outcome::Served);
        let (reqs, dropped) = t.take_requests();
        assert_eq!(dropped, 0);
        assert_eq!(reqs.len(), 1);
        reqs.into_iter().next().unwrap()
    }

    #[test]
    fn off_records_nothing_and_children_stay_off() {
        let mut t = Tracer::Off;
        assert!(!t.wants(0));
        t.begin(1, 0.0);
        t.mark(1, SpanKind::HostIo, 1.0);
        t.finish(1, 2.0, Outcome::Served);
        assert!(t.is_empty());
        assert!(!t.child(3).is_on());
        let (reqs, dropped) = t.take_requests();
        assert!(reqs.is_empty());
        assert_eq!(dropped, 0);
    }

    #[test]
    fn sampling_is_by_request_id() {
        let t = Tracer::in_memory(4);
        assert!(t.wants(0));
        assert!(!t.wants(1));
        assert!(!t.wants(3));
        assert!(t.wants(8));
        let every = Tracer::in_memory(1);
        assert!(every.wants(17));
    }

    #[test]
    fn phases_partition_the_timeline_bitwise() {
        let r = traced_one(
            &[
                (SpanKind::Admission, 0.013),
                (SpanKind::FormationWait, 0.1 + 0.2), // awkward float
                (SpanKind::HostIo, 0.7),
                (SpanKind::HostCompute, 0.9000000001),
            ],
            0.001,
            1.1,
        );
        assert_eq!(r.phases.len(), 4);
        assert_eq!(r.phases[0].t0, 0.001);
        assert_eq!(r.phases[3].t1, 1.1);
        verify_conservation(&[r]).unwrap();
    }

    #[test]
    fn no_marks_collapses_to_admission() {
        let r = traced_one(&[], 2.0, 2.0);
        assert_eq!(r.phases.len(), 1);
        assert_eq!(r.phases[0].kind, SpanKind::Admission);
        assert_eq!(r.end_to_end(), 0.0);
        verify_conservation(&[r]).unwrap();
    }

    #[test]
    fn out_of_range_marks_clamp_monotone() {
        let r = traced_one(
            &[
                (SpanKind::HostIo, 5.0),  // past done
                (SpanKind::Admission, -1.0), // before arrival (sorts first)
            ],
            1.0,
            2.0,
        );
        // stable sort orders by t: -1.0 then 5.0; both clamp into [1, 2]
        assert_eq!(r.phases[0].kind, SpanKind::Admission);
        assert_eq!(r.phases[0].t1, 1.0);
        assert_eq!(r.phases[1].t1, 2.0);
        verify_conservation(&[r]).unwrap();
    }

    #[test]
    fn ring_evicts_smallest_id() {
        let mut t = Tracer::ring(2, 1);
        for id in [3u64, 1, 2] {
            t.begin_on(id, id as f64, 0);
            t.finish(id, id as f64 + 1.0, Outcome::Served);
        }
        assert_eq!(t.dropped(), 1);
        let (reqs, _) = t.take_requests();
        let ids: Vec<u64> = reqs.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![2, 3]);
    }

    #[test]
    fn merge_folds_child_marks_and_master_wins_done() {
        let mut master = Tracer::in_memory(1);
        master.begin_on(5, 1.0, 0);
        master.finish(5, 4.0, Outcome::Served);
        let mut child = master.child(2);
        child.begin(5, 1.5); // later arrival: master's earlier one wins
        child.mark_drive(5, SpanKind::FlashRead, 2.0, 1);
        child.mark(5, SpanKind::IspCompute, 3.0);
        master.merge(child);
        let (reqs, _) = master.take_requests();
        assert_eq!(reqs.len(), 1);
        let r = &reqs[0];
        assert_eq!(r.arrival, 1.0);
        assert_eq!(r.server, 0);
        assert_eq!(r.outcome, Outcome::Served);
        assert_eq!(r.phases.len(), 2);
        assert_eq!(r.phases[0].kind, SpanKind::FlashRead);
        assert_eq!(r.phases[0].drive, 1);
        verify_conservation(&reqs).unwrap();
    }

    #[test]
    fn unfinished_timelines_are_dropped_and_counted() {
        let mut t = Tracer::in_memory(1);
        t.begin_on(1, 0.0, 0);
        t.begin_on(2, 0.0, 0);
        t.finish(2, 1.0, Outcome::Served);
        let (reqs, unfinished) = t.take_requests();
        assert_eq!(reqs.len(), 1);
        assert_eq!(unfinished, 1);
    }

    #[test]
    fn labels_round_trip_including_retry() {
        for k in SPAN_KINDS {
            let label = k.label(3);
            assert_eq!(SpanKind::parse(&label), Some(k), "label {label}");
        }
        assert_eq!(SpanKind::Retry.label(2), "retry[2]");
        assert_eq!(SpanKind::parse("retry[11]"), Some(SpanKind::Retry));
        assert_eq!(SpanKind::parse("nope"), None);
    }

    #[test]
    fn attribution_finds_the_dominant_phase() {
        // 99 fast requests dominated by host_io, 1 slow one by gc_stall.
        let mut t = Tracer::in_memory(1);
        for id in 0..99u64 {
            let a = id as f64;
            t.begin_on(id, a, 0);
            t.mark(id, SpanKind::HostIo, a + 0.01);
            t.finish(id, a + 0.012, Outcome::Served);
        }
        t.begin_on(99, 100.0, 0);
        t.mark_drive(99, SpanKind::GcStall, 101.0, 0);
        t.mark_drive(99, SpanKind::FlashRead, 101.01, 0);
        t.finish(99, 101.02, Outcome::Served);
        let (reqs, _) = t.take_requests();
        verify_conservation(&reqs).unwrap();
        let bands = attribution(&reqs);
        let p99 = bands.iter().find(|b| b.band == "p99").unwrap();
        assert_eq!(p99.dominant().unwrap().0, "gc_stall");
        assert!(p99.share_of("gc_stall") > 0.9);
        let all = bands.iter().find(|b| b.band == "all").unwrap();
        assert_eq!(all.requests, 100);
        let table = attribution_table(&bands);
        assert!(table.render().contains("gc_stall"));
    }

    #[test]
    fn chrome_export_passes_schema_check() {
        let mut t = Tracer::in_memory(1);
        // Two overlapping requests on the same drive + a rack hop.
        for id in [0u64, 1] {
            let a = 0.1 * id as f64;
            t.begin_on(id, a, 0);
            t.mark(id, SpanKind::Admission, a + 0.05);
            t.mark_drive(id, SpanKind::FlashRead, a + 0.3, 0);
            t.mark_drive(id, SpanKind::IspCompute, a + 0.4, 0);
            t.mark(id, SpanKind::RackLink, a + 0.45);
            t.finish(id, a + 0.45, Outcome::Served);
        }
        let (reqs, _) = t.take_requests();
        let j = chrome_trace(&reqs);
        check_chrome(&j).unwrap();
        // Round-trip through the codec: serialize, reparse, recheck.
        let text = j.to_pretty();
        let back = Json::parse(&text).unwrap();
        check_chrome(&back).unwrap();
        let evs = back.get("traceEvents").unwrap().as_arr().unwrap();
        assert!(evs.iter().any(|e| e.get("ph").unwrap().as_str() == Some("M")));
        assert!(evs.iter().any(|e| e.get("ph").unwrap().as_str() == Some("B")));
    }

    #[test]
    fn chrome_check_rejects_broken_traces() {
        let bad = Json::parse(
            r#"{"traceEvents":[
                {"name":"a","ph":"B","ts":2,"pid":0,"tid":1},
                {"name":"a","ph":"E","ts":1,"pid":0,"tid":1}
            ]}"#,
        )
        .unwrap();
        assert!(check_chrome(&bad).is_err()); // non-monotone ts
        let unclosed = Json::parse(
            r#"{"traceEvents":[{"name":"a","ph":"B","ts":1,"pid":0,"tid":1}]}"#,
        )
        .unwrap();
        assert!(check_chrome(&unclosed).is_err());
    }

    #[test]
    fn jsonl_round_trips_bitwise() {
        let mut t = Tracer::in_memory(1);
        t.begin_on(0, 0.1, 1);
        t.mark(0, SpanKind::Admission, 0.1 + 1e-9);
        t.mark_drive(0, SpanKind::FlashRead, 0.30000000001, 2);
        t.finish(0, 0.5, Outcome::Served);
        t.begin_on(1, 0.2, 1);
        t.finish(1, 0.2, Outcome::Shed);
        let (reqs, _) = t.take_requests();
        let text = to_jsonl(&reqs);
        let back = parse_jsonl(&text).unwrap();
        assert_eq!(back.len(), reqs.len());
        for (a, b) in reqs.iter().zip(back.iter()) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.server, b.server);
            assert_eq!(a.outcome, b.outcome);
            assert_eq!(a.arrival.to_bits(), b.arrival.to_bits());
            assert_eq!(a.done.to_bits(), b.done.to_bits());
            assert_eq!(a.phases.len(), b.phases.len());
            for (p, q) in a.phases.iter().zip(b.phases.iter()) {
                assert_eq!(p.kind, q.kind);
                assert_eq!(p.drive, q.drive);
                assert_eq!(p.attempt, q.attempt);
                assert_eq!(p.dur.to_bits(), q.dur.to_bits());
            }
        }
        verify_conservation(&back).unwrap();
    }

    #[test]
    fn trace_config_validates_and_builds() {
        let mut c = TraceConfig::default();
        assert!(c.validate().is_ok());
        assert!(!c.tracer().is_on());
        c.enabled = true;
        c.sample_every = 3;
        assert!(c.tracer().is_on());
        assert!(c.tracer().wants(6));
        assert!(!c.tracer().wants(7));
        c.ring_cap = 10;
        assert!(matches!(c.tracer(), Tracer::On(_)));
        c.sample_every = 0;
        assert!(c.validate().is_err());
        assert_eq!(TraceFormat::parse("chrome"), Some(TraceFormat::Chrome));
        assert_eq!(TraceFormat::parse("bogus"), None);
    }

    #[test]
    fn profile_absorb_sums_and_maxes() {
        let mut a = EngineProfile {
            events: 10,
            wake_events: 2,
            queue_depth_sum: 30,
            max_queue_depth: 5,
            ..EngineProfile::default()
        };
        let b = EngineProfile {
            events: 5,
            wake_events: 1,
            queue_depth_sum: 5,
            max_queue_depth: 9,
            ..EngineProfile::default()
        };
        a.absorb(&b);
        assert_eq!(a.events, 15);
        assert_eq!(a.max_queue_depth, 9);
        assert!((a.mean_queue_depth() - 35.0 / 15.0).abs() < 1e-12);
    }
}
