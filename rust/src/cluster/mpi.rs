//! MPI-like rank messaging ("This scheduler is MPI-based", §IV-A).
//!
//! A tiny typed point-to-point layer over `std::sync::mpsc` used by the
//! *live* execution mode ([`crate::sched::live`]): rank 0 is the
//! scheduler/host, ranks 1..n are ISP workers. Payloads are raw bytes —
//! the codec helpers below serialize the f32 weight tensors the workers
//! need, mirroring how the paper's scheduler ships only small control
//! messages while bulk data stays put.

use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::time::Duration;

/// Message tags (MPI-style).
pub mod tag {
    pub const WEIGHTS: u32 = 1;
    pub const BATCH: u32 = 2;
    pub const RESULT: u32 = 3;
    pub const SHUTDOWN: u32 = 4;
    /// A worker failed; payload is the error text. Lets the coordinator
    /// fail fast instead of waiting forever for a RESULT that will
    /// never come.
    pub const ERROR: u32 = 5;
}

/// A delivered packet.
#[derive(Debug)]
pub struct Packet {
    pub src: usize,
    pub tag: u32,
    pub payload: Vec<u8>,
}

/// One rank's endpoint.
pub struct Communicator {
    rank: usize,
    txs: Vec<Sender<Packet>>,
    rx: Receiver<Packet>,
    sent: u64,
    received: u64,
}

/// Build a fully-connected group of `size` ranks.
pub fn group(size: usize) -> Vec<Communicator> {
    assert!(size > 0);
    let mut txs = Vec::with_capacity(size);
    let mut rxs = Vec::with_capacity(size);
    for _ in 0..size {
        let (tx, rx) = channel();
        txs.push(tx);
        rxs.push(rx);
    }
    rxs.into_iter()
        .enumerate()
        .map(|(rank, rx)| Communicator {
            rank,
            txs: txs.clone(),
            rx,
            sent: 0,
            received: 0,
        })
        .collect()
}

/// Send/receive errors.
#[derive(Debug, PartialEq, Eq)]
pub enum MpiError {
    BadRank(usize),
    Disconnected,
    Timeout,
}

impl std::fmt::Display for MpiError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            MpiError::BadRank(r) => write!(f, "rank {r} out of range"),
            MpiError::Disconnected => write!(f, "peer disconnected"),
            MpiError::Timeout => write!(f, "recv timed out"),
        }
    }
}

impl std::error::Error for MpiError {}

impl Communicator {
    pub fn rank(&self) -> usize {
        self.rank
    }

    pub fn size(&self) -> usize {
        self.txs.len()
    }

    pub fn send(&mut self, dst: usize, tag: u32, payload: Vec<u8>) -> Result<(), MpiError> {
        let tx = self.txs.get(dst).ok_or(MpiError::BadRank(dst))?;
        tx.send(Packet { src: self.rank, tag, payload })
            .map_err(|_| MpiError::Disconnected)?;
        self.sent += 1;
        Ok(())
    }

    /// Blocking receive.
    pub fn recv(&mut self) -> Result<Packet, MpiError> {
        let p = self.rx.recv().map_err(|_| MpiError::Disconnected)?;
        self.received += 1;
        Ok(p)
    }

    /// Receive with a timeout — the scheduler's 0.2 s polling loop uses
    /// this instead of busy-waiting (the paper: "wakes up every 0.2
    /// seconds to check if there is a new message").
    pub fn recv_timeout(&mut self, dur: Duration) -> Result<Packet, MpiError> {
        match self.rx.recv_timeout(dur) {
            Ok(p) => {
                self.received += 1;
                Ok(p)
            }
            Err(RecvTimeoutError::Timeout) => Err(MpiError::Timeout),
            Err(RecvTimeoutError::Disconnected) => Err(MpiError::Disconnected),
        }
    }

    /// Non-blocking receive: pop one queued packet if any is waiting.
    /// An empty queue maps to [`MpiError::Timeout`] (a zero-length
    /// timeout), so callers drain with the same error handling as the
    /// polling path. The event-driven live scheduler uses this to
    /// re-arm every worker whose RESULT is already queued without
    /// waiting out the polling grid.
    pub fn try_recv(&mut self) -> Result<Packet, MpiError> {
        match self.rx.try_recv() {
            Ok(p) => {
                self.received += 1;
                Ok(p)
            }
            Err(TryRecvError::Empty) => Err(MpiError::Timeout),
            Err(TryRecvError::Disconnected) => Err(MpiError::Disconnected),
        }
    }

    /// Broadcast from this rank to every other rank.
    pub fn bcast(&mut self, tag: u32, payload: &[u8]) -> Result<(), MpiError> {
        for dst in 0..self.size() {
            if dst != self.rank {
                self.send(dst, tag, payload.to_vec())?;
            }
        }
        Ok(())
    }

    pub fn stats(&self) -> (u64, u64) {
        (self.sent, self.received)
    }
}

// ---------------------------------------------------------------------
// Payload codecs (no serde offline — explicit LE byte layouts)
// ---------------------------------------------------------------------

/// Encode an f32 slice (LE).
pub fn encode_f32s(xs: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(4 * xs.len());
    for x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

/// Decode an f32 slice (LE); errors on misaligned length.
pub fn decode_f32s(buf: &[u8]) -> Result<Vec<f32>, MpiError> {
    if buf.len() % 4 != 0 {
        return Err(MpiError::Disconnected);
    }
    Ok(buf
        .chunks_exact(4)
        // solana-lint: allow(no-unwrap, reason = "chunks_exact(4) yields exactly 4-byte slices; the length check above rejects ragged input")
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

/// Encode a u32 slice (LE) — batch index lists.
pub fn encode_u32s(xs: &[u32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(4 * xs.len());
    for x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

pub fn decode_u32s(buf: &[u8]) -> Result<Vec<u32>, MpiError> {
    if buf.len() % 4 != 0 {
        return Err(MpiError::Disconnected);
    }
    Ok(buf
        .chunks_exact(4)
        // solana-lint: allow(no-unwrap, reason = "chunks_exact(4) yields exactly 4-byte slices; the length check above rejects ragged input")
        .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn point_to_point_across_threads() {
        let mut comms = group(3);
        let mut c2 = comms.pop().unwrap();
        let mut c1 = comms.pop().unwrap();
        let mut c0 = comms.pop().unwrap();
        let t1 = std::thread::spawn(move || {
            let p = c1.recv().unwrap();
            assert_eq!(p.src, 0);
            assert_eq!(p.tag, tag::BATCH);
            c1.send(0, tag::RESULT, p.payload).unwrap();
        });
        let t2 = std::thread::spawn(move || {
            let p = c2.recv().unwrap();
            c2.send(0, tag::RESULT, p.payload).unwrap();
        });
        c0.send(1, tag::BATCH, vec![1, 2, 3]).unwrap();
        c0.send(2, tag::BATCH, vec![4, 5]).unwrap();
        let mut totals = 0usize;
        for _ in 0..2 {
            let p = c0.recv().unwrap();
            assert_eq!(p.tag, tag::RESULT);
            totals += p.payload.len();
        }
        assert_eq!(totals, 5);
        t1.join().unwrap();
        t2.join().unwrap();
        assert_eq!(c0.stats(), (2, 2));
    }

    #[test]
    fn timeout_polling() {
        let mut comms = group(2);
        let mut c0 = comms.remove(0);
        assert_eq!(
            c0.recv_timeout(Duration::from_millis(10)).unwrap_err(),
            MpiError::Timeout
        );
    }

    #[test]
    fn try_recv_drains_without_blocking() {
        let mut comms = group(2);
        let mut c1 = comms.pop().unwrap();
        let mut c0 = comms.pop().unwrap();
        assert_eq!(c0.try_recv().unwrap_err(), MpiError::Timeout);
        c1.send(0, tag::RESULT, vec![7]).unwrap();
        c1.send(0, tag::RESULT, vec![8]).unwrap();
        assert_eq!(c0.try_recv().unwrap().payload, vec![7]);
        assert_eq!(c0.try_recv().unwrap().payload, vec![8]);
        assert_eq!(c0.try_recv().unwrap_err(), MpiError::Timeout);
        assert_eq!(c0.stats(), (0, 2));
    }

    #[test]
    fn bcast_reaches_all() {
        let mut comms = group(4);
        let mut rest: Vec<_> = comms.drain(1..).collect();
        let mut c0 = comms.pop().unwrap();
        c0.bcast(tag::WEIGHTS, &[9, 9]).unwrap();
        for c in rest.iter_mut() {
            let p = c.recv().unwrap();
            assert_eq!(p.tag, tag::WEIGHTS);
            assert_eq!(p.payload, vec![9, 9]);
        }
    }

    #[test]
    fn bad_rank_rejected() {
        let mut comms = group(1);
        let mut c0 = comms.pop().unwrap();
        assert_eq!(c0.send(5, 0, vec![]).unwrap_err(), MpiError::BadRank(5));
    }

    #[test]
    fn codecs_roundtrip() {
        let f = vec![1.5f32, -2.25, 0.0, f32::MAX];
        assert_eq!(decode_f32s(&encode_f32s(&f)).unwrap(), f);
        let u = vec![0u32, 7, u32::MAX];
        assert_eq!(decode_u32s(&encode_u32s(&u)).unwrap(), u);
        assert!(decode_f32s(&[1, 2, 3]).is_err());
    }
}
