//! Self-tests for solana-lint (ISSUE-7 satellite): every rule has
//! positive and negative fixtures, a meta-test asserts each rule has at
//! least one firing fixture, and a tree-wide run asserts the real
//! source tree has zero unsuppressed findings at HEAD.

use std::path::{Path, PathBuf};
use std::process::Command;

use solana_lint::{scan_file, scan_tree, Report, RULES};

fn fixture_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures")
}

/// Scan a single fixture, preserving the fixture-relative path so the
/// path-scoped rules (rng-gate, join-reduce) see the right components.
fn scan_fixture(rel: &str) -> Report {
    scan_file(&fixture_root().join(rel), rel).expect("fixture readable")
}

fn rules_of(report: &Report) -> Vec<&'static str> {
    report.findings.iter().map(|f| f.rule).collect()
}

#[test]
fn hash_iter_fires_on_both_iteration_forms() {
    let r = scan_fixture("hash_iter/fire.rs");
    assert_eq!(rules_of(&r), ["hash-iter", "hash-iter"], "{:?}", r.findings);
    assert!(r.findings[0].msg.contains("counts.values()"));
    assert!(r.findings[1].msg.contains("`counts`"));
}

#[test]
fn hash_iter_allows_keyed_lookup_and_btreemap() {
    let r = scan_fixture("hash_iter/clean.rs");
    assert!(r.findings.is_empty(), "{:?}", r.findings);
}

#[test]
fn wall_clock_fires_on_both_clock_types() {
    let r = scan_fixture("wall_clock/fire.rs");
    assert_eq!(rules_of(&r), ["wall-clock", "wall-clock"], "{:?}", r.findings);
}

#[test]
fn wall_clock_marker_suppresses() {
    let r = scan_fixture("wall_clock/suppressed.rs");
    assert!(r.findings.is_empty(), "{:?}", r.findings);
    assert_eq!(r.suppressed, 1);
}

#[test]
fn rng_gate_fires_on_ungated_draw_in_traffic() {
    let r = scan_fixture("rng_gate/traffic/fire.rs");
    assert_eq!(rules_of(&r), ["rng-gate"], "{:?}", r.findings);
}

#[test]
fn rng_gate_accepts_guarded_draws() {
    let r = scan_fixture("rng_gate/traffic/clean.rs");
    assert!(r.findings.is_empty(), "{:?}", r.findings);
}

#[test]
fn rng_gate_is_path_scoped() {
    let r = scan_fixture("rng_gate/sim/out_of_scope.rs");
    assert!(r.findings.is_empty(), "{:?}", r.findings);
}

#[test]
fn rng_gate_allow_file_suppresses() {
    let r = scan_fixture("rng_gate/faults/suppressed.rs");
    assert!(r.findings.is_empty(), "{:?}", r.findings);
    assert_eq!(r.suppressed, 1);
}

#[test]
fn no_unwrap_fires_on_unwrap_expect_and_panic() {
    let r = scan_fixture("no_unwrap/fire.rs");
    assert_eq!(
        rules_of(&r),
        ["no-unwrap", "no-unwrap", "no-unwrap"],
        "{:?}",
        r.findings
    );
}

#[test]
fn no_unwrap_skips_test_code() {
    let r = scan_fixture("no_unwrap/clean_tests.rs");
    assert!(r.findings.is_empty(), "{:?}", r.findings);
}

#[test]
fn no_unwrap_marker_suppresses() {
    let r = scan_fixture("no_unwrap/suppressed.rs");
    assert!(r.findings.is_empty(), "{:?}", r.findings);
    assert_eq!(r.suppressed, 1);
}

#[test]
fn lossy_cast_fires_on_counter_narrowing() {
    let r = scan_fixture("lossy_cast/fire.rs");
    assert_eq!(
        rules_of(&r),
        ["lossy-cast", "lossy-cast", "lossy-cast"],
        "{:?}",
        r.findings
    );
}

#[test]
fn lossy_cast_allows_widening_and_non_counters() {
    let r = scan_fixture("lossy_cast/clean.rs");
    assert!(r.findings.is_empty(), "{:?}", r.findings);
}

#[test]
fn join_reduce_fires_on_spawn_outside_pool() {
    let r = scan_fixture("join_reduce/fire.rs");
    assert_eq!(rules_of(&r), ["join-reduce"], "{:?}", r.findings);
}

#[test]
fn join_reduce_exempts_exp_pool_and_tests() {
    let pool = scan_fixture("join_reduce/exp/pool.rs");
    assert!(pool.findings.is_empty(), "{:?}", pool.findings);
    let tests = scan_fixture("join_reduce/clean_tests.rs");
    assert!(tests.findings.is_empty(), "{:?}", tests.findings);
}

#[test]
fn bad_markers_are_findings() {
    let missing = scan_fixture("bad_marker/fire_missing_reason.rs");
    assert_eq!(
        rules_of(&missing),
        ["no-unwrap", "bad-marker"],
        "{:?}",
        missing.findings
    );
    let unknown = scan_fixture("bad_marker/fire_unknown_rule.rs");
    assert_eq!(rules_of(&unknown), ["bad-marker"], "{:?}", unknown.findings);
    let unparseable = scan_fixture("bad_marker/fire_unparseable.rs");
    assert_eq!(
        rules_of(&unparseable),
        ["bad-marker"],
        "{:?}",
        unparseable.findings
    );
}

#[test]
fn trace_shaped_code_is_covered_by_wall_clock_and_hash_iter() {
    // ISSUE-9: a span tracer's two likeliest determinism sins — wall
    // clocks for timestamps and a hash-ordered span-map drain — both
    // fire on the tracer-shaped positive fixture...
    let r = scan_fixture("trace/fire.rs");
    assert_eq!(
        rules_of(&r),
        ["wall-clock", "wall-clock", "hash-iter"],
        "{:?}",
        r.findings
    );
}

#[test]
fn trace_sanctioned_shape_is_clean() {
    // ...and the sanctioned shape (sim-time f64 stamps, BTreeMap span
    // store) produces no findings at all.
    let r = scan_fixture("trace/clean.rs");
    assert!(r.findings.is_empty(), "{:?}", r.findings);
}

/// Meta-test: every rule (and the bad-marker meta-rule) has at least
/// one firing fixture in the corpus — a rule whose positive case stops
/// firing has silently died.
#[test]
fn every_rule_has_a_firing_fixture() {
    let all = scan_tree(&fixture_root()).expect("fixture tree readable");
    for rule in RULES.iter().chain(["bad-marker"].iter()) {
        assert!(
            all.findings.iter().any(|f| f.rule == *rule),
            "no firing fixture for rule '{rule}'"
        );
    }
}

/// The acceptance gate: the real source tree is clean at HEAD — zero
/// unsuppressed findings — and the suppressions that keep it clean are
/// actually being parsed (suppressed > 0).
#[test]
fn source_tree_has_zero_unsuppressed_findings() {
    let src = Path::new(env!("CARGO_MANIFEST_DIR")).join("../../src");
    let report = scan_tree(&src).expect("rust/src readable");
    let rendered: Vec<String> = report
        .findings
        .iter()
        .map(|f| format!("{}:{}:{}: [{}] {}", f.file, f.line, f.col, f.rule, f.msg))
        .collect();
    assert!(
        report.findings.is_empty(),
        "rust/src has unsuppressed lint findings:\n{}",
        rendered.join("\n")
    );
    assert!(
        report.suppressed > 0,
        "expected at least one reasoned suppression in rust/src"
    );
}

/// The CLI contract CI relies on: non-zero exit on a positive fixture
/// under --deny all, zero exit on a clean one, and JSON output carries
/// the rule names.
#[test]
fn binary_exit_codes_and_json() {
    let bin = env!("CARGO_BIN_EXE_solana-lint");
    let fire = fixture_root().join("no_unwrap/fire.rs");
    let clean = fixture_root().join("hash_iter/clean.rs");

    let out = Command::new(bin)
        .args(["--deny", "all"])
        .arg(&fire)
        .output()
        .expect("run solana-lint");
    assert_eq!(out.status.code(), Some(1), "positive fixture must deny");

    let out = Command::new(bin)
        .args(["--deny", "all"])
        .arg(&clean)
        .output()
        .expect("run solana-lint");
    assert_eq!(out.status.code(), Some(0), "clean fixture must pass");

    let out = Command::new(bin)
        .args(["--json"])
        .arg(&fire)
        .output()
        .expect("run solana-lint");
    let stdout = String::from_utf8_lossy(&out.stdout).to_string();
    assert!(stdout.contains("\"rule\": \"no-unwrap\""), "{stdout}");
    assert!(stdout.contains("\"suppressed\": 0"), "{stdout}");
    // --json without --deny is advisory: findings reported, exit 0.
    assert_eq!(out.status.code(), Some(0));
}
