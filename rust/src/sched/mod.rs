//! The paper's distributed scheduler (§IV-A) and its simulation runner.
//!
//! Mechanism (faithful to the paper):
//!
//! * **pull-based**: each node (host, CSD ISPs) sends an *ack* when its
//!   current batch finishes, which doubles as the request for the next
//!   one;
//! * **polling loop**: the scheduler thread wakes every 0.2 s, drains
//!   pending acks, and dispatches new batches — sleeping between wakes
//!   releases the host CPU (the paper's stated reason for the design);
//! * **index-only dispatch**: because host and ISP mount the same OCFS2
//!   partition, the scheduler ships only item *indexes* over the TCP/IP
//!   tunnel; data moves over the fast paths (PCIe for the host,
//!   intra-chip DMA for the ISP);
//! * **batch ratio**: the host gets `ratio ×` the CSD batch size to match
//!   its Xeon-vs-A53 speed advantage (§IV-A: "ranging from 20 to 30");
//!   any other ratio under-utilizes one side (ablation A1).
//!
//! The runner executes this protocol in virtual time against the full
//! device models in [`crate::cluster`] and reports the quantities the
//! paper's figures plot.

pub mod live;
pub mod locality;

use crate::cluster::StorageServer;
use crate::csd::CsdConfig;
use crate::metrics::Metrics;
use crate::power::PowerModel;
use crate::sim::EventQueue;
use crate::workloads::{AppModel, HOST_THREADS, ISP_CORES};

/// Scheduler configuration for one run.
#[derive(Clone, Debug)]
pub struct SchedConfig {
    /// Items per CSD batch (the paper's "batch size").
    pub csd_batch: u64,
    /// Host batch = `ratio × csd_batch` (the paper's "batch ratio").
    pub batch_ratio: f64,
    /// Scheduler polling period (paper: 0.2 s).
    pub wakeup_secs: f64,
    /// Populated drive bays (data is striped over all of them).
    pub drives: usize,
    /// How many of those drives have their ISP engine engaged
    /// (Fig 5's x-axis). `0` = the paper's baseline: CSDs act as
    /// storage only.
    pub isp_drives: usize,
    /// Host participates in compute (always true in the paper).
    pub use_host: bool,
    /// Fair-share tail shrinking (our improvement over the paper's
    /// scheduler): near the end of the run the host's batch shrinks to
    /// its fair share so host and CSDs finish together. Disable to get
    /// the paper's plain behaviour (ablation A1 shows the difference).
    pub fair_tail: bool,
    /// Deterministic seed (shard layout etc.).
    pub seed: u64,
}

impl Default for SchedConfig {
    fn default() -> Self {
        SchedConfig {
            csd_batch: 6,
            batch_ratio: 20.0,
            wakeup_secs: 0.2,
            drives: 36,
            isp_drives: 36,
            use_host: true,
            fair_tail: true,
            seed: 42,
        }
    }
}

impl SchedConfig {
    /// The host-only baseline the paper compares against (drives
    /// populated, every ISP disabled).
    pub fn baseline(drives: usize) -> SchedConfig {
        SchedConfig { isp_drives: 0, drives, ..SchedConfig::default() }
    }

    pub fn use_isp(&self) -> bool {
        self.isp_drives > 0
    }

    pub fn host_batch(&self) -> u64 {
        ((self.csd_batch as f64 * self.batch_ratio).round() as u64).max(1)
    }
}

/// Everything a run produces; feeds every figure/table in the paper.
#[derive(Clone, Debug)]
pub struct RunReport {
    pub app: &'static str,
    pub total_items: u64,
    pub makespan_secs: f64,
    pub items_per_sec: f64,
    /// Speech reports words/s (items/s × words per item).
    pub words_per_sec: f64,
    pub host_items: u64,
    pub csd_items: u64,
    /// Bytes that crossed PCIe into host memory.
    pub pcie_bytes: u64,
    /// Bytes served to ISP engines without leaving the drives.
    pub isp_bytes: u64,
    /// Result/ack/dispatch traffic over the tunnels.
    pub tunnel_messages: u64,
    pub energy_j: f64,
    pub avg_power_w: f64,
    pub energy_per_item_j: f64,
    pub host_busy_secs: f64,
    pub isp_busy_secs: f64,
    /// Mean batch latency (dispatch → ack), seconds.
    pub mean_batch_latency: f64,
    pub host_batches: u64,
    pub csd_batches: u64,
}

impl RunReport {
    /// Fraction of input data processed in storage (Table I's
    /// "data processed in CSDs").
    pub fn csd_data_fraction(&self) -> f64 {
        if self.total_items == 0 {
            return 0.0;
        }
        self.csd_items as f64 / self.total_items as f64
    }
}

#[derive(Clone, Copy, Debug)]
enum Ev {
    /// Scheduler polling wake.
    Wake,
    /// Host finished its batch (local ack).
    HostDone { items: u64, dispatched: f64 },
    /// CSD ack delivered over the tunnel.
    CsdAck { drive: usize, items: u64, dispatched: f64 },
}

/// Simulated dataset shard name on each drive.
const SHARD: &str = "shard.dat";

/// Run one benchmark under the scheduler; returns the report.
///
/// `server` should be freshly built; this function ingests the dataset
/// shards, runs the full protocol in virtual time, and reads the
/// counters back out of the device models.
pub fn run(
    model: &AppModel,
    cfg: &SchedConfig,
    power: &PowerModel,
    metrics: &mut Metrics,
) -> anyhow::Result<RunReport> {
    anyhow::ensure!(cfg.drives > 0, "need at least one drive for data");
    anyhow::ensure!(cfg.isp_drives <= cfg.drives, "isp_drives exceeds drives");
    anyhow::ensure!(cfg.use_host || cfg.use_isp(), "no compute nodes enabled");
    let mut server = StorageServer::new(cfg.drives, CsdConfig::default());

    // ---- ingest: stripe the dataset across drives --------------------
    let items_per_drive = crate::util::div_ceil(model.items, cfg.drives as u64);
    let mut shard_remaining: Vec<u64> = Vec::with_capacity(cfg.drives);
    let mut shard_offset: Vec<u64> = vec![0; cfg.drives];
    let mut assigned = model.items;
    let mut ingest_done = 0.0f64;
    for d in 0..cfg.drives {
        let n = assigned.min(items_per_drive);
        assigned -= n;
        shard_remaining.push(n);
        let bytes = (n * model.bytes_per_item).max(1);
        ingest_done = ingest_done.max(server.ingest(0.0, d, SHARD, bytes)?);
    }
    debug_assert_eq!(assigned, 0);
    // The benchmark clock starts after the dataset is resident (the paper
    // measures steady-state processing, not ingest).
    let t0 = ingest_done;

    // ---- event loop ---------------------------------------------------
    let mut q: EventQueue<Ev> = EventQueue::new();
    q.schedule_at(t0, Ev::Wake);

    let mut host_idle = true;
    let mut csd_idle = vec![true; cfg.drives];
    let mut host_items = 0u64;
    let mut csd_items = 0u64;
    let mut host_busy_secs = 0.0f64;
    let mut isp_busy_secs = 0.0f64;
    let mut host_batches = 0u64;
    let mut csd_batches = 0u64;
    let mut last_completion = t0;
    let mut latency_sum = 0.0f64;
    let mut latency_n = 0u64;

    let host_batch_target = cfg.host_batch();

    while let Some((now, ev)) = q.pop() {
        match ev {
            Ev::HostDone { items, dispatched } => {
                host_idle = true;
                host_items += items;
                last_completion = now;
                latency_sum += now - dispatched;
                latency_n += 1;
                metrics.observe("sched.host_batch_latency", now - dispatched);
            }
            Ev::CsdAck { drive, items, dispatched } => {
                csd_idle[drive] = true;
                csd_items += items;
                last_completion = now;
                latency_sum += now - dispatched;
                latency_n += 1;
                metrics.observe("sched.csd_batch_latency", now - dispatched);
            }
            Ev::Wake => {
                // ---- dispatch to the host --------------------------------
                let total_remaining: u64 = shard_remaining.iter().sum();
                if cfg.use_host && host_idle && total_remaining > 0 {
                    // Near the end of the run the host's batch shrinks to
                    // its *fair share* of what's left, so host and CSDs
                    // drain together instead of leaving a long CSD tail.
                    let fair = if cfg.use_isp() && cfg.fair_tail {
                        let host_rate = HOST_THREADS / model.host_item_secs;
                        let csd_rate = cfg.isp_drives as f64 * ISP_CORES / model.csd_item_secs;
                        ((total_remaining as f64 * host_rate / (host_rate + csd_rate)).ceil()
                            as u64)
                            .max(1)
                    } else {
                        total_remaining
                    };
                    let take = host_batch_target.min(total_remaining).min(fair);
                    // Proportional take across shards: every drive's shard
                    // drains at the same fractional rate, keeping each
                    // CSD's local work alive (an ISP can only process
                    // items on its own flash). On ISP drives the host
                    // additionally leaves one CSD batch in reserve; the
                    // reservation lapses when the host would otherwise
                    // idle (pass 1).
                    let mut left = take;
                    let mut io_done = now;
                    for pass in 0..2 {
                        for d in 0..cfg.drives {
                            if left == 0 {
                                break;
                            }
                            let avail = shard_remaining[d];
                            let cap = if pass == 0 && d < cfg.isp_drives {
                                avail.saturating_sub(cfg.csd_batch)
                            } else {
                                avail
                            };
                            let share = if pass == 0 {
                                crate::util::div_ceil(
                                    take * avail,
                                    total_remaining.max(1),
                                )
                            } else {
                                left
                            };
                            let n = left.min(cap).min(share);
                            if n == 0 {
                                continue;
                            }
                            let bytes = n * model.bytes_per_item;
                            let r = server.host_read(now, d, SHARD, shard_offset[d], bytes)?;
                            shard_offset[d] += bytes;
                            shard_remaining[d] -= n;
                            left -= n;
                            io_done = io_done.max(r.done);
                        }
                        // Second pass (ignores reservations) only when the
                        // host would otherwise sit completely idle.
                        if left < take || !cfg.use_isp() {
                            break;
                        }
                    }
                    let taken = take - left;
                    if taken > 0 {
                        let compute = model.host_batch_overhead
                            + taken as f64 * model.host_item_secs / HOST_THREADS;
                        let done = io_done + compute;
                        host_busy_secs += done - now;
                        host_idle = false;
                        host_batches += 1;
                        q.schedule_at(done, Ev::HostDone { items: taken, dispatched: now });
                    }
                }
                // ---- dispatch to each idle CSD ---------------------------
                if cfg.use_isp() {
                    for d in 0..cfg.isp_drives {
                        if !csd_idle[d] || shard_remaining[d] == 0 {
                            continue;
                        }
                        let n = cfg.csd_batch.min(shard_remaining[d]);
                        shard_remaining[d] -= n;
                        // dispatch message: header + the item indexes only
                        let delivered = server.send_to_isp(now, d, 64 + 8 * n);
                        let bytes = n * model.bytes_per_item;
                        let r = server.isp_read(delivered, d, SHARD, shard_offset[d], bytes)?;
                        shard_offset[d] += bytes;
                        let compute = model.csd_batch_overhead
                            + n as f64 * model.csd_item_secs / ISP_CORES;
                        let done = r.done + compute;
                        // result + ack back over the tunnel
                        let ack = server
                            .send_to_host(done, d, 64 + n * model.output_bytes_per_item);
                        isp_busy_secs += done - delivered;
                        csd_idle[d] = false;
                        csd_batches += 1;
                        q.schedule_at(ack, Ev::CsdAck { drive: d, items: n, dispatched: now });
                    }
                }
                // ---- keep polling while anything is outstanding ----------
                let work_left = shard_remaining.iter().any(|&r| r > 0);
                let busy = !host_idle || csd_idle.iter().any(|i| !*i);
                if work_left || busy {
                    q.schedule_at(now + cfg.wakeup_secs, Ev::Wake);
                }
            }
        }
    }

    // ---- conservation check -------------------------------------------
    let processed = host_items + csd_items;
    anyhow::ensure!(
        processed == model.items,
        "scheduler lost items: {processed} != {}",
        model.items
    );

    let makespan = (last_completion - t0).max(1e-9);
    let items_per_sec = model.items as f64 / makespan;
    let energy = power.energy(
        makespan,
        cfg.drives,
        host_busy_secs.min(makespan),
        isp_busy_secs,
    );

    // PCIe bytes after ingest: subtract what ingest itself pushed.
    let ingest_pcie: u64 = (0..cfg.drives)
        .map(|d| {
            let n = items_per_drive.min(model.items.saturating_sub(items_per_drive * d as u64));
            (n * model.bytes_per_item).max(1)
        })
        .sum();
    let pcie_total = server.total_pcie_bytes();
    let pcie_bytes = pcie_total.saturating_sub(ingest_pcie);
    let isp_bytes: u64 = server.bays.iter().map(|b| b.csd.fcu.io.isp_read_bytes).sum();

    metrics.inc("sched.items", model.items as f64);
    metrics.inc("sched.host_items", host_items as f64);
    metrics.inc("sched.csd_items", csd_items as f64);
    metrics.inc("io.pcie_bytes", pcie_bytes as f64);
    metrics.inc("io.isp_bytes", isp_bytes as f64);
    metrics.inc("energy.joules", energy.energy_j);

    Ok(RunReport {
        app: model.app.name(),
        total_items: model.items,
        makespan_secs: makespan,
        items_per_sec,
        words_per_sec: items_per_sec * model.words_per_item,
        host_items,
        csd_items,
        pcie_bytes,
        isp_bytes,
        tunnel_messages: server.total_tunnel_messages(),
        energy_j: energy.energy_j,
        avg_power_w: energy.avg_power_w,
        energy_per_item_j: energy.energy_j / model.items as f64,
        host_busy_secs,
        isp_busy_secs,
        mean_batch_latency: if latency_n > 0 { latency_sum / latency_n as f64 } else { 0.0 },
        host_batches,
        csd_batches,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::App;

    fn quick(model: AppModel, cfg: SchedConfig) -> RunReport {
        let mut m = Metrics::new();
        run(&model, &cfg, &PowerModel::default(), &mut m).unwrap()
    }

    #[test]
    fn conservation_host_only() {
        let r = quick(
            AppModel::sentiment(50_000),
            SchedConfig { isp_drives: 0, drives: 4, csd_batch: 5_000, ..Default::default() },
        );
        assert_eq!(r.host_items, 50_000);
        assert_eq!(r.csd_items, 0);
        assert_eq!(r.csd_batches, 0);
    }

    #[test]
    fn conservation_with_isp() {
        let r = quick(
            AppModel::sentiment(100_000),
            SchedConfig { drives: 8, isp_drives: 8, csd_batch: 2_000, batch_ratio: 26.0, ..Default::default() },
        );
        assert_eq!(r.host_items + r.csd_items, 100_000);
        assert!(r.csd_items > 0, "ISPs processed something");
        assert!(r.host_items > r.csd_items, "host is much faster");
    }

    #[test]
    fn isp_speedup_over_baseline() {
        // Full LJ-sized corpus, paper's Fig 5(a) best configuration.
        let base = quick(AppModel::speech(13_100), SchedConfig::baseline(36));
        let isp = quick(
            AppModel::speech(13_100),
            SchedConfig { csd_batch: 6, batch_ratio: 20.0, drives: 36, ..Default::default() },
        );
        let speedup = isp.words_per_sec / base.words_per_sec;
        assert!(
            (2.6..3.4).contains(&speedup),
            "paper: ~3.1x (296 vs 96 w/s); got {speedup:.2} ({:.1} vs {:.1} w/s)",
            isp.words_per_sec,
            base.words_per_sec
        );
        // absolute rates in the paper's ballpark
        assert!((250.0..320.0).contains(&isp.words_per_sec));
        assert!((90.0..110.0).contains(&base.words_per_sec));
    }

    #[test]
    fn isp_path_reduces_pcie_traffic() {
        let base = quick(AppModel::speech(1_310), SchedConfig::baseline(12));
        let isp = quick(
            AppModel::speech(1_310),
            SchedConfig { drives: 12, isp_drives: 12, csd_batch: 6, ..Default::default() },
        );
        assert!(isp.pcie_bytes < base.pcie_bytes);
        assert!(isp.isp_bytes > 0);
        // baseline moves every byte over PCIe
        assert_eq!(base.pcie_bytes, 1_310 * 290_000);
    }

    #[test]
    fn energy_per_item_improves_with_isp() {
        let base = quick(AppModel::sentiment(200_000), SchedConfig::baseline(36));
        let isp = quick(
            AppModel::sentiment(200_000),
            SchedConfig { drives: 36, isp_drives: 36, csd_batch: 40_000, batch_ratio: 26.0, ..Default::default() },
        );
        assert!(
            isp.energy_per_item_j < base.energy_per_item_j * 0.7,
            "paper: ≥54% saving; got {} vs {}",
            isp.energy_per_item_j,
            base.energy_per_item_j
        );
    }

    #[test]
    fn zero_drives_rejected() {
        let mut m = Metrics::new();
        let cfg = SchedConfig { drives: 0, ..Default::default() };
        assert!(run(&AppModel::sentiment(10), &cfg, &PowerModel::default(), &mut m).is_err());
    }

    #[test]
    fn throughput_scales_with_drives() {
        let apps = [App::Sentiment];
        for app in apps {
            let items = 2_000_000;
            let mk = |drives| {
                quick(
                    AppModel::for_app(app, items),
                    SchedConfig {
                        drives,
                        isp_drives: drives,
                        csd_batch: 10_000,
                        batch_ratio: 26.0,
                        ..Default::default()
                    },
                )
            };
            let r9 = mk(9);
            let r36 = mk(36);
            assert!(
                r36.items_per_sec > r9.items_per_sec * 1.3,
                "{app:?}: 36 drives {} !> 9 drives {}",
                r36.items_per_sec,
                r9.items_per_sec
            );
        }
    }
}
