//! `solana` — CLI entrypoint for the Solana ISP reproduction.
//!
//! Subcommands are registered in [`solana_isp::exp`] (experiment drivers)
//! and dispatched here; run `solana help` for the list.

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match solana_isp::exp::dispatch(&args) {
        Ok(code) => std::process::exit(code),
        Err(e) => {
            eprintln!("error: {e:#}");
            std::process::exit(1);
        }
    }
}
