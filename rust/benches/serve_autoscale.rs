//! `cargo bench --bench serve_autoscale` — regenerates Fig 10: the
//! autoscaling study (minimum servers meeting the p99 SLO as offered
//! load grows, with goodput and per-request energy at the chosen
//! operating point; the ISSUE-5 tentpole). Serving runs use the control
//! plane as deployed — admission on, least-work balancing — so the
//! reported operating points are the ones a production fleet would run
//! at. See `traffic` for the control plane and `exp::fig10_autoscale`
//! for the sweep definition.
//!
//! Scale with `SOLANA_BENCH_FAST=1` (5%) or default 25% of the paper's
//! dataset sizes; the *shape* (all-CSD meeting the SLO with strictly
//! fewer servers than all-SSD at every load past one SSD server's
//! capacity) is scale-invariant.

use solana_isp::bench_support::Bencher;
use solana_isp::exp::{self, Scale};

fn main() -> anyhow::Result<()> {
    let scale = Scale::from_env();
    let table = exp::fig10_autoscale(scale)?;
    exp::emit(&table, "fig10")?;
    // Wall-time of regenerating the artifact (simulator throughput):
    let mut b = Bencher::new(0, if std::env::var("SOLANA_BENCH_FAST").is_ok() { 1 } else { 2 });
    b.bench("fig10_serve_autoscale", || {
        let t = exp::fig10_autoscale(scale).expect("rerun");
        t.rows.len() as u64
    });
    print!("{}", b.report());
    b.write_json("serve_autoscale")?;
    Ok(())
}
