//! The storage server: host CPU + up to 36 CSD bays.
//!
//! Models the paper's testbed (§IV): an AIC FB128-LX 1U server with an
//! 8-core/16-thread Xeon Silver 4108 and 36 E1.S Solana drives. Each bay
//! couples a [`Csd`] with its NVMe/PCIe link, its TCP/IP tunnel endpoint,
//! and the OCFS2-style shared partition mounted by both the host and that
//! drive's ISP engine.
//!
//! This module provides *mechanics* (who moves which bytes over which
//! link, who burns which compute seconds); the batching policy lives in
//! [`crate::sched`].
//!
//! # Fleet layer (multi-server scale-out)
//!
//! One [`StorageServer`] is the paper's testbed; the paper's *deployment*
//! is a rack of them. The [`fleet`] submodule lifts the single-server
//! scheduler to N servers processing one sharded corpus:
//!
//! * [`fleet::FleetConfig`] describes the fleet — server count, a
//!   per-server [`crate::sched::SchedConfig`] template, the
//!   [`fleet::FleetShape`] (`all-csd`, the plain-SSD `all-ssd` baseline,
//!   or the survey-realistic `mixed` 50/50), and the top-of-rack
//!   [`crate::interconnect::RackLink`] parameters;
//! * the corpus is sharded across servers by storage capacity
//!   ([`fleet::shard_by_weight`], exact total conservation);
//! * each server runs [`crate::sched::run`] over its shard unchanged —
//!   a 1-server all-CSD fleet is bit-identical to a direct run
//!   (property-tested) — and the per-server reports roll up into a
//!   [`fleet::FleetReport`] after a rack-costed aggregation phase.
//!
//! Experiment Fig 8 ([`crate::exp::fig8_scaleout`], `solana fig8`,
//! `solana fleet`) sweeps 1→8 servers across all three apps and all
//! three shapes.

pub mod fleet;
pub mod mpi;

use crate::csd::{Csd, CsdConfig, IoRequester};
use crate::fs::{LockMode, Mount, SharedFs};
use crate::interconnect::{PcieLink, TcpTunnel};
use crate::sim::{Servers, SimTime};

/// Compute node identity.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum NodeId {
    Host,
    Csd(usize),
}

impl std::fmt::Display for NodeId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            NodeId::Host => write!(f, "host"),
            NodeId::Csd(i) => write!(f, "csd{i:02}"),
        }
    }
}

/// Host CPU model: Xeon Silver 4108, 8C/16T @ 2.1 GHz. Per-app service
/// times are calibrated at the *node* level (the paper's single-node
/// rates), so SMT effects are already folded in; we expose 16 hardware
/// threads.
pub struct HostCpu {
    pub threads: Servers,
}

impl Default for HostCpu {
    fn default() -> Self {
        HostCpu { threads: Servers::new(16) }
    }
}

impl HostCpu {
    pub fn new(threads: usize) -> HostCpu {
        HostCpu { threads: Servers::new(threads) }
    }

    /// Run a job of `work_secs` thread-seconds; returns completion time.
    pub fn run(&mut self, now: SimTime, work_secs: f64) -> SimTime {
        self.threads.acquire(now, work_secs)
    }

    pub fn busy_secs(&self) -> f64 {
        self.threads.busy_secs()
    }

    pub fn drain_time(&self) -> SimTime {
        self.threads.drain_time()
    }
}

/// One E1.S bay: drive + links + shared partition.
pub struct DriveBay {
    pub csd: Csd,
    pub pcie: PcieLink,
    pub tunnel: TcpTunnel,
    pub fs: SharedFs,
}

impl DriveBay {
    pub fn new(id: usize, cfg: &CsdConfig) -> DriveBay {
        let capacity = cfg.flash.capacity_bytes();
        DriveBay {
            csd: Csd::new(id, cfg.clone()),
            pcie: PcieLink::default(),
            tunnel: TcpTunnel::default(),
            fs: SharedFs::new(capacity, 4096),
        }
    }
}

/// The assembled server.
pub struct StorageServer {
    pub host: HostCpu,
    pub bays: Vec<DriveBay>,
    pub cfg: CsdConfig,
}

/// Outcome of a file read issued by a compute node.
#[derive(Clone, Copy, Debug)]
pub struct ReadOutcome {
    /// When the reader holds the bytes.
    pub done: SimTime,
    /// Bytes that crossed the PCIe link to the host (0 for ISP reads —
    /// the paper's headline data-transfer reduction).
    pub pcie_bytes: u64,
}

impl StorageServer {
    pub fn new(n_drives: usize, cfg: CsdConfig) -> StorageServer {
        let bays = (0..n_drives).map(|i| DriveBay::new(i, &cfg)).collect();
        StorageServer { host: HostCpu::default(), bays, cfg }
    }

    pub fn drives(&self) -> usize {
        self.bays.len()
    }

    /// Ingest a dataset file onto drive `d`'s shared partition (host
    /// writes through NVMe). Returns completion time.
    pub fn ingest(&mut self, now: SimTime, d: usize, name: &str, bytes: u64) -> anyhow::Result<SimTime> {
        let bay = &mut self.bays[d];
        bay.fs.create(name, bytes)?;
        let t_lock = bay.fs.lock(now, &mut bay.tunnel, name, Mount::Host, LockMode::Write)?;
        // Host pushes the data over PCIe, device programs flash.
        let runs = bay.fs.map_range(name, 0, bytes)?;
        let mut done = t_lock;
        for (dev_off, len) in runs {
            let dma = bay.pcie.dma(t_lock, len);
            done = done.max(bay.csd.write(dma.end, dev_off, len, IoRequester::Host));
        }
        Ok(done)
    }

    /// In-place update of an already-ingested file on drive `d` (the
    /// fig13 ingest/update stream): DLM write lock, PCIe DMA, flash
    /// program through the FTL — so foreground GC stalls land in the
    /// returned completion time. Unlike [`StorageServer::ingest`] the
    /// file must already exist; `offset`/`len` select the extent.
    pub fn update(
        &mut self,
        now: SimTime,
        d: usize,
        name: &str,
        offset: u64,
        len: u64,
    ) -> anyhow::Result<SimTime> {
        let bay = &mut self.bays[d];
        let t_lock = bay.fs.lock(now, &mut bay.tunnel, name, Mount::Host, LockMode::Write)?;
        let runs = bay.fs.map_range(name, offset, len)?;
        let mut done = t_lock;
        for (dev_off, run_len) in runs {
            let dma = bay.pcie.dma(t_lock, run_len);
            done = done.max(bay.csd.write(dma.end, dev_off, run_len, IoRequester::Host));
        }
        Ok(done)
    }

    /// Host reads `len` bytes of `name` on drive `d` (path "a"):
    /// DLM read lock, flash→DRAM staging, PCIe DMA to host memory.
    pub fn host_read(
        &mut self,
        now: SimTime,
        d: usize,
        name: &str,
        offset: u64,
        len: u64,
    ) -> anyhow::Result<ReadOutcome> {
        let bay = &mut self.bays[d];
        let t = bay.fs.lock(now, &mut bay.tunnel, name, Mount::Host, LockMode::Read)?;
        let runs = bay.fs.map_range(name, offset, len)?;
        let mut done = t;
        for (dev_off, run_len) in runs {
            let staged = bay.csd.host_read_staged(t, dev_off, run_len);
            let dma = bay.pcie.dma(staged.delivered, run_len);
            done = done.max(dma.end);
        }
        Ok(ReadOutcome { done, pcie_bytes: len })
    }

    /// The ISP on drive `d` reads `len` bytes of `name` (path "b"):
    /// DLM read lock, flash→DRAM→intra-chip DMA. No PCIe bytes.
    pub fn isp_read(
        &mut self,
        now: SimTime,
        d: usize,
        name: &str,
        offset: u64,
        len: u64,
    ) -> anyhow::Result<ReadOutcome> {
        let bay = &mut self.bays[d];
        let t = bay.fs.lock(now, &mut bay.tunnel, name, Mount::Isp, LockMode::Read)?;
        let runs = bay.fs.map_range(name, offset, len)?;
        let mut done = t;
        for (dev_off, run_len) in runs {
            let r = bay.csd.isp_read(t, dev_off, run_len);
            done = done.max(r.delivered);
        }
        Ok(ReadOutcome { done, pcie_bytes: 0 })
    }

    /// Send a control message from host to drive `d`'s ISP over the
    /// tunnel (scheduler dispatch); returns delivery time. Uses the
    /// fire-and-forget path: dispatch/ack times are computed ahead of the
    /// simulation cursor, so they must not reserve the pipe's FIFO
    /// horizon (see [`TcpTunnel::send_async`]).
    pub fn send_to_isp(&mut self, at: SimTime, d: usize, bytes: u64) -> SimTime {
        self.bays[d].tunnel.send_async(at, bytes)
    }

    /// Send a message from drive `d`'s ISP to the host (ack/result).
    pub fn send_to_host(&mut self, at: SimTime, d: usize, bytes: u64) -> SimTime {
        self.bays[d].tunnel.send_async(at, bytes)
    }

    /// Total bytes that crossed PCIe links (the paper's data-transfer
    /// metric).
    pub fn total_pcie_bytes(&self) -> u64 {
        self.bays.iter().map(|b| b.pcie.bytes_moved()).sum()
    }

    /// Total tunnel messages (scheduler + DLM traffic).
    pub fn total_tunnel_messages(&self) -> u64 {
        self.bays.iter().map(|b| b.tunnel.messages()).sum()
    }

    /// FTL statistics rolled up across all drive bays: summed counters
    /// plus the worst per-drive wear spread. Feeds `RunReport` /
    /// `ServeReport` (WAF, gc_runs, wear_spread).
    pub fn ftl_rollup(&self) -> (crate::csd::ftl::FtlStats, u32) {
        let mut total = crate::csd::ftl::FtlStats::default();
        let mut wear = 0u32;
        for b in &self.bays {
            total.absorb(&b.csd.fcu.ftl_stats());
            wear = wear.max(b.csd.fcu.ftl.wear_spread());
        }
        (total, wear)
    }

    /// Latest simulated time a GC pass on drive `d` runs until.
    /// Read-only tracer hook for `gc_stall` attribution — never used
    /// for scheduling.
    pub fn gc_busy_until(&self, d: usize) -> SimTime {
        self.bays[d].csd.fcu.ftl.gc_busy_until()
    }

    /// Cumulative ECC-engine busy seconds on drive `d`. The tracer
    /// snapshots this around a dispatch to carve the batch's `ecc`
    /// phase out of its flash/io span.
    pub fn ecc_busy_secs(&self, d: usize) -> f64 {
        self.bays[d].csd.fcu.busy_secs().2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn server(drives: usize) -> StorageServer {
        StorageServer::new(drives, CsdConfig::tiny())
    }

    #[test]
    fn ingest_then_host_read() {
        let mut s = server(1);
        let t = s.ingest(0.0, 0, "corpus", 64 * 1024).unwrap();
        assert!(t > 0.0);
        let r = s.host_read(t, 0, "corpus", 0, 64 * 1024).unwrap();
        assert!(r.done > t);
        assert_eq!(r.pcie_bytes, 64 * 1024);
        // ingest pushed the data over PCIe too
        assert_eq!(s.total_pcie_bytes(), 2 * 64 * 1024);
    }

    #[test]
    fn isp_read_moves_no_pcie_bytes() {
        let mut s = server(1);
        let t = s.ingest(0.0, 0, "corpus", 64 * 1024).unwrap();
        let before = s.total_pcie_bytes();
        let r = s.isp_read(t, 0, "corpus", 0, 64 * 1024).unwrap();
        assert_eq!(r.pcie_bytes, 0);
        assert_eq!(s.total_pcie_bytes(), before, "ISP path bypasses PCIe");
    }

    #[test]
    fn isp_read_faster_than_host_read_for_same_extent() {
        // The headline mechanism: path (b) skips FE + PCIe.
        let mut s = server(2);
        let t0 = s.ingest(0.0, 0, "x", 1 << 20).unwrap();
        let t1 = s.ingest(0.0, 1, "x", 1 << 20).unwrap();
        let t = t0.max(t1);
        let host = s.host_read(t, 0, "x", 0, 1 << 20).unwrap();
        let isp = s.isp_read(t, 1, "x", 0, 1 << 20).unwrap();
        let host_cost = host.done - t;
        let isp_cost = isp.done - t;
        assert!(
            isp_cost < host_cost,
            "isp {isp_cost} should beat host {host_cost}"
        );
    }

    #[test]
    fn drives_operate_in_parallel() {
        let mut s = server(4);
        let mut ingest_done = 0.0f64;
        for d in 0..4 {
            ingest_done = ingest_done.max(s.ingest(0.0, d, "x", 256 * 1024).unwrap());
        }
        // Reads on 4 drives at once finish ~when one drive would.
        let solo = {
            let mut s1 = server(1);
            let t = s1.ingest(0.0, 0, "x", 256 * 1024).unwrap();
            s1.isp_read(t, 0, "x", 0, 256 * 1024).unwrap().done - t
        };
        let mut max_done = 0.0f64;
        for d in 0..4 {
            let r = s.isp_read(ingest_done, d, "x", 0, 256 * 1024).unwrap();
            max_done = max_done.max(r.done);
        }
        let par = max_done - ingest_done;
        assert!(par < 1.5 * solo, "4-drive parallel {par} ≈ solo {solo}");
    }

    #[test]
    fn host_compute_threads() {
        let mut h = HostCpu::default();
        let dones: Vec<f64> = (0..16).map(|_| h.run(0.0, 1.0)).collect();
        assert!(dones.iter().all(|&d| (d - 1.0).abs() < 1e-12));
        assert!((h.run(0.0, 1.0) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn tunnel_messages_counted() {
        let mut s = server(2);
        s.send_to_isp(0.0, 0, 64);
        s.send_to_host(0.0, 1, 64);
        assert_eq!(s.total_tunnel_messages(), 2);
    }
}
