//! The Solana CSD device model.
//!
//! Mirrors the hardware described in §III of the paper: a NAND array
//! behind a 16-channel bus ([`flash`]), flash-management routines
//! ([`ftl`]: mapping, garbage collection, wear leveling), the flash
//! controller unit with its NVMe front-end and ECC-equipped back-end
//! ([`fcu`]), the quad-core ARM Cortex-A53 in-storage-processing engine
//! ([`isp`]), and the 6-GB DRAM shared between FCU and ISP over the
//! intra-chip bus ([`dram`]).
//!
//! The assembled [`Csd`] exposes the two data paths the paper's Fig. 4
//! distinguishes:
//!
//! * **path (a)** flash → BE → DRAM → NVMe/PCIe → host
//! * **path (b)** flash → BE → DRAM → intra-chip bus → ISP (bypasses the
//!   NVMe front-end entirely — this is what makes in-storage processing
//!   cheap)
//!
//! Path (c), the TCP/IP tunnel, lives in [`crate::interconnect`] because
//! it spans host and device.
//!
//! **Flash management under mutation (ISSUE-8):** every die reserves a
//! small headroom of over-provisioned blocks that host allocation may
//! never consume — only GC relocation can dip into them, which is what
//! makes mid-relocation free-pool exhaustion impossible by construction.
//! Foreground GC stalls the triggering write; with
//! `FlashConfig::background_gc` idle dies also relocate ahead of the
//! low-water mark, so GC steals die/channel bandwidth from future IO
//! (the fig13 write + GC interference scenario). With `FlashConfig::zns`
//! the FTL switches to ZCSD-style zoned placement: append-only zones,
//! host-visible zone resets, no device relocation, WAF ≡ 1.

pub mod dram;
pub mod fcu;
pub mod flash;
pub mod ftl;
pub mod isp;
pub mod nvme;

use crate::sim::SimTime;

pub use dram::SharedDram;
pub use fcu::{Fcu, IoRequester};
pub use flash::{FlashArray, FlashConfig, PhysAddr};
pub use ftl::Ftl;
pub use isp::{IspConfig, IspEngine};
pub use nvme::{NvmeFrontEnd, Opcode};

/// Static configuration of one Solana drive (defaults = the paper's
/// prototype: 12 TB, 16 channels, quad A53, 6 GB shared DRAM).
#[derive(Clone, Debug)]
pub struct CsdConfig {
    pub flash: FlashConfig,
    pub isp: IspConfig,
    /// Shared DRAM capacity in bytes (paper: 6 GB).
    pub dram_bytes: u64,
    /// Shared DRAM bandwidth in bytes/s (LPDDR4-class).
    pub dram_bw: f64,
    /// Intra-chip BE↔ISP link bandwidth in bytes/s. "High-speed
    /// intra-chip data bus" (§III-A2) — on-die, far faster than PCIe.
    pub intra_bw: f64,
    /// Intra-chip link per-transfer latency (s).
    pub intra_lat: f64,
    /// Per-page ECC decode cost in the BE (s) — BCH/LDPC pipeline.
    pub ecc_per_page: f64,
    /// NVMe front-end per-command processing overhead (s).
    pub fe_cmd_overhead: f64,
}

impl Default for CsdConfig {
    fn default() -> Self {
        CsdConfig {
            flash: FlashConfig::default(),
            isp: IspConfig::default(),
            dram_bytes: 6 * (1 << 30),
            dram_bw: 12.8e9,
            intra_bw: 8.0e9,
            intra_lat: 2e-6,
            ecc_per_page: 8e-6,
            fe_cmd_overhead: 5e-6,
        }
    }
}

impl CsdConfig {
    /// A tiny geometry for unit tests (MBs instead of TBs) — same code
    /// paths, fast to exercise GC.
    pub fn tiny() -> CsdConfig {
        CsdConfig { flash: FlashConfig::tiny(), ..CsdConfig::default() }
    }
}

/// One assembled Solana drive.
pub struct Csd {
    pub id: usize,
    pub cfg: CsdConfig,
    pub fcu: Fcu,
    pub isp: IspEngine,
    pub dram: SharedDram,
}

/// Timing outcome of a device-level file read.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DeviceRead {
    /// When the data was fully in shared DRAM (BE + ECC done).
    pub in_dram: SimTime,
    /// When the consumer (ISP or host DMA engine) had the bytes.
    pub delivered: SimTime,
    /// Bytes actually read from flash (page-aligned).
    pub flash_bytes: u64,
}

impl Csd {
    pub fn new(id: usize, cfg: CsdConfig) -> Csd {
        Csd {
            id,
            fcu: Fcu::new(&cfg),
            isp: IspEngine::new(cfg.isp.clone()),
            dram: SharedDram::new(cfg.dram_bytes, cfg.dram_bw),
            cfg,
        }
    }

    /// Path (b): the ISP engine reads `bytes` at logical offset `lba_byte`
    /// through the CBDD file-system interface. Bypasses the NVMe FE
    /// (§III-C2): BE flash read + ECC, then intra-chip DMA into the ISP's
    /// address space.
    pub fn isp_read(&mut self, now: SimTime, lba_byte: u64, bytes: u64) -> DeviceRead {
        let in_dram = self.fcu.read(now, lba_byte, bytes, IoRequester::Isp);
        let dma = self.dram.isp_port.transfer(in_dram, bytes);
        DeviceRead {
            in_dram,
            delivered: dma.end,
            flash_bytes: self.fcu.page_aligned(bytes),
        }
    }

    /// Path (a) device half: host reads `bytes`; returns when the data is
    /// staged in DRAM ready for the NVMe DMA (the PCIe leg is modeled by
    /// the caller's [`crate::interconnect::PcieLink`]).
    pub fn host_read_staged(&mut self, now: SimTime, lba_byte: u64, bytes: u64) -> DeviceRead {
        // FE command processing precedes the BE work on this path.
        let after_fe = now + self.cfg.fe_cmd_overhead;
        let in_dram = self.fcu.read(after_fe, lba_byte, bytes, IoRequester::Host);
        let dma = self.dram.host_port.transfer(in_dram, bytes);
        DeviceRead {
            in_dram,
            delivered: dma.end,
            flash_bytes: self.fcu.page_aligned(bytes),
        }
    }

    /// Write `bytes` at logical offset (either requester). Returns
    /// completion time.
    pub fn write(&mut self, now: SimTime, lba_byte: u64, bytes: u64, req: IoRequester) -> SimTime {
        let start = match req {
            IoRequester::Host => now + self.cfg.fe_cmd_overhead,
            IoRequester::Isp => now,
        };
        self.fcu.write(start, lba_byte, bytes, req)
    }

    /// Run `work_secs` of single-threaded-equivalent compute on the ISP
    /// engine starting at `now`; returns completion time.
    pub fn isp_compute(&mut self, now: SimTime, work_secs: f64) -> SimTime {
        self.isp.run(now, work_secs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn isp_path_skips_fe_overhead() {
        let cfg = CsdConfig::tiny();
        let mut a = Csd::new(0, cfg.clone());
        let mut b = Csd::new(1, cfg);
        // Prime identical writes so reads hit mapped pages.
        a.write(0.0, 0, 1 << 20, IoRequester::Host);
        b.write(0.0, 0, 1 << 20, IoRequester::Host);
        let t0 = a.fcu.drain_time().max(b.fcu.drain_time());
        let via_isp = a.isp_read(t0, 0, 1 << 20);
        let via_host = b.host_read_staged(t0, 0, 1 << 20);
        assert!(
            via_isp.in_dram < via_host.in_dram,
            "ISP path must bypass FE: {} vs {}",
            via_isp.in_dram,
            via_host.in_dram
        );
    }

    #[test]
    fn reads_are_page_aligned_in_flash_accounting() {
        let mut c = Csd::new(0, CsdConfig::tiny());
        c.write(0.0, 0, 100, IoRequester::Isp);
        let r = c.isp_read(1.0, 0, 100);
        let page = c.cfg.flash.page_bytes;
        assert_eq!(r.flash_bytes, page);
    }

    #[test]
    fn compute_uses_all_four_cores() {
        let mut c = Csd::new(0, CsdConfig::default());
        // 4 independent 1s jobs on 4 cores should finish ~together.
        let dones: Vec<f64> = (0..4).map(|_| c.isp_compute(0.0, 1.0)).collect();
        let max = dones.iter().cloned().fold(0.0, f64::max);
        let min = dones.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!((max - min).abs() < 1e-9, "cores run in parallel");
    }
}
