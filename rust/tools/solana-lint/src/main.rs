//! CLI for solana-lint. From the workspace root:
//!
//!     cargo run --release -p solana-lint -- --deny all
//!
//! Exit codes: 0 = no denied findings, 1 = denied findings present,
//! 2 = usage or I/O error. Without `--deny`, findings are printed but
//! advisory (exit 0) — except `bad-marker`, which is always denied: a
//! broken suppression must never pass.

use std::path::{Path, PathBuf};
use std::process::ExitCode;

use solana_lint::{scan_file, scan_tree, to_json, Report, RULES};

struct Opts {
    json: bool,
    deny_all: bool,
    deny: Vec<String>,
    paths: Vec<PathBuf>,
}

const USAGE: &str = "usage: solana-lint [--root DIR] [--json] [--deny all|rule,...] [PATH...]\n\
                     rules: hash-iter wall-clock rng-gate no-unwrap lossy-cast join-reduce\n\
                     default PATH is rust/src (run from the workspace root)";

fn parse_args(args: &[String]) -> Result<Opts, String> {
    let mut opts = Opts {
        json: false,
        deny_all: false,
        deny: Vec::new(),
        paths: Vec::new(),
    };
    let mut root: Option<PathBuf> = None;
    let mut i = 0usize;
    while i < args.len() {
        match args[i].as_str() {
            "--json" => opts.json = true,
            "--deny" => {
                i += 1;
                let spec = args.get(i).ok_or("--deny needs an argument")?;
                if spec == "all" {
                    opts.deny_all = true;
                } else {
                    for r in spec.split(',') {
                        let r = r.trim();
                        if r.is_empty() {
                            continue;
                        }
                        if !RULES.contains(&r) && r != "bad-marker" {
                            return Err(format!("unknown rule '{r}' in --deny"));
                        }
                        opts.deny.push(r.to_string());
                    }
                }
            }
            "--root" => {
                i += 1;
                root = Some(PathBuf::from(
                    args.get(i).ok_or("--root needs an argument")?,
                ));
            }
            "--help" | "-h" => return Err(String::new()),
            p if p.starts_with('-') => return Err(format!("unknown flag '{p}'")),
            p => opts.paths.push(PathBuf::from(p)),
        }
        i += 1;
    }
    if opts.paths.is_empty() {
        opts.paths.push(PathBuf::from("rust/src"));
    }
    if let Some(root) = root {
        opts.paths = opts.paths.iter().map(|p| root.join(p)).collect();
    }
    Ok(opts)
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let opts = match parse_args(&args) {
        Ok(o) => o,
        Err(e) => {
            if !e.is_empty() {
                eprintln!("solana-lint: {e}");
            }
            eprintln!("{USAGE}");
            return ExitCode::from(2);
        }
    };

    let mut report = Report::default();
    for p in &opts.paths {
        let scanned = if p.is_dir() {
            scan_tree(p)
        } else {
            scan_file(p, &p.to_string_lossy())
        };
        match scanned {
            Ok(r) => {
                report.findings.extend(r.findings);
                report.suppressed += r.suppressed;
            }
            Err(e) => {
                eprintln!("solana-lint: {}: {e}", p.display());
                return ExitCode::from(2);
            }
        }
    }
    report
        .findings
        .sort_by(|a, b| (&a.file, a.line, a.col).cmp(&(&b.file, b.line, b.col)));

    if opts.json {
        print!("{}", to_json(&report));
    } else {
        for f in &report.findings {
            println!("{}:{}:{}: [{}] {}", f.file, f.line, f.col, f.rule, f.msg);
        }
        eprintln!(
            "solana-lint: {} finding(s), {} suppressed",
            report.findings.len(),
            report.suppressed
        );
    }

    let denied = report.findings.iter().any(|f| {
        f.rule == "bad-marker" || opts.deny_all || opts.deny.iter().any(|r| r == f.rule)
    });
    if denied {
        ExitCode::from(1)
    } else {
        ExitCode::SUCCESS
    }
}
