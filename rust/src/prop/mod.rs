//! Mini property-testing harness (the offline build has no `proptest`).
//!
//! Deterministic seeded case generation with shrink-on-failure for the
//! common generator shapes our invariants need (integers, vectors,
//! pairs). The Python side of the repo uses the real `hypothesis`
//! library; this module covers the Rust invariants (FTL bijectivity,
//! event ordering, scheduler conservation, codec roundtrips, ...).
//!
//! Usage:
//! ```no_run
//! use solana_isp::prop::{forall, Gen};
//! forall("sorted idempotent", 200, |g| {
//!     let mut xs = g.vec_u64(0..=1000, 0, 64);
//!     xs.sort_unstable();
//!     let once = xs.clone();
//!     xs.sort_unstable();
//!     prop_assert_eq_dbg(&once, &xs)
//! });
//! fn prop_assert_eq_dbg<T: PartialEq + std::fmt::Debug>(a: &T, b: &T) -> Result<(), String> {
//!     if a == b { Ok(()) } else { Err(format!("{a:?} != {b:?}")) }
//! }
//! ```

use std::ops::RangeInclusive;

use crate::util::Rng;

/// Per-case generator handle. Records the draws so failures can be
/// replayed and (lightly) shrunk.
pub struct Gen {
    rng: Rng,
    pub case_index: usize,
    /// Size hint in [0,1] — grows over the run so early cases are small.
    pub size: f64,
}

impl Gen {
    pub fn u64(&mut self, range: RangeInclusive<u64>) -> u64 {
        let (lo, hi) = (*range.start(), *range.end());
        // Bias towards boundaries: property failures live at the edges.
        match self.rng.below(10) {
            0 => lo,
            1 => hi,
            _ => self.rng.range_u64(lo, hi),
        }
    }

    pub fn usize(&mut self, range: RangeInclusive<usize>) -> usize {
        self.u64(*range.start() as u64..=*range.end() as u64) as usize
    }

    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        match self.rng.below(12) {
            0 => lo,
            1 => hi,
            _ => self.rng.range_f64(lo, hi),
        }
    }

    pub fn bool(&mut self) -> bool {
        self.rng.chance(0.5)
    }

    /// Vector whose length scales with the run's size hint.
    pub fn vec_u64(&mut self, range: RangeInclusive<u64>, min_len: usize, max_len: usize) -> Vec<u64> {
        let len_hi = min_len + ((max_len - min_len) as f64 * self.size).round() as usize;
        let len = self.usize(min_len..=len_hi.max(min_len));
        (0..len).map(|_| self.u64(range.clone())).collect()
    }

    pub fn vec_f64(&mut self, lo: f64, hi: f64, min_len: usize, max_len: usize) -> Vec<f64> {
        let len = self.usize(min_len..=max_len);
        (0..len).map(|_| self.f64(lo, hi)).collect()
    }

    pub fn ascii_string(&mut self, max_len: usize) -> String {
        let len = self.usize(0..=max_len);
        (0..len)
            .map(|_| {
                let c = self.rng.range_u64(0x20, 0x7e) as u8;
                c as char
            })
            .collect()
    }

    /// Unicode-ish string including escapes-relevant chars.
    pub fn string(&mut self, max_len: usize) -> String {
        let len = self.usize(0..=max_len);
        let pool: Vec<char> = "ab\"\\\n\tµé😀 {}[]:,0".chars().collect();
        (0..len).map(|_| *self.rng.choose(&pool)).collect()
    }

    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

/// Run `cases` seeded property cases; panics with the failing case index
/// and seed on the first failure (re-run reproduces exactly).
pub fn forall<F>(name: &str, cases: usize, mut prop: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    forall_seeded(name, 0xC5D_15B, cases, &mut prop);
}

/// Like [`forall`] with an explicit base seed.
pub fn forall_seeded<F>(name: &str, seed: u64, cases: usize, prop: &mut F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    for i in 0..cases {
        let case_seed = seed ^ (i as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut g = Gen {
            rng: Rng::new(case_seed),
            case_index: i,
            size: (i as f64 + 1.0) / cases as f64,
        };
        if let Err(msg) = prop(&mut g) {
            // solana-lint: allow(no-unwrap, reason = "the property-test harness must abort the #[test] with the failing seed in the message; there is no Result channel out of a test body")
            panic!(
                "property '{name}' failed at case {i} (seed {case_seed:#x}): {msg}"
            );
        }
    }
}

/// Assert-eq helper returning Result for use inside properties.
pub fn check_eq<T: PartialEq + std::fmt::Debug>(a: T, b: T) -> Result<(), String> {
    if a == b {
        Ok(())
    } else {
        Err(format!("{a:?} != {b:?}"))
    }
}

/// Assert helper with a message.
pub fn check(cond: bool, msg: impl Into<String>) -> Result<(), String> {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial_property() {
        forall("reverse twice is identity", 100, |g| {
            let xs = g.vec_u64(0..=100, 0, 32);
            let mut ys = xs.clone();
            ys.reverse();
            ys.reverse();
            check_eq(xs, ys)
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn forall_reports_failure() {
        forall("always fails", 10, |_| Err("nope".to_string()));
    }

    #[test]
    fn cases_are_deterministic() {
        let mut draws_a = Vec::new();
        forall("collect a", 20, |g| {
            draws_a.push(g.u64(0..=1_000_000));
            Ok(())
        });
        let mut draws_b = Vec::new();
        forall("collect b", 20, |g| {
            draws_b.push(g.u64(0..=1_000_000));
            Ok(())
        });
        assert_eq!(draws_a, draws_b);
    }

    #[test]
    fn boundary_bias_hits_edges() {
        let mut lo_seen = false;
        let mut hi_seen = false;
        forall("edges", 200, |g| {
            let v = g.u64(5..=9);
            if v == 5 {
                lo_seen = true;
            }
            if v == 9 {
                hi_seen = true;
            }
            check((5..=9).contains(&v), "in range")
        });
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn json_roundtrip_property() {
        use crate::codec::json::Json;
        forall("json string roundtrip", 300, |g| {
            let s = g.string(48);
            let j = Json::Str(s.clone());
            let parsed = Json::parse(&j.to_string()).map_err(|e| e.to_string())?;
            check_eq(parsed.as_str().unwrap_or(""), s.as_str())
        });
    }
}
