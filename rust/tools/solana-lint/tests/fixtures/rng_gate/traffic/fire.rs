// Positive fixture for D3 rng-gate: an ungated draw in a traffic/
// path component must fire.
pub struct Gen {
    rng: Rng,
    rate: f64,
}

impl Gen {
    pub fn next_gap(&mut self) -> f64 {
        self.rng.exponential(self.rate)
    }
}
