// Positive fixture for the bad-marker meta-rule: unknown rule name.
// solana-lint: allow(made-up-rule, reason = "this rule does not exist")
pub fn f() {}
