//! Bit-identity regression tests for the ISSUE-7 hash-iteration fixes.
//!
//! The D1 (`hash-iter`) burn-down replaced hash-map iteration on hot
//! determinism-sensitive paths with key-ordered structures:
//!
//! * the fleet balancer's in-flight request `tracker` (hedge candidate
//!   scans iterate it) is now a `BTreeMap`,
//! * `Ftl::check_invariants` walks `sorted_pairs` of its maps,
//! * the runtime's executable cache is a `BTreeMap`.
//!
//! These tests pin the property those changes protect: a resilient,
//! faulted fleet serve — retries, hedging, crash/rejoin, link chaos,
//! i.e. every path that iterates the tracker — is bit-identical across
//! back-to-back runs, and conserves every offered request. They are
//! deliberately free of pinned absolute values: bit-identity is
//! *within* a binary, so the assertions survive toolchain bumps.

use solana_isp::cluster::fleet::{FleetConfig, FleetShape};
use solana_isp::faults::FaultsConfig;
use solana_isp::metrics::Metrics;
use solana_isp::power::PowerModel;
use solana_isp::traffic::{serve_fleet, LbPolicy, ServeReport, TrafficConfig};
use solana_isp::workloads::App;

fn serve(app: App, fcfg: &FleetConfig, tcfg: &TrafficConfig) -> ServeReport {
    let mut m = Metrics::new();
    serve_fleet(app, fcfg, tcfg, &PowerModel::default(), &mut m).expect("serve_fleet")
}

/// The tracker-heavy configuration: hedging scans every tracked
/// request, retries re-enter the tracker, and a crash/rejoin forces
/// failover re-dispatch — all while drive and link faults reorder
/// completions.
fn resilient_config(servers: usize) -> (FleetConfig, TrafficConfig) {
    let fcfg = FleetConfig {
        servers,
        shape: FleetShape::Mixed,
        replicas: 1,
        ..FleetConfig::default()
    };
    let faults = FaultsConfig {
        seed: 0xD15EA5E,
        ack_loss: 0.08,
        stall: 0.08,
        stall_s: 0.02,
        link_drop: 0.05,
        link_dup: 0.05,
        server_crash_at: Some(0.35),
        crash_server: 1,
        rejoin_s: Some(0.5),
        ..FaultsConfig::default()
    };
    let tcfg = TrafficConfig {
        load: 0.7,
        requests: 500,
        policy: LbPolicy::LeastWork,
        retries: 2,
        hedge: true,
        faults: Some(faults),
        ..TrafficConfig::default()
    };
    (fcfg, tcfg)
}

#[test]
fn resilient_faulted_serve_is_bit_identical_across_runs() {
    for app in [App::SpeechToText, App::Sentiment] {
        let (fcfg, tcfg) = resilient_config(3);
        let a = serve(app, &fcfg, &tcfg);
        let b = serve(app, &fcfg, &tcfg);
        a.check_bit_identical(&b)
            .unwrap_or_else(|e| panic!("{app:?}: tracker iteration leaked nondeterminism: {e}"));
        assert_eq!(
            a.served + a.failed + a.shed,
            a.requests,
            "{app:?}: offered == accepted + shed conservation"
        );
    }
}

#[test]
fn hedge_scan_order_is_stable_across_policies() {
    // The hedge candidate scan is the one site that *iterates* the
    // tracker; run it under every balancer policy so a future
    // policy-specific iteration shortcut can't silently reintroduce
    // hash-order dependence.
    for policy in [
        LbPolicy::RoundRobin,
        LbPolicy::WeightedCapacity,
        LbPolicy::JoinShortestQueue,
        LbPolicy::LeastWork,
    ] {
        let (fcfg, mut tcfg) = resilient_config(3);
        tcfg.policy = policy;
        tcfg.requests = 300;
        let a = serve(App::Recommender, &fcfg, &tcfg);
        let b = serve(App::Recommender, &fcfg, &tcfg);
        a.check_bit_identical(&b)
            .unwrap_or_else(|e| panic!("{policy:?}: {e}"));
    }
}
