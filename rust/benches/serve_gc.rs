//! `cargo bench --bench serve_gc` — regenerates Fig 13: write + GC
//! interference under a background ingest/update stream across fleet
//! shapes (all-CSD vs all-SSD) and flash-management modes (foreground
//! GC, background GC, ZNS append-only) — the ISSUE-8 tentpole. See
//! `csd::ftl` for the page-mapped FTL and garbage collector,
//! `traffic::engine` for the seeded ingest interleave, and `exp` for
//! the sweep definition.
//!
//! Scale with `SOLANA_BENCH_FAST=1` (5%) or default 25% of the paper's
//! dataset sizes; the *shape* (GC tail inflation hits the host-read
//! baseline harder than the ISP build, ZNS holds WAF at 1.0) is
//! scale-invariant.

use solana_isp::bench_support::Bencher;
use solana_isp::exp::{self, Scale};

fn main() -> anyhow::Result<()> {
    let scale = Scale::from_env();
    let table = exp::fig13_gc(scale)?;
    exp::emit(&table, "fig13")?;
    // Wall-time of regenerating the artifact (simulator throughput):
    let mut b = Bencher::new(0, if std::env::var("SOLANA_BENCH_FAST").is_ok() { 1 } else { 2 });
    b.bench("fig13_serve_gc", || {
        let t = exp::fig13_gc(scale).expect("rerun");
        t.rows.len() as u64
    });
    print!("{}", b.report());
    b.write_json("serve_gc")?;
    Ok(())
}
