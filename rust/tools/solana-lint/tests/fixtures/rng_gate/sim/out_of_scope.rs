// Negative fixture for D3 rng-gate path scoping: the rule applies only
// to files under a `faults` or `traffic` path component. This file
// lives under `sim/`, so its ungated draw is out of scope.
pub fn draw(rng: &mut Rng) -> f64 {
    rng.f64()
}
