//! NAND flash array model: geometry, timing, and channel/die contention.
//!
//! §III-A1 of the paper: the BE talks to the flash packages over a
//! 16-channel data bus capable of concurrent IO. We model each die as a
//! single-server resource (tR / tPROG / tBERS occupancy) and each channel
//! as a serialized bus (page transfer at ONFI-class bandwidth). This is
//! the standard SSD-simulator decomposition (cf. MQSim): an operation
//! occupies its die for the cell time, then its channel for the data
//! transfer.

use crate::sim::{Pipe, Servers, SimTime};

/// Physical page address.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct PhysAddr {
    pub channel: u16,
    pub die: u16,
    pub block: u32,
    pub page: u32,
}

/// Flash geometry + timing. Defaults model the 12-TB Solana prototype:
/// 16 channels × 8 dies × 2500 blocks × 2304 pages × 16 KiB ≈ 12.1 TB.
#[derive(Clone, Debug)]
pub struct FlashConfig {
    pub channels: u16,
    pub dies_per_channel: u16,
    pub blocks_per_die: u32,
    pub pages_per_block: u32,
    pub page_bytes: u64,
    /// Cell read time tR (s) — TLC-class.
    pub read_secs: f64,
    /// Page program time tPROG (s).
    pub program_secs: f64,
    /// Block erase time tBERS (s).
    pub erase_secs: f64,
    /// Per-channel bus bandwidth (bytes/s) — ONFI 4 class.
    pub channel_bw: f64,
    /// Per-operation channel command overhead (s).
    pub channel_cmd_secs: f64,
    /// Zoned-namespace mode (ZCSD-style): append-only placement per
    /// zone, reclamation via host-visible zone resets, no device GC.
    pub zns: bool,
    /// Opportunistic GC on idle dies ahead of the low-water mark.
    pub background_gc: bool,
}

impl Default for FlashConfig {
    fn default() -> Self {
        FlashConfig {
            channels: 16,
            dies_per_channel: 8,
            blocks_per_die: 2500,
            pages_per_block: 2304,
            page_bytes: 16 * 1024,
            read_secs: 70e-6,
            program_secs: 650e-6,
            erase_secs: 3.5e-3,
            channel_bw: 800e6,
            channel_cmd_secs: 1e-6,
            zns: false,
            background_gc: false,
        }
    }
}

impl FlashConfig {
    /// Tiny geometry for tests: 2 channels × 2 dies × 8 blocks × 16 pages
    /// × 4 KiB = 4 MiB. Same code paths, GC reachable in milliseconds.
    pub fn tiny() -> FlashConfig {
        FlashConfig {
            channels: 2,
            dies_per_channel: 2,
            blocks_per_die: 8,
            pages_per_block: 16,
            page_bytes: 4096,
            ..FlashConfig::default()
        }
    }

    pub fn dies(&self) -> usize {
        self.channels as usize * self.dies_per_channel as usize
    }

    pub fn pages_per_die(&self) -> u64 {
        self.blocks_per_die as u64 * self.pages_per_block as u64
    }

    pub fn total_pages(&self) -> u64 {
        self.dies() as u64 * self.pages_per_die()
    }

    pub fn capacity_bytes(&self) -> u64 {
        self.total_pages() * self.page_bytes
    }

    pub fn die_index(&self, a: &PhysAddr) -> usize {
        a.channel as usize * self.dies_per_channel as usize + a.die as usize
    }
}

/// The flash array: per-die occupancy + per-channel bus.
pub struct FlashArray {
    pub cfg: FlashConfig,
    dies: Vec<Servers>,
    channels: Vec<Pipe>,
    reads: u64,
    programs: u64,
    erases: u64,
}

impl FlashArray {
    pub fn new(cfg: FlashConfig) -> FlashArray {
        let dies = (0..cfg.dies()).map(|_| Servers::new(1)).collect();
        let channels = (0..cfg.channels as usize)
            .map(|_| Pipe::new(cfg.channel_bw, cfg.channel_cmd_secs))
            .collect();
        FlashArray { cfg, dies, channels, reads: 0, programs: 0, erases: 0 }
    }

    /// Read one page: die busy for tR, then the channel moves the page.
    /// Returns the time the page is in controller DRAM.
    pub fn read_page(&mut self, now: SimTime, addr: PhysAddr) -> SimTime {
        let die = self.cfg.die_index(&addr);
        let cell_done = self.dies[die].acquire(now, self.cfg.read_secs);
        let xfer = self.channels[addr.channel as usize].transfer(cell_done, self.cfg.page_bytes);
        self.reads += 1;
        xfer.end
    }

    /// Program one page: channel moves data to the die, then tPROG.
    pub fn program_page(&mut self, now: SimTime, addr: PhysAddr) -> SimTime {
        let xfer = self.channels[addr.channel as usize].transfer(now, self.cfg.page_bytes);
        let die = self.cfg.die_index(&addr);
        self.programs += 1;
        self.dies[die].acquire(xfer.end, self.cfg.program_secs)
    }

    /// Erase a block: die busy for tBERS (no data on the channel).
    pub fn erase_block(&mut self, now: SimTime, channel: u16, die: u16) -> SimTime {
        let idx = channel as usize * self.cfg.dies_per_channel as usize + die as usize;
        self.erases += 1;
        self.dies[idx].acquire(now, self.cfg.erase_secs)
    }

    pub fn counts(&self) -> (u64, u64, u64) {
        (self.reads, self.programs, self.erases)
    }

    /// Whether a die has drained all scheduled work by `now` — the
    /// background-GC eligibility test.
    pub fn die_idle(&self, die_idx: usize, now: SimTime) -> bool {
        self.dies[die_idx].drain_time() <= now
    }

    /// Total busy seconds across dies (for power/utilization accounting).
    pub fn die_busy_secs(&self) -> f64 {
        self.dies.iter().map(|d| d.busy_secs()).sum()
    }

    pub fn channel_busy_secs(&self) -> f64 {
        self.channels.iter().map(|c| c.busy_secs()).sum()
    }

    /// When all in-flight flash work drains.
    pub fn drain_time(&self) -> SimTime {
        let d = self.dies.iter().map(|x| x.drain_time()).fold(0.0, f64::max);
        let c = self.channels.iter().map(|x| x.busy_until()).fold(0.0, f64::max);
        d.max(c)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn addr(channel: u16, die: u16, block: u32, page: u32) -> PhysAddr {
        PhysAddr { channel, die, block, page }
    }

    #[test]
    fn geometry_capacity_is_12tb_class() {
        let cfg = FlashConfig::default();
        let tb = cfg.capacity_bytes() as f64 / 1e12;
        assert!((11.5..13.0).contains(&tb), "capacity {tb} TB");
        assert_eq!(cfg.dies(), 128);
    }

    #[test]
    fn tiny_geometry_math() {
        let cfg = FlashConfig::tiny();
        assert_eq!(cfg.total_pages(), 2 * 2 * 8 * 16);
        assert_eq!(cfg.capacity_bytes(), 2 * 2 * 8 * 16 * 4096);
    }

    #[test]
    fn read_page_timing_unloaded() {
        let cfg = FlashConfig::default();
        let mut f = FlashArray::new(cfg.clone());
        let done = f.read_page(0.0, addr(0, 0, 0, 0));
        let expect = cfg.read_secs + cfg.channel_cmd_secs + cfg.page_bytes as f64 / cfg.channel_bw;
        assert!((done - expect).abs() < 1e-12, "{done} vs {expect}");
    }

    #[test]
    fn dies_on_different_channels_are_parallel() {
        let mut f = FlashArray::new(FlashConfig::default());
        let d0 = f.read_page(0.0, addr(0, 0, 0, 0));
        let d1 = f.read_page(0.0, addr(1, 0, 0, 0));
        assert!((d0 - d1).abs() < 1e-12, "independent channels overlap fully");
    }

    #[test]
    fn same_die_serializes_cell_time() {
        let cfg = FlashConfig::default();
        let mut f = FlashArray::new(cfg.clone());
        let d0 = f.read_page(0.0, addr(0, 0, 0, 0));
        let d1 = f.read_page(0.0, addr(0, 0, 0, 1));
        assert!(d1 > d0, "second read on same die queues");
        assert!(d1 - d0 >= cfg.read_secs - 1e-9);
    }

    #[test]
    fn same_channel_different_die_overlaps_cell_time() {
        let cfg = FlashConfig::default();
        let mut f = FlashArray::new(cfg.clone());
        // two dies on channel 0: tR overlaps, channel transfer serializes
        let d0 = f.read_page(0.0, addr(0, 0, 0, 0));
        let d1 = f.read_page(0.0, addr(0, 1, 0, 0));
        let xfer = cfg.channel_cmd_secs + cfg.page_bytes as f64 / cfg.channel_bw;
        assert!((d1 - d0 - xfer).abs() < 1e-9, "serialized only on the bus");
    }

    #[test]
    fn program_and_erase_counts() {
        let mut f = FlashArray::new(FlashConfig::tiny());
        f.program_page(0.0, addr(0, 0, 0, 0));
        f.erase_block(1.0, 0, 0);
        let (r, p, e) = f.counts();
        assert_eq!((r, p, e), (0, 1, 1));
    }
}
