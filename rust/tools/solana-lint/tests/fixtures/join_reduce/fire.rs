// Positive fixture for D6 join-reduce: spawning a thread outside
// exp::pool in non-test code must fire.
use std::thread;

pub fn fan_out() -> f64 {
    let h = thread::spawn(|| 1.0f64);
    h.join().unwrap_or(0.0)
}
