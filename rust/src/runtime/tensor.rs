//! Host-side f32/i32 tensors and their conversion to/from XLA literals.
//!
//! Small by design: the runtime only ever moves f32 arrays (model
//! inputs/outputs) and i32 arrays (top-k indices). Everything is
//! row-major, matching XLA's default layout.

use anyhow::{anyhow, bail, Result};

/// A host tensor (row-major f32, plus an i32 view for index outputs).
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
    /// Set when the underlying literal was s32 (e.g. top-k indices); the
    /// values in `data` are then exact integers.
    pub was_i32: bool,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Tensor {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape/data mismatch"
        );
        Tensor { shape, data, was_i32: false }
    }

    pub fn scalar(v: f32) -> Tensor {
        Tensor::new(vec![], vec![v])
    }

    pub fn zeros(shape: Vec<usize>) -> Tensor {
        let n = shape.iter().product();
        Tensor::new(shape, vec![0.0; n])
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Row `i` of a 2-D tensor.
    pub fn row(&self, i: usize) -> &[f32] {
        assert_eq!(self.shape.len(), 2, "row() needs a matrix");
        let w = self.shape[1];
        &self.data[i * w..(i + 1) * w]
    }

    /// Values as i32 (for index tensors).
    pub fn as_i32(&self) -> Vec<i32> {
        self.data.iter().map(|&v| v as i32).collect()
    }

    /// Convert to an XLA literal (f32, row-major).
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        xla::Literal::vec1(&self.data)
            .reshape(&dims)
            .map_err(|e| anyhow!("reshape literal: {e:?}"))
    }

    /// Read a literal back into a host tensor (f32 or s32).
    pub fn from_literal(lit: &xla::Literal) -> Result<Tensor> {
        let shape = lit
            .array_shape()
            .map_err(|e| anyhow!("literal shape: {e:?}"))?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => {
                let data = lit.to_vec::<f32>().map_err(|e| anyhow!("to_vec f32: {e:?}"))?;
                Ok(Tensor { shape: dims, data, was_i32: false })
            }
            xla::ElementType::S32 => {
                let data = lit.to_vec::<i32>().map_err(|e| anyhow!("to_vec i32: {e:?}"))?;
                Ok(Tensor {
                    shape: dims,
                    data: data.into_iter().map(|v| v as f32).collect(),
                    was_i32: true,
                })
            }
            other => bail!("unsupported output dtype {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_rows() {
        let t = Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.row(0), &[1., 2., 3.]);
        assert_eq!(t.row(1), &[4., 5., 6.]);
        assert_eq!(t.len(), 6);
    }

    #[test]
    #[should_panic(expected = "shape/data mismatch")]
    fn bad_shape_panics() {
        Tensor::new(vec![2, 2], vec![1.0]);
    }

    #[test]
    fn zeros_and_scalar() {
        assert_eq!(Tensor::zeros(vec![3]).data, vec![0.0; 3]);
        assert_eq!(Tensor::scalar(2.5).shape, Vec::<usize>::new());
    }

    #[test]
    fn i32_view_rounds() {
        let mut t = Tensor::new(vec![2], vec![3.0, 7.0]);
        t.was_i32 = true;
        assert_eq!(t.as_i32(), vec![3, 7]);
    }
}
