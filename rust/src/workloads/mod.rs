//! The three NLP benchmark applications (§IV-B), end-to-end.
//!
//! Each app has two faces:
//!
//! * a **compute pipeline** that does the real work through the PJRT
//!   runtime (featurize → AOT executable → decode/score) — used by the
//!   examples and the accuracy checks ("output accuracy: same", Table I);
//! * an [`AppModel`] — the *calibrated* workload description the
//!   simulator schedules: per-item service times on the host Xeon and on
//!   the CSD's A53, bytes read per item, output bytes per item, and
//!   per-batch fixed overheads. Calibration constants come straight from
//!   the paper's single-node measurements and are documented inline.

pub mod recommender;
pub mod sentiment;
pub mod speech;

pub use recommender::RecommenderApp;
pub use sentiment::SentimentApp;
pub use speech::SpeechApp;

/// Which benchmark.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum App {
    SpeechToText,
    Recommender,
    Sentiment,
}

impl App {
    pub fn name(&self) -> &'static str {
        match self {
            App::SpeechToText => "speech_to_text",
            App::Recommender => "recommender",
            App::Sentiment => "sentiment",
        }
    }

    pub fn all() -> [App; 3] {
        [App::SpeechToText, App::Recommender, App::Sentiment]
    }
}

/// Calibrated workload model consumed by the scheduler/simulator.
///
/// Service times are *per item, per execution unit*: a node with `k`
/// units processes a batch of `B` items in `B × item_secs / k` (+ IO +
/// fixed overhead). Node-level rates therefore reproduce the paper's
/// single-node numbers:
///
/// | app        | host node rate      | CSD node rate     | source |
/// |------------|---------------------|-------------------|--------|
/// | speech     | 102 words/s         | 5.3 words/s       | §IV-B1 |
/// | recommender| 579 q/s             | ≈25.8 q/s         | §IV-B2 (1506−579)/36 |
/// | sentiment  | 9496 q/s            | 364 q/s           | §IV-B3 / Fig 6 |
#[derive(Clone, Debug)]
pub struct AppModel {
    pub app: App,
    /// Total items in the benchmark run (clips / queries / tweets).
    pub items: u64,
    /// Average flash bytes read per item.
    pub bytes_per_item: u64,
    /// Output bytes sent back to the host per item (ISP path).
    pub output_bytes_per_item: u64,
    /// Per-item service seconds on one host hardware thread (16 total).
    pub host_item_secs: f64,
    /// Per-item service seconds on one ISP core (4 total).
    pub csd_item_secs: f64,
    /// Fixed per-batch overhead on the host (dispatch, process wakeup).
    pub host_batch_overhead: f64,
    /// Fixed per-batch overhead on a CSD (tunnel dispatch, slower cores).
    pub csd_batch_overhead: f64,
    /// Words per item (speech reports words/s; 1.0 elsewhere).
    pub words_per_item: f64,
}

pub const HOST_THREADS: f64 = 16.0;
pub const ISP_CORES: f64 = 4.0;

impl AppModel {
    /// Speech-to-text over the LJ-like corpus (13,100 clips, ~3.3 GB).
    ///
    /// Host: 102 words/s ÷ 17.23 words/clip = 5.92 clips/s nodewide ⇒
    /// per-thread 16/5.92 = 2.70 s/clip. CSD: 5.3 words/s ⇒ 0.308
    /// clips/s ⇒ per-core 4/0.308 = 13.0 s/clip.
    pub fn speech(items: u64) -> AppModel {
        let words_per_item = 17.23;
        AppModel {
            app: App::SpeechToText,
            items,
            bytes_per_item: 290_000, // ≈3.8 GB / 13,100 clips (§IV-B1)
            output_bytes_per_item: 95, // 1.2 MB of text / 13,100 clips
            host_item_secs: HOST_THREADS / (102.0 / words_per_item),
            csd_item_secs: ISP_CORES / (5.3 / words_per_item),
            host_batch_overhead: 0.05,
            csd_batch_overhead: 0.20,
            words_per_item,
        }
    }

    /// Movie recommender over the 58 K catalogue: each query reads its
    /// precomputed similarity-matrix row from flash (58,000 × 4 B ≈
    /// 232 KB — "ran the training process once and stored the matrix on
    /// flash", §IV-B2) and top-10 filters.
    pub fn recommender(items: u64) -> AppModel {
        AppModel {
            app: App::Recommender,
            items,
            bytes_per_item: 232_000,
            output_bytes_per_item: 80, // 10 ids + scores
            host_item_secs: HOST_THREADS / 579.0,
            csd_item_secs: ISP_CORES / 25.75,
            host_batch_overhead: 0.05,
            csd_batch_overhead: 0.20,
            words_per_item: 1.0,
        }
    }

    /// Twitter sentiment: tiny per-item input, model resident.
    pub fn sentiment(items: u64) -> AppModel {
        AppModel {
            app: App::Sentiment,
            items,
            bytes_per_item: 140,
            output_bytes_per_item: 1,
            host_item_secs: HOST_THREADS / 9496.0,
            csd_item_secs: ISP_CORES / 364.0,
            host_batch_overhead: 0.05,
            csd_batch_overhead: 0.20,
            words_per_item: 1.0,
        }
    }

    /// IO-bound synthetic scan (ablation A2 only): grep-like filtering
    /// of 1-MiB log chunks. Compute is memory-bound (~1.2 GB/s per A53
    /// core with NEON, ~6 GB/s per Xeon thread), so the *data path* —
    /// local flash DMA vs the MB/s tunnel — decides throughput. This is
    /// the workload class where index-only dispatch into the shared FS
    /// is not just cheaper but the difference between scaling and
    /// collapsing (DESIGN.md A2).
    pub fn scan(items: u64) -> AppModel {
        let chunk = 1 << 20;
        AppModel {
            app: App::Sentiment, // reuses reporting units (items/s)
            items,
            bytes_per_item: chunk,
            output_bytes_per_item: 32,
            host_item_secs: chunk as f64 / 6.0e9,
            csd_item_secs: chunk as f64 / 1.2e9,
            host_batch_overhead: 0.05,
            csd_batch_overhead: 0.20,
            words_per_item: 1.0,
        }
    }

    pub fn for_app(app: App, items: u64) -> AppModel {
        match app {
            App::SpeechToText => AppModel::speech(items),
            App::Recommender => AppModel::recommender(items),
            App::Sentiment => AppModel::sentiment(items),
        }
    }

    /// Paper-default total items for the full benchmark run.
    pub fn paper_items(app: App) -> u64 {
        match app {
            App::SpeechToText => 13_100,
            App::Recommender => 58_000,
            App::Sentiment => 8_000_000, // 1.6 M tweets duplicated ×5 (§IV-B3)
        }
    }

    /// Node-level steady-state rate (items/s) ignoring batch overheads.
    pub fn host_rate(&self) -> f64 {
        HOST_THREADS / self.host_item_secs
    }

    pub fn csd_rate(&self) -> f64 {
        ISP_CORES / self.csd_item_secs
    }

    /// The paper's batch ratio: host-batch = ratio × csd-batch (§IV-A,
    /// "considerably large, ranging from 20 to 30").
    pub fn natural_batch_ratio(&self) -> f64 {
        self.host_rate() / self.csd_rate()
    }

    /// Single-node throughput at a given batch size (items/s), including
    /// the fixed per-batch overhead — this is the Fig. 6 curve.
    pub fn node_rate_at_batch(&self, batch: u64, is_host: bool) -> f64 {
        let (units, item_secs, overhead) = if is_host {
            (HOST_THREADS, self.host_item_secs, self.host_batch_overhead)
        } else {
            (ISP_CORES, self.csd_item_secs, self.csd_batch_overhead)
        };
        let service = batch as f64 * item_secs / units;
        batch as f64 / (overhead + service)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn calibration_reproduces_paper_single_node_rates() {
        let sp = AppModel::speech(13_100);
        // words/s = clips/s × words/clip
        let host_wps = sp.host_rate() * sp.words_per_item;
        let csd_wps = sp.csd_rate() * sp.words_per_item;
        assert!((host_wps - 102.0).abs() < 1.0, "host {host_wps} w/s");
        assert!((csd_wps - 5.3).abs() < 0.1, "csd {csd_wps} w/s");

        let rec = AppModel::recommender(58_000);
        assert!((rec.host_rate() - 579.0).abs() < 1.0);
        assert!((rec.csd_rate() - 25.75).abs() < 0.5);

        let se = AppModel::sentiment(1_600_000);
        assert!((se.host_rate() - 9496.0).abs() < 1.0);
        assert!((se.csd_rate() - 364.0).abs() < 1.0);
    }

    #[test]
    fn batch_ratios_match_paper_range() {
        // §IV-A: "ranging from 20 to 30"
        for app in App::all() {
            let m = AppModel::for_app(app, 1000);
            let r = m.natural_batch_ratio();
            assert!((15.0..32.0).contains(&r), "{:?} ratio {r}", app);
        }
        // §IV-B3: sentiment ratio 9496/364 ≈ 26
        let s = AppModel::sentiment(1000).natural_batch_ratio();
        assert!((s - 26.0).abs() < 0.5, "sentiment ratio {s}");
    }

    #[test]
    fn fig6_shape_rate_grows_then_saturates() {
        let m = AppModel::sentiment(1_000_000);
        let small = m.node_rate_at_batch(10, true);
        let mid = m.node_rate_at_batch(1_000, true);
        let big = m.node_rate_at_batch(40_000, true);
        let huge = m.node_rate_at_batch(80_000, true);
        assert!(small < mid && mid < big, "ramp: {small} {mid} {big}");
        // saturation: 40k → 80k gains < 2%
        assert!((huge - big) / big < 0.02, "{big} vs {huge}");
        // host saturates near 9496 q/s
        assert!((big - 9496.0).abs() / 9496.0 < 0.02, "host sat {big}");
        // CSD saturates near 364 q/s
        let csd = m.node_rate_at_batch(40_000, false);
        assert!((csd - 364.0).abs() / 364.0 < 0.02, "csd sat {csd}");
    }

    #[test]
    fn paper_items_defaults() {
        assert_eq!(AppModel::paper_items(App::SpeechToText), 13_100);
        assert_eq!(AppModel::paper_items(App::Sentiment), 8_000_000);
    }
}
