//! Summary statistics over sample sets — used by the metrics layer, the
//! bench harness, and the experiment drivers to report mean / percentile
//! rows the way the paper's figures do. The serving layer
//! ([`crate::traffic`]) reports tail latency through the same code:
//! exact percentiles over the full sorted sample set, no sketching.
//!
//! # NaN policy
//!
//! Samples are expected to be NaN-free — every producer in this crate
//! records simulated durations, counts, or rates, none of which can be
//! NaN without an upstream bug. [`percentile_sorted`] and [`Summary::of`]
//! therefore `debug_assert!` NaN-freedom; in release builds they stay
//! deterministic instead of panicking by ordering with [`f64::total_cmp`]
//! (NaNs sort last, so low/mid percentiles of a lightly-polluted set are
//! still meaningful and bit-stable).

/// Aggregate summary of a set of f64 samples, including the tail
/// percentiles the serving experiments report (p95/p99/p99.9 — Fig 9's
/// y-axes).
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub count: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p90: f64,
    pub p95: f64,
    pub p99: f64,
    pub p999: f64,
}

impl Summary {
    /// Compute a summary; returns `None` for an empty sample set.
    pub fn of(samples: &[f64]) -> Option<Summary> {
        if samples.is_empty() {
            return None;
        }
        debug_assert!(samples.iter().all(|x| !x.is_nan()), "NaN sample");
        let mut sorted: Vec<f64> = samples.to_vec();
        sorted.sort_by(f64::total_cmp);
        let count = sorted.len();
        let mean = sorted.iter().sum::<f64>() / count as f64;
        let var = sorted.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / count as f64;
        // solana-lint: allow(no-unwrap, reason = "Summary::of returned None on empty input above, so sorted has at least one sample")
        let pct = |p: f64| percentile_sorted(&sorted, p).expect("non-empty");
        Some(Summary {
            count,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[count - 1],
            p50: pct(50.0),
            p90: pct(90.0),
            p95: pct(95.0),
            p99: pct(99.0),
            p999: pct(99.9),
        })
    }
}

/// Percentile over a pre-sorted slice using linear interpolation
/// (the "exclusive" definition, matching numpy's default closely enough
/// for reporting). Returns `None` for an empty slice; `pct` outside
/// `[0, 100]` is a caller bug (debug-asserted, clamped in release).
/// See the module docs for the NaN policy.
pub fn percentile_sorted(sorted: &[f64], pct: f64) -> Option<f64> {
    if sorted.is_empty() {
        return None;
    }
    debug_assert!((0.0..=100.0).contains(&pct), "percentile {pct} out of range");
    debug_assert!(sorted.iter().all(|x| !x.is_nan()), "NaN sample");
    let pct = pct.clamp(0.0, 100.0);
    if sorted.len() == 1 {
        return Some(sorted[0]);
    }
    let rank = pct / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    Some(if lo == hi {
        sorted[lo]
    } else {
        let w = rank - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    })
}

/// Online mean/variance accumulator (Welford) for streaming metrics.
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    pub fn new() -> Self {
        Welford { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_known_set() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]).unwrap();
        assert_eq!(s.count, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.p50 - 3.0).abs() < 1e-12);
        assert!(s.p95 <= s.p99 && s.p99 <= s.p999 && s.p999 <= s.max);
    }

    #[test]
    fn summary_empty_is_none() {
        assert!(Summary::of(&[]).is_none());
    }

    #[test]
    fn summary_single_sample_is_every_percentile() {
        let s = Summary::of(&[7.5]).unwrap();
        assert_eq!(s.count, 1);
        for v in [s.min, s.p50, s.p90, s.p95, s.p99, s.p999, s.max] {
            assert_eq!(v, 7.5);
        }
        assert_eq!(s.std, 0.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [10.0, 20.0, 30.0, 40.0];
        assert!((percentile_sorted(&xs, 0.0).unwrap() - 10.0).abs() < 1e-12);
        assert!((percentile_sorted(&xs, 100.0).unwrap() - 40.0).abs() < 1e-12);
        assert!((percentile_sorted(&xs, 50.0).unwrap() - 25.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_empty_is_none() {
        assert_eq!(percentile_sorted(&[], 50.0), None);
    }

    #[test]
    fn percentile_single_sample() {
        assert_eq!(percentile_sorted(&[3.25], 0.0), Some(3.25));
        assert_eq!(percentile_sorted(&[3.25], 99.9), Some(3.25));
    }

    #[test]
    #[cfg(not(debug_assertions))]
    fn nan_samples_are_deterministic_in_release() {
        // Release builds don't panic on NaN pollution: total_cmp sorts
        // NaNs last, so low percentiles stay meaningful and bit-stable.
        let xs = [1.0, 2.0, f64::NAN];
        let s = Summary::of(&xs).unwrap();
        assert_eq!(s.count, 3);
        let p0 = percentile_sorted(&[1.0, 2.0, f64::NAN], 0.0).unwrap();
        assert_eq!(p0, 1.0);
    }

    #[test]
    fn tail_percentiles_on_skewed_set() {
        // 1000 samples, one large outlier: p99.9 sees it, p95 does not.
        let mut xs: Vec<f64> = (0..999).map(|i| i as f64 / 1000.0).collect();
        xs.push(100.0);
        let s = Summary::of(&xs).unwrap();
        assert!(s.p95 < 1.0, "p95 {}", s.p95);
        assert!(s.p999 > 1.0, "p99.9 {}", s.p999);
        assert_eq!(s.max, 100.0);
    }

    #[test]
    fn welford_matches_batch() {
        let xs: Vec<f64> = (0..1000).map(|i| (i as f64).sin() * 10.0).collect();
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        let s = Summary::of(&xs).unwrap();
        assert!((w.mean() - s.mean).abs() < 1e-9);
        assert!((w.std() - s.std).abs() < 1e-9);
        assert_eq!(w.min(), s.min);
        assert_eq!(w.max(), s.max);
        assert_eq!(w.count(), 1000);
    }
}
