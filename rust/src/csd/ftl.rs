//! Flash translation layer: logical→physical page mapping, dynamic
//! striping across dies, garbage collection, and wear-leveling
//! accounting (§III-A1: "BE is also responsible for implementing flash
//! management routines, such as wear-leveling, address translation, and
//! garbage collection").
//!
//! Page-level mapping with a sparse table (only written LPNs are mapped —
//! the simulated drive is 12 TB but experiments touch a few GB). Writes
//! stripe round-robin across all dies for channel parallelism; GC is
//! greedy (min-valid victim) per die and is triggered when a die's free
//! block pool drops below a threshold. All timed flash operations go
//! through the [`FlashArray`] so GC traffic contends with foreground IO
//! exactly like on real hardware.

use std::collections::VecDeque;

use crate::util::FastMap;

use super::flash::{FlashArray, FlashConfig, PhysAddr};
use crate::sim::SimTime;

/// Per-die allocation state.
#[derive(Clone, Debug)]
struct DieState {
    free_blocks: VecDeque<u32>,
    open_block: u32,
    next_page: u32,
    /// valid page count per block
    valid: Vec<u32>,
    /// erase count per block (wear)
    erases: Vec<u32>,
}

/// FTL statistics for reports and tests.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct FtlStats {
    pub host_pages_written: u64,
    pub flash_pages_written: u64,
    pub gc_runs: u64,
    pub gc_pages_moved: u64,
    pub blocks_erased: u64,
}

impl FtlStats {
    /// Write amplification factor.
    pub fn waf(&self) -> f64 {
        if self.host_pages_written == 0 {
            1.0
        } else {
            self.flash_pages_written as f64 / self.host_pages_written as f64
        }
    }
}

pub struct Ftl {
    cfg: FlashConfig,
    l2p: FastMap<u64, PhysAddr>,
    p2l: FastMap<PhysAddr, u64>,
    dies: Vec<DieState>,
    next_die: usize,
    /// GC kicks in when a die's free pool drops below this many blocks.
    pub gc_low_water: usize,
    stats: FtlStats,
}

impl Ftl {
    pub fn new(cfg: FlashConfig) -> Ftl {
        let dies: Vec<DieState> = (0..cfg.dies())
            .map(|_| {
                // Block 0 opens first; the rest are free.
                let free: VecDeque<u32> = (1..cfg.blocks_per_die).collect();
                DieState {
                    free_blocks: free,
                    open_block: 0,
                    next_page: 0,
                    valid: vec![0; cfg.blocks_per_die as usize],
                    erases: vec![0; cfg.blocks_per_die as usize],
                }
            })
            .collect();
        Ftl {
            gc_low_water: 2usize.max(cfg.blocks_per_die as usize / 50),
            cfg,
            l2p: FastMap::default(),
            p2l: FastMap::default(),
            dies,
            next_die: 0,
            stats: FtlStats::default(),
        }
    }

    pub fn stats(&self) -> FtlStats {
        self.stats
    }

    pub fn mapped_pages(&self) -> usize {
        self.l2p.len()
    }

    /// Physical address of a logical page, if written.
    pub fn lookup(&self, lpn: u64) -> Option<PhysAddr> {
        self.l2p.get(&lpn).copied()
    }

    fn die_addr(&self, die_idx: usize, block: u32, page: u32) -> PhysAddr {
        PhysAddr {
            channel: (die_idx / self.cfg.dies_per_channel as usize) as u16,
            die: (die_idx % self.cfg.dies_per_channel as usize) as u16,
            block,
            page,
        }
    }

    /// Allocate the next physical page on a die (advancing the open
    /// block), assuming capacity checks already passed.
    fn alloc_on_die(&mut self, die_idx: usize) -> PhysAddr {
        let pages_per_block = self.cfg.pages_per_block;
        let d = &mut self.dies[die_idx];
        if d.next_page >= pages_per_block {
            let nb = d
                .free_blocks
                .pop_front()
                // solana-lint: allow(no-unwrap, reason = "maybe_gc runs before every alloc and asserts reclaimability; an empty pool here is a simulator bug, not a recoverable state")
                .expect("alloc_on_die called with empty free pool (GC failed?)");
            d.open_block = nb;
            d.next_page = 0;
        }
        let a = self.die_addr(die_idx, self.dies[die_idx].open_block, self.dies[die_idx].next_page);
        self.dies[die_idx].next_page += 1;
        a
    }

    /// Write one logical page at `now`; returns program completion time.
    pub fn write_page(&mut self, now: SimTime, flash: &mut FlashArray, lpn: u64) -> SimTime {
        self.stats.host_pages_written += 1;
        let mut t = now;
        // Invalidate the previous version.
        if let Some(old) = self.l2p.remove(&lpn) {
            self.p2l.remove(&old);
            let die = self.cfg.die_index(&old);
            let d = &mut self.dies[die];
            debug_assert!(d.valid[old.block as usize] > 0);
            d.valid[old.block as usize] -= 1;
        }
        let die_idx = self.next_die;
        self.next_die = (self.next_die + 1) % self.dies.len();
        t = self.maybe_gc(t, flash, die_idx);
        let addr = self.alloc_on_die(die_idx);
        self.dies[die_idx].valid[addr.block as usize] += 1;
        self.l2p.insert(lpn, addr);
        self.p2l.insert(addr, lpn);
        self.stats.flash_pages_written += 1;
        flash.program_page(t, addr)
    }

    /// Read one logical page; unmapped pages return a deterministic
    /// "unmapped read" (the controller answers zeroes without touching
    /// flash, like a real SSD).
    pub fn read_page(&mut self, now: SimTime, flash: &mut FlashArray, lpn: u64) -> SimTime {
        match self.l2p.get(&lpn) {
            Some(&addr) => flash.read_page(now, addr),
            None => now, // zero-fill response from the controller
        }
    }

    /// TRIM a logical page.
    pub fn trim(&mut self, lpn: u64) {
        if let Some(old) = self.l2p.remove(&lpn) {
            self.p2l.remove(&old);
            let die = self.cfg.die_index(&old);
            self.dies[die].valid[old.block as usize] -= 1;
        }
    }

    /// Run GC on a die if its free pool is low. Returns the (possibly
    /// advanced) time cursor — foreground writes stall behind GC exactly
    /// as they would in the device.
    fn maybe_gc(&mut self, now: SimTime, flash: &mut FlashArray, die_idx: usize) -> SimTime {
        let mut t = now;
        let mut guard = 0;
        while self.dies[die_idx].free_blocks.len() < self.gc_low_water {
            guard += 1;
            assert!(
                guard <= self.cfg.blocks_per_die,
                "GC cannot reclaim space: drive over-full on die {die_idx}"
            );
            // Victim: min-valid block that isn't the open block.
            let open = self.dies[die_idx].open_block;
            let victim = {
                let d = &self.dies[die_idx];
                let mut best: Option<(u32, u32)> = None; // (valid, block)
                for b in 0..self.cfg.blocks_per_die {
                    if b == open || d.free_blocks.contains(&b) {
                        continue;
                    }
                    let v = d.valid[b as usize];
                    if best.map(|(bv, _)| v < bv).unwrap_or(true) {
                        best = Some((v, b));
                    }
                }
                match best {
                    Some((_, b)) => b,
                    None => break, // nothing reclaimable
                }
            };
            self.stats.gc_runs += 1;
            // Relocate valid pages.
            let pages: Vec<(PhysAddr, u64)> = (0..self.cfg.pages_per_block)
                .filter_map(|p| {
                    let a = self.die_addr(die_idx, victim, p);
                    self.p2l.get(&a).map(|&l| (a, l))
                })
                .collect();
            for (old_addr, lpn) in pages {
                t = flash.read_page(t, old_addr);
                self.p2l.remove(&old_addr);
                self.dies[die_idx].valid[victim as usize] -= 1;
                let new_addr = self.alloc_on_die(die_idx);
                self.dies[die_idx].valid[new_addr.block as usize] += 1;
                self.l2p.insert(lpn, new_addr);
                self.p2l.insert(new_addr, lpn);
                self.stats.flash_pages_written += 1;
                self.stats.gc_pages_moved += 1;
                t = flash.program_page(t, new_addr);
            }
            debug_assert_eq!(self.dies[die_idx].valid[victim as usize], 0);
            // Erase and return to the pool.
            let a = self.die_addr(die_idx, victim, 0);
            t = flash.erase_block(t, a.channel, a.die);
            self.dies[die_idx].erases[victim as usize] += 1;
            self.stats.blocks_erased += 1;
            self.dies[die_idx].free_blocks.push_back(victim);
        }
        t
    }

    /// Max-min erase-count spread across all blocks (wear-leveling
    /// quality metric).
    pub fn wear_spread(&self) -> u32 {
        let mut lo = u32::MAX;
        let mut hi = 0;
        for d in &self.dies {
            for &e in &d.erases {
                lo = lo.min(e);
                hi = hi.max(e);
            }
        }
        if lo == u32::MAX {
            0
        } else {
            hi - lo
        }
    }

    /// Check internal consistency (tests): l2p and p2l are inverse maps
    /// and per-block valid counters match the reverse map.
    pub fn check_invariants(&self) -> Result<(), String> {
        if self.l2p.len() != self.p2l.len() {
            return Err(format!("l2p {} != p2l {}", self.l2p.len(), self.p2l.len()));
        }
        // Iterate in key order (FastMap order is hasher-dependent) so
        // the first-reported inconsistency is deterministic: the
        // smallest offending lpn, not whichever bucket hashed first.
        for (&lpn, addr) in crate::util::sorted_pairs(&self.l2p) {
            match self.p2l.get(addr) {
                Some(&back) if back == lpn => {}
                other => return Err(format!("p2l mismatch for lpn {lpn}: {other:?}")),
            }
        }
        let mut counts: std::collections::BTreeMap<(usize, u32), u32> = Default::default();
        for (addr, _lpn) in crate::util::sorted_pairs(&self.p2l) {
            *counts.entry((self.cfg.die_index(addr), addr.block)).or_insert(0) += 1;
        }
        for (di, d) in self.dies.iter().enumerate() {
            for b in 0..self.cfg.blocks_per_die {
                let expect = counts.get(&(di, b)).copied().unwrap_or(0);
                if d.valid[b as usize] != expect {
                    return Err(format!(
                        "die {di} block {b}: valid {} != reverse-map {expect}",
                        d.valid[b as usize]
                    ));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop::{check, forall};

    fn tiny() -> (Ftl, FlashArray) {
        let cfg = FlashConfig::tiny();
        (Ftl::new(cfg.clone()), FlashArray::new(cfg))
    }

    #[test]
    fn write_then_read_maps() {
        let (mut ftl, mut flash) = tiny();
        let t1 = ftl.write_page(0.0, &mut flash, 7);
        assert!(t1 > 0.0);
        assert!(ftl.lookup(7).is_some());
        let t2 = ftl.read_page(t1, &mut flash, 7);
        assert!(t2 > t1);
        ftl.check_invariants().unwrap();
    }

    #[test]
    fn unmapped_read_is_free() {
        let (mut ftl, mut flash) = tiny();
        let t = ftl.read_page(5.0, &mut flash, 999);
        assert_eq!(t, 5.0);
    }

    #[test]
    fn overwrite_invalidates_old() {
        let (mut ftl, mut flash) = tiny();
        ftl.write_page(0.0, &mut flash, 1);
        let first = ftl.lookup(1).unwrap();
        ftl.write_page(1.0, &mut flash, 1);
        let second = ftl.lookup(1).unwrap();
        assert_ne!(first, second);
        ftl.check_invariants().unwrap();
        assert_eq!(ftl.mapped_pages(), 1);
    }

    #[test]
    fn writes_stripe_across_dies() {
        let (mut ftl, mut flash) = tiny();
        ftl.write_page(0.0, &mut flash, 0);
        ftl.write_page(0.0, &mut flash, 1);
        let a = ftl.lookup(0).unwrap();
        let b = ftl.lookup(1).unwrap();
        assert_ne!(
            (a.channel, a.die),
            (b.channel, b.die),
            "consecutive writes land on different dies"
        );
    }

    #[test]
    fn gc_reclaims_space_under_overwrite_churn() {
        let (mut ftl, mut flash) = tiny();
        // Working set = 25% of capacity, overwritten many times: forces GC.
        let total_pages = FlashConfig::tiny().total_pages();
        let hot = total_pages / 4;
        let mut t = 0.0;
        for round in 0..12u64 {
            for lpn in 0..hot {
                t = ftl.write_page(t, &mut flash, lpn ^ (round % 2) * 3);
            }
        }
        let s = ftl.stats();
        assert!(s.gc_runs > 0, "GC must have run: {s:?}");
        assert!(s.waf() >= 1.0);
        ftl.check_invariants().unwrap();
    }

    #[test]
    fn trim_unmaps() {
        let (mut ftl, mut flash) = tiny();
        ftl.write_page(0.0, &mut flash, 3);
        ftl.trim(3);
        assert!(ftl.lookup(3).is_none());
        ftl.check_invariants().unwrap();
    }

    #[test]
    fn property_l2p_bijective_under_random_ops() {
        forall("ftl mapping stays bijective", 60, |g| {
            let (mut ftl, mut flash) = tiny();
            let space = FlashConfig::tiny().total_pages() / 2;
            let ops = g.usize(1..=300);
            let mut t = 0.0;
            for _ in 0..ops {
                let lpn = g.u64(0..=space - 1);
                match g.u64(0..=9) {
                    0 => ftl.trim(lpn),
                    1..=2 => {
                        t = ftl.read_page(t, &mut flash, lpn);
                    }
                    _ => {
                        t = ftl.write_page(t, &mut flash, lpn);
                    }
                }
            }
            ftl.check_invariants()?;
            check(ftl.stats().waf() >= 1.0, "WAF below 1")?;
            Ok(())
        });
    }

    /// D1 regression (ISSUE-7): `check_invariants` walks the maps in
    /// key order, so the first-reported inconsistency is the *smallest*
    /// offending lpn — identical across runs and across hashers — not
    /// whichever bucket the hash function happened to visit first.
    #[test]
    fn invariant_errors_are_deterministic_and_smallest_lpn_first() {
        let corrupt = || {
            let (mut ftl, mut flash) = tiny();
            let mut t = 0.0;
            for lpn in 0..20u64 {
                t = ftl.write_page(t, &mut flash, lpn);
            }
            // Break the back-pointers of two mappings (lengths stay
            // equal, so the length precheck passes and the sorted walk
            // must find them).
            for lpn in [12u64, 5] {
                let addr = ftl.lookup(lpn).expect("mapped");
                ftl.p2l.insert(addr, 900 + lpn);
            }
            ftl.check_invariants().expect_err("corruption must be detected")
        };
        let a = corrupt();
        let b = corrupt();
        assert_eq!(a, b, "identical corruption must report identically");
        assert!(
            a.contains("lpn 5"),
            "smallest corrupted lpn must be reported first, got: {a}"
        );
    }

    #[test]
    fn wear_spread_reported() {
        let (mut ftl, mut flash) = tiny();
        let mut t = 0.0;
        for i in 0..2000u64 {
            t = ftl.write_page(t, &mut flash, i % 40);
        }
        // churn happened; spread is finite and small relative to erases
        let s = ftl.stats();
        if s.blocks_erased > 0 {
            assert!(ftl.wear_spread() <= s.blocks_erased as u32);
        }
    }
}
