//! TCP/IP-tunnel frame protocol: the byte-level encapsulation the two
//! user-level daemons use to move TCP segments through NVMe vendor
//! commands and a pair of shared-DRAM ring buffers (§III-C3).
//!
//! Frame layout (little-endian):
//!
//! ```text
//! 0    4     8        12       16       20            20+len
//! MAGIC seq   ack      len      crc32    payload…
//! ```
//!
//! The ring buffer is a classic single-producer single-consumer byte
//! ring; the daemons poll it through [`crate::csd::nvme::Opcode::VendorTunnelTx`]
//! / `Rx` commands. Everything here is real code the simulated stack
//! executes — frames round-trip byte-exactly and CRCs are verified.

/// Frame header magic ("SolT").
pub const MAGIC: u32 = 0x536F_6C54;
/// Header bytes on the wire.
pub const HEADER_BYTES: usize = 20;
/// Max payload per frame (one ring slot / vendor command).
pub const MTU: usize = 16 * 1024;

/// A tunnel frame.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Frame {
    pub seq: u32,
    pub ack: u32,
    pub payload: Vec<u8>,
}

/// Encode/decode errors.
#[derive(Debug, PartialEq, Eq)]
pub enum FrameError {
    TooBig(usize),
    Short(usize),
    BadMagic(u32),
    BadLength { len: usize, have: usize },
    BadCrc { header: u32, computed: u32 },
}

impl std::fmt::Display for FrameError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FrameError::TooBig(n) => write!(f, "payload exceeds MTU: {n} > {MTU}"),
            FrameError::Short(n) => write!(f, "short buffer: {n} bytes"),
            FrameError::BadMagic(m) => write!(f, "bad magic {m:#x}"),
            FrameError::BadLength { len, have } => {
                write!(f, "length field {len} exceeds buffer {have}")
            }
            FrameError::BadCrc { header, computed } => {
                write!(f, "crc mismatch: header {header:#x} computed {computed:#x}")
            }
        }
    }
}

impl std::error::Error for FrameError {}

/// CRC-32 (IEEE, bitwise — cold path, clarity over speed).
pub fn crc32(data: &[u8]) -> u32 {
    let mut crc = 0xFFFF_FFFFu32;
    for &b in data {
        crc ^= b as u32;
        for _ in 0..8 {
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xEDB8_8320 & mask);
        }
    }
    !crc
}

impl Frame {
    pub fn new(seq: u32, ack: u32, payload: Vec<u8>) -> Result<Frame, FrameError> {
        if payload.len() > MTU {
            return Err(FrameError::TooBig(payload.len()));
        }
        Ok(Frame { seq, ack, payload })
    }

    /// Bytes on the wire for this frame.
    pub fn wire_bytes(&self) -> usize {
        HEADER_BYTES + self.payload.len()
    }

    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.wire_bytes());
        out.extend_from_slice(&MAGIC.to_le_bytes());
        out.extend_from_slice(&self.seq.to_le_bytes());
        out.extend_from_slice(&self.ack.to_le_bytes());
        assert!(self.payload.len() <= MTU, "frame payload exceeds MTU");
        // solana-lint: allow(lossy-cast, reason = "payload length is asserted <= MTU (16 KiB) on the previous line, so the u32 wire field cannot truncate")
        out.extend_from_slice(&(self.payload.len() as u32).to_le_bytes());
        out.extend_from_slice(&crc32(&self.payload).to_le_bytes());
        out.extend_from_slice(&self.payload);
        out
    }

    pub fn decode(buf: &[u8]) -> Result<(Frame, usize), FrameError> {
        if buf.len() < HEADER_BYTES {
            return Err(FrameError::Short(buf.len()));
        }
        // solana-lint: allow(no-unwrap, reason = "rd is only called with offsets 0..16 after the buf.len() >= HEADER_BYTES (20) check above, so the 4-byte slice always exists")
        let rd = |i: usize| u32::from_le_bytes(buf[i..i + 4].try_into().unwrap());
        let magic = rd(0);
        if magic != MAGIC {
            return Err(FrameError::BadMagic(magic));
        }
        let seq = rd(4);
        let ack = rd(8);
        let len = rd(12) as usize;
        let crc_hdr = rd(16);
        if len > MTU {
            return Err(FrameError::TooBig(len));
        }
        if buf.len() < HEADER_BYTES + len {
            return Err(FrameError::BadLength { len, have: buf.len() - HEADER_BYTES });
        }
        let payload = buf[HEADER_BYTES..HEADER_BYTES + len].to_vec();
        let computed = crc32(&payload);
        if computed != crc_hdr {
            return Err(FrameError::BadCrc { header: crc_hdr, computed });
        }
        Ok((Frame { seq, ack, payload }, HEADER_BYTES + len))
    }
}

/// Split an arbitrary byte stream into MTU-sized frames with running
/// sequence numbers starting at `seq0`.
pub fn segment(data: &[u8], seq0: u32) -> Vec<Frame> {
    let mut frames = Vec::with_capacity(data.len().div_ceil(MTU).max(1));
    if data.is_empty() {
        frames.push(Frame { seq: seq0, ack: 0, payload: Vec::new() });
        return frames;
    }
    for (i, chunk) in data.chunks(MTU).enumerate() {
        frames.push(Frame { seq: seq0.wrapping_add(i as u32), ack: 0, payload: chunk.to_vec() });
    }
    frames
}

/// Reassemble a contiguous run of frames back into the byte stream,
/// verifying sequence continuity.
pub fn reassemble(frames: &[Frame]) -> Result<Vec<u8>, FrameError> {
    let mut out = Vec::new();
    for (i, f) in frames.iter().enumerate() {
        if i > 0 {
            let expect = frames[0].seq.wrapping_add(i as u32);
            if f.seq != expect {
                return Err(FrameError::BadLength { len: f.seq as usize, have: expect as usize });
            }
        }
        out.extend_from_slice(&f.payload);
    }
    Ok(out)
}

/// SPSC byte ring buffer — the shared-DRAM structure both daemons map
/// (§III-C3: "two shared buffers on the on-board DDR").
pub struct RingBuffer {
    buf: Vec<u8>,
    head: usize, // producer cursor
    tail: usize, // consumer cursor
    len: usize,
}

impl RingBuffer {
    pub fn new(capacity: usize) -> RingBuffer {
        assert!(capacity > 0);
        RingBuffer { buf: vec![0; capacity], head: 0, tail: 0, len: 0 }
    }

    pub fn capacity(&self) -> usize {
        self.buf.len()
    }

    pub fn free(&self) -> usize {
        self.buf.len() - self.len
    }

    pub fn used(&self) -> usize {
        self.len
    }

    /// Push bytes; returns false (and writes nothing) when they don't fit.
    pub fn push(&mut self, data: &[u8]) -> bool {
        if data.len() > self.free() {
            return false;
        }
        for &b in data {
            self.buf[self.head] = b;
            self.head = (self.head + 1) % self.buf.len();
        }
        self.len += data.len();
        true
    }

    /// Pop up to `n` bytes.
    pub fn pop(&mut self, n: usize) -> Vec<u8> {
        let take = n.min(self.len);
        let mut out = Vec::with_capacity(take);
        for _ in 0..take {
            out.push(self.buf[self.tail]);
            self.tail = (self.tail + 1) % self.buf.len();
        }
        self.len -= take;
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop::{check, forall};

    #[test]
    fn frame_roundtrip() {
        let f = Frame::new(7, 3, b"hello tunnel".to_vec()).unwrap();
        let wire = f.encode();
        assert_eq!(wire.len(), f.wire_bytes());
        let (back, consumed) = Frame::decode(&wire).unwrap();
        assert_eq!(back, f);
        assert_eq!(consumed, wire.len());
    }

    #[test]
    fn corrupt_payload_detected() {
        let f = Frame::new(1, 0, vec![1, 2, 3, 4, 5]).unwrap();
        let mut wire = f.encode();
        wire[HEADER_BYTES + 2] ^= 0xFF;
        match Frame::decode(&wire) {
            Err(FrameError::BadCrc { .. }) => {}
            other => panic!("expected BadCrc, got {other:?}"),
        }
    }

    #[test]
    fn bad_magic_and_short_buffers() {
        assert!(matches!(Frame::decode(&[0u8; 4]), Err(FrameError::Short(4))));
        let mut wire = Frame::new(0, 0, vec![]).unwrap().encode();
        wire[0] = 0;
        assert!(matches!(Frame::decode(&wire), Err(FrameError::BadMagic(_))));
    }

    #[test]
    fn oversize_rejected() {
        assert!(matches!(
            Frame::new(0, 0, vec![0; MTU + 1]),
            Err(FrameError::TooBig(_))
        ));
    }

    #[test]
    fn segment_and_reassemble_stream() {
        let data: Vec<u8> = (0..100_000u32).map(|i| (i % 251) as u8).collect();
        let frames = segment(&data, 42);
        assert_eq!(frames.len(), data.len().div_ceil(MTU));
        assert_eq!(frames[0].seq, 42);
        let back = reassemble(&frames).unwrap();
        assert_eq!(back, data);
    }

    #[test]
    fn reassemble_detects_gap() {
        let data = vec![7u8; 3 * MTU];
        let mut frames = segment(&data, 0);
        frames.remove(1);
        assert!(reassemble(&frames).is_err());
    }

    #[test]
    fn crc_known_vector() {
        // standard IEEE CRC-32 of "123456789"
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn ring_buffer_wraparound() {
        let mut r = RingBuffer::new(8);
        assert!(r.push(&[1, 2, 3, 4, 5]));
        assert_eq!(r.pop(3), vec![1, 2, 3]);
        assert!(r.push(&[6, 7, 8, 9, 10])); // wraps
        assert_eq!(r.used(), 7);
        assert_eq!(r.pop(10), vec![4, 5, 6, 7, 8, 9, 10]);
        assert_eq!(r.free(), 8);
    }

    #[test]
    fn ring_buffer_rejects_overflow() {
        let mut r = RingBuffer::new(4);
        assert!(r.push(&[1, 2, 3]));
        assert!(!r.push(&[4, 5]));
        assert_eq!(r.used(), 3, "failed push writes nothing");
    }

    #[test]
    fn property_frame_roundtrip_and_stream() {
        forall("tunnel frame/stream roundtrip", 80, |g| {
            let n = g.usize(0..=3 * MTU);
            let data: Vec<u8> = (0..n).map(|_| g.u64(0..=255) as u8).collect();
            let frames = segment(&data, g.u64(0..=u32::MAX as u64) as u32);
            // every frame round-trips on the wire
            for f in &frames {
                let (back, _) = Frame::decode(&f.encode()).map_err(|e| e.to_string())?;
                check(back == *f, "frame roundtrip")?;
            }
            let back = reassemble(&frames).map_err(|e| e.to_string())?;
            check(back == data, "stream roundtrip")
        });
    }

    #[test]
    fn property_ring_fifo_order() {
        forall("ring preserves FIFO bytes", 60, |g| {
            let cap = g.usize(1..=256);
            let mut r = RingBuffer::new(cap);
            let mut model: std::collections::VecDeque<u8> = Default::default();
            for _ in 0..g.usize(1..=100) {
                if g.bool() {
                    let n = g.usize(0..=16);
                    let data: Vec<u8> = (0..n).map(|_| g.u64(0..=255) as u8).collect();
                    if r.push(&data) {
                        model.extend(&data);
                    } else {
                        check(data.len() > cap - model.len(), "push refused with space")?;
                    }
                } else {
                    let n = g.usize(0..=16);
                    let got = r.pop(n);
                    let expect: Vec<u8> =
                        (0..got.len()).map(|_| model.pop_front().unwrap()).collect();
                    check(got == expect, "FIFO order")?;
                }
            }
            check(r.used() == model.len(), "length tracking")
        });
    }
}
