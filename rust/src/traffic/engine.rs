//! Single-server serving engine: an arrival-fed frontend over the
//! *batch* scheduler's dispatch state machine.
//!
//! The engine owns a [`SchedState`] whose shards start **empty**:
//! arriving requests are routed round-robin to the drive holding their
//! data (`id % drives`), incrementing that drive's `shard_remaining`,
//! and the engine then invokes the exact same
//! [`SchedState::dispatch_host`] / [`SchedState::dispatch_csds`] bodies
//! the batch runner uses — flash reads, DLM locks, tunnel messages and
//! batch overheads are all modeled by the code that produced every
//! batch-mode figure, never re-implemented here.
//!
//! What the frontend adds on top:
//!
//! * **batch formation (size-or-timeout)** — dispatch is gated until
//!   either `min_batch` requests are queued or the oldest has waited
//!   `batch_timeout_s`. `min_batch = 1` (the default) dispatches
//!   immediately: latency-optimal, at the cost of per-batch overhead
//!   amortization — the knob Fig 9's batching ablation turns.
//! * **dispatch timing** — both [`DispatchMode`]s are honored.
//!   `Polling` quantizes dispatch to the paper's wake grid (arrivals
//!   wait for the next grid point — the dispatch-latency tax the CSD
//!   survey calls out); `EventDriven` dispatches on every arrival and
//!   ack, subject only to the formation gate.
//! * **per-request latency** — the engine remembers which queued
//!   requests each dispatched batch consumed (FIFO per drive, so the
//!   diff of `shard_remaining` around a dispatch call identifies them)
//!   and emits a [`Completion`] per request when the batch's ack pops.
//!
//! The engine's corpus is resident before serving starts: each drive is
//! ingested with a circular window of the dataset sized to cover the
//! largest possible single-dispatch read, and read offsets wrap so a
//! serving run of any length reads only resident bytes.
//!
//! # Ingest/update stream (the ISSUE-8 tentpole)
//!
//! [`ServeEngine::set_ingest`] arms a deterministic seeded Poisson
//! stream of item-sized *update writes* that interleaves with query
//! dispatch on the engine's own virtual-time loop. Each write rotates
//! round-robin across the server's drives and walks a circular offset
//! through the resident corpus, flowing through the full device write
//! path ([`crate::csd::Fcu::write`]) — so FTL garbage-collection stalls
//! land in die/channel occupancy that subsequent query reads (and their
//! per-request latencies) actually feel. The stream stops at its horizon
//! (the arrival window), updates are not requests (they never touch the
//! admission, completion, or conservation accounting), and an unarmed
//! engine draws no RNG and runs the exact pre-ISSUE-8 path.
//!
//! # Admission control (the ISSUE-5 tentpole)
//!
//! With [`EnginePolicy::admission_budget_s`] set, the engine becomes
//! SLO-aware: every offered request carries an implicit deadline budget
//! (arrival + the p99 SLO), and a request whose *estimated* completion
//! would blow that budget is **shed** at the door instead of queued.
//! The estimate is deliberately cheap and deterministic — outstanding
//! work (queued + in-flight requests) divided by the engine's nominal
//! service rate, plus the one-item service floor — so admission is a
//! queue-depth/estimated-wait gate, not an oracle. Shed requests are
//! answered immediately (a rejection is a response), excluded from the
//! latency percentiles, and accounted exactly:
//! `offered == accepted + shed` at every engine, every seed.
//!
//! # Hot-shard placement skew
//!
//! [`EnginePolicy::skew`] warps data placement from uniform round-robin
//! to a Zipf-like per-drive weighting (`w_d ∝ 1/(d+1)^skew`, realized
//! by a deterministic smooth weighted rotation). `skew = 0` is
//! bit-identical to the PR-4 round-robin; positive skew concentrates
//! requests on low-index drives — the hot-shard scenario that stresses
//! the wait estimate (a hot drive's backlog drains at one drive's rate,
//! not the engine's) and the fleet balancer above it.

use std::collections::VecDeque;

use crate::cluster::StorageServer;
use crate::csd::ftl::FtlStats;
use crate::faults::{AckOutcome, DriveFaults};
use crate::metrics::Metrics;
use crate::sched::{CsdBatchTiming, DispatchMode, Ev, HostBatchTiming, SchedConfig, SchedState, SHARD};
use crate::sim::EventQueue;
use crate::trace::{EngineProfile, SpanKind, Tracer};
use crate::util::Rng;
use crate::workloads::{AppModel, HOST_THREADS, ISP_CORES};

/// One served request: issue id, frontend arrival instant, and the
/// instant its batch's result reached the frontend (all on the engine's
/// absolute clock).
#[derive(Clone, Copy, Debug)]
pub(crate) struct Completion {
    pub id: u64,
    pub arrival: f64,
    pub done: f64,
}

/// A queued request awaiting dispatch.
#[derive(Clone, Copy, Debug)]
struct Queued {
    id: u64,
    arrival: f64,
}

/// An armed background ingest/update stream (ISSUE-8): seeded Poisson
/// item-sized writes, round-robin across drives, circular offsets
/// through the resident corpus, self-disarming past `horizon`.
struct IngestStream {
    rng: Rng,
    /// Mean update arrivals per second (per server).
    rate: f64,
    /// Next update's absolute instant (≤ `horizon` by construction).
    next: f64,
    /// Last instant an update may fire — the arrival window's end.
    horizon: f64,
    /// Round-robin target drive for the next update.
    drive: usize,
    /// Circular byte offset into the resident corpus.
    off: u64,
}

/// Batch-formation policy: release queued work to the scheduler when
/// either `min_batch` requests are waiting or the oldest has waited
/// `timeout_s`.
#[derive(Clone, Copy, Debug)]
pub struct FormationPolicy {
    pub min_batch: u64,
    pub timeout_s: f64,
}

impl Default for FormationPolicy {
    fn default() -> Self {
        // Dispatch immediately: latency-optimal serving. Raising
        // `min_batch` trades first-request wait for per-batch overhead
        // amortization (bounded by `timeout_s`).
        FormationPolicy { min_batch: 1, timeout_s: 0.05 }
    }
}

/// Everything the serving frontend layers on top of the scheduler for
/// one engine: batch formation, data-placement skew, and the admission
/// gate. Resolved from [`super::TrafficConfig`] by the fleet driver.
#[derive(Clone, Copy, Debug)]
pub(crate) struct EnginePolicy {
    pub formation: FormationPolicy,
    /// Zipf-like placement skew exponent (0 = uniform round-robin).
    pub skew: f64,
    /// SLO-derived deadline budget (s). `None` admits everything — the
    /// PR-4 behavior and the default.
    pub admission_budget_s: Option<f64>,
}

impl Default for EnginePolicy {
    fn default() -> Self {
        EnginePolicy {
            formation: FormationPolicy::default(),
            skew: 0.0,
            admission_budget_s: None,
        }
    }
}

/// Outcome of offering one request to the engine.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) enum Offer {
    /// Queued for dispatch; a [`Completion`] will eventually follow.
    Accepted,
    /// Shed by admission control: answered immediately with a
    /// rejection, never queued, never completed.
    Shed,
}

pub(crate) struct ServeEngine<'a> {
    st: SchedState<'a>,
    q: EventQueue<Ev>,
    metrics: Metrics,
    formation: FormationPolicy,
    event_driven: bool,
    /// Serving clock origin (corpus resident).
    t0: f64,
    /// Per-drive FIFO of queued requests (arrival order). A dispatch
    /// consumes from the front — the scheduler takes the oldest items of
    /// each shard.
    pending: Vec<VecDeque<Queued>>,
    queued: u64,
    /// Requests inside the in-flight host batch (at most one exists).
    host_inflight: Vec<Queued>,
    /// Requests inside each drive's in-flight CSD batch.
    csd_inflight: Vec<Vec<Queued>>,
    /// Next wake-grid point (polling mode; consumed only while work is
    /// queued, walked forward over idle stretches).
    next_wake: f64,
    /// Pending formation-timeout flush (event-driven mode only).
    flush_at: Option<f64>,
    /// Scratch: shard occupancy before a dispatch call, for the diff.
    prev_remaining: Vec<u64>,
    /// Per-drive placement counters for the smooth weighted rotation
    /// (one slot per routable drive).
    placed: Vec<u64>,
    /// Per-drive placement weights: all 1.0 at `skew = 0` (uniform
    /// round-robin), Zipf-like `1/(d+1)^skew` otherwise.
    place_weight: Vec<f64>,
    /// Admission gate: deadline budget (s), `None` admits everything.
    admission_budget: Option<f64>,
    /// Nominal service rate of this engine (items/s) — the
    /// per-shape service estimate the admission gate divides by.
    svc_rate: f64,
    /// One-item service floor on the engine's fastest unit (s).
    min_svc_s: f64,
    /// Requests accepted (queued or beyond) and shed, for exact
    /// `offered == accepted + shed` accounting.
    accepted: u64,
    shed: u64,
    /// Requests inside an in-flight batch (accepted − queued − done).
    inflight: u64,
    /// Drive-level fault stream (ISSUE-6). `None` — the default and the
    /// only state every pre-chaos caller sees — takes the exact
    /// fault-free code path: no draw, no branch beyond one `is_some`.
    faults: Option<DriveFaults>,
    /// Per-drive marker: the drive's outstanding CSD ack has already
    /// drawn `Stall` and been re-scheduled; deliver it on the next pop
    /// instead of drawing again.
    stall_armed: Vec<bool>,
    /// Requests whose results were destroyed by a drive fault (lost or
    /// corrupted ack, ISP crash). They are *not* completions and *not*
    /// shed — the front door's timeout/retry layer resolves them.
    lost: u64,
    /// Bytes of resident corpus per drive; read offsets wrap below it.
    corpus_bytes: u64,
    /// Largest single-dispatch read; offsets wrap once they pass
    /// `corpus_bytes - max_read_bytes`.
    max_read_bytes: u64,
    /// Background ingest/update stream (ISSUE-8). `None` — the default
    /// and the only state pre-ISSUE-8 callers see — draws no RNG and
    /// adds no events.
    ingest: Option<IngestStream>,
    /// One update write is one item (page-rounded by the FTL).
    ingest_item_bytes: u64,
    /// Update writes applied so far (survives stream disarm).
    ingest_writes: u64,
    /// Span tracer (ISSUE-9). `Tracer::Off` — the default and the only
    /// state every untraced caller sees — makes every record call a
    /// no-op, so untraced engines run the exact pre-trace path.
    tracer: Tracer,
    /// Instant the formation gate opened for the currently queued
    /// batch. Tracer bookkeeping only (maintained while the tracer is
    /// on); feeds the `formation_wait`/`dispatch_wait` split.
    gate_since: Option<f64>,
    /// Always-on execution counters (identical traced on and off —
    /// they never feed back into the simulation).
    profile: EngineProfile,
    completions: Vec<Completion>,
}

impl<'a> ServeEngine<'a> {
    pub(crate) fn new(
        model: &'a AppModel,
        cfg: &'a SchedConfig,
        policy: EnginePolicy,
    ) -> anyhow::Result<ServeEngine<'a>> {
        let formation = policy.formation;
        anyhow::ensure!(cfg.drives > 0, "need at least one drive for data");
        anyhow::ensure!(cfg.isp_drives <= cfg.drives, "isp_drives exceeds drives");
        anyhow::ensure!(cfg.use_host || cfg.use_isp(), "no compute nodes enabled");
        anyhow::ensure!(
            cfg.wakeup_secs > 0.0 && cfg.wakeup_secs.is_finite(),
            "wakeup_secs must be positive and finite, got {}",
            cfg.wakeup_secs
        );
        anyhow::ensure!(formation.min_batch >= 1, "min_batch must be >= 1");
        // A formation gate larger than what one dispatch can drain is a
        // degenerate config: the queue sits above min_batch forever and
        // every batch waits out the timeout instead (ISSUE-5 satellite).
        let dispatch_cap = (if cfg.use_host { cfg.host_batch() } else { 0 })
            + cfg.isp_drives as u64 * cfg.csd_batch;
        anyhow::ensure!(
            formation.min_batch <= dispatch_cap,
            "traffic.min_batch ({}) exceeds what this server can drain in one dispatch \
             (host batch {} + {} ISP drives x csd batch {} = {dispatch_cap}); lower min_batch \
             or raise the batch sizes",
            formation.min_batch,
            if cfg.use_host { cfg.host_batch() } else { 0 },
            cfg.isp_drives,
            cfg.csd_batch
        );
        anyhow::ensure!(
            formation.timeout_s >= 0.0 && formation.timeout_s.is_finite(),
            "batch timeout must be non-negative and finite, got {}",
            formation.timeout_s
        );
        anyhow::ensure!(
            policy.skew >= 0.0 && policy.skew.is_finite(),
            "traffic.skew must be non-negative and finite, got {}",
            policy.skew
        );
        if let Some(b) = policy.admission_budget_s {
            anyhow::ensure!(
                b > 0.0 && b.is_finite(),
                "admission deadline budget must be positive and finite, got {b}"
            );
        }
        let mut server = StorageServer::new(cfg.drives, cfg.csd.clone());

        // Resident corpus: a circular per-drive window twice the largest
        // single-dispatch read, so offsets always have room before the
        // wrap point.
        let max_read_bytes =
            (cfg.host_batch().max(cfg.csd_batch) * model.bytes_per_item).max(1);
        let corpus_bytes = 2 * max_read_bytes;
        let mut t0 = 0.0f64;
        for d in 0..cfg.drives {
            t0 = t0.max(server.ingest(0.0, d, SHARD, corpus_bytes)?);
        }

        let mut metrics = Metrics::new();
        let st = SchedState::new(model, cfg, server, vec![0; cfg.drives], t0, &mut metrics);
        // Requests may land only on drives something can serve: every
        // drive when the host computes, else just the ISP drives.
        let routable = if cfg.use_host { cfg.drives } else { cfg.isp_drives };
        let place_weight: Vec<f64> =
            (0..routable).map(|d| 1.0 / ((d + 1) as f64).powf(policy.skew)).collect();
        // Fastest single-item service this engine can deliver: the floor
        // of the admission gate's completion estimate.
        let min_svc_s = if cfg.use_host {
            model.host_batch_overhead + model.host_item_secs / HOST_THREADS
        } else {
            model.csd_batch_overhead + model.csd_item_secs / ISP_CORES
        };
        Ok(ServeEngine {
            event_driven: cfg.dispatch == DispatchMode::EventDriven,
            q: EventQueue::new(),
            metrics,
            formation,
            t0,
            pending: (0..cfg.drives).map(|_| VecDeque::new()).collect(),
            queued: 0,
            host_inflight: Vec::new(),
            csd_inflight: vec![Vec::new(); cfg.drives],
            next_wake: t0,
            flush_at: None,
            prev_remaining: vec![0; cfg.drives],
            placed: vec![0; routable],
            place_weight,
            admission_budget: policy.admission_budget_s,
            svc_rate: super::nominal_rate(model, cfg),
            min_svc_s,
            accepted: 0,
            shed: 0,
            inflight: 0,
            faults: None,
            stall_armed: vec![false; cfg.drives],
            lost: 0,
            corpus_bytes,
            max_read_bytes,
            ingest: None,
            ingest_item_bytes: model.bytes_per_item.max(1),
            ingest_writes: 0,
            tracer: Tracer::Off,
            gate_since: None,
            profile: EngineProfile::default(),
            completions: Vec::new(),
            st,
        })
    }

    /// Serving clock origin: the instant the resident corpus is in
    /// place. Drivers offset generator timelines by this.
    pub(crate) fn t0(&self) -> f64 {
        self.t0
    }

    pub(crate) fn state(&self) -> &SchedState<'a> {
        &self.st
    }

    /// The engine's private metrics registry (batch-latency histograms
    /// recorded by the shared dispatch bodies) — merged into the
    /// caller's registry when the run ends.
    pub(crate) fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// Completed requests since the last call (order: completion order).
    pub(crate) fn take_completions(&mut self) -> Vec<Completion> {
        std::mem::take(&mut self.completions)
    }

    /// Earliest instant at which this engine has internal work to do.
    pub(crate) fn next_time(&self) -> Option<f64> {
        let mut t = f64::INFINITY;
        if let Some(tq) = self.q.peek_time() {
            t = t.min(tq);
        }
        if !self.event_driven && self.queued > 0 {
            t = t.min(self.next_wake);
        }
        if let Some(tf) = self.flush_at {
            t = t.min(tf);
        }
        if let Some(ing) = &self.ingest {
            t = t.min(ing.next);
        }
        t.is_finite().then_some(t)
    }

    /// Requests shed by the admission gate so far.
    pub(crate) fn shed(&self) -> u64 {
        self.shed
    }

    /// Requests accepted (queued, in flight, or completed) so far.
    pub(crate) fn accepted(&self) -> u64 {
        self.accepted
    }

    /// Arm this engine's drive-fault stream (ISSUE-6). Called once by
    /// the fleet driver before serving starts; engines without a stream
    /// run the exact fault-free path.
    pub(crate) fn set_faults(&mut self, f: DriveFaults) {
        self.faults = Some(f);
    }

    /// Requests destroyed by drive faults so far (never completions).
    pub(crate) fn lost(&self) -> u64 {
        self.lost
    }

    /// Arm this engine's span tracer (ISSUE-9). Called once by the
    /// fleet driver before serving starts; engines left at
    /// [`Tracer::Off`] (the default) run the exact untraced path.
    pub(crate) fn set_tracer(&mut self, t: Tracer) {
        self.tracer = t;
    }

    /// Take the tracer back at end of run, leaving `Off` behind.
    pub(crate) fn take_tracer(&mut self) -> Tracer {
        std::mem::take(&mut self.tracer)
    }

    /// Always-on execution counters for this engine.
    pub(crate) fn profile(&self) -> &EngineProfile {
        &self.profile
    }

    /// Requests currently queued awaiting dispatch.
    pub(crate) fn queued(&self) -> u64 {
        self.queued
    }

    /// Requests currently inside an in-flight batch.
    pub(crate) fn inflight(&self) -> u64 {
        self.inflight
    }

    /// Whether this engine holds no queued or in-flight work — the
    /// drain-completion predicate (ISSUE-10): a draining server leaves
    /// the fleet only once this turns true.
    pub(crate) fn idle(&self) -> bool {
        self.queued == 0 && self.inflight == 0
    }

    /// Arm the background ingest/update stream (ISSUE-8): `rate`
    /// updates/s drawn from the caller's forked `rng`, firing until
    /// `horizon`. Called once by the fleet driver before serving starts;
    /// a non-positive rate arms nothing and draws no RNG (the quiet-plan
    /// contract), so unarmed engines run the exact ingest-free path.
    pub(crate) fn set_ingest(&mut self, rate: f64, horizon: f64, mut rng: Rng) {
        if rate > 0.0 {
            let next = self.t0 + rng.exponential(rate);
            if next > horizon {
                return; // window too short for even one update
            }
            self.ingest = Some(IngestStream { rng, rate, next, horizon, drive: 0, off: 0 });
        }
    }

    /// Background update writes applied so far.
    pub(crate) fn ingest_writes(&self) -> u64 {
        self.ingest_writes
    }

    /// This server's FTL counters rolled up across its drives, plus the
    /// worst per-drive wear spread.
    pub(crate) fn ftl_rollup(&self) -> (FtlStats, u32) {
        self.st.server.ftl_rollup()
    }

    /// The admission gate's completion estimate for a request offered
    /// now: outstanding work drained at the engine's nominal rate, plus
    /// the one-item service floor. Deliberately cheap — a queue-depth
    /// proxy, not a simulation — and deterministic. Also the base the
    /// front door's deadline-aware retry timeout scales from.
    pub(crate) fn estimated_completion_s(&self) -> f64 {
        (self.queued + self.inflight + 1) as f64 / self.svc_rate + self.min_svc_s
    }

    /// Pick the next request's home drive: [`super::smooth_pick`] over
    /// the routable drives. Uniform weights (skew 0) reproduce
    /// round-robin `0,1,…,n-1,0,…` exactly; skewed weights converge to
    /// the Zipf-like share deterministically.
    fn place(&mut self) -> usize {
        let best = super::smooth_pick(&self.placed, &self.place_weight);
        self.placed[best] += 1;
        best
    }

    /// Offer one request at absolute time `now` (must be ≥ every
    /// previously processed instant — the driver advances global time
    /// monotonically). Returns whether the request was accepted or shed
    /// by the admission gate.
    pub(crate) fn offer(&mut self, now: f64, id: u64) -> anyhow::Result<Offer> {
        if let Some(budget) = self.admission_budget {
            if self.estimated_completion_s() > budget {
                self.shed += 1;
                return Ok(Offer::Shed);
            }
        }
        self.accepted += 1;
        // With the host disabled only ISP drives can serve, so requests
        // are placed only on them (a request on a host-less non-ISP
        // drive could never be dispatched).
        let d = self.place();
        self.pending[d].push_back(Queued { id, arrival: now });
        self.st.shard_remaining[d] += 1;
        self.st.total_remaining += 1;
        self.queued += 1;
        // A drained drive was retired from the idle index (batch-mode
        // shards never refill); a request landing on it re-opens it.
        if d < self.st.cfg.isp_drives && self.csd_inflight[d].is_empty() {
            self.st.idle_isp.insert(d);
        }
        self.profile.max_queue_depth = self.profile.max_queue_depth.max(self.queued);
        if self.tracer.wants(id) {
            self.tracer.begin(id, now);
            self.tracer.mark_drive(id, SpanKind::Admission, now, d);
        }
        if self.tracer.is_on()
            && self.gate_since.is_none()
            && self.queued >= self.formation.min_batch
        {
            // The formation gate just opened for this batch: everything
            // between here and the actual dispatch is dispatch_wait
            // (the polling-grid tax), not formation_wait.
            self.gate_since = Some(now);
        }
        if self.event_driven {
            self.try_dispatch(now, false)?;
        } else {
            // Polling: the request waits for the wake grid. Walk the
            // grid cursor past any idle stretch so the next consumed
            // wake is the first grid point at or after this arrival.
            while self.next_wake < now {
                self.next_wake += self.st.cfg.wakeup_secs;
            }
        }
        Ok(Offer::Accepted)
    }

    /// Process exactly one internal event (the one at
    /// [`ServeEngine::next_time`]). Tie order is fixed and part of the
    /// bit-identity contract: sched-queue events first (acks mutate node
    /// state before any same-instant dispatch runs, matching the batch
    /// runner's calendar order), then ingest writes (device occupancy
    /// lands before a same-instant dispatch reads), then wakes/flushes.
    pub(crate) fn step(&mut self) -> anyhow::Result<()> {
        self.profile.events += 1;
        self.profile.queue_depth_sum += self.queued;
        self.profile.max_queue_depth = self.profile.max_queue_depth.max(self.queued);
        self.profile.max_inflight = self.profile.max_inflight.max(self.inflight);
        let tq = self.q.peek_time().unwrap_or(f64::INFINITY);
        let tw = if !self.event_driven && self.queued > 0 {
            self.next_wake
        } else {
            f64::INFINITY
        };
        let tf = self.flush_at.unwrap_or(f64::INFINITY);
        let ti = self.ingest.as_ref().map(|i| i.next).unwrap_or(f64::INFINITY);
        if tq <= tw && tq <= tf && tq <= ti {
            let Some((now, ev)) = self.q.pop() else {
                anyhow::bail!("event queue drained between peek and pop");
            };
            match ev {
                Ev::HostDone { items, dispatched } => {
                    self.profile.host_done_events += 1;
                    self.st.host_done(now, items, dispatched, &mut self.metrics);
                    debug_assert_eq!(self.host_inflight.len() as u64, items);
                    self.inflight -= items;
                    for r in std::mem::take(&mut self.host_inflight) {
                        self.completions.push(Completion { id: r.id, arrival: r.arrival, done: now });
                    }
                    if self.event_driven {
                        self.try_dispatch(now, false)?;
                    }
                }
                Ev::CsdAck { drive, items, dispatched } => {
                    self.profile.csd_ack_events += 1;
                    // Drive-fault hook (ISSUE-6): the fate of this batch
                    // ack is drawn from the engine's own seeded stream at
                    // this virtual-time event — see the faults module's
                    // determinism contract. Fault-free engines skip
                    // straight to delivery.
                    if let Some(f) = self.faults.as_mut() {
                        if self.stall_armed[drive] {
                            // Rescheduled stalled ack: deliver, no redraw.
                            self.stall_armed[drive] = false;
                        } else {
                            match f.ack_outcome(drive) {
                                AckOutcome::Deliver => {}
                                AckOutcome::Stall => {
                                    // The drive is stuck for stall_s: the
                                    // ack (and the drive's idle event) are
                                    // pushed into the future as one late
                                    // delivery of the same batch.
                                    self.stall_armed[drive] = true;
                                    let at = now + f.stall_s;
                                    if self.tracer.is_on() {
                                        for r in &self.csd_inflight[drive] {
                                            self.tracer.mark_drive(r.id, SpanKind::Stall, at, drive);
                                        }
                                    }
                                    self.q.schedule_at(at, Ev::CsdAck { drive, items, dispatched });
                                    return Ok(());
                                }
                                AckOutcome::Lost => {
                                    // The drive worked (or died trying);
                                    // the results never arrive. Free the
                                    // drive in the sched state exactly as
                                    // a delivery would, but emit no
                                    // completions — the front door's
                                    // timeout layer owns recovery. A
                                    // crashed ISP additionally leaves the
                                    // placement rotation (weight 0 →
                                    // plain-SSD fallback for new work).
                                    self.st.csd_ack(now, drive, items, dispatched, &mut self.metrics);
                                    debug_assert_eq!(self.csd_inflight[drive].len() as u64, items);
                                    self.inflight -= items;
                                    self.lost += self.csd_inflight[drive].len() as u64;
                                    self.csd_inflight[drive].clear();
                                    if f.crashed(drive) && drive < self.place_weight.len() {
                                        self.place_weight[drive] = 0.0;
                                    }
                                    if self.event_driven {
                                        self.try_dispatch(now, false)?;
                                    }
                                    return Ok(());
                                }
                            }
                        }
                    }
                    self.st.csd_ack(now, drive, items, dispatched, &mut self.metrics);
                    debug_assert_eq!(self.csd_inflight[drive].len() as u64, items);
                    self.inflight -= items;
                    for r in std::mem::take(&mut self.csd_inflight[drive]) {
                        self.completions.push(Completion { id: r.id, arrival: r.arrival, done: now });
                    }
                    if self.event_driven {
                        self.try_dispatch(now, false)?;
                    }
                }
                // Serving always dispatches CSDs with `coalesce = false`
                // and never schedules wakes on the sched queue.
                Ev::CsdAckBatch { .. } | Ev::Wake => {
                    unreachable!("batch-mode-only event in serving engine")
                }
            }
        } else if ti <= tw && ti <= tf {
            self.profile.ingest_events += 1;
            self.ingest_step()?;
        } else if tw <= tf {
            // Wake-grid point (polling): the grid is both the dispatch
            // clock and the formation timeout check.
            self.profile.wake_events += 1;
            let now = self.next_wake;
            self.next_wake += self.st.cfg.wakeup_secs;
            self.try_dispatch(now, false)?;
        } else {
            // Formation timeout (event-driven): the oldest queued
            // request has waited long enough — force the batch out.
            self.profile.flush_events += 1;
            let now = self
                .flush_at
                .take()
                .ok_or_else(|| anyhow::anyhow!("flush fired with no armed deadline"))?;
            if self.tracer.is_on() {
                // The flush *is* the gate opening for the queued batch.
                self.gate_since.get_or_insert(now);
            }
            self.try_dispatch(now, true)?;
        }
        Ok(())
    }

    /// Apply one background update write: overwrite one item of the
    /// resident corpus in place on the next round-robin drive. The write
    /// runs the full device path (FE overhead, FTL mapping, program,
    /// any foreground/background GC), so its die/channel occupancy is
    /// exactly what later query reads contend with. Updates are not
    /// requests: no queue, no completion, no admission interaction.
    fn ingest_step(&mut self) -> anyhow::Result<()> {
        let drives = self.st.cfg.drives;
        let bytes = self.ingest_item_bytes;
        let corpus = self.corpus_bytes;
        let Some(ing) = self.ingest.as_mut() else {
            anyhow::bail!("ingest event fired with no armed stream");
        };
        let now = ing.next;
        let d = ing.drive;
        ing.drive = (ing.drive + 1) % drives;
        if ing.off + bytes > corpus {
            ing.off = 0;
        }
        let off = ing.off;
        ing.off += bytes;
        // solana-lint: allow(rng-gate, reason = "an armed stream is never quiet: set_ingest only constructs IngestStream under a rate > 0.0 guard")
        ing.next = now + ing.rng.exponential(ing.rate);
        if ing.next > ing.horizon {
            // Past the arrival window: disarm so the run can drain.
            self.ingest = None;
        }
        self.ingest_writes += 1;
        self.st.server.update(now, d, SHARD, off, bytes)?;
        Ok(())
    }

    /// Oldest queued arrival across all drives (None when empty).
    fn oldest_arrival(&self) -> Option<f64> {
        self.pending
            .iter()
            .filter_map(|dq| dq.front().map(|r| r.arrival))
            .min_by(f64::total_cmp)
    }

    /// The size-or-timeout gate: release queued work when enough has
    /// accumulated or the head of the queue has waited out the timeout.
    fn gate_open(&self, now: f64) -> bool {
        if self.queued == 0 {
            return false;
        }
        if self.queued >= self.formation.min_batch {
            return true;
        }
        match self.oldest_arrival() {
            // Written as `now >= t + timeout` — the exact float
            // expression the flush deadline is computed with — so a
            // flush firing at its own deadline always finds the gate
            // open (no same-instant re-arm loop).
            Some(t) => now >= t + self.formation.timeout_s,
            None => false,
        }
    }

    /// Run the shared dispatch bodies (host first, then CSDs — the batch
    /// runner's wake order), map consumed shard items back to queued
    /// requests, and re-arm the formation flush if work stays queued.
    fn try_dispatch(&mut self, now: f64, force: bool) -> anyhow::Result<()> {
        // Fast path for the saturated case (every offer retries the
        // gate): when the host is busy and no ISP drive is idle, both
        // dispatch bodies are guaranteed no-ops, so skip the O(drives)
        // occupancy snapshots entirely. Offsets cannot have moved since
        // the last dispatch, so skipping `wrap_offsets` is a no-op too.
        let host_ready = self.st.cfg.use_host && self.st.host_idle;
        let csd_ready = self.st.cfg.use_isp() && !self.st.idle_isp.is_empty();
        if (host_ready || csd_ready) && (force || self.gate_open(now)) {
            // Arm the scheduler's read-only timing capture for each
            // dispatch pass (only while tracing); `collect_taken`
            // drains it into per-request span marks.
            let tracing = self.tracer.is_on();
            if tracing {
                self.st.trace = Some(Box::default());
            }
            self.prev_remaining.copy_from_slice(&self.st.shard_remaining);
            self.st.dispatch_host(now, &mut self.q)?;
            self.collect_taken(now, true)?;
            self.wrap_offsets();

            if tracing {
                self.st.trace = Some(Box::default());
            }
            self.prev_remaining.copy_from_slice(&self.st.shard_remaining);
            self.st.dispatch_csds(now, &mut self.q, false)?;
            self.collect_taken(now, false)?;
            self.wrap_offsets();
        }
        // Re-arm the formation timeout: in event-driven mode a closed
        // gate with queued work must still fire on its own.
        self.flush_at = if self.event_driven && self.queued > 0 && !self.gate_open(now) {
            self.oldest_arrival().map(|t| t + self.formation.timeout_s)
        } else {
            None
        };
        // Tracer bookkeeping: once the queue drops back below the
        // formation gate, the next batch's gate has not opened yet.
        if self.gate_since.is_some() && self.queued < self.formation.min_batch {
            self.gate_since = None;
        }
        Ok(())
    }

    /// Diff shard occupancy around a dispatch call and move the consumed
    /// requests (FIFO per drive) into the matching in-flight set. When
    /// the tracer is armed, the scheduler's per-batch timing capture
    /// ([`SchedState`]'s `trace`) is drained here into per-request span
    /// marks.
    fn collect_taken(&mut self, now: f64, host: bool) -> anyhow::Result<()> {
        let timing = self.st.trace.take();
        for d in 0..self.st.cfg.drives {
            let taken = self.prev_remaining[d] - self.st.shard_remaining[d];
            for _ in 0..taken {
                let r = self.pending[d].pop_front().ok_or_else(|| {
                    anyhow::anyhow!("dispatch consumed {taken} from shard {d} but its FIFO ran dry")
                })?;
                if host {
                    if let Some(ht) = timing.as_ref().and_then(|t| t.host) {
                        self.mark_host_batch(r, now, ht);
                    }
                    self.host_inflight.push(r);
                } else {
                    if let Some(ct) = timing
                        .as_ref()
                        .and_then(|t| t.csd.iter().find(|(dd, _)| *dd == d).map(|&(_, c)| c))
                    {
                        self.mark_csd_batch(r, now, d, ct);
                    }
                    self.csd_inflight[d].push(r);
                }
            }
            self.queued -= taken;
            self.inflight += taken;
        }
        Ok(())
    }

    /// Emit the span marks for one request consumed by a host batch:
    /// formation/dispatch waits, any GC overhang its reads queued
    /// behind, the SSD read over PCIe (ECC decode split out), and host
    /// compute. Marks *end* phases — see the trace module contract.
    fn mark_host_batch(&mut self, r: Queued, now: f64, ht: HostBatchTiming) {
        if !self.tracer.wants(r.id) {
            return;
        }
        let gate = self.gate_since.unwrap_or(now).max(r.arrival).min(now);
        self.tracer.mark(r.id, SpanKind::FormationWait, gate);
        self.tracer.mark(r.id, SpanKind::DispatchWait, now);
        let gc_end = if ht.gc_overhang > 0.0 {
            let t = (now + ht.gc_overhang).min(ht.io_done);
            self.tracer.mark(r.id, SpanKind::GcStall, t);
            t
        } else {
            now
        };
        let ecc_start = (ht.io_done - ht.ecc_secs).max(gc_end);
        self.tracer.mark(r.id, SpanKind::HostIo, ecc_start);
        if ht.ecc_secs > 0.0 {
            self.tracer.mark(r.id, SpanKind::Ecc, ht.io_done);
        }
        self.tracer.mark(r.id, SpanKind::HostCompute, ht.done);
    }

    /// Emit the span marks for one request consumed by a CSD batch on
    /// `drive`: waits, the dispatch tunnel hop, GC overhang, the flash
    /// array read (ECC decode split out), ISP compute, and the result
    /// tunnel hop back to the host.
    fn mark_csd_batch(&mut self, r: Queued, now: f64, drive: usize, ct: CsdBatchTiming) {
        if !self.tracer.wants(r.id) {
            return;
        }
        let gate = self.gate_since.unwrap_or(now).max(r.arrival).min(now);
        self.tracer.mark_drive(r.id, SpanKind::FormationWait, gate, drive);
        self.tracer.mark_drive(r.id, SpanKind::DispatchWait, now, drive);
        self.tracer.mark_drive(r.id, SpanKind::Tunnel, ct.delivered, drive);
        let gc_end = if ct.gc_overhang > 0.0 {
            let t = (ct.delivered + ct.gc_overhang).min(ct.read_done);
            self.tracer.mark_drive(r.id, SpanKind::GcStall, t, drive);
            t
        } else {
            ct.delivered
        };
        let ecc_start = (ct.read_done - ct.ecc_secs).max(gc_end);
        self.tracer.mark_drive(r.id, SpanKind::FlashRead, ecc_start, drive);
        if ct.ecc_secs > 0.0 {
            self.tracer.mark_drive(r.id, SpanKind::Ecc, ct.read_done, drive);
        }
        self.tracer.mark_drive(r.id, SpanKind::IspCompute, ct.done, drive);
        self.tracer.mark_drive(r.id, SpanKind::Tunnel, ct.ack, drive);
    }

    /// Wrap read cursors so the next dispatch's largest possible read
    /// stays inside the resident corpus window (circular re-read of
    /// resident data — serving reads the same stored dataset forever).
    fn wrap_offsets(&mut self) {
        for off in &mut self.st.shard_offset {
            if *off + self.max_read_bytes > self.corpus_bytes {
                *off = 0;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::App;

    fn engine_cfg(dispatch: DispatchMode) -> SchedConfig {
        SchedConfig {
            csd_batch: 500,
            batch_ratio: 26.0,
            drives: 4,
            isp_drives: 4,
            dispatch,
            ..SchedConfig::default()
        }
    }

    /// Drive an engine by hand: `n` requests at fixed spacing; every
    /// request must complete exactly once, in both dispatch modes.
    #[test]
    fn engine_serves_every_request_exactly_once() {
        for dispatch in [DispatchMode::Polling, DispatchMode::EventDriven] {
            let model = AppModel::for_app(App::Sentiment, 1_000);
            let cfg = engine_cfg(dispatch);
            let mut e = ServeEngine::new(&model, &cfg, EnginePolicy::default()).unwrap();
            let t0 = e.t0();
            let n: u64 = 1_000;
            let mut next_arrival = 0u64;
            let mut done = std::collections::BTreeSet::new();
            loop {
                let ta = (next_arrival < n).then(|| t0 + next_arrival as f64 * 1e-4);
                match (ta, e.next_time()) {
                    (Some(a), Some(t)) if a <= t => {
                        e.offer(a, next_arrival).unwrap();
                        next_arrival += 1;
                    }
                    (Some(a), None) => {
                        e.offer(a, next_arrival).unwrap();
                        next_arrival += 1;
                    }
                    (_, Some(_)) => e.step().unwrap(),
                    (None, None) => break,
                }
                for c in e.take_completions() {
                    assert!(c.done >= c.arrival, "{dispatch:?}: time travel");
                    assert!(done.insert(c.id), "{dispatch:?}: duplicate completion {}", c.id);
                }
            }
            assert_eq!(done.len() as u64, n, "{dispatch:?}: every request served once");
            assert_eq!(e.state().host_items + e.state().csd_items, n);
        }
    }

    /// ISSUE-8: an armed ingest stream interleaves update writes with
    /// query serving, flows through the drives' FTLs (host pages written
    /// grow beyond the resident corpus), disarms at its horizon so the
    /// run drains, never perturbs request conservation, and is a pure
    /// function of its seed.
    #[test]
    fn ingest_stream_interleaves_and_disarms_at_horizon() {
        let run = |seed: u64| {
            let model = AppModel::for_app(App::Sentiment, 500);
            let cfg = engine_cfg(DispatchMode::EventDriven);
            let mut e = ServeEngine::new(&model, &cfg, EnginePolicy::default()).unwrap();
            let t0 = e.t0();
            let (corpus_only, _) = e.ftl_rollup();
            e.set_ingest(1_000.0, t0 + 2.0, Rng::new(seed));
            let n: u64 = 500;
            let mut next_arrival = 0u64;
            let mut done = std::collections::BTreeSet::new();
            loop {
                let ta = (next_arrival < n).then(|| t0 + next_arrival as f64 * 4e-3);
                match (ta, e.next_time()) {
                    (Some(a), Some(t)) if a <= t => {
                        e.offer(a, next_arrival).unwrap();
                        next_arrival += 1;
                    }
                    (Some(a), None) => {
                        e.offer(a, next_arrival).unwrap();
                        next_arrival += 1;
                    }
                    (_, Some(_)) => e.step().unwrap(),
                    (None, None) => break,
                }
                for c in e.take_completions() {
                    assert!(done.insert(c.id), "duplicate completion {}", c.id);
                }
            }
            assert_eq!(done.len() as u64, n, "updates must not eat requests");
            assert!(e.ingest_writes() > 0, "a 1 kHz stream over 2 s must fire");
            assert!(e.next_time().is_none(), "the stream disarmed; the run drained");
            let (ftl, _) = e.ftl_rollup();
            assert!(
                ftl.host_pages_written > corpus_only.host_pages_written,
                "updates flow through the FTL write path"
            );
            (e.ingest_writes(), ftl)
        };
        let (w1, f1) = run(7);
        let (w2, f2) = run(7);
        assert_eq!(w1, w2, "same seed, same update count");
        assert_eq!(f1, f2, "same seed, same FTL counters");
        let (w3, _) = run(8);
        assert!(w3 > 0);
    }

    #[test]
    fn host_less_engine_places_requests_only_on_isp_drives() {
        // Regression: with use_host = false and isp_drives < drives,
        // round-robin placement over *all* drives would park requests on
        // drives nothing can dispatch (polling would wake forever,
        // event-driven would lose requests). Placement is restricted to
        // the drives that can actually serve.
        let model = AppModel::for_app(App::Sentiment, 200);
        let cfg = SchedConfig {
            csd_batch: 50,
            drives: 4,
            isp_drives: 2,
            use_host: false,
            dispatch: DispatchMode::EventDriven,
            ..SchedConfig::default()
        };
        let mut e = ServeEngine::new(&model, &cfg, EnginePolicy::default()).unwrap();
        let t0 = e.t0();
        for i in 0..200u64 {
            e.offer(t0 + i as f64 * 1e-3, i).unwrap();
            while let Some(t) = e.next_time() {
                if t > t0 + (i + 1) as f64 * 1e-3 {
                    break;
                }
                e.step().unwrap();
            }
        }
        let mut served = e.take_completions().len();
        while e.next_time().is_some() {
            e.step().unwrap();
            served += e.take_completions().len();
        }
        assert_eq!(served, 200, "every request lands on a dispatchable drive");
        assert_eq!(e.state().csd_items, 200);
        assert_eq!(e.state().host_items, 0);
    }

    #[test]
    fn formation_gate_holds_small_batches_until_timeout() {
        let model = AppModel::for_app(App::Sentiment, 100);
        let cfg = engine_cfg(DispatchMode::EventDriven);
        let formation = FormationPolicy { min_batch: 50, timeout_s: 0.5 };
        let mut e =
            ServeEngine::new(&model, &cfg, EnginePolicy { formation, ..Default::default() })
                .unwrap();
        let t0 = e.t0();
        e.offer(t0, 0).unwrap();
        // Below min_batch: nothing dispatched, a flush is armed instead.
        assert!(e.host_inflight.is_empty() && e.queued == 1);
        let flush = e.next_time().expect("flush deadline pending");
        assert!((flush - (t0 + 0.5)).abs() < 1e-12, "flush at arrival + timeout");
        // The flush forces the lone request out; it completes.
        let mut served = 0;
        while e.next_time().is_some() {
            e.step().unwrap();
            served += e.take_completions().len();
        }
        assert_eq!(served, 1);
    }

    #[test]
    fn polling_engine_quantizes_dispatch_to_the_grid() {
        let model = AppModel::for_app(App::Sentiment, 100);
        let cfg = engine_cfg(DispatchMode::Polling);
        let mut e = ServeEngine::new(&model, &cfg, EnginePolicy::default()).unwrap();
        let t0 = e.t0();
        // Arrive just after a grid point: the request waits ~one period.
        e.offer(t0 + 0.01, 0).unwrap();
        let wake = e.next_time().unwrap();
        assert!(wake >= t0 + cfg.wakeup_secs - 1e-12, "dispatch waits for the grid: {wake}");
        let mut comps = Vec::new();
        while e.next_time().is_some() {
            e.step().unwrap();
            comps.extend(e.take_completions());
        }
        assert_eq!(comps.len(), 1);
        // Latency includes the grid wait the event-driven engine avoids.
        assert!(comps[0].done - comps[0].arrival >= cfg.wakeup_secs - 0.01 - 1e-12);
    }

    #[test]
    fn admission_sheds_when_estimated_wait_blows_the_budget() {
        // A tight budget over a saturated engine: the first requests fit
        // under the deadline, a same-instant stampede behind them must
        // shed, and the accounting is exact (offered == accepted + shed).
        let model = AppModel::for_app(App::Sentiment, 1_000);
        let cfg = engine_cfg(DispatchMode::EventDriven);
        let budget = 0.5; // ≈ 0.5 s of backlog at the engine's rate
        let policy = EnginePolicy { admission_budget_s: Some(budget), ..Default::default() };
        let mut e = ServeEngine::new(&model, &cfg, policy).unwrap();
        let t0 = e.t0();
        let offered: u64 = 50_000; // far beyond budget × svc_rate
        let mut accepted = 0u64;
        let mut shed = 0u64;
        for i in 0..offered {
            match e.offer(t0, i).unwrap() {
                Offer::Accepted => accepted += 1,
                Offer::Shed => shed += 1,
            }
        }
        assert!(shed > 0, "a same-instant stampede must shed");
        assert!(accepted > 0, "the head of the stampede fits the budget");
        assert_eq!(accepted + shed, offered, "exact admission accounting");
        assert_eq!((e.accepted(), e.shed()), (accepted, shed));
        // Every *accepted* request still completes exactly once.
        let mut done = 0u64;
        while e.next_time().is_some() {
            e.step().unwrap();
            done += e.take_completions().len() as u64;
        }
        assert_eq!(done, accepted, "accepted requests are served exactly once");
    }

    #[test]
    fn admission_never_sheds_an_idle_engine() {
        // The deadline budget is generous relative to a lone request's
        // service time, so a trickle through an idle engine admits 100%.
        let model = AppModel::for_app(App::Sentiment, 100);
        let cfg = engine_cfg(DispatchMode::EventDriven);
        let policy = EnginePolicy { admission_budget_s: Some(1.0), ..Default::default() };
        let mut e = ServeEngine::new(&model, &cfg, policy).unwrap();
        let t0 = e.t0();
        for i in 0..100u64 {
            // Drain fully between arrivals: the engine is idle each time.
            assert_eq!(e.offer(t0 + i as f64, i).unwrap(), Offer::Accepted, "request {i}");
            while e.next_time().is_some() {
                e.step().unwrap();
            }
        }
        assert_eq!(e.shed(), 0);
        assert_eq!(e.take_completions().len(), 100);
    }

    #[test]
    fn zero_skew_placement_is_plain_round_robin() {
        // skew = 0 must reproduce the PR-4 `id % drives` rotation
        // exactly: drive d gets requests d, d+4, d+8, … in order.
        let model = AppModel::for_app(App::Sentiment, 100);
        let cfg = engine_cfg(DispatchMode::Polling); // polling: offer only queues
        let mut e = ServeEngine::new(&model, &cfg, EnginePolicy::default()).unwrap();
        let t0 = e.t0();
        for i in 0..16u64 {
            e.offer(t0, i).unwrap();
        }
        for d in 0..4usize {
            let ids: Vec<u64> = e.pending[d].iter().map(|r| r.id).collect();
            let want: Vec<u64> = (0..4).map(|k| d as u64 + 4 * k).collect();
            assert_eq!(ids, want, "drive {d}");
        }
    }

    #[test]
    fn positive_skew_concentrates_placement_on_low_drives() {
        // skew = 1 over 4 drives is the Zipf weighting 1 : 1/2 : 1/3 :
        // 1/4 — drive 0 takes ~48% of placements (vs 25% uniform), and
        // the per-drive counts are strictly decreasing.
        let model = AppModel::for_app(App::Sentiment, 100);
        let cfg = engine_cfg(DispatchMode::Polling);
        let policy = EnginePolicy { skew: 1.0, ..Default::default() };
        let mut e = ServeEngine::new(&model, &cfg, policy).unwrap();
        let t0 = e.t0();
        let n = 1_000u64;
        for i in 0..n {
            e.offer(t0, i).unwrap();
        }
        let counts: Vec<usize> = e.pending.iter().map(|q| q.len()).collect();
        assert_eq!(counts.iter().sum::<usize>() as u64, n);
        for w in counts.windows(2) {
            assert!(w[0] > w[1], "hot drives come first: {counts:?}");
        }
        let share0 = counts[0] as f64 / n as f64;
        assert!(
            (share0 - 0.48).abs() < 0.02,
            "drive 0 share {share0:.3} should track its 1/H4 Zipf share"
        );
    }

    #[test]
    fn degenerate_engine_policies_rejected() {
        let model = AppModel::for_app(App::Sentiment, 100);
        let cfg = engine_cfg(DispatchMode::EventDriven);
        // min_batch beyond the single-dispatch drain capacity
        // (host 500×26 + 4×500 = 15_000 for this config).
        let big = EnginePolicy {
            formation: FormationPolicy { min_batch: 15_001, timeout_s: 0.05 },
            ..Default::default()
        };
        assert!(ServeEngine::new(&model, &cfg, big).is_err());
        let at_cap = EnginePolicy {
            formation: FormationPolicy { min_batch: 15_000, timeout_s: 0.05 },
            ..Default::default()
        };
        assert!(ServeEngine::new(&model, &cfg, at_cap).is_ok(), "the cap itself is fine");
        // negative / non-finite skew
        let neg = EnginePolicy { skew: -0.5, ..Default::default() };
        assert!(ServeEngine::new(&model, &cfg, neg).is_err());
        let nan = EnginePolicy { skew: f64::NAN, ..Default::default() };
        assert!(ServeEngine::new(&model, &cfg, nan).is_err());
        // non-positive admission budget
        let bad_budget = EnginePolicy { admission_budget_s: Some(0.0), ..Default::default() };
        assert!(ServeEngine::new(&model, &cfg, bad_budget).is_err());
    }
}
