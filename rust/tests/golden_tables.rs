//! Golden-table regression net (ISSUE-3 satellite): every experiment
//! table the repo emits — Fig 5 × 3 apps, Fig 6, Fig 7, Table I, the
//! power breakdown, ablations A1–A4, the Fig 8 fleet sweep, the Fig 9
//! serving-latency sweep, the Fig 10 autoscaling study, the Fig 11
//! availability-under-faults study, the Fig 12 elastic-fleet study, and
//! the Fig 13 write + GC interference study — is
//! serialized at `--scale 0.01` and diffed **cell-by-cell** against a
//! committed snapshot under `tests/golden/`. The comparison is an exact
//! string match on the tables' fixed-precision formatting, so any
//! single-cell perturbation (a float op reordered, a counter off by
//! one, a format width change) trips the net.
//!
//! Workflow:
//!
//! * **normal run** — every table must match its `tests/golden/*.golden`
//!   snapshot; a mismatch panics with the exact (row, column) and both
//!   cell values, and drops the fresh rendering in
//!   `target/golden-diffs/` for CI to upload.
//! * **`SOLANA_UPDATE_GOLDEN=1 cargo test --test golden_tables`** —
//!   regenerate every snapshot in place (then commit the diff).
//! * **bootstrap** — a snapshot that does not exist yet is written and
//!   reported (not failed), so the first toolchain-equipped run after a
//!   table is added produces the files to commit. A clean checkout with
//!   committed goldens never takes this path.
//!
//! Tables are deterministic by construction: every sweep runs on the
//! deterministic [`exp::pool`] (input-order results, thread count only
//! changes wall-clock) over a virtual-time simulator.

use std::fs;
use std::path::PathBuf;

use solana_isp::exp::{self, Scale};
use solana_isp::metrics::Table;
use solana_isp::workloads::App;

/// All goldens are pinned at 1% of the paper's dataset sizes: big
/// enough to exercise every code path (multi-batch runs, fair tails,
/// coalescing), small enough that the full net regenerates in seconds.
const SCALE: Scale = Scale(0.01);

fn golden_dir() -> PathBuf {
    // Anchored to the package root, not the cwd — `cargo test` runs
    // integration tests from the package dir but this stays correct
    // from the workspace root too.
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests").join("golden")
}

fn diff_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("target").join("golden-diffs")
}

/// Snapshot format: a `#`-prefixed title line, then the table's CSV
/// (headers + rows) with every cell's exact formatted string.
fn serialize(t: &Table) -> String {
    format!("# {}\n{}", t.title, t.to_csv())
}

/// Cell-by-cell comparison; returns the first difference with its
/// coordinates ("line" counts the title as line 0, headers as line 1).
fn diff_tables(name: &str, golden: &str, fresh: &str) -> Result<(), String> {
    let g: Vec<&str> = golden.lines().collect();
    let f: Vec<&str> = fresh.lines().collect();
    if g.len() != f.len() {
        return Err(format!(
            "{name}: line count changed: golden {} vs fresh {}",
            g.len(),
            f.len()
        ));
    }
    for (line_no, (gl, fl)) in g.iter().zip(&f).enumerate() {
        if gl == fl {
            continue;
        }
        let gc: Vec<&str> = gl.split(',').collect();
        let fc: Vec<&str> = fl.split(',').collect();
        if gc.len() != fc.len() {
            return Err(format!(
                "{name} line {line_no}: column count changed: golden {} vs fresh {}",
                gc.len(),
                fc.len()
            ));
        }
        // Unequal lines must differ in some cell (cells joined by ','
        // reproduce the line), so this loop always returns.
        for (col, (gcell, fcell)) in gc.iter().zip(&fc).enumerate() {
            if gcell != fcell {
                return Err(format!(
                    "{name} line {line_no} col {col}: golden '{gcell}' != fresh '{fcell}'"
                ));
            }
        }
    }
    Ok(())
}

/// Check one table against its snapshot (or write it, per the module
/// docs' workflow).
fn check_table(name: &str, table: &Table) {
    let fresh = serialize(table);
    let dir = golden_dir();
    let path = dir.join(format!("{name}.golden"));
    let update = std::env::var("SOLANA_UPDATE_GOLDEN").ok().as_deref() == Some("1");
    if update || !path.exists() {
        // Tamper-evidence: once baselines are committed, CI sets
        // SOLANA_REQUIRE_GOLDEN=1 so a deleted/renamed snapshot (or a
        // typo'd table name) fails instead of silently re-bootstrapping
        // and disabling that table's net forever.
        let strict = std::env::var("SOLANA_REQUIRE_GOLDEN").ok().as_deref() == Some("1");
        if !update && strict {
            panic!(
                "golden snapshot missing: {} (SOLANA_REQUIRE_GOLDEN=1 forbids bootstrap; use SOLANA_UPDATE_GOLDEN=1 to regenerate deliberately)",
                path.display()
            );
        }
        fs::create_dir_all(&dir).expect("create tests/golden");
        fs::write(&path, &fresh).expect("write golden snapshot");
        if !update {
            eprintln!(
                "golden: bootstrapped {} — commit it to pin this table",
                path.display()
            );
        }
        return;
    }
    let golden = fs::read_to_string(&path).expect("read golden snapshot");
    if let Err(msg) = diff_tables(name, &golden, &fresh) {
        let dd = diff_dir();
        fs::create_dir_all(&dd).expect("create golden-diffs");
        fs::write(dd.join(format!("{name}.fresh")), &fresh).expect("write fresh copy");
        panic!(
            "golden table drift: {msg}\nfresh copy: {}/{name}.fresh\naccept with: SOLANA_UPDATE_GOLDEN=1 cargo test --test golden_tables",
            dd.display()
        );
    }
}

// ---- one test per table: independent failures, parallel runs ---------

#[test]
fn golden_fig5a_speech() {
    check_table("fig5a_speech", &exp::fig5(App::SpeechToText, SCALE).unwrap());
}

#[test]
fn golden_fig5b_recommender() {
    check_table("fig5b_recommender", &exp::fig5(App::Recommender, SCALE).unwrap());
}

#[test]
fn golden_fig5c_sentiment() {
    check_table("fig5c_sentiment", &exp::fig5(App::Sentiment, SCALE).unwrap());
}

#[test]
fn golden_fig6() {
    check_table("fig6", &exp::fig6(SCALE).unwrap());
}

#[test]
fn golden_fig7() {
    check_table("fig7", &exp::fig7(SCALE).unwrap());
}

#[test]
fn golden_table1() {
    check_table("table1", &exp::table1(SCALE).unwrap());
}

#[test]
fn golden_power_breakdown() {
    check_table("power", &exp::power_breakdown());
}

#[test]
fn golden_a1_batch_ratio() {
    check_table("a1_batch_ratio", &exp::ablate_batch_ratio(App::Sentiment, SCALE).unwrap());
}

#[test]
fn golden_a2_datapath() {
    check_table("a2_datapath", &exp::ablate_datapath(App::Sentiment, SCALE).unwrap());
}

#[test]
fn golden_a3_wakeup() {
    check_table("a3_wakeup", &exp::ablate_wakeup(App::Sentiment, SCALE).unwrap());
}

#[test]
fn golden_a4_dispatch() {
    check_table("a4_dispatch", &exp::ablate_dispatch(App::SpeechToText, SCALE).unwrap());
}

#[test]
fn golden_fig8_scaleout() {
    check_table("fig8", &exp::fig8_scaleout(SCALE).unwrap());
}

#[test]
fn golden_fig9_latency() {
    check_table("fig9", &exp::fig9_latency(SCALE).unwrap());
}

#[test]
fn golden_fig10_autoscale() {
    check_table("fig10", &exp::fig10_autoscale(SCALE).unwrap());
}

#[test]
fn golden_fig11_availability() {
    check_table("fig11", &exp::fig11_availability(SCALE).unwrap());
}

#[test]
fn golden_fig12_elastic() {
    check_table("fig12", &exp::fig12_elastic(SCALE).unwrap());
}

#[test]
fn golden_fig13_gc() {
    check_table("fig13", &exp::fig13_gc(SCALE).unwrap());
}

// ---- the net itself is tested: a single-cell change must trip --------

#[test]
fn harness_catches_any_single_cell_perturbation() {
    let t = exp::power_breakdown();
    let golden = serialize(&t);
    // Perturb every cell in turn; the diff must locate each one.
    let lines: Vec<&str> = golden.lines().collect();
    for (line_no, line) in lines.iter().enumerate().skip(1) {
        let ncells = line.split(',').count();
        for col in 0..ncells {
            let mut cells: Vec<String> =
                line.split(',').map(|c| c.to_string()).collect();
            cells[col].push('~');
            let mut perturbed: Vec<String> =
                lines.iter().map(|l| l.to_string()).collect();
            perturbed[line_no] = cells.join(",");
            let fresh = perturbed.join("\n");
            let err = diff_tables("power", &golden, &fresh)
                .expect_err("perturbed cell must be caught");
            assert!(
                err.contains(&format!("line {line_no}")),
                "diff should name line {line_no}: {err}"
            );
        }
    }
    // and an identical rendering passes
    diff_tables("power", &golden, &golden).unwrap();
}
