//! Synthetic corpora with the statistical shape of the paper's datasets.
//!
//! * [`TweetCorpus`] — Sentiment140-like: 1.6 M short texts with binary
//!   labels, built from sentiment-bearing vocabulary + neutral filler so
//!   a bag-of-words model is genuinely learnable (and accuracy is a real
//!   signal, matching "output accuracy: same" in Table I).
//! * [`MovieCatalog`] — MovieLens-like: 58 K titles with genres,
//!   director, actors, keywords and a Zipf-skewed popularity score.
//! * [`SpeechCorpus`] — LJSpeech-like: 13,100 clips averaging ~17 words,
//!   with reference transcripts; "audio" is the MFCC-like feature stream
//!   produced by [`super::features`].

use crate::util::Rng;

const POSITIVE_WORDS: &[&str] = &[
    "love", "great", "fantastic", "wonderful", "amazing", "excellent", "happy",
    "brilliant", "perfect", "beautiful", "enjoy", "awesome", "best", "delightful",
    "superb", "fun", "charming", "impressive", "favorite", "glad",
];

const NEGATIVE_WORDS: &[&str] = &[
    "hate", "terrible", "awful", "horrible", "worst", "boring", "sad", "bad",
    "disappointing", "dreadful", "annoying", "ugly", "mess", "waste", "angry",
    "painful", "miserable", "broken", "failure", "regret",
];

const NEUTRAL_WORDS: &[&str] = &[
    "the", "a", "this", "that", "movie", "day", "today", "just", "really",
    "phone", "work", "home", "time", "people", "thing", "going", "new", "was",
    "with", "about", "after", "before", "when", "while", "weather", "coffee",
    "train", "meeting", "morning", "night", "weekend", "week", "friend",
];

/// A labeled tweet.
#[derive(Clone, Debug, PartialEq)]
pub struct Tweet {
    pub text: String,
    pub positive: bool,
}

/// Sentiment140-like corpus generator.
pub struct TweetCorpus {
    rng: Rng,
}

impl TweetCorpus {
    pub fn new(seed: u64) -> TweetCorpus {
        TweetCorpus { rng: Rng::new(seed) }
    }

    /// Generate one tweet (balanced labels).
    pub fn next(&mut self) -> Tweet {
        let positive = self.rng.chance(0.5);
        let sentiment_pool = if positive { POSITIVE_WORDS } else { NEGATIVE_WORDS };
        // 6–18 words; 2–4 sentiment-bearing.
        let len = self.rng.range_u64(6, 18) as usize;
        let n_sent = self.rng.range_u64(2, 4) as usize;
        let mut words: Vec<&str> = Vec::with_capacity(len);
        for _ in 0..n_sent {
            words.push(*self.rng.choose(sentiment_pool));
        }
        // Word-level label noise: ~8% of tweets carry one opposite-polarity
        // word ("not bad", sarcasm) so accuracy tops out below 100%.
        if self.rng.chance(0.08) {
            let opposite = if positive { NEGATIVE_WORDS } else { POSITIVE_WORDS };
            words.push(*self.rng.choose(opposite));
        }
        while words.len() < len {
            words.push(*self.rng.choose(NEUTRAL_WORDS));
        }
        self.rng.shuffle(&mut words);
        Tweet { text: words.join(" "), positive }
    }

    pub fn take(&mut self, n: usize) -> Vec<Tweet> {
        (0..n).map(|_| self.next()).collect()
    }

    /// Average encoded bytes per tweet (for the IO model).
    pub fn avg_bytes(&self) -> u64 {
        90
    }
}

/// One movie's metadata (MovieLens-like).
#[derive(Clone, Debug)]
pub struct Movie {
    pub id: u32,
    pub title: String,
    pub genres: Vec<&'static str>,
    pub director: String,
    pub actors: Vec<String>,
    pub keywords: Vec<&'static str>,
    /// Popularity in [0, 1], Zipf-skewed over ids.
    pub popularity: f32,
    /// Mean rating in [0.5, 5.0].
    pub rating: f32,
}

impl Movie {
    /// The metadata "document" the recommender vectorizes (title, genres,
    /// director, main actors, story-line keywords — §IV-B2).
    pub fn metadata_doc(&self) -> String {
        let mut s = String::with_capacity(128);
        s.push_str(&self.title);
        for g in &self.genres {
            s.push(' ');
            s.push_str(g);
        }
        s.push(' ');
        s.push_str(&self.director);
        for a in &self.actors {
            s.push(' ');
            s.push_str(a);
        }
        for k in &self.keywords {
            s.push(' ');
            s.push_str(k);
        }
        s
    }
}

const GENRES: &[&str] = &[
    "action", "comedy", "drama", "thriller", "romance", "scifi", "horror",
    "documentary", "animation", "fantasy", "crime", "western", "musical",
    "adventure", "mystery", "war", "noir",
];

const KEYWORDS: &[&str] = &[
    "revenge", "family", "space", "heist", "journey", "secret", "war",
    "love", "betrayal", "survival", "monster", "detective", "escape",
    "friendship", "dystopia", "ghost", "robot", "island", "desert", "city",
    "ocean", "mountain", "winter", "dream", "memory", "time", "identity",
    "conspiracy", "treasure", "redemption", "sacrifice", "legacy",
];

const NAME_FIRST: &[&str] = &[
    "ava", "noah", "mia", "liam", "zoe", "ethan", "ivy", "owen", "ruby",
    "felix", "nora", "jude", "iris", "hugo", "elsa", "remy", "anya", "colt",
];
const NAME_LAST: &[&str] = &[
    "stone", "rivers", "marsh", "blake", "cross", "fox", "hale", "kane",
    "lane", "moss", "nash", "pike", "quinn", "reed", "shaw", "tate", "vale",
];

/// MovieLens-like catalogue.
pub struct MovieCatalog {
    pub movies: Vec<Movie>,
}

impl MovieCatalog {
    /// Build a catalogue of `n` movies (paper: 58,000).
    pub fn generate(seed: u64, n: usize) -> MovieCatalog {
        let mut rng = Rng::new(seed);
        let mut movies = Vec::with_capacity(n);
        for id in 0..n as u32 {
            let title = format!(
                "{} {} {}",
                rng.choose(KEYWORDS),
                rng.choose(&["of", "in", "beyond", "under", "against"]),
                rng.choose(KEYWORDS),
            );
            let n_genres = rng.range_u64(1, 3) as usize;
            let mut genres = Vec::with_capacity(n_genres);
            for _ in 0..n_genres {
                let g = *rng.choose(GENRES);
                if !genres.contains(&g) {
                    genres.push(g);
                }
            }
            let director = format!("{} {}", rng.choose(NAME_FIRST), rng.choose(NAME_LAST));
            let actors = (0..3)
                .map(|_| format!("{} {}", rng.choose(NAME_FIRST), rng.choose(NAME_LAST)))
                .collect();
            let n_kw = rng.range_u64(3, 6) as usize;
            let keywords = (0..n_kw).map(|_| *rng.choose(KEYWORDS)).collect();
            // Zipf-ish popularity by id with noise.
            let popularity =
                (1.0 / (1.0 + id as f64 / 500.0)).powf(0.7) as f32 * rng.range_f64(0.6, 1.0) as f32;
            let rating = rng.range_f64(0.5, 5.0) as f32;
            movies.push(Movie { id, title, genres, director, actors, keywords, popularity, rating });
        }
        MovieCatalog { movies }
    }

    pub fn len(&self) -> usize {
        self.movies.len()
    }

    pub fn is_empty(&self) -> bool {
        self.movies.is_empty()
    }

    /// Query stream: all titles shuffled (§IV-A: "we made a list of all
    /// movie titles and randomly shuffled them").
    pub fn shuffled_query_ids(&self, seed: u64) -> Vec<u32> {
        let mut ids: Vec<u32> = (0..self.movies.len() as u32).collect();
        Rng::new(seed).shuffle(&mut ids);
        ids
    }
}

/// Sentence word bank for speech transcripts.
const SPEECH_WORDS: &[&str] = &[
    "the", "quick", "brown", "fox", "jumps", "over", "lazy", "dog", "and",
    "then", "walks", "home", "through", "rain", "sun", "light", "river",
    "stone", "bridge", "old", "tower", "clock", "rings", "twice", "morning",
    "evening", "people", "gather", "market", "square", "voice", "echoes",
    "softly", "wind", "carries", "words", "away", "toward", "distant",
    "hills", "children", "laugh", "stories", "told", "again",
];

/// One speech clip: transcript + derived length stats.
#[derive(Clone, Debug)]
pub struct Clip {
    pub id: u32,
    pub transcript: String,
    pub words: usize,
    /// Simulated audio duration (s) — LJSpeech averages ~6.6 s/clip.
    pub duration_secs: f64,
}

/// LJSpeech-like corpus: 13,100 clips, ~225k words total, ~24 h audio.
pub struct SpeechCorpus {
    pub clips: Vec<Clip>,
}

impl SpeechCorpus {
    pub fn generate(seed: u64, n_clips: usize) -> SpeechCorpus {
        let mut rng = Rng::new(seed);
        let mut clips = Vec::with_capacity(n_clips);
        for id in 0..n_clips as u32 {
            // LJ distribution: mean ~17.2 words/clip, sd ~8, min 2.
            let words = rng.gaussian_trunc(17.2, 8.0, 2.0).round() as usize;
            let transcript: Vec<&str> =
                (0..words).map(|_| *rng.choose(SPEECH_WORDS)).collect();
            let transcript = transcript.join(" ");
            // ~2.6 words/sec speaking rate.
            let duration_secs = words as f64 / rng.range_f64(2.2, 3.0);
            clips.push(Clip { id, transcript, words, duration_secs });
        }
        SpeechCorpus { clips }
    }

    pub fn total_words(&self) -> usize {
        self.clips.iter().map(|c| c.words).sum()
    }

    pub fn total_audio_secs(&self) -> f64 {
        self.clips.iter().map(|c| c.duration_secs).sum()
    }

    /// Bytes of "audio" per clip: 16 kHz × 2 B mono PCM — this is what
    /// sits on flash and what the ISP path avoids moving (3.8 GB total
    /// for the full corpus, matching §IV-B1).
    pub fn clip_bytes(clip: &Clip) -> u64 {
        (clip.duration_secs * 16_000.0 * 2.0) as u64
    }

    pub fn total_bytes(&self) -> u64 {
        self.clips.iter().map(Self::clip_bytes).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tweets_deterministic_and_balanced() {
        let a = TweetCorpus::new(1).take(2000);
        let b = TweetCorpus::new(1).take(2000);
        assert_eq!(a, b);
        let pos = a.iter().filter(|t| t.positive).count();
        assert!((800..1200).contains(&pos), "balanced labels, got {pos}");
        assert!(a.iter().all(|t| !t.text.is_empty()));
    }

    #[test]
    fn tweets_carry_sentiment_signal() {
        let tweets = TweetCorpus::new(2).take(500);
        let signal = tweets
            .iter()
            .filter(|t| {
                let pool = if t.positive { POSITIVE_WORDS } else { NEGATIVE_WORDS };
                t.text.split(' ').any(|w| pool.contains(&w))
            })
            .count();
        assert!(signal as f64 / 500.0 > 0.95);
    }

    #[test]
    fn catalog_shape() {
        let c = MovieCatalog::generate(3, 1000);
        assert_eq!(c.len(), 1000);
        let m = &c.movies[0];
        assert!(!m.metadata_doc().is_empty());
        assert!(m.popularity > 0.0 && m.popularity <= 1.0);
        // popularity skew: early ids more popular on average
        let head: f32 = c.movies[..100].iter().map(|m| m.popularity).sum::<f32>() / 100.0;
        let tail: f32 = c.movies[900..].iter().map(|m| m.popularity).sum::<f32>() / 100.0;
        assert!(head > tail, "popularity skew {head} vs {tail}");
    }

    #[test]
    fn query_shuffle_is_permutation() {
        let c = MovieCatalog::generate(4, 200);
        let q = c.shuffled_query_ids(9);
        let mut sorted = q.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..200).collect::<Vec<u32>>());
        assert_ne!(q[..10], sorted[..10]);
    }

    #[test]
    fn speech_corpus_matches_lj_statistics() {
        let s = SpeechCorpus::generate(5, 13_100);
        let words = s.total_words();
        // paper: 225,715 words in 13,100 clips — within 10%
        assert!(
            (200_000..255_000).contains(&words),
            "total words {words}"
        );
        let hours = s.total_audio_secs() / 3600.0;
        assert!((20.0..30.0).contains(&hours), "audio {hours} h");
        let gb = s.total_bytes() as f64 / 1e9;
        // 16 kHz 16-bit mono ≈ 2.7 GB; paper's 3.8 GB dataset includes
        // 22 kHz original — same order, documented in DESIGN.md.
        assert!((2.0..5.0).contains(&gb), "dataset {gb} GB");
    }

    #[test]
    fn clips_are_nonempty_with_duration() {
        let s = SpeechCorpus::generate(6, 50);
        for c in &s.clips {
            assert!(c.words >= 2);
            assert!(c.duration_secs > 0.5);
            assert_eq!(c.transcript.split(' ').count(), c.words);
        }
    }
}
