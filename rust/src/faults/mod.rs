//! Deterministic fault injection for the serving stack (ISSUE-6).
//!
//! The paper evaluates Solana clusters on the happy path only; the CSD
//! survey (arXiv 2112.09691) calls out fault handling as a chief open
//! problem for CSD adoption, and the data-integrity revisit (arXiv
//! 2504.15293) measures in-storage compute paths silently losing results
//! under faults. This module makes failure a first-class, reproducible
//! scenario axis: a seeded [`FaultPlan`] perturbs a serving run with
//! drive-level faults (ISP engine crash → the drive falls back to
//! plain-SSD service for new work, transient stalls, ack loss),
//! server-level faults (crash at a deterministic virtual time, optional
//! rejoin), and rack-link faults (message drop / duplication on the
//! [`crate::interconnect::RackLink`]).
//!
//! # Determinism contract
//!
//! Every fault draw comes from **one seeded root stream**
//! (`Rng::new(seed).fork("faults")`), forked once per component with a
//! stable label — `server0..serverN` for the per-server drive fault
//! streams, `rack` for the link stream — before the run starts. Faults
//! are then *scheduled in virtual time*: a component draws from its own
//! stream only at its own events (a CSD batch ack, a rack message), so
//! the draw sequence each component sees is independent of how events
//! from different components interleave. Two runs with the same
//! `(config, seed, fault seed)` are bit-identical, and a plan whose
//! rates are all zero ([`FaultsConfig::is_quiet`]) draws **nothing** —
//! every rate is guarded by `rate > 0.0` before touching the RNG — so
//! the chaos layer provably costs nothing when quiet (property-tested
//! in `tests/chaos.rs`).
//!
//! Server crashes are fully deterministic (no RNG): the crash instant
//! is `t0 + server_crash_at × arrival_window`, a fraction of the
//! offered-arrival window, so the same spec crashes the same server at
//! the same virtual time at any scale.
//!
//! # Spec grammar (CLI `--faults`, e.g. `server-crash@0.3,ack-loss@0.05`)
//!
//! ```text
//! spec      := clause (',' clause)*
//! clause    := 'ack-loss@' PROB        # P(CSD batch ack lost)
//!            | 'stall@' PROB           # P(CSD batch ack stalls stall-s)
//!            | 'drive-crash@' PROB     # P(ISP dies at a batch ack, permanent)
//!            | 'link-drop@' PROB       # P(rack response message dropped)
//!            | 'link-dup@' PROB        # P(rack response message duplicated)
//!            | 'server-crash@' FRAC    # crash at FRAC of the arrival window
//!            | 'stall-s=' SECONDS      # stall duration (default 1.0)
//!            | 'rejoin-s=' SECONDS     # server rejoins after this downtime
//!            | 'crash-server=' INDEX   # which server crashes (default 0)
//! ```

use crate::util::rng::Rng;

/// Fault scenario configuration: the `[faults]` TOML section /
/// `solana serve --faults <spec>`. All probabilities are per-event
/// (per CSD batch ack, per rack message); the server crash is a
/// deterministic point in virtual time.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultsConfig {
    /// Seed for the fault RNG root stream (`--fault-seed`,
    /// `[faults] seed`). Independent of the traffic/scheduler seed so
    /// the same workload can be replayed under different fault draws.
    pub seed: u64,
    /// P(a CSD batch ack is lost): the drive did the work but the
    /// result never reaches the scheduler (arXiv 2504.15293's silent
    /// result-loss class).
    pub ack_loss: f64,
    /// P(a CSD batch ack stalls): the ack is delivered [`stall_s`]
    /// late and the drive is stuck for the duration.
    pub stall: f64,
    /// Transient stall duration in seconds.
    pub stall_s: f64,
    /// P(the drive's ISP engine crashes at a batch ack, permanently):
    /// the in-flight batch is lost and the drive serves no further
    /// in-storage work — new requests fall back to the plain-SSD path
    /// (host or surviving ISP drives).
    pub drive_crash: f64,
    /// Crash one server at this fraction of the offered-arrival window
    /// (`None` = no server crash).
    pub server_crash_at: Option<f64>,
    /// Which server crashes.
    pub crash_server: usize,
    /// Rejoin after this much downtime (`None` = the crash is
    /// permanent).
    pub rejoin_s: Option<f64>,
    /// P(a rack response message is dropped).
    pub link_drop: f64,
    /// P(a rack response message is duplicated).
    pub link_dup: f64,
}

impl Default for FaultsConfig {
    fn default() -> Self {
        FaultsConfig {
            seed: 7,
            ack_loss: 0.0,
            stall: 0.0,
            stall_s: 1.0,
            drive_crash: 0.0,
            server_crash_at: None,
            crash_server: 0,
            rejoin_s: None,
            link_drop: 0.0,
            link_dup: 0.0,
        }
    }
}

fn prob(name: &str, v: f64) -> anyhow::Result<()> {
    anyhow::ensure!(
        (0.0..=1.0).contains(&v) && v.is_finite(),
        "faults.{name} must be a probability in [0, 1], got {v}"
    );
    Ok(())
}

impl FaultsConfig {
    /// A plan with every rate zero: the chaos machinery runs but no
    /// fault ever fires (and no RNG draw ever happens).
    pub fn quiet() -> FaultsConfig {
        FaultsConfig::default()
    }

    /// Whether this plan can never perturb a run.
    pub fn is_quiet(&self) -> bool {
        self.ack_loss == 0.0
            && self.stall == 0.0
            && self.drive_crash == 0.0
            && self.link_drop == 0.0
            && self.link_dup == 0.0
            && self.server_crash_at.is_none()
    }

    /// Validate against a fleet of `servers` servers.
    pub fn validate(&self, servers: usize) -> anyhow::Result<()> {
        prob("ack_loss", self.ack_loss)?;
        prob("stall", self.stall)?;
        prob("drive_crash", self.drive_crash)?;
        prob("link_drop", self.link_drop)?;
        prob("link_dup", self.link_dup)?;
        anyhow::ensure!(
            self.stall_s >= 0.0 && self.stall_s.is_finite(),
            "faults.stall_s must be non-negative and finite, got {}",
            self.stall_s
        );
        if let Some(frac) = self.server_crash_at {
            anyhow::ensure!(
                (0.0..=1.0).contains(&frac) && frac.is_finite(),
                "faults.server_crash_at must be a fraction of the arrival window in [0, 1], got {frac}"
            );
            anyhow::ensure!(
                self.crash_server < servers,
                "faults.crash_server {} out of range for a {servers}-server fleet",
                self.crash_server
            );
        }
        if let Some(d) = self.rejoin_s {
            anyhow::ensure!(
                d > 0.0 && d.is_finite(),
                "faults.rejoin_s must be positive and finite, got {d}"
            );
        }
        Ok(())
    }

    /// Parse the CLI spec grammar (module docs); `seed` seeds the plan
    /// (the `--fault-seed` flag). An empty spec is the quiet plan.
    pub fn parse(spec: &str, seed: u64) -> anyhow::Result<FaultsConfig> {
        let mut cfg = FaultsConfig { seed, ..FaultsConfig::default() };
        for clause in spec.split(',').map(str::trim).filter(|c| !c.is_empty()) {
            if let Some((name, val)) = clause.split_once('@') {
                let v: f64 = val
                    .parse()
                    .map_err(|_| anyhow::anyhow!("bad fault rate '{val}' in clause '{clause}'"))?;
                match name.trim() {
                    "ack-loss" => cfg.ack_loss = v,
                    "stall" | "drive-stall" => cfg.stall = v,
                    "drive-crash" => cfg.drive_crash = v,
                    "link-drop" => cfg.link_drop = v,
                    "link-dup" => cfg.link_dup = v,
                    "server-crash" => cfg.server_crash_at = Some(v),
                    other => anyhow::bail!(
                        "unknown fault clause '{other}@' (expected ack-loss|stall|drive-crash|link-drop|link-dup|server-crash)"
                    ),
                }
            } else if let Some((key, val)) = clause.split_once('=') {
                match key.trim() {
                    "stall-s" => {
                        cfg.stall_s = val
                            .parse()
                            .map_err(|_| anyhow::anyhow!("bad stall-s '{val}'"))?;
                    }
                    "rejoin-s" => {
                        cfg.rejoin_s = Some(
                            val.parse().map_err(|_| anyhow::anyhow!("bad rejoin-s '{val}'"))?,
                        );
                    }
                    "crash-server" => {
                        cfg.crash_server = val
                            .parse()
                            .map_err(|_| anyhow::anyhow!("bad crash-server '{val}'"))?;
                    }
                    other => anyhow::bail!(
                        "unknown fault parameter '{other}=' (expected stall-s|rejoin-s|crash-server)"
                    ),
                }
            } else {
                anyhow::bail!(
                    "bad fault clause '{clause}': expected name@rate or key=value (see --help)"
                );
            }
        }
        Ok(cfg)
    }
}

/// What happens to one CSD batch ack under the fault plan.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AckOutcome {
    /// The ack arrives normally.
    Deliver,
    /// The drive is stuck for `stall_s`; the ack arrives late.
    Stall,
    /// The ack (and the batch's results) never arrive.
    Lost,
}

/// Per-server drive fault stream: owned by one `ServeEngine`, drawn
/// only at that engine's CSD batch acks (virtual-time scheduling — see
/// the module docs' determinism contract).
#[derive(Clone, Debug)]
pub struct DriveFaults {
    ack_loss: f64,
    stall: f64,
    /// Stall duration, read by the engine when re-scheduling the ack.
    pub stall_s: f64,
    crash: f64,
    rng: Rng,
    crashed: Vec<bool>,
}

impl DriveFaults {
    pub fn new(cfg: &FaultsConfig, rng: Rng, drives: usize) -> DriveFaults {
        DriveFaults {
            ack_loss: cfg.ack_loss,
            stall: cfg.stall,
            stall_s: cfg.stall_s,
            crash: cfg.drive_crash,
            rng,
            crashed: vec![false; drives],
        }
    }

    /// Whether `drive`'s ISP engine has crashed.
    pub fn crashed(&self, drive: usize) -> bool {
        self.crashed[drive]
    }

    /// Number of crashed ISP engines on this server.
    pub fn crashed_count(&self) -> usize {
        self.crashed.iter().filter(|&&c| c).count()
    }

    /// Draw the fate of one CSD batch ack on `drive`. Zero-rate checks
    /// guard every draw, so a quiet plan never touches the RNG.
    pub fn ack_outcome(&mut self, drive: usize) -> AckOutcome {
        if self.crashed[drive] {
            // A dead ISP completes nothing: batches already queued on
            // the drive drain as lost acks.
            return AckOutcome::Lost;
        }
        if self.crash > 0.0 && self.rng.chance(self.crash) {
            self.crashed[drive] = true;
            return AckOutcome::Lost;
        }
        if self.stall > 0.0 && self.rng.chance(self.stall) {
            return AckOutcome::Stall;
        }
        if self.ack_loss > 0.0 && self.rng.chance(self.ack_loss) {
            return AckOutcome::Lost;
        }
        AckOutcome::Deliver
    }
}

/// What happens to one rack response message under the fault plan.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LinkOutcome {
    Deliver,
    /// The message is lost; its completions never reach the front door.
    Drop,
    /// The message arrives twice (the duplicate is suppressed by the
    /// front door's first-response-wins bookkeeping, but both copies
    /// pay rack bandwidth).
    Duplicate,
}

/// Rack-link fault stream, drawn once per non-head response message.
#[derive(Clone, Debug)]
pub struct LinkFaults {
    drop: f64,
    dup: f64,
    rng: Rng,
}

impl LinkFaults {
    pub fn new(cfg: &FaultsConfig, rng: Rng) -> LinkFaults {
        LinkFaults { drop: cfg.link_drop, dup: cfg.link_dup, rng }
    }

    /// Draw the fate of one rack message (zero-rate draws are free).
    pub fn outcome(&mut self) -> LinkOutcome {
        if self.drop > 0.0 && self.rng.chance(self.drop) {
            return LinkOutcome::Drop;
        }
        if self.dup > 0.0 && self.rng.chance(self.dup) {
            return LinkOutcome::Duplicate;
        }
        LinkOutcome::Deliver
    }
}

/// A deterministic server crash: `server` is down in `[at, until)`
/// (or forever when `until` is `None`).
#[derive(Clone, Copy, Debug)]
pub struct ServerCrash {
    pub server: usize,
    pub at: f64,
    pub until: Option<f64>,
}

impl ServerCrash {
    /// Ground truth: is `server` down at virtual time `now`? (The front
    /// door never reads this directly for routing — it detects death by
    /// missed acks, honestly.)
    pub fn down(&self, server: usize, now: f64) -> bool {
        server == self.server && now >= self.at && self.until.map_or(true, |u| now < u)
    }
}

/// The resolved, seeded fault plan for one fleet serving run: one
/// drive-fault stream per server, one rack-link stream, and the
/// (deterministic) server crash schedule.
#[derive(Clone, Debug)]
pub struct FaultPlan {
    /// Per-server drive fault streams, in server order. `serve_fleet`
    /// drains these into the engines at startup.
    pub drive: Vec<DriveFaults>,
    pub link: LinkFaults,
    pub crash: Option<ServerCrash>,
}

impl FaultPlan {
    /// Build the plan: fork the root stream per component (stable
    /// labels, fixed order), resolve the crash schedule against the
    /// run's start time `t0` and offered-arrival window `window_s`.
    pub fn new(
        cfg: &FaultsConfig,
        drives_per_server: &[usize],
        t0: f64,
        window_s: f64,
    ) -> FaultPlan {
        let mut root = Rng::new(cfg.seed).fork("faults");
        let drive = drives_per_server
            .iter()
            .enumerate()
            .map(|(i, &d)| DriveFaults::new(cfg, root.fork(&format!("server{i}")), d))
            .collect();
        let link = LinkFaults::new(cfg, root.fork("rack"));
        let crash = cfg.server_crash_at.map(|frac| {
            let at = t0 + frac * window_s;
            ServerCrash { server: cfg.crash_server, at, until: cfg.rejoin_s.map(|d| at + d) }
        });
        FaultPlan { drive, link, crash }
    }

    /// Ground-truth down check (see [`ServerCrash::down`]).
    pub fn down(&self, server: usize, now: f64) -> bool {
        self.crash.map_or(false, |c| c.down(server, now))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_full_spec() {
        let c = FaultsConfig::parse(
            "server-crash@0.3,ack-loss@0.05,stall@0.1,stall-s=2.5,drive-crash@0.01,\
             link-drop@0.02,link-dup@0.03,rejoin-s=4,crash-server=1",
            99,
        )
        .unwrap();
        assert_eq!(c.seed, 99);
        assert_eq!(c.server_crash_at, Some(0.3));
        assert_eq!(c.ack_loss, 0.05);
        assert_eq!(c.stall, 0.1);
        assert_eq!(c.stall_s, 2.5);
        assert_eq!(c.drive_crash, 0.01);
        assert_eq!(c.link_drop, 0.02);
        assert_eq!(c.link_dup, 0.03);
        assert_eq!(c.rejoin_s, Some(4.0));
        assert_eq!(c.crash_server, 1);
        assert!(c.validate(2).is_ok());
        assert!(!c.is_quiet());
    }

    #[test]
    fn parse_empty_spec_is_quiet() {
        let c = FaultsConfig::parse("", 7).unwrap();
        assert!(c.is_quiet());
        assert_eq!(c, FaultsConfig::quiet());
    }

    #[test]
    fn parse_rejects_nonsense() {
        assert!(FaultsConfig::parse("psychic@0.5", 7).is_err());
        assert!(FaultsConfig::parse("ack-loss@lots", 7).is_err());
        assert!(FaultsConfig::parse("warp=9", 7).is_err());
        assert!(FaultsConfig::parse("just-words", 7).is_err());
    }

    #[test]
    fn validate_rejects_out_of_range() {
        assert!(FaultsConfig { ack_loss: 1.5, ..FaultsConfig::default() }.validate(4).is_err());
        assert!(FaultsConfig { stall: -0.1, ..FaultsConfig::default() }.validate(4).is_err());
        assert!(FaultsConfig { stall_s: f64::NAN, ..FaultsConfig::default() }.validate(4).is_err());
        assert!(FaultsConfig { server_crash_at: Some(2.0), ..FaultsConfig::default() }
            .validate(4)
            .is_err());
        assert!(FaultsConfig {
            server_crash_at: Some(0.5),
            crash_server: 4,
            ..FaultsConfig::default()
        }
        .validate(4)
        .is_err());
        assert!(FaultsConfig { rejoin_s: Some(0.0), ..FaultsConfig::default() }
            .validate(4)
            .is_err());
        assert!(FaultsConfig::default().validate(1).is_ok());
    }

    #[test]
    fn quiet_plan_never_fires() {
        let cfg = FaultsConfig::quiet();
        let mut d = DriveFaults::new(&cfg, Rng::new(1), 4);
        for i in 0..1_000 {
            assert_eq!(d.ack_outcome(i % 4), AckOutcome::Deliver);
        }
        assert_eq!(d.crashed_count(), 0);
        let mut l = LinkFaults::new(&cfg, Rng::new(2));
        for _ in 0..1_000 {
            assert_eq!(l.outcome(), LinkOutcome::Deliver);
        }
    }

    #[test]
    fn same_seed_same_outcome_sequence() {
        let cfg = FaultsConfig {
            ack_loss: 0.2,
            stall: 0.2,
            drive_crash: 0.05,
            ..FaultsConfig::default()
        };
        let mut a = DriveFaults::new(&cfg, Rng::new(33), 8);
        let mut b = DriveFaults::new(&cfg, Rng::new(33), 8);
        for i in 0..500 {
            assert_eq!(a.ack_outcome(i % 8), b.ack_outcome(i % 8), "draw {i}");
        }
    }

    #[test]
    fn crashed_drive_loses_everything_after() {
        let cfg = FaultsConfig { drive_crash: 1.0, ..FaultsConfig::default() };
        let mut d = DriveFaults::new(&cfg, Rng::new(5), 2);
        assert_eq!(d.ack_outcome(0), AckOutcome::Lost);
        assert!(d.crashed(0));
        assert!(!d.crashed(1));
        for _ in 0..10 {
            assert_eq!(d.ack_outcome(0), AckOutcome::Lost);
        }
        assert_eq!(d.crashed_count(), 1);
    }

    #[test]
    fn server_crash_window() {
        let plan = FaultPlan::new(
            &FaultsConfig {
                server_crash_at: Some(0.5),
                crash_server: 1,
                rejoin_s: Some(3.0),
                ..FaultsConfig::default()
            },
            &[4, 4],
            10.0,
            20.0,
        );
        let c = plan.crash.unwrap();
        assert_eq!(c.server, 1);
        assert!((c.at - 20.0).abs() < 1e-12);
        assert_eq!(c.until, Some(23.0));
        assert!(!plan.down(1, 19.9));
        assert!(plan.down(1, 20.0));
        assert!(plan.down(1, 22.9));
        assert!(!plan.down(1, 23.0), "rejoined");
        assert!(!plan.down(0, 21.0), "only the named server crashes");
        // permanent crash
        let forever = FaultPlan::new(
            &FaultsConfig { server_crash_at: Some(0.0), ..FaultsConfig::default() },
            &[4],
            0.0,
            10.0,
        );
        assert!(forever.down(0, 1e9));
    }

    #[test]
    fn fault_plan_streams_are_independent_of_each_other() {
        // Forked per-component streams: server0's draws do not shift
        // when server1 draws more or less — the virtual-time contract.
        let cfg = FaultsConfig { ack_loss: 0.3, ..FaultsConfig::default() };
        let mut p1 = FaultPlan::new(&cfg, &[2, 2], 0.0, 1.0);
        let mut p2 = FaultPlan::new(&cfg, &[2, 2], 0.0, 1.0);
        // p2's server1 draws heavily first; server0 must be unaffected.
        for _ in 0..100 {
            p2.drive[1].ack_outcome(0);
        }
        for i in 0..200 {
            assert_eq!(
                p1.drive[0].ack_outcome(i % 2),
                p2.drive[0].ack_outcome(i % 2),
                "server0 stream shifted by server1 activity"
            );
        }
    }
}
