//! Flash translation layer: logical→physical page mapping, dynamic
//! striping across dies, garbage collection, and wear-leveling
//! accounting (§III-A1: "BE is also responsible for implementing flash
//! management routines, such as wear-leveling, address translation, and
//! garbage collection").
//!
//! Page-level mapping with a sparse table (only written LPNs are mapped —
//! the simulated drive is 12 TB but experiments touch a few GB). Writes
//! stripe round-robin across all dies for channel parallelism; GC is
//! greedy (min-valid victim) per die and is triggered when a die's free
//! block pool drops below a threshold. All timed flash operations go
//! through the [`FlashArray`] so GC traffic contends with foreground IO
//! exactly like on real hardware.
//!
//! Three operating regimes:
//!
//! * **Foreground GC** (always on): a write that finds its die below the
//!   low-water mark stalls behind victim relocation — the GC latency
//!   lands in that request's tail.
//! * **Background GC** (`FlashConfig::background_gc`): idle dies
//!   relocate ahead of the low-water mark, so GC steals die/channel
//!   bandwidth from *future* IO instead of only stalling the triggering
//!   write. Driven by the FCU on the write path.
//! * **ZNS** (`FlashConfig::zns`, after ZCSD): placement is a fixed
//!   append-only zone mapping (zone = one block), the device never
//!   relocates, and reclamation is a host-visible **zone reset** that
//!   unmaps every page in the zone. WAF is 1.0 by construction.
//!
//! **Headroom invariant:** each die reserves `headroom` over-provisioned
//! blocks (≈1% of blocks, min 1) that host allocation may never consume.
//! Only GC relocation may dip into them, and a single victim pass pops at
//! most one block before its erase returns one, so the free pool can
//! never be exhausted mid-relocation (the bug family this guards against:
//! a valid-heavy victim plus a nearly-full open block used to pop the
//! last free block and panic even though space was reclaimable).

use std::collections::VecDeque;

use crate::util::FastMap;

use super::flash::{FlashArray, FlashConfig, PhysAddr};
use crate::sim::SimTime;

/// Per-die allocation state.
#[derive(Clone, Debug)]
struct DieState {
    free_blocks: VecDeque<u32>,
    /// O(1) free-membership mirror of `free_blocks` (the GC victim scan
    /// used `VecDeque::contains` per candidate — O(blocks²) per pass at
    /// the 2500-blocks-per-die default).
    free: Vec<bool>,
    open_block: u32,
    next_page: u32,
    /// valid page count per block
    valid: Vec<u32>,
    /// erase count per block (wear)
    erases: Vec<u32>,
}

/// FTL statistics for reports and tests.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct FtlStats {
    pub host_pages_written: u64,
    pub flash_pages_written: u64,
    /// Victim passes, foreground + background.
    pub gc_runs: u64,
    /// Subset of `gc_runs` initiated opportunistically on idle dies.
    pub background_gc_runs: u64,
    pub gc_pages_moved: u64,
    pub blocks_erased: u64,
    /// Host-visible zone resets (ZNS mode only).
    pub zone_resets: u64,
}

impl FtlStats {
    /// Accumulate another drive's counters (fleet/server rollups).
    pub fn absorb(&mut self, o: &FtlStats) {
        self.host_pages_written += o.host_pages_written;
        self.flash_pages_written += o.flash_pages_written;
        self.gc_runs += o.gc_runs;
        self.background_gc_runs += o.background_gc_runs;
        self.gc_pages_moved += o.gc_pages_moved;
        self.blocks_erased += o.blocks_erased;
        self.zone_resets += o.zone_resets;
    }

    /// Write amplification factor.
    pub fn waf(&self) -> f64 {
        if self.host_pages_written == 0 {
            1.0
        } else {
            self.flash_pages_written as f64 / self.host_pages_written as f64
        }
    }
}

pub struct Ftl {
    cfg: FlashConfig,
    l2p: FastMap<u64, PhysAddr>,
    p2l: FastMap<PhysAddr, u64>,
    dies: Vec<DieState>,
    next_die: usize,
    /// GC kicks in when a die's free pool drops below this many blocks.
    /// The effective trigger is `low_water()`, which never drops below
    /// `headroom + 1` so GC always starts with relocation room.
    pub gc_low_water: usize,
    /// Over-provisioned blocks per die reserved for GC relocation; host
    /// allocation refuses to consume them.
    headroom: usize,
    /// Latest simulated time any GC pass (foreground or background) on
    /// this drive runs until — observability only (request tracing
    /// attributes `gc_stall` phases from it); never feeds back into
    /// scheduling decisions.
    gc_busy_until: SimTime,
    stats: FtlStats,
}

impl Ftl {
    pub fn new(cfg: FlashConfig) -> Ftl {
        let headroom = 1usize
            .max(cfg.blocks_per_die as usize / 100)
            .min(cfg.blocks_per_die.saturating_sub(1) as usize);
        let dies: Vec<DieState> = (0..cfg.dies())
            .map(|_| {
                // Block 0 opens first; the rest are free.
                let free: VecDeque<u32> = (1..cfg.blocks_per_die).collect();
                let mut free_bitmap = vec![true; cfg.blocks_per_die as usize];
                free_bitmap[0] = false;
                DieState {
                    free_blocks: free,
                    free: free_bitmap,
                    open_block: 0,
                    next_page: 0,
                    valid: vec![0; cfg.blocks_per_die as usize],
                    erases: vec![0; cfg.blocks_per_die as usize],
                }
            })
            .collect();
        Ftl {
            gc_low_water: 2usize.max(cfg.blocks_per_die as usize / 50),
            headroom,
            cfg,
            l2p: FastMap::default(),
            p2l: FastMap::default(),
            dies,
            next_die: 0,
            gc_busy_until: 0.0,
            stats: FtlStats::default(),
        }
    }

    /// Latest simulated time a GC pass on this drive runs until (0.0 if
    /// GC has never run). Read-only observability hook for the tracer's
    /// `gc_stall` attribution.
    pub fn gc_busy_until(&self) -> SimTime {
        self.gc_busy_until
    }

    pub fn stats(&self) -> FtlStats {
        self.stats
    }

    pub fn mapped_pages(&self) -> usize {
        self.l2p.len()
    }

    /// Over-provisioned blocks per die excluded from host allocation.
    pub fn headroom(&self) -> usize {
        self.headroom
    }

    /// Effective GC trigger: the configured low-water mark, floored so
    /// GC always enters with at least one block beyond the headroom.
    fn low_water(&self) -> usize {
        self.gc_low_water.max(self.headroom + 1)
    }

    /// Physical address of a logical page, if written.
    pub fn lookup(&self, lpn: u64) -> Option<PhysAddr> {
        self.l2p.get(&lpn).copied()
    }

    fn die_addr(&self, die_idx: usize, block: u32, page: u32) -> PhysAddr {
        PhysAddr {
            channel: (die_idx / self.cfg.dies_per_channel as usize) as u16,
            die: (die_idx % self.cfg.dies_per_channel as usize) as u16,
            block,
            page,
        }
    }

    /// Decrement a block's valid-page counter. A zero counter here means
    /// the maps and the counters disagree (the bug family: double
    /// accounting between trim/overwrite/GC); debug builds fail loudly,
    /// release builds saturate instead of wrapping to four billion.
    fn dec_valid(&mut self, die: usize, block: u32) {
        let v = &mut self.dies[die].valid[block as usize];
        debug_assert!(*v > 0, "valid-page underflow on die {die} block {block}");
        *v = v.saturating_sub(1);
    }

    /// Allocate the next physical page on a die (advancing the open
    /// block). Host allocation (`for_gc = false`) never consumes the
    /// reserved headroom blocks; GC relocation may.
    fn alloc_on_die(&mut self, die_idx: usize, for_gc: bool) -> PhysAddr {
        let pages_per_block = self.cfg.pages_per_block;
        let headroom = self.headroom;
        let d = &mut self.dies[die_idx];
        if d.next_page >= pages_per_block {
            assert!(
                for_gc || d.free_blocks.len() > headroom,
                "die {die_idx} over-full: logical data exceeds usable capacity \
                 (headroom blocks are reserved for GC relocation)"
            );
            let nb = d
                .free_blocks
                .pop_front()
                // solana-lint: allow(no-unwrap, reason = "host allocation keeps free > headroom >= 1 and a GC pass pops at most one block before its erase pushes one back, so the pool cannot be empty here; an empty pool is a simulator bug, not a recoverable state")
                .expect("alloc_on_die called with empty free pool (GC failed?)");
            d.free[nb as usize] = false;
            d.open_block = nb;
            d.next_page = 0;
        }
        let a = self.die_addr(die_idx, self.dies[die_idx].open_block, self.dies[die_idx].next_page);
        self.dies[die_idx].next_page += 1;
        a
    }

    /// Write one logical page at `now`; returns program completion time.
    pub fn write_page(&mut self, now: SimTime, flash: &mut FlashArray, lpn: u64) -> SimTime {
        if self.cfg.zns {
            return self.write_page_zns(now, flash, lpn);
        }
        self.stats.host_pages_written += 1;
        let mut t = now;
        // Invalidate the previous version.
        if let Some(old) = self.l2p.remove(&lpn) {
            self.p2l.remove(&old);
            let die = self.cfg.die_index(&old);
            self.dec_valid(die, old.block);
        }
        let die_idx = self.next_die;
        self.next_die = (self.next_die + 1) % self.dies.len();
        t = self.maybe_gc(t, flash, die_idx);
        let addr = self.alloc_on_die(die_idx, false);
        self.dies[die_idx].valid[addr.block as usize] += 1;
        self.l2p.insert(lpn, addr);
        self.p2l.insert(addr, lpn);
        self.stats.flash_pages_written += 1;
        flash.program_page(t, addr)
    }

    /// ZNS write path (ZCSD-style): every logical page has a fixed slot
    /// in a fixed zone (zone = one block, striped across dies), writes
    /// append within the zone, and rewriting a mapped page first resets
    /// the whole zone — a host-visible erase that unmaps every sibling
    /// page. The device never relocates, so WAF is exactly 1.
    fn write_page_zns(&mut self, now: SimTime, flash: &mut FlashArray, lpn: u64) -> SimTime {
        assert!(
            lpn < self.cfg.total_pages(),
            "zns write beyond capacity: lpn {lpn} of {}",
            self.cfg.total_pages()
        );
        self.stats.host_pages_written += 1;
        let mut t = now;
        let ppb = self.cfg.pages_per_block as u64;
        let zone = lpn / ppb;
        let dies = self.dies.len() as u64;
        let die_idx = (zone % dies) as usize;
        let block = ((zone / dies) % self.cfg.blocks_per_die as u64) as u32;
        let slot = (lpn % ppb) as u32;
        if self.l2p.contains_key(&lpn) {
            t = self.zone_reset(t, flash, die_idx, block);
        }
        let addr = self.die_addr(die_idx, block, slot);
        self.dies[die_idx].valid[block as usize] += 1;
        self.l2p.insert(lpn, addr);
        self.p2l.insert(addr, lpn);
        self.stats.flash_pages_written += 1;
        flash.program_page(t, addr)
    }

    /// Host-visible zone reset: unmap every page in the zone and erase
    /// the backing block. Charged to the caller's time cursor like any
    /// other flash operation.
    fn zone_reset(
        &mut self,
        now: SimTime,
        flash: &mut FlashArray,
        die_idx: usize,
        block: u32,
    ) -> SimTime {
        for p in 0..self.cfg.pages_per_block {
            let a = self.die_addr(die_idx, block, p);
            if let Some(l) = self.p2l.remove(&a) {
                self.l2p.remove(&l);
                self.dec_valid(die_idx, block);
            }
        }
        self.stats.zone_resets += 1;
        self.stats.blocks_erased += 1;
        self.dies[die_idx].erases[block as usize] += 1;
        let a = self.die_addr(die_idx, block, 0);
        flash.erase_block(now, a.channel, a.die)
    }

    /// Read one logical page; unmapped pages return a deterministic
    /// "unmapped read" (the controller answers zeroes without touching
    /// flash, like a real SSD).
    pub fn read_page(&mut self, now: SimTime, flash: &mut FlashArray, lpn: u64) -> SimTime {
        match self.l2p.get(&lpn) {
            Some(&addr) => flash.read_page(now, addr),
            None => now, // zero-fill response from the controller
        }
    }

    /// TRIM a logical page.
    pub fn trim(&mut self, lpn: u64) {
        if let Some(old) = self.l2p.remove(&lpn) {
            self.p2l.remove(&old);
            let die = self.cfg.die_index(&old);
            self.dec_valid(die, old.block);
        }
    }

    /// Greedy min-valid victim on a die: skips the open block, free
    /// blocks (O(1) via the bitmap), and fully-valid blocks (relocating
    /// one reclaims nothing — the old scan would grind through them and
    /// livelock the reclaim loop on a packed die).
    fn pick_victim(&self, die_idx: usize) -> Option<u32> {
        let d = &self.dies[die_idx];
        let open = d.open_block;
        let mut best: Option<(u32, u32)> = None; // (valid, block)
        for b in 0..self.cfg.blocks_per_die {
            if b == open || d.free[b as usize] {
                continue;
            }
            debug_assert_eq!(
                d.free[b as usize],
                d.free_blocks.contains(&b),
                "free bitmap out of sync with free pool on die {die_idx} block {b}"
            );
            let v = d.valid[b as usize];
            if v >= self.cfg.pages_per_block {
                continue; // fully valid: no space to reclaim
            }
            if best.map(|(bv, _)| v < bv).unwrap_or(true) {
                best = Some((v, b));
            }
        }
        best.map(|(_, b)| b)
    }

    /// Relocate one victim block's valid pages and erase it. Returns the
    /// advanced time cursor. Pops at most one free block (a victim has
    /// at most `pages_per_block − 1` valid pages) before the erase
    /// pushes one back, so the free pool never drains below
    /// `headroom − 1` transiently and never ends a pass below where it
    /// started.
    fn collect_victim(
        &mut self,
        now: SimTime,
        flash: &mut FlashArray,
        die_idx: usize,
        victim: u32,
    ) -> SimTime {
        let mut t = now;
        self.stats.gc_runs += 1;
        let pages: Vec<(PhysAddr, u64)> = (0..self.cfg.pages_per_block)
            .filter_map(|p| {
                let a = self.die_addr(die_idx, victim, p);
                self.p2l.get(&a).map(|&l| (a, l))
            })
            .collect();
        for (old_addr, lpn) in pages {
            t = flash.read_page(t, old_addr);
            self.p2l.remove(&old_addr);
            self.dec_valid(die_idx, victim);
            let new_addr = self.alloc_on_die(die_idx, true);
            self.dies[die_idx].valid[new_addr.block as usize] += 1;
            self.l2p.insert(lpn, new_addr);
            self.p2l.insert(new_addr, lpn);
            self.stats.flash_pages_written += 1;
            self.stats.gc_pages_moved += 1;
            t = flash.program_page(t, new_addr);
        }
        debug_assert_eq!(self.dies[die_idx].valid[victim as usize], 0);
        // Erase and return to the pool.
        let a = self.die_addr(die_idx, victim, 0);
        t = flash.erase_block(t, a.channel, a.die);
        self.dies[die_idx].erases[victim as usize] += 1;
        self.stats.blocks_erased += 1;
        self.dies[die_idx].free_blocks.push_back(victim);
        self.dies[die_idx].free[victim as usize] = true;
        self.gc_busy_until = self.gc_busy_until.max(t);
        t
    }

    /// Run GC on a die if its free pool is low. Returns the (possibly
    /// advanced) time cursor — foreground writes stall behind GC exactly
    /// as they would in the device. Terminates: every pass converts at
    /// least one invalid page to free space (fully-valid victims are
    /// skipped), and breaks when nothing is reclaimable.
    fn maybe_gc(&mut self, now: SimTime, flash: &mut FlashArray, die_idx: usize) -> SimTime {
        let mut t = now;
        while self.dies[die_idx].free_blocks.len() < self.low_water() {
            let victim = match self.pick_victim(die_idx) {
                Some(v) => v,
                None => break, // nothing reclaimable
            };
            t = self.collect_victim(t, flash, die_idx, victim);
        }
        t
    }

    /// Opportunistic background GC: for every die that is idle at `now`
    /// and below twice the low-water mark, relocate one victim. The
    /// relocation occupies the die and its channel starting at `now`, so
    /// it steals bandwidth from *future* foreground IO instead of
    /// stalling the write that tripped the threshold. No-op in ZNS mode
    /// (reclamation is host-driven there).
    pub fn background_collect(&mut self, now: SimTime, flash: &mut FlashArray) {
        if self.cfg.zns {
            return;
        }
        let bg_water = 2 * self.low_water();
        for die_idx in 0..self.dies.len() {
            if self.dies[die_idx].free_blocks.len() >= bg_water {
                continue;
            }
            if !flash.die_idle(die_idx, now) {
                continue;
            }
            if let Some(victim) = self.pick_victim(die_idx) {
                self.stats.background_gc_runs += 1;
                self.collect_victim(now, flash, die_idx, victim);
            }
        }
    }

    /// Max-min erase-count spread across all blocks (wear-leveling
    /// quality metric).
    pub fn wear_spread(&self) -> u32 {
        let mut lo = u32::MAX;
        let mut hi = 0;
        for d in &self.dies {
            for &e in &d.erases {
                lo = lo.min(e);
                hi = hi.max(e);
            }
        }
        if lo == u32::MAX {
            0
        } else {
            hi - lo
        }
    }

    /// Check internal consistency (tests): l2p and p2l are inverse maps,
    /// per-block valid counters match the reverse map, the free bitmap
    /// mirrors the free pool, and (outside ZNS) no die has eaten into
    /// its reserved headroom.
    pub fn check_invariants(&self) -> Result<(), String> {
        if self.l2p.len() != self.p2l.len() {
            return Err(format!("l2p {} != p2l {}", self.l2p.len(), self.p2l.len()));
        }
        // Iterate in key order (FastMap order is hasher-dependent) so
        // the first-reported inconsistency is deterministic: the
        // smallest offending lpn, not whichever bucket hashed first.
        for (&lpn, addr) in crate::util::sorted_pairs(&self.l2p) {
            match self.p2l.get(addr) {
                Some(&back) if back == lpn => {}
                other => return Err(format!("p2l mismatch for lpn {lpn}: {other:?}")),
            }
        }
        let mut counts: std::collections::BTreeMap<(usize, u32), u32> = Default::default();
        for (addr, _lpn) in crate::util::sorted_pairs(&self.p2l) {
            *counts.entry((self.cfg.die_index(addr), addr.block)).or_insert(0) += 1;
        }
        for (di, d) in self.dies.iter().enumerate() {
            for b in 0..self.cfg.blocks_per_die {
                let expect = counts.get(&(di, b)).copied().unwrap_or(0);
                if d.valid[b as usize] != expect {
                    return Err(format!(
                        "die {di} block {b}: valid {} != reverse-map {expect}",
                        d.valid[b as usize]
                    ));
                }
            }
            // The free pool is only meaningful outside ZNS (zones map
            // straight to blocks; the pool is never consulted there).
            if !self.cfg.zns {
                let set_bits = d.free.iter().filter(|&&f| f).count();
                if set_bits != d.free_blocks.len() {
                    return Err(format!(
                        "die {di}: free bitmap has {set_bits} bits but pool holds {}",
                        d.free_blocks.len()
                    ));
                }
                for &b in &d.free_blocks {
                    if !d.free[b as usize] {
                        return Err(format!("die {di}: pooled block {b} not set in bitmap"));
                    }
                    if d.valid[b as usize] != 0 {
                        return Err(format!(
                            "die {di}: free block {b} has {} valid pages",
                            d.valid[b as usize]
                        ));
                    }
                }
                if d.free_blocks.len() < self.headroom {
                    return Err(format!(
                        "die {di}: free pool {} below reserved headroom {}",
                        d.free_blocks.len(),
                        self.headroom
                    ));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop::{check, forall};

    fn tiny() -> (Ftl, FlashArray) {
        let cfg = FlashConfig::tiny();
        (Ftl::new(cfg.clone()), FlashArray::new(cfg))
    }

    /// One die, 8 blocks × 4 pages: the smallest geometry where the
    /// historical free-pool exhaustion was reachable.
    fn one_die() -> (Ftl, FlashArray) {
        let cfg = FlashConfig {
            channels: 1,
            dies_per_channel: 1,
            blocks_per_die: 8,
            pages_per_block: 4,
            page_bytes: 4096,
            ..FlashConfig::default()
        };
        (Ftl::new(cfg.clone()), FlashArray::new(cfg))
    }

    #[test]
    fn write_then_read_maps() {
        let (mut ftl, mut flash) = tiny();
        let t1 = ftl.write_page(0.0, &mut flash, 7);
        assert!(t1 > 0.0);
        assert!(ftl.lookup(7).is_some());
        let t2 = ftl.read_page(t1, &mut flash, 7);
        assert!(t2 > t1);
        ftl.check_invariants().unwrap();
    }

    #[test]
    fn unmapped_read_is_free() {
        let (mut ftl, mut flash) = tiny();
        let t = ftl.read_page(5.0, &mut flash, 999);
        assert_eq!(t, 5.0);
    }

    #[test]
    fn overwrite_invalidates_old() {
        let (mut ftl, mut flash) = tiny();
        ftl.write_page(0.0, &mut flash, 1);
        let first = ftl.lookup(1).unwrap();
        ftl.write_page(1.0, &mut flash, 1);
        let second = ftl.lookup(1).unwrap();
        assert_ne!(first, second);
        ftl.check_invariants().unwrap();
        assert_eq!(ftl.mapped_pages(), 1);
    }

    #[test]
    fn writes_stripe_across_dies() {
        let (mut ftl, mut flash) = tiny();
        ftl.write_page(0.0, &mut flash, 0);
        ftl.write_page(0.0, &mut flash, 1);
        let a = ftl.lookup(0).unwrap();
        let b = ftl.lookup(1).unwrap();
        assert_ne!(
            (a.channel, a.die),
            (b.channel, b.die),
            "consecutive writes land on different dies"
        );
    }

    #[test]
    fn gc_reclaims_space_under_overwrite_churn() {
        let (mut ftl, mut flash) = tiny();
        // Working set = 25% of capacity, overwritten many times: forces GC.
        let total_pages = FlashConfig::tiny().total_pages();
        let hot = total_pages / 4;
        let mut t = 0.0;
        for round in 0..12u64 {
            for lpn in 0..hot {
                t = ftl.write_page(t, &mut flash, lpn ^ (round % 2) * 3);
            }
        }
        let s = ftl.stats();
        assert!(s.gc_runs > 0, "GC must have run: {s:?}");
        assert!(s.waf() >= 1.0);
        ftl.check_invariants().unwrap();
    }

    #[test]
    fn trim_unmaps() {
        let (mut ftl, mut flash) = tiny();
        ftl.write_page(0.0, &mut flash, 3);
        ftl.trim(3);
        assert!(ftl.lookup(3).is_none());
        ftl.check_invariants().unwrap();
    }

    /// Regression (ISSUE-8): packing a die with cold, never-overwritten
    /// data used to send GC into a relocation livelock (every victim
    /// fully valid, nothing reclaimed, pool popped mid-pass) that ended
    /// in a panic. With the headroom reserve and fully-valid victims
    /// skipped, the same fill runs clean up to usable capacity.
    #[test]
    fn packed_die_does_not_exhaust_free_pool() {
        let (mut ftl, mut flash) = one_die();
        assert_eq!(ftl.headroom(), 1);
        // Usable capacity = (blocks − headroom) × pages = (8−1)×4 = 28.
        let mut t = 0.0;
        for lpn in 0..26u64 {
            t = ftl.write_page(t, &mut flash, lpn);
            assert!(
                ftl.dies[0].free_blocks.len() >= ftl.headroom(),
                "host write consumed the reserved headroom"
            );
        }
        assert_eq!(ftl.stats().gc_runs, 0, "nothing reclaimable: GC must not spin");
        assert_eq!(ftl.mapped_pages(), 26);
        ftl.check_invariants().unwrap();
    }

    /// Writing past usable capacity (all blocks valid, only headroom
    /// left) fails loudly instead of corrupting GC state.
    #[test]
    #[should_panic(expected = "over-full")]
    fn over_full_die_panics_cleanly() {
        let (mut ftl, mut flash) = one_die();
        let mut t = 0.0;
        for lpn in 0..29u64 {
            t = ftl.write_page(t, &mut flash, lpn);
        }
    }

    /// Churn right at the headroom boundary with the most aggressive
    /// (smallest) legal low-water setting: GC must keep reclaiming
    /// without ever draining the pool below the reserve.
    #[test]
    fn churn_at_minimum_low_water_respects_headroom() {
        let (mut ftl, mut flash) = one_die();
        ftl.gc_low_water = 1; // low_water() floors this to headroom + 1
        let mut t = 0.0;
        // 24 live pages = 86% of the 28 usable; 8 rounds of overwrites.
        for round in 0..8u64 {
            for lpn in 0..24u64 {
                t = ftl.write_page(t, &mut flash, lpn.wrapping_add(round) % 24);
            }
            assert!(ftl.dies[0].free_blocks.len() >= ftl.headroom());
            ftl.check_invariants().unwrap();
        }
        let s = ftl.stats();
        assert!(s.gc_runs > 0, "churn at 86% fill must trigger GC: {s:?}");
        assert!(s.blocks_erased > 0, "GC must have erased victims: {s:?}");
        assert!(s.waf() >= 1.0);
    }

    /// Regression (ISSUE-8): trimming twice is a no-op, not an
    /// underflow.
    #[test]
    fn double_trim_is_idempotent() {
        let (mut ftl, mut flash) = tiny();
        ftl.write_page(0.0, &mut flash, 3);
        ftl.trim(3);
        ftl.trim(3);
        assert!(ftl.lookup(3).is_none());
        ftl.check_invariants().unwrap();
    }

    /// Regression (ISSUE-8): a trim that hits a corrupted (already-zero)
    /// valid counter must fail with the FTL's own diagnostic, not a raw
    /// arithmetic overflow — and must saturate rather than wrap in
    /// release builds.
    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "valid-page underflow")]
    fn trim_after_counter_corruption_is_caught() {
        let (mut ftl, mut flash) = tiny();
        ftl.write_page(0.0, &mut flash, 5);
        let a = ftl.lookup(5).unwrap();
        let die = ftl.cfg.die_index(&a);
        ftl.dies[die].valid[a.block as usize] = 0; // simulated corruption
        ftl.trim(5);
    }

    #[test]
    fn property_l2p_bijective_under_random_ops() {
        forall("ftl mapping stays bijective", 60, |g| {
            let (mut ftl, mut flash) = tiny();
            let space = FlashConfig::tiny().total_pages() / 2;
            let ops = g.usize(1..=300);
            let mut t = 0.0;
            for _ in 0..ops {
                let lpn = g.u64(0..=space - 1);
                match g.u64(0..=9) {
                    0 => ftl.trim(lpn),
                    1..=2 => {
                        t = ftl.read_page(t, &mut flash, lpn);
                    }
                    _ => {
                        t = ftl.write_page(t, &mut flash, lpn);
                    }
                }
            }
            ftl.check_invariants()?;
            check(ftl.stats().waf() >= 1.0, "WAF below 1")?;
            Ok(())
        });
    }

    /// ISSUE-8 coverage: random write/trim/read churn at ≥90% of usable
    /// capacity across random geometries. The pool must never dip into
    /// the headroom, invariants must hold throughout, and WAF stays ≥ 1.
    /// (Geometry floor `blocks_per_die ≥ 12` guarantees a 90% fill is
    /// below the packed-die bound `(blocks − 1 − headroom) × pages`.)
    #[test]
    fn property_near_full_churn_respects_headroom() {
        forall("near-full ftl churn across geometries", 30, |g| {
            let cfg = FlashConfig {
                channels: g.u64(1..=2) as u16,
                dies_per_channel: g.u64(1..=2) as u16,
                blocks_per_die: g.u64(12..=20) as u32,
                pages_per_block: g.u64(4..=10) as u32,
                page_bytes: 4096,
                ..FlashConfig::default()
            };
            let mut ftl = Ftl::new(cfg.clone());
            let mut flash = FlashArray::new(cfg.clone());
            let usable = cfg.dies() as u64
                * (cfg.blocks_per_die as u64 - ftl.headroom() as u64)
                * cfg.pages_per_block as u64;
            let working = (usable * 9) / 10;
            let mut t = 0.0;
            // Fill to 90% of usable, then churn inside the working set.
            for lpn in 0..working {
                t = ftl.write_page(t, &mut flash, lpn);
            }
            let ops = g.usize(50..=400);
            for _ in 0..ops {
                let lpn = g.u64(0..=working - 1);
                match g.u64(0..=9) {
                    0 => ftl.trim(lpn),
                    1..=2 => {
                        t = ftl.read_page(t, &mut flash, lpn);
                    }
                    _ => {
                        t = ftl.write_page(t, &mut flash, lpn);
                    }
                }
            }
            for (di, d) in ftl.dies.iter().enumerate() {
                check(
                    d.free_blocks.len() >= ftl.headroom(),
                    &format!("die {di} dipped into headroom"),
                )?;
            }
            ftl.check_invariants()?;
            check(ftl.stats().waf() >= 1.0, "WAF below 1")?;
            Ok(())
        });
    }

    /// D1 regression (ISSUE-7): `check_invariants` walks the maps in
    /// key order, so the first-reported inconsistency is the *smallest*
    /// offending lpn — identical across runs and across hashers — not
    /// whichever bucket the hash function happened to visit first.
    #[test]
    fn invariant_errors_are_deterministic_and_smallest_lpn_first() {
        let corrupt = || {
            let (mut ftl, mut flash) = tiny();
            let mut t = 0.0;
            for lpn in 0..20u64 {
                t = ftl.write_page(t, &mut flash, lpn);
            }
            // Break the back-pointers of two mappings (lengths stay
            // equal, so the length precheck passes and the sorted walk
            // must find them).
            for lpn in [12u64, 5] {
                let addr = ftl.lookup(lpn).expect("mapped");
                ftl.p2l.insert(addr, 900 + lpn);
            }
            ftl.check_invariants().expect_err("corruption must be detected")
        };
        let a = corrupt();
        let b = corrupt();
        assert_eq!(a, b, "identical corruption must report identically");
        assert!(
            a.contains("lpn 5"),
            "smallest corrupted lpn must be reported first, got: {a}"
        );
    }

    #[test]
    fn wear_spread_reported() {
        let (mut ftl, mut flash) = tiny();
        let mut t = 0.0;
        for i in 0..2000u64 {
            t = ftl.write_page(t, &mut flash, i % 40);
        }
        // churn happened; spread is finite and small relative to erases
        let s = ftl.stats();
        if s.blocks_erased > 0 {
            assert!(ftl.wear_spread() <= s.blocks_erased as u32);
        }
    }

    #[test]
    fn background_collect_reclaims_on_idle_dies() {
        let (mut ftl, mut flash) = tiny();
        // Drive free pools below 2 × low_water with overwrite churn.
        let hot = FlashConfig::tiny().total_pages() / 3;
        let mut t = 0.0;
        for round in 0..4u64 {
            for lpn in 0..hot {
                t = ftl.write_page(t, &mut flash, lpn + (round % 2));
            }
        }
        let before = ftl.stats();
        // Far in the future every die is idle: background GC may run.
        ftl.background_collect(t + 100.0, &mut flash);
        let after = ftl.stats();
        assert!(
            after.background_gc_runs > before.background_gc_runs,
            "idle dies below the bg watermark must collect: {after:?}"
        );
        assert_eq!(after.host_pages_written, before.host_pages_written);
        ftl.check_invariants().unwrap();
        // While a die is busy (time cursor in the past), nothing runs.
        let busy = ftl.stats();
        ftl.background_collect(0.0, &mut flash);
        assert_eq!(ftl.stats().background_gc_runs, busy.background_gc_runs);
    }

    fn zns_tiny() -> (Ftl, FlashArray) {
        let cfg = FlashConfig { zns: true, ..FlashConfig::tiny() };
        (Ftl::new(cfg.clone()), FlashArray::new(cfg))
    }

    #[test]
    fn zns_write_read_roundtrip_waf_is_one() {
        let (mut ftl, mut flash) = zns_tiny();
        let mut t = 0.0;
        let pages = 3 * FlashConfig::tiny().pages_per_block as u64;
        // Two sequential passes over three zones: pass 2 resets each.
        for pass in 0..2u64 {
            for lpn in 0..pages {
                t = ftl.write_page(t, &mut flash, lpn);
            }
            let _ = pass;
        }
        let r = ftl.read_page(t, &mut flash, 1);
        assert!(r > t);
        let s = ftl.stats();
        assert_eq!(s.waf(), 1.0, "zns never relocates: {s:?}");
        assert_eq!(s.gc_runs, 0);
        assert_eq!(s.zone_resets, 3, "one reset per rewritten zone");
        ftl.check_invariants().unwrap();
    }

    #[test]
    fn zns_overwrite_resets_whole_zone() {
        let (mut ftl, mut flash) = zns_tiny();
        let ppb = FlashConfig::tiny().pages_per_block as u64;
        let mut t = 0.0;
        for lpn in 0..ppb {
            t = ftl.write_page(t, &mut flash, lpn);
        }
        // Rewriting page 0 resets zone 0: siblings become unmapped.
        ftl.write_page(t, &mut flash, 0);
        assert!(ftl.lookup(0).is_some());
        for lpn in 1..ppb {
            assert!(ftl.lookup(lpn).is_none(), "zone reset must unmap lpn {lpn}");
        }
        assert_eq!(ftl.stats().zone_resets, 1);
        ftl.check_invariants().unwrap();
    }
}
