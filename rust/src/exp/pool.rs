//! Deterministic parallel sweep runner.
//!
//! The figure/table drivers in [`crate::exp`] are embarrassingly
//! parallel: every sweep cell is an independent virtual-time run that
//! owns its [`crate::metrics::Metrics`] and its simulated
//! [`crate::cluster::StorageServer`]. This module fans those cells out
//! over a fixed-size pool of `std::thread` workers while keeping the
//! output *deterministic*: results come back in input order, and each
//! cell's simulation is bit-identical to a sequential run (the simulator
//! shares no mutable state across cells).
//!
//! Pool size resolution, highest precedence first:
//!
//! 1. [`set_threads`] (the CLI's `--threads`, benches comparing modes);
//! 2. the `SOLANA_THREADS` environment variable;
//! 3. `std::thread::available_parallelism()`.
//!
//! `set_threads(0)` clears the override, falling back to 2 and 3.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Process-wide override set by [`set_threads`]; 0 means "not set".
static THREAD_OVERRIDE: AtomicUsize = AtomicUsize::new(0);

/// Override the worker-pool size for subsequent sweeps (0 clears the
/// override). Thread counts never change simulated results — only
/// wall-clock — so racing overrides from concurrent tests are benign.
pub fn set_threads(n: usize) {
    THREAD_OVERRIDE.store(n, Ordering::SeqCst);
}

/// The worker-pool size the next sweep will use (see module docs for
/// the precedence order).
pub fn pool_size() -> usize {
    let o = THREAD_OVERRIDE.load(Ordering::SeqCst);
    if o > 0 {
        return o;
    }
    if let Ok(s) = std::env::var("SOLANA_THREADS") {
        if let Ok(n) = s.trim().parse::<usize>() {
            if n >= 1 {
                return n;
            }
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Run `f` over every input on the worker pool; the result vector is in
/// input order regardless of which worker finished when. Each slot holds
/// that cell's own `Result` — one failing cell does not poison its
/// neighbours (the caller decides whether to bail).
///
/// Work is pulled from a shared cursor, so long cells never leave
/// workers idle behind a static partition.
pub fn map_cells<I, T, F>(inputs: Vec<I>, f: F) -> Vec<anyhow::Result<T>>
where
    I: Send,
    T: Send,
    F: Fn(I) -> anyhow::Result<T> + Sync,
{
    let n = inputs.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = pool_size().min(n);
    if workers <= 1 {
        return inputs.into_iter().map(f).collect();
    }
    let jobs: Vec<Mutex<Option<I>>> = inputs.into_iter().map(|i| Mutex::new(Some(i))).collect();
    let slots: Vec<Mutex<Option<anyhow::Result<T>>>> = (0..n).map(|_| Mutex::new(None)).collect();
    let cursor = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::SeqCst);
                if i >= n {
                    break;
                }
                let input = jobs[i]
                    .lock()
                    // solana-lint: allow(no-unwrap, reason = "a poisoned mutex means a worker already panicked; the pool cannot recover and propagating the panic is the correct behavior")
                    .expect("job mutex")
                    .take()
                    // solana-lint: allow(no-unwrap, reason = "the SeqCst cursor hands index i to exactly one worker, so the job is still present")
                    .expect("each job is taken exactly once");
                let out = f(input);
                // solana-lint: allow(no-unwrap, reason = "a poisoned mutex means a worker already panicked; the pool cannot recover and propagating the panic is the correct behavior")
                *slots[i].lock().expect("slot mutex") = Some(out);
            });
        }
    });
    slots
        .into_iter()
        .map(|m| {
            m.into_inner()
                // solana-lint: allow(no-unwrap, reason = "a poisoned mutex means a worker already panicked; the pool cannot recover and propagating the panic is the correct behavior")
                .expect("slot mutex")
                // solana-lint: allow(no-unwrap, reason = "scope() joined every worker, and each claimed index filled its slot before exiting")
                .expect("every claimed slot was filled")
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_come_back_in_input_order() {
        let inputs: Vec<u64> = (0..100).collect();
        let out = map_cells(inputs, |i| {
            // Finish out of order on purpose.
            if i % 7 == 0 {
                std::thread::sleep(std::time::Duration::from_micros(200));
            }
            Ok(i * 2)
        });
        let vals: Vec<u64> = out.into_iter().map(|r| r.unwrap()).collect();
        assert_eq!(vals, (0..100).map(|i| i * 2).collect::<Vec<u64>>());
    }

    #[test]
    fn errors_stay_in_their_slot() {
        let out = map_cells(vec![1u64, 0, 3], |i| {
            anyhow::ensure!(i != 0, "zero cell");
            Ok(i)
        });
        assert!(out[0].is_ok());
        assert!(out[1].is_err());
        assert!(out[2].is_ok());
    }

    #[test]
    fn empty_input_is_fine() {
        let out: Vec<anyhow::Result<u64>> = map_cells(Vec::<u64>::new(), |i| Ok(i));
        assert!(out.is_empty());
    }

    #[test]
    fn pool_size_is_at_least_one() {
        assert!(pool_size() >= 1);
    }

    #[test]
    fn non_send_free_inputs_move_through() {
        // Heap-owning inputs and outputs move across the pool intact.
        let inputs: Vec<String> = (0..16).map(|i| format!("cell-{i}")).collect();
        let out = map_cells(inputs, |s| Ok(s + "!"));
        for (i, r) in out.into_iter().enumerate() {
            assert_eq!(r.unwrap(), format!("cell-{i}!"));
        }
    }
}
