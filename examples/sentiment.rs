//! Sentiment analysis end to end: train the logistic-regression model
//! with the AOT SGD-step executable, measure accuracy on held-out
//! tweets, then simulate the paper's 8M-tweet cluster run (Fig 5(c)).
//!
//! ```bash
//! make artifacts && cargo run --release --example sentiment
//! ```

use solana_isp::metrics::Metrics;
use solana_isp::nlp::corpus::TweetCorpus;
use solana_isp::power::PowerModel;
use solana_isp::runtime::Engine;
use solana_isp::sched::{run, SchedConfig};
use solana_isp::workloads::{AppModel, SentimentApp};

fn main() -> anyhow::Result<()> {
    let Some(mut eng) = Engine::load_default() else {
        anyhow::bail!("run `make artifacts` first");
    };

    // --- real training through the AOT train-step ---------------------
    let mut corpus = TweetCorpus::new(1);
    let train = corpus.take(8_192);
    let test = corpus.take(2_048);
    println!("training on {} tweets (AOT SGD step, batch 256)…", train.len());
    let t0 = std::time::Instant::now();
    let (app, losses) = SentimentApp::train(&mut eng, &train, 3, 9)?;
    println!(
        "trained in {:.2}s wall — loss {:.3} → {:.3}",
        t0.elapsed().as_secs_f64(),
        losses.first().unwrap(),
        losses.last().unwrap()
    );
    let acc = app.accuracy(&mut eng, &test)?;
    println!("held-out accuracy: {:.1}% ({} tweets)", acc * 100.0, test.len());
    anyhow::ensure!(acc > 0.85, "model under-trained: {acc}");

    // A few live predictions.
    for text in [
        "what a fantastic wonderful day i love this",
        "terrible awful waste of time i regret everything",
    ] {
        let p = app.predict(&mut eng, &[text])?[0];
        println!("  P(positive)={p:.2}  \"{text}\"");
    }

    // --- cluster simulation: Fig 5(c) headline ------------------------
    println!("\nsimulating 8,000,000 tweets on the 36-CSD server…");
    let model = AppModel::sentiment(8_000_000);
    let power = PowerModel::default();
    let mut m = Metrics::new();
    let cfg = SchedConfig { csd_batch: 40_000, batch_ratio: 26.0, ..SchedConfig::default() };
    let base = run(&model, &SchedConfig { isp_drives: 0, ..cfg.clone() }, &power, &mut m)?;
    let isp = run(&model, &cfg, &power, &mut m)?;
    println!(
        "host-only : {:.0} queries/s   (paper:  9496 q/s)",
        base.items_per_sec
    );
    println!(
        "36 CSDs   : {:.0} queries/s   (paper: 20994 q/s) — speedup {:.2}x (paper 2.2x)",
        isp.items_per_sec,
        isp.items_per_sec / base.items_per_sec
    );
    println!(
        "energy/query: {:.1} mJ → {:.1} mJ (paper: 51 → 23 mJ)",
        base.energy_per_item_j * 1e3,
        isp.energy_per_item_j * 1e3
    );
    Ok(())
}
