//! `cargo bench --bench table1_summary` — regenerates Table I: summary of experimental results
//!
//! Scale with `SOLANA_BENCH_FAST=1` (5%) or default 25% of the paper's
//! dataset sizes; the *shape* (who wins, by what factor, where the
//! crossovers fall) is scale-invariant. See EXPERIMENTS.md.

use solana_isp::bench_support::Bencher;
use solana_isp::exp::{self, Scale};
#[allow(unused_imports)]
use solana_isp::workloads::App;

fn main() -> anyhow::Result<()> {
    let scale = Scale::from_env();
    let table = exp::table1(scale)?;
    exp::emit(&table, "table1")?;
    // Wall-time of regenerating the artifact (simulator throughput):
    let mut b = Bencher::new(0, if std::env::var("SOLANA_BENCH_FAST").is_ok() { 1 } else { 2 });
    b.bench("table1_summary", || {
        let t = exp::table1(scale).expect("rerun");
        t.rows.len() as u64
    });
    print!("{}", b.report());
    b.write_json("table1_summary")?;
    Ok(())
}
