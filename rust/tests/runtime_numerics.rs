//! Integration: the PJRT runtime executes real AOT artifacts and the
//! numbers match Rust-side oracles.
//!
//! These tests skip (with a note) when `artifacts/` hasn't been built —
//! the Makefile runs `make artifacts` before `cargo test`.

use solana_isp::runtime::{Engine, Tensor};
use solana_isp::util::Rng;

fn engine() -> Option<Engine> {
    Engine::load_default()
}

/// Deterministic pseudo-random tensor.
fn rand_tensor(rng: &mut Rng, shape: Vec<usize>, scale: f32) -> Tensor {
    let n: usize = shape.iter().product();
    let data = (0..n).map(|_| (rng.f64() as f32 - 0.5) * 2.0 * scale).collect();
    Tensor::new(shape, data)
}

#[test]
fn sentiment_infer_matches_rust_oracle() {
    let Some(mut eng) = engine() else { return };
    let f = eng.manifest.dim("sent_features").unwrap() as usize;
    let b = 32usize;
    let mut rng = Rng::new(42);
    let x = rand_tensor(&mut rng, vec![b, f], 1.0);
    let w = rand_tensor(&mut rng, vec![f, 1], 0.05);
    let bias = Tensor::new(vec![1], vec![0.1]);
    let out = eng.run("sentiment_infer", "b32", &[x.clone(), w.clone(), bias.clone()]).unwrap();
    assert_eq!(out.len(), 1);
    let probs = &out[0];
    assert_eq!(probs.shape, vec![b]);
    // Rust oracle: sigmoid(x @ w + b)
    for i in 0..b {
        let mut logit = 0.1f64;
        for j in 0..f {
            logit += (x.data[i * f + j] * w.data[j]) as f64;
        }
        let expect = 1.0 / (1.0 + (-logit).exp());
        let got = probs.data[i] as f64;
        assert!(
            (got - expect).abs() < 1e-4,
            "row {i}: got {got}, expect {expect}"
        );
    }
}

#[test]
fn sentiment_train_step_decreases_loss() {
    let Some(mut eng) = engine() else { return };
    let f = eng.manifest.dim("sent_features").unwrap() as usize;
    let b = eng.manifest.dim("sent_train_batch").unwrap() as usize;
    let mut rng = Rng::new(7);
    // Separable data: feature 0 => positive, feature 1 => negative.
    let mut x = Tensor::zeros(vec![b, f]);
    let mut y = Tensor::zeros(vec![b]);
    for i in 0..b {
        let pos = rng.chance(0.5);
        y.data[i] = if pos { 1.0 } else { 0.0 };
        x.data[i * f + usize::from(!pos)] = 1.0;
    }
    let mut w = Tensor::zeros(vec![f, 1]);
    let mut bias = Tensor::zeros(vec![1]);
    let lr = Tensor::scalar(5.0);
    let mut losses = Vec::new();
    for _ in 0..10 {
        let out = eng
            .run(
                "sentiment_train_step",
                &format!("b{b}"),
                &[x.clone(), y.clone(), w, bias, lr.clone()],
            )
            .unwrap();
        assert_eq!(out.len(), 3);
        w = out[0].clone();
        bias = out[1].clone();
        losses.push(out[2].data[0]);
    }
    assert!(
        losses.last().unwrap() < &(losses[0] * 0.5),
        "loss should halve: {losses:?}"
    );
}

#[test]
fn recommender_topk_puts_self_first() {
    let Some(mut eng) = engine() else { return };
    let n = eng.manifest.dim("rec_items").unwrap() as usize;
    let d = eng.manifest.dim("rec_dim").unwrap() as usize;
    let k = eng.manifest.dim("rec_topk").unwrap() as usize;
    let mut rng = Rng::new(3);
    // Unit-normalized random rows.
    let mut m = rand_tensor(&mut rng, vec![n, d], 1.0);
    for i in 0..n {
        let row = &mut m.data[i * d..(i + 1) * d];
        let norm = row.iter().map(|v| v * v).sum::<f32>().sqrt().max(1e-8);
        row.iter_mut().for_each(|v| *v /= norm);
    }
    let pop = Tensor::new(vec![n], vec![1.0; n]);
    let probe = 12_345usize % n;
    let q = Tensor::new(vec![1, d], m.row(probe).to_vec());
    let out = eng.run("recommender_topk", "q1", &[m, pop, q]).unwrap();
    assert_eq!(out.len(), 2);
    let (vals, idx) = (&out[0], &out[1]);
    assert_eq!(vals.shape, vec![1, k]);
    assert_eq!(idx.shape, vec![1, k]);
    assert!(idx.was_i32);
    assert_eq!(idx.as_i32()[0] as usize, probe, "self is most similar");
    // scores descending
    for w in vals.data.windows(2) {
        assert!(w[0] >= w[1] - 1e-6);
    }
}

#[test]
fn acoustic_forward_emits_log_distributions() {
    let Some(mut eng) = engine() else { return };
    let t = eng.manifest.dim("speech_frames").unwrap() as usize;
    let f = eng.manifest.dim("speech_features").unwrap() as usize;
    let h = eng.manifest.dim("speech_hidden").unwrap() as usize;
    let v = eng.manifest.dim("speech_vocab").unwrap() as usize;
    let mut rng = Rng::new(9);
    let frames = rand_tensor(&mut rng, vec![t, f], 1.0);
    let w1 = rand_tensor(&mut rng, vec![f, h], 0.1);
    let b1 = Tensor::zeros(vec![h]);
    let w2 = rand_tensor(&mut rng, vec![h, h], 0.1);
    let b2 = Tensor::zeros(vec![h]);
    let w3 = rand_tensor(&mut rng, vec![h, v], 0.1);
    let b3 = Tensor::zeros(vec![v]);
    let out = eng
        .run(
            "acoustic_forward",
            &format!("t{t}"),
            &[frames, w1, b1, w2, b2, w3, b3],
        )
        .unwrap();
    let lp = &out[0];
    assert_eq!(lp.shape, vec![t, v]);
    for row in 0..t {
        let s: f64 = lp.data[row * v..(row + 1) * v]
            .iter()
            .map(|&l| (l as f64).exp())
            .sum();
        assert!((s - 1.0).abs() < 1e-3, "row {row} sums to {s}");
    }
}

#[test]
fn input_validation_rejects_wrong_shapes() {
    let Some(mut eng) = engine() else { return };
    let bad = Tensor::zeros(vec![2, 2]);
    let err = eng
        .run("sentiment_infer", "b32", &[bad.clone(), bad.clone(), bad])
        .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("shape"), "{msg}");
}

#[test]
fn executable_cache_reuses_compilation() {
    let Some(mut eng) = engine() else { return };
    let f = eng.manifest.dim("sent_features").unwrap() as usize;
    let mut rng = Rng::new(1);
    let x = rand_tensor(&mut rng, vec![32, f], 1.0);
    let w = Tensor::zeros(vec![f, 1]);
    let b = Tensor::zeros(vec![1]);
    let t0 = std::time::Instant::now();
    eng.run("sentiment_infer", "b32", &[x.clone(), w.clone(), b.clone()]).unwrap();
    let first = t0.elapsed();
    let t1 = std::time::Instant::now();
    for _ in 0..5 {
        eng.run("sentiment_infer", "b32", &[x.clone(), w.clone(), b.clone()]).unwrap();
    }
    let rest = t1.elapsed() / 5;
    assert!(rest < first, "cached executions ({rest:?}) beat compile+run ({first:?})");
    assert_eq!(eng.executions(), 6);
}
