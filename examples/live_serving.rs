//! Live serving demo: the scheduler protocol on REAL threads with REAL
//! PJRT inference — rank 0 (coordinator/host) trains the sentiment model
//! via the AOT SGD step, broadcasts weights to worker ranks (stand-ins
//! for ISP engines, each with its own PJRT runtime), and drives the
//! paper's pull/ack, index-only dispatch protocol until every tweet is
//! served exactly once.
//!
//! ```bash
//! make artifacts && cargo run --release --example live_serving
//! ```

use std::time::Duration;

use solana_isp::sched::live::{run_live, LiveConfig};

fn main() -> anyhow::Result<()> {
    let cfg = LiveConfig {
        workers: 3,
        batch: 64,
        ratio: 4,
        items: 8_192,
        train_items: 4_096,
        wakeup: Duration::from_millis(200),
        seed: 21,
        // The paper's 0.2 s polling grid; pass
        // `dispatch: DispatchMode::EventDriven` to re-arm workers the
        // moment each RESULT arrives (`solana ablate --which dispatch`
        // quantifies the difference in the simulator).
        ..LiveConfig::default()
    };
    println!(
        "live cluster: 1 coordinator + {} workers, {} tweets, batch {} (host x{})\n",
        cfg.workers, cfg.items, cfg.batch, cfg.ratio
    );
    let r = run_live(&cfg)?;
    println!("served      : {} tweets in {:.2}s wall", r.items, r.wall_secs);
    println!("throughput  : {:.0} tweets/s (real PJRT inference)", r.items_per_sec);
    println!("host items  : {}", r.host_items);
    for (i, n) in r.worker_items.iter().enumerate() {
        println!("worker {i}    : {n}");
    }
    println!("accuracy    : {:.1}%", r.accuracy * 100.0);
    println!("mpi messages: {}", r.messages);
    anyhow::ensure!(r.accuracy > 0.85);
    Ok(())
}
